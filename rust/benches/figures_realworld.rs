//! Bench: the real-world benchmark figures (15–18) — generator + cell
//! pipeline per family, plus generator-only cases (FFT/GE/MD/EW structure
//! construction).

use ceft::exp::cells::{realworld_grid, RealWorld, Scale};
use ceft::exp::run::{run_realworld_cell, run_realworld_sweep};
use ceft::graph::realworld;
use ceft::util::bench::{black_box, Bench};
use ceft::util::pool;

fn main() {
    let mut b = Bench::new("figures_realworld");

    b.case("structure/fft_64", || {
        black_box(realworld::fft(64));
    });
    b.case("structure/ge_32", || {
        black_box(realworld::gaussian_elimination(32));
    });
    b.case("structure/md", || {
        black_box(realworld::molecular_dynamics());
    });
    b.case("structure/ew_64", || {
        black_box(realworld::epigenomics(64));
    });

    for fam in RealWorld::ALL {
        let cells = realworld_grid(fam, Scale::Smoke);
        b.case(&format!("cell/{}", fam.name()), || {
            black_box(run_realworld_cell(&cells[0]));
        });
        b.case(&format!("sweep/{}x{}", fam.name(), cells.len()), || {
            black_box(run_realworld_sweep(&cells, pool::default_threads(), false));
        });
    }
    b.save_csv();
}
