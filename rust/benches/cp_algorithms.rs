//! Bench: critical-path algorithms (CEFT vs CPOP-CP vs min-exec vs CP_MIN)
//! across graph sizes and class counts. The paper's complexity claim is
//! O(P²e) for CEFT vs O(Pe)-ish for the mean-value ranks; this bench makes
//! the constant factors visible and tracks the DP's cells/second.

use ceft::cp::ceft::find_critical_path;
use ceft::cp::cpmin::cp_min_cost;
use ceft::cp::minexec::min_exec_critical_path;
use ceft::cp::ranks::cpop_critical_path;
use ceft::graph::generator::{generate, RggParams};
use ceft::platform::{CostModel, Platform};
use ceft::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("cp_algorithms");
    for &(n, p) in &[(128usize, 8usize), (1024, 8), (4096, 8), (1024, 2), (1024, 64)] {
        let plat = Platform::uniform(p, 1.0, 0.0);
        let inst = generate(
            &RggParams {
                n,
                out_degree: 4,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.25,
            },
            &CostModel::Classic { beta: 0.5 },
            &plat,
            42,
        );
        let iref = inst.bind(&plat);
        let e = inst.graph.num_edges() as u64;
        let cells = e * (p * p) as u64;
        b.case_with_elements(&format!("ceft/n{n}_p{p}"), Some(cells), || {
            black_box(find_critical_path(iref));
        });
        b.case(&format!("cpop_cp/n{n}_p{p}"), || {
            black_box(cpop_critical_path(iref));
        });
        b.case(&format!("minexec/n{n}_p{p}"), || {
            black_box(min_exec_critical_path(iref, false));
        });
        b.case(&format!("cp_min/n{n}_p{p}"), || {
            black_box(cp_min_cost(iref));
        });
    }
    b.save_csv();
}
