//! Bench: the blocked min-plus CEFT kernel vs the scalar reference DP.
//!
//! Every path fills the same workspace table over the same instance, so
//! the per-case "Melem/s" column (relaxed `(j, l)` class-pair cells per
//! second = `e · P²` per iteration) is directly comparable across
//! `kernel/*`, `kernel_ctx/*` (fused kernel over resident `PlatformCtx`
//! panels — no per-entry panel fill), `batched_b8/*` (the min-plus
//! matrix-matrix DP, chunk size 8) and `scalar/*` rows. Protocol and
//! block-size rationale: EXPERIMENTS.md §Min-plus kernel and §Platform
//! contexts. `CEFT_BENCH_FAST=1` is the CI smoke mode (`ci.sh`).

use ceft::cp::ceft::{
    ceft_table_batched_into, ceft_table_into, ceft_table_rev_into, ceft_table_rev_scalar_into,
    ceft_table_scalar_into,
};
use ceft::cp::workspace::Workspace;
use ceft::graph::generator::{generate, RggParams};
use ceft::model::PlatformCtx;
use ceft::platform::{CostModel, Platform};
use ceft::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("ceft_kernel");
    // class counts span the panel-size regimes: tiny rows (P=2), the
    // paper's common case (P=8), and panel footprints past L1-resident
    // rows (P=64)
    for &(n, p) in &[
        (512usize, 2usize),
        (1024, 8),
        (4096, 8),
        (1024, 16),
        (512, 64),
    ] {
        let plat = Platform::uniform(p, 1.0, 0.0);
        let inst = generate(
            &RggParams {
                n,
                out_degree: 4,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.25,
            },
            &CostModel::Classic { beta: 0.5 },
            &plat,
            42,
        );
        let iref = inst.bind(&plat);
        let ctx = PlatformCtx::new(plat.clone());
        let cref = inst.bind_ctx(&ctx);
        let cells = inst.graph.num_edges() as u64 * (p * p) as u64;
        let mut ws = Workspace::new();
        b.case_with_elements(&format!("kernel/n{n}_p{p}"), Some(cells), || {
            ceft_table_into(&mut ws, iref);
            black_box(ws.table.last().copied());
        });
        b.case_with_elements(&format!("kernel_ctx/n{n}_p{p}"), Some(cells), || {
            ceft_table_into(&mut ws, cref);
            black_box(ws.table.last().copied());
        });
        b.case_with_elements(&format!("batched_b8/n{n}_p{p}"), Some(cells), || {
            ceft_table_batched_into(&mut ws, cref, 8);
            black_box(ws.table.last().copied());
        });
        b.case_with_elements(&format!("scalar/n{n}_p{p}"), Some(cells), || {
            ceft_table_scalar_into(&mut ws, iref);
            black_box(ws.table.last().copied());
        });
        b.case_with_elements(&format!("kernel_rev/n{n}_p{p}"), Some(cells), || {
            ceft_table_rev_into(&mut ws, iref);
            black_box(ws.table.last().copied());
        });
        b.case_with_elements(&format!("scalar_rev/n{n}_p{p}"), Some(cells), || {
            ceft_table_rev_scalar_into(&mut ws, iref);
            black_box(ws.table.last().copied());
        });
    }
    b.save_csv();
}
