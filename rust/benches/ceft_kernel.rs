//! Bench: the blocked min-plus CEFT kernel vs the scalar reference DP.
//!
//! Every path fills the same workspace table over the same instance, so
//! the per-case "Melem/s" column (relaxed `(j, l)` class-pair cells per
//! second = `e · P²` per iteration) is directly comparable across
//! `kernel/*` (env-dispatched, workspace panels), `kernel_ctx/*`
//! (env-dispatched over resident `PlatformCtx` panels), `simd/*` /
//! `forced_scalar_lanes/*` (lane implementation pinned explicitly,
//! resident panels — the pair the SIMD speedup is read from),
//! `batched_b8/*` (the min-plus matrix-matrix DP, chunk size 8),
//! `gathered_tables/*` (the multi-instance table sweep the service
//! engine's cross-request batcher drains — four same-platform instances
//! per dispatch, cells summed across the window), `scalar/*` (the
//! scalar-recurrence oracle) and `telemetry_overhead/*`
//! (fused kernel with the `crate::obs` KernelTimer forced on vs off — the
//! per-dispatch hook cost) rows. Protocol and block-size rationale:
//! EXPERIMENTS.md §Min-plus kernel, §Platform contexts, §SIMD dispatch
//! and §Telemetry. `CEFT_BENCH_FAST=1` is the CI smoke mode (`ci.sh`,
//! which runs it under both `CEFT_FORCE_SCALAR` settings).
//!
//! Besides the CSV every bench appends, this bench writes the repo-root
//! `BENCH_kernel.json` — per-case cells/s for the `scalar`, `simd`,
//! `batched_b8`, `gathered_tables`, `delta_suffix/{10,50,90}pct`
//! (dirty-suffix incremental recompute against a memoized basis) and
//! `sp_tree_{fork_join,pipeline}` (the series-parallel tree-DP kernel
//! over recognizer-decomposed structured instances of matching size)
//! rows plus the `telemetry` on/off pair — seeding the
//! kernel-throughput trajectory across PRs (the acceptance gauge is
//! `simd >= scalar` at `P >= 8`).

use ceft::cp::ceft::simd::KernelDispatch;
use ceft::cp::ceft::{
    ceft_table_batched_into, ceft_table_delta_into, ceft_table_into, ceft_table_into_dispatched,
    ceft_table_rev_into, ceft_table_rev_scalar_into, ceft_table_scalar_into, ceft_table_with,
    find_ceft_tables_gathered, DeltaPlan,
};
use ceft::cp::ceft::sp::ceft_table_sp_into;
use ceft::cp::workspace::Workspace;
use ceft::graph::generator::{generate, generate_fork_join, generate_pipeline, RggParams};
use ceft::graph::shape;
use ceft::model::PlatformCtx;
use ceft::platform::{CostModel, Platform};
use ceft::util::bench::{black_box, Bench};
use ceft::util::json::Json;

fn main() {
    let mut b = Bench::new("ceft_kernel");
    let mut report_cases: Vec<Json> = Vec::new();
    // class counts span the panel-size regimes: tiny rows (P=2), the
    // paper's common case (P=8), and panel footprints past L1-resident
    // rows (P=64)
    for &(n, p) in &[
        (512usize, 2usize),
        (1024, 8),
        (4096, 8),
        (1024, 16),
        (512, 64),
    ] {
        let plat = Platform::uniform(p, 1.0, 0.0);
        let inst = generate(
            &RggParams {
                n,
                out_degree: 4,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.25,
            },
            &CostModel::Classic { beta: 0.5 },
            &plat,
            42,
        );
        let iref = inst.bind(&plat);
        let ctx = PlatformCtx::new(plat.clone());
        let cref = inst.bind_ctx(&ctx);
        let cells = inst.graph.num_edges() as u64 * (p * p) as u64;
        let mut ws = Workspace::new();
        b.case_with_elements(&format!("kernel/n{n}_p{p}"), Some(cells), || {
            ceft_table_into(&mut ws, iref);
            black_box(ws.table.last().copied());
        });
        b.case_with_elements(&format!("kernel_ctx/n{n}_p{p}"), Some(cells), || {
            ceft_table_into(&mut ws, cref);
            black_box(ws.table.last().copied());
        });
        // the SIMD-vs-scalar pair the speedup gauge reads: lane choice
        // pinned explicitly, both over the same resident panels
        let simd_row = b.case_with_elements(&format!("simd/n{n}_p{p}"), Some(cells), || {
            ceft_table_into_dispatched(&mut ws, cref, KernelDispatch::Simd);
            black_box(ws.table.last().copied());
        });
        b.case_with_elements(&format!("forced_scalar_lanes/n{n}_p{p}"), Some(cells), || {
            ceft_table_into_dispatched(&mut ws, cref, KernelDispatch::Scalar);
            black_box(ws.table.last().copied());
        });
        let batched_row = b.case_with_elements(&format!("batched_b8/n{n}_p{p}"), Some(cells), || {
            ceft_table_batched_into(&mut ws, cref, 8);
            black_box(ws.table.last().copied());
        });
        // the engine's batch-drain shape: one gathered sweep producing a
        // full table per instance for a window of four same-platform
        // instances (distinct seeds); throughput is summed window cells,
        // so the row is directly comparable to the single-instance ones
        let ginsts: Vec<_> = (0..4u64)
            .map(|s| {
                generate(
                    &RggParams {
                        n,
                        out_degree: 4,
                        ccr: 1.0,
                        alpha: 0.5,
                        beta_pct: 50.0,
                        gamma: 0.25,
                    },
                    &CostModel::Classic { beta: 0.5 },
                    &plat,
                    42 + s,
                )
            })
            .collect();
        let grefs: Vec<_> = ginsts.iter().map(|i| i.bind_ctx(&ctx)).collect();
        let gcells: u64 = ginsts
            .iter()
            .map(|i| i.graph.num_edges() as u64 * (p * p) as u64)
            .sum();
        let gathered_row =
            b.case_with_elements(&format!("gathered_tables/n{n}_p{p}"), Some(gcells), || {
                let tables = find_ceft_tables_gathered(&ctx, &grefs, false);
                black_box(tables.last().and_then(|t| t.table.last().copied()));
            });
        let scalar_row = b.case_with_elements(&format!("scalar/n{n}_p{p}"), Some(cells), || {
            ceft_table_scalar_into(&mut ws, iref);
            black_box(ws.table.last().copied());
        });
        // Incremental recompute economy: dirty the last {10,50,90}% of the
        // topological order and re-run the delta kernel against the
        // memoized basis. Elements are the class-pair cells of the dirty
        // suffix only (in-edges of suffix tasks × P²), so cells/s stays
        // comparable to the full-table rows while the wall time shrinks
        // with the suffix — the rows BENCH_kernel.json tracks across PRs
        // (EXPERIMENTS.md §Incremental re-scheduling).
        let basis = {
            let mut bws = Workspace::new();
            ceft_table_with(&mut bws, cref)
        };
        let topo = inst.graph.topo_order();
        let mut delta_rates = [0.0f64; 3];
        for (slot, &pct) in [10usize, 50, 90].iter().enumerate() {
            let cut = n - (n * pct) / 100;
            let mut dirty = vec![false; n];
            for &t in &topo[cut..] {
                dirty[t] = true;
            }
            let in_suffix = |t: usize| dirty[t];
            let dcells = (inst
                .graph
                .edges()
                .iter()
                .filter(|e| in_suffix(e.dst))
                .count() as u64
                * (p * p) as u64)
                .max(1);
            let row = b.case_with_elements(
                &format!("delta_suffix/{pct}pct_n{n}_p{p}"),
                Some(dcells),
                || {
                    let plan = DeltaPlan {
                        prev: &basis,
                        prev_topo: topo,
                        basis_n: n,
                        dirty: &dirty,
                    };
                    let rows = ceft_table_delta_into(&mut ws, cref, &plan, false);
                    black_box(rows);
                },
            );
            delta_rates[slot] = row.throughput().unwrap_or(0.0);
        }
        // Structured-graph fast path: fork-join and pipeline instances of
        // matching size, decomposed once by the recognizer, swept by the
        // series-parallel tree-DP kernel. Cells are the same e·P² measure,
        // so the rows are directly comparable to the general-kernel ones —
        // the win comes from the SpTree visit order and the specialized
        // in-degree-1 fold (EXPERIMENTS.md §Structured-graph fast paths).
        let fj_depth = ((n.saturating_sub(1)) / 5).max(1);
        let fj_inst = generate_fork_join(
            4,
            fj_depth,
            1.0,
            50.0,
            &CostModel::Classic { beta: 0.5 },
            &plat,
            42,
        );
        let fj_sp = shape::recognize(&fj_inst.graph)
            .sp
            .expect("generated fork-join must be recognized as series-parallel");
        let fj_ref = fj_inst.bind_ctx(&ctx);
        let fj_cells = fj_inst.graph.num_edges() as u64 * (p * p) as u64;
        let fj_row = b.case_with_elements(
            &format!("sp_tree/fork_join_n{n}_p{p}"),
            Some(fj_cells),
            || {
                ceft_table_sp_into(&mut ws, fj_ref, &fj_sp);
                black_box(ws.table.last().copied());
            },
        );
        let pl_stages = ((n.saturating_sub(2)) / 4).max(1);
        let pl_inst = generate_pipeline(
            pl_stages,
            4,
            1.0,
            50.0,
            &CostModel::Classic { beta: 0.5 },
            &plat,
            42,
        );
        let pl_sp = shape::recognize(&pl_inst.graph)
            .sp
            .expect("generated pipeline must be recognized as series-parallel");
        let pl_ref = pl_inst.bind_ctx(&ctx);
        let pl_cells = pl_inst.graph.num_edges() as u64 * (p * p) as u64;
        let pl_row = b.case_with_elements(
            &format!("sp_tree/pipeline_n{n}_p{p}"),
            Some(pl_cells),
            || {
                ceft_table_sp_into(&mut ws, pl_ref, &pl_sp);
                black_box(ws.table.last().copied());
            },
        );
        b.case_with_elements(&format!("kernel_rev/n{n}_p{p}"), Some(cells), || {
            ceft_table_rev_into(&mut ws, iref);
            black_box(ws.table.last().copied());
        });
        b.case_with_elements(&format!("scalar_rev/n{n}_p{p}"), Some(cells), || {
            ceft_table_rev_scalar_into(&mut ws, iref);
            black_box(ws.table.last().copied());
        });
        // telemetry on/off A/B around the fused kernel: the KernelTimer
        // (two clock reads + three relaxed atomics per dispatch) is the
        // only per-call telemetry hook on this path, so the pair bounds
        // its cost; the process switch is restored afterwards so the
        // remaining rows keep the environment's setting
        let prev_telemetry = ceft::obs::enabled();
        ceft::obs::set_enabled(true);
        let tel_on = b.case_with_elements(
            &format!("telemetry_overhead/on_n{n}_p{p}"),
            Some(cells),
            || {
                ceft_table_into(&mut ws, cref);
                black_box(ws.table.last().copied());
            },
        );
        ceft::obs::set_enabled(false);
        let tel_off = b.case_with_elements(
            &format!("telemetry_overhead/off_n{n}_p{p}"),
            Some(cells),
            || {
                ceft_table_into(&mut ws, cref);
                black_box(ws.table.last().copied());
            },
        );
        ceft::obs::set_enabled(prev_telemetry);
        let (tel_on_rate, tel_off_rate) = (
            tel_on.throughput().unwrap_or(0.0),
            tel_off.throughput().unwrap_or(0.0),
        );
        report_cases.push(Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("p", Json::Num(p as f64)),
            (
                "cells_per_s",
                Json::obj(vec![
                    ("scalar", Json::Num(scalar_row.throughput().unwrap_or(0.0))),
                    ("simd", Json::Num(simd_row.throughput().unwrap_or(0.0))),
                    (
                        "batched_b8",
                        Json::Num(batched_row.throughput().unwrap_or(0.0)),
                    ),
                    (
                        "gathered_tables",
                        Json::Num(gathered_row.throughput().unwrap_or(0.0)),
                    ),
                    ("delta_suffix_10pct", Json::Num(delta_rates[0])),
                    ("delta_suffix_50pct", Json::Num(delta_rates[1])),
                    ("delta_suffix_90pct", Json::Num(delta_rates[2])),
                    (
                        "sp_tree_fork_join",
                        Json::Num(fj_row.throughput().unwrap_or(0.0)),
                    ),
                    (
                        "sp_tree_pipeline",
                        Json::Num(pl_row.throughput().unwrap_or(0.0)),
                    ),
                ]),
            ),
            (
                "telemetry",
                Json::obj(vec![
                    ("cells_per_s_on", Json::Num(tel_on_rate)),
                    ("cells_per_s_off", Json::Num(tel_off_rate)),
                    (
                        "overhead_pct",
                        Json::Num(if tel_on_rate > 0.0 {
                            (tel_off_rate / tel_on_rate - 1.0) * 100.0
                        } else {
                            0.0
                        }),
                    ),
                ]),
            ),
        ]));
    }
    b.save_csv();
    // machine-readable kernel-throughput record, tracked across PRs
    // (EXPERIMENTS.md §SIMD dispatch); "scalar" is the scalar-recurrence
    // oracle, "simd" the pinned-lane fused kernel over resident panels
    let report = Json::obj(vec![
        ("bench", Json::Str("ceft_kernel".to_string())),
        (
            "force_scalar_env",
            Json::Bool(std::env::var("CEFT_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false)),
        ),
        ("cases", Json::Arr(report_cases)),
    ]);
    let path = "BENCH_kernel.json";
    match std::fs::write(path, format!("{}\n", report.to_string())) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
