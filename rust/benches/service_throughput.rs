//! Bench: online-service throughput — requests/sec through the engine at
//! 1, N, and 2N worker threads, on cached (memo hit) and uncached (forced
//! miss) request mixes. The throughput column ("Melem/s") is requests/sec
//! divided by 1e6.

use ceft::exp::cells::{grid, Scale, Workload};
use ceft::exp::run::build_instance;
use ceft::graph::io;
use ceft::service::{Engine, EngineConfig};
use ceft::util::bench::{black_box, Bench};

fn request_lines(count: usize) -> Vec<String> {
    let base = grid(Workload::RggClassic, Scale::Smoke)[0];
    (0..count)
        .map(|i| {
            let mut cell = base;
            cell.index = i as u64;
            let (platform, inst) = build_instance(&cell);
            format!(
                r#"{{"op":"schedule","algorithm":"CEFT-CPOP","instance":{},"platform":{}}}"#,
                io::instance_to_json(&inst).to_string(),
                io::platform_to_json(&platform).to_string()
            )
        })
        .collect()
}

fn main() {
    let mut b = Bench::new("service_throughput");
    let n = ceft::util::pool::default_threads();
    let mut thread_counts = vec![1, n, 2 * n];
    thread_counts.sort_unstable();
    thread_counts.dedup();

    let lines = request_lines(32);
    // the same memo-hit mix with a generous budget on every request: the
    // per-request cost of the deadline/admission checks on the hot path
    // (a hit must stay a hit, deadline or not)
    let deadlined: Vec<String> = lines
        .iter()
        .map(|l| l.replacen('{', r#"{"deadline_ms":60000,"#, 1))
        .collect();
    for &threads in &thread_counts {
        // cached: warm every entry once, then measure pure memo-hit serving
        let engine = Engine::new(EngineConfig {
            cache_capacity: 4096,
            threads,
            ..EngineConfig::default()
        });
        engine.handle_batch(&lines);
        b.case_with_elements(
            &format!("cached/t{threads}"),
            Some(lines.len() as u64),
            || {
                black_box(engine.handle_batch(&lines));
            },
        );
        b.case_with_elements(
            &format!("cached_deadlined/t{threads}"),
            Some(deadlined.len() as u64),
            || {
                black_box(engine.handle_batch(&deadlined));
            },
        );

        // uncached: capacity 1 with 32 distinct instances means every
        // request misses and reruns the full CEFT + list-scheduler path
        let cold = Engine::new(EngineConfig {
            cache_capacity: 1,
            threads,
            ..EngineConfig::default()
        });
        b.case_with_elements(
            &format!("uncached/t{threads}"),
            Some(lines.len() as u64),
            || {
                black_box(cold.handle_batch(&lines));
            },
        );
    }
    b.save_csv();
}
