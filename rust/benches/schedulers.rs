//! Bench: end-to-end scheduler wall-clock (CPOP, HEFT, CEFT-CPOP, rank
//! variants) across sizes — the cost of adopting CEFT-CPOP over CPOP is the
//! headline here (one extra O(P²e) DP on top of CPOP's own machinery).

use ceft::graph::generator::{generate, RggParams};
use ceft::platform::{CostModel, Platform};
use ceft::sched::{
    ceft_cpop::CeftCpop,
    ceft_heft::{CeftHeftDown, CeftHeftUp},
    cpop::Cpop,
    heft::{Heft, HeftDown},
    Scheduler,
};
use ceft::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("schedulers");
    for &(n, p) in &[(128usize, 8usize), (1024, 8), (1024, 32)] {
        let plat = Platform::uniform(p, 1.0, 0.0);
        let inst = generate(
            &RggParams {
                n,
                out_degree: 4,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.25,
            },
            &CostModel::Classic { beta: 0.5 },
            &plat,
            7,
        );
        let iref = inst.bind(&plat);
        let algos: [&dyn Scheduler; 6] = [
            &Cpop,
            &Heft,
            &CeftCpop,
            &HeftDown,
            &CeftHeftUp,
            &CeftHeftDown,
        ];
        for a in algos {
            b.case_with_elements(
                &format!("{}/n{n}_p{p}", a.name()),
                Some(n as u64),
                || {
                    black_box(a.schedule(iref));
                },
            );
        }
    }
    b.save_csv();
}
