//! Bench: figure-regeneration kernels for the RGG figures (7–14, 19, 20) —
//! sweep slices at reduced scale plus the aggregation stage itself. Each
//! case is one paper figure's compute at smoke scale (full regeneration is
//! `repro experiment <id>`).

use ceft::exp::cells::{grid, Scale, Workload};
use ceft::exp::figures;
use ceft::exp::run::run_sweep;
use ceft::util::bench::{black_box, Bench};
use ceft::util::pool;

fn main() {
    let mut b = Bench::new("figures_rgg");
    let threads = pool::default_threads();

    // sweep slice: one smoke grid per workload (shared by all figures)
    for wl in [Workload::RggClassic, Workload::RggHigh] {
        let cells = grid(wl, Scale::Smoke);
        b.case(&format!("sweep/{}x{}", wl.name(), cells.len()), || {
            black_box(run_sweep(&cells, threads, false));
        });
    }

    // aggregation stage on a precomputed row set
    let rows = {
        let mut all = Vec::new();
        for wl in Workload::ALL {
            all.extend(run_sweep(&grid(wl, Scale::Smoke), threads, false));
        }
        all
    };
    b.case("aggregate/table3", || {
        black_box(figures::table3(&rows));
    });
    b.case("aggregate/fig7", || {
        black_box(figures::fig7(&rows));
    });
    b.case("aggregate/fig10", || {
        black_box(figures::fig10(&rows));
    });
    b.case("aggregate/fig13b", || {
        black_box(figures::fig13b(&rows));
    });
    b.case("aggregate/fig19", || {
        black_box(figures::fig19(&rows));
    });
    b.case("aggregate/raw_csv", || {
        black_box(figures::raw_rows(&rows).to_csv());
    });
    b.save_csv();
}
