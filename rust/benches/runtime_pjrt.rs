//! Bench: the PJRT artifact path vs the pure-rust relaxation — measures the
//! per-call overhead of the AOT boundary and the crossover batch size.
//! Skips (with a message) when `artifacts/` has not been built.

use ceft::runtime::{relax_batch_reference, AcceleratedCeft, PjrtRuntime, BATCH};
use ceft::util::bench::{black_box, Bench};
use ceft::util::rng::Xoshiro256;

fn main() {
    let mut b = Bench::new("runtime_pjrt");
    let rt = match PjrtRuntime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping runtime_pjrt bench: PJRT client unavailable ({e})");
            return;
        }
    };
    if !rt.has_artifact(8) {
        eprintln!("skipping runtime_pjrt bench: run `make artifacts` first");
        return;
    }

    let mut rng = Xoshiro256::new(1);
    for &p in &[2usize, 8, 64] {
        if !rt.has_artifact(p) {
            continue;
        }
        let f: Vec<f32> = (0..BATCH * p).map(|_| rng.uniform(0.0, 100.0) as f32).collect();
        let data: Vec<f32> = (0..BATCH).map(|_| rng.uniform(0.0, 10.0) as f32).collect();
        let l: Vec<f32> = (0..p).map(|_| 0.0).collect();
        let mut invbw = vec![1f32; p * p];
        for i in 0..p {
            invbw[i * p + i] = 0.0;
        }
        let comp: Vec<f32> = (0..BATCH * p).map(|_| rng.uniform(1.0, 20.0) as f32).collect();
        let cells = (BATCH * p * p) as u64;
        // warm the executable cache outside the timed region
        rt.relax_batch(p, &f, &data, &l, &invbw, &comp).unwrap();
        b.case_with_elements(&format!("pjrt_relax/p{p}"), Some(cells), || {
            black_box(rt.relax_batch(p, &f, &data, &l, &invbw, &comp).unwrap());
        });
        b.case_with_elements(&format!("rust_relax/p{p}"), Some(cells), || {
            black_box(relax_batch_reference(p, &f, &data, &l, &invbw, &comp));
        });
    }

    // whole-graph accelerated CEFT vs pure rust
    let acc = AcceleratedCeft::new(rt);
    let plat = ceft::platform::Platform::uniform(8, 1.0, 0.0);
    let inst = ceft::graph::generator::generate(
        &ceft::graph::generator::RggParams {
            n: 512,
            out_degree: 4,
            ccr: 1.0,
            alpha: 0.5,
            beta_pct: 50.0,
            gamma: 0.25,
        },
        &ceft::platform::CostModel::Classic { beta: 0.5 },
        &plat,
        3,
    );
    b.case("accelerated_ceft/n512_p8", || {
        black_box(acc.find_critical_path(inst.bind(&plat)).unwrap());
    });
    b.case("rust_ceft/n512_p8", || {
        black_box(ceft::cp::ceft::find_critical_path(inst.bind(&plat)));
    });
    b.save_csv();
}
