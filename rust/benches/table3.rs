//! Bench: the Table-3 pipeline — one full experiment cell (generate →
//! all CP algorithms → all 6 schedulers → all metrics) per workload family.
//! This is the unit of work the coordinator fans out 86,400× at full scale;
//! its wall-clock bounds the whole reproduction.

use ceft::exp::cells::{grid, Scale, Workload};
use ceft::exp::run::run_cell;
use ceft::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("table3_cell");
    for wl in Workload::ALL {
        let mut cell = grid(wl, Scale::Smoke)[0];
        cell.n = 256;
        cell.p = 8;
        b.case(&format!("{}/n256_p8", wl.name()), || {
            black_box(run_cell(&cell));
        });
        let mut big = cell;
        big.n = 1024;
        b.case(&format!("{}/n1024_p8", wl.name()), || {
            black_box(run_cell(&big));
        });
    }
    b.save_csv();
}
