//! ASCII Gantt rendering of schedules — terminal visualisation for the CLI
//! and examples.

use super::Schedule;
use std::fmt::Write as _;

/// Render a schedule as one row per processor, time flowing right, each
/// task drawn as `[id···]` scaled to `width` columns. Tasks too narrow to
/// label are drawn as `#`.
pub fn render(s: &Schedule, width: usize) -> String {
    let m = s.makespan().max(1e-12);
    let scale = width as f64 / m;
    // group tasks per processor, sorted by start
    let mut per_proc: Vec<Vec<(usize, f64, f64)>> = vec![Vec::new(); s.p];
    for (t, a) in s.assignments.iter().enumerate() {
        per_proc[a.proc].push((t, a.start, a.finish));
    }
    let mut out = String::new();
    let _ = writeln!(out, "makespan = {m:.2}");
    for (j, tasks) in per_proc.iter_mut().enumerate() {
        tasks.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let mut row = String::new();
        let mut col = 0usize;
        for &(t, start, finish) in tasks.iter() {
            let s_col = (start * scale).round() as usize;
            let e_col = ((finish * scale).round() as usize).max(s_col + 1);
            if s_col > col {
                row.push_str(&".".repeat(s_col - col));
            }
            let w = e_col - s_col;
            let label = format!("{t}");
            if w >= label.len() + 2 {
                let pad = w - label.len() - 2;
                row.push('[');
                row.push_str(&label);
                row.push_str(&"·".repeat(pad));
                row.push(']');
            } else {
                row.push_str(&"#".repeat(w));
            }
            col = e_col;
        }
        let _ = writeln!(out, "P{j:<3}|{row}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskGraph;
    use crate::model::{CostMatrix, InstanceRef};
    use crate::platform::Platform;
    use crate::sched::{heft::Heft, Scheduler};

    #[test]
    fn renders_all_processors_and_tasks() {
        let g = TaskGraph::from_edges(3, &[(0, 1, 1.0), (0, 2, 1.0)]);
        let plat = Platform::uniform(2, 1.0, 0.0);
        let comp = CostMatrix::new(2, vec![5.0, 5.0, 10.0, 10.0, 10.0, 10.0]);
        let s = Heft.schedule(InstanceRef::new(&g, &plat, &comp));
        let text = render(&s, 60);
        assert!(text.contains("P0"));
        assert!(text.contains("P1"));
        assert!(text.contains("makespan"));
        // at least one labelled task box
        assert!(text.contains('['));
    }

    #[test]
    fn tiny_width_degrades_to_hashes() {
        let g = TaskGraph::from_edges(2, &[(0, 1, 1.0)]);
        let plat = Platform::uniform(1, 1.0, 0.0);
        let comp = CostMatrix::new(1, vec![1.0, 1.0]);
        let s = Heft.schedule(InstanceRef::new(&g, &plat, &comp));
        let text = render(&s, 4);
        assert!(text.contains('#') || text.contains('['));
    }
}
