//! CEFT-based HEFT ranking functions (§8.2 of the paper).
//!
//! * `rank_ceft_down(t) = min_p CEFT(t, p)` — the CEFT table gives the
//!   accurate length of the critical path from the entry to `t`.
//! * `rank_ceft_up(t) = min_p CEFT_T(t, p)` where `CEFT_T` is the table of
//!   the *transposed* DAG — the accurate length from `t` to the exit.
//!
//! CEFT-HEFT-UP orders tasks by descending `rank_ceft_up`; CEFT-HEFT-DOWN
//! by ascending `rank_ceft_down` (downward ranks grow towards the exit,
//! so ascending order is the topologically consistent one, matching
//! HEFT-DOWN). Placement stays min-EFT.

use super::{list_schedule_with, PlacementWs, Schedule, Scheduler};
use crate::cp::ceft::{ceft_table_into, ceft_table_rev_into, CeftTable};
use crate::cp::workspace::Workspace;
use crate::model::InstanceRef;

/// Per-task row minimum of the `v × P` table in `ws.table`, appended to
/// `out` (cleared first). Lowest value per task = the CEFT-based rank.
fn min_rows_into(table: &[f64], v: usize, p: usize, out: &mut Vec<f64>) {
    out.clear();
    for t in 0..v {
        let row = &table[t * p..(t + 1) * p];
        out.push(row.iter().fold(f64::INFINITY, |a, &b| a.min(b)));
    }
}

/// `rank_ceft_down` for every task: `min_p CEFT(t, p)` on the original DAG.
pub fn rank_ceft_down(inst: InstanceRef) -> Vec<f64> {
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    rank_ceft_down_into(&mut ws, inst, &mut out);
    out
}

/// [`rank_ceft_down`] with workspace scratch and a caller-owned output.
pub fn rank_ceft_down_into(ws: &mut Workspace, inst: InstanceRef, out: &mut Vec<f64>) {
    ceft_table_into(ws, inst);
    min_rows_into(&ws.table, inst.n(), inst.p(), out);
}

/// `rank_ceft_up` for every task: `min_p CEFT_T(t, p)` on the transposed
/// DAG — computed by the reverse sweep
/// [`ceft_table_rev_into`], which is bit-identical to the DP over a
/// materialised transpose without allocating one.
pub fn rank_ceft_up(inst: InstanceRef) -> Vec<f64> {
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    rank_ceft_up_into(&mut ws, inst, &mut out);
    out
}

/// [`rank_ceft_up`] with workspace scratch and a caller-owned output.
pub fn rank_ceft_up_into(ws: &mut Workspace, inst: InstanceRef, out: &mut Vec<f64>) {
    ceft_table_rev_into(ws, inst);
    min_rows_into(&ws.table, inst.n(), inst.p(), out);
}

/// HEFT with the CEFT upward rank.
#[derive(Clone, Copy, Debug, Default)]
pub struct CeftHeftUp;

impl Scheduler for CeftHeftUp {
    fn name(&self) -> &'static str {
        "CEFT-HEFT-UP"
    }

    fn schedule_with(&self, ws: &mut Workspace, inst: InstanceRef) -> Schedule {
        ceft_table_rev_into(ws, inst);
        let Workspace { table, prio, .. } = &mut *ws;
        min_rows_into(table, inst.n(), inst.p(), prio);
        list_schedule_with(ws, inst, PlacementWs::MinEft)
    }

    fn schedule_with_table(
        &self,
        ws: &mut Workspace,
        inst: InstanceRef,
        table: &CeftTable,
    ) -> Schedule {
        // the caller's *reverse*-orientation table replaces the transpose
        // DP; row minima and placement are unchanged, so the schedule is
        // bit-identical to schedule_with
        assert_eq!(table.p, inst.p(), "table/platform class count mismatch");
        min_rows_into(&table.table, inst.n(), inst.p(), &mut ws.prio);
        list_schedule_with(ws, inst, PlacementWs::MinEft)
    }
}

/// HEFT with the CEFT downward rank.
#[derive(Clone, Copy, Debug, Default)]
pub struct CeftHeftDown;

impl Scheduler for CeftHeftDown {
    fn name(&self) -> &'static str {
        "CEFT-HEFT-DOWN"
    }

    fn schedule_with(&self, ws: &mut Workspace, inst: InstanceRef) -> Schedule {
        ceft_table_into(ws, inst);
        let Workspace { table, down, prio, .. } = &mut *ws;
        min_rows_into(table, inst.n(), inst.p(), down);
        prio.clear();
        prio.extend(down.iter().map(|d| -d));
        list_schedule_with(ws, inst, PlacementWs::MinEft)
    }

    fn schedule_with_table(
        &self,
        ws: &mut Workspace,
        inst: InstanceRef,
        table: &CeftTable,
    ) -> Schedule {
        // the caller's *forward* table replaces the DP; the negated-rank
        // priority build matches schedule_with exactly
        assert_eq!(table.p, inst.p(), "table/platform class count mismatch");
        let Workspace { down, prio, .. } = &mut *ws;
        min_rows_into(&table.table, inst.n(), inst.p(), down);
        prio.clear();
        prio.extend(down.iter().map(|d| -d));
        list_schedule_with(ws, inst, PlacementWs::MinEft)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{generate, Instance, RggParams};
    use crate::platform::{CostModel, Platform};

    fn instance(seed: u64) -> (Instance, Platform) {
        let plat = Platform::uniform(4, 1.0, 0.0);
        let inst = generate(
            &RggParams {
                n: 90,
                out_degree: 3,
                ccr: 1.0,
                alpha: 0.75,
                beta_pct: 75.0,
                gamma: 0.1,
            },
            &CostModel::Classic { beta: 0.75 },
            &plat,
            seed,
        );
        (inst, plat)
    }

    #[test]
    fn both_variants_produce_valid_schedules() {
        for seed in 0..5 {
            let (inst, plat) = instance(seed);
            let iref = inst.bind(&plat);
            CeftHeftUp.schedule(iref).validate(iref).unwrap();
            CeftHeftDown.schedule(iref).validate(iref).unwrap();
        }
    }

    #[test]
    fn ceft_up_rank_decreases_along_edges() {
        let (inst, plat) = instance(3);
        let up = rank_ceft_up(inst.bind(&plat));
        for e in inst.graph.edges() {
            assert!(
                up[e.src] > up[e.dst],
                "upward rank must strictly decrease along {} -> {}",
                e.src,
                e.dst
            );
        }
    }

    #[test]
    fn ceft_down_rank_increases_along_edges() {
        let (inst, plat) = instance(3);
        let down = rank_ceft_down(inst.bind(&plat));
        for e in inst.graph.edges() {
            assert!(
                down[e.src] < down[e.dst],
                "downward rank must strictly increase along {} -> {}",
                e.src,
                e.dst
            );
        }
    }

    #[test]
    fn up_rank_of_entry_tracks_ceft_cp_length() {
        // The transposed CEFT at the original entry measures the same
        // longest-chain quantity with the class anchor moved from the sink
        // to the source — not exactly equal on multi-path DAGs, but it must
        // be the same order of magnitude and upper-bounded by neither side
        // diverging (regression check on a fixed instance).
        let (inst, plat) = instance(8);
        let iref = inst.bind(&plat);
        let up = rank_ceft_up(iref);
        let cp = crate::cp::ceft::find_critical_path(iref);
        let entry = inst.graph.sources()[0];
        let rel = (up[entry] - cp.length).abs() / cp.length;
        assert!(
            rel < 0.05,
            "rank_ceft_up(entry)={} vs CPL={} (rel {rel})",
            up[entry],
            cp.length
        );
    }
}
