//! CEFT-CPOP — the paper's scheduling algorithm (§6).
//!
//! Identical to CPOP except lines 2–13 of Algorithm 2 are replaced: the
//! critical path *and its partial assignment* come from the CEFT dynamic
//! program. Each CP task is pinned to the class CEFT chose for it — the
//! whole point of the paper's "mutual inclusivity": the path is only
//! critical *together with* its mapping, so the scheduler honours that
//! mapping instead of collapsing the path onto one processor.

use super::{list_schedule_with, PlacementWs, Schedule, Scheduler};
use crate::cp::ceft::{critical_path_from_table, find_critical_path_with, CeftTable};
use crate::cp::ranks::cpop_priorities_into;
use crate::cp::workspace::Workspace;
use crate::model::InstanceRef;

/// CEFT-CPOP: CPOP with CEFT's critical path and partial assignment.
#[derive(Clone, Copy, Debug, Default)]
pub struct CeftCpop;

impl Scheduler for CeftCpop {
    fn name(&self) -> &'static str {
        "CEFT-CPOP"
    }

    fn schedule_with(&self, ws: &mut Workspace, inst: InstanceRef) -> Schedule {
        // the CEFT path first: it uses ws.table/backptr, which the rank
        // sweeps below do not touch
        let cp = find_critical_path_with(ws, inst);
        // priorities stay mean-value rank_u + rank_d ("the rest of the
        // algorithm remains the same", §6)
        cpop_priorities_into(ws, inst);
        // pin every CP task to the class its partial assignment chose
        cp.fill_assignment_dense(inst.n(), &mut ws.pins);
        list_schedule_with(ws, inst, PlacementWs::Pinned)
    }

    fn schedule_with_table(
        &self,
        ws: &mut Workspace,
        inst: InstanceRef,
        table: &CeftTable,
    ) -> Schedule {
        assert_eq!(table.p, inst.p(), "table/platform class count mismatch");
        // the caller's forward table replaces the DP; sink selection and
        // backtracking are the same code path schedule_with runs over the
        // workspace buffers, so the pins — and the schedule — match bit
        // for bit
        let cp = critical_path_from_table(inst.graph, table);
        cpop_priorities_into(ws, inst);
        cp.fill_assignment_dense(inst.n(), &mut ws.pins);
        list_schedule_with(ws, inst, PlacementWs::Pinned)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::ceft::find_critical_path;
    use crate::graph::generator::{generate, Instance, RggParams};
    use crate::platform::{CostModel, Platform};
    use crate::sched::cpop::Cpop;
    use crate::util::rng::Xoshiro256;

    fn rgg(seed: u64, plat: &Platform, model: &CostModel, n: usize) -> Instance {
        generate(
            &RggParams {
                n,
                out_degree: 3,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.2,
            },
            model,
            plat,
            seed,
        )
    }

    #[test]
    fn ceft_cpop_schedules_are_valid() {
        let plat = Platform::uniform(4, 1.0, 0.0);
        for seed in 0..5 {
            let inst = rgg(seed, &plat, &CostModel::Classic { beta: 0.5 }, 100);
            let iref = inst.bind(&plat);
            let s = CeftCpop.schedule(iref);
            s.validate(iref).unwrap();
        }
    }

    #[test]
    fn cp_tasks_follow_ceft_assignment() {
        let plat = Platform::uniform(4, 1.0, 0.0);
        let inst = rgg(21, &plat, &CostModel::Classic { beta: 0.5 }, 80);
        let iref = inst.bind(&plat);
        let cp = find_critical_path(iref);
        let s = CeftCpop.schedule(iref);
        for step in &cp.path {
            assert_eq!(
                s.assignments[step.task].proc, step.class,
                "task {} should be pinned to class {}",
                step.task, step.class
            );
        }
    }

    #[test]
    fn beats_cpop_under_high_heterogeneity_most_of_the_time() {
        // the paper's headline: under accelerator-like heterogeneity the
        // CEFT path (and its multi-class assignment) yields shorter
        // makespans in ~90% of experiments. Check the direction on a small
        // sample: CEFT-CPOP must win strictly more often than it loses.
        let mut wins = 0;
        let mut losses = 0;
        for seed in 0..30u64 {
            let mut prng = Xoshiro256::new(seed.wrapping_mul(0xABCD));
            let plat = Platform::two_weight(8, 0.5, &mut prng, 1.0, 0.0);
            let inst = generate(
                &RggParams {
                    n: 120,
                    out_degree: 3,
                    ccr: 0.1,
                    alpha: 0.5,
                    beta_pct: 50.0,
                    gamma: 0.2,
                },
                &CostModel::two_weight_high(0.5),
                &plat,
                seed,
            );
            let iref = inst.bind(&plat);
            let m_ceft = CeftCpop.schedule(iref).makespan();
            let m_cpop = Cpop.schedule(iref).makespan();
            if m_ceft < m_cpop * (1.0 - 1e-9) {
                wins += 1;
            } else if m_cpop < m_ceft * (1.0 - 1e-9) {
                losses += 1;
            }
        }
        assert!(
            wins > losses,
            "CEFT-CPOP should dominate CPOP on RGG-high-like instances: {wins} wins vs {losses} losses"
        );
    }

    #[test]
    fn identical_when_single_class() {
        // with P=1 both algorithms degenerate to the same serial schedule
        let plat = Platform::uniform(1, 1.0, 0.0);
        let inst = rgg(4, &plat, &CostModel::Classic { beta: 0.0 }, 60);
        let iref = inst.bind(&plat);
        let a = CeftCpop.schedule(iref).makespan();
        let b = Cpop.schedule(iref).makespan();
        assert!((a - b).abs() < 1e-9);
    }
}
