//! List schedulers over heterogeneous platforms.
//!
//! All schedulers share the same insertion-based machinery ([`ListContext`]):
//! a ready queue ordered by a per-task priority, and a placement policy that
//! is either "the processor minimising the earliest finish time" or "a
//! pinned processor from a critical-path assignment, min-EFT for the rest".
//!
//! * [`heft`] — HEFT (upward rank, min-EFT placement) and HEFT-DOWN.
//! * [`cpop`] — CPOP (Algorithm 2): priority `rank_u + rank_d`, critical
//!   path pinned to the single processor minimising its total weight.
//! * [`ceft_cpop`] — the paper's CEFT-CPOP: CPOP with the critical path
//!   *and its partial assignment* replaced by CEFT's (§6).
//! * [`ceft_heft`] — HEFT with CEFT-based ranking functions (§8.2).
//!
//! Every scheduler consumes an instance through
//! [`crate::model::InstanceRef`] — the shape-checked
//! `&TaskGraph + &Platform + &CostMatrix` view — and has two entry points:
//! [`Scheduler::schedule_with`] borrows a [`Workspace`] and allocates
//! nothing but the returned [`Schedule`]; [`Scheduler::schedule`] is the
//! classic convenience signature over a one-shot workspace. Outputs are
//! bit-identical either way (see `rust/tests/workspace.rs`).
//!
//! ## The `run_with_tables` contract
//!
//! The CEFT-based schedulers spend most of their time filling the same
//! `v × P` CEFT table the critical-path answer is derived from — the
//! paper's mutual-inclusivity observation. [`Algorithm::run_with_tables`]
//! lets a caller that already holds that table (the service engine's
//! table memo, the batch harness's per-instance reuse) hand it in as a
//! borrowed [`CeftTable`] and skip the DP entirely:
//!
//! * [`Algorithm::table_use`] declares which orientation an algorithm
//!   consumes — [`TableDir::Forward`] ([`Algorithm::CeftCpop`],
//!   [`Algorithm::CeftHeftDown`]), [`TableDir::Reverse`]
//!   ([`Algorithm::CeftHeftUp`]), or `None` for the mean-value schedulers,
//!   which never touch a CEFT table.
//! * The **caller** is responsible for passing a table of the declared
//!   orientation computed over *exactly* the instance being scheduled
//!   (same graph, platform, and cost matrix). Passing `None` — or any
//!   table to a `table_use() == None` algorithm — falls back to
//!   [`Algorithm::run_with`], recomputing in the workspace.
//! * Bit-identity is guaranteed: for a correctly-oriented table, the
//!   schedule equals [`Algorithm::run_with`]'s bit for bit (placements
//!   *and* times), because the table-accepting paths
//!   ([`Scheduler::schedule_with_table`]) consume the table through the
//!   same rank/pin machinery the recomputing paths feed from workspace
//!   buffers. `prop_run_with_tables_bit_identical` in
//!   `rust/tests/properties.rs` enforces this for every registry entry,
//!   with tables from both the serial producers and the gathered sweep
//!   ([`crate::cp::ceft::find_ceft_tables_gathered`]).

pub mod ceft_cpop;
pub mod ceft_heft;
pub mod cpop;
pub mod gantt;
pub mod heft;

use crate::cp::ceft::CeftTable;
use crate::cp::workspace::{ReadyEntry, Workspace};
use crate::graph::TaskGraph;
use crate::model::{CostMatrix, InstanceRef};
use crate::platform::Platform;

/// Where and when one task executes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Assignment {
    /// processor (class) index
    pub proc: usize,
    /// actual start time
    pub start: f64,
    /// actual finish time
    pub finish: f64,
}

/// A complete schedule of a task graph.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// per-task assignment, indexed by task id
    pub assignments: Vec<Assignment>,
    /// number of processors
    pub p: usize,
}

impl Schedule {
    /// The makespan — latest finish time.
    pub fn makespan(&self) -> f64 {
        self.assignments
            .iter()
            .map(|a| a.finish)
            .fold(0.0, f64::max)
    }

    /// Verify the schedule is legal: every task runs for exactly its
    /// execution cost, starts after all its inputs have arrived (with
    /// communication delays), and no processor runs two tasks at once.
    pub fn validate(&self, inst: InstanceRef) -> Result<(), String> {
        let graph = inst.graph;
        let platform = inst.platform;
        let costs = inst.costs;
        let eps = 1e-6;
        if self.assignments.len() != graph.num_tasks() {
            return Err("wrong number of assignments".into());
        }
        for (t, a) in self.assignments.iter().enumerate() {
            if a.proc >= self.p {
                return Err(format!("task {t} on invalid proc {}", a.proc));
            }
            let dur = costs.get(t, a.proc);
            if (a.finish - a.start - dur).abs() > eps {
                return Err(format!(
                    "task {t}: duration {} != cost {dur}",
                    a.finish - a.start
                ));
            }
            for &(k, data) in graph.preds(t) {
                let pk = &self.assignments[k];
                let arrival = pk.finish + platform.comm_cost(pk.proc, a.proc, data);
                if a.start + eps < arrival {
                    return Err(format!(
                        "task {t} starts {} before input from {k} arrives {arrival}",
                        a.start
                    ));
                }
            }
        }
        // exclusivity per processor
        let mut per_proc: Vec<Vec<(f64, f64, usize)>> = vec![Vec::new(); self.p];
        for (t, a) in self.assignments.iter().enumerate() {
            per_proc[a.proc].push((a.start, a.finish, t));
        }
        for (j, iv) in per_proc.iter_mut().enumerate() {
            iv.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in iv.windows(2) {
                if w[0].1 > w[1].0 + eps {
                    return Err(format!(
                        "proc {j}: tasks {} and {} overlap ([{}, {}] vs [{}, {}])",
                        w[0].2, w[1].2, w[0].0, w[0].1, w[1].0, w[1].1
                    ));
                }
            }
        }
        Ok(())
    }
}

/// A scheduling algorithm.
pub trait Scheduler {
    /// Short display name (used in result tables).
    fn name(&self) -> &'static str;

    /// Produce a schedule using caller-provided scratch — the hot path.
    /// All transient state lives in `ws`; the only allocation is the
    /// returned [`Schedule`] itself.
    fn schedule_with(&self, ws: &mut Workspace, inst: InstanceRef) -> Schedule;

    /// Convenience wrapper over [`Scheduler::schedule_with`] that allocates
    /// a one-shot workspace. Bit-identical to the workspace path.
    fn schedule(&self, inst: InstanceRef) -> Schedule {
        self.schedule_with(&mut Workspace::new(), inst)
    }

    /// Produce a schedule reusing a caller-held CEFT table of this
    /// scheduler's orientation (see the module docs' `run_with_tables`
    /// contract) instead of recomputing the DP. The default ignores the
    /// table and recomputes — correct for every scheduler, which is what
    /// keeps the mean-value schedulers untouched; the CEFT-based
    /// schedulers override it to skip their dominant cost. Bit-identical
    /// to [`Scheduler::schedule_with`] for a correctly-oriented table.
    fn schedule_with_table(
        &self,
        ws: &mut Workspace,
        inst: InstanceRef,
        table: &CeftTable,
    ) -> Schedule {
        let _ = table;
        self.schedule_with(ws, inst)
    }
}

/// Which CEFT-table orientation an algorithm consumes through
/// [`Algorithm::run_with_tables`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TableDir {
    /// the forward DP of [`crate::cp::ceft::ceft_table_with`]
    Forward,
    /// the transpose DP of [`crate::cp::ceft::ceft_table_rev_with`]
    Reverse,
}

/// The unified algorithm registry: one name per scheduler, shared by the
/// batch driver (`repro schedule`, the experiment harness) and the online
/// service, so "CEFT-CPOP" means the same code path everywhere. Variants
/// are in result-column order (the order of [`crate::exp::run::ALGOS`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// CPOP (Algorithm 2): mean-value ranks, critical path on one processor
    Cpop,
    /// classic HEFT: mean-value upward rank, min-EFT placement
    Heft,
    /// the paper's CEFT-CPOP: CEFT path + partial assignment pinned
    CeftCpop,
    /// HEFT driven by the mean-value downward rank
    HeftDown,
    /// HEFT with the CEFT upward rank (§8.2)
    CeftHeftUp,
    /// HEFT with the CEFT downward rank (§8.2)
    CeftHeftDown,
}

impl Algorithm {
    /// Every algorithm, in result-column order.
    pub const ALL: [Algorithm; 6] = [
        Algorithm::Cpop,
        Algorithm::Heft,
        Algorithm::CeftCpop,
        Algorithm::HeftDown,
        Algorithm::CeftHeftUp,
        Algorithm::CeftHeftDown,
    ];

    /// Canonical display name (matches [`Scheduler::name`]).
    pub const fn name(&self) -> &'static str {
        match self {
            Algorithm::Cpop => "CPOP",
            Algorithm::Heft => "HEFT",
            Algorithm::CeftCpop => "CEFT-CPOP",
            Algorithm::HeftDown => "HEFT-DOWN",
            Algorithm::CeftHeftUp => "CEFT-HEFT-UP",
            Algorithm::CeftHeftDown => "CEFT-HEFT-DOWN",
        }
    }

    /// Stable numeric id — part of the service's memoization cache key, so
    /// these values must never be reused for a different algorithm.
    pub const fn id(&self) -> u64 {
        match self {
            Algorithm::Cpop => 0,
            Algorithm::Heft => 1,
            Algorithm::CeftCpop => 2,
            Algorithm::HeftDown => 3,
            Algorithm::CeftHeftUp => 4,
            Algorithm::CeftHeftDown => 5,
        }
    }

    /// Parse a (case-insensitive, `_`/`-` agnostic) algorithm name.
    pub fn parse(s: &str) -> Result<Algorithm, String> {
        let norm = s.trim().to_ascii_lowercase().replace('_', "-");
        for a in Algorithm::ALL {
            if a.name().to_ascii_lowercase() == norm {
                return Ok(a);
            }
        }
        let names: Vec<&str> = Algorithm::ALL.iter().map(|a| a.name()).collect();
        Err(format!(
            "unknown algorithm {s:?} (expected one of: {})",
            names.join(", ")
        ))
    }

    /// The scheduler implementation behind this registry entry.
    pub fn scheduler(&self) -> &'static dyn Scheduler {
        match self {
            Algorithm::Cpop => &cpop::Cpop,
            Algorithm::Heft => &heft::Heft,
            Algorithm::CeftCpop => &ceft_cpop::CeftCpop,
            Algorithm::HeftDown => &heft::HeftDown,
            Algorithm::CeftHeftUp => &ceft_heft::CeftHeftUp,
            Algorithm::CeftHeftDown => &ceft_heft::CeftHeftDown,
        }
    }

    /// The CEFT-table orientation this algorithm can reuse through
    /// [`Algorithm::run_with_tables`], or `None` for the mean-value
    /// schedulers (which never compute a CEFT table and so have nothing
    /// to skip).
    pub const fn table_use(&self) -> Option<TableDir> {
        match self {
            Algorithm::CeftCpop | Algorithm::CeftHeftDown => Some(TableDir::Forward),
            Algorithm::CeftHeftUp => Some(TableDir::Reverse),
            Algorithm::Cpop | Algorithm::Heft | Algorithm::HeftDown => None,
        }
    }

    /// Schedule an instance with this algorithm and caller-provided scratch
    /// — the entry point of the online service's per-request dispatch and
    /// the batch harness. Allocates nothing but the returned schedule once
    /// `ws` has warmed to the instance size.
    pub fn run_with(&self, ws: &mut Workspace, inst: InstanceRef) -> Schedule {
        self.scheduler().schedule_with(ws, inst)
    }

    /// Schedule an instance reusing a caller-held CEFT table when one is
    /// offered *and* this algorithm consumes one
    /// ([`Algorithm::table_use`]); falls back to [`Algorithm::run_with`]
    /// otherwise. The caller must pass a table of the declared orientation
    /// computed over exactly this instance — see the module docs for the
    /// full contract. Bit-identical to [`Algorithm::run_with`] either way.
    pub fn run_with_tables(
        &self,
        ws: &mut Workspace,
        inst: InstanceRef,
        table: Option<&CeftTable>,
    ) -> Schedule {
        match (self.table_use(), table) {
            (Some(_), Some(t)) => self.scheduler().schedule_with_table(ws, inst, t),
            _ => self.run_with(ws, inst),
        }
    }

    /// Schedule an instance with this algorithm (one-shot workspace).
    pub fn schedule(&self, inst: InstanceRef) -> Schedule {
        self.scheduler().schedule(inst)
    }
}

/// Deprecated raw-triple shim at the service/JSON boundary: copies `comp`
/// into a fresh [`CostMatrix`] and dispatches through the registry.
#[deprecated(note = "build a CostMatrix + InstanceRef and call Algorithm::schedule")]
pub fn schedule_raw(
    algorithm: Algorithm,
    graph: &TaskGraph,
    platform: &Platform,
    comp: &[f64],
) -> Schedule {
    let costs = crate::model::cost_matrix_from_raw(platform.num_classes(), comp);
    algorithm.schedule(InstanceRef::new(graph, platform, &costs))
}

/// Placement policy for the generic list scheduler.
pub enum Placement {
    /// choose the processor minimising the (insertion-based) EFT
    MinEft,
    /// dense pin table (`pins[t] = Some(class)` pins task `t` to `class`,
    /// `None` falls back to min-EFT) — one entry per task, no hashing on
    /// the hot path. Build one with
    /// [`CriticalPath::assignment_dense`](crate::cp::ceft::CriticalPath::assignment_dense).
    Pinned(Vec<Option<usize>>),
}

/// Placement selector for the workspace entry point: the pin table, when
/// used, is read from `ws.pins` (sized by the caller) so no borrow of the
/// workspace escapes into the argument list.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementWs {
    /// choose the processor minimising the (insertion-based) EFT
    MinEft,
    /// consult the dense `ws.pins` table, min-EFT for unpinned tasks
    Pinned,
}

/// Shared machinery: machine state + EFT computation, over buffers borrowed
/// from a [`Workspace`] (so repeated scheduling reuses their capacity).
pub struct ListContext<'a> {
    graph: &'a TaskGraph,
    platform: &'a Platform,
    costs: &'a CostMatrix,
    /// busy intervals per processor, kept sorted by start time
    busy: &'a mut [Vec<(f64, f64)>],
    /// actual finish time per scheduled task
    aft: &'a mut [f64],
    /// processor per scheduled task
    proc_of: &'a mut [usize],
    scheduled: &'a mut [bool],
}

impl<'a> ListContext<'a> {
    /// Context over an instance, backed by the given scratch buffers
    /// (resized and reset here; capacity is reused across calls).
    fn from_parts(
        inst: InstanceRef<'a>,
        busy: &'a mut Vec<Vec<(f64, f64)>>,
        aft: &'a mut Vec<f64>,
        proc_of: &'a mut Vec<usize>,
        scheduled: &'a mut Vec<bool>,
    ) -> Self {
        let v = inst.n();
        let p = inst.p();
        while busy.len() < p {
            busy.push(Vec::new());
        }
        for row in busy[..p].iter_mut() {
            row.clear();
        }
        aft.clear();
        aft.resize(v, 0.0);
        proc_of.clear();
        proc_of.resize(v, usize::MAX);
        scheduled.clear();
        scheduled.resize(v, false);
        Self {
            graph: inst.graph,
            platform: inst.platform,
            costs: inst.costs,
            busy: &mut busy[..p],
            aft: &mut aft[..],
            proc_of: &mut proc_of[..],
            scheduled: &mut scheduled[..],
        }
    }

    /// Earliest moment all of `t`'s inputs are available on processor `j`.
    fn ready_time(&self, t: usize, j: usize) -> f64 {
        let mut ready = 0.0f64;
        for &(k, data) in self.graph.preds(t) {
            debug_assert!(self.scheduled[k], "parent {k} not scheduled before {t}");
            let arrival = self.aft[k] + self.platform.comm_cost(self.proc_of[k], j, data);
            ready = ready.max(arrival);
        }
        ready
    }

    /// Insertion-based earliest start on processor `j` for a task of
    /// duration `dur`, not before `ready`: scan idle gaps between busy
    /// intervals, fall back to the end of the last one.
    fn earliest_slot(&self, j: usize, ready: f64, dur: f64) -> f64 {
        let iv = &self.busy[j];
        let mut cursor = ready;
        for &(s, e) in iv {
            if cursor + dur <= s + 1e-12 {
                return cursor;
            }
            cursor = cursor.max(e);
        }
        cursor
    }

    /// Earliest (start, finish) of `t` on processor `j` under the current
    /// partial schedule (Definition 5/6: EST and EFT).
    pub fn eft(&self, t: usize, j: usize) -> (f64, f64) {
        let ready = self.ready_time(t, j);
        let dur = self.costs.get(t, j);
        let start = self.earliest_slot(j, ready, dur);
        (start, start + dur)
    }

    /// Commit `t` to processor `j` at its EFT slot.
    pub fn place(&mut self, t: usize, j: usize) {
        let (start, finish) = self.eft(t, j);
        let iv = &mut self.busy[j];
        let pos = iv
            .binary_search_by(|probe| probe.0.partial_cmp(&start).unwrap())
            .unwrap_or_else(|e| e);
        iv.insert(pos, (start, finish));
        self.aft[t] = finish;
        self.proc_of[t] = j;
        self.scheduled[t] = true;
    }

    /// Processor minimising EFT for `t` (ties: lowest processor id).
    pub fn argmin_eft(&self, t: usize) -> usize {
        let p = self.platform.num_classes();
        let mut best = 0usize;
        let mut best_f = f64::INFINITY;
        for j in 0..p {
            let (_, f) = self.eft(t, j);
            if f < best_f {
                best_f = f;
                best = j;
            }
        }
        best
    }
}

/// Generic priority-driven list scheduler: repeatedly pop the
/// highest-priority *ready* task and place it per the policy. Ties break
/// toward the lower task id, making every scheduler deterministic.
///
/// Convenience wrapper over [`list_schedule_with`]: copies `priority` (and
/// the pin table) into a one-shot workspace. Use the workspace entry point
/// on hot paths.
pub fn list_schedule(inst: InstanceRef, priority: &[f64], placement: &Placement) -> Schedule {
    let mut ws = Workspace::new();
    ws.prio.extend_from_slice(priority);
    let pw = match placement {
        Placement::MinEft => PlacementWs::MinEft,
        Placement::Pinned(pins) => {
            assert_eq!(pins.len(), inst.n(), "pin table must be dense");
            ws.pins.extend_from_slice(pins);
            PlacementWs::Pinned
        }
    };
    list_schedule_with(&mut ws, inst, pw)
}

/// Workspace-backed list scheduler — the allocation-free core shared by
/// every scheduler. Priorities are read from `ws.prio` (one per task) and,
/// for [`PlacementWs::Pinned`], pins from `ws.pins`; callers fill those
/// before the call. Everything else (ready heap, in-degree counters, busy
/// lists, finish times) is workspace scratch re-initialised here, so a
/// reused workspace produces bit-identical schedules with zero heap
/// allocation beyond the returned [`Schedule`].
pub fn list_schedule_with(
    ws: &mut Workspace,
    inst: InstanceRef,
    placement: PlacementWs,
) -> Schedule {
    let graph = inst.graph;
    let v = inst.n();
    let Workspace { prio, pins, indeg, heap, busy, aft, proc_of, scheduled, .. } = ws;
    assert_eq!(prio.len(), v, "ws.prio must hold one priority per task");
    if placement == PlacementWs::Pinned {
        assert_eq!(pins.len(), v, "ws.pins must hold one entry per task");
    }
    let mut ctx = ListContext::from_parts(inst, busy, aft, proc_of, scheduled);
    indeg.clear();
    indeg.extend((0..v).map(|t| graph.in_degree(t)));
    heap.clear();
    for t in 0..v {
        if indeg[t] == 0 {
            heap.push(ReadyEntry { prio: prio[t], task: t });
        }
    }
    let mut placed = 0usize;
    while let Some(e) = heap.pop() {
        let t = e.task;
        let j = match placement {
            PlacementWs::MinEft => ctx.argmin_eft(t),
            PlacementWs::Pinned => pins[t].unwrap_or_else(|| ctx.argmin_eft(t)),
        };
        ctx.place(t, j);
        placed += 1;
        for &(s, _) in graph.succs(t) {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                heap.push(ReadyEntry { prio: prio[s], task: s });
            }
        }
    }
    assert_eq!(placed, v, "not all tasks scheduled (cycle?)");
    let assignments = (0..v)
        .map(|t| Assignment {
            proc: ctx.proc_of[t],
            start: ctx.aft[t] - ctx.costs.get(t, ctx.proc_of[t]),
            finish: ctx.aft[t],
        })
        .collect();
    Schedule {
        assignments,
        p: inst.p(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskGraph;

    fn tiny() -> (TaskGraph, Platform, CostMatrix) {
        let g = TaskGraph::from_edges(
            4,
            &[(0, 1, 4.0), (0, 2, 4.0), (1, 3, 4.0), (2, 3, 4.0)],
        );
        let plat = Platform::uniform(2, 1.0, 0.0);
        #[rustfmt::skip]
        let comp = CostMatrix::new(2, vec![
            2.0, 3.0,
            3.0, 2.0,
            3.0, 2.0,
            2.0, 3.0,
        ]);
        (g, plat, comp)
    }

    #[test]
    fn min_eft_schedule_is_valid() {
        let (g, plat, comp) = tiny();
        let inst = InstanceRef::new(&g, &plat, &comp);
        let prio = vec![3.0, 2.0, 1.0, 0.0];
        let s = list_schedule(inst, &prio, &Placement::MinEft);
        s.validate(inst).unwrap();
        assert!(s.makespan() > 0.0);
    }

    #[test]
    fn pinned_placement_respected() {
        let (g, plat, comp) = tiny();
        let inst = InstanceRef::new(&g, &plat, &comp);
        let prio = vec![3.0, 2.0, 1.0, 0.0];
        let pin = vec![None, Some(1usize), None, Some(1usize)];
        let s = list_schedule(inst, &prio, &Placement::Pinned(pin));
        s.validate(inst).unwrap();
        assert_eq!(s.assignments[1].proc, 1);
        assert_eq!(s.assignments[3].proc, 1);
    }

    #[test]
    fn workspace_list_schedule_matches_wrapper_and_reuses() {
        let (g, plat, comp) = tiny();
        let inst = InstanceRef::new(&g, &plat, &comp);
        let prio = vec![3.0, 2.0, 1.0, 0.0];
        let wrapped = list_schedule(inst, &prio, &Placement::MinEft);
        let mut ws = Workspace::new();
        ws.prio.extend_from_slice(&prio);
        let a = list_schedule_with(&mut ws, inst, PlacementWs::MinEft);
        // dirty reuse: refill priorities, schedule again
        ws.prio.clear();
        ws.prio.extend_from_slice(&prio);
        let b = list_schedule_with(&mut ws, inst, PlacementWs::MinEft);
        assert_eq!(wrapped.assignments, a.assignments);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn insertion_fills_gaps() {
        // one proc; schedule long task, then a task constrained to start
        // late, then verify a short independent task slots into the gap.
        let g = TaskGraph::from_edges(3, &[(0, 1, 50.0)]); // 2 independent of chain
        let plat = Platform::uniform(2, 1.0, 0.0);
        // task 0 tiny on proc0; task 1 must wait 50 comm if it moves, so it
        // stays on proc0 after a gap? Instead verify validity + makespan sane.
        let comp =
            CostMatrix::new(2, vec![5.0, 100.0, 10.0, 100.0, 3.0, 100.0]);
        let inst = InstanceRef::new(&g, &plat, &comp);
        let prio = vec![2.0, 1.0, 0.0];
        let s = list_schedule(inst, &prio, &Placement::MinEft);
        s.validate(inst).unwrap();
        // all three prefer proc 0 (100x slower on proc 1); insertion keeps
        // makespan = 5 + 10 + 3 at worst
        assert!(s.makespan() <= 18.0 + 1e-9);
    }

    #[test]
    fn validate_catches_overlap() {
        let g = TaskGraph::from_edges(2, &[]);
        let plat = Platform::uniform(1, 1.0, 0.0);
        let comp = CostMatrix::new(1, vec![5.0, 5.0]);
        let s = Schedule {
            assignments: vec![
                Assignment { proc: 0, start: 0.0, finish: 5.0 },
                Assignment { proc: 0, start: 3.0, finish: 8.0 },
            ],
            p: 1,
        };
        assert!(s
            .validate(InstanceRef::new(&g, &plat, &comp))
            .unwrap_err()
            .contains("overlap"));
    }

    #[test]
    fn validate_catches_early_start() {
        let g = TaskGraph::from_edges(2, &[(0, 1, 10.0)]);
        let plat = Platform::uniform(2, 1.0, 0.0);
        let comp = CostMatrix::new(2, vec![5.0, 5.0, 5.0, 5.0]);
        let s = Schedule {
            assignments: vec![
                Assignment { proc: 0, start: 0.0, finish: 5.0 },
                // starts at 6 on another proc; data arrives at 5 + 10 = 15
                Assignment { proc: 1, start: 6.0, finish: 11.0 },
            ],
            p: 2,
        };
        assert!(s
            .validate(InstanceRef::new(&g, &plat, &comp))
            .unwrap_err()
            .contains("before input"));
    }

    #[test]
    fn validate_catches_wrong_duration() {
        let g = TaskGraph::from_edges(1, &[]);
        let plat = Platform::uniform(1, 1.0, 0.0);
        let comp = CostMatrix::new(1, vec![5.0]);
        let s = Schedule {
            assignments: vec![Assignment { proc: 0, start: 0.0, finish: 2.0 }],
            p: 1,
        };
        assert!(s
            .validate(InstanceRef::new(&g, &plat, &comp))
            .unwrap_err()
            .contains("duration"));
    }

    #[test]
    fn algorithm_registry_is_consistent() {
        // names unique, ids unique, registry name == scheduler name
        let mut names = std::collections::HashSet::new();
        let mut ids = std::collections::HashSet::new();
        for a in Algorithm::ALL {
            assert!(names.insert(a.name()), "duplicate name {}", a.name());
            assert!(ids.insert(a.id()), "duplicate id {}", a.id());
            assert_eq!(a.name(), a.scheduler().name());
        }
    }

    #[test]
    fn algorithm_parse_accepts_aliases_and_rejects_unknown() {
        assert_eq!(Algorithm::parse("CEFT-CPOP").unwrap(), Algorithm::CeftCpop);
        assert_eq!(Algorithm::parse("ceft_cpop").unwrap(), Algorithm::CeftCpop);
        assert_eq!(Algorithm::parse(" heft ").unwrap(), Algorithm::Heft);
        assert_eq!(
            Algorithm::parse("ceft-heft-down").unwrap(),
            Algorithm::CeftHeftDown
        );
        let e = Algorithm::parse("nope").unwrap_err();
        assert!(e.contains("unknown algorithm"));
        assert!(e.contains("CEFT-CPOP"));
    }

    #[test]
    fn algorithm_dispatch_matches_direct_scheduler() {
        let (g, plat, comp) = tiny();
        let inst = InstanceRef::new(&g, &plat, &comp);
        let via_registry = Algorithm::CeftCpop.schedule(inst);
        let direct = crate::sched::ceft_cpop::CeftCpop.schedule(inst);
        assert_eq!(via_registry.assignments, direct.assignments);
    }

    #[test]
    fn run_with_tables_matches_run_with_for_every_algorithm() {
        // the declared-orientation table path and the recomputing path
        // must agree bit for bit; None always falls back to run_with
        let (g, plat, comp) = tiny();
        let inst = InstanceRef::new(&g, &plat, &comp);
        let mut ws = Workspace::new();
        let mut ws2 = Workspace::new();
        for a in Algorithm::ALL {
            let direct = a.run_with(&mut ws, inst);
            let table = match a.table_use() {
                Some(TableDir::Forward) => {
                    Some(crate::cp::ceft::ceft_table_with(&mut ws2, inst))
                }
                Some(TableDir::Reverse) => {
                    Some(crate::cp::ceft::ceft_table_rev_with(&mut ws2, inst))
                }
                None => None,
            };
            let via_table = a.run_with_tables(&mut ws2, inst, table.as_ref());
            assert_eq!(direct.assignments, via_table.assignments, "{}", a.name());
            let fallback = a.run_with_tables(&mut ws2, inst, None);
            assert_eq!(direct.assignments, fallback.assignments, "{}", a.name());
        }
    }

    #[test]
    #[allow(deprecated)]
    fn raw_shim_matches_instance_ref_path() {
        let (g, plat, comp) = tiny();
        let inst = InstanceRef::new(&g, &plat, &comp);
        let via_ref = Algorithm::Heft.schedule(inst);
        let via_raw = schedule_raw(Algorithm::Heft, &g, &plat, comp.as_slice());
        assert_eq!(via_ref.assignments, via_raw.assignments);
    }

    #[test]
    fn higher_priority_pops_first_on_ties() {
        // two independent tasks, same priority -> lower id first; both on
        // the faster proc in sequence or split across procs.
        let g = TaskGraph::from_edges(2, &[]);
        let plat = Platform::uniform(2, 1.0, 0.0);
        let comp = CostMatrix::new(2, vec![1.0, 1.0, 1.0, 1.0]);
        let inst = InstanceRef::new(&g, &plat, &comp);
        let s = list_schedule(inst, &[1.0, 1.0], &Placement::MinEft);
        s.validate(inst).unwrap();
        // both start at 0 on different procs
        assert_eq!(s.makespan(), 1.0);
    }
}
