//! HEFT — Heterogeneous Earliest Finish Time (Topcuoglu et al., 2002).
//!
//! Tasks are prioritised by the mean-value upward rank and placed on the
//! processor minimising the insertion-based earliest finish time. HEFT is
//! the paper's "state of the art" reference point (it is *not* critical-path
//! based, so it only appears in makespan-derived comparisons).

use super::{list_schedule_with, PlacementWs, Schedule, Scheduler};
use crate::cp::ranks::{rank_downward_into, rank_upward_into};
use crate::cp::workspace::Workspace;
use crate::graph::TaskGraph;
use crate::platform::Platform;

/// Classic HEFT: descending `rank_u` priority, min-EFT placement.
#[derive(Clone, Copy, Debug, Default)]
pub struct Heft;

impl Scheduler for Heft {
    fn name(&self) -> &'static str {
        "HEFT"
    }

    fn schedule_with(
        &self,
        ws: &mut Workspace,
        graph: &TaskGraph,
        platform: &Platform,
        comp: &[f64],
    ) -> Schedule {
        rank_upward_into(graph, platform, comp, &mut ws.prio);
        list_schedule_with(ws, graph, platform, comp, PlacementWs::MinEft)
    }
}

/// HEFT-DOWN (§8.2): the same scheduler driven by the *downward* rank.
/// Since `rank_d` grows from entry to exit, tasks are ordered by ascending
/// downward rank (the only topologically consistent direction).
#[derive(Clone, Copy, Debug, Default)]
pub struct HeftDown;

impl Scheduler for HeftDown {
    fn name(&self) -> &'static str {
        "HEFT-DOWN"
    }

    fn schedule_with(
        &self,
        ws: &mut Workspace,
        graph: &TaskGraph,
        platform: &Platform,
        comp: &[f64],
    ) -> Schedule {
        rank_downward_into(graph, platform, comp, &mut ws.down);
        ws.prio.clear();
        ws.prio.extend(ws.down.iter().map(|d| -d));
        list_schedule_with(ws, graph, platform, comp, PlacementWs::MinEft)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{generate, RggParams};
    use crate::metrics;
    use crate::platform::CostModel;

    fn instance(seed: u64) -> (TaskGraph, Platform, Vec<f64>) {
        let plat = Platform::uniform(4, 1.0, 0.0);
        let inst = generate(
            &RggParams {
                n: 100,
                out_degree: 3,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.2,
            },
            &CostModel::Classic { beta: 0.5 },
            &plat,
            seed,
        );
        (inst.graph, plat, inst.comp)
    }

    #[test]
    fn heft_produces_valid_schedules() {
        for seed in 0..5 {
            let (g, plat, comp) = instance(seed);
            let s = Heft.schedule(&g, &plat, &comp);
            s.validate(&g, &plat, &comp).unwrap();
        }
    }

    #[test]
    fn heft_down_produces_valid_schedules() {
        for seed in 0..5 {
            let (g, plat, comp) = instance(seed);
            let s = HeftDown.schedule(&g, &plat, &comp);
            s.validate(&g, &plat, &comp).unwrap();
        }
    }

    #[test]
    fn heft_beats_serial_execution() {
        let (g, plat, comp) = instance(7);
        let s = Heft.schedule(&g, &plat, &comp);
        let serial = metrics::serial_time(&comp, 4);
        assert!(s.makespan() < serial, "heft should beat best serial");
    }

    #[test]
    fn heft_respects_cpmin_lower_bound() {
        let (g, plat, comp) = instance(11);
        let s = Heft.schedule(&g, &plat, &comp);
        let lb = crate::cp::cpmin::cp_min_cost(&g, &comp, 4);
        assert!(s.makespan() + 1e-9 >= lb);
    }

    #[test]
    fn heft_on_known_example() {
        // 0 -> {1,2} -> 3 with strongly class-specialised tasks
        let g = TaskGraph::from_edges(
            4,
            &[(0, 1, 1.0), (0, 2, 1.0), (1, 3, 1.0), (2, 3, 1.0)],
        );
        let plat = Platform::uniform(2, 1.0, 0.0);
        #[rustfmt::skip]
        let comp = vec![
            1.0, 9.0,
            8.0, 1.0,
            1.0, 8.0,
            1.0, 9.0,
        ];
        let s = Heft.schedule(&g, &plat, &comp);
        s.validate(&g, &plat, &comp).unwrap();
        // the specialised tasks should land on their fast classes
        assert_eq!(s.assignments[1].proc, 1);
        assert_eq!(s.assignments[2].proc, 0);
    }
}
