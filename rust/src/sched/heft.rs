//! HEFT — Heterogeneous Earliest Finish Time (Topcuoglu et al., 2002).
//!
//! Tasks are prioritised by the mean-value upward rank and placed on the
//! processor minimising the insertion-based earliest finish time. HEFT is
//! the paper's "state of the art" reference point (it is *not* critical-path
//! based, so it only appears in makespan-derived comparisons).

use super::{list_schedule_with, PlacementWs, Schedule, Scheduler};
use crate::cp::ranks::{rank_downward_into, rank_upward_into};
use crate::cp::workspace::Workspace;
use crate::model::InstanceRef;

/// Classic HEFT: descending `rank_u` priority, min-EFT placement.
#[derive(Clone, Copy, Debug, Default)]
pub struct Heft;

impl Scheduler for Heft {
    fn name(&self) -> &'static str {
        "HEFT"
    }

    fn schedule_with(&self, ws: &mut Workspace, inst: InstanceRef) -> Schedule {
        rank_upward_into(inst, &mut ws.prio);
        list_schedule_with(ws, inst, PlacementWs::MinEft)
    }
}

/// HEFT-DOWN (§8.2): the same scheduler driven by the *downward* rank.
/// Since `rank_d` grows from entry to exit, tasks are ordered by ascending
/// downward rank (the only topologically consistent direction).
#[derive(Clone, Copy, Debug, Default)]
pub struct HeftDown;

impl Scheduler for HeftDown {
    fn name(&self) -> &'static str {
        "HEFT-DOWN"
    }

    fn schedule_with(&self, ws: &mut Workspace, inst: InstanceRef) -> Schedule {
        rank_downward_into(inst, &mut ws.down);
        ws.prio.clear();
        ws.prio.extend(ws.down.iter().map(|d| -d));
        list_schedule_with(ws, inst, PlacementWs::MinEft)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{generate, RggParams};
    use crate::graph::TaskGraph;
    use crate::metrics;
    use crate::model::CostMatrix;
    use crate::platform::{CostModel, Platform};

    fn instance(seed: u64) -> (crate::graph::generator::Instance, Platform) {
        let plat = Platform::uniform(4, 1.0, 0.0);
        let inst = generate(
            &RggParams {
                n: 100,
                out_degree: 3,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.2,
            },
            &CostModel::Classic { beta: 0.5 },
            &plat,
            seed,
        );
        (inst, plat)
    }

    #[test]
    fn heft_produces_valid_schedules() {
        for seed in 0..5 {
            let (inst, plat) = instance(seed);
            let iref = inst.bind(&plat);
            let s = Heft.schedule(iref);
            s.validate(iref).unwrap();
        }
    }

    #[test]
    fn heft_down_produces_valid_schedules() {
        for seed in 0..5 {
            let (inst, plat) = instance(seed);
            let iref = inst.bind(&plat);
            let s = HeftDown.schedule(iref);
            s.validate(iref).unwrap();
        }
    }

    #[test]
    fn heft_beats_serial_execution() {
        let (inst, plat) = instance(7);
        let iref = inst.bind(&plat);
        let s = Heft.schedule(iref);
        let serial = metrics::serial_time(&inst.comp);
        assert!(s.makespan() < serial, "heft should beat best serial");
    }

    #[test]
    fn heft_respects_cpmin_lower_bound() {
        let (inst, plat) = instance(11);
        let iref = inst.bind(&plat);
        let s = Heft.schedule(iref);
        let lb = crate::cp::cpmin::cp_min_cost(iref);
        assert!(s.makespan() + 1e-9 >= lb);
    }

    #[test]
    fn heft_on_known_example() {
        // 0 -> {1,2} -> 3 with strongly class-specialised tasks
        let g = TaskGraph::from_edges(
            4,
            &[(0, 1, 1.0), (0, 2, 1.0), (1, 3, 1.0), (2, 3, 1.0)],
        );
        let plat = Platform::uniform(2, 1.0, 0.0);
        #[rustfmt::skip]
        let comp = CostMatrix::new(2, vec![
            1.0, 9.0,
            8.0, 1.0,
            1.0, 8.0,
            1.0, 9.0,
        ]);
        let inst = InstanceRef::new(&g, &plat, &comp);
        let s = Heft.schedule(inst);
        s.validate(inst).unwrap();
        // the specialised tasks should land on their fast classes
        assert_eq!(s.assignments[1].proc, 1);
        assert_eq!(s.assignments[2].proc, 0);
    }
}
