//! CPOP — Critical Path On a Processor (Topcuoglu et al., 2002),
//! Algorithm 2 of the paper.
//!
//! Priorities are `rank_u + rank_d` on mean costs; the (mean-value) critical
//! path is extracted by priority equality and pinned *in its entirety* onto
//! the single processor minimising its total execution time. The paper
//! argues this single-processor restriction is CPOP's central weakness once
//! tasks on the path prefer different classes.

use super::{list_schedule_with, PlacementWs, Schedule, Scheduler};
use crate::cp::ranks::{cpop_cp_from_priorities, cpop_cp_processor, cpop_priorities_into};
use crate::cp::workspace::Workspace;
use crate::model::InstanceRef;

/// Classic CPOP.
#[derive(Clone, Copy, Debug, Default)]
pub struct Cpop;

impl Scheduler for Cpop {
    fn name(&self) -> &'static str {
        "CPOP"
    }

    fn schedule_with(&self, ws: &mut Workspace, inst: InstanceRef) -> Schedule {
        cpop_priorities_into(ws, inst);
        // Algorithm 2 lines 5-13 over the priorities just computed (the
        // classic signature recomputed the ranks a second time here).
        cpop_cp_from_priorities(inst.graph, &ws.prio, &mut ws.cp_tasks);
        let p_cp = cpop_cp_processor(&ws.cp_tasks, inst.costs);
        ws.pins.clear();
        ws.pins.resize(inst.n(), None);
        for &t in &ws.cp_tasks {
            ws.pins[t] = Some(p_cp);
        }
        list_schedule_with(ws, inst, PlacementWs::Pinned)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::ranks::cpop_critical_path;
    use crate::graph::generator::{generate, Instance, RggParams};
    use crate::platform::{CostModel, Platform};

    fn instance(seed: u64, p: usize) -> (Instance, Platform) {
        let plat = Platform::uniform(p, 1.0, 0.0);
        let inst = generate(
            &RggParams {
                n: 80,
                out_degree: 3,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 75.0,
                gamma: 0.2,
            },
            &CostModel::Classic { beta: 0.75 },
            &plat,
            seed,
        );
        (inst, plat)
    }

    #[test]
    fn cpop_schedules_are_valid() {
        for seed in 0..5 {
            let (inst, plat) = instance(seed, 4);
            let iref = inst.bind(&plat);
            let s = Cpop.schedule(iref);
            s.validate(iref).unwrap();
        }
    }

    #[test]
    fn critical_path_tasks_share_one_processor() {
        let (inst, plat) = instance(3, 4);
        let iref = inst.bind(&plat);
        let (cp, _) = cpop_critical_path(iref);
        let s = Cpop.schedule(iref);
        let procs: std::collections::HashSet<usize> =
            cp.iter().map(|&t| s.assignments[t].proc).collect();
        assert_eq!(procs.len(), 1, "CPOP must pin the whole CP to one proc");
    }

    #[test]
    fn cp_is_entry_to_exit_connected() {
        let (inst, plat) = instance(9, 4);
        let iref = inst.bind(&plat);
        let (cp, _) = cpop_critical_path(iref);
        let g = &inst.graph;
        assert_eq!(g.in_degree(cp[0]), 0);
        assert_eq!(g.out_degree(*cp.last().unwrap()), 0);
        for w in cp.windows(2) {
            assert!(g.succs(w[0]).iter().any(|&(s, _)| s == w[1]));
        }
    }

    #[test]
    fn single_proc_cpop_is_serial() {
        let (inst, plat) = instance(5, 1);
        let iref = inst.bind(&plat);
        let s = Cpop.schedule(iref);
        s.validate(iref).unwrap();
        let serial: f64 = inst.comp.as_slice().iter().sum();
        assert!((s.makespan() - serial).abs() < 1e-6);
    }
}
