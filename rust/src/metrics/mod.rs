//! Comparison metrics (§7.3 of the paper): makespan, speedup, SLR, slack,
//! and pairwise longer/equal/shorter tallies. Instance-derived metrics
//! consume the [`InstanceRef`] view; cost-only metrics take the
//! [`CostMatrix`] directly.

use crate::cp::cpmin::cp_min_cost;
use crate::model::{CostMatrix, InstanceRef};
use crate::sched::Schedule;

/// Makespan of a schedule (§7.3.3 context).
pub fn makespan(s: &Schedule) -> f64 {
    s.makespan()
}

/// Best sequential execution time: all tasks on the single processor
/// minimising the total (the numerator of eq. 8). Independent of the
/// scheduling algorithm.
pub fn serial_time(costs: &CostMatrix) -> f64 {
    let v = costs.n();
    (0..costs.p())
        .map(|j| (0..v).map(|t| costs.get(t, j)).sum::<f64>())
        .fold(f64::INFINITY, f64::min)
}

/// Speedup (eq. 8): best sequential time / makespan.
pub fn speedup(costs: &CostMatrix, makespan: f64) -> f64 {
    serial_time(costs) / makespan
}

/// Schedule length ratio (eq. 9): makespan normalised by the
/// minimum-computation critical path. `>= 1` for every valid schedule.
pub fn slr(inst: InstanceRef, makespan: f64) -> f64 {
    makespan / cp_min_cost(inst)
}

/// Slack (eq. 10): mean over tasks of `M − b_level(t) − t_level(t)`,
/// computed on the *scheduled* DAG — each task weighted by its realised
/// execution cost on its assigned processor, each edge by the realised
/// communication cost between the assigned processors.
pub fn slack(inst: InstanceRef, s: &Schedule) -> f64 {
    let graph = inst.graph;
    let platform = inst.platform;
    let costs = inst.costs;
    let v = graph.num_tasks();
    let m = s.makespan();
    let w = |t: usize| costs.get(t, s.assignments[t].proc);
    let c = |k: usize, t: usize, data: f64| {
        platform.comm_cost(s.assignments[k].proc, s.assignments[t].proc, data)
    };
    // t_level: longest path from an entry up to (excluding) t
    let mut tlevel = vec![0f64; v];
    for &t in graph.topo_order() {
        let mut best = 0f64;
        for &(k, data) in graph.preds(t) {
            best = best.max(tlevel[k] + w(k) + c(k, t, data));
        }
        tlevel[t] = best;
    }
    // b_level: longest path from t (inclusive) to an exit
    let mut blevel = vec![0f64; v];
    for &t in graph.topo_order().iter().rev() {
        let mut best = 0f64;
        for &(su, data) in graph.succs(t) {
            best = best.max(c(t, su, data) + blevel[su]);
        }
        blevel[t] = w(t) + best;
    }
    let total: f64 = (0..v).map(|t| m - blevel[t] - tlevel[t]).sum();
    total / v as f64
}

/// Outcome of a pairwise comparison with relative tolerance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    /// left value is larger
    Longer,
    /// equal within tolerance
    Equal,
    /// left value is smaller
    Shorter,
}

/// Compare `a` vs `b` with relative epsilon (the Table 3
/// longer/equal/shorter classification).
pub fn compare(a: f64, b: f64, rel_eps: f64) -> Cmp {
    let tol = rel_eps * a.abs().max(b.abs()).max(1e-30);
    if (a - b).abs() <= tol {
        Cmp::Equal
    } else if a > b {
        Cmp::Longer
    } else {
        Cmp::Shorter
    }
}

/// Tally of pairwise outcomes, convertible to Table 3 percentages.
#[derive(Clone, Copy, Debug, Default)]
pub struct WinTally {
    /// count of Longer outcomes
    pub longer: u64,
    /// count of Equal outcomes
    pub equal: u64,
    /// count of Shorter outcomes
    pub shorter: u64,
}

impl WinTally {
    /// Record one comparison.
    pub fn push(&mut self, c: Cmp) {
        match c {
            Cmp::Longer => self.longer += 1,
            Cmp::Equal => self.equal += 1,
            Cmp::Shorter => self.shorter += 1,
        }
    }

    /// Merge another tally.
    pub fn merge(&mut self, o: &WinTally) {
        self.longer += o.longer;
        self.equal += o.equal;
        self.shorter += o.shorter;
    }

    /// Total comparisons recorded.
    pub fn total(&self) -> u64 {
        self.longer + self.equal + self.shorter
    }

    /// `(longer%, equal%, shorter%)`.
    pub fn percentages(&self) -> (f64, f64, f64) {
        let n = self.total().max(1) as f64;
        (
            100.0 * self.longer as f64 / n,
            100.0 * self.equal as f64 / n,
            100.0 * self.shorter as f64 / n,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskGraph;
    use crate::platform::Platform;
    use crate::sched::{Placement, Scheduler};

    fn chain() -> (TaskGraph, Platform, CostMatrix) {
        let g = TaskGraph::from_edges(3, &[(0, 1, 10.0), (1, 2, 10.0)]);
        let plat = Platform::uniform(2, 1.0, 0.0);
        let comp = CostMatrix::new(2, vec![2.0, 4.0, 2.0, 4.0, 2.0, 4.0]);
        (g, plat, comp)
    }

    #[test]
    fn serial_time_picks_best_processor() {
        let (_, _, comp) = chain();
        assert_eq!(serial_time(&comp), 6.0);
    }

    #[test]
    fn speedup_of_serial_schedule_is_one() {
        let (g, plat, comp) = chain();
        let inst = InstanceRef::new(&g, &plat, &comp);
        let s = crate::sched::list_schedule(inst, &[2.0, 1.0, 0.0], &Placement::MinEft);
        // chain on one proc: makespan 6 == best serial
        assert!((speedup(&comp, s.makespan()) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn slr_at_least_one() {
        let (g, plat, comp) = chain();
        let inst = InstanceRef::new(&g, &plat, &comp);
        let s = crate::sched::heft::Heft.schedule(inst);
        assert!(slr(inst, s.makespan()) >= 1.0 - 1e-12);
    }

    #[test]
    fn slack_zero_on_linear_dag() {
        // the paper: a linear DAG's schedule has zero slack
        let (g, plat, comp) = chain();
        let inst = InstanceRef::new(&g, &plat, &comp);
        let s = crate::sched::heft::Heft.schedule(inst);
        let sl = slack(inst, &s);
        assert!(sl.abs() < 1e-9, "slack={sl}");
    }

    #[test]
    fn slack_positive_on_parallel_dag() {
        let g = TaskGraph::from_edges(
            4,
            &[(0, 1, 0.1), (0, 2, 0.1), (1, 3, 0.1), (2, 3, 0.1)],
        );
        let plat = Platform::uniform(2, 1.0, 0.0);
        // branch 2 much shorter than branch 1 -> it has slack
        let comp =
            CostMatrix::new(2, vec![1.0, 1.0, 50.0, 50.0, 1.0, 1.0, 1.0, 1.0]);
        let inst = InstanceRef::new(&g, &plat, &comp);
        let s = crate::sched::heft::Heft.schedule(inst);
        assert!(slack(inst, &s) > 0.0);
    }

    #[test]
    fn compare_with_tolerance() {
        assert_eq!(compare(1.0, 1.0 + 1e-12, 1e-9), Cmp::Equal);
        assert_eq!(compare(2.0, 1.0, 1e-9), Cmp::Longer);
        assert_eq!(compare(1.0, 2.0, 1e-9), Cmp::Shorter);
    }

    #[test]
    fn tally_percentages() {
        let mut t = WinTally::default();
        t.push(Cmp::Longer);
        t.push(Cmp::Shorter);
        t.push(Cmp::Shorter);
        t.push(Cmp::Equal);
        let (l, e, s) = t.percentages();
        assert!((l - 25.0).abs() < 1e-9);
        assert!((e - 25.0).abs() < 1e-9);
        assert!((s - 50.0).abs() < 1e-9);
        let mut t2 = WinTally::default();
        t2.push(Cmp::Longer);
        t.merge(&t2);
        assert_eq!(t.total(), 5);
    }
}
