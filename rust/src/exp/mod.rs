//! The experiment harness: regenerates every table and figure of the
//! paper's evaluation (§7–§8).
//!
//! * [`cells`] — the factorial experiment grids (workload families ×
//!   parameter sweeps × processor graphs) at three scales.
//! * [`run`] — run one cell (generate instance → run every algorithm →
//!   record every metric) and whole sweeps in parallel.
//! * [`figures`] — aggregate result rows into the paper's tables/figures
//!   (Table 3, Figures 5–20) as CSV + ASCII tables.

pub mod cells;
pub mod figures;
pub mod run;

pub use cells::{grid, realworld_grid, Cell, Scale, Workload};
pub use run::{run_cell, run_sweep, Row, ALGOS};
