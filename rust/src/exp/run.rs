//! Running experiment cells: instance generation, algorithm execution,
//! metric collection.
//!
//! Every cell runs through a [`PlatformCtx`] — the platform's resident
//! communication panels — and the sweep drivers intern one context per
//! **distinct platform per run** ([`SweepCtxCache`], bounded): workloads
//! whose platform is shared across cells (the uniform-platform families)
//! price thousands of cells against one set of panels, while workloads
//! that draw a fresh platform per cell (the two-weight families) bypass
//! the intern table past its cap, so sweep memory stays bounded either
//! way. Scratch arenas stay in one pool per sweep, shared across workers
//! as before — per-platform arena pooling is the long-lived service's
//! concern ([`crate::service`]), not a bounded batch run's.

use super::cells::{Cell, RealWorldCell};
use crate::cp::ceft::{ceft_table_with, critical_path_from_table};
use crate::cp::cpmin::cp_min_cost_with;
use crate::cp::minexec::min_exec_critical_path_with;
use crate::cp::ranks::{cpop_cp_from_priorities, cpop_priorities_into};
use crate::cp::workspace::{Workspace, WorkspacePool};
use crate::graph::generator::{generate, Instance, RggParams};
use crate::graph::realworld;
use crate::metrics;
use crate::model::PlatformCtx;
use crate::platform::{CostModel, Platform};
use crate::sched::Algorithm;
use crate::util::hashing;
use crate::util::pool;
use crate::util::rng::SplitMix64;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Salt XORed into cell seeds to derive the independent platform RNG stream.
const PLATFORM_SEED_SALT: u64 = 0x504C_4154_504C_4154; // "PLATPLAT"

/// The schedulers every cell runs, in result-column order — derived from
/// the unified [`Algorithm`] registry so the batch harness, the CLI, and
/// the online service all agree on names and ordering.
pub const ALGOS: [&str; 6] = [
    Algorithm::Cpop.name(),
    Algorithm::Heft.name(),
    Algorithm::CeftCpop.name(),
    Algorithm::HeftDown.name(),
    Algorithm::CeftHeftUp.name(),
    Algorithm::CeftHeftDown.name(),
];

/// Per-algorithm metrics for one cell.
#[derive(Clone, Copy, Debug, Default)]
pub struct AlgoResult {
    /// makespan of the produced schedule
    pub makespan: f64,
    /// eq. 8 speedup
    pub speedup: f64,
    /// eq. 9 schedule length ratio
    pub slr: f64,
    /// eq. 10 slack
    pub slack: f64,
}

/// Full record of one experiment.
#[derive(Clone, Debug)]
pub struct Row {
    /// workload family name (or real-world family)
    pub workload: String,
    /// grid coordinates
    pub n: usize,
    /// average out-degree (0 for real-world graphs)
    pub out_degree: usize,
    /// CCR
    pub ccr: f64,
    /// α (0 for real-world graphs — their structure is fixed, §7.2)
    pub alpha: f64,
    /// β percent
    pub beta_pct: f64,
    /// γ (0 for real-world)
    pub gamma: f64,
    /// processors
    pub p: usize,
    /// CEFT critical-path length (with partial assignment)
    pub cpl_ceft: f64,
    /// CPOP mean-value critical-path length estimate (|CP|)
    pub cpl_cpop: f64,
    /// CPOP's path re-costed on its single chosen processor
    pub cpl_cpop_realized: f64,
    /// min-execution-time CP (zero comm), the §3 baseline
    pub cpl_minexec: f64,
    /// CP_MIN (SLR denominator)
    pub cp_min: f64,
    /// per-algorithm results, aligned with [`ALGOS`]
    pub algos: [AlgoResult; 6],
}

impl Row {
    /// Result for a named algorithm.
    pub fn algo(&self, name: &str) -> &AlgoResult {
        let i = ALGOS.iter().position(|&a| a == name).expect("unknown algo");
        &self.algos[i]
    }
}

/// Interned contexts per sweep are capped here: legitimate sharing needs
/// a handful of entries (one per distinct `(p, platform kind)` the grid
/// sweeps), while per-cell-platform workloads would otherwise intern one
/// context per cell and grow without bound. Past the cap, `get` hands out
/// correct unshared contexts that die with their cell.
const MAX_INTERNED_PLATFORMS: usize = 32;

/// One [`PlatformCtx`] per distinct platform for a sweep, bounded at
/// [`MAX_INTERNED_PLATFORMS`]: cells whose platforms hash equal (and
/// match content — hash collisions fall back to a fresh unshared context
/// rather than mispricing) share resident panels; platforms beyond the
/// cap get unshared contexts, so a sweep whose workload draws a fresh
/// platform per cell retains `O(cap)` contexts, not `O(cells)`. `Sync`,
/// so parallel sweep workers intern through one cache; the `O(P²)`
/// context build always runs outside the map lock.
pub struct SweepCtxCache {
    map: Mutex<HashMap<u64, Arc<PlatformCtx>>>,
}

impl Default for SweepCtxCache {
    fn default() -> Self {
        Self::new()
    }
}

impl SweepCtxCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self {
            map: Mutex::new(HashMap::new()),
        }
    }

    /// The context for `platform`, building (and interning, below the
    /// cap) it on first sight. Panels are computed at most once per
    /// distinct platform per sweep while the intern table has room; a
    /// racing build of the same platform is resolved by re-checking after
    /// the (lock-free) build, like the engine's intern path.
    pub fn get(&self, platform: Platform) -> Arc<PlatformCtx> {
        let hash = hashing::hash_platform(&platform);
        {
            let map = self.map.lock().unwrap();
            if let Some(ctx) = map.get(&hash) {
                if ctx.platform().content_eq(&platform) {
                    return ctx.clone();
                }
                // 64-bit hash collision between different platforms: fall
                // through and serve a correct unshared context instead of
                // another platform's panels
            }
        }
        // O(P²) build with the lock released; ctx pools are unused by the
        // sweep drivers (they share one sweep-wide workspace pool), so the
        // idle cap is minimal
        let built = Arc::new(PlatformCtx::bounded_prehashed(Arc::new(platform), 1, hash));
        let mut map = self.map.lock().unwrap();
        match map.get(&hash).cloned() {
            Some(raced) if raced.platform().content_eq(built.platform()) => raced,
            Some(_) => built, // collision: unshared, never interned
            None => {
                if map.len() < MAX_INTERNED_PLATFORMS {
                    map.insert(hash, built.clone());
                }
                built
            }
        }
    }

    /// Distinct platforms interned so far (bounded by
    /// [`MAX_INTERNED_PLATFORMS`]).
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// Whether no platform has been interned yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Build the platform + instance for an RGG cell (deterministic in the cell).
pub fn build_instance(cell: &Cell) -> (Platform, Instance) {
    let seed = SplitMix64::seed_for(&[cell.workload.id(), cell.index]);
    let mut plat_rng = crate::util::rng::Xoshiro256::new(seed ^ PLATFORM_SEED_SALT);
    let platform = if cell.workload.needs_two_weight_platform() {
        Platform::two_weight(cell.p, cell.beta_pct / 100.0, &mut plat_rng, 1.0, 0.0)
    } else {
        Platform::uniform(cell.p, 1.0, 0.0)
    };
    let params = RggParams {
        n: cell.n,
        out_degree: cell.out_degree,
        ccr: cell.ccr,
        alpha: cell.alpha,
        beta_pct: cell.beta_pct,
        gamma: cell.gamma,
    };
    let model = cell.workload.cost_model(cell.beta_pct);
    let inst = generate(&params, &model, &platform, seed);
    (platform, inst)
}

/// Run every algorithm and metric on one instance (one-shot workspace and
/// context).
#[allow(clippy::too_many_arguments)]
pub fn run_instance(
    workload: &str,
    n: usize,
    out_degree: usize,
    ccr: f64,
    alpha: f64,
    beta_pct: f64,
    gamma: f64,
    platform: &Platform,
    inst: &Instance,
) -> Row {
    let ctx = PlatformCtx::new(platform.clone());
    run_instance_with(
        &mut Workspace::new(),
        workload,
        n,
        out_degree,
        ccr,
        alpha,
        beta_pct,
        gamma,
        &ctx,
        inst,
    )
}

/// Run every algorithm and metric on one instance, borrowing `ws` for all
/// transient state and `ctx` for the platform's resident panels — the
/// sweep drivers below hand each worker a pooled workspace and an
/// interned context so a 10k-cell grid neither re-allocates DP tables nor
/// refills shared platforms' communication panels per cell.
#[allow(clippy::too_many_arguments)]
pub fn run_instance_with(
    ws: &mut Workspace,
    workload: &str,
    n: usize,
    out_degree: usize,
    ccr: f64,
    alpha: f64,
    beta_pct: f64,
    gamma: f64,
    ctx: &PlatformCtx,
    inst: &Instance,
) -> Row {
    let iref = inst.bind_ctx(ctx);
    let p = ctx.p();

    // One forward CEFT DP serves the whole row: the critical path is
    // derived from the table instead of a second sweep, and the
    // forward-table consumers below (CEFT-CPOP, CEFT-HEFT-DOWN) borrow it
    // through `run_with_tables` — bit-identical to each running its own DP
    // (`prop_run_with_tables_bit_identical`), one DP instead of three.
    let fwd_table = ceft_table_with(ws, iref);
    let ceft_cp = critical_path_from_table(iref.graph, &fwd_table);
    // CPOP's mean-value CP from ranks computed in workspace buffers
    cpop_priorities_into(ws, iref);
    let cpl_cpop = cpop_cp_from_priorities(iref.graph, &ws.prio, &mut ws.cp_tasks);
    let cpl_cpop_realized = crate::cp::ranks::cpop_realized_cp_length(&ws.cp_tasks, iref.costs);
    let minexec = min_exec_critical_path_with(ws, iref, false);
    let cp_min = cp_min_cost_with(ws, iref);

    let mut algos = [AlgoResult::default(); 6];
    for (i, a) in Algorithm::ALL.iter().enumerate() {
        let table = match a.table_use() {
            Some(crate::sched::TableDir::Forward) => Some(&fwd_table),
            _ => None,
        };
        let schedule = a.run_with_tables(ws, iref, table);
        debug_assert!(schedule.validate(iref).is_ok());
        let m = schedule.makespan();
        algos[i] = AlgoResult {
            makespan: m,
            speedup: metrics::speedup(iref.costs, m),
            slr: metrics::slr(iref, m),
            slack: metrics::slack(iref, &schedule),
        };
    }

    Row {
        workload: workload.to_string(),
        n,
        out_degree,
        ccr,
        alpha,
        beta_pct,
        gamma,
        p,
        cpl_ceft: ceft_cp.length,
        cpl_cpop,
        cpl_cpop_realized,
        cpl_minexec: minexec.length,
        cp_min,
        algos,
    }
}

/// Run one RGG cell end to end (one-shot workspace and context).
pub fn run_cell(cell: &Cell) -> Row {
    run_cell_with(&mut Workspace::new(), cell)
}

/// Run one RGG cell end to end with caller-provided scratch (one-shot
/// context).
pub fn run_cell_with(ws: &mut Workspace, cell: &Cell) -> Row {
    let (platform, inst) = build_instance(cell);
    let ctx = PlatformCtx::new(platform);
    run_cell_parts(ws, cell, &ctx, &inst)
}

/// Run one RGG cell through an interned sweep context: same-platform
/// cells share one set of resident panels, and the caller supplies the
/// scratch (the sweep drivers reuse one pool of arenas across workers).
pub fn run_cell_ctx(ctxs: &SweepCtxCache, ws: &mut Workspace, cell: &Cell) -> Row {
    let (platform, inst) = build_instance(cell);
    let ctx = ctxs.get(platform);
    run_cell_parts(ws, cell, &ctx, &inst)
}

/// The shared tail of the RGG cell drivers.
fn run_cell_parts(ws: &mut Workspace, cell: &Cell, ctx: &PlatformCtx, inst: &Instance) -> Row {
    run_instance_with(
        ws,
        cell.workload.name(),
        cell.n,
        cell.out_degree,
        cell.ccr,
        cell.alpha,
        cell.beta_pct,
        cell.gamma,
        ctx,
        inst,
    )
}

/// Deterministically build one real-world cell's workload name, platform
/// and weighted instance — shared by the one-shot and sweep drivers.
fn realworld_parts(cell: &RealWorldCell) -> (String, Platform, Instance) {
    let seed = SplitMix64::seed_for(&[cell.family.id(), cell.index]);
    let skel = match cell.family {
        super::cells::RealWorld::Fft => realworld::fft(cell.size),
        super::cells::RealWorld::Ge => realworld::gaussian_elimination(cell.size),
        super::cells::RealWorld::Md => realworld::molecular_dynamics(),
        super::cells::RealWorld::Ew => realworld::epigenomics(cell.size),
    };
    let beta = cell.beta_pct / 100.0;
    let mut plat_rng = crate::util::rng::Xoshiro256::new(seed ^ PLATFORM_SEED_SALT);
    let (platform, model) = if cell.medium_variant {
        (
            Platform::two_weight(cell.p, beta, &mut plat_rng, 1.0, 0.0),
            CostModel::two_weight_medium(beta),
        )
    } else {
        (
            Platform::uniform(cell.p, 1.0, 0.0),
            CostModel::Classic { beta },
        )
    };
    let inst =
        realworld::weighted_instance(&skel, cell.ccr, cell.beta_pct, &model, &platform, seed);
    let variant = if cell.medium_variant { "medium" } else { "classic" };
    (
        format!("{}-{}", cell.family.name(), variant),
        platform,
        inst,
    )
}

/// Run one real-world cell end to end (one-shot workspace and context).
pub fn run_realworld_cell(cell: &RealWorldCell) -> Row {
    run_realworld_cell_with(&mut Workspace::new(), cell)
}

/// Run one real-world cell end to end with caller-provided scratch
/// (one-shot context).
pub fn run_realworld_cell_with(ws: &mut Workspace, cell: &RealWorldCell) -> Row {
    let (workload, platform, inst) = realworld_parts(cell);
    let ctx = PlatformCtx::new(platform);
    run_realworld_tail(ws, cell, &workload, &ctx, &inst)
}

/// Run one real-world cell through an interned sweep context (scratch
/// supplied by the caller, as in [`run_cell_ctx`]).
pub fn run_realworld_cell_ctx(
    ctxs: &SweepCtxCache,
    ws: &mut Workspace,
    cell: &RealWorldCell,
) -> Row {
    let (workload, platform, inst) = realworld_parts(cell);
    let ctx = ctxs.get(platform);
    run_realworld_tail(ws, cell, &workload, &ctx, &inst)
}

/// The shared tail of the real-world cell drivers.
fn run_realworld_tail(
    ws: &mut Workspace,
    cell: &RealWorldCell,
    workload: &str,
    ctx: &PlatformCtx,
    inst: &Instance,
) -> Row {
    run_instance_with(
        ws,
        workload,
        inst.graph.num_tasks(),
        0,
        cell.ccr,
        0.0,
        cell.beta_pct,
        0.0,
        ctx,
        inst,
    )
}

/// Run a sweep of RGG cells in parallel with optional progress output.
/// Workers intern one [`PlatformCtx`] per distinct platform
/// ([`SweepCtxCache`], bounded) so shared platforms compute their
/// communication panels once per run, and draw long-lived workspaces from
/// one shared pool, so the sweep allocates `threads` scratch arenas total
/// instead of one set per cell.
pub fn run_sweep(cells: &[Cell], threads: usize, verbose: bool) -> Vec<Row> {
    let done = std::sync::atomic::AtomicUsize::new(0);
    let ctxs = SweepCtxCache::new();
    let workspaces = WorkspacePool::bounded(threads.max(1));
    pool::parallel_map(cells, threads, |_, cell| {
        let row = workspaces.with(|ws| run_cell_ctx(&ctxs, ws, cell));
        let d = done.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
        if verbose && (d % 100 == 0 || d == cells.len()) {
            eprintln!("  [{d}/{}] cells done", cells.len());
        }
        row
    })
}

/// Run a sweep of real-world cells in parallel (interned contexts +
/// pooled workspaces, as in [`run_sweep`]).
pub fn run_realworld_sweep(cells: &[RealWorldCell], threads: usize, verbose: bool) -> Vec<Row> {
    let done = std::sync::atomic::AtomicUsize::new(0);
    let ctxs = SweepCtxCache::new();
    let workspaces = WorkspacePool::bounded(threads.max(1));
    pool::parallel_map(cells, threads, |_, cell| {
        let row = workspaces.with(|ws| run_realworld_cell_ctx(&ctxs, ws, cell));
        let d = done.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
        if verbose && (d % 100 == 0 || d == cells.len()) {
            eprintln!("  [{d}/{}] real-world cells done", cells.len());
        }
        row
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::cells::{grid, realworld_grid, RealWorld, Scale, Workload};

    #[test]
    fn run_cell_produces_consistent_metrics() {
        let cells = grid(Workload::RggClassic, Scale::Smoke);
        let row = run_cell(&cells[0]);
        assert!(row.cpl_ceft > 0.0);
        assert!(row.cp_min > 0.0);
        assert!(row.cp_min <= row.cpl_ceft + 1e-9);
        for a in &row.algos {
            assert!(a.makespan > 0.0);
            assert!(a.slr >= 1.0 - 1e-9, "slr={}", a.slr);
            assert!(a.speedup > 0.0);
            // makespan >= CP_MIN (hard lower bound)
            assert!(a.makespan + 1e-9 >= row.cp_min);
        }
    }

    #[test]
    fn rerun_is_deterministic() {
        let cells = grid(Workload::RggHigh, Scale::Smoke);
        let a = run_cell(&cells[0]);
        let b = run_cell(&cells[0]);
        assert_eq!(a.cpl_ceft, b.cpl_ceft);
        assert_eq!(a.algos[0].makespan, b.algos[0].makespan);
        assert_eq!(a.algos[2].slr, b.algos[2].slr);
    }

    #[test]
    fn reused_workspace_matches_fresh_rows() {
        // one workspace threaded through two different cells must produce
        // the same rows as fresh one-shot workspaces
        let cells = grid(Workload::RggHigh, Scale::Smoke);
        let mut ws = Workspace::new();
        let a1 = run_cell_with(&mut ws, &cells[0]);
        let b1 = run_cell_with(&mut ws, &cells[1 % cells.len()]);
        let a2 = run_cell(&cells[0]);
        let b2 = run_cell(&cells[1 % cells.len()]);
        assert_eq!(a1.cpl_ceft, a2.cpl_ceft);
        assert_eq!(b1.cpl_ceft, b2.cpl_ceft);
        assert_eq!(a1.cpl_cpop, a2.cpl_cpop);
        for i in 0..6 {
            assert_eq!(a1.algos[i].makespan, a2.algos[i].makespan);
            assert_eq!(b1.algos[i].makespan, b2.algos[i].makespan);
        }
    }

    #[test]
    fn sweep_parallel_equals_serial() {
        let cells: Vec<_> = grid(Workload::RggClassic, Scale::Smoke)
            .into_iter()
            .take(4)
            .collect();
        let par = run_sweep(&cells, 4, false);
        let ser = run_sweep(&cells, 1, false);
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.cpl_ceft, b.cpl_ceft);
            assert_eq!(a.algos[2].makespan, b.algos[2].makespan);
        }
    }

    #[test]
    fn sweep_ctx_cache_interns_once_per_platform() {
        let ctxs = SweepCtxCache::new();
        let a = ctxs.get(Platform::uniform(4, 1.0, 0.0));
        let b = ctxs.get(Platform::uniform(4, 1.0, 0.0));
        assert!(Arc::ptr_eq(&a, &b), "identical platforms share one ctx");
        assert_eq!(ctxs.len(), 1);
        let c = ctxs.get(Platform::uniform(4, 2.0, 0.0));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(ctxs.len(), 2);
    }

    #[test]
    fn sweep_ctx_cache_caps_interned_platforms() {
        // per-cell-platform workloads must not grow the intern table (and
        // its retained panels) without bound: past the cap, every fresh
        // platform gets a correct unshared ctx while interned platforms
        // keep sharing
        let ctxs = SweepCtxCache::new();
        let first = ctxs.get(Platform::uniform(2, 1.0, 0.0));
        for i in 0..(2 * MAX_INTERNED_PLATFORMS) {
            ctxs.get(Platform::uniform(2, 2.0 + i as f64, 0.0));
        }
        assert_eq!(ctxs.len(), MAX_INTERNED_PLATFORMS, "intern table is capped");
        // over-cap platforms still serve correct contexts
        let over = ctxs.get(Platform::uniform(2, 1e6, 0.0));
        assert_eq!(over.p(), 2);
        assert_eq!(over.panel_bw()[1], 1e6);
        // interned platforms still share
        let again = ctxs.get(Platform::uniform(2, 1.0, 0.0));
        assert!(Arc::ptr_eq(&first, &again));
    }

    #[test]
    fn ctx_driven_cell_matches_one_shot_cell() {
        // the interned-context sweep path must be bit-identical to the
        // one-shot path (ctx sharing changes where panels live, not what
        // they hold)
        let cells = grid(Workload::RggClassic, Scale::Smoke);
        let ctxs = SweepCtxCache::new();
        let mut ws = Workspace::new();
        for cell in cells.iter().take(3) {
            let via_ctx = run_cell_ctx(&ctxs, &mut ws, cell);
            let one_shot = run_cell(cell);
            assert_eq!(via_ctx.cpl_ceft, one_shot.cpl_ceft);
            for i in 0..6 {
                assert_eq!(via_ctx.algos[i].makespan, one_shot.algos[i].makespan);
            }
        }
        // the classic workload's uniform platform is shared across cells
        assert_eq!(ctxs.len(), 1, "uniform-platform cells share one ctx");
    }

    #[test]
    fn algos_column_order_matches_registry() {
        for (name, a) in ALGOS.iter().zip(Algorithm::ALL.iter()) {
            assert_eq!(*name, a.name());
        }
    }

    #[test]
    fn realworld_cells_run() {
        for family in RealWorld::ALL {
            let cells = realworld_grid(family, Scale::Smoke);
            let row = run_realworld_cell(&cells[0]);
            assert!(row.cpl_ceft > 0.0, "{}", family.name());
            assert!(row.algos.iter().all(|a| a.makespan > 0.0));
        }
    }
}
