//! Running experiment cells: instance generation, algorithm execution,
//! metric collection.

use super::cells::{Cell, RealWorldCell};
use crate::cp::ceft::find_critical_path_with;
use crate::cp::cpmin::cp_min_cost_with;
use crate::cp::minexec::min_exec_critical_path_with;
use crate::cp::ranks::{cpop_cp_from_priorities, cpop_priorities_into};
use crate::cp::workspace::{Workspace, WorkspacePool};
use crate::graph::generator::{generate, Instance, RggParams};
use crate::graph::realworld;
use crate::metrics;
use crate::platform::{CostModel, Platform};
use crate::sched::Algorithm;
use crate::util::pool;
use crate::util::rng::SplitMix64;

/// Salt XORed into cell seeds to derive the independent platform RNG stream.
const PLATFORM_SEED_SALT: u64 = 0x504C_4154_504C_4154; // "PLATPLAT"

/// The schedulers every cell runs, in result-column order — derived from
/// the unified [`Algorithm`] registry so the batch harness, the CLI, and
/// the online service all agree on names and ordering.
pub const ALGOS: [&str; 6] = [
    Algorithm::Cpop.name(),
    Algorithm::Heft.name(),
    Algorithm::CeftCpop.name(),
    Algorithm::HeftDown.name(),
    Algorithm::CeftHeftUp.name(),
    Algorithm::CeftHeftDown.name(),
];

/// Per-algorithm metrics for one cell.
#[derive(Clone, Copy, Debug, Default)]
pub struct AlgoResult {
    /// makespan of the produced schedule
    pub makespan: f64,
    /// eq. 8 speedup
    pub speedup: f64,
    /// eq. 9 schedule length ratio
    pub slr: f64,
    /// eq. 10 slack
    pub slack: f64,
}

/// Full record of one experiment.
#[derive(Clone, Debug)]
pub struct Row {
    /// workload family name (or real-world family)
    pub workload: String,
    /// grid coordinates
    pub n: usize,
    /// average out-degree (0 for real-world graphs)
    pub out_degree: usize,
    /// CCR
    pub ccr: f64,
    /// α (0 for real-world graphs — their structure is fixed, §7.2)
    pub alpha: f64,
    /// β percent
    pub beta_pct: f64,
    /// γ (0 for real-world)
    pub gamma: f64,
    /// processors
    pub p: usize,
    /// CEFT critical-path length (with partial assignment)
    pub cpl_ceft: f64,
    /// CPOP mean-value critical-path length estimate (|CP|)
    pub cpl_cpop: f64,
    /// CPOP's path re-costed on its single chosen processor
    pub cpl_cpop_realized: f64,
    /// min-execution-time CP (zero comm), the §3 baseline
    pub cpl_minexec: f64,
    /// CP_MIN (SLR denominator)
    pub cp_min: f64,
    /// per-algorithm results, aligned with [`ALGOS`]
    pub algos: [AlgoResult; 6],
}

impl Row {
    /// Result for a named algorithm.
    pub fn algo(&self, name: &str) -> &AlgoResult {
        let i = ALGOS.iter().position(|&a| a == name).expect("unknown algo");
        &self.algos[i]
    }
}

/// Build the platform + instance for an RGG cell (deterministic in the cell).
pub fn build_instance(cell: &Cell) -> (Platform, Instance) {
    let seed = SplitMix64::seed_for(&[cell.workload.id(), cell.index]);
    let mut plat_rng = crate::util::rng::Xoshiro256::new(seed ^ PLATFORM_SEED_SALT);
    let platform = if cell.workload.needs_two_weight_platform() {
        Platform::two_weight(cell.p, cell.beta_pct / 100.0, &mut plat_rng, 1.0, 0.0)
    } else {
        Platform::uniform(cell.p, 1.0, 0.0)
    };
    let params = RggParams {
        n: cell.n,
        out_degree: cell.out_degree,
        ccr: cell.ccr,
        alpha: cell.alpha,
        beta_pct: cell.beta_pct,
        gamma: cell.gamma,
    };
    let model = cell.workload.cost_model(cell.beta_pct);
    let inst = generate(&params, &model, &platform, seed);
    (platform, inst)
}

/// Run every algorithm and metric on one instance (one-shot workspace).
#[allow(clippy::too_many_arguments)]
pub fn run_instance(
    workload: &str,
    n: usize,
    out_degree: usize,
    ccr: f64,
    alpha: f64,
    beta_pct: f64,
    gamma: f64,
    platform: &Platform,
    inst: &Instance,
) -> Row {
    run_instance_with(
        &mut Workspace::new(),
        workload,
        n,
        out_degree,
        ccr,
        alpha,
        beta_pct,
        gamma,
        platform,
        inst,
    )
}

/// Run every algorithm and metric on one instance, borrowing `ws` for all
/// transient state — the sweep drivers below hand each worker a pooled
/// workspace so a 10k-cell grid does not re-allocate DP tables per cell.
#[allow(clippy::too_many_arguments)]
pub fn run_instance_with(
    ws: &mut Workspace,
    workload: &str,
    n: usize,
    out_degree: usize,
    ccr: f64,
    alpha: f64,
    beta_pct: f64,
    gamma: f64,
    platform: &Platform,
    inst: &Instance,
) -> Row {
    let iref = inst.bind(platform);
    let p = platform.num_classes();

    let ceft_cp = find_critical_path_with(ws, iref);
    // CPOP's mean-value CP from ranks computed in workspace buffers
    cpop_priorities_into(ws, iref);
    let cpl_cpop = cpop_cp_from_priorities(iref.graph, &ws.prio, &mut ws.cp_tasks);
    let cpl_cpop_realized = crate::cp::ranks::cpop_realized_cp_length(&ws.cp_tasks, iref.costs);
    let minexec = min_exec_critical_path_with(ws, iref, false);
    let cp_min = cp_min_cost_with(ws, iref);

    let mut algos = [AlgoResult::default(); 6];
    for (i, a) in Algorithm::ALL.iter().enumerate() {
        let schedule = a.run_with(ws, iref);
        debug_assert!(schedule.validate(iref).is_ok());
        let m = schedule.makespan();
        algos[i] = AlgoResult {
            makespan: m,
            speedup: metrics::speedup(iref.costs, m),
            slr: metrics::slr(iref, m),
            slack: metrics::slack(iref, &schedule),
        };
    }

    Row {
        workload: workload.to_string(),
        n,
        out_degree,
        ccr,
        alpha,
        beta_pct,
        gamma,
        p,
        cpl_ceft: ceft_cp.length,
        cpl_cpop,
        cpl_cpop_realized,
        cpl_minexec: minexec.length,
        cp_min,
        algos,
    }
}

/// Run one RGG cell end to end (one-shot workspace).
pub fn run_cell(cell: &Cell) -> Row {
    run_cell_with(&mut Workspace::new(), cell)
}

/// Run one RGG cell end to end with caller-provided scratch.
pub fn run_cell_with(ws: &mut Workspace, cell: &Cell) -> Row {
    let (platform, inst) = build_instance(cell);
    run_instance_with(
        ws,
        cell.workload.name(),
        cell.n,
        cell.out_degree,
        cell.ccr,
        cell.alpha,
        cell.beta_pct,
        cell.gamma,
        &platform,
        &inst,
    )
}

/// Run one real-world cell end to end (one-shot workspace).
pub fn run_realworld_cell(cell: &RealWorldCell) -> Row {
    run_realworld_cell_with(&mut Workspace::new(), cell)
}

/// Run one real-world cell end to end with caller-provided scratch.
pub fn run_realworld_cell_with(ws: &mut Workspace, cell: &RealWorldCell) -> Row {
    let seed = SplitMix64::seed_for(&[cell.family.id(), cell.index]);
    let skel = match cell.family {
        super::cells::RealWorld::Fft => realworld::fft(cell.size),
        super::cells::RealWorld::Ge => realworld::gaussian_elimination(cell.size),
        super::cells::RealWorld::Md => realworld::molecular_dynamics(),
        super::cells::RealWorld::Ew => realworld::epigenomics(cell.size),
    };
    let beta = cell.beta_pct / 100.0;
    let mut plat_rng = crate::util::rng::Xoshiro256::new(seed ^ PLATFORM_SEED_SALT);
    let (platform, model) = if cell.medium_variant {
        (
            Platform::two_weight(cell.p, beta, &mut plat_rng, 1.0, 0.0),
            CostModel::two_weight_medium(beta),
        )
    } else {
        (
            Platform::uniform(cell.p, 1.0, 0.0),
            CostModel::Classic { beta },
        )
    };
    let inst =
        realworld::weighted_instance(&skel, cell.ccr, cell.beta_pct, &model, &platform, seed);
    let variant = if cell.medium_variant { "medium" } else { "classic" };
    run_instance_with(
        ws,
        &format!("{}-{}", cell.family.name(), variant),
        inst.graph.num_tasks(),
        0,
        cell.ccr,
        0.0,
        cell.beta_pct,
        0.0,
        &platform,
        &inst,
    )
}

/// Run a sweep of RGG cells in parallel with optional progress output.
/// Workers draw long-lived workspaces from a shared pool, so the sweep
/// allocates `threads` scratch arenas total instead of one set per cell.
pub fn run_sweep(cells: &[Cell], threads: usize, verbose: bool) -> Vec<Row> {
    let done = std::sync::atomic::AtomicUsize::new(0);
    let workspaces = WorkspacePool::bounded(threads.max(1));
    pool::parallel_map(cells, threads, |_, cell| {
        let row = workspaces.with(|ws| run_cell_with(ws, cell));
        let d = done.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
        if verbose && (d % 100 == 0 || d == cells.len()) {
            eprintln!("  [{d}/{}] cells done", cells.len());
        }
        row
    })
}

/// Run a sweep of real-world cells in parallel (pooled workspaces, as in
/// [`run_sweep`]).
pub fn run_realworld_sweep(cells: &[RealWorldCell], threads: usize, verbose: bool) -> Vec<Row> {
    let done = std::sync::atomic::AtomicUsize::new(0);
    let workspaces = WorkspacePool::bounded(threads.max(1));
    pool::parallel_map(cells, threads, |_, cell| {
        let row = workspaces.with(|ws| run_realworld_cell_with(ws, cell));
        let d = done.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
        if verbose && (d % 100 == 0 || d == cells.len()) {
            eprintln!("  [{d}/{}] real-world cells done", cells.len());
        }
        row
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::cells::{grid, realworld_grid, RealWorld, Scale, Workload};

    #[test]
    fn run_cell_produces_consistent_metrics() {
        let cells = grid(Workload::RggClassic, Scale::Smoke);
        let row = run_cell(&cells[0]);
        assert!(row.cpl_ceft > 0.0);
        assert!(row.cp_min > 0.0);
        assert!(row.cp_min <= row.cpl_ceft + 1e-9);
        for a in &row.algos {
            assert!(a.makespan > 0.0);
            assert!(a.slr >= 1.0 - 1e-9, "slr={}", a.slr);
            assert!(a.speedup > 0.0);
            // makespan >= CP_MIN (hard lower bound)
            assert!(a.makespan + 1e-9 >= row.cp_min);
        }
    }

    #[test]
    fn rerun_is_deterministic() {
        let cells = grid(Workload::RggHigh, Scale::Smoke);
        let a = run_cell(&cells[0]);
        let b = run_cell(&cells[0]);
        assert_eq!(a.cpl_ceft, b.cpl_ceft);
        assert_eq!(a.algos[0].makespan, b.algos[0].makespan);
        assert_eq!(a.algos[2].slr, b.algos[2].slr);
    }

    #[test]
    fn reused_workspace_matches_fresh_rows() {
        // one workspace threaded through two different cells must produce
        // the same rows as fresh one-shot workspaces
        let cells = grid(Workload::RggHigh, Scale::Smoke);
        let mut ws = Workspace::new();
        let a1 = run_cell_with(&mut ws, &cells[0]);
        let b1 = run_cell_with(&mut ws, &cells[1 % cells.len()]);
        let a2 = run_cell(&cells[0]);
        let b2 = run_cell(&cells[1 % cells.len()]);
        assert_eq!(a1.cpl_ceft, a2.cpl_ceft);
        assert_eq!(b1.cpl_ceft, b2.cpl_ceft);
        assert_eq!(a1.cpl_cpop, a2.cpl_cpop);
        for i in 0..6 {
            assert_eq!(a1.algos[i].makespan, a2.algos[i].makespan);
            assert_eq!(b1.algos[i].makespan, b2.algos[i].makespan);
        }
    }

    #[test]
    fn sweep_parallel_equals_serial() {
        let cells: Vec<_> = grid(Workload::RggClassic, Scale::Smoke)
            .into_iter()
            .take(4)
            .collect();
        let par = run_sweep(&cells, 4, false);
        let ser = run_sweep(&cells, 1, false);
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.cpl_ceft, b.cpl_ceft);
            assert_eq!(a.algos[2].makespan, b.algos[2].makespan);
        }
    }

    #[test]
    fn algos_column_order_matches_registry() {
        for (name, a) in ALGOS.iter().zip(Algorithm::ALL.iter()) {
            assert_eq!(*name, a.name());
        }
    }

    #[test]
    fn realworld_cells_run() {
        for family in RealWorld::ALL {
            let cells = realworld_grid(family, Scale::Smoke);
            let row = run_realworld_cell(&cells[0]);
            assert!(row.cpl_ceft > 0.0, "{}", family.name());
            assert!(row.algos.iter().all(|a| a.makespan > 0.0));
        }
    }
}
