//! Table/figure emitters: turn experiment [`Row`]s into the exact tables
//! and data series of the paper's evaluation section.
//!
//! Each `figN` function returns a [`Table`] whose rows are the data points
//! of the corresponding paper figure (the figure's x-axis as the first
//! column, one column per plotted series). `table3` reproduces Table 3
//! (plus Figures 5 and 6, which are the same data drawn as bars).

use super::run::{Row, ALGOS};
use crate::metrics::{compare, Cmp, WinTally};
use crate::util::csv::Table;

/// Relative tolerance for classifying two lengths as "equal".
pub const EQUAL_EPS: f64 = 1e-6;

fn fmt(v: f64) -> String {
    if v.abs() >= 1000.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.4}")
    }
}

/// Table 3 (and Figures 5–6): per workload, the percentage of experiments
/// where CEFT's CPL / CEFT-CPOP's makespan is longer / equal / shorter than
/// CPOP's.
pub fn table3(rows: &[Row]) -> Table {
    let mut table = Table::new(vec![
        "workload",
        "experiments",
        "outcome",
        "CPL(%)",
        "makespan(%)",
    ]);
    let mut workloads: Vec<String> = Vec::new();
    for r in rows {
        if !workloads.contains(&r.workload) {
            workloads.push(r.workload.clone());
        }
    }
    for wl in &workloads {
        let mut cpl = WinTally::default();
        let mut mk = WinTally::default();
        let mut count = 0u64;
        for r in rows.iter().filter(|r| &r.workload == wl) {
            cpl.push(compare(r.cpl_ceft, r.cpl_cpop_realized, EQUAL_EPS));
            mk.push(compare(
                r.algo("CEFT-CPOP").makespan,
                r.algo("CPOP").makespan,
                EQUAL_EPS,
            ));
            count += 1;
        }
        let (cl, ce, cs) = cpl.percentages();
        let (ml, me, ms) = mk.percentages();
        for (outcome, c, m) in [
            ("Longer", cl, ml),
            ("Equal", ce, me),
            ("Shorter", cs, ms),
        ] {
            table.push_row(vec![
                wl.clone(),
                count.to_string(),
                outcome.to_string(),
                format!("{c:.2}"),
                format!("{m:.2}"),
            ]);
        }
    }
    table
}

/// Group rows by a key, average a metric per group, one series per
/// algorithm. `key` maps a row to an x-axis value (rendered `{:.3}` trimmed).
fn series_by<K: Fn(&Row) -> f64, M: Fn(&Row, &str) -> f64>(
    rows: &[Row],
    x_name: &str,
    key: K,
    metric: M,
    algos: &[&str],
) -> Table {
    let mut header = vec![x_name.to_string()];
    header.extend(algos.iter().map(|a| a.to_string()));
    let mut table = Table::new(header);
    let mut xs: Vec<f64> = rows.iter().map(&key).collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
    for x in xs {
        let group: Vec<&Row> = rows
            .iter()
            .filter(|r| (key(r) - x).abs() < 1e-12)
            .collect();
        let mut cells = vec![trim_float(x)];
        for &a in algos {
            let mean =
                group.iter().map(|r| metric(r, a)).sum::<f64>() / group.len() as f64;
            cells.push(fmt(mean));
        }
        table.push_row(cells);
    }
    table
}

fn trim_float(x: f64) -> String {
    if x.fract() == 0.0 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// The three paper headliner algorithms.
const MAIN3: [&str; 3] = ["CEFT-CPOP", "CPOP", "HEFT"];
/// The §8.2 ranking-variant comparison set (Figures 19–20).
const RANKS6: [&str; 6] = [
    "CEFT-CPOP",
    "CPOP",
    "HEFT",
    "HEFT-DOWN",
    "CEFT-HEFT-UP",
    "CEFT-HEFT-DOWN",
];

/// Figure 7: CPL ratio (CEFT / CPOP) vs α — the per-α mean ratio plus the
/// spread (p10/p90), standing in for the paper's jittered scatter "bars".
pub fn fig7(rows: &[Row]) -> Table {
    let mut table = Table::new(vec!["alpha", "mean_ratio", "p10", "p90"]);
    let mut alphas: Vec<f64> = rows.iter().map(|r| r.alpha).collect();
    alphas.sort_by(|a, b| a.partial_cmp(b).unwrap());
    alphas.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
    for a in alphas {
        let ratios: Vec<f64> = rows
            .iter()
            .filter(|r| (r.alpha - a).abs() < 1e-12)
            .map(|r| r.cpl_ceft / r.cpl_cpop_realized)
            .collect();
        table.push_row(vec![
            trim_float(a),
            fmt(crate::util::stats::mean(&ratios)),
            fmt(crate::util::stats::percentile(&ratios, 10.0)),
            fmt(crate::util::stats::percentile(&ratios, 90.0)),
        ]);
    }
    table
}

/// Figure 8: mean CPL vs β (CEFT vs CPOP estimates).
pub fn fig8(rows: &[Row]) -> Table {
    let mut table = Table::new(vec!["beta", "CEFT_CPL", "CPOP_CPL"]);
    let mut betas: Vec<f64> = rows.iter().map(|r| r.beta_pct).collect();
    betas.sort_by(|a, b| a.partial_cmp(b).unwrap());
    betas.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
    for b in betas {
        let group: Vec<&Row> = rows
            .iter()
            .filter(|r| (r.beta_pct - b).abs() < 1e-12)
            .collect();
        let ceft = group.iter().map(|r| r.cpl_ceft).sum::<f64>() / group.len() as f64;
        let cpop = group.iter().map(|r| r.cpl_cpop_realized).sum::<f64>() / group.len() as f64;
        table.push_row(vec![trim_float(b), fmt(ceft), fmt(cpop)]);
    }
    table
}

/// Figure 9: speedup vs number of tasks.
pub fn fig9(rows: &[Row]) -> Table {
    series_by(rows, "n", |r| r.n as f64, |r, a| r.algo(a).speedup, &MAIN3)
}

/// Figure 10: speedup vs number of processors.
pub fn fig10(rows: &[Row]) -> Table {
    series_by(rows, "p", |r| r.p as f64, |r, a| r.algo(a).speedup, &MAIN3)
}

/// Figure 11: SLR vs β.
pub fn fig11(rows: &[Row]) -> Table {
    series_by(rows, "beta", |r| r.beta_pct, |r, a| r.algo(a).slr, &MAIN3)
}

/// Figure 12: speedup vs β.
pub fn fig12(rows: &[Row]) -> Table {
    series_by(rows, "beta", |r| r.beta_pct, |r, a| r.algo(a).speedup, &MAIN3)
}

/// Figure 13a: SLR vs α.
pub fn fig13a(rows: &[Row]) -> Table {
    series_by(rows, "alpha", |r| r.alpha, |r, a| r.algo(a).slr, &MAIN3)
}

/// Figure 13b: SLR vs CCR.
pub fn fig13b(rows: &[Row]) -> Table {
    series_by(rows, "ccr", |r| r.ccr, |r, a| r.algo(a).slr, &MAIN3)
}

/// Figure 13c: slack vs CCR.
pub fn fig13c(rows: &[Row]) -> Table {
    series_by(rows, "ccr", |r| r.ccr, |r, a| r.algo(a).slack, &MAIN3)
}

/// Figure 14a: SLR vs number of tasks.
pub fn fig14a(rows: &[Row]) -> Table {
    series_by(rows, "n", |r| r.n as f64, |r, a| r.algo(a).slr, &MAIN3)
}

/// Figure 14b: SLR vs number of processors.
pub fn fig14b(rows: &[Row]) -> Table {
    series_by(rows, "p", |r| r.p as f64, |r, a| r.algo(a).slr, &MAIN3)
}

/// Figures 15/17 (real-world SLR vs CCR) — pass rows filtered to the
/// benchmark variant.
pub fn fig_realworld_slr(rows: &[Row]) -> Table {
    series_by(rows, "ccr", |r| r.ccr, |r, a| r.algo(a).slr, &MAIN3)
}

/// Figures 16/18 (real-world speedup vs CCR).
pub fn fig_realworld_speedup(rows: &[Row]) -> Table {
    series_by(rows, "ccr", |r| r.ccr, |r, a| r.algo(a).speedup, &MAIN3)
}

/// Figure 19: speedup vs α for the ranking-function variants.
pub fn fig19(rows: &[Row]) -> Table {
    series_by(rows, "alpha", |r| r.alpha, |r, a| r.algo(a).speedup, &RANKS6)
}

/// Figure 20: SLR vs α for the ranking-function variants.
pub fn fig20(rows: &[Row]) -> Table {
    series_by(rows, "alpha", |r| r.alpha, |r, a| r.algo(a).slr, &RANKS6)
}

/// Dump raw rows as a CSV table (one row per experiment, all metrics).
pub fn raw_rows(rows: &[Row]) -> Table {
    let mut header = vec![
        "workload".to_string(),
        "n".to_string(),
        "out_degree".to_string(),
        "ccr".to_string(),
        "alpha".to_string(),
        "beta".to_string(),
        "gamma".to_string(),
        "p".to_string(),
        "cpl_ceft".to_string(),
        "cpl_cpop".to_string(),
        "cpl_cpop_realized".to_string(),
        "cpl_minexec".to_string(),
        "cp_min".to_string(),
    ];
    for a in ALGOS {
        for m in ["makespan", "speedup", "slr", "slack"] {
            header.push(format!("{a}:{m}"));
        }
    }
    let mut table = Table::new(header);
    for r in rows {
        let mut cells = vec![
            r.workload.clone(),
            r.n.to_string(),
            r.out_degree.to_string(),
            format!("{}", r.ccr),
            format!("{}", r.alpha),
            format!("{}", r.beta_pct),
            format!("{}", r.gamma),
            r.p.to_string(),
            format!("{}", r.cpl_ceft),
            format!("{}", r.cpl_cpop),
            format!("{}", r.cpl_cpop_realized),
            format!("{}", r.cpl_minexec),
            format!("{}", r.cp_min),
        ];
        for a in &r.algos {
            cells.push(format!("{}", a.makespan));
            cells.push(format!("{}", a.speedup));
            cells.push(format!("{}", a.slr));
            cells.push(format!("{}", a.slack));
        }
        table.push_row(cells);
    }
    table
}

/// Table-3 outcome percentages broken down by a grid dimension (diagnostic
/// view: where in the sweep does CEFT win/lose?).
pub fn table3_breakdown<K: Fn(&Row) -> f64>(rows: &[Row], dim: &str, key: K) -> Table {
    let mut table = Table::new(vec![
        dim.to_string(),
        "cpl_longer%".to_string(),
        "cpl_shorter%".to_string(),
        "mk_longer%".to_string(),
        "mk_shorter%".to_string(),
        "n_exp".to_string(),
    ]);
    let mut xs: Vec<f64> = rows.iter().map(&key).collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
    for x in xs {
        let mut cpl = WinTally::default();
        let mut mk = WinTally::default();
        for r in rows.iter().filter(|r| (key(r) - x).abs() < 1e-12) {
            cpl.push(compare(r.cpl_ceft, r.cpl_cpop_realized, EQUAL_EPS));
            mk.push(compare(
                r.algo("CEFT-CPOP").makespan,
                r.algo("CPOP").makespan,
                EQUAL_EPS,
            ));
        }
        let (cl, _, cs) = cpl.percentages();
        let (ml, _, ms) = mk.percentages();
        table.push_row(vec![
            trim_float(x),
            format!("{cl:.1}"),
            format!("{cs:.1}"),
            format!("{ml:.1}"),
            format!("{ms:.1}"),
            cpl.total().to_string(),
        ]);
    }
    table
}

/// Win/tie/loss classification for one row's CPL comparison (exposed for
/// tests and the CLI summary).
pub fn cpl_outcome(r: &Row) -> Cmp {
    compare(r.cpl_ceft, r.cpl_cpop_realized, EQUAL_EPS)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::cells::{grid, Scale, Workload};
    use crate::exp::run::run_sweep;

    fn smoke_rows() -> Vec<Row> {
        let cells = grid(Workload::RggClassic, Scale::Smoke);
        run_sweep(&cells, 2, false)
    }

    #[test]
    fn table3_has_three_outcomes_per_workload() {
        let rows = smoke_rows();
        let t = table3(&rows);
        assert_eq!(t.rows.len(), 3);
        // percentages sum to ~100
        let sum: f64 = t
            .rows
            .iter()
            .map(|r| r[3].parse::<f64>().unwrap())
            .sum();
        assert!((sum - 100.0).abs() < 0.1, "cpl% sum={sum}");
    }

    #[test]
    fn figures_have_expected_columns() {
        let rows = smoke_rows();
        assert_eq!(fig10(&rows).header[0], "p");
        assert_eq!(fig11(&rows).header.len(), 4);
        assert_eq!(fig19(&rows).header.len(), 7);
        assert!(!fig7(&rows).rows.is_empty());
        assert!(!fig8(&rows).rows.is_empty());
    }

    #[test]
    fn raw_rows_roundtrip_via_csv() {
        let rows = smoke_rows();
        let t = raw_rows(&rows);
        let parsed = crate::util::csv::Table::from_csv(&t.to_csv()).unwrap();
        assert_eq!(parsed.rows.len(), rows.len());
        assert_eq!(parsed.header.len(), 13 + 6 * 4);
    }

    #[test]
    fn series_means_are_finite() {
        let rows = smoke_rows();
        for t in [fig9(&rows), fig10(&rows), fig12(&rows), fig13b(&rows)] {
            for row in &t.rows {
                for cell in &row[1..] {
                    let v: f64 = cell.parse().unwrap();
                    assert!(v.is_finite());
                }
            }
        }
    }
}
