//! Experiment grids (§7.1–§7.2 of the paper).
//!
//! The paper's full factorial: `n ∈ {128..16384}`, out-degree `{2,4,8}`,
//! CCR `{0.001..10}`, α `{0.1..1.0}`, β `{10..95}`, γ `{0.1..0.95}`,
//! processor graphs `p ∈ {2..64}` — 86,400 experiments per workload family,
//! 345,600 total. [`Scale`] selects the full grid or two reduced grids that
//! preserve every swept dimension (see DESIGN.md §6 for the substitution
//! argument).

use crate::platform::CostModel;

/// Workload family (§7.1): how execution-cost heterogeneity is generated.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Workload {
    /// eq. 5, the Topcuoglu-style β-band heterogeneity
    RggClassic,
    /// eq. 6 with I₂ = [1e3, 1e4]
    RggLow,
    /// eq. 6 with I₂ = [1e4, 1e5]
    RggMedium,
    /// eq. 6 with I₂ = [1e5, 1e6]
    RggHigh,
}

impl Workload {
    /// All four families, Table 3 order.
    pub const ALL: [Workload; 4] = [
        Workload::RggClassic,
        Workload::RggLow,
        Workload::RggMedium,
        Workload::RggHigh,
    ];

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Workload::RggClassic => "RGG-classic",
            Workload::RggLow => "RGG-low",
            Workload::RggMedium => "RGG-medium",
            Workload::RggHigh => "RGG-high",
        }
    }

    /// Stable id used in seed derivation.
    pub fn id(&self) -> u64 {
        match self {
            Workload::RggClassic => 0,
            Workload::RggLow => 1,
            Workload::RggMedium => 2,
            Workload::RggHigh => 3,
        }
    }

    /// The cost model for a given β percentage.
    pub fn cost_model(&self, beta_pct: f64) -> CostModel {
        let beta = beta_pct / 100.0;
        match self {
            Workload::RggClassic => CostModel::Classic { beta },
            Workload::RggLow => CostModel::two_weight_low(beta),
            Workload::RggMedium => CostModel::two_weight_medium(beta),
            Workload::RggHigh => CostModel::two_weight_high(beta),
        }
    }

    /// Whether the platform needs two-weight class capacities.
    pub fn needs_two_weight_platform(&self) -> bool {
        !matches!(self, Workload::RggClassic)
    }
}

/// Sweep scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// the paper's exact grid (86,400 cells per workload family)
    Full,
    /// every dimension swept, reduced cardinality (default; minutes)
    PaperSmall,
    /// tiny grid for CI and unit tests (seconds)
    Smoke,
}

impl Scale {
    /// Parse from CLI text.
    pub fn parse(s: &str) -> Result<Scale, String> {
        match s {
            "full" => Ok(Scale::Full),
            "paper-small" | "small" => Ok(Scale::PaperSmall),
            "smoke" => Ok(Scale::Smoke),
            other => Err(format!("unknown scale {other:?} (full|paper-small|smoke)")),
        }
    }

    fn ns(&self) -> Vec<usize> {
        match self {
            Scale::Full => vec![128, 256, 512, 1024, 2048, 4096, 8192, 16384],
            Scale::PaperSmall => vec![128, 512, 2048],
            Scale::Smoke => vec![64],
        }
    }

    fn out_degrees(&self) -> Vec<usize> {
        match self {
            Scale::Full => vec![2, 4, 8],
            Scale::PaperSmall => vec![4],
            Scale::Smoke => vec![3],
        }
    }

    fn ccrs(&self) -> Vec<f64> {
        match self {
            Scale::Full => vec![0.001, 0.01, 0.1, 1.0, 5.0, 10.0],
            Scale::PaperSmall => vec![0.01, 0.1, 1.0, 10.0],
            Scale::Smoke => vec![1.0],
        }
    }

    fn alphas(&self) -> Vec<f64> {
        match self {
            Scale::Full => vec![0.1, 0.25, 0.75, 1.0],
            Scale::PaperSmall => vec![0.1, 0.25, 0.75, 1.0],
            Scale::Smoke => vec![0.5],
        }
    }

    fn betas(&self) -> Vec<f64> {
        match self {
            Scale::Full => vec![10.0, 25.0, 50.0, 75.0, 95.0],
            Scale::PaperSmall => vec![10.0, 25.0, 50.0, 75.0, 95.0],
            Scale::Smoke => vec![50.0],
        }
    }

    fn gammas(&self) -> Vec<f64> {
        match self {
            Scale::Full => vec![0.1, 0.25, 0.5, 0.75, 0.95],
            Scale::PaperSmall => vec![0.25, 0.75],
            Scale::Smoke => vec![0.25],
        }
    }

    fn procs(&self) -> Vec<usize> {
        match self {
            Scale::Full => vec![2, 4, 8, 16, 32, 64],
            Scale::PaperSmall => vec![2, 4, 8, 32],
            Scale::Smoke => vec![4],
        }
    }
}

/// One experiment cell: an (application graph spec, processor graph) pair.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    /// workload family
    pub workload: Workload,
    /// number of tasks
    pub n: usize,
    /// average out-degree
    pub out_degree: usize,
    /// communication-to-computation ratio
    pub ccr: f64,
    /// shape α
    pub alpha: f64,
    /// heterogeneity β (percent)
    pub beta_pct: f64,
    /// skewness γ
    pub gamma: f64,
    /// number of processors (classes)
    pub p: usize,
    /// cell index within the grid (seed derivation)
    pub index: u64,
}

/// The RGG grid for one workload family at the given scale.
pub fn grid(workload: Workload, scale: Scale) -> Vec<Cell> {
    let mut cells = Vec::new();
    let mut index = 0u64;
    for &n in &scale.ns() {
        for &out_degree in &scale.out_degrees() {
            for &ccr in &scale.ccrs() {
                for &alpha in &scale.alphas() {
                    for &beta_pct in &scale.betas() {
                        for &gamma in &scale.gammas() {
                            for &p in &scale.procs() {
                                cells.push(Cell {
                                    workload,
                                    n,
                                    out_degree,
                                    ccr,
                                    alpha,
                                    beta_pct,
                                    gamma,
                                    p,
                                    index,
                                });
                                index += 1;
                            }
                        }
                    }
                }
            }
        }
    }
    cells
}

/// Real-world benchmark family (§7.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RealWorld {
    /// Fast Fourier Transform
    Fft,
    /// Gaussian elimination
    Ge,
    /// Molecular dynamics (fixed 41-task graph)
    Md,
    /// Epigenomics workflow
    Ew,
}

impl RealWorld {
    /// All four families, paper order.
    pub const ALL: [RealWorld; 4] = [
        RealWorld::Fft,
        RealWorld::Ge,
        RealWorld::Md,
        RealWorld::Ew,
    ];

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            RealWorld::Fft => "FFT",
            RealWorld::Ge => "GE",
            RealWorld::Md => "MD",
            RealWorld::Ew => "EW",
        }
    }

    /// Stable id for seeding (offset past RGG ids).
    pub fn id(&self) -> u64 {
        match self {
            RealWorld::Fft => 10,
            RealWorld::Ge => 11,
            RealWorld::Md => 12,
            RealWorld::Ew => 13,
        }
    }

    /// Structure sizes used per scale (size parameter of the generator).
    pub fn sizes(&self, scale: Scale) -> Vec<usize> {
        match (self, scale) {
            (RealWorld::Fft, Scale::Full) => vec![8, 16, 32, 64],
            (RealWorld::Fft, Scale::PaperSmall) => vec![8, 16],
            (RealWorld::Fft, Scale::Smoke) => vec![8],
            (RealWorld::Ge, Scale::Full) => vec![8, 16, 32, 64],
            (RealWorld::Ge, Scale::PaperSmall) => vec![8, 16],
            (RealWorld::Ge, Scale::Smoke) => vec![8],
            (RealWorld::Md, _) => vec![0], // fixed graph
            (RealWorld::Ew, Scale::Full) => vec![8, 16, 32, 64],
            (RealWorld::Ew, Scale::PaperSmall) => vec![8, 16],
            (RealWorld::Ew, Scale::Smoke) => vec![8],
        }
    }
}

/// One real-world experiment cell.
#[derive(Clone, Copy, Debug)]
pub struct RealWorldCell {
    /// benchmark family
    pub family: RealWorld,
    /// generator size parameter (matrix size m, FFT points, EW lanes)
    pub size: usize,
    /// CCR
    pub ccr: f64,
    /// heterogeneity β (percent)
    pub beta_pct: f64,
    /// "classic" (eq. 5) vs "medium" (eq. 6 medium intervals) variant
    pub medium_variant: bool,
    /// processors
    pub p: usize,
    /// cell index for seeding
    pub index: u64,
}

/// The real-world grid (§7.2): CCR ∈ {0.001..10}, β ∈ {10..95}, both cost
/// variants, the six processor graphs.
pub fn realworld_grid(family: RealWorld, scale: Scale) -> Vec<RealWorldCell> {
    let ccrs: Vec<f64> = match scale {
        Scale::Full => vec![0.001, 0.01, 0.1, 0.5, 1.0, 5.0, 10.0],
        Scale::PaperSmall => vec![0.1, 1.0, 10.0],
        Scale::Smoke => vec![1.0],
    };
    let betas: Vec<f64> = match scale {
        Scale::Full => vec![10.0, 25.0, 50.0, 75.0, 95.0],
        Scale::PaperSmall => vec![10.0, 50.0, 95.0],
        Scale::Smoke => vec![50.0],
    };
    let procs: Vec<usize> = match scale {
        Scale::Full => vec![2, 4, 8, 16, 32, 64],
        Scale::PaperSmall => vec![2, 8, 32],
        Scale::Smoke => vec![4],
    };
    let mut cells = Vec::new();
    let mut index = 0u64;
    for &size in &family.sizes(scale) {
        for &ccr in &ccrs {
            for &beta_pct in &betas {
                for &medium_variant in &[false, true] {
                    for &p in &procs {
                        cells.push(RealWorldCell {
                            family,
                            size,
                            ccr,
                            beta_pct,
                            medium_variant,
                            p,
                            index,
                        });
                        index += 1;
                    }
                }
            }
        }
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_grid_matches_paper_cardinality() {
        let cells = grid(Workload::RggClassic, Scale::Full);
        // 8 n × 3 o × 6 ccr × 4 α × 5 β × 5 γ × 6 p = 86,400
        assert_eq!(cells.len(), 86_400);
    }

    #[test]
    fn paper_small_is_tractable() {
        let cells = grid(Workload::RggHigh, Scale::PaperSmall);
        assert!(cells.len() <= 4000, "got {}", cells.len());
        assert!(cells.len() >= 500);
    }

    #[test]
    fn indices_are_unique() {
        let cells = grid(Workload::RggLow, Scale::PaperSmall);
        let mut idx: Vec<u64> = cells.iter().map(|c| c.index).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), cells.len());
    }

    #[test]
    fn scale_parsing() {
        assert_eq!(Scale::parse("full").unwrap(), Scale::Full);
        assert_eq!(Scale::parse("paper-small").unwrap(), Scale::PaperSmall);
        assert_eq!(Scale::parse("smoke").unwrap(), Scale::Smoke);
        assert!(Scale::parse("nope").is_err());
    }

    #[test]
    fn workload_ids_distinct() {
        let ids: std::collections::HashSet<u64> =
            Workload::ALL.iter().map(|w| w.id()).collect();
        assert_eq!(ids.len(), 4);
    }

    #[test]
    fn realworld_grid_has_both_variants() {
        let cells = realworld_grid(RealWorld::Ge, Scale::Smoke);
        assert!(cells.iter().any(|c| c.medium_variant));
        assert!(cells.iter().any(|c| !c.medium_variant));
    }
}
