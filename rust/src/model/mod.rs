//! The instance model layer: SoA cost storage, the borrowed instance view
//! every algorithm entry point consumes, and the platform-scoped execution
//! context that owns everything derivable from a platform alone.
//!
//! Before this layer existed, every algorithm took a loose
//! `(graph: &TaskGraph, platform: &Platform, comp: &[f64])` triple that each
//! caller re-threaded by hand, and nothing guaranteed the three parts
//! agreed on task or class counts until an index blew up deep inside a DP.
//! The model layer replaces that with three types:
//!
//! * [`CostMatrix`] — the dense task-major `v × P` execution-cost matrix as
//!   a first-class structure-of-arrays value. Row-slice accessors
//!   ([`CostMatrix::row`]) hand the DP kernels contiguous per-task cost
//!   rows, and the per-task scalarisations CPOP/HEFT use
//!   ([`CostMatrix::mean`], [`CostMatrix::min`], [`CostMatrix::argmin`])
//!   live next to the data they read.
//! * [`InstanceRef`] — a `Copy` borrowed view bundling
//!   `&TaskGraph + &Platform + &CostMatrix` with the shape invariants
//!   checked **once** at construction ([`InstanceRef::new`] /
//!   [`InstanceRef::try_new`]). Every public algorithm entry point in
//!   [`crate::cp`], [`crate::sched`], [`crate::metrics`] and
//!   [`crate::runtime`] takes an `InstanceRef` by value. An `InstanceRef`
//!   may additionally carry a borrowed [`PlatformCtx`]
//!   ([`PlatformCtx::bind`]), in which case the CEFT kernels read the
//!   context's resident communication panels instead of refilling them.
//! * [`PlatformCtx`] — everything that is a pure function of the platform,
//!   computed **once** and shared by every request/cell/backend that uses
//!   that platform: the interned structural hash, the destination-major
//!   `P × P` startup/bandwidth panels of the min-plus kernel (`0` / `+inf`
//!   diagonals preserved — see EXPERIMENTS.md §Platform contexts), the
//!   per-sender-class mean-comm scalars, the f32 marshals the PJRT backend
//!   feeds to `relax_batch`, and a platform-sized [`WorkspacePool`] so
//!   scratch arenas are pooled per platform shape rather than globally.
//!
//! The raw `&[f64]` representation survives only at the JSON/service
//! boundary (wire decoding in [`crate::graph::io`], structural hashing in
//! [`crate::service::hashing`]) and as the deprecated one-line shims below.
//!
//! `CostMatrix` derefs to its flat `[f64]` storage, so boundary code that
//! needs the raw row-major buffer (serialisation, hashing, the f32 PJRT
//! marshalling) reads it without a copy.

use crate::cp::ceft::simd::KernelDispatch;
use crate::cp::workspace::{Workspace, WorkspacePool};
use crate::graph::TaskGraph;
use crate::platform::Platform;
use crate::util::aligned::AlignedVec;
use std::sync::Arc;

/// Dense task-major `v × P` execution-cost matrix (`C_comp(t, j)` of the
/// paper): row `t` holds task `t`'s cost on every processor class,
/// contiguously. The SoA layout is what the blocked CEFT kernel and the
/// rank sweeps iterate over.
#[derive(Clone, Debug, PartialEq)]
pub struct CostMatrix {
    /// number of classes (row stride)
    p: usize,
    /// row-major `v × P` costs
    data: Vec<f64>,
}

impl CostMatrix {
    /// Build from the row stride and the flat row-major data. Panics when
    /// `data.len()` is not a multiple of `p` (a programming error, not a
    /// runtime condition — untrusted input goes through
    /// [`CostMatrix::try_new`]).
    pub fn new(p: usize, data: Vec<f64>) -> Self {
        Self::try_new(p, data).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible constructor for untrusted input (the JSON/service
    /// boundary): validates the shape instead of panicking.
    pub fn try_new(p: usize, data: Vec<f64>) -> Result<Self, String> {
        if p == 0 {
            return Err("cost matrix needs at least one class".to_string());
        }
        if data.len() % p != 0 {
            return Err(format!(
                "cost data has {} entries, not a multiple of P = {p}",
                data.len()
            ));
        }
        Ok(Self { p, data })
    }

    /// Number of tasks (rows).
    #[inline]
    pub fn n(&self) -> usize {
        self.data.len() / self.p
    }

    /// Number of processor classes (row stride).
    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// `C_comp(t, j)`.
    #[inline]
    pub fn get(&self, t: usize, j: usize) -> f64 {
        self.data[t * self.p + j]
    }

    /// Task `t`'s contiguous cost row over all classes.
    #[inline]
    pub fn row(&self, t: usize) -> &[f64] {
        &self.data[t * self.p..(t + 1) * self.p]
    }

    /// Mean execution cost of task `t` over classes — the CPOP/HEFT
    /// scalarisation.
    pub fn mean(&self, t: usize) -> f64 {
        self.row(t).iter().sum::<f64>() / self.p as f64
    }

    /// Minimum execution cost of task `t`.
    pub fn min(&self, t: usize) -> f64 {
        self.row(t).iter().fold(f64::INFINITY, |a, &b| a.min(b))
    }

    /// Fastest class for task `t` (lowest cost; ties at lowest id).
    pub fn argmin(&self, t: usize) -> usize {
        let row = self.row(t);
        let mut best = 0;
        for j in 1..self.p {
            if row[j] < row[best] {
                best = j;
            }
        }
        best
    }

    /// The flat row-major storage (also available through `Deref`).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Consume the matrix, returning the flat storage.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }
}

impl std::ops::Deref for CostMatrix {
    type Target = [f64];

    /// Deref to the flat row-major storage, so boundary code (hashing,
    /// serialisation, f32 marshalling) reads the raw buffer without a copy.
    fn deref(&self) -> &[f64] {
        &self.data
    }
}

/// A borrowed, shape-checked view of one scheduling instance:
/// `&TaskGraph + &Platform + &CostMatrix`. `Copy`, so it is passed by value
/// through every layer instead of re-threading three loose references.
///
/// When constructed through [`PlatformCtx::bind`] the view additionally
/// carries the platform's execution context ([`InstanceRef::ctx`]), and
/// the CEFT kernels read the context's resident communication panels
/// instead of refilling workspace-local copies — same bits, no `O(P²)`
/// per-call setup.
#[derive(Clone, Copy, Debug)]
pub struct InstanceRef<'a> {
    /// the task DAG
    pub graph: &'a TaskGraph,
    /// the processor classes and communication model
    pub platform: &'a Platform,
    /// the dense execution-cost matrix
    pub costs: &'a CostMatrix,
    /// the platform execution context, when bound through
    /// [`PlatformCtx::bind`] (private so `platform` and `ctx` can never
    /// disagree — the only constructor that sets it borrows `platform`
    /// from the context itself)
    ctx: Option<&'a PlatformCtx>,
}

impl<'a> InstanceRef<'a> {
    /// Bundle the three parts, asserting the shape invariants
    /// (`costs.n() == graph.num_tasks()`, `costs.p() ==
    /// platform.num_classes()`). Panics on mismatch — internal callers
    /// construct from already-validated parts; untrusted input goes through
    /// [`InstanceRef::try_new`].
    pub fn new(graph: &'a TaskGraph, platform: &'a Platform, costs: &'a CostMatrix) -> Self {
        Self::try_new(graph, platform, costs).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible constructor for the service boundary: reports shape
    /// mismatches instead of panicking.
    pub fn try_new(
        graph: &'a TaskGraph,
        platform: &'a Platform,
        costs: &'a CostMatrix,
    ) -> Result<Self, String> {
        if costs.p() != platform.num_classes() {
            return Err(format!(
                "cost matrix has {} classes but platform has {}",
                costs.p(),
                platform.num_classes()
            ));
        }
        if costs.n() != graph.num_tasks() {
            return Err(format!(
                "cost matrix has {} rows but graph has {} tasks",
                costs.n(),
                graph.num_tasks()
            ));
        }
        Ok(Self {
            graph,
            platform,
            costs,
            ctx: None,
        })
    }

    /// Number of tasks `v`.
    #[inline]
    pub fn n(&self) -> usize {
        self.graph.num_tasks()
    }

    /// Number of processor classes `P`.
    #[inline]
    pub fn p(&self) -> usize {
        self.platform.num_classes()
    }

    /// The platform execution context this view was bound through, if any
    /// ([`PlatformCtx::bind`]). The CEFT kernels use it to read resident
    /// communication panels; `None` means they fill workspace-local panels
    /// as before — outputs are bit-identical either way.
    #[inline]
    pub fn ctx(&self) -> Option<&'a PlatformCtx> {
        self.ctx
    }
}

/// Fill the destination-major `P × P` communication panels for `platform`:
/// for destination class `j` and sender class `l`,
/// `startup_panel[j*P + l] = startup(l)` and
/// `bw_panel[j*P + l] = bandwidth(l → j)`, with a `0` / `+inf` diagonal so
/// the min-plus kernel's `S + data / B` evaluates to exactly `+0.0` for
/// co-located classes — the same bits [`Platform::comm_cost`] produces.
/// Single implementation behind both the resident [`PlatformCtx`] panels
/// and the workspace-local fallback in [`crate::cp::ceft`].
pub(crate) fn fill_comm_panels(platform: &Platform, sp: &mut AlignedVec, bp: &mut AlignedVec) {
    let p = platform.num_classes();
    sp.clear();
    sp.resize(p * p, 0.0);
    bp.clear();
    bp.resize(p * p, 0.0);
    for j in 0..p {
        let srow = &mut sp[j * p..(j + 1) * p];
        let brow = &mut bp[j * p..(j + 1) * p];
        for l in 0..p {
            if l == j {
                srow[l] = 0.0;
                brow[l] = f64::INFINITY;
            } else {
                srow[l] = platform.startup(l);
                brow[l] = platform.bandwidth(l, j);
            }
        }
    }
}

/// Fill the f32 marshals the PJRT `relax_batch` artifact consumes:
/// `l[j] = startup(j) as f32`, and the sender-major reciprocal-bandwidth
/// matrix `invbw[l*P + j] = (1 / bandwidth(l → j)) as f32` with a `0`
/// diagonal (the artifact's co-located branch). Single implementation
/// behind the resident [`PlatformCtx`] marshals and the unbound fallback
/// in [`crate::runtime`], so the two accelerator paths cannot diverge.
pub(crate) fn fill_f32_marshals(platform: &Platform, l: &mut Vec<f32>, invbw: &mut Vec<f32>) {
    let p = platform.num_classes();
    l.clear();
    l.extend((0..p).map(|j| platform.startup(j) as f32));
    invbw.clear();
    invbw.resize(p * p, 0.0);
    for a in 0..p {
        for b in 0..p {
            if a != b {
                invbw[a * p + b] = (1.0 / platform.bandwidth(a, b)) as f32;
            }
        }
    }
}

/// A platform-scoped execution context: everything that depends only on
/// the platform, computed once and borrowed by every instance that runs
/// on it.
///
/// The CEFT min-plus kernel prices every edge against the platform's
/// `P × P` startup/bandwidth panels. Those panels are a pure function of
/// the platform, yet before this type existed every DP entry refilled them
/// into the [`Workspace`] — `O(P²)` per call, repeated thousands of times
/// by the online service for a handful of distinct platforms. A
/// `PlatformCtx` makes the platform's derived state **resident**:
///
/// * the interned structural hash ([`PlatformCtx::hash`], the same
///   [`crate::util::hashing::hash_platform`] the service keys its
///   caches on);
/// * the destination-major communication panels
///   ([`PlatformCtx::panel_startup`] / [`PlatformCtx::panel_bw`]) with the
///   `0` / `+inf` diagonal contract of the kernel preserved;
/// * per-sender-class mean-comm scalars ([`PlatformCtx::mean_comm_from`]),
///   the class-resolved refinement of [`Platform::mean_comm_cost`];
/// * the f32 marshals ([`PlatformCtx::startup_f32`] /
///   [`PlatformCtx::invbw_f32`]) the PJRT `relax_batch` artifact consumes,
///   filled by the same routine as the runtime's unbound fallback so both
///   backends share one batching layer;
/// * a platform-sized [`WorkspacePool`] ([`PlatformCtx::with_workspace`]):
///   scratch arenas are pooled per platform shape, so a large-`P`
///   platform's high-water arenas are never handed to (and retained for)
///   small-`P` requests.
///
/// Bind a graph + cost matrix with [`PlatformCtx::bind`] to obtain an
/// [`InstanceRef`] that carries the context through every layer; the CEFT
/// kernels then skip the per-call panel fill entirely. Construction is
/// `O(P²)`; everything after is read-only and `Sync`, so one `Arc<PlatformCtx>`
/// serves concurrent workers (the service engine interns one per distinct
/// platform hash, the sweep harness one per distinct platform per run).
pub struct PlatformCtx {
    platform: Arc<Platform>,
    /// structural platform hash (`crate::util::hashing::hash_platform`)
    hash: u64,
    /// destination-major `P × P` startup panel (`0` diagonal), 32-byte
    /// aligned so the SIMD lanes' panel loads never straddle a cache line
    panel_startup: AlignedVec,
    /// destination-major `P × P` bandwidth panel (`+inf` diagonal), aligned
    /// like `panel_startup`
    panel_bw: AlignedVec,
    /// lane implementation the CEFT kernels run for this platform —
    /// selected once at construction ([`KernelDispatch::select`];
    /// `CEFT_FORCE_SCALAR=1` forces the scalar lanes)
    dispatch: KernelDispatch,
    /// per-sender-class mean reciprocal bandwidth over the `P - 1` distinct
    /// destinations (all zeros when `P == 1` — no distinct pairs)
    mean_inv_bw_from: Vec<f64>,
    /// f32 marshal of per-class startup latencies (PJRT `relax_batch` `l`)
    startup_f32: Vec<f32>,
    /// f32 marshal of the reciprocal-bandwidth matrix, sender-major with a
    /// `0` diagonal (PJRT `relax_batch` `invbw`)
    invbw_f32: Vec<f32>,
    /// platform-sized workspace pool (arenas shaped by this platform's `P`)
    pool: WorkspacePool,
}

impl PlatformCtx {
    /// Context over an owned platform with an unbounded workspace pool —
    /// the one-shot constructor for CLI commands, tests and benches.
    pub fn new(platform: Platform) -> Self {
        Self::from_arc(Arc::new(platform))
    }

    /// Context over a shared platform with an unbounded workspace pool.
    pub fn from_arc(platform: Arc<Platform>) -> Self {
        Self::build(platform, usize::MAX, None)
    }

    /// Context whose workspace pool retains at most `max_idle` idle arenas
    /// — what the service engine and the sweep harness use (bounded at
    /// their worker-thread count, like the former global pools).
    pub fn bounded(platform: Arc<Platform>, max_idle: usize) -> Self {
        Self::build(platform, max_idle, None)
    }

    /// [`PlatformCtx::bounded`] for interning callers that already computed
    /// the structural platform hash — skips rehashing the `O(P²)` platform
    /// encoding (debug builds assert the supplied hash matches).
    pub(crate) fn bounded_prehashed(platform: Arc<Platform>, max_idle: usize, hash: u64) -> Self {
        Self::build(platform, max_idle, Some(hash))
    }

    fn build(platform: Arc<Platform>, max_idle: usize, prehash: Option<u64>) -> Self {
        let p = platform.num_classes();
        let hash =
            prehash.unwrap_or_else(|| crate::util::hashing::hash_platform(&platform));
        debug_assert_eq!(hash, crate::util::hashing::hash_platform(&platform));
        let mut panel_startup = AlignedVec::new();
        let mut panel_bw = AlignedVec::new();
        fill_comm_panels(&platform, &mut panel_startup, &mut panel_bw);
        panel_startup.assert_aligned();
        panel_bw.assert_aligned();
        // per-sender mean reciprocal bandwidth over distinct destinations;
        // panel_bw is destination-major, so sender l's reciprocals live at
        // stride P — the +inf diagonal contributes exactly 0.0
        let mut mean_inv_bw_from = vec![0.0; p];
        if p > 1 {
            for (l, m) in mean_inv_bw_from.iter_mut().enumerate() {
                let mut sum = 0.0;
                for j in 0..p {
                    sum += 1.0 / panel_bw[j * p + l];
                }
                *m = sum / (p - 1) as f64;
            }
        }
        // f32 marshals for the PJRT backend — one shared routine with the
        // runtime's unbound fallback, so the two paths cannot diverge
        let mut startup_f32 = Vec::new();
        let mut invbw_f32 = Vec::new();
        fill_f32_marshals(&platform, &mut startup_f32, &mut invbw_f32);
        Self {
            platform,
            hash,
            panel_startup,
            panel_bw,
            dispatch: KernelDispatch::select(),
            mean_inv_bw_from,
            startup_f32,
            invbw_f32,
            pool: if max_idle == usize::MAX {
                WorkspacePool::new()
            } else {
                WorkspacePool::bounded(max_idle)
            },
        }
    }

    /// The platform this context was derived from.
    #[inline]
    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    /// The shared platform handle (for callers that intern the context).
    pub fn platform_arc(&self) -> &Arc<Platform> {
        &self.platform
    }

    /// Interned structural platform hash
    /// ([`crate::util::hashing::hash_platform`]).
    #[inline]
    pub fn hash(&self) -> u64 {
        self.hash
    }

    /// Number of processor classes `P`.
    #[inline]
    pub fn p(&self) -> usize {
        self.platform.num_classes()
    }

    /// The resident destination-major `P × P` startup panel: row `j` holds
    /// `startup(l)` for every sender class `l != j` and `0.0` on the
    /// diagonal.
    #[inline]
    pub fn panel_startup(&self) -> &[f64] {
        self.panel_startup.as_slice()
    }

    /// The resident destination-major `P × P` bandwidth panel, aligned
    /// with [`PlatformCtx::panel_startup`]: row `j` holds
    /// `bandwidth(l → j)` for `l != j` and `+inf` on the diagonal (so
    /// `data / bw` contributes exactly `+0.0` when co-located).
    #[inline]
    pub fn panel_bw(&self) -> &[f64] {
        self.panel_bw.as_slice()
    }

    /// The lane implementation the CEFT kernels run for instances bound
    /// through this context — selected once at construction
    /// ([`KernelDispatch::select`]), so thousands of requests on one
    /// platform never re-read the environment.
    #[inline]
    pub fn dispatch(&self) -> KernelDispatch {
        self.dispatch
    }

    /// Mean communication cost of moving `data` units *from* class `l` to
    /// a uniformly random *other* class — the per-sender-class refinement
    /// of [`Platform::mean_comm_cost`]. Exactly `0` when `P == 1` (no
    /// distinct destinations, all transfers co-located).
    ///
    /// Not yet consumed by the rank sweeps: CPOP/HEFT deliberately keep
    /// the paper's global scalarisation (`Platform::mean_comm_cost`), and
    /// changing that would break the bit-identity contract with the
    /// published algorithms. This is the ctx surface for the class-aware
    /// rank refinements the ROADMAP sketches.
    #[inline]
    pub fn mean_comm_from(&self, l: usize, data: f64) -> f64 {
        if self.p() == 1 {
            0.0
        } else {
            self.platform.startup(l) + data * self.mean_inv_bw_from[l]
        }
    }

    /// f32 marshal of the per-class startup latencies — the `l` operand of
    /// the PJRT `relax_batch` artifact.
    #[inline]
    pub fn startup_f32(&self) -> &[f32] {
        &self.startup_f32
    }

    /// f32 marshal of the sender-major reciprocal-bandwidth matrix with a
    /// `0` diagonal — the `invbw` operand of the PJRT `relax_batch`
    /// artifact, filled by the same routine as the runtime's unbound
    /// fallback.
    #[inline]
    pub fn invbw_f32(&self) -> &[f32] {
        &self.invbw_f32
    }

    /// Run `f` with a workspace from this context's platform-sized pool.
    /// Arenas checked out here only ever serve instances of this
    /// platform's `P`, so their high-water capacity tracks this platform's
    /// shape instead of the largest platform the whole process has seen.
    pub fn with_workspace<R>(&self, f: impl FnOnce(&mut Workspace) -> R) -> R {
        self.pool.with(f)
    }

    /// Workspaces ever created by this context's pool (concurrency
    /// high-water mark).
    pub fn pool_created(&self) -> usize {
        self.pool.created()
    }

    /// Workspaces currently idle in this context's pool.
    pub fn pool_idle(&self) -> usize {
        self.pool.idle()
    }

    /// Bind a graph and cost matrix to this platform as a ctx-carrying
    /// [`InstanceRef`]: the CEFT kernels will read this context's resident
    /// panels instead of refilling workspace copies. Panics on shape
    /// mismatch (see [`PlatformCtx::try_bind`]).
    pub fn bind<'a>(&'a self, graph: &'a TaskGraph, costs: &'a CostMatrix) -> InstanceRef<'a> {
        self.try_bind(graph, costs).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible [`PlatformCtx::bind`] for untrusted shapes.
    pub fn try_bind<'a>(
        &'a self,
        graph: &'a TaskGraph,
        costs: &'a CostMatrix,
    ) -> Result<InstanceRef<'a>, String> {
        let mut inst = InstanceRef::try_new(graph, &self.platform, costs)?;
        inst.ctx = Some(self);
        Ok(inst)
    }
}

impl std::fmt::Debug for PlatformCtx {
    /// Concise form: the panels are `P²` floats and would drown test
    /// failure output.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlatformCtx")
            .field("p", &self.p())
            .field("hash", &format_args!("{:016x}", self.hash))
            .field("pool_created", &self.pool.created())
            .finish()
    }
}

/// Deprecated raw-triple shim for the service/JSON boundary: copy a
/// borrowed row-major `v × P` slice into an owned [`CostMatrix`].
#[deprecated(
    note = "build a CostMatrix once (CostMatrix::new) and pass InstanceRef; this shim copies the slice"
)]
pub fn cost_matrix_from_raw(p: usize, comp: &[f64]) -> CostMatrix {
    CostMatrix::new(p, comp.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_match_layout() {
        let m = CostMatrix::new(3, vec![3.0, 1.0, 2.0, 5.0, 5.0, 5.0]);
        assert_eq!(m.n(), 2);
        assert_eq!(m.p(), 3);
        assert_eq!(m.get(0, 1), 1.0);
        assert_eq!(m.row(1), &[5.0, 5.0, 5.0]);
        assert_eq!(m.argmin(0), 1);
        assert_eq!(m.min(0), 1.0);
        assert!((m.mean(0) - 2.0).abs() < 1e-12);
        assert_eq!(m.argmin(1), 0, "ties break to the lowest class id");
        // deref exposes the flat storage
        assert_eq!(m.len(), 6);
        assert_eq!(&m[..2], &[3.0, 1.0]);
        assert_eq!(m.as_slice(), &m[..]);
    }

    #[test]
    fn try_new_rejects_bad_shapes() {
        assert!(CostMatrix::try_new(0, vec![]).is_err());
        assert!(CostMatrix::try_new(2, vec![1.0, 2.0, 3.0]).is_err());
        assert!(CostMatrix::try_new(2, vec![1.0, 2.0]).is_ok());
    }

    #[test]
    fn instance_ref_checks_shapes() {
        let g = TaskGraph::from_edges(2, &[(0, 1, 1.0)]);
        let plat = Platform::uniform(2, 1.0, 0.0);
        let good = CostMatrix::new(2, vec![1.0; 4]);
        let inst = InstanceRef::new(&g, &plat, &good);
        assert_eq!(inst.n(), 2);
        assert_eq!(inst.p(), 2);
        // wrong class count
        let bad_p = CostMatrix::new(3, vec![1.0; 6]);
        assert!(InstanceRef::try_new(&g, &plat, &bad_p)
            .unwrap_err()
            .contains("classes"));
        // wrong task count
        let bad_n = CostMatrix::new(2, vec![1.0; 6]);
        assert!(InstanceRef::try_new(&g, &plat, &bad_n)
            .unwrap_err()
            .contains("rows"));
    }

    #[test]
    #[allow(deprecated)]
    fn raw_shim_copies() {
        let raw = [1.0, 2.0, 3.0, 4.0];
        let m = cost_matrix_from_raw(2, &raw);
        assert_eq!(m.n(), 2);
        assert_eq!(m.as_slice(), &raw);
    }

    #[test]
    fn platform_ctx_panels_match_comm_cost_contract() {
        let mut rng = crate::util::rng::Xoshiro256::new(11);
        let plat = Platform::random_links(4, &mut rng, 0.3, 3.0, 0.1, 0.8);
        let ctx = PlatformCtx::new(plat.clone());
        let p = ctx.p();
        assert_eq!(p, 4);
        let (sp, bp) = (ctx.panel_startup(), ctx.panel_bw());
        for j in 0..p {
            for l in 0..p {
                if l == j {
                    assert_eq!(sp[j * p + l], 0.0);
                    assert_eq!(bp[j * p + l], f64::INFINITY);
                    // the kernel's branch-free form reproduces co-location
                    assert_eq!(sp[j * p + l] + 7.0 / bp[j * p + l], 0.0);
                } else {
                    assert_eq!(sp[j * p + l], plat.startup(l));
                    assert_eq!(bp[j * p + l], plat.bandwidth(l, j));
                    // panel form == Platform::comm_cost, bit for bit
                    let data = 13.5;
                    assert_eq!(
                        sp[j * p + l] + data / bp[j * p + l],
                        plat.comm_cost(l, j, data)
                    );
                }
            }
        }
        // the interned hash is the service's structural platform hash
        assert_eq!(ctx.hash(), crate::util::hashing::hash_platform(&plat));
    }

    #[test]
    fn platform_ctx_mean_comm_scalars() {
        // uniform platform: every sender sees the same mean as the global
        // scalarisation
        let plat = Platform::uniform(3, 2.0, 0.5);
        let ctx = PlatformCtx::new(plat.clone());
        for l in 0..3 {
            assert!(
                (ctx.mean_comm_from(l, 10.0) - (0.5 + 10.0 / 2.0)).abs() < 1e-12,
                "sender {l}"
            );
        }
        // heterogeneous links: the per-class means average back to the
        // platform's global mean_comm_cost (both average the same
        // P(P-1) distinct ordered pairs)
        let mut rng = crate::util::rng::Xoshiro256::new(23);
        let het = Platform::random_links(5, &mut rng, 0.2, 4.0, 0.0, 1.0);
        let hctx = PlatformCtx::new(het.clone());
        let data = 6.25;
        let avg: f64 = (0..5).map(|l| hctx.mean_comm_from(l, data)).sum::<f64>() / 5.0;
        assert!((avg - het.mean_comm_cost(data)).abs() < 1e-9);
        // P == 1: no distinct pairs, exactly zero (Definition 3)
        let one = PlatformCtx::new(Platform::uniform(1, 1.0, 5.0));
        assert_eq!(one.mean_comm_from(0, 100.0), 0.0);
    }

    #[test]
    fn platform_ctx_f32_marshals_match_runtime_layout() {
        let mut rng = crate::util::rng::Xoshiro256::new(41);
        let plat = Platform::random_links(3, &mut rng, 0.5, 2.0, 0.0, 1.0);
        let ctx = PlatformCtx::new(plat.clone());
        for a in 0..3 {
            assert_eq!(ctx.startup_f32()[a], plat.startup(a) as f32);
            for b in 0..3 {
                let expect = if a == b {
                    0.0
                } else {
                    (1.0 / plat.bandwidth(a, b)) as f32
                };
                assert_eq!(ctx.invbw_f32()[a * 3 + b], expect, "({a},{b})");
            }
        }
    }

    #[test]
    fn platform_ctx_bind_carries_ctx_and_checks_shapes() {
        let g = TaskGraph::from_edges(2, &[(0, 1, 1.0)]);
        let ctx = PlatformCtx::new(Platform::uniform(2, 1.0, 0.0));
        let good = CostMatrix::new(2, vec![1.0; 4]);
        let inst = ctx.bind(&g, &good);
        assert!(inst.ctx().is_some());
        assert!(std::ptr::eq(inst.platform, ctx.platform()));
        // the plain constructor carries no context
        let plain = InstanceRef::new(&g, ctx.platform(), &good);
        assert!(plain.ctx().is_none());
        // shape mismatches are still rejected
        let bad = CostMatrix::new(3, vec![1.0; 6]);
        assert!(ctx.try_bind(&g, &bad).is_err());
    }

    #[test]
    fn platform_ctx_panels_are_lane_aligned_and_dispatch_pinned() {
        let ctx = PlatformCtx::new(Platform::uniform(5, 1.0, 0.5));
        let align = crate::util::aligned::ALIGN;
        assert_eq!(ctx.panel_startup().as_ptr() as usize % align, 0);
        assert_eq!(ctx.panel_bw().as_ptr() as usize % align, 0);
        // selected once at construction from the same environment rule
        assert_eq!(ctx.dispatch(), KernelDispatch::select());
    }

    #[test]
    fn platform_ctx_pool_is_platform_scoped() {
        let ctx = PlatformCtx::bounded(Arc::new(Platform::uniform(2, 1.0, 0.0)), 2);
        assert_eq!(ctx.pool_created(), 0);
        ctx.with_workspace(|ws| ws.table.resize(64, 0.0));
        assert_eq!(ctx.pool_created(), 1);
        assert_eq!(ctx.pool_idle(), 1);
        // reuse, not regrowth
        ctx.with_workspace(|ws| assert!(ws.table.capacity() >= 64));
        assert_eq!(ctx.pool_created(), 1);
    }
}
