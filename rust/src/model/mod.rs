//! The instance model layer: SoA cost storage and the borrowed instance
//! view every algorithm entry point consumes.
//!
//! Before this layer existed, every algorithm took a loose
//! `(graph: &TaskGraph, platform: &Platform, comp: &[f64])` triple that each
//! caller re-threaded by hand, and nothing guaranteed the three parts
//! agreed on task or class counts until an index blew up deep inside a DP.
//! The model layer replaces that with two types:
//!
//! * [`CostMatrix`] — the dense task-major `v × P` execution-cost matrix as
//!   a first-class structure-of-arrays value. Row-slice accessors
//!   ([`CostMatrix::row`]) hand the DP kernels contiguous per-task cost
//!   rows, and the per-task scalarisations CPOP/HEFT use
//!   ([`CostMatrix::mean`], [`CostMatrix::min`], [`CostMatrix::argmin`])
//!   live next to the data they read.
//! * [`InstanceRef`] — a `Copy` borrowed view bundling
//!   `&TaskGraph + &Platform + &CostMatrix` with the shape invariants
//!   checked **once** at construction ([`InstanceRef::new`] /
//!   [`InstanceRef::try_new`]). Every public algorithm entry point in
//!   [`crate::cp`], [`crate::sched`], [`crate::metrics`] and
//!   [`crate::runtime`] takes an `InstanceRef` by value.
//!
//! The raw `&[f64]` representation survives only at the JSON/service
//! boundary (wire decoding in [`crate::graph::io`], structural hashing in
//! [`crate::service::hashing`]) and as the deprecated one-line shims below.
//!
//! `CostMatrix` derefs to its flat `[f64]` storage, so boundary code that
//! needs the raw row-major buffer (serialisation, hashing, the f32 PJRT
//! marshalling) reads it without a copy.

use crate::graph::TaskGraph;
use crate::platform::Platform;

/// Dense task-major `v × P` execution-cost matrix (`C_comp(t, j)` of the
/// paper): row `t` holds task `t`'s cost on every processor class,
/// contiguously. The SoA layout is what the blocked CEFT kernel and the
/// rank sweeps iterate over.
#[derive(Clone, Debug, PartialEq)]
pub struct CostMatrix {
    /// number of classes (row stride)
    p: usize,
    /// row-major `v × P` costs
    data: Vec<f64>,
}

impl CostMatrix {
    /// Build from the row stride and the flat row-major data. Panics when
    /// `data.len()` is not a multiple of `p` (a programming error, not a
    /// runtime condition — untrusted input goes through
    /// [`CostMatrix::try_new`]).
    pub fn new(p: usize, data: Vec<f64>) -> Self {
        Self::try_new(p, data).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible constructor for untrusted input (the JSON/service
    /// boundary): validates the shape instead of panicking.
    pub fn try_new(p: usize, data: Vec<f64>) -> Result<Self, String> {
        if p == 0 {
            return Err("cost matrix needs at least one class".to_string());
        }
        if data.len() % p != 0 {
            return Err(format!(
                "cost data has {} entries, not a multiple of P = {p}",
                data.len()
            ));
        }
        Ok(Self { p, data })
    }

    /// Number of tasks (rows).
    #[inline]
    pub fn n(&self) -> usize {
        self.data.len() / self.p
    }

    /// Number of processor classes (row stride).
    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// `C_comp(t, j)`.
    #[inline]
    pub fn get(&self, t: usize, j: usize) -> f64 {
        self.data[t * self.p + j]
    }

    /// Task `t`'s contiguous cost row over all classes.
    #[inline]
    pub fn row(&self, t: usize) -> &[f64] {
        &self.data[t * self.p..(t + 1) * self.p]
    }

    /// Mean execution cost of task `t` over classes — the CPOP/HEFT
    /// scalarisation.
    pub fn mean(&self, t: usize) -> f64 {
        self.row(t).iter().sum::<f64>() / self.p as f64
    }

    /// Minimum execution cost of task `t`.
    pub fn min(&self, t: usize) -> f64 {
        self.row(t).iter().fold(f64::INFINITY, |a, &b| a.min(b))
    }

    /// Fastest class for task `t` (lowest cost; ties at lowest id).
    pub fn argmin(&self, t: usize) -> usize {
        let row = self.row(t);
        let mut best = 0;
        for j in 1..self.p {
            if row[j] < row[best] {
                best = j;
            }
        }
        best
    }

    /// The flat row-major storage (also available through `Deref`).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Consume the matrix, returning the flat storage.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }
}

impl std::ops::Deref for CostMatrix {
    type Target = [f64];

    /// Deref to the flat row-major storage, so boundary code (hashing,
    /// serialisation, f32 marshalling) reads the raw buffer without a copy.
    fn deref(&self) -> &[f64] {
        &self.data
    }
}

/// A borrowed, shape-checked view of one scheduling instance:
/// `&TaskGraph + &Platform + &CostMatrix`. `Copy`, so it is passed by value
/// through every layer instead of re-threading three loose references.
#[derive(Clone, Copy, Debug)]
pub struct InstanceRef<'a> {
    /// the task DAG
    pub graph: &'a TaskGraph,
    /// the processor classes and communication model
    pub platform: &'a Platform,
    /// the dense execution-cost matrix
    pub costs: &'a CostMatrix,
}

impl<'a> InstanceRef<'a> {
    /// Bundle the three parts, asserting the shape invariants
    /// (`costs.n() == graph.num_tasks()`, `costs.p() ==
    /// platform.num_classes()`). Panics on mismatch — internal callers
    /// construct from already-validated parts; untrusted input goes through
    /// [`InstanceRef::try_new`].
    pub fn new(graph: &'a TaskGraph, platform: &'a Platform, costs: &'a CostMatrix) -> Self {
        Self::try_new(graph, platform, costs).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible constructor for the service boundary: reports shape
    /// mismatches instead of panicking.
    pub fn try_new(
        graph: &'a TaskGraph,
        platform: &'a Platform,
        costs: &'a CostMatrix,
    ) -> Result<Self, String> {
        if costs.p() != platform.num_classes() {
            return Err(format!(
                "cost matrix has {} classes but platform has {}",
                costs.p(),
                platform.num_classes()
            ));
        }
        if costs.n() != graph.num_tasks() {
            return Err(format!(
                "cost matrix has {} rows but graph has {} tasks",
                costs.n(),
                graph.num_tasks()
            ));
        }
        Ok(Self {
            graph,
            platform,
            costs,
        })
    }

    /// Number of tasks `v`.
    #[inline]
    pub fn n(&self) -> usize {
        self.graph.num_tasks()
    }

    /// Number of processor classes `P`.
    #[inline]
    pub fn p(&self) -> usize {
        self.platform.num_classes()
    }
}

/// Deprecated raw-triple shim for the service/JSON boundary: copy a
/// borrowed row-major `v × P` slice into an owned [`CostMatrix`].
#[deprecated(
    note = "build a CostMatrix once (CostMatrix::new) and pass InstanceRef; this shim copies the slice"
)]
pub fn cost_matrix_from_raw(p: usize, comp: &[f64]) -> CostMatrix {
    CostMatrix::new(p, comp.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_match_layout() {
        let m = CostMatrix::new(3, vec![3.0, 1.0, 2.0, 5.0, 5.0, 5.0]);
        assert_eq!(m.n(), 2);
        assert_eq!(m.p(), 3);
        assert_eq!(m.get(0, 1), 1.0);
        assert_eq!(m.row(1), &[5.0, 5.0, 5.0]);
        assert_eq!(m.argmin(0), 1);
        assert_eq!(m.min(0), 1.0);
        assert!((m.mean(0) - 2.0).abs() < 1e-12);
        assert_eq!(m.argmin(1), 0, "ties break to the lowest class id");
        // deref exposes the flat storage
        assert_eq!(m.len(), 6);
        assert_eq!(&m[..2], &[3.0, 1.0]);
        assert_eq!(m.as_slice(), &m[..]);
    }

    #[test]
    fn try_new_rejects_bad_shapes() {
        assert!(CostMatrix::try_new(0, vec![]).is_err());
        assert!(CostMatrix::try_new(2, vec![1.0, 2.0, 3.0]).is_err());
        assert!(CostMatrix::try_new(2, vec![1.0, 2.0]).is_ok());
    }

    #[test]
    fn instance_ref_checks_shapes() {
        let g = TaskGraph::from_edges(2, &[(0, 1, 1.0)]);
        let plat = Platform::uniform(2, 1.0, 0.0);
        let good = CostMatrix::new(2, vec![1.0; 4]);
        let inst = InstanceRef::new(&g, &plat, &good);
        assert_eq!(inst.n(), 2);
        assert_eq!(inst.p(), 2);
        // wrong class count
        let bad_p = CostMatrix::new(3, vec![1.0; 6]);
        assert!(InstanceRef::try_new(&g, &plat, &bad_p)
            .unwrap_err()
            .contains("classes"));
        // wrong task count
        let bad_n = CostMatrix::new(2, vec![1.0; 6]);
        assert!(InstanceRef::try_new(&g, &plat, &bad_n)
            .unwrap_err()
            .contains("rows"));
    }

    #[test]
    #[allow(deprecated)]
    fn raw_shim_copies() {
        let raw = [1.0, 2.0, 3.0, 4.0];
        let m = cost_matrix_from_raw(2, &raw);
        assert_eq!(m.n(), 2);
        assert_eq!(m.as_slice(), &raw);
    }
}
