//! Critical-path algorithms for heterogeneous machines.
//!
//! * [`ceft`] — the paper's contribution: the Critical Earliest Finish Time
//!   dynamic program (Algorithm 1) that finds the critical path *together
//!   with* the partial assignment of its tasks to processor classes. Its
//!   `O(P²e)` inner loop runs as a blocked class-pair min-plus kernel over
//!   communication panels — resident in a
//!   [`crate::model::PlatformCtx`] when the instance is bound through one,
//!   filled into the workspace otherwise — and a batched matrix-matrix
//!   variant relaxes many parent rows against one shared panel pair
//!   (bit-identical to the retained scalar reference path either way).
//!
//! Every entry point takes a [`crate::model::InstanceRef`] — the
//! shape-checked `&TaskGraph + &Platform + &CostMatrix` view — instead of a
//! loose `(graph, platform, comp)` triple.
//! * [`ranks`] — the mean-value upward/downward ranks of HEFT/CPOP and
//!   CPOP's critical-path extraction (Algorithm 2 lines 2–13).
//! * [`minexec`] — the "every task on its fastest processor, zero comm"
//!   critical path that §3 of the paper proposes as a better simple
//!   baseline.
//! * [`cpmin`] — `CP_MIN`, the minimum-computation critical path used as
//!   the SLR denominator (eq. 9).
//! * [`exact`] — exponential brute-force oracles for tiny graphs
//!   (duplication-allowed vs no-duplication critical paths, §4.1).
//! * [`workspace`] — the reusable scratch arena every algorithm above (and
//!   the list schedulers in [`crate::sched`]) borrows its transient buffers
//!   from, making the steady-state hot path allocation-free.

pub mod ceft;
pub mod exact;
pub mod cpmin;
pub mod minexec;
pub mod ranks;
pub mod workspace;

pub use workspace::{Workspace, WorkspacePool};
