//! Mean-value ranking functions and CPOP's critical path (Algorithm 2,
//! lines 2–13).
//!
//! HEFT and CPOP scalarise the heterogeneous cost structure up front:
//! each task gets its *average* execution cost over classes, each edge its
//! *average* communication cost over distinct class pairs. The paper's
//! central claim is that the critical paths extracted from these averages
//! are misleading once heterogeneity is real; this module implements the
//! averaging machinery faithfully so the comparison is fair.

use crate::cp::workspace::Workspace;
use crate::graph::TaskGraph;
use crate::model::{CostMatrix, InstanceRef};

/// Relative epsilon used when testing `priority(t) == |CP|` (floating-point
/// equality of sums of identical terms — exact in theory, guarded anyway).
const PRIO_EPS: f64 = 1e-9;

/// Upward rank: `rank_u(t) = w̄(t) + max_{s ∈ succ(t)} ( c̄(t,s) + rank_u(s) )`.
pub fn rank_upward(inst: InstanceRef) -> Vec<f64> {
    let mut rank = Vec::new();
    rank_upward_into(inst, &mut rank);
    rank
}

/// [`rank_upward`] into a caller-owned (typically workspace-owned) buffer —
/// no allocation once the buffer has reached the instance size.
pub fn rank_upward_into(inst: InstanceRef, rank: &mut Vec<f64>) {
    let graph = inst.graph;
    let platform = inst.platform;
    let costs = inst.costs;
    let v = inst.n();
    rank.clear();
    rank.resize(v, 0.0);
    for &t in graph.topo_order().iter().rev() {
        let mut best = 0f64;
        for &(s, data) in graph.succs(t) {
            best = best.max(platform.mean_comm_cost(data) + rank[s]);
        }
        rank[t] = costs.mean(t) + best;
    }
}

/// Downward rank: `rank_d(t) = max_{k ∈ pred(t)} ( rank_d(k) + w̄(k) + c̄(k,t) )`,
/// zero for entry tasks.
pub fn rank_downward(inst: InstanceRef) -> Vec<f64> {
    let mut rank = Vec::new();
    rank_downward_into(inst, &mut rank);
    rank
}

/// [`rank_downward`] into a caller-owned buffer.
pub fn rank_downward_into(inst: InstanceRef, rank: &mut Vec<f64>) {
    let graph = inst.graph;
    let platform = inst.platform;
    let costs = inst.costs;
    let v = inst.n();
    rank.clear();
    rank.resize(v, 0.0);
    for &t in graph.topo_order() {
        let mut best = 0f64;
        let mut any = false;
        for &(k, data) in graph.preds(t) {
            any = true;
            best = best.max(rank[k] + costs.mean(k) + platform.mean_comm_cost(data));
        }
        rank[t] = if any { best } else { 0.0 };
    }
}

/// CPOP's scheduling priorities: fill `ws.up`, `ws.down` and
/// `ws.prio = rank_u + rank_d` (Algorithm 2 lines 2–4). The single
/// definition shared by the CPOP/CEFT-CPOP schedulers and the batch
/// harness, so the priority formula cannot drift between them.
pub fn cpop_priorities_into(ws: &mut Workspace, inst: InstanceRef) {
    rank_upward_into(inst, &mut ws.up);
    rank_downward_into(inst, &mut ws.down);
    ws.prio.clear();
    ws.prio.extend(ws.up.iter().zip(&ws.down).map(|(u, d)| u + d));
}

/// CPOP's critical path (Algorithm 2 lines 5–12): `priority = rank_u +
/// rank_d`; `|CP| = priority(entry)`; walk from the entry picking the
/// successor whose priority equals `|CP|`.
///
/// Returns `(cp_tasks, cp_length_estimate)` where the estimate is `|CP|`,
/// CPOP's mean-value critical-path length — the CPL the paper compares CEFT
/// against in Table 3.
///
/// Graphs with multiple entries take the max-priority entry (the paper's
/// generators produce single-entry graphs; MD does not, so we generalise the
/// same way `rank_d` does).
pub fn cpop_critical_path(inst: InstanceRef) -> (Vec<usize>, f64) {
    let up = rank_upward(inst);
    let down = rank_downward(inst);
    cpop_critical_path_from_ranks(inst.graph, &up, &down)
}

/// CP extraction from precomputed ranks (shared with the CEFT-ranked
/// variants in §8.2).
pub fn cpop_critical_path_from_ranks(
    graph: &TaskGraph,
    up: &[f64],
    down: &[f64],
) -> (Vec<usize>, f64) {
    let prio: Vec<f64> = up.iter().zip(down).map(|(u, d)| u + d).collect();
    let mut set = Vec::new();
    let cp_len = cpop_cp_from_priorities(graph, &prio, &mut set);
    (set, cp_len)
}

/// The Algorithm-2 critical-path walk over precomputed `rank_u + rank_d`
/// priorities, written into a caller-owned buffer. Returns `|CP|` (the
/// entry task's priority). Allocation-free: entry selection iterates the
/// task range directly instead of collecting `graph.sources()`, taking the
/// *last* max-priority source — the same element `Iterator::max_by`
/// returned over the ascending sources list.
pub fn cpop_cp_from_priorities(graph: &TaskGraph, prio: &[f64], out: &mut Vec<usize>) -> f64 {
    let v = graph.num_tasks();
    assert_eq!(prio.len(), v);
    let mut entry: Option<usize> = None;
    for t in 0..v {
        if graph.in_degree(t) != 0 {
            continue;
        }
        match entry {
            Some(e) if prio[t] < prio[e] => {}
            _ => entry = Some(t),
        }
    }
    let entry = entry.expect("graph has sources");
    let cp_len = prio[entry];
    out.clear();
    out.push(entry);
    let mut t = entry;
    while graph.out_degree(t) > 0 {
        // successor with priority == |CP| (relative epsilon); fall back to
        // the max-priority successor if float drift breaks exact equality
        let mut chosen = None;
        let mut fallback = graph.succs(t)[0].0;
        for &(s, _) in graph.succs(t) {
            if prio[s] > prio[fallback] {
                fallback = s;
            }
            let eq = (prio[s] - cp_len).abs() <= PRIO_EPS * cp_len.abs().max(1.0);
            if eq && chosen.is_none() {
                chosen = Some(s);
            }
        }
        t = chosen.unwrap_or(fallback);
        out.push(t);
    }
    cp_len
}

/// The processor that minimises the critical path's total execution time
/// when the whole path is placed on it (Algorithm 2 line 13).
pub fn cpop_cp_processor(cp: &[usize], costs: &CostMatrix) -> usize {
    let p = costs.p();
    let mut best = 0usize;
    let mut best_sum = f64::INFINITY;
    for j in 0..p {
        let sum: f64 = cp.iter().map(|&t| costs.get(t, j)).sum();
        if sum < best_sum {
            best_sum = sum;
            best = j;
        }
    }
    best
}

/// Realised length of CPOP's critical path: the path's tasks executed
/// back-to-back on the single chosen processor (zero internal comm).
pub fn cpop_realized_cp_length(cp: &[usize], costs: &CostMatrix) -> f64 {
    let j = cpop_cp_processor(cp, costs);
    cp.iter().map(|&t| costs.get(t, j)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskGraph;
    use crate::model::CostMatrix;
    use crate::platform::Platform;

    fn chain3() -> (TaskGraph, Platform, CostMatrix) {
        let g = TaskGraph::from_edges(3, &[(0, 1, 10.0), (1, 2, 20.0)]);
        let plat = Platform::uniform(2, 1.0, 0.0);
        // means: 2, 4, 6
        let comp = CostMatrix::new(2, vec![1.0, 3.0, 3.0, 5.0, 5.0, 7.0]);
        (g, plat, comp)
    }

    #[test]
    fn rank_u_on_chain() {
        let (g, plat, comp) = chain3();
        let up = rank_upward(InstanceRef::new(&g, &plat, &comp));
        // rank_u(2)=6; rank_u(1)=4+20+6=30; rank_u(0)=2+10+30=42
        assert_eq!(up, vec![42.0, 30.0, 6.0]);
    }

    #[test]
    fn rank_d_on_chain() {
        let (g, plat, comp) = chain3();
        let down = rank_downward(InstanceRef::new(&g, &plat, &comp));
        // rank_d(0)=0; rank_d(1)=0+2+10=12; rank_d(2)=12+4+20=36
        assert_eq!(down, vec![0.0, 12.0, 36.0]);
    }

    #[test]
    fn priority_constant_along_cp() {
        let (g, plat, comp) = chain3();
        let (cp, len) = cpop_critical_path(InstanceRef::new(&g, &plat, &comp));
        assert_eq!(cp, vec![0, 1, 2]);
        assert_eq!(len, 42.0);
    }

    #[test]
    fn cp_walks_the_heavy_branch() {
        // 0 -> 1 -> 3 and 0 -> 2 -> 3; task 2 much heavier on average
        let g = TaskGraph::from_edges(
            4,
            &[(0, 1, 1.0), (0, 2, 1.0), (1, 3, 1.0), (2, 3, 1.0)],
        );
        let plat = Platform::uniform(2, 1.0, 0.0);
        #[rustfmt::skip]
        let comp = CostMatrix::new(2, vec![
            2.0, 2.0,
            1.0, 1.0,
            50.0, 50.0,
            2.0, 2.0,
        ]);
        let (cp, _) = cpop_critical_path(InstanceRef::new(&g, &plat, &comp));
        assert_eq!(cp, vec![0, 2, 3]);
    }

    #[test]
    fn cp_processor_minimises_sum() {
        let comp = CostMatrix::new(2, vec![
            1.0, 10.0, //
            1.0, 10.0, //
            1.0, 10.0,
        ]);
        assert_eq!(cpop_cp_processor(&[0, 1, 2], &comp), 0);
        assert_eq!(cpop_realized_cp_length(&[0, 1, 2], &comp), 3.0);
    }

    #[test]
    fn multi_entry_uses_max_priority_entry() {
        // two entries: 0 (light) and 1 (heavy) both -> 2
        let g = TaskGraph::from_edges(3, &[(0, 2, 1.0), (1, 2, 1.0)]);
        let plat = Platform::uniform(1, 1.0, 0.0);
        let comp = CostMatrix::new(1, vec![1.0, 50.0, 2.0]);
        let (cp, len) = cpop_critical_path(InstanceRef::new(&g, &plat, &comp));
        assert_eq!(cp, vec![1, 2]);
        assert_eq!(len, 52.0);
    }

    #[test]
    fn single_class_ranks_are_exact_longest_paths() {
        // with P=1 the mean is the true cost: rank_u(entry) = true CP length
        let g = TaskGraph::from_edges(
            4,
            &[(0, 1, 5.0), (0, 2, 1.0), (1, 3, 5.0), (2, 3, 1.0)],
        );
        let plat = Platform::uniform(1, 1.0, 0.0);
        let comp = CostMatrix::new(1, vec![1.0, 2.0, 3.0, 4.0]);
        let up = rank_upward(InstanceRef::new(&g, &plat, &comp));
        // P=1 => mean comm = 0 (co-located), path = node weights only
        assert_eq!(up[0], 1.0 + 3.0 + 4.0);
    }
}
