//! Exact (exponential) critical-path oracles for tiny graphs.
//!
//! §4.1 of the paper: when task duplication is allowed, Algorithm 1's
//! critical path is exact; without duplication the problem is equivalent to
//! PBQP and NP-complete, and CEFT "may result in an overly-optimistic
//! critical path length"… but also — because the DP's `max` over parents is
//! taken per sink class — the Algorithm-1 value can sit *above* the
//! per-path-isolated optimum. These oracles pin both effects down by brute
//! force so tests can quantify them:
//!
//! * [`exact_path_isolated`] — `max` over entry→exit paths of the path's
//!   optimal assignment cost (each path assigned independently; equivalent
//!   to allowing duplication of shared ancestors).
//! * [`exact_no_duplication`] — `min` over *global* assignments (every task
//!   gets exactly one class) of the longest realized path — the
//!   NP-complete quantity.
//!
//! Both are exponential (`O(paths · P^len)` and `O(P^v)`) and guarded to
//! tiny sizes; they exist for validation, not production.

use crate::graph::TaskGraph;
use crate::model::InstanceRef;

/// Maximum tasks accepted by [`exact_no_duplication`].
pub const MAX_EXACT_TASKS: usize = 16;

/// Optimal assignment cost of one explicit path (min over per-task class
/// choices of exec + comm along the chain). `O(len · P²)` by chain DP —
/// exact because a chain has no shared structure.
pub fn path_cost(inst: InstanceRef, path: &[usize]) -> f64 {
    crate::cp::ceft::chain_optimal_length(inst, path)
}

fn enumerate_paths(
    graph: &TaskGraph,
    t: usize,
    cur: &mut Vec<usize>,
    out: &mut Vec<Vec<usize>>,
    cap: usize,
) {
    cur.push(t);
    if graph.out_degree(t) == 0 {
        out.push(cur.clone());
    } else {
        for &(s, _) in graph.succs(t) {
            if out.len() >= cap {
                break;
            }
            enumerate_paths(graph, s, cur, out, cap);
        }
    }
    cur.pop();
}

/// All entry→exit paths (capped; panics past `cap` to catch misuse).
pub fn all_paths(graph: &TaskGraph, cap: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    for s in graph.sources() {
        let mut cur = Vec::new();
        enumerate_paths(graph, s, &mut cur, &mut out, cap);
    }
    assert!(out.len() < cap, "path explosion: graph too large for exact oracle");
    out
}

/// The per-path-isolated critical measure: `max` over paths of the path's
/// own optimal assignment cost. Equals the duplication-allowed critical
/// path of §4.1.
pub fn exact_path_isolated(inst: InstanceRef) -> f64 {
    all_paths(inst.graph, 100_000)
        .iter()
        .map(|p| path_cost(inst, p))
        .fold(f64::NEG_INFINITY, f64::max)
}

/// The no-duplication exact critical path: `min` over global assignments of
/// the longest realized path under that assignment. `O(P^v · e)` — only for
/// `v <= MAX_EXACT_TASKS`.
pub fn exact_no_duplication(inst: InstanceRef) -> f64 {
    let graph = inst.graph;
    let platform = inst.platform;
    let costs = inst.costs;
    let v = inst.n();
    let p = inst.p();
    assert!(
        v <= MAX_EXACT_TASKS,
        "exact_no_duplication limited to {MAX_EXACT_TASKS} tasks"
    );
    let mut assign = vec![0usize; v];
    let mut best = f64::INFINITY;
    let mut dist = vec![0f64; v];
    loop {
        // longest realized path under this assignment
        let mut longest: f64 = 0.0;
        for &t in graph.topo_order() {
            let mut d: f64 = 0.0;
            for &(k, data) in graph.preds(t) {
                d = d.max(dist[k] + platform.comm_cost(assign[k], assign[t], data));
            }
            dist[t] = d + costs.get(t, assign[t]);
            longest = longest.max(dist[t]);
        }
        best = best.min(longest);
        // next assignment (odometer)
        let mut i = 0;
        loop {
            if i == v {
                return best;
            }
            assign[i] += 1;
            if assign[i] < p {
                break;
            }
            assign[i] = 0;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::ceft::find_critical_path;
    use crate::model::CostMatrix;
    use crate::platform::Platform;
    use crate::util::rng::Xoshiro256;

    fn random_tiny(
        rng: &mut Xoshiro256,
        v: usize,
        p: usize,
    ) -> (TaskGraph, Platform, CostMatrix) {
        // random layered DAG on <= v tasks
        let mut edges = Vec::new();
        for t in 1..v {
            let parent = rng.below(t);
            edges.push((parent, t, rng.uniform(0.0, 10.0)));
            if rng.chance(0.5) && t >= 2 {
                let p2 = rng.below(t);
                if p2 != parent {
                    edges.push((p2, t, rng.uniform(0.0, 10.0)));
                }
            }
        }
        let g = TaskGraph::from_edges(v, &edges);
        let plat = Platform::uniform(p, rng.uniform(0.5, 2.0), rng.uniform(0.0, 0.5));
        let comp =
            CostMatrix::new(p, (0..v * p).map(|_| rng.uniform(1.0, 20.0)).collect());
        (g, plat, comp)
    }

    /// §4.1 quantified: the isolated (duplication-allowed) measure lower-
    /// bounds the no-duplication optimum, and Algorithm 1 sits at or above
    /// the isolated measure (its per-sink-class max can only add).
    #[test]
    fn ordering_isolated_leq_noduplication_and_ceft() {
        let mut rng = Xoshiro256::new(404);
        for _ in 0..30 {
            let (g, plat, comp) = random_tiny(&mut rng, 8, 2);
            let inst = InstanceRef::new(&g, &plat, &comp);
            let iso = exact_path_isolated(inst);
            let nodup = exact_no_duplication(inst);
            let ceft = find_critical_path(inst).length;
            assert!(
                iso <= nodup + 1e-9,
                "isolated {iso} > no-dup {nodup} (duplication can only help)"
            );
            assert!(
                ceft >= iso - 1e-9,
                "Algorithm 1 value {ceft} below isolated measure {iso}"
            );
        }
    }

    /// On chains all three coincide exactly.
    #[test]
    fn chain_all_measures_equal() {
        let mut rng = Xoshiro256::new(7);
        for _ in 0..20 {
            let v = rng.range_inclusive(2, 8);
            let edges: Vec<(usize, usize, f64)> = (0..v - 1)
                .map(|i| (i, i + 1, rng.uniform(0.0, 10.0)))
                .collect();
            let g = TaskGraph::from_edges(v, &edges);
            let plat = Platform::uniform(3, 1.0, 0.0);
            let comp =
                CostMatrix::new(3, (0..v * 3).map(|_| rng.uniform(1.0, 20.0)).collect());
            let inst = InstanceRef::new(&g, &plat, &comp);
            let iso = exact_path_isolated(inst);
            let nodup = exact_no_duplication(inst);
            let ceft = find_critical_path(inst).length;
            assert!((iso - nodup).abs() < 1e-9);
            assert!((iso - ceft).abs() < 1e-9);
        }
    }

    /// The diamond from §4.1 / Figure 1: a shared parent whose two children
    /// prefer different classes. With enormous payloads the no-duplication
    /// optimum exceeds the isolated measure — duplication has real value.
    #[test]
    fn duplication_gap_is_realisable() {
        // 0 -> 1, 0 -> 2 (huge payloads), 1 -> 3, 2 -> 3 (free)
        let g = TaskGraph::from_edges(
            4,
            &[(0, 1, 1000.0), (0, 2, 1000.0), (1, 3, 0.0), (2, 3, 0.0)],
        );
        let plat = Platform::uniform(2, 1.0, 0.0);
        #[rustfmt::skip]
        let comp = CostMatrix::new(2, vec![
            1.0, 1.0,   // shared parent: either class
            1.0, 500.0, // child 1 needs class 0
            500.0, 1.0, // child 2 needs class 1
            1.0, 1.0,
        ]);
        let inst = InstanceRef::new(&g, &plat, &comp);
        let iso = exact_path_isolated(inst);
        let nodup = exact_no_duplication(inst);
        // isolated: each chain co-locates parent with its child: ~1+1+1 per
        // chain -> max ~3ish + sink. no-dup: parent committed to ONE class,
        // so one chain pays the 1000 payload.
        assert!(
            nodup > iso + 400.0,
            "expected a large duplication gap: iso={iso} nodup={nodup}"
        );
    }

    #[test]
    fn all_paths_counts_diamond() {
        let g = TaskGraph::from_edges(
            4,
            &[(0, 1, 1.0), (0, 2, 1.0), (1, 3, 1.0), (2, 3, 1.0)],
        );
        assert_eq!(all_paths(&g, 100).len(), 2);
    }

    #[test]
    #[should_panic(expected = "limited to")]
    fn exact_guard_trips() {
        let g = TaskGraph::from_edges(17, &(0..16).map(|i| (i, i + 1, 0.0)).collect::<Vec<_>>());
        let plat = Platform::uniform(2, 1.0, 0.0);
        let comp = CostMatrix::new(2, vec![1.0; 17 * 2]);
        exact_no_duplication(InstanceRef::new(&g, &plat, &comp));
    }
}
