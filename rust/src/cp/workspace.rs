//! `Workspace` — the reusable scratch arena of the algorithm core.
//!
//! Every algorithm in [`crate::cp`] and [`crate::sched`] is a dense sweep
//! over `O(v)` / `O(v × P)` arrays, yet the seed code re-allocated those
//! arrays (DP tables, rank vectors, in-degree counters, ready heaps, busy
//! lists, pin maps) on every invocation. For the batch harness that cost is
//! noise; for the online service it is allocator traffic on *every request*,
//! even a memo-cache miss for a graph shape seen thousands of times.
//!
//! A [`Workspace`] owns all of those transient buffers. The workspace-aware
//! entry points (`cp::ceft::find_critical_path_with`,
//! `sched::list_schedule_with`, `sched::Algorithm::run_with`, …) borrow one
//! and size each buffer with `clear()` + `resize()` at entry:
//!
//! * capacity grows monotonically to the high-water mark of the largest
//!   instance the workspace has served, so steady-state serving performs
//!   **zero heap allocation** in the algorithm core — the only allocations
//!   left on the hot path are the returned result objects themselves
//!   ([`CriticalPath`](crate::cp::ceft::CriticalPath) /
//!   [`Schedule`](crate::sched::Schedule)), which outlive the workspace;
//! * every entry point fully re-initialises the prefix it reads, so a dirty
//!   workspace from a larger instance can never leak state into a smaller
//!   one (enforced by `rust/tests/workspace.rs`).
//!
//! Outputs are bit-identical whether a workspace is fresh, reused, or
//! absent (the classic allocating signatures remain as one-shot wrappers):
//! the deterministic tie-breaking of [`crate::cp::ceft`] is load-bearing
//! for the service memo caches and the batch/online equivalence guarantee,
//! and the equivalence property tests enforce it.
//!
//! Sharing model: a workspace is plain mutable state — one per worker, not
//! one per engine. [`WorkspacePool`] hands long-lived workspaces to
//! concurrent workers (the service engine keeps one pool for its request
//! threads); warmed-up serving re-uses the same arenas forever, while the
//! pool's idle cap keeps retained scratch bounded by
//! `workers × high-water instance size` even under connection bursts.

use crate::cp::ceft::PathStep;
use crate::util::aligned::AlignedVec;
use std::collections::BinaryHeap;
use std::sync::Mutex;

/// Ready-queue entry of the list scheduler: max-heap by priority, ties
/// broken toward the **lowest** task id (the determinism contract of
/// [`crate::sched::list_schedule`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReadyEntry {
    /// scheduling priority (higher pops first)
    pub prio: f64,
    /// task id (lower pops first among equal priorities)
    pub task: usize,
}

impl Eq for ReadyEntry {}

impl PartialOrd for ReadyEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ReadyEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.prio
            .total_cmp(&other.prio)
            .then_with(|| other.task.cmp(&self.task))
    }
}

/// The reusable scratch arena. Fields are public scratch buffers with **no
/// inter-call contract**: any entry point may overwrite any of them, and
/// their contents between calls are unspecified. Callers that need two
/// buffers alive at once borrow disjoint fields (the workspace-aware
/// algorithms do exactly that internally).
#[derive(Debug, Default)]
pub struct Workspace {
    /// CEFT DP values, `v × P` row-major (`cp::ceft::ceft_table_into`).
    /// 32-byte aligned ([`AlignedVec`]) so the SIMD lanes' parent-row loads
    /// never straddle a cache line.
    pub table: AlignedVec,
    /// CEFT DP backpointers, aligned with `table`
    pub backptr: Vec<(usize, usize)>,
    /// upward-rank sweep output (`cp::ranks::rank_upward_into`)
    pub up: Vec<f64>,
    /// downward-rank sweep output (`cp::ranks::rank_downward_into`)
    pub down: Vec<f64>,
    /// per-task scheduling priorities consumed by `sched::list_schedule_with`
    pub prio: Vec<f64>,
    /// longest-path distances (`cp::cpmin`, `cp::minexec`)
    pub dist: Vec<f64>,
    /// longest-path predecessor links (`cp::minexec`)
    pub pred: Vec<Option<usize>>,
    /// remaining in-degree per task (list-scheduler ready tracking)
    pub indeg: Vec<usize>,
    /// the reusable ready heap of the list scheduler
    pub heap: BinaryHeap<ReadyEntry>,
    /// busy intervals per processor, each kept sorted by start time
    pub busy: Vec<Vec<(f64, f64)>>,
    /// actual finish time per scheduled task
    pub aft: Vec<f64>,
    /// processor per scheduled task
    pub proc_of: Vec<usize>,
    /// scheduled-yet flag per task
    pub scheduled: Vec<bool>,
    /// dense critical-path pin table: `pins[t] = Some(class)` pins task `t`
    pub pins: Vec<Option<usize>>,
    /// critical-path backtracking scratch (reverse order)
    pub steps: Vec<PathStep>,
    /// critical-path task-id scratch (`cp::ranks::cpop_cp_from_priorities`)
    pub cp_tasks: Vec<usize>,
    /// destination-major `P × P` startup panel of the CEFT min-plus kernel:
    /// row `j` holds `startup[l]` for every sender class `l != j` and `0.0`
    /// on the diagonal (co-located communication is free, Definition 3).
    /// Only the **fallback** path fills this: instances bound through a
    /// [`crate::model::PlatformCtx`] read the context's resident panels
    /// instead — see EXPERIMENTS.md §Platform contexts. Aligned like the
    /// resident panels so both sources feed the SIMD lanes identically.
    pub panel_startup: AlignedVec,
    /// destination-major `P × P` bandwidth panel, aligned with
    /// `panel_startup`: row `j` holds `bandwidth[l → j]` for `l != j` and
    /// `+inf` on the diagonal so `data / bw` contributes exactly `0.0` —
    /// keeping the kernel branch-free yet bit-identical to
    /// `Platform::comm_cost`. Fallback-only, like `panel_startup`.
    pub panel_bw: AlignedVec,
    /// batched min-plus kernel scratch: gathered parent CEFT rows,
    /// `B × P` row-major (`cp::ceft::ceft_table_batched_into`)
    pub batch_rows: AlignedVec,
    /// batched kernel scratch: per-row edge payloads, aligned with
    /// `batch_rows`
    pub batch_data: Vec<f64>,
    /// batched kernel output scratch: `B × P` per-(row, destination) minima
    pub batch_vals: AlignedVec,
    /// batched kernel output scratch: argmin sender class per cell,
    /// aligned with `batch_vals`
    pub batch_args: Vec<usize>,
    /// gathered multi-instance DP scratch: per-round segment bookkeeping
    /// `(instance, task, pred_count)` for the scatter pass
    /// (`cp::ceft::find_critical_paths_gathered`,
    /// `cp::ceft::find_ceft_tables_gathered`)
    pub gather_seg: Vec<(usize, usize, usize)>,
    /// delta-CEFT change-propagation flags: `row_changed[t]` marks a task
    /// whose recomputed row differs bit-wise from the basis table, so its
    /// swept children cannot reuse their basis rows
    /// (`cp::ceft::ceft_table_delta_into`)
    pub row_changed: Vec<bool>,
    /// slack backward pass scratch: the `v × P` max-fold arrival rows
    /// `m(u, j) = CEFT(u, j) − C_comp(u, j)`, rebuilt with the kernel's
    /// exact comparison sequence (`cp::ceft::slack_from_table_with`)
    pub slack_m: AlignedVec,
    /// per-task slack output scratch (`cp::ceft::slack_from_table_with`)
    pub slack: Vec<f64>,
}

impl Workspace {
    /// Fresh, empty workspace. Buffers allocate lazily on first use and
    /// then grow monotonically to the high-water instance size.
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset every buffer to length zero **without releasing capacity**.
    ///
    /// O(dirty): element types are `Copy` (truncation is a length store)
    /// except the per-processor busy rows, which are cleared individually
    /// so their capacities survive. Calling this between requests is
    /// optional hygiene — every workspace-aware entry point re-initialises
    /// the exact prefix it reads regardless.
    pub fn clear(&mut self) {
        self.table.clear();
        self.backptr.clear();
        self.up.clear();
        self.down.clear();
        self.prio.clear();
        self.dist.clear();
        self.pred.clear();
        self.indeg.clear();
        self.heap.clear();
        for row in &mut self.busy {
            row.clear();
        }
        self.aft.clear();
        self.proc_of.clear();
        self.scheduled.clear();
        self.pins.clear();
        self.steps.clear();
        self.cp_tasks.clear();
        self.panel_startup.clear();
        self.panel_bw.clear();
        self.batch_rows.clear();
        self.batch_data.clear();
        self.batch_vals.clear();
        self.batch_args.clear();
        self.gather_seg.clear();
        self.row_changed.clear();
        self.slack_m.clear();
        self.slack.clear();
    }

    /// Total `f64`-equivalent capacity across the major buffers — a rough
    /// high-water-mark gauge for stats and tests.
    pub fn capacity_hint(&self) -> usize {
        self.table.capacity()
            + self.backptr.capacity()
            + self.prio.capacity()
            + self.busy.iter().map(|r| r.capacity()).sum::<usize>()
    }
}

/// A pool of long-lived workspaces for concurrent workers.
///
/// `with` checks a workspace out (creating one only when every existing
/// workspace is in use), runs the closure, and returns it to the free
/// list. At steady state the pool holds one warmed workspace per
/// peak-concurrent worker and `with` allocates nothing.
///
/// The free list is capped at `max_idle` ([`WorkspacePool::bounded`]):
/// a burst of concurrency beyond it still gets transient workspaces, but
/// on check-in the extras are dropped instead of pinning their
/// high-water-mark capacity for the process lifetime. Workers beyond
/// `max_idle` cannot run concurrently on `max_idle` cores anyway, so the
/// cap does not cost steady-state throughput.
#[derive(Debug)]
pub struct WorkspacePool {
    free: Mutex<Vec<Workspace>>,
    created: std::sync::atomic::AtomicUsize,
    max_idle: usize,
}

impl Default for WorkspacePool {
    fn default() -> Self {
        Self {
            free: Mutex::new(Vec::new()),
            created: std::sync::atomic::AtomicUsize::new(0),
            max_idle: usize::MAX,
        }
    }
}

impl WorkspacePool {
    /// Empty pool with an unbounded free list (suitable when the caller
    /// already bounds concurrency, e.g. a fixed worker pool).
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty pool retaining at most `max_idle` idle workspaces; returned
    /// workspaces beyond the cap are dropped.
    pub fn bounded(max_idle: usize) -> Self {
        Self {
            max_idle: max_idle.max(1),
            ..Self::default()
        }
    }

    /// Run `f` with a pooled workspace. On return the workspace is
    /// [`cleared`](Workspace::clear) — O(dirty), capacity kept — and
    /// checked back in (or dropped, past the `max_idle` cap), so reuse is
    /// allocation-free once the high-water mark is reached. (Entry points
    /// re-initialise what they read regardless; clearing is hygiene, not
    /// correctness.)
    ///
    /// Unwind-safe: check-in happens in a drop guard, so a panicking `f`
    /// (the service engine deliberately routes algorithm panics through
    /// here and rethrows them) still returns the warm workspace to the
    /// pool instead of leaking it and skewing the `created()` high-water
    /// stat.
    pub fn with<R>(&self, f: impl FnOnce(&mut Workspace) -> R) -> R {
        /// Returns the workspace to the pool on drop — normal return and
        /// unwind alike.
        struct CheckIn<'a> {
            pool: &'a WorkspacePool,
            ws: Option<Workspace>,
        }
        impl Drop for CheckIn<'_> {
            fn drop(&mut self) {
                if let Some(mut ws) = self.ws.take() {
                    ws.clear(); // O(dirty), outside the lock
                    // `if let Ok` instead of unwrap: never double-panic in
                    // a drop that may already be running during an unwind
                    if let Ok(mut free) = self.pool.free.lock() {
                        if free.len() < self.pool.max_idle {
                            free.push(ws);
                        }
                    }
                }
            }
        }
        let ws = self.free.lock().unwrap().pop().unwrap_or_else(|| {
            self.created.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            Workspace::new()
        });
        let mut guard = CheckIn {
            pool: self,
            ws: Some(ws),
        };
        f(guard.ws.as_mut().expect("workspace checked out above"))
    }

    /// Number of workspaces ever created — the concurrency high-water mark
    /// (over-capacity bursts create transient workspaces that also count).
    pub fn created(&self) -> usize {
        self.created.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Number of workspaces currently checked in (idle).
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ready_entry_orders_by_priority_then_low_task() {
        let mut heap = BinaryHeap::new();
        heap.push(ReadyEntry { prio: 1.0, task: 7 });
        heap.push(ReadyEntry { prio: 2.0, task: 9 });
        heap.push(ReadyEntry { prio: 2.0, task: 3 });
        heap.push(ReadyEntry { prio: 0.5, task: 0 });
        let order: Vec<usize> = std::iter::from_fn(|| heap.pop().map(|e| e.task)).collect();
        assert_eq!(order, vec![3, 9, 7, 0]);
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut ws = Workspace::new();
        ws.table.resize(1024, 0.0);
        ws.busy.push(Vec::with_capacity(64));
        ws.heap.push(ReadyEntry { prio: 1.0, task: 0 });
        let cap_before = ws.table.capacity();
        ws.clear();
        assert!(ws.table.is_empty());
        assert!(ws.heap.is_empty());
        assert_eq!(ws.table.capacity(), cap_before);
        assert_eq!(ws.busy.len(), 1, "busy rows survive clear");
        assert!(ws.busy[0].capacity() >= 64);
    }

    #[test]
    fn pool_reuses_and_counts_high_water() {
        let pool = WorkspacePool::new();
        pool.with(|ws| ws.table.resize(100, 0.0));
        assert_eq!(pool.created(), 1);
        assert_eq!(pool.idle(), 1);
        // sequential reuse does not create a second workspace
        pool.with(|ws| assert!(ws.table.capacity() >= 100));
        assert_eq!(pool.created(), 1);
        // concurrent checkout does
        pool.with(|_a| {
            pool.with(|_b| {});
        });
        assert_eq!(pool.created(), 2);
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn panicking_closure_still_checks_workspace_back_in() {
        let pool = WorkspacePool::new();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.with(|ws| {
                ws.table.resize(64, 0.0);
                // conditional so the closure's return type stays `()`
                // without tripping the unreachable-code lint
                if ws.table.len() == 64 {
                    panic!("boom");
                }
            })
        }));
        assert!(result.is_err(), "the panic must propagate");
        assert_eq!(pool.created(), 1);
        assert_eq!(pool.idle(), 1, "workspace must survive a panicking closure");
        // the survivor was cleared and is reused, not replaced
        pool.with(|ws| assert!(ws.table.is_empty()));
        assert_eq!(pool.created(), 1);
    }

    #[test]
    fn bounded_pool_drops_over_capacity_workspaces() {
        let pool = WorkspacePool::bounded(1);
        // nested checkouts force a second workspace into existence …
        pool.with(|_a| {
            pool.with(|_b| {
                pool.with(|_c| {});
            });
        });
        assert_eq!(pool.created(), 3);
        // … but only max_idle survive check-in
        assert_eq!(pool.idle(), 1);
        pool.with(|_a| {});
        assert_eq!(pool.created(), 3, "idle workspace is reused");
        assert_eq!(pool.idle(), 1);
    }
}
