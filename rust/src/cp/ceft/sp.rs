//! Structured CEFT: the tree DP over a series-parallel decomposition.
//!
//! For a recognizer-accepted graph (`crate::graph::shape`), the CEFT
//! table factors along the [`SpTree`]: **series** composition applies one
//! `P×P` min-plus panel product per hop (the parent's CEFT row against the
//! resident startup/bandwidth panels — exactly the general kernel's
//! [`LaneKernel::min_plus_row`] scan), and **parallel** composition is an
//! element-wise max over the branches meeting at a join (the general
//! kernel's strict-`>` CSR-order max-fold, which is precisely how
//! branches' series products combine at the join vertex). The sweep visits
//! tasks in the tree-derived order ([`SpTree::order`]) — no frontier
//! bookkeeping, linear memory traffic, and the dominant in-degree-1 hops
//! skip the predecessor fold entirely.
//!
//! ## Bit-identity argument
//!
//! Each task's table/backpointer row is a deterministic function of its
//! parents' rows alone, folded in CSR predecessor order — independent of
//! which valid topological order the sweep visits tasks in. This kernel
//! changes *only* the visit order (the SP tree order is topological —
//! proof in `graph::shape`) and specializes the in-degree-1 case, whose
//! single fold step `best > NEG_INFINITY` is reproduced verbatim; joins
//! run the general kernel's tiled block loop unchanged, same
//! [`LaneKernel`] scans, same panels, same float ops in the same order.
//! Values, argmins and backpointers are therefore bit-identical to
//! [`super::ceft_table_with`] by construction —
//! `prop_sp_tree_dp_bit_identical_to_general` (rust/tests/properties.rs)
//! enforces it across orientations, dispatches and class counts, with the
//! scalar recurrence retained as the oracle.
//!
//! Telemetry attributes this path to `sp_tree`
//! ([`crate::obs::KernelPath::SpTree`]); the engine's miss path routes
//! here per interned shape verdict (`service::engine`).

use super::simd::{KernelDispatch, LaneKernel, ScalarLanes, SimdLanes};
use super::{CeftTable, KERNEL_BLOCK};
use crate::cp::workspace::Workspace;
use crate::graph::shape::SpTree;
use crate::model::{fill_comm_panels, InstanceRef};

/// Fill `ws.table` / `ws.backptr` with the forward CEFT DP swept in
/// `sp`'s tree order. `sp` must decompose `inst.graph` (the engine
/// guarantees this by recognizing at intern time; a stale tree would panic
/// on the order-length debug assert or index out of bounds — edits always
/// re-verify or demote first).
pub fn ceft_table_sp_into(ws: &mut Workspace, inst: InstanceRef, sp: &SpTree) {
    match super::dispatch_for(&inst) {
        KernelDispatch::Simd => ceft_sp_kernel_lanes::<SimdLanes>(ws, inst, sp, false),
        KernelDispatch::Scalar => ceft_sp_kernel_lanes::<ScalarLanes>(ws, inst, sp, false),
    }
}

/// Reverse-orientation variant of [`ceft_table_sp_into`]: the transpose DP
/// (successors as parents), swept in reversed tree order — the reverse of
/// a topological order is topological for the transpose, so
/// [`super::ceft_table_rev_into`] consumers (CEFT-HEFT-UP's upward rank)
/// work unchanged.
pub fn ceft_table_sp_rev_into(ws: &mut Workspace, inst: InstanceRef, sp: &SpTree) {
    match super::dispatch_for(&inst) {
        KernelDispatch::Simd => ceft_sp_kernel_lanes::<SimdLanes>(ws, inst, sp, true),
        KernelDispatch::Scalar => ceft_sp_kernel_lanes::<ScalarLanes>(ws, inst, sp, true),
    }
}

/// [`ceft_table_sp_into`] with the lane implementation pinned explicitly —
/// the hook the bit-identity property tests use to exercise both dispatch
/// paths in one process.
pub fn ceft_table_sp_into_dispatched(
    ws: &mut Workspace,
    inst: InstanceRef,
    sp: &SpTree,
    dispatch: KernelDispatch,
) {
    match dispatch {
        KernelDispatch::Simd => ceft_sp_kernel_lanes::<SimdLanes>(ws, inst, sp, false),
        KernelDispatch::Scalar => ceft_sp_kernel_lanes::<ScalarLanes>(ws, inst, sp, false),
    }
}

/// [`ceft_table_sp_rev_into`] with the lane implementation pinned.
pub fn ceft_table_sp_rev_into_dispatched(
    ws: &mut Workspace,
    inst: InstanceRef,
    sp: &SpTree,
    dispatch: KernelDispatch,
) {
    match dispatch {
        KernelDispatch::Simd => ceft_sp_kernel_lanes::<SimdLanes>(ws, inst, sp, true),
        KernelDispatch::Scalar => ceft_sp_kernel_lanes::<ScalarLanes>(ws, inst, sp, true),
    }
}

/// Workspace-backed table producer over the SP kernel, mirroring
/// [`super::ceft_table_with`]: run the forward tree DP in `ws` and copy
/// the buffers out as an owned [`CeftTable`] for the engine's table memo.
pub fn ceft_table_sp_with(ws: &mut Workspace, inst: InstanceRef, sp: &SpTree) -> CeftTable {
    ceft_table_sp_into(ws, inst, sp);
    CeftTable {
        p: inst.p(),
        table: ws.table.to_vec(),
        backptr: ws.backptr.clone(),
    }
}

/// Reverse-orientation variant of [`ceft_table_sp_with`].
pub fn ceft_table_sp_rev_with(ws: &mut Workspace, inst: InstanceRef, sp: &SpTree) -> CeftTable {
    ceft_table_sp_rev_into(ws, inst, sp);
    CeftTable {
        p: inst.p(),
        table: ws.table.to_vec(),
        backptr: ws.backptr.clone(),
    }
}

/// The structured kernel, monomorphised per lane implementation. Identical
/// to [`super`]'s fused kernel except for (a) the tree-derived visit
/// order and (b) the in-degree-1 series specialization — see the module
/// docs for why both preserve bit-identity.
fn ceft_sp_kernel_lanes<K: LaneKernel>(ws: &mut Workspace, inst: InstanceRef, sp: &SpTree, rev: bool) {
    let graph = inst.graph;
    let costs = inst.costs;
    let v = inst.n();
    let p = inst.p();
    debug_assert_eq!(sp.order.len(), v, "SpTree order must cover every task");
    // cells/s attribution for the structured path (no-op unless telemetry
    // is on)
    let _obs = crate::obs::kernel_timer(crate::obs::KernelPath::SpTree, (graph.num_edges() * p * p) as u64);
    let Workspace {
        table,
        backptr,
        panel_startup,
        panel_bw,
        ..
    } = ws;
    let (panel_startup, panel_bw): (&[f64], &[f64]) = match inst.ctx() {
        Some(ctx) => {
            debug_assert_eq!(ctx.p(), p, "ctx/platform class count mismatch");
            (ctx.panel_startup(), ctx.panel_bw())
        }
        None => {
            fill_comm_panels(inst.platform, panel_startup, panel_bw);
            (panel_startup.as_slice(), panel_bw.as_slice())
        }
    };
    table.clear();
    table.resize(v * p, 0.0);
    backptr.clear();
    backptr.resize(v * p, (usize::MAX, usize::MAX));

    for i in 0..sp.order.len() {
        let t = if rev {
            sp.order[sp.order.len() - 1 - i]
        } else {
            sp.order[i]
        };
        // parents of `t` in the swept orientation, CSR order — the same
        // fold order as the general kernel, so argmax tie-breaks match
        let preds = if rev { graph.succs(t) } else { graph.preds(t) };
        if preds.is_empty() {
            table[t * p..(t + 1) * p].copy_from_slice(costs.row(t));
            continue;
        }
        let crow = costs.row(t);
        if let [(k, data)] = preds {
            let (k, data) = (*k, *data);
            // series hop: one P×P min-plus panel product against the sole
            // parent row. The general kernel's single fold step accepts
            // iff `best > NEG_INFINITY` — reproduced verbatim, so values
            // and backpointers are identical to the fold.
            let mut j0 = 0;
            while j0 < p {
                let j1 = (j0 + KERNEL_BLOCK).min(p);
                let mut best_total = [f64::NEG_INFINITY; KERNEL_BLOCK];
                let mut best_ptr = [(usize::MAX, usize::MAX); KERNEL_BLOCK];
                let krow = &table[k * p..(k + 1) * p];
                for (bi, j) in (j0..j1).enumerate() {
                    let srow = &panel_startup[j * p..j * p + p];
                    let brow = &panel_bw[j * p..j * p + p];
                    let (best, best_l) = K::min_plus_row(krow, srow, brow, data);
                    if best > best_total[bi] {
                        best_total[bi] = best;
                        best_ptr[bi] = (k, best_l);
                    }
                }
                for (bi, j) in (j0..j1).enumerate() {
                    table[t * p + j] = best_total[bi] + crow[j];
                    backptr[t * p + j] = best_ptr[bi];
                }
                j0 = j1;
            }
            continue;
        }
        // parallel join: the branches' series products combine by
        // element-wise max — the general kernel's tiled block loop,
        // verbatim
        let mut j0 = 0;
        while j0 < p {
            let j1 = (j0 + KERNEL_BLOCK).min(p);
            let mut best_total = [f64::NEG_INFINITY; KERNEL_BLOCK];
            let mut best_ptr = [(usize::MAX, usize::MAX); KERNEL_BLOCK];
            for &(k, data) in preds {
                let krow = &table[k * p..(k + 1) * p];
                for (bi, j) in (j0..j1).enumerate() {
                    let srow = &panel_startup[j * p..j * p + p];
                    let brow = &panel_bw[j * p..j * p + p];
                    let (best, best_l) = K::min_plus_row(krow, srow, brow, data);
                    if best > best_total[bi] {
                        best_total[bi] = best;
                        best_ptr[bi] = (k, best_l);
                    }
                }
            }
            for (bi, j) in (j0..j1).enumerate() {
                table[t * p + j] = best_total[bi] + crow[j];
                backptr[t * p + j] = best_ptr[bi];
            }
            j0 = j1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::ceft::{ceft_table_rev_with, ceft_table_with};
    use crate::graph::generator::{generate_fork_join, generate_pipeline, Instance};
    use crate::graph::shape::{recognize, ShapeClass};
    use crate::graph::TaskGraph;
    use crate::model::CostMatrix;
    use crate::platform::{CostModel, Platform};

    fn assert_tables_bit_identical(a: &CeftTable, b: &CeftTable, what: &str) {
        assert_eq!(a.p, b.p, "{what}: stride");
        assert_eq!(a.table.len(), b.table.len(), "{what}: size");
        for (i, (x, y)) in a.table.iter().zip(&b.table).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: value cell {i}");
        }
        assert_eq!(a.backptr, b.backptr, "{what}: backpointers");
    }

    fn check_instance(inst: &Instance, plat: &Platform, what: &str) {
        let verdict = recognize(&inst.graph);
        let sp = verdict.sp.expect("instance must be SP");
        let mut ws = Workspace::new();
        let general = ceft_table_with(&mut ws, inst.bind(plat));
        let fast = ceft_table_sp_with(&mut ws, inst.bind(plat), &sp);
        assert_tables_bit_identical(&fast, &general, what);
        let general_rev = ceft_table_rev_with(&mut ws, inst.bind(plat));
        let fast_rev = ceft_table_sp_rev_with(&mut ws, inst.bind(plat), &sp);
        assert_tables_bit_identical(&fast_rev, &general_rev, &format!("{what} (rev)"));
        // pinned dispatches agree too
        for dispatch in [KernelDispatch::Scalar, KernelDispatch::Simd] {
            ceft_table_sp_into_dispatched(&mut ws, inst.bind(plat), &sp, dispatch);
            let got = CeftTable {
                p: inst.p(),
                table: ws.table.to_vec(),
                backptr: ws.backptr.clone(),
            };
            assert_tables_bit_identical(&got, &general, &format!("{what} ({dispatch:?})"));
        }
    }

    #[test]
    fn fork_join_instance_matches_general_kernel() {
        let plat = Platform::uniform(4, 1.0, 0.0);
        let inst = generate_fork_join(6, 5, 1.0, 50.0, &CostModel::Classic { beta: 0.5 }, &plat, 11);
        assert_eq!(recognize(&inst.graph).class, ShapeClass::ForkJoin);
        check_instance(&inst, &plat, "fork_join");
    }

    #[test]
    fn pipeline_instance_matches_general_kernel() {
        let plat = Platform::uniform(3, 1.0, 0.0);
        let inst = generate_pipeline(7, 4, 1.0, 50.0, &CostModel::Classic { beta: 0.5 }, &plat, 12);
        assert_eq!(recognize(&inst.graph).class, ShapeClass::SeriesParallel);
        check_instance(&inst, &plat, "pipeline");
    }

    #[test]
    fn hand_built_diamond_matches_including_p1() {
        for p in [1usize, 2, 8] {
            let plat = Platform::uniform(p, 1.0, 0.0);
            let graph = TaskGraph::from_edges(
                4,
                &[(0, 1, 1.0), (0, 2, 2.0), (1, 3, 0.5), (2, 3, 1.5)],
            );
            let comp: Vec<f64> = (0..4 * p).map(|i| 1.0 + (i % 3) as f64).collect();
            let inst = Instance {
                graph,
                comp: CostMatrix::new(p, comp),
            };
            check_instance(&inst, &plat, &format!("diamond p={p}"));
        }
    }
}
