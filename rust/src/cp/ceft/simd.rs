//! Hand-vectorised min-plus lanes and the kernel dispatch switch.
//!
//! The CEFT kernels' hot inner loop is the min-plus scan
//! `min_l krow[l] + (S[l] + data / B[l])` with a lowest-`l` argmin — a
//! contiguous, branch-free sweep the blocked kernel set up precisely so it
//! *could* vectorise, but whose argmin the autovectoriser routinely fails
//! to turn into lane-wise selects. This module vectorises it by hand with
//! **portable 4-wide `f64` lanes**: fixed-size `[f64; 4]` chunks with
//! explicit per-lane compare/select, which LLVM lowers to `f64x4`
//! vector instructions on every target with 256-bit lanes (and to clean
//! 2×128-bit code elsewhere) — no nightly `std::simd`, no intrinsics, no
//! `unsafe`.
//!
//! ## Bit-identity contract
//!
//! Every candidate value is computed with exactly the scalar path's
//! operations in the same order (`krow[l] + (S[l] + data / B[l])` — one
//! add, one div, one add per cell), so **values** are bit-identical by
//! construction, including the `±inf` panel cells from the `0`/`+inf`
//! diagonal contract (`data / +inf == +0.0`). Only the *reduction order*
//! of the argmin differs: each lane keeps the running minimum of its own
//! residue class `l ≡ i (mod 4)` (strict `<`, so the lowest index in the
//! lane wins lane-internal ties), and the cross-lane reduction restores
//! the scalar tie-break exactly with
//! `v < best || (v == best && idx < best_idx)` — the minimum *value bits*
//! and the **lowest sender class attaining them**, which is precisely what
//! the scalar strict-`<` scan produces. `P % 4` tail elements run the
//! scalar epilogue against the already-reduced `(best, best_l)`; tail
//! indices are larger than every lane index, so plain strict `<` preserves
//! the tie-break. `prop_simd_kernel_bit_identical_to_scalar`
//! (`rust/tests/properties.rs`) enforces all of this over
//! `P ∈ {1, 2, 3, 4, 5, 7, 8, 9, 16}`.
//!
//! ## Dispatch
//!
//! [`KernelDispatch`] picks the lane implementation once per
//! [`crate::model::PlatformCtx`] (construction time), or per call for
//! ctx-less fallback instances. `CEFT_FORCE_SCALAR=1` in the environment
//! forces the scalar lanes everywhere — the knob `ci.sh` uses to run the
//! kernel bench under both paths, and the escape hatch if a target's
//! vector unit misbehaves. The scalar-recurrence oracle
//! (`ceft_table_scalar_into`) is independent of this switch: it never
//! routes through the lane kernels at all.

/// Lane width: 4 × `f64` = one 32-byte (256-bit) vector register.
pub const LANES: usize = 4;

/// Which lane implementation the min-plus kernels run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelDispatch {
    /// One class per iteration — the pre-SIMD kernel loop, kept as the
    /// in-kernel reference and the `CEFT_FORCE_SCALAR=1` path.
    Scalar,
    /// Portable 4-wide `f64` lanes with lane-wise running-min + argmin.
    Simd,
}

impl KernelDispatch {
    /// Select the dispatch from the environment: [`KernelDispatch::Simd`]
    /// unless `CEFT_FORCE_SCALAR=1` is set. Called once per
    /// [`crate::model::PlatformCtx`] construction; ctx-less kernel entry
    /// points call it per invocation (one env lookup, noise next to the
    /// `O(P²e)` sweep it configures).
    pub fn select() -> Self {
        match std::env::var("CEFT_FORCE_SCALAR") {
            Ok(v) if v == "1" => KernelDispatch::Scalar,
            _ => KernelDispatch::Simd,
        }
    }
}

/// The min-plus row scan both kernel families are generic over: given a
/// parent CEFT row and one destination class's panel rows, return
/// `(min_l krow[l] + (S[l] + data / B[l]), argmin_l)` with the scalar
/// path's lowest-`l` tie-break.
pub(crate) trait LaneKernel {
    /// Telemetry attribution for the fused per-instance kernel driver
    /// (`crate::obs` cells/s counters); the batched and gathered drivers
    /// attribute to their own paths regardless of lane choice.
    const PATH: crate::obs::KernelPath;

    fn min_plus_row(krow: &[f64], srow: &[f64], brow: &[f64], data: f64) -> (f64, usize);
}

/// The scalar lane implementation — the pre-SIMD kernel inner loop,
/// verbatim.
pub(crate) struct ScalarLanes;

impl LaneKernel for ScalarLanes {
    const PATH: crate::obs::KernelPath = crate::obs::KernelPath::Scalar;

    #[inline(always)]
    fn min_plus_row(krow: &[f64], srow: &[f64], brow: &[f64], data: f64) -> (f64, usize) {
        let mut best = f64::INFINITY;
        let mut best_l = 0usize;
        for l in 0..krow.len() {
            let cand = krow[l] + (srow[l] + data / brow[l]);
            if cand < best {
                best = cand;
                best_l = l;
            }
        }
        (best, best_l)
    }
}

/// The 4-wide lane implementation (see the module docs for the reduction
/// argument).
pub(crate) struct SimdLanes;

impl LaneKernel for SimdLanes {
    const PATH: crate::obs::KernelPath = crate::obs::KernelPath::Simd;

    #[inline(always)]
    fn min_plus_row(krow: &[f64], srow: &[f64], brow: &[f64], data: f64) -> (f64, usize) {
        let p = krow.len();
        debug_assert_eq!(srow.len(), p);
        debug_assert_eq!(brow.len(), p);
        let body = p - p % LANES;
        let mut best = f64::INFINITY;
        let mut best_l = 0usize;
        if body > 0 {
            // lane-wise running minima over residue classes l ≡ i (mod 4);
            // fixed-size arrays + branchless selects lower to vector
            // compare/blend
            let mut vbest = [f64::INFINITY; LANES];
            let mut vidx = [0usize; LANES];
            let mut base = 0;
            while base < body {
                let k: &[f64] = &krow[base..base + LANES];
                let s: &[f64] = &srow[base..base + LANES];
                let b: &[f64] = &brow[base..base + LANES];
                let mut cand = [0.0f64; LANES];
                for i in 0..LANES {
                    // same three ops in the same order as the scalar path:
                    // values are bit-identical per cell
                    cand[i] = k[i] + (s[i] + data / b[i]);
                }
                for i in 0..LANES {
                    let lt = cand[i] < vbest[i];
                    vbest[i] = if lt { cand[i] } else { vbest[i] };
                    vidx[i] = if lt { base + i } else { vidx[i] };
                }
                base += LANES;
            }
            // cross-lane reduction restoring the scalar lowest-l tie-break:
            // equal value bits resolve to the smaller sender class
            for i in 0..LANES {
                if vbest[i] < best || (vbest[i] == best && vidx[i] < best_l) {
                    best = vbest[i];
                    best_l = vidx[i];
                }
            }
        }
        // scalar epilogue for the P % 4 tail; tail indices exceed every
        // lane index, so strict `<` alone preserves the tie-break
        for l in body..p {
            let cand = krow[l] + (srow[l] + data / brow[l]);
            if cand < best {
                best = cand;
                best_l = l;
            }
        }
        (best, best_l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn both(krow: &[f64], srow: &[f64], brow: &[f64], data: f64) -> ((f64, usize), (f64, usize)) {
        (
            ScalarLanes::min_plus_row(krow, srow, brow, data),
            SimdLanes::min_plus_row(krow, srow, brow, data),
        )
    }

    #[test]
    fn lane_scan_matches_scalar_across_widths_and_ties() {
        let mut rng = crate::util::rng::Xoshiro256::new(7);
        for p in [1usize, 2, 3, 4, 5, 7, 8, 9, 16, 33] {
            for case in 0..200 {
                let mut krow: Vec<f64> = (0..p).map(|_| rng.uniform(0.0, 50.0)).collect();
                let srow: Vec<f64> = (0..p).map(|_| rng.uniform(0.0, 2.0)).collect();
                let mut brow: Vec<f64> = (0..p).map(|_| rng.uniform(0.2, 4.0)).collect();
                // panel diagonal contract: some +inf bandwidth cells
                if p > 1 {
                    brow[rng.below(p)] = f64::INFINITY;
                }
                // force value ties so the lowest-l rule is actually exercised
                if p > 2 && case % 3 == 0 {
                    let a = rng.below(p);
                    let b = rng.below(p);
                    krow[b] = krow[a];
                }
                let data = if case % 5 == 0 { 0.0 } else { rng.uniform(0.0, 30.0) };
                let (s, v) = both(&krow, &srow, &brow, data);
                assert_eq!(s.0.to_bits(), v.0.to_bits(), "value bits (p={p})");
                assert_eq!(s.1, v.1, "argmin (p={p})");
            }
        }
    }

    #[test]
    fn cross_lane_tie_resolves_to_lowest_class() {
        // identical candidate value in lane 1 (l = 1) and lane 0 of the
        // second chunk (l = 4): the scalar scan picks l = 1, and the
        // cross-lane reduction must too — a plain lane-order `<` reduce
        // would wrongly return l = 4
        let krow = [9.0, 2.0, 9.0, 9.0, 2.0, 9.0, 9.0, 9.0];
        let srow = [0.0; 8];
        let brow = [f64::INFINITY; 8];
        let (s, v) = both(&krow, &srow, &brow, 5.0);
        assert_eq!(s, (2.0, 1));
        assert_eq!(v, (2.0, 1));
    }

    #[test]
    fn exhaustive_tie_patterns_small_p() {
        // every 0/1 value pattern over P = 6 (two chunks' worth of lanes
        // plus tail when narrowed): ties in all positions
        for p in [4usize, 5, 6] {
            for mask in 0..(1u32 << p) {
                let krow: Vec<f64> = (0..p)
                    .map(|l| if (mask >> l) & 1 == 1 { 1.0 } else { 2.0 })
                    .collect();
                let srow = vec![0.0; p];
                let brow = vec![f64::INFINITY; p];
                let (s, v) = both(&krow, &srow, &brow, 3.0);
                assert_eq!(s, v, "p={p} mask={mask:b}");
            }
        }
    }

    #[test]
    fn dispatch_select_honours_force_scalar() {
        // NB: reads the real process environment; the default environment
        // of `cargo test` has the variable unset
        match std::env::var("CEFT_FORCE_SCALAR") {
            Ok(v) if v == "1" => assert_eq!(KernelDispatch::select(), KernelDispatch::Scalar),
            _ => assert_eq!(KernelDispatch::select(), KernelDispatch::Simd),
        }
    }
}
