//! CEFT — the Critical Earliest Finish Time dynamic program (Algorithm 1).
//!
//! For every task `t` and processor class `j`, `CEFT(t, j)` is the earliest
//! time `t` can finish *on class `j`* along the longest dependence chain
//! into `t`, assuming every ancestor is mapped optimally for that chain
//! (Definition 8):
//!
//! ```text
//! CEFT(t, j) = max over parents k of
//!                min over classes l of
//!                  C_comp(t, j) + CEFT(k, l) + comm({k,l},{t,j})
//! ```
//!
//! Source tasks: `CEFT(t, j) = C_comp(t, j)`.
//!
//! The DP visits each edge once per `(j, l)` class pair — `O(P²e)` time —
//! and keeps a `(parent, parent_class)` backpointer per cell, so the
//! critical path *and its partial assignment* are reconstructed in `O(v)`
//! instead of storing a path per cell (the paper's §5 frontier argument
//! bounds the extra space; backpointers achieve the same effect more
//! simply).
//!
//! ## The blocked min-plus kernel
//!
//! The hot inner loop — `min_l CEFT(k, l) + comm(l, j)` for every
//! destination class `j` of every edge — is a dense **min-plus
//! matrix-vector product** between the parent's CEFT row and a `P × P`
//! communication panel. [`ceft_table_into`] runs it as such: two
//! destination-major panels (`startup[l]` with a `0` diagonal, and
//! `bandwidth[l → j]` with a `+inf` diagonal) turn the inner loop into a
//! branch-free contiguous scan `krow[l] + (S[j][l] + data / B[j][l])` that
//! the compiler can vectorise; destination classes are tiled in
//! `KERNEL_BLOCK`-sized blocks with the task's edges iterated inside
//! each block, so one parent-row load serves a whole block and the
//! block's panel rows stay cache-resident across all of the task's
//! edges. The `+inf` diagonal makes `data / bw`
//! contribute exactly `+0.0` for co-located classes, so every cell is
//! **bit-identical** to the scalar recurrence over
//! [`Platform::comm_cost`] — including tie-breaking — which the
//! `rust/tests/properties.rs` bit-identity properties and the
//! [`ceft_table_scalar_into`] reference path enforce. See
//! EXPERIMENTS.md §Min-plus kernel for layout and block-size rationale.
//!
//! **Panel residency.** The panels are a pure function of the platform.
//! An instance bound through a [`crate::model::PlatformCtx`]
//! ([`crate::model::PlatformCtx::bind`]) makes the kernel read the
//! context's **resident** panels — computed once per distinct platform
//! per process — and skip the `O(P²)` per-entry fill entirely; an unbound
//! instance falls back to filling workspace-local panels exactly as
//! before. Same panel values either way, so outputs are bit-identical.
//!
//! **Batched multi-row kernel.** [`ceft_dp_kernel_batch_into`] lifts the
//! matrix-vector product to a min-plus **matrix-matrix** product: `B`
//! parent rows (with per-row payloads) are evaluated against one shared
//! panel pair in one blocked sweep — the same shape the PJRT backend's
//! `relax_batch` artifact computes in f32, so the CPU and accelerator
//! backends share one batching layer. [`ceft_table_batched_into`] drives
//! the full DP through it (gather a task's parent rows, one batched
//! relaxation per chunk, max-fold in CSR order) and is proven
//! bit-identical to the scalar recurrence by
//! `prop_batched_kernel_bit_identical_to_scalar`.
//!
//! **SIMD lanes.** The per-edge min-plus scan is hand-vectorised in
//! [`simd`]: portable 4-wide `f64` lanes with a lane-wise running-min +
//! argmin whose cross-lane reduction restores the scalar lowest-`l`
//! tie-break exactly (`P % 4` tails run a scalar epilogue). Dispatch is
//! selected once per [`PlatformCtx`] ([`simd::KernelDispatch`],
//! `CEFT_FORCE_SCALAR=1` forces the scalar lanes), and the
//! `*_dispatched` entry points pin a path explicitly for tests and
//! benches. The scalar recurrence ([`ceft_table_scalar_into`]) never
//! routes through the lanes and remains the bit-identity oracle.
//!
//! **Gathered multi-instance DP.** [`find_critical_paths_gathered`] runs
//! the CEFT DP for several instances **of one platform** in lock-step:
//! each topo round gathers every instance's frontier task's parent rows
//! into one [`ceft_dp_kernel_batch_into`]-shaped sweep against the shared
//! resident panels, then scatters the per-edge minima back into each
//! instance's max-fold. Per instance the per-edge comparison sequence and
//! CSR fold order are unchanged, so every table is bit-identical to the
//! scalar recurrence — this is the compute core of the service engine's
//! cross-request batching (`service::engine`).
//!
//! **Gathered table production.** [`find_ceft_tables_gathered`] runs the
//! same lock-step sweep but returns each instance's full [`CeftTable`]
//! (forward or reverse orientation) instead of just a path — the entry
//! point behind the service engine's table memo, where one gathered sweep
//! feeds critical-path *and* scheduler requests alike
//! (`sched::Algorithm::run_with_tables`). Bit-identity to the serial
//! producers ([`ceft_table_with`] / [`ceft_table_rev_with`]) — values and
//! backpointers — is part of the contract; see EXPERIMENTS.md §Gathered
//! schedule tables.
//!
//! **Delta recompute.** Because the DP sweeps a fixed topological order
//! and a row depends only on rows at earlier sweep positions, an edit to
//! task `t` (cost row, incident edges) can only invalidate rows at sweep
//! positions ≥ `t`'s. [`ceft_table_delta_into`] exploits this: given a
//! [`DeltaPlan`] — the previous table, the topological order it was
//! computed over, and per-task dirty flags — it copies the longest clean
//! sweep prefix straight from the basis table, then re-runs the blocked
//! kernel only over the dirty suffix, with change propagation inside the
//! suffix (a clean task whose swept parents all reproduced their basis
//! rows copies its basis row instead of recomputing). The result is
//! **bit-identical** to a from-scratch sweep of the same orientation
//! (`prop_delta_ceft_bit_identical_to_scratch`), and
//! [`find_ceft_tables_gathered_delta`] threads the same suffix offsets
//! through the gathered lock-step sweep so delta recomputes ride the
//! service engine's cross-request batches. See EXPERIMENTS.md
//! §Incremental re-scheduling for the invalidation-bound proof sketch.
//!
//! **Slack.** [`slack_from_table_with`] is the CPM latest-finish idiom
//! generalised to Algorithm 1: a backward pass over the forward table
//! derives, per task, how far its whole CEFT row may rise uniformly
//! without increasing the critical-path length. Slack is exactly `0.0`
//! along the reported critical path and non-negative everywhere — the
//! user-facing "what's critical now?" answer and the invalidation bound
//! that lets the service skip recompute for within-slack cost increases.
//!
//! Tie-breaking is deterministic: the lowest class id wins `min`s, the
//! earliest-visited parent wins strict-`>` `max`es, and the lowest task id
//! wins the final sink selection. This makes the rust and PJRT backends,
//! and re-runs, bit-identical.

pub mod simd;
pub mod sp;

use crate::cp::workspace::Workspace;
use crate::graph::TaskGraph;
use crate::model::{fill_comm_panels, InstanceRef, PlatformCtx};
use crate::platform::Platform;
use simd::{KernelDispatch, LaneKernel, ScalarLanes, SimdLanes};

/// Destination classes are tiled in blocks of this many rows, and the
/// task's incoming edges iterate *inside* each block: one load of the
/// parent's CEFT row then serves a whole block of destination rows
/// (instead of being re-fetched once per `j`), while the block's
/// `16 × KERNEL_BLOCK × P` bytes of panel rows stay L1-resident across
/// every edge of the task (resident up to `P = 256` at the default 8 —
/// far past the paper's `P ≤ 64` sweeps). Fold accumulators for a block
/// live in fixed-size stack arrays, which is what bounds the block size.
/// Purely a scheduling choice: each `(edge, j, l)` cell is computed
/// exactly once with the same comparison sequence per `j`, so results
/// are independent of the block size.
const KERNEL_BLOCK: usize = 8;

/// One step of a critical path: a task and the processor class the optimal
/// partial assignment maps it to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PathStep {
    /// task id
    pub task: usize,
    /// processor class the partial assignment picks for it
    pub class: usize,
}

/// A critical path with its partial assignment.
#[derive(Clone, Debug, PartialEq)]
pub struct CriticalPath {
    /// CEFT length of the path (the paper's CPL metric for CEFT)
    pub length: f64,
    /// tasks in dependence order, each with its assigned class
    pub path: Vec<PathStep>,
}

impl CriticalPath {
    /// The partial assignment as a `task -> class` map. Prefer
    /// [`CriticalPath::assignment_dense`] on hot paths — it avoids hashing
    /// task ids entirely.
    pub fn assignment(&self) -> std::collections::HashMap<usize, usize> {
        self.path.iter().map(|s| (s.task, s.class)).collect()
    }

    /// The partial assignment as a dense pin table over `n` tasks:
    /// `pins[t] = Some(class)` for every path task, `None` elsewhere. This
    /// is the representation [`crate::sched::Placement::Pinned`] consumes.
    pub fn assignment_dense(&self, n: usize) -> Vec<Option<usize>> {
        let mut pins = vec![None; n];
        self.fill_assignment_dense(n, &mut pins);
        pins
    }

    /// Non-allocating variant of [`CriticalPath::assignment_dense`]: resize
    /// and fill a caller-owned (typically workspace-owned) pin table.
    pub fn fill_assignment_dense(&self, n: usize, pins: &mut Vec<Option<usize>>) {
        pins.clear();
        pins.resize(n, None);
        for s in &self.path {
            pins[s.task] = Some(s.class);
        }
    }

    /// Task ids on the path, in order.
    pub fn tasks(&self) -> Vec<usize> {
        self.path.iter().map(|s| s.task).collect()
    }
}

/// The full DP table: `table[t*P + j] = CEFT(t, j)`, plus backpointers.
#[derive(Clone, Debug)]
pub struct CeftTable {
    /// number of classes (row stride)
    pub p: usize,
    /// the `v × P` CEFT values
    pub table: Vec<f64>,
    /// per-cell backpointer `(parent task, parent class)`; `usize::MAX`
    /// marks a source cell
    pub backptr: Vec<(usize, usize)>,
}

impl CeftTable {
    /// `CEFT(t, j)`.
    #[inline]
    pub fn get(&self, t: usize, j: usize) -> f64 {
        self.table[t * self.p + j]
    }

    /// `min_j CEFT(t, j)` — the CEFT-based downward rank of §8.2.
    pub fn min_over_classes(&self, t: usize) -> f64 {
        let row = &self.table[t * self.p..(t + 1) * self.p];
        row.iter().fold(f64::INFINITY, |a, &b| a.min(b))
    }

    /// `argmin_j CEFT(t, j)` with lowest-id tie-breaking.
    pub fn argmin_class(&self, t: usize) -> usize {
        let row = &self.table[t * self.p..(t + 1) * self.p];
        let mut best = 0;
        for j in 1..self.p {
            if row[j] < row[best] {
                best = j;
            }
        }
        best
    }
}

/// Compute the CEFT dynamic-programming table for all `(task, class)`
/// cells. Convenience wrapper over [`ceft_table_into`] that allocates a
/// one-shot [`Workspace`] and moves the filled buffers out as an owned
/// [`CeftTable`].
pub fn ceft_table(inst: InstanceRef) -> CeftTable {
    let mut ws = Workspace::new();
    ceft_table_into(&mut ws, inst);
    CeftTable {
        p: inst.p(),
        table: ws.table.to_vec(),
        backptr: std::mem::take(&mut ws.backptr),
    }
}

/// Reference-path variant of [`ceft_table`] over the scalar recurrence
/// ([`ceft_table_scalar_into`]); bit-identical to the kernel path.
pub fn ceft_table_scalar(inst: InstanceRef) -> CeftTable {
    let mut ws = Workspace::new();
    ceft_table_scalar_into(&mut ws, inst);
    CeftTable {
        p: inst.p(),
        table: ws.table.to_vec(),
        backptr: std::mem::take(&mut ws.backptr),
    }
}

/// Fill `ws.table` / `ws.backptr` with the CEFT DP over the instance — the
/// allocation-free core of Algorithm 1, running the blocked min-plus
/// kernel (see the module docs). Buffers are sized at entry (no allocation
/// once the workspace has served an instance this large).
pub fn ceft_table_into(ws: &mut Workspace, inst: InstanceRef) {
    ceft_dp_kernel_into(ws, inst, false)
}

/// Workspace-backed variant of [`ceft_table`]: run the forward DP in `ws`
/// and copy the filled buffers out as an owned [`CeftTable`]. The copy is
/// what lets a *pooled* workspace return to its pool while the table
/// outlives it — the service engine's table memo stores exactly this
/// (`service::engine`), and the batch harness reuses one table across every
/// forward-table consumer of an instance (`exp::run`).
pub fn ceft_table_with(ws: &mut Workspace, inst: InstanceRef) -> CeftTable {
    ceft_table_into(ws, inst);
    CeftTable {
        p: inst.p(),
        table: ws.table.to_vec(),
        backptr: ws.backptr.clone(),
    }
}

/// Workspace-backed **reverse-orientation** table producer: the transpose
/// DP of [`ceft_table_rev_into`], copied out as an owned [`CeftTable`].
/// Consumed by the CEFT upward rank (`sched::ceft_heft::CeftHeftUp`) via
/// [`crate::sched::Algorithm::run_with_tables`].
pub fn ceft_table_rev_with(ws: &mut Workspace, inst: InstanceRef) -> CeftTable {
    ceft_table_rev_into(ws, inst);
    CeftTable {
        p: inst.p(),
        table: ws.table.to_vec(),
        backptr: ws.backptr.clone(),
    }
}

/// The CEFT DP of the **transposed** DAG, computed without materialising
/// the transpose: sweep reverse topological order and treat successors as
/// parents. Communication is charged in the transposed direction
/// (`comm_cost(succ_class, task_class, data)`), exactly as
/// `ceft_table(transposed instance)` would — bit-identical, including
/// tie-breaking, because predecessor CSR order of the transpose equals
/// successor CSR order of the original (both group edges in input order).
/// Used by the CEFT upward rank (§8.2) to avoid rebuilding a graph per
/// call.
pub fn ceft_table_rev_into(ws: &mut Workspace, inst: InstanceRef) {
    ceft_dp_kernel_into(ws, inst, true)
}

/// Scalar reference implementation of [`ceft_table_into`]: the plain
/// nested-loop recurrence over [`Platform::comm_cost`], kept as the
/// ground truth the blocked kernel is proven bit-identical against
/// (property tests in `rust/tests/properties.rs`) and as the baseline of
/// `benches/ceft_kernel.rs`.
pub fn ceft_table_scalar_into(ws: &mut Workspace, inst: InstanceRef) {
    ceft_dp_scalar_into(ws, inst, false)
}

/// Scalar reference implementation of [`ceft_table_rev_into`].
pub fn ceft_table_rev_scalar_into(ws: &mut Workspace, inst: InstanceRef) {
    ceft_dp_scalar_into(ws, inst, true)
}

/// [`ceft_table_into`] with the lane implementation pinned explicitly —
/// the hook the SIMD bit-identity property tests and `benches/ceft_kernel`
/// use to exercise both dispatch paths in one process, independent of the
/// `CEFT_FORCE_SCALAR` environment.
pub fn ceft_table_into_dispatched(ws: &mut Workspace, inst: InstanceRef, dispatch: KernelDispatch) {
    match dispatch {
        KernelDispatch::Simd => ceft_dp_kernel_lanes::<SimdLanes>(ws, inst, false),
        KernelDispatch::Scalar => ceft_dp_kernel_lanes::<ScalarLanes>(ws, inst, false),
    }
}

/// [`ceft_table_rev_into`] with the lane implementation pinned explicitly.
pub fn ceft_table_rev_into_dispatched(
    ws: &mut Workspace,
    inst: InstanceRef,
    dispatch: KernelDispatch,
) {
    match dispatch {
        KernelDispatch::Simd => ceft_dp_kernel_lanes::<SimdLanes>(ws, inst, true),
        KernelDispatch::Scalar => ceft_dp_kernel_lanes::<ScalarLanes>(ws, inst, true),
    }
}

/// A delta-recompute plan: the memoized basis table plus what changed
/// since it was computed. The contract a caller must uphold:
///
/// * `prev` is a table of the **same orientation** as the recompute,
///   computed over a basis instance whose task ids are a prefix of the
///   current id space (`basis_n` tasks; ids `>= basis_n` are new);
/// * `prev_topo` is the basis graph's topological order;
/// * `dirty[t]` is `true` for every task (in the current id space) whose
///   cost row, predecessor list, or successor list differs from the basis
///   — edge edits must mark **both** endpoints so one dirty set serves
///   both orientations.
///
/// Id-shifting edits (task removal) cannot be expressed as a plan; callers
/// fall back to a from-scratch sweep instead (`graph::edit` reports this).
#[derive(Clone, Copy, Debug)]
pub struct DeltaPlan<'a> {
    /// the basis table (same orientation as the recompute)
    pub prev: &'a CeftTable,
    /// topological order of the graph `prev` was computed over
    pub prev_topo: &'a [usize],
    /// basis task count: ids `>= basis_n` did not exist in the basis
    pub basis_n: usize,
    /// per-task dirty flags in the current id space (`len == n`)
    pub dirty: &'a [bool],
}

/// Length of the clean sweep prefix a [`DeltaPlan`] allows: the largest
/// `k` such that the first `k` sweep positions of the current topological
/// order name the same, non-dirty basis tasks as the basis order. Rows at
/// those positions depend only on earlier (equally clean) positions, so
/// they are bit-identical to the basis rows and can be copied. `rev`
/// mirrors the comparison for the reverse sweep (`topo[len-1-i]`).
pub fn delta_clean_prefix(topo: &[usize], plan: &DeltaPlan, rev: bool) -> usize {
    let n = topo.len();
    let pn = plan.prev_topo.len();
    let lim = n.min(pn);
    for i in 0..lim {
        let t = if rev { topo[n - 1 - i] } else { topo[i] };
        let o = if rev {
            plan.prev_topo[pn - 1 - i]
        } else {
            plan.prev_topo[i]
        };
        if t != o || t >= plan.basis_n || plan.dirty[t] {
            return i;
        }
    }
    lim
}

/// Delta-CEFT: fill `ws.table` / `ws.backptr` with the DP of the given
/// orientation, copying the clean sweep prefix from `plan.prev` and
/// re-running the blocked kernel only over the dirty suffix — with change
/// propagation inside the suffix, so a clean task whose swept parents all
/// reproduced their basis rows copies its basis row too. Returns the
/// number of rows actually recomputed (the `delta_rows_recomputed`
/// counter of the service stats). **Bit-identical** to the from-scratch
/// sweep of the same orientation: every copied row is provably equal to
/// what the sweep would have produced (see the module docs and
/// `prop_delta_ceft_bit_identical_to_scratch`).
pub fn ceft_table_delta_into(
    ws: &mut Workspace,
    inst: InstanceRef,
    plan: &DeltaPlan,
    rev: bool,
) -> usize {
    ceft_table_delta_into_dispatched(ws, inst, plan, rev, dispatch_for(&inst))
}

/// [`ceft_table_delta_into`] with the lane implementation pinned
/// explicitly (the delta bit-identity property tests exercise both paths
/// in one process).
pub fn ceft_table_delta_into_dispatched(
    ws: &mut Workspace,
    inst: InstanceRef,
    plan: &DeltaPlan,
    rev: bool,
    dispatch: KernelDispatch,
) -> usize {
    match dispatch {
        KernelDispatch::Simd => ceft_dp_kernel_delta_lanes::<SimdLanes>(ws, inst, plan, rev),
        KernelDispatch::Scalar => ceft_dp_kernel_delta_lanes::<ScalarLanes>(ws, inst, plan, rev),
    }
}

/// Workspace-backed [`ceft_table_delta_into`] copied out as an owned
/// [`CeftTable`] (the table-memo shape of `service::engine`), paired with
/// the recomputed-row count.
pub fn ceft_table_delta_with(
    ws: &mut Workspace,
    inst: InstanceRef,
    plan: &DeltaPlan,
    rev: bool,
) -> (CeftTable, usize) {
    let rows = ceft_table_delta_into(ws, inst, plan, rev);
    (
        CeftTable {
            p: inst.p(),
            table: ws.table.to_vec(),
            backptr: ws.backptr.clone(),
        },
        rows,
    )
}

/// Bit-wise row equality: values compared as `f64` bits (the tables never
/// hold NaN, but `to_bits` keeps the contract exact even for signed
/// zeros), backpointers exactly.
#[inline]
fn delta_row_equal(a_tab: &[f64], b_tab: &[f64], a_ptr: &[(usize, usize)], b_ptr: &[(usize, usize)]) -> bool {
    a_tab
        .iter()
        .zip(b_tab)
        .all(|(x, y)| x.to_bits() == y.to_bits())
        && a_ptr == b_ptr
}

/// The delta kernel DP, monomorphised per lane implementation: the exact
/// per-task tiled sweep of [`ceft_dp_kernel_lanes`], restricted to the
/// dirty suffix of [`delta_clean_prefix`], with basis rows copied
/// everywhere the sweep provably reproduces them. A recomputed row is
/// compared bit-wise against its basis row so change propagation stops as
/// soon as an edit is absorbed by the DP's `min`/`max` structure — the
/// "zero impact" case where a cost edit never reaches the critical path.
fn ceft_dp_kernel_delta_lanes<K: LaneKernel>(
    ws: &mut Workspace,
    inst: InstanceRef,
    plan: &DeltaPlan,
    rev: bool,
) -> usize {
    let graph = inst.graph;
    let costs = inst.costs;
    let v = inst.n();
    let p = inst.p();
    assert_eq!(plan.prev.p, p, "delta basis/platform class count mismatch");
    assert_eq!(
        plan.prev.table.len(),
        plan.basis_n * p,
        "delta basis table/basis_n mismatch"
    );
    assert_eq!(plan.dirty.len(), v, "delta dirty flags must cover every task");
    let topo = graph.topo_order();
    let prefix = delta_clean_prefix(topo, plan, rev);
    // cells/s attribution: the dirty suffix is the work this sweep can do
    // (change propagation may skip further rows; the counter stays an
    // upper bound of the same order)
    let suffix_cells: usize = (prefix..topo.len())
        .map(|i| {
            let t = if rev { topo[topo.len() - 1 - i] } else { topo[i] };
            let deg = if rev {
                graph.out_degree(t)
            } else {
                graph.in_degree(t)
            };
            deg * p * p
        })
        .sum();
    let _obs = crate::obs::kernel_timer(K::PATH, suffix_cells as u64);
    let Workspace {
        table,
        backptr,
        panel_startup,
        panel_bw,
        row_changed,
        ..
    } = ws;
    let (panel_startup, panel_bw): (&[f64], &[f64]) = match inst.ctx() {
        Some(ctx) => {
            debug_assert_eq!(ctx.p(), p, "ctx/platform class count mismatch");
            (ctx.panel_startup(), ctx.panel_bw())
        }
        None => {
            fill_comm_panels(inst.platform, panel_startup, panel_bw);
            (panel_startup.as_slice(), panel_bw.as_slice())
        }
    };
    table.clear();
    table.resize(v * p, 0.0);
    backptr.clear();
    backptr.resize(v * p, (usize::MAX, usize::MAX));
    row_changed.clear();
    row_changed.resize(v, false);

    // clean prefix: rows are bit-identical to the basis — copy them
    for i in 0..prefix {
        let t = if rev { topo[topo.len() - 1 - i] } else { topo[i] };
        table[t * p..(t + 1) * p].copy_from_slice(&plan.prev.table[t * p..(t + 1) * p]);
        backptr[t * p..(t + 1) * p].copy_from_slice(&plan.prev.backptr[t * p..(t + 1) * p]);
    }
    let mut recomputed = 0usize;
    for i in prefix..topo.len() {
        let t = if rev { topo[topo.len() - 1 - i] } else { topo[i] };
        // parents of `t` in the swept orientation
        let preds = if rev { graph.succs(t) } else { graph.preds(t) };
        // change propagation: a clean basis task whose swept parents all
        // kept their basis rows feeds the recurrence identical inputs, so
        // its basis row is the answer — copy instead of recomputing
        if t < plan.basis_n && !plan.dirty[t] && preds.iter().all(|&(k, _)| !row_changed[k]) {
            table[t * p..(t + 1) * p].copy_from_slice(&plan.prev.table[t * p..(t + 1) * p]);
            backptr[t * p..(t + 1) * p]
                .copy_from_slice(&plan.prev.backptr[t * p..(t + 1) * p]);
            continue;
        }
        recomputed += 1;
        if preds.is_empty() {
            table[t * p..(t + 1) * p].copy_from_slice(costs.row(t));
        } else {
            let crow = costs.row(t);
            let mut j0 = 0;
            while j0 < p {
                let j1 = (j0 + KERNEL_BLOCK).min(p);
                // per-block max-fold accumulators on the stack
                let mut best_total = [f64::NEG_INFINITY; KERNEL_BLOCK];
                let mut best_ptr = [(usize::MAX, usize::MAX); KERNEL_BLOCK];
                for &(k, data) in preds {
                    let krow = &table[k * p..(k + 1) * p];
                    for (bi, j) in (j0..j1).enumerate() {
                        let srow = &panel_startup[j * p..j * p + p];
                        let brow = &panel_bw[j * p..j * p + p];
                        let (best, best_l) = K::min_plus_row(krow, srow, brow, data);
                        if best > best_total[bi] {
                            best_total[bi] = best;
                            best_ptr[bi] = (k, best_l);
                        }
                    }
                }
                for (bi, j) in (j0..j1).enumerate() {
                    table[t * p + j] = best_total[bi] + crow[j];
                    backptr[t * p + j] = best_ptr[bi];
                }
                j0 = j1;
            }
        }
        // an absorbed edit (recomputed row equals the basis row bit-wise)
        // stops propagating to the task's swept children
        row_changed[t] = t >= plan.basis_n
            || !delta_row_equal(
                &table[t * p..(t + 1) * p],
                &plan.prev.table[t * p..(t + 1) * p],
                &backptr[t * p..(t + 1) * p],
                &plan.prev.backptr[t * p..(t + 1) * p],
            );
    }
    recomputed
}

/// The dispatch the kernels run an instance under: the context's
/// once-selected choice when the instance is bound through a
/// [`PlatformCtx`], else a fresh environment lookup
/// ([`KernelDispatch::select`]).
fn dispatch_for(inst: &InstanceRef) -> KernelDispatch {
    match inst.ctx() {
        Some(ctx) => ctx.dispatch(),
        None => KernelDispatch::select(),
    }
}

/// The kernel DP behind both orientations: selects the lane
/// implementation ([`dispatch_for`]) and runs [`ceft_dp_kernel_lanes`].
fn ceft_dp_kernel_into(ws: &mut Workspace, inst: InstanceRef, rev: bool) {
    match dispatch_for(&inst) {
        KernelDispatch::Simd => ceft_dp_kernel_lanes::<SimdLanes>(ws, inst, rev),
        KernelDispatch::Scalar => ceft_dp_kernel_lanes::<ScalarLanes>(ws, inst, rev),
    }
}

/// The fused kernel DP, monomorphised per lane implementation: resident
/// [`PlatformCtx`] panels when the instance carries a context,
/// workspace-local panels filled here otherwise ([`crate::model`]'s
/// `fill_comm_panels` — one implementation behind both sources), then per
/// task a tiled min-plus sweep — destination classes in
/// [`KERNEL_BLOCK`]-sized blocks, the task's incoming edges iterated
/// *inside* each block so one parent-row load serves the whole block and
/// the block's panel rows stay resident across every edge. Per destination
/// class the comparison sequence (strict `<` lowest-`l` argmin per edge —
/// scalar or 4-wide lanes, both reproduce it exactly, see
/// [`simd`] — and a strict-`>` earliest-parent max-fold in CSR order) is
/// identical to the scalar path, so values *and* backpointers match bit
/// for bit.
fn ceft_dp_kernel_lanes<K: LaneKernel>(ws: &mut Workspace, inst: InstanceRef, rev: bool) {
    let graph = inst.graph;
    let costs = inst.costs;
    let v = inst.n();
    let p = inst.p();
    // cells/s attribution per dispatch path (no-op unless telemetry is on)
    let _obs = crate::obs::kernel_timer(K::PATH, (graph.num_edges() * p * p) as u64);
    let Workspace {
        table,
        backptr,
        panel_startup,
        panel_bw,
        ..
    } = ws;
    let (panel_startup, panel_bw): (&[f64], &[f64]) = match inst.ctx() {
        Some(ctx) => {
            debug_assert_eq!(ctx.p(), p, "ctx/platform class count mismatch");
            (ctx.panel_startup(), ctx.panel_bw())
        }
        None => {
            fill_comm_panels(inst.platform, panel_startup, panel_bw);
            (panel_startup.as_slice(), panel_bw.as_slice())
        }
    };
    table.clear();
    table.resize(v * p, 0.0);
    backptr.clear();
    backptr.resize(v * p, (usize::MAX, usize::MAX));

    let topo = graph.topo_order();
    for i in 0..topo.len() {
        let t = if rev { topo[topo.len() - 1 - i] } else { topo[i] };
        // parents of `t` in the swept orientation
        let preds = if rev { graph.succs(t) } else { graph.preds(t) };
        if preds.is_empty() {
            table[t * p..(t + 1) * p].copy_from_slice(costs.row(t));
            continue;
        }
        let crow = costs.row(t);
        let mut j0 = 0;
        while j0 < p {
            let j1 = (j0 + KERNEL_BLOCK).min(p);
            // per-block max-fold accumulators on the stack
            let mut best_total = [f64::NEG_INFINITY; KERNEL_BLOCK];
            let mut best_ptr = [(usize::MAX, usize::MAX); KERNEL_BLOCK];
            for &(k, data) in preds {
                let krow = &table[k * p..(k + 1) * p];
                for (bi, j) in (j0..j1).enumerate() {
                    // min over sender classes l: branch-free contiguous scan
                    let srow = &panel_startup[j * p..j * p + p];
                    let brow = &panel_bw[j * p..j * p + p];
                    let (best, best_l) = K::min_plus_row(krow, srow, brow, data);
                    if best > best_total[bi] {
                        best_total[bi] = best;
                        best_ptr[bi] = (k, best_l);
                    }
                }
            }
            for (bi, j) in (j0..j1).enumerate() {
                table[t * p + j] = best_total[bi] + crow[j];
                backptr[t * p + j] = best_ptr[bi];
            }
            j0 = j1;
        }
    }
}

/// The blocked min-plus matrix-matrix core shared by
/// [`ceft_dp_kernel_batch_into`] and [`ceft_table_batched_into`]: for each
/// batch row `i` (a parent CEFT row with payload `data[i]`) and each
/// destination class `j`,
/// `vals[i*P + j] = min_l rows[i*P + l] + (S[j][l] + data[i] / B[j][l])`
/// with the argmin sender class in `args` (strict `<`, lowest `l` wins —
/// the tie-break of the scalar recurrence). Destination classes are tiled
/// in [`KERNEL_BLOCK`]-sized blocks with the batch rows iterated inside
/// each block, so the block's panel rows stay resident across the whole
/// batch — the same loop interchange as the fused kernel, lifted from
/// matrix-vector to matrix-matrix.
fn batch_minplus_core<K: LaneKernel>(
    sp: &[f64],
    bp: &[f64],
    p: usize,
    rows: &[f64],
    data: &[f64],
    vals: &mut [f64],
    args: &mut [usize],
) {
    let b = data.len();
    debug_assert_eq!(rows.len(), b * p);
    debug_assert_eq!(vals.len(), b * p);
    debug_assert_eq!(args.len(), b * p);
    let mut j0 = 0;
    while j0 < p {
        let j1 = (j0 + KERNEL_BLOCK).min(p);
        for i in 0..b {
            let krow = &rows[i * p..(i + 1) * p];
            let d = data[i];
            for j in j0..j1 {
                let srow = &sp[j * p..j * p + p];
                let brow = &bp[j * p..j * p + p];
                let (best, best_l) = K::min_plus_row(krow, srow, brow, d);
                vals[i * p + j] = best;
                args[i * p + j] = best_l;
            }
        }
        j0 = j1;
    }
}

/// The batched min-plus relaxation: evaluate `B` parent CEFT rows (with
/// per-row edge payloads) against one shared resident panel pair in a
/// single blocked min-plus matrix-matrix product. `rows` is `B × P`
/// row-major, `data` holds `B` payloads; `vals`/`args` are resized to
/// `B × P` and receive the per-(row, destination) minima and their argmin
/// sender classes.
///
/// This is the CPU side of the batching layer the PJRT backend's
/// `relax_batch` artifact implements in f32 (same operands modulo
/// precision: rows ↔ `f`, panels ↔ `l`/`invbw` — both marshalled from the
/// same [`PlatformCtx`]), which is what lets the engine amortise panel
/// loads across many relaxations of one platform — per-task today
/// ([`ceft_table_batched_into`]), across queued same-platform instances
/// next (see ROADMAP).
pub fn ceft_dp_kernel_batch_into(
    ctx: &PlatformCtx,
    rows: &[f64],
    data: &[f64],
    vals: &mut Vec<f64>,
    args: &mut Vec<usize>,
) {
    ceft_dp_kernel_batch_into_dispatched(ctx, rows, data, vals, args, ctx.dispatch())
}

/// [`ceft_dp_kernel_batch_into`] with the lane implementation pinned
/// explicitly (the SIMD bit-identity tests compare both paths in one
/// process).
pub fn ceft_dp_kernel_batch_into_dispatched(
    ctx: &PlatformCtx,
    rows: &[f64],
    data: &[f64],
    vals: &mut Vec<f64>,
    args: &mut Vec<usize>,
    dispatch: KernelDispatch,
) {
    let p = ctx.p();
    let b = data.len();
    assert_eq!(rows.len(), b * p, "rows must be B x P for B = data.len()");
    vals.clear();
    vals.resize(b * p, 0.0);
    args.clear();
    args.resize(b * p, 0);
    let (sp, bp) = (ctx.panel_startup(), ctx.panel_bw());
    match dispatch {
        KernelDispatch::Simd => batch_minplus_core::<SimdLanes>(sp, bp, p, rows, data, vals, args),
        KernelDispatch::Scalar => {
            batch_minplus_core::<ScalarLanes>(sp, bp, p, rows, data, vals, args)
        }
    }
}

/// The CEFT DP driven through the batched kernel: per task, gather its
/// parent rows in chunks of `batch`, run one
/// [`ceft_dp_kernel_batch_into`]-shaped relaxation per chunk against the
/// context's resident panels, and max-fold the per-edge minima in CSR
/// order (strict `>`, earliest parent wins — the scalar recurrence's
/// tie-break). Requires a [`PlatformCtx`]-bound instance
/// ([`PlatformCtx::bind`]); forward orientation.
///
/// Bit-identical to [`ceft_table_scalar_into`] (values *and* backpointers)
/// for every `batch >= 1`: chunking changes neither the per-edge `min_l`
/// comparison sequence nor the CSR fold order — enforced by
/// `prop_batched_kernel_bit_identical_to_scalar` across
/// `batch ∈ {1, 2, 7, 8, 9}`.
pub fn ceft_table_batched_into(ws: &mut Workspace, inst: InstanceRef, batch: usize) {
    ceft_table_batched_into_dispatched(ws, inst, batch, dispatch_for(&inst))
}

/// [`ceft_table_batched_into`] with the lane implementation pinned
/// explicitly.
pub fn ceft_table_batched_into_dispatched(
    ws: &mut Workspace,
    inst: InstanceRef,
    batch: usize,
    dispatch: KernelDispatch,
) {
    match dispatch {
        KernelDispatch::Simd => ceft_table_batched_lanes::<SimdLanes>(ws, inst, batch),
        KernelDispatch::Scalar => ceft_table_batched_lanes::<ScalarLanes>(ws, inst, batch),
    }
}

/// The batched DP, monomorphised per lane implementation (see
/// [`ceft_table_batched_into`] for the contract).
fn ceft_table_batched_lanes<K: LaneKernel>(ws: &mut Workspace, inst: InstanceRef, batch: usize) {
    assert!(batch >= 1, "batch size must be at least 1");
    let ctx = inst
        .ctx()
        .expect("batched DP requires a PlatformCtx-bound instance");
    let graph = inst.graph;
    let costs = inst.costs;
    let v = inst.n();
    let p = inst.p();
    let _obs = crate::obs::kernel_timer(
        crate::obs::KernelPath::Batched,
        (graph.num_edges() * p * p) as u64,
    );
    let (sp, bp) = (ctx.panel_startup(), ctx.panel_bw());
    let Workspace {
        table,
        backptr,
        batch_rows,
        batch_data,
        batch_vals,
        batch_args,
        ..
    } = ws;
    table.clear();
    table.resize(v * p, 0.0);
    backptr.clear();
    backptr.resize(v * p, (usize::MAX, usize::MAX));

    for &t in graph.topo_order() {
        let preds = graph.preds(t);
        if preds.is_empty() {
            table[t * p..(t + 1) * p].copy_from_slice(costs.row(t));
            continue;
        }
        // the task's table row doubles as the max-fold accumulator
        table[t * p..(t + 1) * p].fill(f64::NEG_INFINITY);
        for chunk in preds.chunks(batch) {
            // gather parent rows + payloads into contiguous batch buffers
            batch_rows.clear();
            batch_data.clear();
            for &(k, data) in chunk {
                batch_rows.extend_from_slice(&table[k * p..(k + 1) * p]);
                batch_data.push(data);
            }
            batch_vals.clear();
            batch_vals.resize(chunk.len() * p, 0.0);
            batch_args.clear();
            batch_args.resize(chunk.len() * p, 0);
            batch_minplus_core::<K>(sp, bp, p, batch_rows, batch_data, batch_vals, batch_args);
            // max-fold in CSR order — the scalar recurrence's comparison
            // sequence, so backpointer ties resolve identically
            for (i, &(k, _)) in chunk.iter().enumerate() {
                for j in 0..p {
                    let arrival = batch_vals[i * p + j];
                    if arrival > table[t * p + j] {
                        table[t * p + j] = arrival;
                        backptr[t * p + j] = (k, batch_args[i * p + j]);
                    }
                }
            }
        }
        let crow = costs.row(t);
        for j in 0..p {
            table[t * p + j] += crow[j];
        }
    }
}

/// The gathered multi-instance CEFT DP: run Algorithm 1 for several
/// instances **of one platform** in lock-step, so every topo round's
/// frontier relaxations across all instances share a single blocked
/// min-plus sweep against the context's resident panels.
///
/// Round `r` gathers, for each instance whose topological order still has
/// an `r`-th task, that task's parent CEFT rows and edge payloads into one
/// contiguous batch (instances are mutually independent, so cross-instance
/// gathering never violates a dependence), runs one
/// [`ceft_dp_kernel_batch_into`]-shaped relaxation, and scatters the
/// per-edge minima back into each instance's CSR-ordered max-fold. Per
/// instance the per-edge `min_l` comparison sequence and the fold order
/// are exactly the scalar recurrence's, so every returned path — and the
/// full table behind it — is **bit-identical** to a serial
/// [`find_critical_path`] of that instance
/// (`engine_gathered_batch_matches_serial_dispatch` and the service-layer
/// tests enforce this).
///
/// This is the compute core of the service engine's cross-request
/// batching: queued same-platform requests are fanned into one call and
/// their results fanned back to the per-request single-flight cells
/// (`service::engine::BatchCollector`). Panel and table traffic amortise
/// across the whole window the same way `relax_batch` amortises them
/// across edges on the PJRT side.
pub fn find_critical_paths_gathered(
    ctx: &PlatformCtx,
    insts: &[InstanceRef],
) -> Vec<CriticalPath> {
    find_critical_paths_gathered_dispatched(ctx, insts, ctx.dispatch())
}

/// [`find_critical_paths_gathered`] with the lane implementation pinned
/// explicitly.
pub fn find_critical_paths_gathered_dispatched(
    ctx: &PlatformCtx,
    insts: &[InstanceRef],
    dispatch: KernelDispatch,
) -> Vec<CriticalPath> {
    match dispatch {
        KernelDispatch::Simd => gathered_lanes::<SimdLanes>(ctx, insts),
        KernelDispatch::Scalar => gathered_lanes::<ScalarLanes>(ctx, insts),
    }
}

/// The gathered multi-instance **table** producer: the same lock-step
/// sweep as [`find_critical_paths_gathered`], but returning each
/// instance's full [`CeftTable`] (values + backpointers) instead of just
/// its critical path. `rev` selects the orientation: `false` is the
/// forward DP of [`ceft_table_with`], `true` the transpose DP of
/// [`ceft_table_rev_with`] — each instance's own topological order is
/// swept back-to-front with successors as parents, which stays safe in
/// lock-step because instances are mutually independent and a task's
/// transposed dependences all occupy earlier reverse rounds of its own
/// order.
///
/// Every returned table is **bit-identical** to its serial producer for
/// any window width and either dispatch (enforced by
/// `gathered_tables_match_serial_for_every_width`). This is the compute
/// core behind the service engine's table memo: one gathered sweep serves
/// critical-path *and* scheduler misses of a platform's queue
/// (`service::engine`).
pub fn find_ceft_tables_gathered(
    ctx: &PlatformCtx,
    insts: &[InstanceRef],
    rev: bool,
) -> Vec<CeftTable> {
    find_ceft_tables_gathered_dispatched(ctx, insts, rev, ctx.dispatch())
}

/// [`find_ceft_tables_gathered`] with the lane implementation pinned
/// explicitly.
pub fn find_ceft_tables_gathered_dispatched(
    ctx: &PlatformCtx,
    insts: &[InstanceRef],
    rev: bool,
    dispatch: KernelDispatch,
) -> Vec<CeftTable> {
    find_ceft_tables_gathered_delta_dispatched(ctx, insts, rev, &[], dispatch)
        .into_iter()
        .map(|(t, _)| t)
        .collect()
}

/// The gathered table sweep with per-instance **delta plans**: instances
/// with a plan (`plans[i]`, missing or `None` entries mean from-scratch)
/// have their clean sweep prefix copied from the basis table and join the
/// lock-step rounds only from their first dirty position — the
/// `PendingTable` suffix offset of the service engine's batch drain. The
/// gathered delta is prefix-only (no in-suffix change propagation — the
/// lock-step rounds have no per-instance early exit), so the per-instance
/// recomputed-row count returned alongside each table is exactly
/// `topo len − clean prefix`. Tables remain bit-identical to the serial
/// producers, delta or not.
pub fn find_ceft_tables_gathered_delta(
    ctx: &PlatformCtx,
    insts: &[InstanceRef],
    rev: bool,
    plans: &[Option<DeltaPlan>],
) -> Vec<(CeftTable, usize)> {
    find_ceft_tables_gathered_delta_dispatched(ctx, insts, rev, plans, ctx.dispatch())
}

/// [`find_ceft_tables_gathered_delta`] with the lane implementation pinned
/// explicitly.
pub fn find_ceft_tables_gathered_delta_dispatched(
    ctx: &PlatformCtx,
    insts: &[InstanceRef],
    rev: bool,
    plans: &[Option<DeltaPlan>],
    dispatch: KernelDispatch,
) -> Vec<(CeftTable, usize)> {
    match dispatch {
        KernelDispatch::Simd => gathered_tables_lanes::<SimdLanes>(ctx, insts, rev, plans),
        KernelDispatch::Scalar => gathered_tables_lanes::<ScalarLanes>(ctx, insts, rev, plans),
    }
}

/// Per-instance task-row offsets inside the concatenated gathered DP
/// buffers, plus the total row count. Asserts every instance shares the
/// context's platform width.
fn gathered_offsets(ctx: &PlatformCtx, insts: &[InstanceRef]) -> (Vec<usize>, usize) {
    let p = ctx.p();
    let mut offs = Vec::with_capacity(insts.len());
    let mut total = 0usize;
    for inst in insts {
        assert_eq!(
            inst.p(),
            p,
            "gathered instances must share the context's platform"
        );
        offs.push(total);
        total += inst.n();
    }
    (offs, total)
}

/// The lock-step round sweep shared by the path-producing
/// ([`find_critical_paths_gathered`]) and table-producing
/// ([`find_ceft_tables_gathered`]) gathered entry points: fill the
/// concatenated `ws.table` / `ws.backptr` for every instance at the row
/// offsets in `offs`. All DP state lives in the one workspace, so
/// steady-state gathers allocate nothing beyond the caller's returned
/// results — the workspace contract of every other kernel, with
/// capacity's high-water mark at `window × instance size`.
///
/// Round `r` gathers, for each instance whose topological order still has
/// an `r`-th task in the swept orientation (`topo[r]` forward,
/// `topo[len-1-r]` reverse), that task's parent rows and edge payloads
/// into one contiguous batch, runs one [`batch_minplus_core`] relaxation
/// against the shared resident panels, and scatters the per-edge minima
/// back into each instance's CSR-ordered strict-`>` max-fold. Per
/// instance the per-edge `min_l` comparison sequence and the fold order
/// are exactly the scalar recurrence's, so values *and* backpointers are
/// bit-identical to the serial DP of the same orientation.
fn gathered_dp_fill<K: LaneKernel>(
    ctx: &PlatformCtx,
    insts: &[InstanceRef],
    rev: bool,
    offs: &[usize],
    total: usize,
    plans: &[Option<DeltaPlan>],
    ws: &mut Workspace,
) -> Vec<usize> {
    let p = ctx.p();
    // per-instance clean-prefix lengths (0 without a plan): sweep
    // positions below the start are copied from the basis, positions at
    // or past it join the lock-step rounds
    let starts: Vec<usize> = insts
        .iter()
        .enumerate()
        .map(|(i, inst)| match plans.get(i).and_then(Option::as_ref) {
            Some(plan) => delta_clean_prefix(inst.graph.topo_order(), plan, rev),
            None => 0,
        })
        .collect();
    let gathered_cells: usize = insts
        .iter()
        .zip(&starts)
        .map(|(inst, &start)| {
            let topo = inst.graph.topo_order();
            (start..topo.len())
                .map(|i| {
                    let t = if rev { topo[topo.len() - 1 - i] } else { topo[i] };
                    let deg = if rev {
                        inst.graph.out_degree(t)
                    } else {
                        inst.graph.in_degree(t)
                    };
                    deg * p * p
                })
                .sum::<usize>()
        })
        .sum();
    let _obs = crate::obs::kernel_timer(crate::obs::KernelPath::Gathered, gathered_cells as u64);
    let (sp, bp) = (ctx.panel_startup(), ctx.panel_bw());
    let rounds = insts
        .iter()
        .map(|i| i.graph.topo_order().len())
        .max()
        .unwrap_or(0);
    let Workspace {
        table,
        backptr,
        batch_rows,
        batch_data,
        batch_vals,
        batch_args,
        gather_seg,
        ..
    } = ws;
    table.clear();
    table.resize(total * p, 0.0);
    backptr.clear();
    backptr.resize(total * p, (usize::MAX, usize::MAX));
    // clean prefixes: copy basis rows before the rounds begin, so suffix
    // relaxations read them exactly as a from-scratch sweep would have
    // produced them
    for (i, inst) in insts.iter().enumerate() {
        let Some(plan) = plans.get(i).and_then(Option::as_ref) else {
            continue;
        };
        let topo = inst.graph.topo_order();
        for pos in 0..starts[i] {
            let t = if rev { topo[topo.len() - 1 - pos] } else { topo[pos] };
            let base = (offs[i] + t) * p;
            table[base..base + p].copy_from_slice(&plan.prev.table[t * p..(t + 1) * p]);
            backptr[base..base + p].copy_from_slice(&plan.prev.backptr[t * p..(t + 1) * p]);
        }
    }
    for r in 0..rounds {
        batch_rows.clear();
        batch_data.clear();
        gather_seg.clear();
        for (i, inst) in insts.iter().enumerate() {
            let topo = inst.graph.topo_order();
            if r >= topo.len() || r < starts[i] {
                continue;
            }
            let t = if rev { topo[topo.len() - 1 - r] } else { topo[r] };
            let base = (offs[i] + t) * p;
            // parents of `t` in the swept orientation
            let preds = if rev {
                inst.graph.succs(t)
            } else {
                inst.graph.preds(t)
            };
            if preds.is_empty() {
                table[base..base + p].copy_from_slice(inst.costs.row(t));
                continue;
            }
            for &(k, data) in preds {
                let krow = (offs[i] + k) * p;
                batch_rows.extend_from_slice(&table[krow..krow + p]);
                batch_data.push(data);
            }
            gather_seg.push((i, t, preds.len()));
        }
        if batch_data.is_empty() {
            continue;
        }
        batch_vals.clear();
        batch_vals.resize(batch_data.len() * p, 0.0);
        batch_args.clear();
        batch_args.resize(batch_data.len() * p, 0);
        batch_minplus_core::<K>(sp, bp, p, batch_rows, batch_data, batch_vals, batch_args);
        // scatter: per (instance, task) max-fold in CSR order — the
        // scalar recurrence's comparison sequence, so backpointer ties
        // resolve identically
        let mut off = 0;
        for &(i, t, cnt) in gather_seg.iter() {
            let inst = &insts[i];
            let base = (offs[i] + t) * p;
            table[base..base + p].fill(f64::NEG_INFINITY);
            let preds = if rev {
                inst.graph.succs(t)
            } else {
                inst.graph.preds(t)
            };
            for (e, &(k, _)) in preds.iter().enumerate() {
                let row = off + e;
                for j in 0..p {
                    let arrival = batch_vals[row * p + j];
                    if arrival > table[base + j] {
                        table[base + j] = arrival;
                        backptr[base + j] = (k, batch_args[row * p + j]);
                    }
                }
            }
            let crow = inst.costs.row(t);
            for j in 0..p {
                table[base + j] += crow[j];
            }
            off += cnt;
        }
    }
    starts
}

/// The gathered path DP, monomorphised per lane implementation (see
/// [`find_critical_paths_gathered`]): one [`gathered_dp_fill`] forward
/// sweep, then per-instance sink selection over the concatenated buffers.
fn gathered_lanes<K: LaneKernel>(ctx: &PlatformCtx, insts: &[InstanceRef]) -> Vec<CriticalPath> {
    if insts.is_empty() {
        return Vec::new();
    }
    let p = ctx.p();
    let (offs, total) = gathered_offsets(ctx, insts);
    ctx.with_workspace(|ws| {
        gathered_dp_fill::<K>(ctx, insts, false, &offs, total, &[], ws);
        let Workspace {
            table,
            backptr,
            steps,
            ..
        } = ws;
        insts
            .iter()
            .enumerate()
            .map(|(i, inst)| {
                let range = offs[i] * p..(offs[i] + inst.n()) * p;
                critical_path_from_parts(
                    inst.graph,
                    p,
                    &table[range.clone()],
                    &backptr[range],
                    steps,
                )
            })
            .collect()
    })
}

/// The gathered table DP, monomorphised per lane implementation (see
/// [`find_ceft_tables_gathered`]): one [`gathered_dp_fill`] sweep in the
/// requested orientation, then per-instance ranges copied out as owned
/// tables (the copies outlive the pooled workspace, exactly like
/// [`ceft_table_with`]).
fn gathered_tables_lanes<K: LaneKernel>(
    ctx: &PlatformCtx,
    insts: &[InstanceRef],
    rev: bool,
    plans: &[Option<DeltaPlan>],
) -> Vec<(CeftTable, usize)> {
    if insts.is_empty() {
        return Vec::new();
    }
    let p = ctx.p();
    let (offs, total) = gathered_offsets(ctx, insts);
    ctx.with_workspace(|ws| {
        let starts = gathered_dp_fill::<K>(ctx, insts, rev, &offs, total, plans, ws);
        insts
            .iter()
            .enumerate()
            .map(|(i, inst)| {
                let range = offs[i] * p..(offs[i] + inst.n()) * p;
                let recomputed = inst.graph.topo_order().len() - starts[i];
                (
                    CeftTable {
                        p,
                        table: ws.table[range.clone()].to_vec(),
                        backptr: ws.backptr[range].to_vec(),
                    },
                    recomputed,
                )
            })
            .collect()
    })
}

/// The scalar DP behind both orientations — the pre-kernel reference.
/// `rev` selects the sweep (forward topo over `preds` vs reverse topo over
/// `succs`); every comparison — `NEG_INFINITY` init, strict `>` over
/// parents, strict `<` with lowest-`l` tie-break over classes — matches
/// the kernel path exactly.
fn ceft_dp_scalar_into(ws: &mut Workspace, inst: InstanceRef, rev: bool) {
    let graph = inst.graph;
    let platform = inst.platform;
    let costs = inst.costs;
    let v = inst.n();
    let p = inst.p();
    let table = &mut ws.table;
    let backptr = &mut ws.backptr;
    table.clear();
    table.resize(v * p, 0.0);
    backptr.clear();
    backptr.resize(v * p, (usize::MAX, usize::MAX));

    let topo = graph.topo_order();
    for i in 0..topo.len() {
        let t = if rev { topo[topo.len() - 1 - i] } else { topo[i] };
        // parents of `t` in the swept orientation
        let preds = if rev { graph.succs(t) } else { graph.preds(t) };
        if preds.is_empty() {
            for j in 0..p {
                table[t * p + j] = costs.get(t, j);
            }
            continue;
        }
        for j in 0..p {
            // lines 6-18 of Algorithm 1, specialised to destination class j
            let mut best_total = f64::NEG_INFINITY; // max over parents
            let mut best_ptr = (usize::MAX, usize::MAX);
            for &(k, data) in preds {
                // min over parent classes l
                let krow = &table[k * p..(k + 1) * p];
                let mut min_arrival = f64::INFINITY;
                let mut min_l = 0usize;
                for (l, &ceft_kl) in krow.iter().enumerate() {
                    let arrival = ceft_kl + platform.comm_cost(l, j, data);
                    if arrival < min_arrival {
                        min_arrival = arrival;
                        min_l = l;
                    }
                }
                if min_arrival > best_total {
                    best_total = min_arrival;
                    best_ptr = (k, min_l);
                }
            }
            table[t * p + j] = best_total + costs.get(t, j);
            backptr[t * p + j] = best_ptr;
        }
    }
}

/// Algorithm 1 in full: compute the CEFT table, select the critical sink
/// (lines 21–26: per sink, minimise over classes; across sinks, maximise
/// the minimised cost), and reconstruct the path with its assignment.
/// Convenience wrapper over [`find_critical_path_with`] with a one-shot
/// workspace.
pub fn find_critical_path(inst: InstanceRef) -> CriticalPath {
    find_critical_path_with(&mut Workspace::new(), inst)
}

/// Deprecated raw-triple shim at the service/JSON boundary: copies `comp`
/// into a fresh [`crate::model::CostMatrix`] and forwards to
/// [`find_critical_path`].
#[deprecated(note = "build a CostMatrix + InstanceRef and call find_critical_path")]
pub fn find_critical_path_raw(
    graph: &TaskGraph,
    platform: &Platform,
    comp: &[f64],
) -> CriticalPath {
    let costs = crate::model::cost_matrix_from_raw(platform.num_classes(), comp);
    find_critical_path(InstanceRef::new(graph, platform, &costs))
}

/// Workspace-backed Algorithm 1 — the hot path of the online service. All
/// scratch (DP table, backpointers, comm panels, backtracking stack) lives
/// in `ws`; the only allocation is the returned path itself, sized exactly.
pub fn find_critical_path_with(ws: &mut Workspace, inst: InstanceRef) -> CriticalPath {
    ceft_table_into(ws, inst);
    let p = inst.p();
    let Workspace { table, backptr, steps, .. } = ws;
    critical_path_from_parts(inst.graph, p, table, backptr, steps)
}

/// Sink selection + backtracking over borrowed DP buffers — the single
/// implementation behind both [`find_critical_path_with`] (workspace
/// buffers) and [`critical_path_from_table`] (owned table, e.g. filled on
/// the PJRT accelerator), so the tie-break rules cannot desynchronise the
/// backends. `steps` is backtracking scratch; the returned path is the
/// only allocation.
fn critical_path_from_parts(
    graph: &TaskGraph,
    p: usize,
    table: &[f64],
    backptr: &[(usize, usize)],
    steps: &mut Vec<PathStep>,
) -> CriticalPath {
    // sink selection (lines 21-26), iterating sinks in ascending id order
    // with strict-`>` comparison so the lowest-id sink wins ties; per sink
    // the lowest-id minimising class wins via strict `<`.
    let mut best: Option<(usize, usize, f64)> = None;
    for t in 0..graph.num_tasks() {
        if graph.out_degree(t) != 0 {
            continue;
        }
        let row = &table[t * p..(t + 1) * p];
        let mut c = 0usize;
        for j in 1..p {
            if row[j] < row[c] {
                c = j;
            }
        }
        let cost = row[c];
        match best {
            Some((_, _, best_cost)) if cost <= best_cost => {}
            _ => best = Some((t, c, cost)),
        }
    }
    let (mut task, mut class, length) = best.expect("graph has no sinks");
    // backtrack into the scratch buffer, then emit in forward order
    steps.clear();
    loop {
        steps.push(PathStep { task, class });
        let (pk, pl) = backptr[task * p + class];
        if pk == usize::MAX {
            break;
        }
        task = pk;
        class = pl;
    }
    CriticalPath {
        length,
        path: steps.iter().rev().copied().collect(),
    }
}

/// Path selection + reconstruction given a precomputed table (used by the
/// PJRT backend, which fills the table on the accelerator).
pub fn critical_path_from_table(graph: &TaskGraph, t: &CeftTable) -> CriticalPath {
    critical_path_from_parts(graph, t.p, &t.table, &t.backptr, &mut Vec::new())
}

/// Per-task slack from a **forward** CEFT table: the largest uniform rise
/// of a task's CEFT row that provably leaves the critical-path length
/// unchanged — the CPM "total float" idiom, adapted to the max-of-min
/// recurrence. Two passes over the forward table only:
///
/// 1. rebuild the per-`(task, class)` arrival fold
///    `m(u, j) = max_k contrib_k(u, j)` with
///    `contrib_k(u, j) = min_l (CEFT(k, l) + comm(l, j, data))`, using the
///    same [`ScalarLanes::min_plus_row`] float ops and the same CSR parent
///    order the kernel folded — so the realized argmax parent's gap
///    `m(u, j) − contrib_k(u, j)` is an exact float `0.0`;
/// 2. reverse-topo recursion: sinks get
///    `slack(t) = CPL − min_j CEFT(t, ·)`, interior tasks
///    `slack(t) = min_u (slack(u) + min_j (m(u, j) − contrib_t(u, j)))`.
///
/// A uniform rise `δ` of `CEFT(t, ·)` raises `contrib_t(u, j)` by exactly
/// `δ`, so `CEFT(u, j)` rises by at most `max(0, δ − gap_j)`; bounding
/// that by `slack(u)` for every class gives the recursion. Guarantees:
/// `slack(t) ≥ 0` everywhere (gaps are non-negative by the max-fold) and
/// `slack(t) == 0.0` **exactly** along the backpointer critical path — at
/// each hop the realized parent's gap at the realized class is bit-zero
/// and the sink anchor is `CPL − CPL`. Returns
/// `CPL = max_sinks min_j CEFT(t, ·)`; `out` receives the `v` slacks.
pub fn slack_from_table_with(
    ws: &mut Workspace,
    inst: InstanceRef,
    fwd: &CeftTable,
    out: &mut Vec<f64>,
) -> f64 {
    let graph = inst.graph;
    let v = inst.n();
    let p = inst.p();
    assert_eq!(fwd.p, p, "table/platform class count mismatch");
    assert_eq!(fwd.table.len(), v * p, "table/graph size mismatch");
    let Workspace {
        slack_m,
        panel_startup,
        panel_bw,
        ..
    } = ws;
    let (panel_startup, panel_bw): (&[f64], &[f64]) = match inst.ctx() {
        Some(ctx) => {
            debug_assert_eq!(ctx.p(), p, "ctx/platform class count mismatch");
            (ctx.panel_startup(), ctx.panel_bw())
        }
        None => {
            fill_comm_panels(inst.platform, panel_startup, panel_bw);
            (panel_startup.as_slice(), panel_bw.as_slice())
        }
    };
    // pass 1: the arrival fold `m(u, j)`, bit-for-bit as the kernel built
    // it (sources keep `−∞` rows; they are never read below)
    slack_m.clear();
    slack_m.resize(v * p, f64::NEG_INFINITY);
    for u in 0..v {
        let preds = graph.preds(u);
        if preds.is_empty() {
            continue;
        }
        let mrow = &mut slack_m[u * p..(u + 1) * p];
        for &(k, data) in preds {
            let krow = &fwd.table[k * p..(k + 1) * p];
            for (j, m) in mrow.iter_mut().enumerate() {
                let srow = &panel_startup[j * p..j * p + p];
                let brow = &panel_bw[j * p..j * p + p];
                let (arrival, _) = ScalarLanes::min_plus_row(krow, srow, brow, data);
                if arrival > *m {
                    *m = arrival;
                }
            }
        }
    }
    // pass 2: reverse topo, anchored at the sinks' distance to the CPL
    let mut cpl = f64::NEG_INFINITY;
    for t in 0..v {
        if graph.out_degree(t) == 0 {
            cpl = cpl.max(fwd.min_over_classes(t));
        }
    }
    out.clear();
    out.resize(v, 0.0);
    let topo = graph.topo_order();
    for &t in topo.iter().rev() {
        let succs = graph.succs(t);
        if succs.is_empty() {
            out[t] = (cpl - fwd.min_over_classes(t)).max(0.0);
            continue;
        }
        let krow = &fwd.table[t * p..(t + 1) * p];
        let mut slack = f64::INFINITY;
        for &(u, data) in succs {
            let mrow = &slack_m[u * p..(u + 1) * p];
            let mut gap = f64::INFINITY;
            for (j, &m) in mrow.iter().enumerate() {
                let srow = &panel_startup[j * p..j * p + p];
                let brow = &panel_bw[j * p..j * p + p];
                let (arrival, _) = ScalarLanes::min_plus_row(krow, srow, brow, data);
                let g = m - arrival;
                if g < gap {
                    gap = g;
                }
            }
            let cand = out[u] + gap;
            if cand < slack {
                slack = cand;
            }
        }
        out[t] = slack.max(0.0);
    }
    cpl
}

/// Evaluate the CEFT length of a *given* path (sequence of tasks connected
/// by edges) under its *optimal* assignment — a restricted CEFT DP over a
/// chain. Used in tests and to score other algorithms' paths under the
/// paper's Definition 7 measure.
pub fn chain_optimal_length(inst: InstanceRef, tasks: &[usize]) -> f64 {
    let graph = inst.graph;
    let platform = inst.platform;
    let costs = inst.costs;
    let p = inst.p();
    assert!(!tasks.is_empty());
    let mut cur: Vec<f64> = costs.row(tasks[0]).to_vec();
    for w in tasks.windows(2) {
        let (a, b) = (w[0], w[1]);
        let data = graph
            .succs(a)
            .iter()
            .find(|&&(d, _)| d == b)
            .map(|&(_, data)| data)
            .unwrap_or_else(|| panic!("path edge {a}->{b} not in graph"));
        let next: Vec<f64> = (0..p)
            .map(|j| {
                let mut best = f64::INFINITY;
                for (l, &c) in cur.iter().enumerate() {
                    best = best.min(c + platform.comm_cost(l, j, data));
                }
                best + costs.get(b, j)
            })
            .collect();
        cur = next;
    }
    cur.iter().fold(f64::INFINITY, |a, &b| a.min(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskGraph;
    use crate::model::CostMatrix;
    use crate::platform::Platform;

    /// Single chain 0 -> 1 -> 2: CEFT must pick per-task best classes when
    /// comm is free, and trade off comm when it is not.
    #[test]
    fn chain_zero_comm_picks_per_task_minimum() {
        let g = TaskGraph::from_edges(3, &[(0, 1, 100.0), (1, 2, 100.0)]);
        let plat = Platform::uniform(2, 1e12, 0.0); // effectively free comm
        #[rustfmt::skip]
        let comp = CostMatrix::new(2, vec![
            1.0, 10.0, // task 0 best on class 0
            10.0, 2.0, // task 1 best on class 1
            3.0, 10.0, // task 2 best on class 0
        ]);
        let cp = find_critical_path(InstanceRef::new(&g, &plat, &comp));
        assert!((cp.length - 6.0).abs() < 1e-6, "len={}", cp.length);
        assert_eq!(
            cp.path,
            vec![
                PathStep { task: 0, class: 0 },
                PathStep { task: 1, class: 1 },
                PathStep { task: 2, class: 0 },
            ]
        );
    }

    #[test]
    fn chain_expensive_comm_collapses_to_one_class() {
        let g = TaskGraph::from_edges(3, &[(0, 1, 1000.0), (1, 2, 1000.0)]);
        let plat = Platform::uniform(2, 1.0, 0.0); // comm cost = data = 1000
        #[rustfmt::skip]
        let comp = CostMatrix::new(2, vec![
            1.0, 10.0,
            10.0, 2.0,
            3.0, 10.0,
        ]);
        let cp = find_critical_path(InstanceRef::new(&g, &plat, &comp));
        // staying on class 0: 1 + 10 + 3 = 14; class 1: 10+2+10=22; mixing
        // costs 1000 per hop. CEFT must stay on class 0.
        assert!((cp.length - 14.0).abs() < 1e-6, "len={}", cp.length);
        assert!(cp.path.iter().all(|s| s.class == 0));
    }

    /// The motivating example from §1: averaging misidentifies the path.
    /// GPU-like class is 10x faster on array tasks, hopeless on scalar code.
    #[test]
    fn ceft_beats_averaging_on_cpu_gpu_example() {
        // two parallel chains 0->1->3 (array tasks) and 0->2->3 (scalar)
        let g = TaskGraph::from_edges(
            4,
            &[(0, 1, 1.0), (0, 2, 1.0), (1, 3, 1.0), (2, 3, 1.0)],
        );
        let plat = Platform::uniform(2, 1.0, 0.0);
        #[rustfmt::skip]
        let comp = CostMatrix::new(2, vec![
            // cpu,  gpu
            5.0,   5.0,   // 0: neutral
            100.0, 10.0,  // 1: array task, GPU 10x faster
            12.0,  120.0, // 2: scalar task, GPU hopeless
            5.0,   5.0,   // 3: neutral
        ]);
        let cp = find_critical_path(InstanceRef::new(&g, &plat, &comp));
        // optimal: through task 2 on cpu: 5+~1+12+~1+5 = 24ish vs through
        // task 1 on gpu: 5+1+10+1+5 = 22ish -> CP goes through task 2.
        assert!(cp.tasks().contains(&2), "path={:?}", cp.path);
        // averaging would put 55 on task 1 and 66 on task 2 and also pick
        // task 2's chain — but with grossly wrong length (83 vs ~24).
        assert!(cp.length < 30.0, "len={}", cp.length);
    }

    #[test]
    fn multi_sink_selects_longest_min() {
        // 0 -> 1 (cheap sink), 0 -> 2 (expensive sink)
        let g = TaskGraph::from_edges(3, &[(0, 1, 0.0), (0, 2, 0.0)]);
        let plat = Platform::uniform(2, 1.0, 0.0);
        #[rustfmt::skip]
        let comp = CostMatrix::new(2, vec![
            1.0, 1.0,
            2.0, 2.0,
            50.0, 40.0,
        ]);
        let cp = find_critical_path(InstanceRef::new(&g, &plat, &comp));
        assert_eq!(cp.path.last().unwrap().task, 2);
        assert!((cp.length - 41.0).abs() < 1e-9);
        assert_eq!(cp.path.last().unwrap().class, 1);
    }

    #[test]
    fn table_matches_brute_force_on_small_graphs() {
        // Exhaustive check of Definition 8 / Algorithm 1 semantics on a
        // diamond with P=2. Per sink class j, the DP value is
        //   max over paths of (optimal assignment of the path with the sink
        //   fixed on class j),
        // and the final CPL is min over j of that (lines 21-26). We verify
        // exact equality against brute force, and that the CPL upper-bounds
        // the weaker per-path-isolated measure (min_j inside the max) —
        // the distinction §4.1's task-duplication discussion turns on.
        let g = TaskGraph::from_edges(
            4,
            &[(0, 1, 3.0), (0, 2, 7.0), (1, 3, 4.0), (2, 3, 2.0)],
        );
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        for _ in 0..50 {
            let comp =
                CostMatrix::new(2, (0..8).map(|_| rng.uniform(1.0, 20.0)).collect());
            let plat = Platform::uniform(2, rng.uniform(0.5, 2.0), rng.uniform(0.0, 1.0));
            let cp = find_critical_path(InstanceRef::new(&g, &plat, &comp));
            // brute force path cost with the sink's class fixed to `jfix`
            // (None = free)
            let brute = |path: &[usize], jfix: Option<usize>| {
                let p = 2usize;
                let mut best = f64::INFINITY;
                for assign in 0..p.pow(path.len() as u32) {
                    let classes: Vec<usize> =
                        (0..path.len()).map(|i| (assign >> i) & 1).collect();
                    if let Some(j) = jfix {
                        if *classes.last().unwrap() != j {
                            continue;
                        }
                    }
                    let mut t = 0.0;
                    for (i, &task) in path.iter().enumerate() {
                        if i > 0 {
                            let data = g
                                .succs(path[i - 1])
                                .iter()
                                .find(|&&(d, _)| d == task)
                                .unwrap()
                                .1;
                            t += plat.comm_cost(classes[i - 1], classes[i], data);
                        }
                        t += comp.get(task, classes[i]);
                    }
                    best = best.min(t);
                }
                best
            };
            let paths: [&[usize]; 2] = [&[0, 1, 3], &[0, 2, 3]];
            // exact Algorithm-1 semantics: min_j max_path cost(path | sink=j)
            let exact = (0..2)
                .map(|j| {
                    paths
                        .iter()
                        .map(|p| brute(p, Some(j)))
                        .fold(f64::NEG_INFINITY, f64::max)
                })
                .fold(f64::INFINITY, f64::min);
            assert!(
                (cp.length - exact).abs() < 1e-9,
                "ceft={} exact={exact}",
                cp.length
            );
            // ordering vs the per-path-isolated measure
            let isolated = paths
                .iter()
                .map(|p| brute(p, None))
                .fold(f64::NEG_INFINITY, f64::max);
            assert!(
                cp.length >= isolated - 1e-9,
                "ceft={} < isolated={isolated}",
                cp.length
            );
        }
    }

    #[test]
    fn path_is_connected_and_assignment_consistent() {
        let g = crate::graph::generator::generate(
            &crate::graph::generator::RggParams {
                n: 200,
                out_degree: 4,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 75.0,
                gamma: 0.3,
            },
            &crate::platform::CostModel::Classic { beta: 0.75 },
            &Platform::uniform(4, 1.0, 0.0),
            17,
        );
        let plat = Platform::uniform(4, 1.0, 0.0);
        let inst = g.bind(&plat);
        let cp = find_critical_path(inst);
        // connected: consecutive tasks joined by an edge
        for w in cp.path.windows(2) {
            assert!(
                g.graph.succs(w[0].task).iter().any(|&(d, _)| d == w[1].task),
                "no edge {} -> {}",
                w[0].task,
                w[1].task
            );
        }
        // starts at a source, ends at a sink
        assert_eq!(g.graph.in_degree(cp.path[0].task), 0);
        assert_eq!(g.graph.out_degree(cp.path.last().unwrap().task), 0);
        // the chain evaluated under its optimal assignment equals length
        let chain_len = chain_optimal_length(inst, &cp.tasks());
        assert!(
            chain_len <= cp.length + 1e-9,
            "chain opt {chain_len} > ceft {}",
            cp.length
        );
    }

    #[test]
    fn kernel_tables_bit_identical_to_scalar_reference() {
        // The blocked min-plus kernel must reproduce the scalar recurrence
        // bit for bit — values AND backpointers, both orientations — on a
        // platform with asymmetric links and nonzero startup (the case
        // where the panel diagonal trick could plausibly diverge).
        let inst = crate::graph::generator::generate(
            &crate::graph::generator::RggParams {
                n: 160,
                out_degree: 4,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 75.0,
                gamma: 0.3,
            },
            &crate::platform::CostModel::Classic { beta: 0.75 },
            &Platform::uniform(5, 1.0, 0.0),
            31,
        );
        let mut rng = crate::util::rng::Xoshiro256::new(14);
        let plat = Platform::random_links(5, &mut rng, 0.3, 3.0, 0.0, 0.7);
        let iref = inst.bind(&plat);
        let mut kw = Workspace::new();
        let mut sw = Workspace::new();
        ceft_table_into(&mut kw, iref);
        ceft_table_scalar_into(&mut sw, iref);
        assert_eq!(kw.table, sw.table);
        assert_eq!(kw.backptr, sw.backptr);
        ceft_table_rev_into(&mut kw, iref);
        ceft_table_rev_scalar_into(&mut sw, iref);
        assert_eq!(kw.table, sw.table);
        assert_eq!(kw.backptr, sw.backptr);
    }

    #[test]
    fn rev_table_matches_transposed_table_bit_for_bit() {
        // `ceft_table_rev_into` must equal the DP over the materialised
        // transpose exactly (values AND backpointers) — the CEFT upward
        // rank's correctness rests on this.
        let inst = crate::graph::generator::generate(
            &crate::graph::generator::RggParams {
                n: 150,
                out_degree: 4,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 75.0,
                gamma: 0.3,
            },
            &crate::platform::CostModel::Classic { beta: 0.75 },
            &Platform::uniform(4, 1.0, 0.0),
            29,
        );
        let mut rng = crate::util::rng::Xoshiro256::new(92);
        // asymmetric links to exercise the comm direction too
        let plat = Platform::random_links(4, &mut rng, 0.3, 3.0, 0.0, 0.5);
        let transposed = inst.graph.transpose();
        let via_transpose =
            ceft_table(InstanceRef::new(&transposed, &plat, &inst.comp));
        let mut ws = crate::cp::workspace::Workspace::new();
        ceft_table_rev_into(&mut ws, inst.bind(&plat));
        assert_eq!(ws.table, via_transpose.table);
        assert_eq!(ws.backptr, via_transpose.backptr);
    }

    #[test]
    fn workspace_path_matches_owned_path() {
        let inst = crate::graph::generator::generate(
            &crate::graph::generator::RggParams {
                n: 120,
                out_degree: 3,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.2,
            },
            &crate::platform::CostModel::Classic { beta: 0.5 },
            &Platform::uniform(3, 1.0, 0.0),
            7,
        );
        let plat = Platform::uniform(3, 1.0, 0.0);
        let iref = inst.bind(&plat);
        let owned = {
            let t = ceft_table(iref);
            critical_path_from_table(&inst.graph, &t)
        };
        let mut ws = crate::cp::workspace::Workspace::new();
        let a = find_critical_path_with(&mut ws, iref);
        let b = find_critical_path_with(&mut ws, iref);
        assert_eq!(owned, a);
        assert_eq!(a, b, "workspace reuse must be bit-identical");
    }

    #[test]
    fn assignment_dense_mirrors_hashmap_assignment() {
        let g = TaskGraph::from_edges(3, &[(0, 1, 1.0), (1, 2, 1.0)]);
        let plat = Platform::uniform(2, 1.0, 0.0);
        let comp = CostMatrix::new(2, vec![1.0, 5.0, 5.0, 1.0, 2.0, 9.0]);
        let cp = find_critical_path(InstanceRef::new(&g, &plat, &comp));
        let dense = cp.assignment_dense(3);
        let map = cp.assignment();
        for t in 0..3 {
            assert_eq!(dense[t], map.get(&t).copied(), "task {t}");
        }
        assert_eq!(dense.iter().filter(|c| c.is_some()).count(), cp.path.len());
    }

    #[test]
    fn single_task_graph() {
        let g = TaskGraph::from_edges(1, &[]);
        let plat = Platform::uniform(3, 1.0, 0.0);
        let comp = CostMatrix::new(3, vec![5.0, 3.0, 4.0]);
        let cp = find_critical_path(InstanceRef::new(&g, &plat, &comp));
        assert_eq!(cp.length, 3.0);
        assert_eq!(cp.path, vec![PathStep { task: 0, class: 1 }]);
    }

    #[test]
    fn ceft_length_at_least_min_comp_of_any_path_task() {
        let g = TaskGraph::from_edges(3, &[(0, 1, 1.0), (1, 2, 1.0)]);
        let plat = Platform::uniform(2, 1.0, 0.1);
        let comp = CostMatrix::new(2, vec![4.0, 6.0, 3.0, 9.0, 2.0, 8.0]);
        let cp = find_critical_path(InstanceRef::new(&g, &plat, &comp));
        // lower bound: sum of per-task minima (comm >= 0)
        assert!(cp.length >= 4.0 + 3.0 + 2.0 - 1e-9);
    }

    #[test]
    fn ctx_bound_kernel_is_bit_identical_and_skips_panel_fill() {
        // Same instance through a PlatformCtx-bound view and a plain view:
        // identical tables/backpointers, and the bound run must leave the
        // workspace's fallback panel buffers untouched — the proof that
        // the hot loop reads the resident panels instead of refilling.
        let inst = crate::graph::generator::generate(
            &crate::graph::generator::RggParams {
                n: 140,
                out_degree: 4,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 75.0,
                gamma: 0.3,
            },
            &crate::platform::CostModel::Classic { beta: 0.75 },
            &Platform::uniform(5, 1.0, 0.0),
            61,
        );
        let mut rng = crate::util::rng::Xoshiro256::new(62);
        let plat = Platform::random_links(5, &mut rng, 0.3, 3.0, 0.1, 0.7);
        let ctx = crate::model::PlatformCtx::new(plat.clone());
        let mut plain_ws = Workspace::new();
        let mut ctx_ws = Workspace::new();
        for rev in [false, true] {
            let run: fn(&mut Workspace, InstanceRef) = if rev {
                ceft_table_rev_into
            } else {
                ceft_table_into
            };
            run(&mut plain_ws, inst.bind(&plat));
            run(&mut ctx_ws, inst.bind_ctx(&ctx));
            assert_eq!(plain_ws.table, ctx_ws.table, "rev={rev}");
            assert_eq!(plain_ws.backptr, ctx_ws.backptr, "rev={rev}");
            assert!(!plain_ws.panel_startup.is_empty(), "fallback fills panels");
            assert!(
                ctx_ws.panel_startup.is_empty() && ctx_ws.panel_bw.is_empty(),
                "ctx-bound run must not fill workspace panels (rev={rev})"
            );
        }
        // the full critical path agrees too
        assert_eq!(
            find_critical_path(inst.bind(&plat)),
            find_critical_path_with(&mut ctx_ws, inst.bind_ctx(&ctx))
        );
    }

    #[test]
    fn batched_table_matches_scalar_for_every_chunk_size() {
        let inst = crate::graph::generator::generate(
            &crate::graph::generator::RggParams {
                n: 130,
                out_degree: 5,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 75.0,
                gamma: 0.3,
            },
            &crate::platform::CostModel::Classic { beta: 0.75 },
            &Platform::uniform(4, 1.0, 0.0),
            77,
        );
        let mut rng = crate::util::rng::Xoshiro256::new(78);
        let plat = Platform::random_links(4, &mut rng, 0.3, 3.0, 0.0, 0.6);
        let ctx = crate::model::PlatformCtx::new(plat.clone());
        let mut sw = Workspace::new();
        ceft_table_scalar_into(&mut sw, inst.bind(&plat));
        let mut bw = Workspace::new();
        for batch in [1usize, 3, 8, 64] {
            ceft_table_batched_into(&mut bw, inst.bind_ctx(&ctx), batch);
            assert_eq!(bw.table, sw.table, "batch={batch}");
            assert_eq!(bw.backptr, sw.backptr, "batch={batch}");
        }
    }

    #[test]
    fn batch_primitive_matches_scalar_relaxation() {
        // standalone B x P relaxation against hand-rolled scalar minima
        let mut rng = crate::util::rng::Xoshiro256::new(91);
        let p = 3;
        let plat = Platform::random_links(p, &mut rng, 0.4, 2.5, 0.0, 1.0);
        let ctx = crate::model::PlatformCtx::new(plat.clone());
        let b = 5;
        let rows: Vec<f64> = (0..b * p).map(|_| rng.uniform(0.0, 40.0)).collect();
        let data: Vec<f64> = (0..b).map(|_| rng.uniform(0.0, 20.0)).collect();
        let mut vals = Vec::new();
        let mut args = Vec::new();
        ceft_dp_kernel_batch_into(&ctx, &rows, &data, &mut vals, &mut args);
        for i in 0..b {
            for j in 0..p {
                let mut best = f64::INFINITY;
                let mut best_l = 0;
                for l in 0..p {
                    let cand = rows[i * p + l] + plat.comm_cost(l, j, data[i]);
                    if cand < best {
                        best = cand;
                        best_l = l;
                    }
                }
                assert_eq!(vals[i * p + j].to_bits(), best.to_bits(), "({i},{j})");
                assert_eq!(args[i * p + j], best_l, "({i},{j})");
            }
        }
    }

    #[test]
    fn gathered_paths_match_serial_for_every_width() {
        // K instances of different sizes on one platform, run through the
        // gathered lock-step DP under both dispatches: every path must be
        // bit-identical to its serial computation.
        let mut rng = crate::util::rng::Xoshiro256::new(55);
        let plat = Platform::random_links(5, &mut rng, 0.3, 3.0, 0.1, 0.7);
        let ctx = crate::model::PlatformCtx::new(plat.clone());
        let insts: Vec<_> = [30usize, 90, 2, 61]
            .iter()
            .enumerate()
            .map(|(i, &n)| {
                crate::graph::generator::generate(
                    &crate::graph::generator::RggParams {
                        n,
                        out_degree: 3,
                        ccr: 1.0,
                        alpha: 0.5,
                        beta_pct: 50.0,
                        gamma: 0.25,
                    },
                    &crate::platform::CostModel::Classic { beta: 0.5 },
                    &plat,
                    100 + i as u64,
                )
            })
            .collect();
        let serial: Vec<CriticalPath> =
            insts.iter().map(|i| find_critical_path(i.bind(&plat))).collect();
        for width in 1..=insts.len() {
            let bound: Vec<InstanceRef> =
                insts[..width].iter().map(|i| i.bind_ctx(&ctx)).collect();
            for dispatch in [simd::KernelDispatch::Simd, simd::KernelDispatch::Scalar] {
                let gathered =
                    find_critical_paths_gathered_dispatched(&ctx, &bound, dispatch);
                assert_eq!(gathered.len(), width);
                for (g, s) in gathered.iter().zip(&serial[..width]) {
                    assert_eq!(g, s, "width={width} dispatch={dispatch:?}");
                }
            }
        }
        assert!(find_critical_paths_gathered(&ctx, &[]).is_empty());
    }

    #[test]
    fn gathered_tables_match_serial_for_every_width() {
        // Both orientations, both dispatches, every window width: each
        // gathered table must be bit-identical — values *and*
        // backpointers — to its serial workspace producer.
        let mut rng = crate::util::rng::Xoshiro256::new(57);
        let plat = Platform::random_links(5, &mut rng, 0.3, 3.0, 0.1, 0.7);
        let ctx = crate::model::PlatformCtx::new(plat.clone());
        let insts: Vec<_> = [34usize, 80, 3, 55]
            .iter()
            .enumerate()
            .map(|(i, &n)| {
                crate::graph::generator::generate(
                    &crate::graph::generator::RggParams {
                        n,
                        out_degree: 3,
                        ccr: 1.0,
                        alpha: 0.5,
                        beta_pct: 50.0,
                        gamma: 0.25,
                    },
                    &crate::platform::CostModel::Classic { beta: 0.5 },
                    &plat,
                    200 + i as u64,
                )
            })
            .collect();
        let mut ws = Workspace::new();
        for rev in [false, true] {
            let serial: Vec<CeftTable> = insts
                .iter()
                .map(|i| {
                    if rev {
                        ceft_table_rev_with(&mut ws, i.bind(&plat))
                    } else {
                        ceft_table_with(&mut ws, i.bind(&plat))
                    }
                })
                .collect();
            for width in 1..=insts.len() {
                let bound: Vec<InstanceRef> =
                    insts[..width].iter().map(|i| i.bind_ctx(&ctx)).collect();
                for dispatch in [simd::KernelDispatch::Simd, simd::KernelDispatch::Scalar] {
                    let gathered =
                        find_ceft_tables_gathered_dispatched(&ctx, &bound, rev, dispatch);
                    assert_eq!(gathered.len(), width);
                    for (g, s) in gathered.iter().zip(&serial[..width]) {
                        assert_eq!(g.p, s.p);
                        assert_eq!(g.table, s.table, "width={width} rev={rev} {dispatch:?}");
                        assert_eq!(
                            g.backptr, s.backptr,
                            "width={width} rev={rev} {dispatch:?}"
                        );
                    }
                }
            }
            assert!(find_ceft_tables_gathered(&ctx, &[], rev).is_empty());
        }
    }

    #[test]
    fn dispatched_tables_bit_identical_across_lanes() {
        // fused + batched kernels under pinned Simd and pinned Scalar
        // dispatch all equal the scalar-recurrence oracle
        let inst = crate::graph::generator::generate(
            &crate::graph::generator::RggParams {
                n: 120,
                out_degree: 4,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 75.0,
                gamma: 0.3,
            },
            &crate::platform::CostModel::Classic { beta: 0.75 },
            &Platform::uniform(6, 1.0, 0.0),
            87,
        );
        let mut rng = crate::util::rng::Xoshiro256::new(88);
        let plat = Platform::random_links(6, &mut rng, 0.3, 3.0, 0.1, 0.7);
        let ctx = crate::model::PlatformCtx::new(plat.clone());
        let mut oracle = Workspace::new();
        ceft_table_scalar_into(&mut oracle, inst.bind(&plat));
        let mut ws = Workspace::new();
        for dispatch in [simd::KernelDispatch::Simd, simd::KernelDispatch::Scalar] {
            ceft_table_into_dispatched(&mut ws, inst.bind_ctx(&ctx), dispatch);
            assert_eq!(ws.table, oracle.table, "fused {dispatch:?}");
            assert_eq!(ws.backptr, oracle.backptr, "fused {dispatch:?}");
            ceft_table_batched_into_dispatched(&mut ws, inst.bind_ctx(&ctx), 8, dispatch);
            assert_eq!(ws.table, oracle.table, "batched {dispatch:?}");
            assert_eq!(ws.backptr, oracle.backptr, "batched {dispatch:?}");
        }
        // the reverse orientation too
        let mut rev_oracle = Workspace::new();
        ceft_table_rev_scalar_into(&mut rev_oracle, inst.bind(&plat));
        for dispatch in [simd::KernelDispatch::Simd, simd::KernelDispatch::Scalar] {
            ceft_table_rev_into_dispatched(&mut ws, inst.bind_ctx(&ctx), dispatch);
            assert_eq!(ws.table, rev_oracle.table, "rev {dispatch:?}");
            assert_eq!(ws.backptr, rev_oracle.backptr, "rev {dispatch:?}");
        }
    }

    #[test]
    fn ceft_table_monotone_along_edges() {
        // CEFT of a child on any class >= min CEFT of each parent (costs
        // positive), a sanity invariant for the DP.
        let inst = crate::graph::generator::generate(
            &crate::graph::generator::RggParams {
                n: 100,
                out_degree: 3,
                ccr: 1.0,
                alpha: 1.0,
                beta_pct: 50.0,
                gamma: 0.0,
            },
            &crate::platform::CostModel::Classic { beta: 0.5 },
            &Platform::uniform(3, 1.0, 0.0),
            23,
        );
        let plat = Platform::uniform(3, 1.0, 0.0);
        let t = ceft_table(inst.bind(&plat));
        for e in inst.graph.edges() {
            for j in 0..3 {
                assert!(
                    t.get(e.dst, j) >= t.min_over_classes(e.src) - 1e-9,
                    "child {} class {j} ceft {} < parent {} min {}",
                    e.dst,
                    t.get(e.dst, j),
                    e.src,
                    t.min_over_classes(e.src)
                );
            }
        }
    }
}
