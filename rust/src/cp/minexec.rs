//! The minimum-execution-time critical path (§3 of the paper).
//!
//! When communication costs are ignored (or assumed allocation-independent),
//! the optimal per-task choice is simply the fastest class, and the standard
//! homogeneous longest-path algorithm applies. The paper notes this simple
//! strategy is *more* accurate than averaging yet had not been proposed
//! before. We implement it both as a baseline and as an ablation for the
//! experiment harness.

use crate::cp::workspace::Workspace;
use crate::model::InstanceRef;

/// Result of the min-exec critical path.
#[derive(Clone, Debug, PartialEq)]
pub struct MinExecPath {
    /// path length (min execution costs + mean comm costs along the path)
    pub length: f64,
    /// tasks on the path
    pub tasks: Vec<usize>,
    /// the fastest class chosen for each path task
    pub classes: Vec<usize>,
}

/// Find the longest path when every task is charged its *minimum* execution
/// cost. `include_mean_comm` selects whether edges are charged the mean
/// communication cost (the Topcuoglu-style variant) or zero (the pure
/// zero-comm variant from §3).
pub fn min_exec_critical_path(inst: InstanceRef, include_mean_comm: bool) -> MinExecPath {
    min_exec_critical_path_with(&mut Workspace::new(), inst, include_mean_comm)
}

/// [`min_exec_critical_path`] over workspace-owned distance/predecessor
/// scratch; only the returned path vectors are allocated.
pub fn min_exec_critical_path_with(
    ws: &mut Workspace,
    inst: InstanceRef,
    include_mean_comm: bool,
) -> MinExecPath {
    let graph = inst.graph;
    let platform = inst.platform;
    let costs = inst.costs;
    let v = inst.n();
    let dist = &mut ws.dist;
    dist.clear();
    dist.resize(v, 0.0);
    let pred = &mut ws.pred;
    pred.clear();
    pred.resize(v, None);
    for &t in graph.topo_order() {
        let mut best = 0f64;
        let mut best_pred = None;
        for &(k, data) in graph.preds(t) {
            let comm = if include_mean_comm {
                platform.mean_comm_cost(data)
            } else {
                0.0
            };
            let cand = dist[k] + comm;
            if best_pred.is_none() || cand > best {
                best = cand;
                best_pred = Some(k);
            }
        }
        dist[t] = best + costs.min(t);
        pred[t] = best_pred;
    }
    // best sink
    let end = graph
        .sinks()
        .into_iter()
        .max_by(|&a, &b| dist[a].partial_cmp(&dist[b]).unwrap())
        .expect("graph has sinks");
    let mut tasks = vec![end];
    let mut t = end;
    while let Some(k) = pred[t] {
        tasks.push(k);
        t = k;
    }
    tasks.reverse();
    let classes = tasks.iter().map(|&t| costs.argmin(t)).collect();
    MinExecPath {
        length: dist[end],
        tasks,
        classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskGraph;
    use crate::model::CostMatrix;
    use crate::platform::Platform;

    #[test]
    fn picks_fastest_class_per_task() {
        let g = TaskGraph::from_edges(2, &[(0, 1, 10.0)]);
        let plat = Platform::uniform(2, 1.0, 0.0);
        let comp = CostMatrix::new(2, vec![5.0, 2.0, 3.0, 9.0]);
        let r = min_exec_critical_path(InstanceRef::new(&g, &plat, &comp), false);
        assert_eq!(r.length, 2.0 + 3.0);
        assert_eq!(r.classes, vec![1, 0]);
        assert_eq!(r.tasks, vec![0, 1]);
    }

    #[test]
    fn mean_comm_variant_adds_edges() {
        let g = TaskGraph::from_edges(2, &[(0, 1, 10.0)]);
        let plat = Platform::uniform(2, 1.0, 0.0);
        let comp = CostMatrix::new(2, vec![5.0, 2.0, 3.0, 9.0]);
        let r = min_exec_critical_path(InstanceRef::new(&g, &plat, &comp), true);
        assert_eq!(r.length, 2.0 + 10.0 + 3.0);
    }

    #[test]
    fn tracks_the_longer_branch() {
        let g = TaskGraph::from_edges(
            4,
            &[(0, 1, 0.0), (0, 2, 0.0), (1, 3, 0.0), (2, 3, 0.0)],
        );
        let plat = Platform::uniform(1, 1.0, 0.0);
        let comp = CostMatrix::new(1, vec![1.0, 10.0, 2.0, 1.0]);
        let r = min_exec_critical_path(InstanceRef::new(&g, &plat, &comp), false);
        assert_eq!(r.tasks, vec![0, 1, 3]);
        assert_eq!(r.length, 12.0);
    }

    #[test]
    fn min_exec_lower_bounds_ceft() {
        // zero-comm min-exec CP length <= CEFT CP length on the same instance
        let inst = crate::graph::generator::generate(
            &crate::graph::generator::RggParams {
                n: 150,
                out_degree: 3,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 75.0,
                gamma: 0.2,
            },
            &crate::platform::CostModel::Classic { beta: 0.75 },
            &Platform::uniform(4, 1.0, 0.0),
            31,
        );
        let plat = Platform::uniform(4, 1.0, 0.0);
        let iref = inst.bind(&plat);
        let me = min_exec_critical_path(iref, false);
        let ceft = crate::cp::ceft::find_critical_path(iref);
        assert!(
            me.length <= ceft.length + 1e-9,
            "minexec {} > ceft {}",
            me.length,
            ceft.length
        );
    }
}
