//! `CP_MIN` — the minimum-computation critical path (Definition 4), used as
//! the denominator of the schedule length ratio (eq. 9).
//!
//! The longest entry→exit path when each task is charged
//! `min_p C_comp(t, p)` and communication is ignored. No valid schedule can
//! beat this value, so `SLR >= 1` always.

use crate::cp::workspace::Workspace;
use crate::model::InstanceRef;

/// Sum of minimum computation costs along the minimum-computation critical
/// path — eq. 9's denominator.
pub fn cp_min_cost(inst: InstanceRef) -> f64 {
    cp_min_cost_with(&mut Workspace::new(), inst)
}

/// [`cp_min_cost`] over workspace-owned distance scratch. The node weights
/// (`min_p C_comp(t, p)`) are folded into the sweep instead of being
/// materialised, so the whole computation is allocation-free.
pub fn cp_min_cost_with(ws: &mut Workspace, inst: InstanceRef) -> f64 {
    let graph = inst.graph;
    let costs = inst.costs;
    let dist = &mut ws.dist;
    dist.clear();
    dist.resize(graph.num_tasks(), 0.0);
    let mut best: f64 = 0.0;
    for &t in graph.topo_order() {
        let mut d: f64 = 0.0;
        for &(k, _) in graph.preds(t) {
            d = d.max(dist[k]);
        }
        dist[t] = d + costs.min(t);
        best = best.max(dist[t]);
    }
    best
}

/// The tasks on the minimum-computation critical path (for diagnostics).
pub fn cp_min_tasks(inst: InstanceRef) -> Vec<usize> {
    let graph = inst.graph;
    let costs = inst.costs;
    let v = graph.num_tasks();
    let mut dist = vec![0f64; v];
    let mut pred: Vec<Option<usize>> = vec![None; v];
    for &t in graph.topo_order() {
        for &(k, _) in graph.preds(t) {
            if pred[t].is_none() || dist[k] > dist[pred[t].unwrap()] {
                pred[t] = Some(k);
            }
        }
        dist[t] = pred[t].map(|k| dist[k]).unwrap_or(0.0) + costs.min(t);
    }
    let end = graph
        .sinks()
        .into_iter()
        .max_by(|&a, &b| dist[a].partial_cmp(&dist[b]).unwrap())
        .expect("graph has sinks");
    let mut tasks = vec![end];
    let mut t = end;
    while let Some(k) = pred[t] {
        tasks.push(k);
        t = k;
    }
    tasks.reverse();
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskGraph;
    use crate::model::CostMatrix;
    use crate::platform::Platform;

    #[test]
    fn chain_sums_minima() {
        let g = TaskGraph::from_edges(3, &[(0, 1, 100.0), (1, 2, 100.0)]);
        let plat = Platform::uniform(2, 1.0, 0.0);
        let comp = CostMatrix::new(2, vec![5.0, 2.0, 4.0, 7.0, 1.0, 3.0]);
        let inst = InstanceRef::new(&g, &plat, &comp);
        assert_eq!(cp_min_cost(inst), 2.0 + 4.0 + 1.0);
        assert_eq!(cp_min_tasks(inst), vec![0, 1, 2]);
    }

    #[test]
    fn picks_heavier_branch() {
        let g = TaskGraph::from_edges(
            4,
            &[(0, 1, 1.0), (0, 2, 1.0), (1, 3, 1.0), (2, 3, 1.0)],
        );
        let plat = Platform::uniform(2, 1.0, 0.0);
        let comp =
            CostMatrix::new(2, vec![1.0, 1.0, 9.0, 9.0, 2.0, 2.0, 1.0, 1.0]);
        let inst = InstanceRef::new(&g, &plat, &comp);
        assert_eq!(cp_min_cost(inst), 1.0 + 9.0 + 1.0);
        assert_eq!(cp_min_tasks(inst), vec![0, 1, 3]);
    }

    #[test]
    fn cpmin_is_a_lower_bound_for_ceft() {
        let inst = crate::graph::generator::generate(
            &crate::graph::generator::RggParams {
                n: 120,
                out_degree: 4,
                ccr: 2.0,
                alpha: 0.75,
                beta_pct: 95.0,
                gamma: 0.5,
            },
            &crate::platform::CostModel::Classic { beta: 0.95 },
            &crate::platform::Platform::uniform(4, 1.0, 0.0),
            41,
        );
        let plat = crate::platform::Platform::uniform(4, 1.0, 0.0);
        let iref = inst.bind(&plat);
        let ceft = crate::cp::ceft::find_critical_path(iref);
        let cpmin = cp_min_cost(iref);
        assert!(cpmin <= ceft.length + 1e-9);
    }
}
