//! Layer-3 coordinator: experiment orchestration.
//!
//! The coordinator owns the mapping from paper artifact ids (`table3`,
//! `fig7` … `fig20`) to the sweeps that produce them, runs those sweeps on
//! a worker pool, caches rows so figures sharing a sweep don't recompute
//! it, and writes CSV + ASCII outputs. The `repro` binary and the
//! `paper_experiments` example are thin shells over this module.

use crate::exp::cells::{grid, realworld_grid, RealWorld, Scale, Workload};
use crate::exp::figures;
use crate::exp::run::{run_realworld_sweep, run_sweep, Row};
use crate::util::csv::Table;
use std::collections::HashMap;
use std::path::PathBuf;

/// All experiment ids the coordinator can produce.
pub const EXPERIMENT_IDS: [&str; 17] = [
    "table3", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14", "fig15",
    "fig16", "fig17", "fig18", "fig19", "fig20", "breakdown", "all",
];

/// Orchestrates sweeps and caches their results.
pub struct Coordinator {
    /// worker threads for sweeps
    pub threads: usize,
    /// sweep scale
    pub scale: Scale,
    /// output directory for CSV files
    pub out_dir: PathBuf,
    /// print progress to stderr
    pub verbose: bool,
    rgg_cache: HashMap<Workload, Vec<Row>>,
    rw_cache: HashMap<RealWorld, Vec<Row>>,
}

/// One produced artifact: output file stem + the table.
pub struct Produced {
    /// file stem, e.g. `fig10_RGG-high`
    pub name: String,
    /// the data
    pub table: Table,
}

impl Coordinator {
    /// New coordinator.
    pub fn new(threads: usize, scale: Scale, out_dir: PathBuf, verbose: bool) -> Self {
        Self {
            threads,
            scale,
            out_dir,
            verbose,
            rgg_cache: HashMap::new(),
            rw_cache: HashMap::new(),
        }
    }

    /// Rows for one RGG workload (cached).
    pub fn rgg_rows(&mut self, wl: Workload) -> &[Row] {
        if !self.rgg_cache.contains_key(&wl) {
            let cells = grid(wl, self.scale);
            if self.verbose {
                eprintln!("sweep {} ({} cells)...", wl.name(), cells.len());
            }
            let rows = run_sweep(&cells, self.threads, self.verbose);
            self.rgg_cache.insert(wl, rows);
        }
        &self.rgg_cache[&wl]
    }

    /// Rows for one real-world family (cached).
    pub fn rw_rows(&mut self, fam: RealWorld) -> &[Row] {
        if !self.rw_cache.contains_key(&fam) {
            let cells = realworld_grid(fam, self.scale);
            if self.verbose {
                eprintln!("sweep {} ({} cells)...", fam.name(), cells.len());
            }
            let rows = run_realworld_sweep(&cells, self.threads, self.verbose);
            self.rw_cache.insert(fam, rows);
        }
        &self.rw_cache[&fam]
    }

    fn all_rgg_rows(&mut self) -> Vec<Row> {
        let mut rows = Vec::new();
        for wl in Workload::ALL {
            rows.extend(self.rgg_rows(wl).to_vec());
        }
        rows
    }

    /// Produce one experiment id (possibly several tables).
    pub fn produce(&mut self, id: &str) -> Vec<Produced> {
        match id {
            "table3" => {
                let rows = self.all_rgg_rows();
                vec![Produced {
                    name: "table3".into(),
                    table: figures::table3(&rows),
                }]
            }
            "fig7" => {
                let mut out = Vec::new();
                for wl in [Workload::RggClassic, Workload::RggHigh] {
                    let rows = self.rgg_rows(wl).to_vec();
                    out.push(Produced {
                        name: format!("fig7_{}", wl.name()),
                        table: figures::fig7(&rows),
                    });
                }
                out
            }
            "fig8" => {
                let rows = self.rgg_rows(Workload::RggMedium).to_vec();
                vec![Produced {
                    name: "fig8_RGG-medium".into(),
                    table: figures::fig8(&rows),
                }]
            }
            "fig9" => {
                let rows = self.rgg_rows(Workload::RggHigh).to_vec();
                vec![Produced {
                    name: "fig9_RGG-high".into(),
                    table: figures::fig9(&rows),
                }]
            }
            "fig10" | "fig11" | "fig12" | "fig19" | "fig20" => {
                let f: fn(&[Row]) -> Table = match id {
                    "fig10" => figures::fig10,
                    "fig11" => figures::fig11,
                    "fig12" => figures::fig12,
                    "fig19" => figures::fig19,
                    _ => figures::fig20,
                };
                let mut out = Vec::new();
                for wl in Workload::ALL {
                    let rows = self.rgg_rows(wl).to_vec();
                    out.push(Produced {
                        name: format!("{id}_{}", wl.name()),
                        table: f(&rows),
                    });
                }
                out
            }
            "fig13" => {
                let rows = self.rgg_rows(Workload::RggClassic).to_vec();
                vec![
                    Produced {
                        name: "fig13a_slr_vs_alpha".into(),
                        table: figures::fig13a(&rows),
                    },
                    Produced {
                        name: "fig13b_slr_vs_ccr".into(),
                        table: figures::fig13b(&rows),
                    },
                    Produced {
                        name: "fig13c_slack_vs_ccr".into(),
                        table: figures::fig13c(&rows),
                    },
                ]
            }
            "fig14" => {
                let rows = self.rgg_rows(Workload::RggClassic).to_vec();
                vec![
                    Produced {
                        name: "fig14a_slr_vs_n".into(),
                        table: figures::fig14a(&rows),
                    },
                    Produced {
                        name: "fig14b_slr_vs_p".into(),
                        table: figures::fig14b(&rows),
                    },
                ]
            }
            "fig15" | "fig16" | "fig17" | "fig18" => {
                // 15: medium SLR; 16: classic speedup; 17: classic SLR;
                // 18: medium speedup
                let medium = id == "fig15" || id == "fig18";
                let slr = id == "fig15" || id == "fig17";
                let mut out = Vec::new();
                for fam in RealWorld::ALL {
                    let rows: Vec<Row> = self
                        .rw_rows(fam)
                        .iter()
                        .filter(|r| r.workload.ends_with(if medium { "medium" } else { "classic" }))
                        .cloned()
                        .collect();
                    let table = if slr {
                        figures::fig_realworld_slr(&rows)
                    } else {
                        figures::fig_realworld_speedup(&rows)
                    };
                    out.push(Produced {
                        name: format!(
                            "{id}_{}_{}",
                            fam.name(),
                            if medium { "medium" } else { "classic" }
                        ),
                        table,
                    });
                }
                out
            }
            "breakdown" => {
                let rows = self.rgg_rows(Workload::RggHigh).to_vec();
                vec![
                    Produced {
                        name: "breakdown_ccr".into(),
                        table: figures::table3_breakdown(&rows, "ccr", |r| r.ccr),
                    },
                    Produced {
                        name: "breakdown_n".into(),
                        table: figures::table3_breakdown(&rows, "n", |r| r.n as f64),
                    },
                    Produced {
                        name: "breakdown_p".into(),
                        table: figures::table3_breakdown(&rows, "p", |r| r.p as f64),
                    },
                    Produced {
                        name: "breakdown_beta".into(),
                        table: figures::table3_breakdown(&rows, "beta", |r| r.beta_pct),
                    },
                ]
            }
            "all" => {
                let mut out = Vec::new();
                for id in EXPERIMENT_IDS.iter().filter(|&&i| i != "all") {
                    out.extend(self.produce(id));
                }
                // also dump raw rows for post-hoc analysis
                let rows = self.all_rgg_rows();
                out.push(Produced {
                    name: "raw_rgg".into(),
                    table: figures::raw_rows(&rows),
                });
                out
            }
            other => panic!("unknown experiment id {other:?} (see EXPERIMENT_IDS)"),
        }
    }

    /// Produce an experiment and write its tables to `out_dir` as CSV.
    /// Returns the produced tables (for printing).
    pub fn produce_and_write(&mut self, id: &str) -> std::io::Result<Vec<Produced>> {
        let produced = self.produce(id);
        std::fs::create_dir_all(&self.out_dir)?;
        for p in &produced {
            let path = self.out_dir.join(format!("{}.csv", p.name));
            p.table.write_file(&path)?;
            if self.verbose {
                eprintln!("wrote {}", path.display());
            }
        }
        Ok(produced)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_coordinator() -> Coordinator {
        Coordinator::new(
            2,
            Scale::Smoke,
            std::env::temp_dir().join("ceft-coord-test"),
            false,
        )
    }

    #[test]
    fn table3_produces_one_table() {
        let mut c = smoke_coordinator();
        let out = c.produce("table3");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].table.rows.len(), 12); // 4 workloads x 3 outcomes
    }

    #[test]
    fn cache_prevents_recomputation() {
        let mut c = smoke_coordinator();
        let _ = c.produce("fig10");
        let before = c.rgg_cache.len();
        let _ = c.produce("fig11"); // same sweeps
        assert_eq!(c.rgg_cache.len(), before);
    }

    #[test]
    fn fig13_produces_three_tables() {
        let mut c = smoke_coordinator();
        let out = c.produce("fig13");
        assert_eq!(out.len(), 3);
        assert!(out[0].name.contains("alpha"));
    }

    #[test]
    fn realworld_figures_filter_variant() {
        let mut c = smoke_coordinator();
        let out = c.produce("fig15");
        assert_eq!(out.len(), 4);
        for p in &out {
            assert!(p.name.contains("medium"));
            assert!(!p.table.rows.is_empty());
        }
    }

    #[test]
    #[should_panic(expected = "unknown experiment id")]
    fn unknown_id_panics() {
        smoke_coordinator().produce("fig99");
    }

    #[test]
    fn write_creates_csv_files() {
        let dir = std::env::temp_dir().join(format!("ceft-coord-{}", std::process::id()));
        let mut c = Coordinator::new(2, Scale::Smoke, dir.clone(), false);
        c.produce_and_write("fig8").unwrap();
        assert!(dir.join("fig8_RGG-medium.csv").exists());
        let _ = std::fs::remove_dir_all(dir);
    }
}
