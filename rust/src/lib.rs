//! # CEFT — Critical Earliest Finish Time
//!
//! A production-quality reproduction of *"Mutual Inclusivity of the Critical
//! Path and its Partial Schedule on Heterogeneous Systems"* (Vasudevan &
//! Gregg, 2017).
//!
//! The paper's thesis: on a heterogeneous machine the critical path of a task
//! DAG cannot be defined independently of a mapping of tasks to processor
//! classes. The CEFT dynamic program (Algorithm 1 in the paper,
//! [`cp::ceft`] here) finds, in `O(P²e)` time, both the length of the true
//! critical path *and* the partial assignment of its tasks to processor
//! classes. The partial schedule is then injected into CPOP
//! ([`sched::ceft_cpop`]) and into HEFT's ranking functions
//! ([`sched::ceft_heft`]).
//!
//! ## Crate layout
//!
//! * [`graph`] — task DAGs: construction, topological structure, random
//!   (Topcuoglu-style) and real-world (FFT / Gaussian elimination /
//!   molecular dynamics / epigenomics) generators.
//! * [`platform`] — heterogeneous processor graphs, communication model,
//!   and the two execution-cost models from the paper (eq. 5 "classic",
//!   eq. 6 "two-weight").
//! * [`model`] — the instance model layer: [`model::CostMatrix`] (the dense
//!   task-major `v × P` execution-cost matrix as a first-class SoA value),
//!   [`model::InstanceRef`] (the shape-checked borrowed
//!   `&TaskGraph + &Platform + &CostMatrix` view every algorithm entry
//!   point consumes — the raw `(graph, platform, comp)` triple survives
//!   only at the JSON/service boundary), and [`model::PlatformCtx`] (the
//!   platform-scoped execution context: interned hash, resident CEFT
//!   communication panels, per-class mean-comm scalars, PJRT f32 marshals
//!   and a platform-sized workspace pool — computed once per distinct
//!   platform and borrowed by every layer).
//! * [`cp`] — critical-path algorithms: CEFT (the paper's contribution),
//!   CPOP's mean-value critical path, the min-execution-time critical path,
//!   and `CP_MIN` (the SLR denominator) — plus [`cp::workspace`], the
//!   reusable scratch arena that makes the whole algorithm core
//!   allocation-free at steady state (see EXPERIMENTS.md §Workspace), and
//!   [`cp::ceft::simd`], the hand-vectorised 4-wide min-plus lanes behind
//!   the CEFT kernels (bit-identical to the scalar oracle;
//!   `CEFT_FORCE_SCALAR=1` forces the scalar path — EXPERIMENTS.md §SIMD
//!   dispatch).
//! * [`sched`] — list schedulers: HEFT, CPOP, CEFT-CPOP, and the
//!   CEFT-ranked HEFT variants, all over a shared insertion-based core.
//!   Each has a `schedule_with(&mut Workspace, …)` hot path and a classic
//!   allocating `schedule(…)` wrapper with bit-identical output.
//! * [`metrics`] — makespan, speedup, SLR, slack, and pairwise
//!   win/tie/loss comparison.
//! * [`exp`] — the experiment harness that regenerates every table and
//!   figure of the paper's evaluation section.
//! * [`runtime`] — PJRT-backed execution of the AOT-compiled JAX/Pallas
//!   relaxation kernel (`artifacts/*.hlo.txt`), plus the accelerated CEFT
//!   backend that uses it (gated behind the `pjrt` cargo feature; a stub
//!   with the same API compiles by default).
//! * [`coordinator`] — the layer-3 orchestrator: job queue, worker pool,
//!   progress, and result sinks for large sweeps.
//! * [`service`] — the online scheduling service: a persistent engine that
//!   interns instances by structural hash, memoizes CEFT results and
//!   schedules in LRU caches, and speaks a newline-delimited JSON protocol
//!   over stdin/stdout or TCP (`repro serve` / `repro request` /
//!   `repro loadgen`). This is the seam the batch algorithms plug into to
//!   serve streams of small online requests instead of one offline sweep.
//! * [`obs`] — zero-dependency telemetry: the request-lifecycle stage
//!   taxonomy, per-thread lock-free trace recorders, log-linear latency
//!   histograms with exact percentile extraction, and kernel-path cells/s
//!   attribution. Surfaced through the service's `trace` / `metrics` ops
//!   and `repro loadgen`; `CEFT_TELEMETRY=off` turns every hook into a
//!   branch-predictable no-op (EXPERIMENTS.md §Telemetry).
//! * [`util`] — substrates built from scratch for this offline image:
//!   deterministic RNG, statistics, a thread pool, CSV / JSON writers, a
//!   micro-benchmark harness and a property-test harness.
//!
//! ## Quickstart
//!
//! ```
//! use ceft::graph::TaskGraph;
//! use ceft::model::{CostMatrix, InstanceRef};
//! use ceft::platform::Platform;
//! use ceft::cp::ceft::find_critical_path;
//!
//! // diamond DAG: 0 -> {1,2} -> 3, data sizes on edges
//! let g = TaskGraph::from_edges(4, &[(0, 1, 10.0), (0, 2, 10.0), (1, 3, 10.0), (2, 3, 10.0)]);
//! // two processor classes, uniform comm
//! let plat = Platform::uniform(2, 1.0, 0.0);
//! // dense v x P execution-cost matrix (task-major SoA)
//! let comp = CostMatrix::new(2, vec![
//!     1.0, 8.0, // task 0: fast on class 0
//!     9.0, 2.0, // task 1: fast on class 1
//!     4.0, 4.0, // task 2
//!     1.0, 9.0, // task 3: fast on class 0
//! ]);
//! let cp = find_critical_path(InstanceRef::new(&g, &plat, &comp));
//! assert!(cp.length > 0.0);
//! assert_eq!(cp.path.first().unwrap().task, 0);
//! assert_eq!(cp.path.last().unwrap().task, 3);
//! ```

pub mod coordinator;
pub mod cp;
pub mod exp;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod platform;
pub mod runtime;
pub mod sched;
pub mod service;
pub mod util;

/// Convenience prelude for examples and downstream users.
pub mod prelude {
    pub use crate::cp::ceft::{find_critical_path, CriticalPath, PathStep};
    pub use crate::cp::cpmin::cp_min_cost;
    pub use crate::cp::workspace::{Workspace, WorkspacePool};
    pub use crate::graph::{generator::RggParams, realworld, TaskGraph};
    pub use crate::metrics::{makespan, slack, slr, speedup};
    pub use crate::model::{CostMatrix, InstanceRef, PlatformCtx};
    pub use crate::platform::{CostModel, Platform};
    pub use crate::sched::{
        ceft_cpop::CeftCpop, cpop::Cpop, heft::Heft, Algorithm, Schedule, Scheduler,
    };
    pub use crate::service::{Engine, EngineConfig};
}
