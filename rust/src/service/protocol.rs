//! The newline-delimited JSON request/response protocol.
//!
//! One request per line, one response per line, in order. Every response
//! carries `"ok": true|false`; errors carry `"error": "<message>"` and never
//! terminate the connection. The same frames flow over stdin/stdout
//! (`repro serve`) and TCP (`repro serve --addr`).
//!
//! Operations (`"op"` field):
//!
//! | op         | request fields                                     | response |
//! |------------|----------------------------------------------------|----------|
//! | `ping`     | —                                                  | `pong`, `version` |
//! | `submit`   | `instance`, optional `platform`                    | `id` (16-hex handle), `n`, `p`, `edges` |
//! | `cp`       | `id` *or* `instance` (+ optional `platform`), optional `slack: true`, optional `deadline_ms` | `length`, `path` `[[task, class], …]`, `cached`, `id` (+ `slack: [per-task float]` when requested) |
//! | `schedule` | `algorithm`, `id` *or* `instance` (+ `platform`), optional `deadline_ms` | `makespan`, `schedule`, `algorithm`, `cached`, `id` |
//! | `update`   | `id`, `edits` `[{"edit":"task_cost"\|"edge_cost"\|"add_edge"\|"remove_edge"\|"add_task"\|"remove_task", …}, …]`, optional `deadline_ms` | `id`, `generation`, `n`, `edges`, `length`, `slack`, `delta_rows_recomputed`, `full_rows`, `skipped` |
//! | `stats`    | —                                                  | counters + cache occupancy (incl. the memoized CEFT-table cache: hits/misses, `batched_requests`/`batch_width`, `cp_schedule_shares`) + per-stage latency percentiles |
//! | `trace`    | optional `limit` (slowest/recent rows, default 8)  | per-stage histograms, kernel-path throughput, slowest/recent traces |
//! | `metrics`  | —                                                  | `text`: Prometheus-style exposition (same body `--metrics-addr` serves) |
//! | `evict`    | `id`                                               | entries dropped |
//! | `clear`    | —                                                  | entries dropped |
//! | `shutdown` | —                                                  | `shutting_down`; server stops accepting |
//!
//! `instance` is [`crate::graph::io::instance_to_json`] form; `platform`
//! is [`crate::graph::io::platform_to_json`] form (omitted ⇒ a uniform
//! platform with unit bandwidth and zero startup, matching the RGG-classic
//! experiments). Submitting the same content twice returns the same handle:
//! handles are structural hashes, not sequence numbers.
//!
//! Deadlines: the compute ops (`cp`, `schedule`, `update`) accept an
//! optional `"deadline_ms"` — a non-negative relative budget in
//! milliseconds, measured from dispatch. A request whose budget expires
//! before (or while) its computation runs gets
//! `{"ok":false,"error":"deadline_exceeded","retry_after_ms":N}` instead of
//! an answer; an over-budget shard sheds uncached work the same way with
//! `"error":"shed"`. Both are *structured* refusals — the connection
//! survives, and `retry_after_ms` tells a backoff client when the queue is
//! likely to have drained (see EXPERIMENTS.md §Overload & fault model).

use crate::graph::edit::GraphEdit;
use crate::graph::generator::Instance;
use crate::graph::io;
use crate::platform::Platform;
use crate::sched::Algorithm;
use crate::util::json::Json;

/// Protocol version reported by `ping`.
pub const PROTOCOL_VERSION: u64 = 1;

/// Default `limit` for the `trace` op when the request omits it.
pub const DEFAULT_TRACE_LIMIT: usize = 8;

/// An instance reference: inline content or a handle from `submit`.
#[derive(Clone, Debug)]
pub enum Target {
    /// the full instance (and optionally its platform) in the request body
    Inline {
        /// task graph + cost matrix
        instance: Instance,
        /// platform; `None` ⇒ uniform(p, 1.0, 0.0)
        platform: Option<Platform>,
    },
    /// a handle previously returned by `submit`
    Handle(u64),
}

/// A decoded request.
#[derive(Clone, Debug)]
pub enum Request {
    /// liveness / version check
    Ping,
    /// intern an instance, returning its handle
    Submit {
        /// task graph + cost matrix
        instance: Instance,
        /// platform; `None` ⇒ uniform(p, 1.0, 0.0)
        platform: Option<Platform>,
    },
    /// CEFT critical path (with partial assignment)
    CriticalPath {
        /// which instance
        target: Target,
        /// also return per-task slack (the CPM float idiom) derived from
        /// the forward table
        slack: bool,
        /// optional relative deadline (milliseconds from dispatch); the
        /// engine refuses with `deadline_exceeded` once it expires
        deadline_ms: Option<u64>,
    },
    /// edit an interned instance in place, bumping its generation
    Update {
        /// the handle to edit (updates are handle-only: an edit without a
        /// prior `submit` has nothing to be incremental against)
        id: u64,
        /// the edit sequence, applied in order
        edits: Vec<GraphEdit>,
        /// optional relative deadline for the eager recompute phase (the
        /// edit itself is cheap and always applied; an expired deadline
        /// refuses before the edit is attempted)
        deadline_ms: Option<u64>,
    },
    /// full schedule with a registry algorithm
    Schedule {
        /// which scheduler
        algorithm: Algorithm,
        /// which instance
        target: Target,
        /// optional relative deadline (milliseconds from dispatch)
        deadline_ms: Option<u64>,
    },
    /// engine counters and cache occupancy
    Stats,
    /// per-stage latency histograms + slowest/most-recent request traces
    Trace {
        /// how many slowest/recent rows to return (default 8, capped)
        limit: usize,
    },
    /// Prometheus-style text exposition of counters and stage latencies
    Metrics,
    /// drop one interned instance and its cached results
    Evict {
        /// the handle to drop
        id: u64,
    },
    /// drop all cached results and interned instances
    Clear,
    /// stop the server after responding
    Shutdown,
}

/// Op code for a line that never parsed into a [`Request`] — what the
/// telemetry layer labels a trace before (or instead of) identification.
pub const OP_INVALID: u8 = 255;

/// Compact op code for telemetry trace records ([`crate::obs`] stores one
/// `u8` per completed trace, not an op string). Stable: codes are part of
/// the `trace` response via [`op_name`].
pub fn op_code(req: &Request) -> u8 {
    match req {
        Request::Ping => 0,
        Request::Submit { .. } => 1,
        Request::CriticalPath { .. } => 2,
        Request::Schedule { .. } => 3,
        Request::Stats => 4,
        Request::Evict { .. } => 5,
        Request::Clear => 6,
        Request::Shutdown => 7,
        Request::Trace { .. } => 8,
        Request::Metrics => 9,
        Request::Update { .. } => 10,
    }
}

/// Wire name for an [`op_code`] (the `"op"` strings clients send);
/// unknown codes and [`OP_INVALID`] render as `"invalid"`.
pub fn op_name(code: u8) -> &'static str {
    match code {
        0 => "ping",
        1 => "submit",
        2 => "cp",
        3 => "schedule",
        4 => "stats",
        5 => "evict",
        6 => "clear",
        7 => "shutdown",
        8 => "trace",
        9 => "metrics",
        10 => "update",
        _ => "invalid",
    }
}

/// Render a handle as the wire format (16 lowercase hex digits).
pub fn handle_to_hex(h: u64) -> String {
    format!("{h:016x}")
}

/// Parse a wire-format handle.
pub fn parse_handle(s: &str) -> Result<u64, String> {
    u64::from_str_radix(s, 16).map_err(|e| format!("bad handle {s:?}: {e}"))
}

fn instance_parts(j: &Json, op: &str) -> Result<(Instance, Option<Platform>), String> {
    let inst_j = j
        .get("instance")
        .ok_or_else(|| format!("{op} requires \"instance\" (or \"id\")"))?;
    let instance = io::instance_from_json(inst_j)?;
    let platform = match j.get("platform") {
        Some(pj) => {
            let plat = io::platform_from_json(pj)?;
            if plat.num_classes() != instance.p() {
                return Err(format!(
                    "platform has {} classes but instance expects {}",
                    plat.num_classes(),
                    instance.p()
                ));
            }
            Some(plat)
        }
        None => None,
    };
    Ok((instance, platform))
}

fn edit_usize(j: &Json, field: &str, kind: &str) -> Result<usize, String> {
    j.get(field)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("{kind} edit requires numeric \"{field}\""))
}

fn edit_f64(j: &Json, field: &str, kind: &str) -> Result<f64, String> {
    j.get(field)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{kind} edit requires numeric \"{field}\""))
}

fn edit_costs(j: &Json, kind: &str) -> Result<Vec<f64>, String> {
    j.get("costs")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{kind} edit requires \"costs\" array"))?
        .iter()
        .map(|v| {
            v.as_f64()
                .ok_or_else(|| format!("{kind} edit: \"costs\" entries must be numbers"))
        })
        .collect()
}

/// Decode one edit object (the elements of `update`'s `"edits"` array).
pub fn edit_from_json(j: &Json) -> Result<GraphEdit, String> {
    let kind = j
        .get("edit")
        .and_then(Json::as_str)
        .ok_or("each edit requires an \"edit\" tag")?;
    match kind {
        "task_cost" => Ok(GraphEdit::TaskCost {
            task: edit_usize(j, "task", kind)?,
            costs: edit_costs(j, kind)?,
        }),
        "edge_cost" => Ok(GraphEdit::EdgeCost {
            src: edit_usize(j, "src", kind)?,
            dst: edit_usize(j, "dst", kind)?,
            data: edit_f64(j, "data", kind)?,
        }),
        "add_edge" => Ok(GraphEdit::AddEdge {
            src: edit_usize(j, "src", kind)?,
            dst: edit_usize(j, "dst", kind)?,
            data: edit_f64(j, "data", kind)?,
        }),
        "remove_edge" => Ok(GraphEdit::RemoveEdge {
            src: edit_usize(j, "src", kind)?,
            dst: edit_usize(j, "dst", kind)?,
        }),
        "add_task" => Ok(GraphEdit::AddTask {
            costs: edit_costs(j, kind)?,
        }),
        "remove_task" => Ok(GraphEdit::RemoveTask {
            task: edit_usize(j, "task", kind)?,
        }),
        other => Err(format!("unknown edit kind {other:?}")),
    }
}

/// Encode one edit as its wire object — the inverse of [`edit_from_json`].
pub fn edit_to_json(e: &GraphEdit) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![("edit", Json::Str(e.kind().to_string()))];
    match e {
        GraphEdit::TaskCost { task, costs } => {
            fields.push(("task", Json::Num(*task as f64)));
            fields.push(("costs", Json::Arr(costs.iter().map(|&c| Json::Num(c)).collect())));
        }
        GraphEdit::EdgeCost { src, dst, data } => {
            fields.push(("src", Json::Num(*src as f64)));
            fields.push(("dst", Json::Num(*dst as f64)));
            fields.push(("data", Json::Num(*data)));
        }
        GraphEdit::AddEdge { src, dst, data } => {
            fields.push(("src", Json::Num(*src as f64)));
            fields.push(("dst", Json::Num(*dst as f64)));
            fields.push(("data", Json::Num(*data)));
        }
        GraphEdit::RemoveEdge { src, dst } => {
            fields.push(("src", Json::Num(*src as f64)));
            fields.push(("dst", Json::Num(*dst as f64)));
        }
        GraphEdit::AddTask { costs } => {
            fields.push(("costs", Json::Arr(costs.iter().map(|&c| Json::Num(c)).collect())));
        }
        GraphEdit::RemoveTask { task } => {
            fields.push(("task", Json::Num(*task as f64)));
        }
    }
    Json::obj(fields)
}

/// Decode the optional `"deadline_ms"` field. Rejects negatives, NaN and
/// infinities (a `1e999` literal parses to `+inf`, which must not become a
/// deadline the engine converts to an `Instant`); fractional budgets
/// truncate to whole milliseconds.
fn parse_deadline(j: &Json) -> Result<Option<u64>, String> {
    match j.get("deadline_ms") {
        None => Ok(None),
        Some(v) => {
            let ms = v
                .as_f64()
                .filter(|m| m.is_finite() && *m >= 0.0)
                .ok_or("\"deadline_ms\" must be a finite non-negative number")?;
            Ok(Some(ms as u64))
        }
    }
}

fn parse_target(j: &Json, op: &str) -> Result<Target, String> {
    if let Some(h) = j.get("id") {
        let s = h.as_str().ok_or("\"id\" must be a hex string")?;
        return Ok(Target::Handle(parse_handle(s)?));
    }
    let (instance, platform) = instance_parts(j, op)?;
    Ok(Target::Inline { instance, platform })
}

/// Decode one request line. Errors are client errors (malformed JSON,
/// unknown op, bad fields) suitable for an `"ok": false` response.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = Json::parse(line.trim()).map_err(|e| format!("bad json: {e}"))?;
    let op = j
        .get("op")
        .and_then(Json::as_str)
        .ok_or("missing \"op\" field")?;
    match op {
        "ping" => Ok(Request::Ping),
        "submit" => {
            let (instance, platform) = instance_parts(&j, "submit")?;
            Ok(Request::Submit { instance, platform })
        }
        "cp" => {
            let slack = match j.get("slack") {
                Some(v) => v.as_bool().ok_or("\"slack\" must be a boolean")?,
                None => false,
            };
            Ok(Request::CriticalPath {
                target: parse_target(&j, "cp")?,
                slack,
                deadline_ms: parse_deadline(&j)?,
            })
        }
        "update" => {
            let s = j
                .get("id")
                .and_then(Json::as_str)
                .ok_or("update requires \"id\" (updates are handle-only)")?;
            let edits = j
                .get("edits")
                .and_then(Json::as_arr)
                .ok_or("update requires \"edits\" array")?
                .iter()
                .map(edit_from_json)
                .collect::<Result<Vec<_>, _>>()?;
            if edits.is_empty() {
                return Err("update requires at least one edit".to_string());
            }
            Ok(Request::Update {
                id: parse_handle(s)?,
                edits,
                deadline_ms: parse_deadline(&j)?,
            })
        }
        "schedule" => {
            let name = j
                .get("algorithm")
                .and_then(Json::as_str)
                .ok_or("schedule requires \"algorithm\"")?;
            Ok(Request::Schedule {
                algorithm: Algorithm::parse(name)?,
                target: parse_target(&j, "schedule")?,
                deadline_ms: parse_deadline(&j)?,
            })
        }
        "stats" => Ok(Request::Stats),
        "trace" => {
            let limit = match j.get("limit") {
                Some(v) => v
                    .as_usize()
                    .ok_or("\"limit\" must be a non-negative integer")?,
                None => DEFAULT_TRACE_LIMIT,
            };
            Ok(Request::Trace { limit })
        }
        "metrics" => Ok(Request::Metrics),
        "evict" => {
            let s = j
                .get("id")
                .and_then(Json::as_str)
                .ok_or("evict requires \"id\"")?;
            Ok(Request::Evict {
                id: parse_handle(s)?,
            })
        }
        "clear" => Ok(Request::Clear),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(format!("unknown op {other:?}")),
    }
}

fn push_instance(fields: &mut Vec<(&str, Json)>, instance: &Instance, platform: &Option<Platform>) {
    fields.push(("instance", io::instance_to_json(instance)));
    if let Some(p) = platform {
        fields.push(("platform", io::platform_to_json(p)));
    }
}

fn push_target(fields: &mut Vec<(&str, Json)>, target: &Target) {
    match target {
        Target::Handle(id) => fields.push(("id", Json::Str(handle_to_hex(*id)))),
        Target::Inline { instance, platform } => push_instance(fields, instance, platform),
    }
}

/// Encode a request as its wire JSON object — the inverse of
/// [`parse_request`]. Clients (the `repro request`/`repro loadgen`
/// commands, embedded users) should build [`Request`] values and encode
/// them here rather than splicing strings, so field names, handle format
/// and escaping have a single owner.
pub fn request_to_json(req: &Request) -> Json {
    let mut fields: Vec<(&str, Json)> = Vec::new();
    match req {
        Request::Ping => fields.push(("op", Json::Str("ping".to_string()))),
        Request::Stats => fields.push(("op", Json::Str("stats".to_string()))),
        Request::Metrics => fields.push(("op", Json::Str("metrics".to_string()))),
        Request::Trace { limit } => {
            fields.push(("op", Json::Str("trace".to_string())));
            fields.push(("limit", Json::Num(*limit as f64)));
        }
        Request::Clear => fields.push(("op", Json::Str("clear".to_string()))),
        Request::Shutdown => fields.push(("op", Json::Str("shutdown".to_string()))),
        Request::Evict { id } => {
            fields.push(("op", Json::Str("evict".to_string())));
            fields.push(("id", Json::Str(handle_to_hex(*id))));
        }
        Request::Submit { instance, platform } => {
            fields.push(("op", Json::Str("submit".to_string())));
            push_instance(&mut fields, instance, platform);
        }
        Request::CriticalPath {
            target,
            slack,
            deadline_ms,
        } => {
            fields.push(("op", Json::Str("cp".to_string())));
            if *slack {
                fields.push(("slack", Json::Bool(true)));
            }
            if let Some(ms) = deadline_ms {
                fields.push(("deadline_ms", Json::Num(*ms as f64)));
            }
            push_target(&mut fields, target);
        }
        Request::Update {
            id,
            edits,
            deadline_ms,
        } => {
            fields.push(("op", Json::Str("update".to_string())));
            fields.push(("id", Json::Str(handle_to_hex(*id))));
            fields.push(("edits", Json::Arr(edits.iter().map(edit_to_json).collect())));
            if let Some(ms) = deadline_ms {
                fields.push(("deadline_ms", Json::Num(*ms as f64)));
            }
        }
        Request::Schedule {
            algorithm,
            target,
            deadline_ms,
        } => {
            fields.push(("op", Json::Str("schedule".to_string())));
            fields.push(("algorithm", Json::Str(algorithm.name().to_string())));
            if let Some(ms) = deadline_ms {
                fields.push(("deadline_ms", Json::Num(*ms as f64)));
            }
            push_target(&mut fields, target);
        }
    }
    Json::obj(fields)
}

/// Build a success response (`"ok": true` plus the given fields).
pub fn ok_response(mut fields: Vec<(&str, Json)>) -> Json {
    fields.insert(0, ("ok", Json::Bool(true)));
    Json::obj(fields)
}

/// Build an error response.
pub fn error_response(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
}

/// Build an error response with extra structured fields — the shape for
/// refusals a client is expected to act on (`deadline_exceeded` / `shed`
/// with `retry_after_ms`, `internal_panic` with `detail`).
pub fn error_response_with(msg: &str, extra: Vec<(&str, Json)>) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ];
    fields.extend(extra);
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_instance_json() -> String {
        // 2-task chain, p=1
        r#"{"n":2,"p":1,"edges":[[0,1,1.0]],"comp":[1.0,2.0]}"#.to_string()
    }

    #[test]
    fn parses_every_op() {
        assert!(matches!(parse_request(r#"{"op":"ping"}"#), Ok(Request::Ping)));
        assert!(matches!(parse_request(r#"{"op":"stats"}"#), Ok(Request::Stats)));
        assert!(matches!(parse_request(r#"{"op":"clear"}"#), Ok(Request::Clear)));
        assert!(matches!(
            parse_request(r#"{"op":"shutdown"}"#),
            Ok(Request::Shutdown)
        ));
        let submit = format!(r#"{{"op":"submit","instance":{}}}"#, sample_instance_json());
        assert!(matches!(parse_request(&submit), Ok(Request::Submit { .. })));
        let cp = format!(r#"{{"op":"cp","instance":{}}}"#, sample_instance_json());
        assert!(matches!(
            parse_request(&cp),
            Ok(Request::CriticalPath {
                target: Target::Inline { .. },
                slack: false,
                deadline_ms: None,
            })
        ));
        let cp_deadline = format!(
            r#"{{"op":"cp","deadline_ms":250,"instance":{}}}"#,
            sample_instance_json()
        );
        assert!(matches!(
            parse_request(&cp_deadline),
            Ok(Request::CriticalPath {
                deadline_ms: Some(250),
                ..
            })
        ));
        let cp_slack = format!(
            r#"{{"op":"cp","slack":true,"instance":{}}}"#,
            sample_instance_json()
        );
        assert!(matches!(
            parse_request(&cp_slack),
            Ok(Request::CriticalPath { slack: true, .. })
        ));
        let sched = format!(
            r#"{{"op":"schedule","algorithm":"ceft-cpop","instance":{}}}"#,
            sample_instance_json()
        );
        match parse_request(&sched).unwrap() {
            Request::Schedule { algorithm, .. } => assert_eq!(algorithm, Algorithm::CeftCpop),
            other => panic!("wrong request: {other:?}"),
        }
        let by_handle = r#"{"op":"cp","id":"00000000000000ff"}"#;
        match parse_request(by_handle).unwrap() {
            Request::CriticalPath {
                target: Target::Handle(h),
                ..
            } => assert_eq!(h, 0xff),
            other => panic!("wrong request: {other:?}"),
        }
        match parse_request(r#"{"op":"evict","id":"0000000000000010"}"#).unwrap() {
            Request::Evict { id } => assert_eq!(id, 16),
            other => panic!("wrong request: {other:?}"),
        }
        assert!(matches!(
            parse_request(r#"{"op":"metrics"}"#),
            Ok(Request::Metrics)
        ));
        match parse_request(r#"{"op":"trace"}"#).unwrap() {
            Request::Trace { limit } => assert_eq!(limit, DEFAULT_TRACE_LIMIT),
            other => panic!("wrong request: {other:?}"),
        }
        match parse_request(r#"{"op":"trace","limit":3}"#).unwrap() {
            Request::Trace { limit } => assert_eq!(limit, 3),
            other => panic!("wrong request: {other:?}"),
        }
        let update = r#"{"op":"update","id":"00000000000000ff","edits":[
            {"edit":"task_cost","task":2,"costs":[1.5,3.0]},
            {"edit":"edge_cost","src":1,"dst":3,"data":9.0},
            {"edit":"add_edge","src":0,"dst":4,"data":1.0},
            {"edit":"remove_edge","src":1,"dst":2},
            {"edit":"add_task","costs":[2.0]},
            {"edit":"remove_task","task":1}]}"#
            .replace('\n', "");
        match parse_request(&update).unwrap() {
            Request::Update { id, edits, .. } => {
                assert_eq!(id, 0xff);
                assert_eq!(edits.len(), 6);
                assert_eq!(
                    edits[0],
                    GraphEdit::TaskCost {
                        task: 2,
                        costs: vec![1.5, 3.0]
                    }
                );
                assert_eq!(edits[3], GraphEdit::RemoveEdge { src: 1, dst: 2 });
                assert_eq!(edits[5], GraphEdit::RemoveTask { task: 1 });
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn op_codes_roundtrip_to_wire_names() {
        let inst = crate::graph::io::instance_from_json(
            &Json::parse(&sample_instance_json()).unwrap(),
        )
        .unwrap();
        let reqs = vec![
            Request::Ping,
            Request::Submit {
                instance: inst.clone(),
                platform: None,
            },
            Request::CriticalPath {
                target: Target::Handle(1),
                slack: false,
                deadline_ms: None,
            },
            Request::Schedule {
                algorithm: Algorithm::CeftCpop,
                target: Target::Handle(1),
                deadline_ms: None,
            },
            Request::Stats,
            Request::Evict { id: 1 },
            Request::Clear,
            Request::Shutdown,
            Request::Trace { limit: 4 },
            Request::Metrics,
            Request::Update {
                id: 1,
                edits: vec![GraphEdit::RemoveEdge { src: 0, dst: 1 }],
                deadline_ms: None,
            },
        ];
        let mut codes = std::collections::HashSet::new();
        for req in &reqs {
            let code = op_code(req);
            assert!(codes.insert(code), "duplicate op code {code}");
            // every op's trace label parses back to the same variant
            let name = op_name(code);
            let back = parse_request(&format!(r#"{{"op":"{name}","instance":{},"algorithm":"ceft-cpop","id":"01","edits":[{{"edit":"remove_edge","src":0,"dst":1}}]}}"#, sample_instance_json()));
            // `id` + `instance` coexisting is fine (id wins for targets);
            // the point is the name is a real wire op
            assert!(back.is_ok(), "op_name({code}) = {name:?} not parseable");
            assert_eq!(op_code(&back.unwrap()), code);
        }
        assert_eq!(op_name(OP_INVALID), "invalid");
        assert_eq!(op_name(200), "invalid");
    }

    #[test]
    fn error_paths_are_reported_not_panicked() {
        assert!(parse_request("not json").unwrap_err().contains("bad json"));
        assert!(parse_request("{}").unwrap_err().contains("missing \"op\""));
        assert!(parse_request(r#"{"op":"frobnicate"}"#)
            .unwrap_err()
            .contains("unknown op"));
        assert!(parse_request(r#"{"op":"submit"}"#)
            .unwrap_err()
            .contains("requires \"instance\""));
        assert!(parse_request(r#"{"op":"schedule","instance":{}}"#)
            .unwrap_err()
            .contains("requires \"algorithm\""));
        let bad_algo = format!(
            r#"{{"op":"schedule","algorithm":"nope","instance":{}}}"#,
            sample_instance_json()
        );
        assert!(parse_request(&bad_algo)
            .unwrap_err()
            .contains("unknown algorithm"));
        assert!(parse_request(r#"{"op":"cp","id":"zz"}"#)
            .unwrap_err()
            .contains("bad handle"));
        assert!(parse_request(r#"{"op":"evict"}"#)
            .unwrap_err()
            .contains("requires \"id\""));
        // update is handle-only and needs a non-empty edits array
        assert!(parse_request(r#"{"op":"update","edits":[]}"#)
            .unwrap_err()
            .contains("handle-only"));
        assert!(parse_request(r#"{"op":"update","id":"01","edits":[]}"#)
            .unwrap_err()
            .contains("at least one edit"));
        assert!(parse_request(r#"{"op":"update","id":"01"}"#)
            .unwrap_err()
            .contains("\"edits\""));
        assert!(
            parse_request(r#"{"op":"update","id":"01","edits":[{"edit":"warp"}]}"#)
                .unwrap_err()
                .contains("unknown edit kind")
        );
        assert!(
            parse_request(r#"{"op":"update","id":"01","edits":[{"edit":"add_edge","src":0}]}"#)
                .unwrap_err()
                .contains("\"dst\"")
        );
        assert!(
            parse_request(r#"{"op":"update","id":"01","edits":[{"edit":"task_cost","task":0,"costs":["x"]}]}"#)
                .unwrap_err()
                .contains("numbers")
        );
        assert!(parse_request(r#"{"op":"cp","id":"01","slack":1}"#)
            .unwrap_err()
            .contains("boolean"));
        // deadline_ms must be a finite non-negative number: negatives,
        // infinities (1e999 parses to +inf) and strings are all refused
        for bad in [
            r#"{"op":"cp","id":"01","deadline_ms":-5}"#,
            r#"{"op":"cp","id":"01","deadline_ms":1e999}"#,
            r#"{"op":"schedule","algorithm":"ceft-cpop","id":"01","deadline_ms":"soon"}"#,
            r#"{"op":"update","id":"01","edits":[{"edit":"remove_edge","src":0,"dst":1}],"deadline_ms":-1}"#,
        ] {
            assert!(
                parse_request(bad)
                    .unwrap_err()
                    .contains("finite non-negative"),
                "accepted bad deadline: {bad}"
            );
        }
        // malformed instance content surfaces io's message
        let cyc = r#"{"op":"cp","instance":{"n":2,"p":1,"edges":[[0,1,1.0],[1,0,1.0]],"comp":[1,2]}}"#;
        assert!(parse_request(cyc).unwrap_err().contains("cycle"));
        // platform class-count mismatch
        let mismatch = format!(
            r#"{{"op":"cp","instance":{},"platform":{{"p":3,"startup":[0,0,0],"bandwidth":[1,1,1,1,1,1,1,1,1]}}}}"#,
            sample_instance_json()
        );
        assert!(parse_request(&mismatch)
            .unwrap_err()
            .contains("classes"));
    }

    #[test]
    fn handles_roundtrip_hex() {
        for h in [0u64, 1, 0xdead_beef, u64::MAX] {
            assert_eq!(parse_handle(&handle_to_hex(h)).unwrap(), h);
        }
    }

    #[test]
    fn request_encoder_roundtrips_through_parser() {
        let inst = crate::graph::io::instance_from_json(
            &Json::parse(&sample_instance_json()).unwrap(),
        )
        .unwrap();
        let reqs = vec![
            Request::Ping,
            Request::Stats,
            Request::Clear,
            Request::Shutdown,
            Request::Trace { limit: 12 },
            Request::Metrics,
            Request::Evict { id: 0xbeef },
            Request::Submit {
                instance: inst.clone(),
                platform: Some(crate::platform::Platform::uniform(1, 2.0, 0.5)),
            },
            Request::CriticalPath {
                target: Target::Handle(7),
                slack: false,
                deadline_ms: None,
            },
            Request::CriticalPath {
                target: Target::Handle(7),
                slack: true,
                deadline_ms: Some(250),
            },
            Request::Schedule {
                algorithm: Algorithm::CeftHeftUp,
                target: Target::Inline {
                    instance: inst,
                    platform: None,
                },
                deadline_ms: Some(1000),
            },
            Request::Update {
                id: 0xabc,
                deadline_ms: None,
                edits: vec![
                    GraphEdit::TaskCost {
                        task: 0,
                        costs: vec![2.5],
                    },
                    GraphEdit::EdgeCost {
                        src: 0,
                        dst: 1,
                        data: 0.25,
                    },
                    GraphEdit::AddEdge {
                        src: 0,
                        dst: 1,
                        data: 1.5,
                    },
                    GraphEdit::RemoveEdge { src: 0, dst: 1 },
                    GraphEdit::AddTask { costs: vec![1.0] },
                    GraphEdit::RemoveTask { task: 1 },
                ],
            },
        ];
        for req in reqs {
            let line = request_to_json(&req).to_string();
            let back = parse_request(&line)
                .unwrap_or_else(|e| panic!("encoded {req:?} failed to parse: {e} ({line})"));
            // the re-encoded form is identical (field set and values agree)
            assert_eq!(
                request_to_json(&back).to_string(),
                line,
                "encode/parse/encode not a fixed point"
            );
        }
    }

    #[test]
    fn response_builders_shape() {
        let ok = ok_response(vec![("x", Json::Num(1.0))]);
        assert_eq!(ok.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(ok.get("x").and_then(Json::as_f64), Some(1.0));
        let err = error_response("boom");
        assert_eq!(err.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(err.get("error").and_then(Json::as_str), Some("boom"));
        let shed = error_response_with("shed", vec![("retry_after_ms", Json::Num(25.0))]);
        assert_eq!(shed.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(shed.get("error").and_then(Json::as_str), Some("shed"));
        assert_eq!(shed.get("retry_after_ms").and_then(Json::as_f64), Some(25.0));
    }
}
