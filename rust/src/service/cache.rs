//! Bounded LRU result cache with hit/miss/eviction accounting.
//!
//! The engine keeps two of these: one for CEFT critical paths and one for
//! schedules, both keyed by [`CacheKey`]. Recency is tracked with a
//! monotonic tick and a `BTreeMap<tick, key>` index, giving `O(log n)`
//! touch/insert/evict without unsafe code or intrusive lists — plenty for a
//! cache bounded at thousands of entries, and trivially correct to audit.

use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Lock a mutex, recovering from poisoning instead of propagating it.
///
/// A mutex is poisoned when a thread panicked while holding it. Every lock
/// in `service` guards state with its own consistency story (caches can
/// only go stale-empty, in-flight tables are cleaned up by the panicking
/// path's unwind contract), so the right response to poison is to keep
/// serving with the data as-is — one caught panic must not turn every
/// later request on the engine into an error. See
/// EXPERIMENTS.md §Overload & fault model.
pub(crate) fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait`] with the same poison-recovery policy as
/// [`lock_clean`]: a panic elsewhere while we slept must not kill this
/// waiter, whose wake condition is re-checked by the caller's loop anyway.
pub(crate) fn wait_clean<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Memoization key: structural hashes of the problem parts plus the
/// algorithm id ([`crate::sched::Algorithm::id`], or the critical-path
/// marker used by the engine).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheKey {
    /// [`crate::service::hashing::hash_graph`] of the task graph
    pub graph: u64,
    /// [`crate::service::hashing::hash_platform`] of the platform
    pub platform: u64,
    /// [`crate::service::hashing::hash_comp`] of the realized cost matrix
    pub comp: u64,
    /// algorithm id (the cost model is already folded into `comp`)
    pub algorithm: u64,
    /// generation of the interned instance the result was computed over
    /// (`0` for never-edited instances). Edits bump the generation instead
    /// of re-hashing, so the structural hashes above stay those of the
    /// *original* submission — the generation is what keeps a post-edit
    /// result from colliding with a pre-edit one.
    pub generation: u64,
}

/// Counters exposed through the service stats endpoint.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// lookups that found a live entry
    pub hits: u64,
    /// lookups that missed
    pub misses: u64,
    /// entries written (including overwrites)
    pub insertions: u64,
    /// entries displaced by the capacity bound
    pub evictions: u64,
    /// misses that piggybacked on another request's in-flight computation
    /// instead of running the DP themselves (the engine's single-flight
    /// dedup; every dedup hit is also counted in `misses` — the lookup did
    /// miss the cache — so `misses - dedup_hits` is the number of actual
    /// computations)
    pub dedup_hits: u64,
    /// distinct-key computations served by a **gathered** multi-request
    /// sweep (the engine's cross-request batching): every request whose DP
    /// ran inside a batch of width ≥ 2 counts once, so
    /// `batched_requests / requests` is the loadgen batch-efficiency ratio
    pub batched_requests: u64,
    /// high-water gather width: the widest multi-request sweep observed
    /// (0 until the first batch of width ≥ 2 forms)
    pub batch_width: u64,
    /// cp↔schedule table shares: lookups of one request kind (critical
    /// path vs schedule) served by a memoized CEFT table the *other* kind
    /// computed — each is a whole `O(P²e)` DP the mutual-inclusivity memo
    /// eliminated (only meaningful on the engine's table cache)
    pub cp_schedule_shares: u64,
    /// table rows actually recomputed by delta-planned sweeps (the dirty
    /// suffix minus change-propagation copies); only sweeps that carried a
    /// delta basis count here
    pub delta_rows_recomputed: u64,
    /// total table rows those same delta-planned sweeps *would* have
    /// computed from scratch — `delta_rows_recomputed / delta_full_rows`
    /// is the fraction of the DP an edit actually cost
    pub delta_full_rows: u64,
    /// table computations routed to the series-parallel tree-DP kernel
    /// because the interned shape verdict carried an `SpTree`
    /// ([`crate::cp::ceft::sp`]); only meaningful on the engine's table
    /// cache
    pub shape_fast_path_hits: u64,
    /// table computations that ran the general topo-sweep kernel — either
    /// the graph is a general DAG or the request rode a delta/gathered
    /// path where the basis table dictates the kernel
    pub shape_general_fallbacks: u64,
}

impl CacheStats {
    /// Accumulate another shard's counters into this one — how the engine
    /// reports aggregate stats over its per-platform cache shards.
    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.insertions += other.insertions;
        self.evictions += other.evictions;
        self.dedup_hits += other.dedup_hits;
        self.batched_requests += other.batched_requests;
        self.batch_width = self.batch_width.max(other.batch_width);
        self.cp_schedule_shares += other.cp_schedule_shares;
        self.delta_rows_recomputed += other.delta_rows_recomputed;
        self.delta_full_rows += other.delta_full_rows;
        self.shape_fast_path_hits += other.shape_fast_path_hits;
        self.shape_general_fallbacks += other.shape_general_fallbacks;
    }
}

/// A bounded least-recently-used map.
pub struct LruCache<K, V> {
    cap: usize,
    map: HashMap<K, (u64, V)>,
    order: BTreeMap<u64, K>,
    tick: u64,
    stats: CacheStats,
}

impl<K: Eq + Hash + Copy, V> LruCache<K, V> {
    /// New cache bounded at `cap` entries (`cap >= 1`).
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "cache capacity must be at least 1");
        Self {
            cap,
            map: HashMap::new(),
            order: BTreeMap::new(),
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Look up `k`, bumping its recency on a hit.
    pub fn get(&mut self, k: &K) -> Option<&V> {
        let tick = self.next_tick();
        if let Some(entry) = self.map.get_mut(k) {
            self.order.remove(&entry.0);
            entry.0 = tick;
            self.order.insert(tick, *k);
            self.stats.hits += 1;
            Some(&entry.1)
        } else {
            self.stats.misses += 1;
            None
        }
    }

    /// Check for `k` without bumping recency or counting a hit/miss.
    pub fn peek(&self, k: &K) -> Option<&V> {
        self.map.get(k).map(|(_, v)| v)
    }

    /// Visit every live entry without touching recency or stats
    /// (arbitrary order — callers that need determinism sort). Used by the
    /// engine's stats endpoint to report per-platform-context pool gauges.
    pub fn iter(&self) -> impl Iterator<Item = (&K, &V)> {
        self.map.iter().map(|(k, (_, v))| (k, v))
    }

    /// Insert (or overwrite) `k`, evicting the least-recently-used entry
    /// when over capacity. Returns the evicted `(key, value)` when the
    /// capacity bound displaced one — the engine uses it to retire the
    /// cache shard of an evicted platform context alongside the context.
    pub fn put(&mut self, k: K, v: V) -> Option<(K, V)> {
        let tick = self.next_tick();
        let mut evicted = None;
        if let Some((old_tick, _)) = self.map.insert(k, (tick, v)) {
            self.order.remove(&old_tick);
        } else if self.map.len() > self.cap {
            // the new key has no order entry yet, so it can't be the victim
            if let Some((_, victim)) = self.order.pop_first() {
                evicted = self.map.remove(&victim).map(|(_, v)| (victim, v));
                self.stats.evictions += 1;
            }
        }
        self.order.insert(tick, k);
        self.stats.insertions += 1;
        evicted
    }

    /// Remove one key; returns its value when present.
    pub fn remove(&mut self, k: &K) -> Option<V> {
        match self.map.remove(k) {
            Some((tick, v)) => {
                self.order.remove(&tick);
                Some(v)
            }
            None => None,
        }
    }

    /// Remove every key matching a predicate; returns how many were removed.
    pub fn remove_matching<F: Fn(&K) -> bool>(&mut self, f: F) -> usize {
        let victims: Vec<K> = self.map.keys().filter(|k| f(k)).copied().collect();
        for k in &victims {
            self.remove(k);
        }
        victims.len()
    }

    /// Drop every entry (stats are kept).
    pub fn clear(&mut self) {
        self.map.clear();
        self.order.clear();
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Capacity bound.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Record one single-flight dedup hit: a lookup that missed but was
    /// satisfied by waiting on another request's in-flight computation
    /// (counted by the engine, which owns the in-flight table).
    pub fn record_dedup_hit(&mut self) {
        self.stats.dedup_hits += 1;
    }

    /// Record one gathered multi-request sweep of `width` distinct keys
    /// (the engine's cross-request batching; only widths ≥ 2 are batches).
    pub fn record_batch(&mut self, width: u64) {
        if width >= 2 {
            self.stats.batched_requests += width;
            self.stats.batch_width = self.stats.batch_width.max(width);
        }
    }

    /// Record one cp↔schedule table share: a lookup of one request kind
    /// served by a table the other kind computed (the engine's table memo
    /// — one eliminated `O(P²e)` DP per call).
    pub fn record_share(&mut self) {
        self.stats.cp_schedule_shares += 1;
    }

    /// Record one delta-planned sweep: `recomputed` rows actually run
    /// against the `full` rows a from-scratch sweep would have cost.
    pub fn record_delta(&mut self, recomputed: u64, full: u64) {
        self.stats.delta_rows_recomputed += recomputed;
        self.stats.delta_full_rows += full;
    }

    /// Record one table computation's kernel routing: `fast_path` is
    /// `true` when the interned shape verdict sent it to the
    /// series-parallel tree DP, `false` when it ran the general sweep.
    pub fn record_shape_route(&mut self, fast_path: bool) {
        if fast_path {
            self.stats.shape_fast_path_hits += 1;
        } else {
            self.stats.shape_general_fallbacks += 1;
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(n: u64) -> CacheKey {
        CacheKey {
            graph: n,
            platform: 10 + n,
            comp: 20 + n,
            algorithm: 0,
            generation: 0,
        }
    }

    #[test]
    fn generation_distinguishes_otherwise_equal_keys() {
        let mut c: LruCache<CacheKey, u32> = LruCache::new(4);
        let g0 = key(1);
        let g1 = CacheKey { generation: 1, ..g0 };
        c.put(g0, 10);
        c.put(g1, 11);
        assert_eq!(c.peek(&g0), Some(&10));
        assert_eq!(c.peek(&g1), Some(&11));
    }

    #[test]
    fn delta_counters_accumulate_and_merge() {
        let mut c: LruCache<CacheKey, u32> = LruCache::new(2);
        c.record_delta(3, 40);
        c.record_delta(5, 40);
        let s = c.stats();
        assert_eq!(s.delta_rows_recomputed, 8);
        assert_eq!(s.delta_full_rows, 80);
        let mut agg = CacheStats {
            delta_rows_recomputed: 2,
            delta_full_rows: 20,
            ..CacheStats::default()
        };
        agg.merge(&s);
        assert_eq!(agg.delta_rows_recomputed, 10);
        assert_eq!(agg.delta_full_rows, 100);
    }

    #[test]
    fn shape_route_counters_accumulate_and_merge() {
        let mut c: LruCache<CacheKey, u32> = LruCache::new(2);
        c.record_shape_route(true);
        c.record_shape_route(true);
        c.record_shape_route(false);
        let s = c.stats();
        assert_eq!(s.shape_fast_path_hits, 2);
        assert_eq!(s.shape_general_fallbacks, 1);
        let mut agg = CacheStats {
            shape_fast_path_hits: 1,
            shape_general_fallbacks: 4,
            ..CacheStats::default()
        };
        agg.merge(&s);
        assert_eq!(agg.shape_fast_path_hits, 3);
        assert_eq!(agg.shape_general_fallbacks, 5);
    }

    #[test]
    fn hit_and_miss_accounting() {
        let mut c: LruCache<CacheKey, u32> = LruCache::new(4);
        assert!(c.get(&key(1)).is_none());
        c.put(key(1), 11);
        assert_eq!(c.get(&key(1)), Some(&11));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.insertions, s.evictions), (1, 1, 1, 0));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c: LruCache<CacheKey, u32> = LruCache::new(2);
        c.put(key(1), 1);
        c.put(key(2), 2);
        // touch 1 so 2 becomes the LRU
        assert!(c.get(&key(1)).is_some());
        c.put(key(3), 3);
        assert_eq!(c.len(), 2);
        assert!(c.peek(&key(2)).is_none(), "LRU entry must be evicted");
        assert!(c.peek(&key(1)).is_some());
        assert!(c.peek(&key(3)).is_some());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn overwrite_does_not_evict() {
        let mut c: LruCache<CacheKey, u32> = LruCache::new(2);
        c.put(key(1), 1);
        c.put(key(2), 2);
        c.put(key(1), 100);
        assert_eq!(c.len(), 2);
        assert_eq!(c.peek(&key(1)), Some(&100));
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn remove_and_remove_matching() {
        let mut c: LruCache<CacheKey, u32> = LruCache::new(8);
        for n in 0..6 {
            c.put(key(n), n as u32);
        }
        assert_eq!(c.remove(&key(3)), Some(3));
        assert_eq!(c.remove(&key(3)), None);
        let removed = c.remove_matching(|k| k.graph < 2);
        assert_eq!(removed, 2);
        assert_eq!(c.len(), 3);
        // removed keys can be re-inserted and found again
        c.put(key(0), 99);
        assert_eq!(c.get(&key(0)), Some(&99));
    }

    #[test]
    fn clear_keeps_stats() {
        let mut c: LruCache<CacheKey, u32> = LruCache::new(2);
        c.put(key(1), 1);
        assert!(c.get(&key(1)).is_some());
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats().hits, 1);
    }

    #[test]
    fn put_returns_the_evicted_entry() {
        let mut c: LruCache<CacheKey, u32> = LruCache::new(2);
        assert_eq!(c.put(key(1), 1), None);
        assert_eq!(c.put(key(2), 2), None);
        // overwrite never evicts
        assert_eq!(c.put(key(2), 20), None);
        // capacity displacement returns the LRU victim
        assert_eq!(c.put(key(3), 3), Some((key(1), 1)));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn batch_counters_accumulate_and_merge() {
        let mut c: LruCache<CacheKey, u32> = LruCache::new(2);
        c.record_batch(1); // width 1 is not a batch
        assert_eq!(c.stats().batched_requests, 0);
        c.record_batch(3);
        c.record_batch(2);
        let s = c.stats();
        assert_eq!(s.batched_requests, 5);
        assert_eq!(s.batch_width, 3);
        let mut agg = CacheStats::default();
        agg.merge(&s);
        let other = CacheStats {
            batched_requests: 7,
            batch_width: 2,
            hits: 4,
            ..CacheStats::default()
        };
        agg.merge(&other);
        assert_eq!(agg.batched_requests, 12);
        assert_eq!(agg.batch_width, 3, "width merges as a high-water mark");
        assert_eq!(agg.hits, 4);
    }

    #[test]
    fn share_counter_accumulates_and_merges() {
        let mut c: LruCache<CacheKey, u32> = LruCache::new(2);
        c.record_share();
        c.record_share();
        assert_eq!(c.stats().cp_schedule_shares, 2);
        let mut agg = CacheStats {
            cp_schedule_shares: 3,
            ..CacheStats::default()
        };
        agg.merge(&c.stats());
        assert_eq!(agg.cp_schedule_shares, 5, "shares merge additively");
    }

    #[test]
    fn poisoned_lock_recovers_with_data_intact() {
        use std::sync::{Arc, Mutex};
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let joined = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(joined.is_err());
        assert!(m.is_poisoned());
        assert_eq!(*lock_clean(&m), 7, "data survives the poison flag");
        *lock_clean(&m) = 8;
        assert_eq!(*lock_clean(&m), 8, "lock stays usable after recovery");
    }

    #[test]
    fn heavy_churn_respects_capacity() {
        let mut c: LruCache<CacheKey, u64> = LruCache::new(16);
        for n in 0..1000 {
            c.put(key(n), n);
            assert!(c.len() <= 16);
        }
        assert_eq!(c.len(), 16);
        // the 16 most recent keys survive
        for n in 984..1000 {
            assert!(c.peek(&key(n)).is_some(), "key {n} should be live");
        }
        assert_eq!(c.stats().evictions, 1000 - 16);
    }
}
