//! Deterministic fault injection — the test substrate for the engine's
//! resilience layer.
//!
//! A [`FaultPlan`] is a seeded, ordinal-addressed schedule of faults:
//! kernel panics, pre-kernel stage delays, and connection drops. Each
//! fault site owns a monotonic ordinal counter; visiting the site
//! advances the counter and the plan decides *deterministically* from
//! `(ordinal, seed)` whether the fault fires. The determinism contract
//! (see EXPERIMENTS.md §Overload & fault model): for a fixed plan and a
//! fixed serial sequence of site visits, the same visits fault on every
//! run. Under concurrency the *set* of ordinals is still consumed exactly
//! once each — total fault counts are reproducible even when the mapping
//! from ordinal to request is not.
//!
//! Zero-cost when off: the engine stores `Option<Arc<FaultPlan>>` and
//! every hook is behind a single `is_some` branch; a disarmed plan
//! ([`FaultPlan::disarm`]) stops advancing ordinals entirely, so a
//! post-fault replay runs the exact fault-free code path.
//!
//! Plan syntax (`repro serve --fault-plan`, `repro loadgen --fault-plan`,
//! or the `CEFT_FAULT` environment variable):
//!
//! ```text
//! seed=7,kernel_panic=13x4,delay=9:25x6,conn_drop=5x1
//! ```
//!
//! * `seed=N` — phase-shifts every rule: a rule with period `E` fires on
//!   ordinals `o` with `o % E == seed % E`.
//! * `kernel_panic=E[xC]` — every `E`-th gathered/width-1 table kernel
//!   call panics, at most `C` times (`x` omitted ⇒ unbounded).
//! * `delay=E:MS[xC]` — every `E`-th compute request (`cp` / `schedule` /
//!   `update`) sleeps `MS` milliseconds before its deadline checks, at
//!   most `C` times.
//! * `conn_drop=E[xC]` — every `E`-th TCP request line is dropped: the
//!   connection closes without a response, at most `C` times.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

/// One ordinal-addressed fault rule: fire on every `every`-th visit whose
/// ordinal is congruent to `phase`, at most `limit` times.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Rule {
    every: u64,
    phase: u64,
    limit: u64,
}

impl Rule {
    fn new(every: u64, seed: u64, limit: u64) -> Result<Rule, String> {
        if every == 0 {
            return Err("fault rule period must be >= 1".to_string());
        }
        Ok(Rule {
            every,
            phase: seed % every,
            limit,
        })
    }
}

/// A seeded deterministic fault schedule. See the module docs for the
/// spec grammar and determinism contract.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    kernel_panic: Option<Rule>,
    delay: Option<(Rule, u64)>,
    conn_drop: Option<Rule>,
    kernel_ordinal: AtomicU64,
    request_ordinal: AtomicU64,
    line_ordinal: AtomicU64,
    panics_fired: AtomicU64,
    delays_fired: AtomicU64,
    drops_fired: AtomicU64,
    armed: AtomicBool,
}

impl Clone for FaultPlan {
    /// Cloning yields the same *schedule* with fresh ordinal counters — a
    /// clone replays the plan from ordinal zero.
    fn clone(&self) -> FaultPlan {
        FaultPlan {
            seed: self.seed,
            kernel_panic: self.kernel_panic,
            delay: self.delay,
            conn_drop: self.conn_drop,
            kernel_ordinal: AtomicU64::new(0),
            request_ordinal: AtomicU64::new(0),
            line_ordinal: AtomicU64::new(0),
            panics_fired: AtomicU64::new(0),
            delays_fired: AtomicU64::new(0),
            drops_fired: AtomicU64::new(0),
            armed: AtomicBool::new(true),
        }
    }
}

/// Parse `E[xC]` — a period with an optional firing cap.
fn parse_rule(text: &str, seed: u64) -> Result<Rule, String> {
    let (every, limit) = match text.split_once('x') {
        Some((e, c)) => (
            e.parse::<u64>().map_err(|_| format!("bad period {e:?}"))?,
            c.parse::<u64>().map_err(|_| format!("bad cap {c:?}"))?,
        ),
        None => (
            text.parse::<u64>()
                .map_err(|_| format!("bad period {text:?}"))?,
            u64::MAX,
        ),
    };
    Rule::new(every, seed, limit)
}

impl FaultPlan {
    /// Parse a plan spec (see module docs). Errors name the offending
    /// clause — suitable for a CLI flag message.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        // two passes so `seed=` phases every rule regardless of clause order
        let mut seed = 0u64;
        for clause in spec.split(',').filter(|c| !c.trim().is_empty()) {
            if let Some(v) = clause.trim().strip_prefix("seed=") {
                seed = v
                    .parse::<u64>()
                    .map_err(|_| format!("bad fault seed {v:?}"))?;
            }
        }
        let mut kernel_panic = None;
        let mut delay = None;
        let mut conn_drop = None;
        for clause in spec.split(',').filter(|c| !c.trim().is_empty()) {
            let clause = clause.trim();
            let (key, value) = clause
                .split_once('=')
                .ok_or_else(|| format!("fault clause {clause:?} is not key=value"))?;
            match key {
                "seed" => {}
                "kernel_panic" => kernel_panic = Some(parse_rule(value, seed)?),
                "delay" => {
                    let (rule_text, ms_text) = value
                        .split_once(':')
                        .ok_or_else(|| format!("delay clause {value:?} needs EVERY:MS"))?;
                    // the cap rides the millisecond part: delay=E:MSxC
                    let (ms_text, cap) = match ms_text.split_once('x') {
                        Some((m, c)) => (m, Some(c)),
                        None => (ms_text, None),
                    };
                    let ms = ms_text
                        .parse::<u64>()
                        .map_err(|_| format!("bad delay ms {ms_text:?}"))?;
                    let rule_spec = match cap {
                        Some(c) => format!("{rule_text}x{c}"),
                        None => rule_text.to_string(),
                    };
                    delay = Some((parse_rule(&rule_spec, seed)?, ms));
                }
                "conn_drop" => conn_drop = Some(parse_rule(value, seed)?),
                other => return Err(format!("unknown fault clause {other:?}")),
            }
        }
        if kernel_panic.is_none() && delay.is_none() && conn_drop.is_none() {
            return Err("fault plan has no rules".to_string());
        }
        Ok(FaultPlan {
            seed,
            kernel_panic,
            delay,
            conn_drop,
            kernel_ordinal: AtomicU64::new(0),
            request_ordinal: AtomicU64::new(0),
            line_ordinal: AtomicU64::new(0),
            panics_fired: AtomicU64::new(0),
            delays_fired: AtomicU64::new(0),
            drops_fired: AtomicU64::new(0),
            armed: AtomicBool::new(true),
        })
    }

    /// Build a plan from the `CEFT_FAULT` environment variable, if set.
    /// A malformed spec is reported to stderr and ignored — a typo in an
    /// env var must not take the server down at startup.
    pub fn from_env() -> Option<FaultPlan> {
        let spec = std::env::var("CEFT_FAULT").ok()?;
        if spec.trim().is_empty() {
            return None;
        }
        match FaultPlan::parse(&spec) {
            Ok(plan) => Some(plan),
            Err(e) => {
                eprintln!("ignoring CEFT_FAULT={spec:?}: {e}");
                None
            }
        }
    }

    fn fires(&self, rule: Option<Rule>, ordinal: &AtomicU64, fired: &AtomicU64) -> bool {
        let Some(r) = rule else { return false };
        if !self.armed.load(Ordering::Relaxed) {
            return false;
        }
        let o = ordinal.fetch_add(1, Ordering::Relaxed);
        if o % r.every != r.phase {
            return false;
        }
        // bounded burst: only the first `limit` congruent visits fire
        fired.fetch_add(1, Ordering::Relaxed) < r.limit
    }

    /// Visit the kernel fault site; `true` means the caller must panic
    /// (the engine does, with [`INJECTED_PANIC`] in the message, inside
    /// its gather `catch_unwind` so the recovery contracts are exercised).
    pub fn should_panic_kernel(&self) -> bool {
        self.fires(self.kernel_panic, &self.kernel_ordinal, &self.panics_fired)
    }

    /// Visit the request-delay site; `Some(d)` means the caller sleeps
    /// `d` before its deadline checks.
    pub fn injected_delay(&self) -> Option<Duration> {
        let (rule, ms) = self.delay?;
        if self.fires(Some(rule), &self.request_ordinal, &self.delays_fired) {
            Some(Duration::from_millis(ms))
        } else {
            None
        }
    }

    /// Visit the connection-drop site; `true` means the server closes the
    /// connection without responding to the line just read.
    pub fn should_drop_connection(&self) -> bool {
        self.fires(self.conn_drop, &self.line_ordinal, &self.drops_fired)
    }

    /// Disarm every rule: subsequent visits neither fire nor advance
    /// ordinals, so a replay after `disarm()` runs fault-free.
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::Relaxed);
    }

    /// Whether the plan is still armed.
    pub fn armed(&self) -> bool {
        self.armed.load(Ordering::Relaxed)
    }

    /// Total faults fired so far: `(kernel panics, delays, conn drops)`.
    pub fn fired(&self) -> (u64, u64, u64) {
        (
            self.panics_fired.load(Ordering::Relaxed),
            self.delays_fired.load(Ordering::Relaxed),
            self.drops_fired.load(Ordering::Relaxed),
        )
    }

    /// The plan's seed (surfaced in stats for reproducibility).
    pub fn seed(&self) -> u64 {
        self.seed
    }
}

/// Marker substring carried by every injected kernel panic's payload, so
/// tests (and log readers) can tell an injected fault from a real defect.
pub const INJECTED_PANIC: &str = "injected fault: kernel panic";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec_and_rejects_bad_clauses() {
        let p = FaultPlan::parse("seed=7,kernel_panic=13x4,delay=9:25x6,conn_drop=5x1").unwrap();
        assert_eq!(p.seed(), 7);
        assert_eq!(p.kernel_panic, Some(Rule { every: 13, phase: 7, limit: 4 }));
        assert_eq!(p.delay, Some((Rule { every: 9, phase: 7, limit: 6 }, 25)));
        assert_eq!(p.conn_drop, Some(Rule { every: 5, phase: 2, limit: 1 }));
        // seed phases rules regardless of clause order
        let p2 = FaultPlan::parse("kernel_panic=13x4,seed=7").unwrap();
        assert_eq!(p2.kernel_panic, Some(Rule { every: 13, phase: 7, limit: 4 }));
        assert!(FaultPlan::parse("").is_err(), "empty plan has no rules");
        assert!(FaultPlan::parse("seed=1").is_err(), "seed alone has no rules");
        assert!(FaultPlan::parse("kernel_panic=0").is_err(), "period 0");
        assert!(FaultPlan::parse("warp=1").is_err(), "unknown clause");
        assert!(FaultPlan::parse("delay=5").is_err(), "delay needs :MS");
        assert!(FaultPlan::parse("kernel_panic=abc").is_err());
    }

    #[test]
    fn ordinals_fire_deterministically_with_phase_and_cap() {
        let p = FaultPlan::parse("seed=1,kernel_panic=3x2").unwrap();
        // phase = 1 % 3 = 1: ordinals 1 and 4 fire, the cap stops 7
        let fired: Vec<bool> = (0..9).map(|_| p.should_panic_kernel()).collect();
        assert_eq!(
            fired,
            vec![false, true, false, false, true, false, false, false, false]
        );
        assert_eq!(p.fired().0, 2);
        // a clone replays the same schedule from ordinal zero
        let q = p.clone();
        let refired: Vec<bool> = (0..9).map(|_| q.should_panic_kernel()).collect();
        assert_eq!(fired, refired);
    }

    #[test]
    fn delay_site_returns_duration_and_respects_disarm() {
        let p = FaultPlan::parse("delay=2:40").unwrap();
        // phase 0: ordinals 0, 2, 4 … fire
        assert_eq!(p.injected_delay(), Some(Duration::from_millis(40)));
        assert_eq!(p.injected_delay(), None);
        assert_eq!(p.injected_delay(), Some(Duration::from_millis(40)));
        p.disarm();
        assert!(!p.armed());
        for _ in 0..8 {
            assert_eq!(p.injected_delay(), None, "disarmed plans never fire");
        }
        assert_eq!(p.fired().1, 2);
    }

    #[test]
    fn independent_sites_keep_independent_ordinals() {
        let p = FaultPlan::parse("kernel_panic=1x1,conn_drop=2x8").unwrap();
        assert!(p.should_panic_kernel());
        assert!(!p.should_panic_kernel(), "cap 1 exhausted");
        // the kernel visits above must not have advanced the line ordinal
        assert!(p.should_drop_connection()); // ordinal 0
        assert!(!p.should_drop_connection()); // ordinal 1
        assert!(p.should_drop_connection()); // ordinal 2
        assert_eq!(p.fired(), (1, 0, 2));
    }
}
