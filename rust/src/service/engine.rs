//! The persistent scheduling engine and its serving loops.
//!
//! [`Engine`] is the long-lived heart of the service: it interns platforms
//! (as [`PlatformCtx`] execution contexts) and task graphs by structural
//! hash, memoizes CEFT critical paths and schedules in LRU caches keyed by
//! `(graph-hash, platform-hash, comp-hash, algorithm)`, and dispatches
//! every computation through the unified [`Algorithm`] registry — the same
//! code paths as the batch `repro schedule` / `repro cp` commands, so an
//! online answer is bit-identical to the offline one (both inherit
//! [`crate::cp::ceft`]'s deterministic tie-breaking).
//!
//! Platform contexts: the `P × P` communication panels the CEFT kernel
//! prices every edge against depend only on the platform, so the engine
//! interns one `Arc<PlatformCtx>` per distinct platform hash and every
//! instance on that platform borrows it — panels are computed exactly once
//! per distinct platform per process, not per request (the
//! `panel_cache` hit/miss counters in the stats endpoint measure this;
//! `repro loadgen --platform-mix K` exercises it). The context also owns a
//! platform-sized workspace pool: scratch arenas are pooled **per platform
//! shape**, so a large-`P` platform's high-water arenas are never retained
//! for and handed to small-`P` requests (per-context created/idle gauges
//! are in the stats endpoint too).
//!
//! Concurrency model: the memo caches are **sharded per platform
//! context** — each interned platform hash owns a `CacheShard` holding
//! its own result caches and single-flight tables behind its own mutex, so
//! the hit path of a resolved instance never touches the global intern
//! lock (request counters are plain atomics) and platform-heavy mixes
//! scale past one lock. All algorithm work (the `O(P²e)` CEFT DP, the
//! list schedulers) runs outside every lock. Uncached keys are
//! **single-flight**: the first requester becomes the leader and runs the
//! DP; concurrent requests for the same key park on the leader's in-flight
//! cell (a `Condvar`) and receive its result the moment it lands, counted
//! as `dedup_hits` in the cache stats. Cache hits never touch the
//! in-flight table, so the fast path is unchanged. Batched entry points fan
//! work across [`crate::util::pool`] workers so throughput scales with
//! cores (see `benches/service_throughput.rs`). Cache misses borrow a
//! long-lived [`crate::cp::workspace::Workspace`] from the instance's
//! platform-context pool (idle list capped at the worker count), so the
//! algorithm core (CEFT DP, rank sweeps, the list scheduler's heap and
//! busy lists) allocates nothing once warmed while retained scratch memory
//! stays bounded — see EXPERIMENTS.md §Workspace and §Platform contexts
//! for the benchmark methodology.
//!
//! Cross-request batching: the CEFT **table** is the shared
//! sub-computation of critical-path requests *and* the CEFT-family
//! schedulers, so the engine memoizes it in its own per-shard cache
//! (`table_cache`, keyed like the result caches with a direction marker in
//! the algorithm slot — `TABLE_FWD_MARKER` / `TABLE_REV_MARKER`) and
//! gathers distinct-key table misses on **one platform** into lock-step
//! [`crate::cp::ceft::find_ceft_tables_gathered`] sweeps via the shard's
//! `BatchCollector` (group commit, saturation-gated, no added wait: below
//! `threads` in-flight gathers every distinct miss computes on its own
//! core exactly as before; a key leader that arrives once the worker
//! budget is saturated queues instead of oversubscribing, and each
//! finishing gather promotes the queue's head, which drains up to
//! [`EngineConfig::batch_window`] queued requests into one sweep — one
//! sweep per table direction present in the window — and fans each result
//! back to its single-flight cell). A critical-path miss derives its path
//! from the memoized table ([`crate::cp::ceft::critical_path_from_table`]);
//! a CEFT-based schedule miss borrows the same table through
//! [`crate::sched::Algorithm::run_with_tables`] — so schedule traffic
//! joins the same gathered sweeps as cp misses, and a mixed cp+schedule
//! workload computes each instance's table exactly once (the
//! `cp_schedule_shares` counter in the table-cache stats counts those
//! cross-workload reuses). Results are bit-identical to serial dispatch —
//! the gathered DP preserves the per-instance comparison sequence exactly,
//! and the table-borrowing schedulers run the same priority/placement code
//! over the same bits — and the `batched_requests` / `batch_width`
//! counters in the table-cache stats (and `repro loadgen`'s
//! batch-efficiency line) measure how often it engages. A gather leader
//! that unwinds resolves every gathered cell with a retry signal and
//! re-raises, exactly like a single-flight leader.
//!
//! Versioned interning and delta recompute: an interned instance is no
//! longer immutable — the `update` op applies [`crate::graph::edit`]
//! batches **in place** under the instance's version mutex, bumping a
//! monotonic `generation` instead of re-hashing into a new handle. Every
//! memo key carries the generation ([`CacheKey::generation`]), so
//! post-edit requests can never observe a pre-edit entry: a reader
//! captures one [`Snapshot`] (graph + costs + generation) and builds its
//! keys from that snapshot, an updater swaps the snapshot and purges every
//! `generation ≤ old` entry under the same locks — stale tables drop
//! atomically with the graph they described. The purged tables are not
//! wasted: the update retains them as a [`DeltaBasis`] (basis graph +
//! accumulated dirty flags), and the next table miss of the new generation
//! runs [`crate::cp::ceft::ceft_table_delta_with`] — copy the clean sweep
//! prefix, recompute only the dirty suffix — instead of the from-scratch
//! DP, bit-identically. Delta-planned computes ride the same
//! [`BatchCollector`] gather queue as everything else (each
//! [`PendingTable`] carries its snapshot and optional delta plan, so a
//! drain started before an edit still computes exactly the generation its
//! key names), and the `delta_rows_recomputed` / `delta_full_rows`
//! counters in the table-cache stats measure the fraction of the DP an
//! edit actually cost. Cost-only, increase-only edit batches whose total
//! increase is bounded by the slack of every edited task provably leave
//! the critical-path length unchanged (see EXPERIMENTS.md §Incremental
//! re-scheduling); such updates skip the eager recompute entirely and
//! answer from the basis (`skipped: true`, zero rows recomputed).
//!
//! Serving loops: [`serve_stdio`] speaks the protocol on stdin/stdout,
//! greedily draining whatever lines are already buffered into one batch;
//! [`Server`] accepts TCP connections (`std::net`) with one thread per
//! connection. Both share one engine, hence one cache.
//!
//! Telemetry: every request carries a stack-local
//! [`crate::obs::RequestTrace`] through
//! parse → intern → ctx build → cache probe → (queue wait → batch drain |
//! kernel) → respond; stage durations land in the engine's [`Recorder`]
//! histograms, surfaced by the `stats` / `trace` / `metrics` ops and the
//! `repro serve --metrics-addr` exposition endpoint. `queue_wait` and
//! `batch_drain` are charged **only** to requests actually served by a
//! width ≥ 2 gathered sweep — the gather leader stamps each drained
//! request's park and sweep durations into its [`PendingTable`]'s
//! [`BatchTiming`] cell, and the parked thread records them after its
//! single-flight cell resolves. A follower parked behind an identical-key
//! leader, and a promoted gather leader's own park, charge `cache_probe`
//! instead (they were not served by a sweep). With telemetry disabled
//! (`CEFT_TELEMETRY=off`, or `EngineConfig::telemetry = Some(false)`)
//! every hook degrades to a branch-predictable no-op with no clock reads.

use crate::cp::ceft::sp::{ceft_table_sp_rev_with, ceft_table_sp_with};
use crate::cp::ceft::{
    ceft_table_delta_with, ceft_table_rev_with, ceft_table_with, critical_path_from_table,
    find_ceft_tables_gathered_delta, slack_from_table_with, CeftTable, CriticalPath, DeltaPlan,
};
use crate::graph::edit::{apply_edits, GraphEdit};
use crate::graph::generator::Instance;
use crate::graph::io;
use crate::graph::shape::{self, ShapeClass, ShapeVerdict, NUM_SHAPE_CLASSES};
use crate::graph::TaskGraph;
use crate::model::{CostMatrix, InstanceRef, PlatformCtx};
use crate::obs::{self, Recorder, RequestTrace, Stage};
use crate::platform::Platform;
use crate::sched::{Algorithm, Schedule, TableDir};
use crate::service::cache::{lock_clean, wait_clean, CacheKey, CacheStats, LruCache};
use crate::service::fault::{FaultPlan, INJECTED_PANIC};
use crate::service::hashing;
use crate::service::protocol::{self, Request, Target};
use crate::util::json::Json;
use crate::util::pool;
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Algorithm-slot marker for critical-path cache entries. Real algorithm
/// ids ([`Algorithm::id`]) are small; this can never collide.
const CP_MARKER: u64 = u64::MAX;

/// Algorithm-slot marker for **forward** CEFT-table cache entries: the
/// memoized `(graph, platform, comp)` table shared by critical-path
/// requests and the forward-table schedulers (CEFT-CPOP, CEFT-HEFT-DOWN).
const TABLE_FWD_MARKER: u64 = u64::MAX - 1;

/// Algorithm-slot marker for **reverse** (transposed-DAG) CEFT-table cache
/// entries, consumed by CEFT-HEFT-UP. A separate slot from
/// [`TABLE_FWD_MARKER`] because the two orientations are distinct DPs over
/// the same instance.
const TABLE_REV_MARKER: u64 = u64::MAX - 2;

/// Cap on one protocol line over TCP, enforced *before* the line is parsed
/// (the JSON-level `MAX_TASKS` guard only runs after a full line is
/// buffered, so without this a newline-free stream would grow the read
/// buffer without bound). 16 MiB comfortably fits instances with hundreds
/// of thousands of tasks while keeping per-connection transient memory
/// bounded.
const MAX_REQUEST_BYTES: u64 = 16 * 1024 * 1024;

/// Cap on concurrently served TCP connections; beyond it new clients get an
/// error line and are disconnected, bounding total transient memory at
/// roughly `MAX_CONNECTIONS × MAX_REQUEST_BYTES` plus parse overhead.
const MAX_CONNECTIONS: usize = 256;

/// Engine tuning knobs.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// LRU bound per result cache (critical paths and schedules each, per
    /// platform-context shard)
    pub cache_capacity: usize,
    /// LRU bound on interned instances; least-recently-used handles expire
    /// (subsequent by-handle requests get "unknown instance id")
    pub intern_capacity: usize,
    /// worker threads for batched entry points
    pub threads: usize,
    /// most critical-path requests one gathered cross-request sweep may
    /// serve (`<= 1` disables gathering; misses then compute one instance
    /// per thread exactly as before)
    pub batch_window: usize,
    /// request-lifecycle telemetry: `None` inherits the process-wide
    /// `CEFT_TELEMETRY` switch ([`crate::obs::enabled`]) at engine
    /// construction; `Some(false)` forces every tracing hook in this
    /// engine to a no-op, `Some(true)` records regardless of the switch
    pub telemetry: Option<bool>,
    /// pin the admission governor's per-shard in-flight table budget to a
    /// fixed value (`Some(n)` disables the feedback loop; `None` lets the
    /// governor adapt it from the recorder's `queue_wait` p99)
    pub admission_budget: Option<usize>,
    /// deterministic fault-injection plan; `None` falls back to the
    /// `CEFT_FAULT` environment variable ([`FaultPlan::from_env`])
    pub fault: Option<FaultPlan>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            cache_capacity: 1024,
            intern_capacity: 1024,
            threads: pool::default_threads(),
            batch_window: 8,
            telemetry: None,
            admission_budget: None,
            fault: None,
        }
    }
}

/// An interned instance: shared, hash-addressed, **versioned**. The
/// platform lives inside the shared [`PlatformCtx`], so every instance on
/// the same platform borrows one set of resident communication panels and
/// one platform-sized workspace pool — and its memo caches live in the
/// platform's [`CacheShard`], carried here so the hit path resolves
/// straight to the right shard without touching the global intern lock.
///
/// The graph and costs live behind the version mutex as an immutable
/// [`Snapshot`]: readers clone the `Arc` once per request and never see a
/// half-applied edit; the `update` op swaps the snapshot under the mutex
/// and bumps `generation`. The hashes stay those of the **original
/// submission** — the handle is stable across edits; the generation inside
/// every [`CacheKey`] is what separates pre- and post-edit results.
struct Interned {
    id: u64,
    ctx: Arc<PlatformCtx>,
    shard: Arc<CacheShard>,
    graph_hash: u64,
    platform_hash: u64,
    comp_hash: u64,
    /// monotonic edit counter, mirrored from the snapshot inside
    /// `versioned` so lock-free readers (the raced-edit early-out in
    /// [`Interned::delta_for`], the resubmit diagnostics) never take the
    /// version mutex; the snapshot's own `generation` is authoritative
    generation: AtomicU64,
    /// the current graph/cost snapshot plus the delta-recompute basis.
    /// Lock order: the engine state lock and this mutex may each be held
    /// when taking the shard lock; never take this mutex under a shard
    /// lock.
    versioned: Mutex<VersionedState>,
}

/// One immutable generation of an interned instance. Requests capture one
/// snapshot up front and do *everything* — key construction, kernel
/// dispatch, response shaping — against it, so a concurrent edit can
/// reorder with a request but never tear it.
struct Snapshot {
    generation: u64,
    graph: Arc<TaskGraph>,
    comp: Arc<CostMatrix>,
    /// the graph's shape verdict ([`shape::recognize`]), computed once at
    /// intern time (O(V+E)) and carried through edits: a cost-only edit
    /// reuses the graph `Arc` and keeps the verdict, a structural edit
    /// re-runs the recognizer on the successor graph — an SP-breaking
    /// edit therefore demotes the handle to the general kernel
    /// transparently, never a stale decomposition
    shape: ShapeVerdict,
}

impl Snapshot {
    /// The ctx-carrying [`InstanceRef`] view of this snapshot — what the
    /// algorithm layer consumes (the CEFT kernels read the context's
    /// resident panels through it).
    fn bind<'a>(&'a self, ctx: &'a PlatformCtx) -> InstanceRef<'a> {
        ctx.bind(self.graph.as_ref(), self.comp.as_ref())
    }
}

/// What the version mutex guards: the current snapshot and the basis the
/// next table miss may delta-recompute from.
struct VersionedState {
    snap: Arc<Snapshot>,
    basis: Option<DeltaBasis>,
}

/// The delta-recompute basis an update leaves behind: the tables it
/// purged from the cache (still valid for the graph they were computed
/// over) plus the dirty flags accumulated since. `dirty` always covers the
/// **current** id space; `basis_n`/`graph` describe the id space and
/// topological order the tables were computed over. Id-shifting edits
/// (task removal) clear the basis — [`crate::graph::edit`] reports
/// `ids_stable = false` and the next compute runs from scratch.
struct DeltaBasis {
    /// graph the basis tables were computed over (its topo order is the
    /// `prev_topo` of every [`DeltaPlan`] built from this basis)
    graph: Arc<TaskGraph>,
    /// basis task count: ids `>= basis_n` were added after the basis
    basis_n: usize,
    /// accumulated per-task dirty flags, current id space
    dirty: Arc<Vec<bool>>,
    /// the memoized forward table of the basis generation, if one existed
    fwd: Option<Arc<MemoTable>>,
    /// the memoized reverse table of the basis generation, if one existed
    rev: Option<Arc<MemoTable>>,
}

impl Interned {
    /// The current snapshot (one mutex acquisition, one `Arc` clone).
    fn current(&self) -> Arc<Snapshot> {
        lock_clean(&self.versioned).snap.clone()
    }

    /// The delta-recompute handoff for a table miss of `snap`'s generation
    /// in the given orientation: the basis table, its graph, and the
    /// accumulated dirty flags — or `None` when no basis exists for that
    /// orientation or an edit raced past `snap` (a from-scratch sweep is
    /// always sound, so races only cost speed, never bits).
    fn delta_for(&self, snap: &Snapshot, rev: bool) -> Option<PendingDelta> {
        if self.generation.load(Ordering::Acquire) != snap.generation {
            return None;
        }
        let vs = lock_clean(&self.versioned);
        if vs.snap.generation != snap.generation {
            return None;
        }
        let b = vs.basis.as_ref()?;
        let memo = if rev { b.rev.as_ref()? } else { b.fwd.as_ref()? };
        Some(PendingDelta {
            basis: memo.clone(),
            basis_graph: b.graph.clone(),
            basis_n: b.basis_n,
            dirty: b.dirty.clone(),
        })
    }
}

/// How a single-flight cell resolved, from a parked follower's view.
enum FlightOutcome<T> {
    /// the leader landed a result
    Ready(Arc<T>),
    /// the computation was abandoned without a verdict for this follower
    /// (queue purge, promoted-cell handoff, a leader that rejected its own
    /// admission) — re-enter admission, where the follower's *own*
    /// deadline and the shard's budget get their say
    Retry,
    /// the leader (or its gather) panicked; the message is the panic
    /// payload — surface a structured `internal_panic` error, do not retry
    /// (the fault is not the follower's to re-trigger)
    Failed(Arc<str>),
}

// manual impl: `derive(Clone)` would demand `T: Clone`, but the payloads
// only ever clone through the `Arc`s
impl<T> Clone for FlightOutcome<T> {
    fn clone(&self) -> Self {
        match self {
            FlightOutcome::Ready(v) => FlightOutcome::Ready(v.clone()),
            FlightOutcome::Retry => FlightOutcome::Retry,
            FlightOutcome::Failed(m) => FlightOutcome::Failed(m.clone()),
        }
    }
}

/// One in-flight computation cell: the leader deposits the outcome and
/// wakes every parked follower. The compute runs *outside* the engine's
/// state mutex, so a panicking leader does not take the engine down —
/// which is exactly why the leader path must still resolve the cell on
/// unwind: it completes with [`FlightOutcome::Failed`] (and removes the
/// in-flight entry) before re-raising, so followers surface a structured
/// error instead of hanging forever. Cell locks use the
/// poison-recovering [`lock_clean`]/[`wait_clean`] helpers: the stored
/// outcome is always a whole value, so a panic between lock and unlock
/// cannot leave a torn cell.
struct Inflight<T> {
    /// `None` = still computing; `Some(outcome)` = resolved
    result: Mutex<Option<FlightOutcome<T>>>,
    ready: Condvar,
}

impl<T> Inflight<T> {
    fn new() -> Self {
        Self {
            result: Mutex::new(None),
            ready: Condvar::new(),
        }
    }

    /// Park until the leader resolves the cell.
    fn wait(&self) -> FlightOutcome<T> {
        let mut guard = lock_clean(&self.result);
        while guard.is_none() {
            guard = wait_clean(&self.ready, guard);
        }
        guard.as_ref().unwrap().clone()
    }

    /// Deposit the outcome and wake all followers.
    fn complete(&self, outcome: FlightOutcome<T>) {
        *lock_clean(&self.result) = Some(outcome);
        self.ready.notify_all();
    }
}

/// Outcome of the single admission pass over the engine state: a cache
/// hit, a follower parked on someone else's computation, or leadership of
/// a fresh one.
enum Flight<T> {
    Hit(Arc<T>),
    Follower(Arc<Inflight<T>>),
    Leader(Arc<Inflight<T>>),
}

/// Why the engine refused to serve a request: its deadline expired, the
/// admission governor shed it, or the computation it depended on
/// panicked. Every variant maps to a structured error response with a
/// `retry_after_ms` hint ([`Engine::reject_response`]) — rejection is a
/// *reply*, never a dropped connection or a hung cell.
enum Reject {
    /// `deadline_ms` elapsed before the result could be produced
    Deadline,
    /// the shard was over its in-flight miss budget (cache hits are
    /// exempt — they are served regardless of load)
    Shed,
    /// the leader computing this key panicked; the payload message rides
    /// along so co-batched requests report *which* fault failed them
    Failed(Arc<str>),
}

/// Dispatch-level error: a client mistake (bad target, malformed edit —
/// worth a plain `error_response`) or an engine [`Reject`].
enum RequestError {
    Client(String),
    Reject(Reject),
}

impl From<String> for RequestError {
    fn from(msg: String) -> Self {
        RequestError::Client(msg)
    }
}

impl From<Reject> for RequestError {
    fn from(rej: Reject) -> Self {
        RequestError::Reject(rej)
    }
}

/// Per-request admission terms, fixed at dispatch: the absolute deadline
/// (from the protocol's relative `deadline_ms`) and whether the shard
/// governor may shed this request. Compute requests are governed; the
/// `update` op's eager recompute is not (the edit is already committed —
/// refusing its recompute would desynchronise the reply from the state),
/// and its deadline is checked once *before* the edit applies.
#[derive(Clone, Copy)]
struct Admission {
    deadline: Option<Instant>,
    governed: bool,
}

impl Admission {
    /// Ungoverned, deadline-free admission (internal recomputes).
    fn free() -> Self {
        Admission {
            deadline: None,
            governed: false,
        }
    }

    /// Governed admission with the request's optional relative deadline,
    /// converted to an absolute instant at dispatch.
    fn governed(deadline_ms: Option<u64>) -> Self {
        Admission {
            deadline: deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms)),
            governed: true,
        }
    }

    fn expired(&self) -> bool {
        self.deadline.map_or(false, |d| Instant::now() >= d)
    }
}

/// How many table-admission probes pass between governor refreshes. The
/// refresh reads a recorder snapshot — O(sinks × buckets) — so it must
/// stay off the per-request path; at 256 the amortised cost is noise
/// while the budget still tracks load shifts within a few hundred
/// requests.
const GOVERNOR_REFRESH_PROBES: u64 = 256;

/// `queue_wait` p99 above which the governor halves the budget. 250 ms of
/// queueing means the gather queue is growing faster than the kernels
/// drain it — deliberately far above anything a healthy engine shows, so
/// ordinary bursts (and the CI loadgen) never shed.
const SHED_HIGH_WATER_NS: u64 = 250_000_000;

/// `queue_wait` p99 below which the governor grows the budget back. The
/// wide (50 ms, 250 ms) dead band is the hysteresis: a budget change
/// needs a regime change, not noise, so the budget cannot flap between
/// consecutive refreshes straddling one threshold.
const SHED_LOW_WATER_NS: u64 = 50_000_000;

/// Pure budget step: halve toward `min` above the high water, grow by a
/// quarter toward `max` below the low water, hold inside the dead band.
fn next_budget(cur: usize, p99_ns: u64, min: usize, max: usize) -> usize {
    if p99_ns > SHED_HIGH_WATER_NS {
        (cur / 2).max(min)
    } else if p99_ns < SHED_LOW_WATER_NS {
        (cur + (cur / 4).max(1)).min(max)
    } else {
        cur
    }
}

/// The admission governor: a per-engine in-flight miss budget steered by
/// the telemetry loop. Each shard admits a new table *leader* only while
/// its `table_inflight` population is under the budget; beyond it, misses
/// are shed with a `retry_after_ms` hint derived from the same p99 that
/// tripped the budget. Followers and cache hits are never shed — they add
/// no kernel work. With telemetry disabled the observed p99 is 0, the
/// budget rides at `max`, and only a pinned budget
/// ([`EngineConfig::admission_budget`]) sheds.
struct Governor {
    budget: AtomicUsize,
    min: usize,
    max: usize,
    /// `true` ⇒ the budget was pinned by config; the feedback loop is off
    pinned: bool,
    probes: AtomicU64,
    /// last observed `queue_wait` p99 (ns) — the `retry_after_ms` source
    last_p99_ns: AtomicU64,
}

impl Governor {
    fn new(threads: usize, batch_window: usize, pinned: Option<usize>) -> Self {
        let min = threads.max(1);
        let max = (threads * batch_window.max(1) * 4).max(min);
        match pinned {
            Some(b) => Governor {
                budget: AtomicUsize::new(b),
                min,
                max,
                pinned: true,
                probes: AtomicU64::new(0),
                last_p99_ns: AtomicU64::new(0),
            },
            None => Governor {
                budget: AtomicUsize::new(max),
                min,
                max,
                pinned: false,
                probes: AtomicU64::new(0),
                last_p99_ns: AtomicU64::new(0),
            },
        }
    }

    fn budget(&self) -> usize {
        self.budget.load(Ordering::Relaxed)
    }

    /// The backoff hint attached to every rejection: the last observed
    /// queueing p99, clamped to [1 ms, 1000 ms] — "come back after about
    /// one queue drain".
    fn retry_after_ms(&self) -> u64 {
        (self.last_p99_ns.load(Ordering::Relaxed) / 1_000_000).clamp(1, 1000)
    }

    /// Count one admission probe; every [`GOVERNOR_REFRESH_PROBES`]-th
    /// re-reads the recorder and steps the budget.
    fn on_probe(&self, recorder: &Recorder) {
        let n = self.probes.fetch_add(1, Ordering::Relaxed);
        if n % GOVERNOR_REFRESH_PROBES != 0 {
            return;
        }
        let p99 = recorder.snapshot().stages[Stage::QueueWait.idx()].p99();
        self.last_p99_ns.store(p99, Ordering::Relaxed);
        if self.pinned {
            return;
        }
        let cur = self.budget.load(Ordering::Relaxed);
        self.budget
            .store(next_budget(cur, p99, self.min, self.max), Ordering::Relaxed);
    }
}

/// Best-effort panic payload extraction (`&str` / `String` payloads; the
/// common cases from `panic!` and `assert!`).
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic of unknown type".to_string()
    }
}

/// The (result cache, in-flight table) pair [`Engine::single_flight`]
/// operates on, projected out of [`ShardState`] by a plain fn pointer.
/// NOTE: since the table memo layer landed, both **result** caches
/// (critical paths and schedules) route through the generic
/// `single_flight` — their compute closures delegate the heavy DP to
/// `Engine::table_for`. The **table** cache runs the same
/// admission/follower/leader-unwind protocol inline in `table_for` (it
/// needs the gather queue between admission and compute). A
/// concurrency-protocol fix in one place must be mirrored in the other —
/// `racing_identical_requests_are_single_flight` and
/// `concurrent_distinct_cp_requests_match_serial_and_count_sanely`
/// cover both sides.
type Slots<'a, T> = (
    &'a mut LruCache<CacheKey, Arc<T>>,
    &'a mut HashMap<CacheKey, Arc<Inflight<T>>>,
);

/// [`Slots`] projection for the schedule cache.
fn sched_slots(st: &mut ShardState) -> Slots<'_, Schedule> {
    (&mut st.sched_cache, &mut st.sched_inflight)
}

/// [`Slots`] projection for the critical-path cache. (The table cache
/// runs its own admission loop in `Engine::table_for` — same protocol,
/// extended with the cross-request gather queue.)
fn cp_slots(st: &mut ShardState) -> Slots<'_, CriticalPath> {
    (&mut st.cp_cache, &mut st.cp_inflight)
}

/// Which kind of request first computed (or is computing) a memoized CEFT
/// table. When a request of the *other* kind later consumes the entry, the
/// table cache records a `cp_schedule_shares` event — the cross-workload
/// reuse the table memo layer exists for (one instance's table serves its
/// critical path *and* its CEFT-family schedules).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TableOrigin {
    Cp,
    Schedule,
}

/// A memoized CEFT table plus the kind of request that computed it (for
/// the `cp_schedule_shares` counter; the bits of `table` are independent
/// of origin) and how much of the DP its producing sweep actually ran —
/// the per-entry source of the `delta_rows_recomputed` / `delta_full_rows`
/// stats and the `update` response's row accounting.
struct MemoTable {
    table: CeftTable,
    origin: TableOrigin,
    /// rows the producing sweep recomputed: `== full_rows` for a
    /// from-scratch sweep, the dirty-suffix length for a delta sweep
    recomputed_rows: usize,
    /// the instance's task count at compute time
    full_rows: usize,
}

/// Park/sweep durations a gather leader stamps into each drained
/// request's [`PendingTable`] so the *requester's* trace can charge its own
/// `queue_wait` / `batch_drain` stages: the leader thread does the timing
/// (the parked thread is inside `Condvar::wait`), the parked thread does
/// the recording after its cell resolves — the cell's mutex provides the
/// happens-before. Durations are floored to 1 ns at the stamp site so a
/// sub-resolution wait still registers as having occurred.
#[derive(Default)]
struct BatchTiming {
    queue_ns: AtomicU64,
    drain_ns: AtomicU64,
}

/// The delta-recompute ingredients a table key leader captures at
/// admission time. Captured as owned `Arc`s — a concurrent edit may
/// replace the instance's basis before the gather drains, but this plan
/// stays self-consistent with the snapshot (and generation-carrying key)
/// it was captured with.
struct PendingDelta {
    basis: Arc<MemoTable>,
    basis_graph: Arc<TaskGraph>,
    basis_n: usize,
    dirty: Arc<Vec<bool>>,
}

impl PendingDelta {
    /// The borrow-shaped [`DeltaPlan`] the kernels consume.
    fn plan(&self) -> DeltaPlan<'_> {
        DeltaPlan {
            prev: &self.basis.table,
            prev_topo: self.basis_graph.topo_order(),
            basis_n: self.basis_n,
            dirty: &self.dirty,
        }
    }
}

/// One CEFT-table request parked in (or drained from) a shard's
/// [`BatchCollector`]: the interned instance to relax, the snapshot its
/// key's generation names, its cache key, the table orientation, who asked
/// (for share accounting), an optional delta plan, and the single-flight
/// cell its result (or retry signal) fans back to.
struct PendingTable {
    inst: Arc<Interned>,
    /// the graph/cost generation this key refers to — compute reads this,
    /// never `inst`'s current state (an edit may land between admission
    /// and drain)
    snap: Arc<Snapshot>,
    /// delta-recompute basis captured at admission; `None` ⇒ from scratch
    delta: Option<PendingDelta>,
    key: CacheKey,
    /// `true` = reverse (transposed-DAG) orientation
    rev: bool,
    /// the kind of request leading this table computation
    origin: TableOrigin,
    cell: Arc<Inflight<MemoTable>>,
    /// when this request entered the collector (the drain leader measures
    /// park time against it)
    queued_at: Instant,
    /// where the drain leader deposits this request's telemetry durations
    timing: Arc<BatchTiming>,
    /// the owning request's absolute deadline; a drain leader purges
    /// expired cells from the queue instead of sweeping dead work
    deadline: Option<Instant>,
}

impl PendingTable {
    fn expired(&self) -> bool {
        self.deadline.map_or(false, |d| Instant::now() >= d)
    }
}

/// The cross-request gather queue of one shard. Group-commit shaped and
/// **saturation-gated**: a table key leader computes immediately while
/// the shard has fewer than `Engine::threads` gathers in flight (below
/// saturation every distinct miss still gets its own core, exactly like
/// pre-batching dispatch — zero added latency, and a width-1 "gather"
/// runs the plain fused kernel); only once the worker budget is saturated
/// do further leaders park here instead of oversubscribing the CPU. Each
/// finishing gather promotes the queue head, which drains up to
/// `batch_window` parked requests into one drain — one
/// [`find_ceft_tables_gathered`] sweep per table direction present in the
/// window — batches form exactly when load exceeds the cores, which is
/// when amortising panel/table traffic pays instead of costing
/// parallelism. Because the queue holds *table* requests, critical-path
/// and CEFT-schedule misses gather together.
#[derive(Default)]
struct BatchCollector {
    /// gathers (width ≥ 1) for this shard currently computing
    active: usize,
    /// key leaders parked while the shard is at its gather budget, FIFO
    pending: VecDeque<PendingTable>,
}

/// Per-platform-context cache shard: the memo caches, single-flight
/// tables and gather queue of one interned platform, behind their own
/// mutex. The platform hash already partitions the key space (it is part
/// of every [`CacheKey`]), so sharding by it is invisible to lookups while
/// removing the global lock from the hit path.
///
/// Lock order: the engine's intern state lock may be held while taking a
/// shard lock (stats, evict); **never** the reverse.
struct CacheShard {
    state: Mutex<ShardState>,
}

struct ShardState {
    cp_cache: LruCache<CacheKey, Arc<CriticalPath>>,
    sched_cache: LruCache<CacheKey, Arc<Schedule>>,
    /// the memoized CEFT tables (forward and reverse entries, marker-keyed)
    /// both result caches' misses derive from
    table_cache: LruCache<CacheKey, Arc<MemoTable>>,
    /// single-flight tables: uncached keys currently being computed; the
    /// entry is inserted by the leader under this same mutex and removed
    /// when its result lands in the cache, so membership here is exact
    cp_inflight: HashMap<CacheKey, Arc<Inflight<CriticalPath>>>,
    sched_inflight: HashMap<CacheKey, Arc<Inflight<Schedule>>>,
    table_inflight: HashMap<CacheKey, Arc<Inflight<MemoTable>>>,
    /// the shard's cross-request table gather queue
    collector: BatchCollector,
}

impl CacheShard {
    fn new(cache_capacity: usize) -> Self {
        Self {
            state: Mutex::new(ShardState {
                cp_cache: LruCache::new(cache_capacity),
                sched_cache: LruCache::new(cache_capacity),
                table_cache: LruCache::new(cache_capacity),
                cp_inflight: HashMap::new(),
                sched_inflight: HashMap::new(),
                table_inflight: HashMap::new(),
                collector: BatchCollector::default(),
            }),
        }
    }

    /// One coherent point-in-time copy of this shard's occupancy and
    /// counters, captured under a **single** acquisition of the shard
    /// lock. This is the stats aggregation's consistency contract made
    /// structural: within a shard, lengths and counters are mutually
    /// consistent (`insertions - evictions - explicit removals == len`
    /// holds exactly); across shards, snapshots are taken sequentially,
    /// so requests completing mid-aggregation may make one shard's
    /// counters "newer" than another's — cross-shard totals are coherent
    /// per shard and monotone overall, not a global atomic cut.
    fn snapshot(&self) -> ShardSnapshot {
        let st = lock_clean(&self.state);
        ShardSnapshot {
            cp_len: st.cp_cache.len(),
            sched_len: st.sched_cache.len(),
            table_len: st.table_cache.len(),
            cp: st.cp_cache.stats(),
            sched: st.sched_cache.stats(),
            table: st.table_cache.stats(),
        }
    }
}

/// See [`CacheShard::snapshot`] for the consistency contract.
struct ShardSnapshot {
    cp_len: usize,
    sched_len: usize,
    table_len: usize,
    cp: CacheStats,
    sched: CacheStats,
    table: CacheStats,
}

/// Request counters — plain atomics so the hit path bumps them without
/// any lock.
#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    errors: AtomicU64,
    submits: AtomicU64,
    cp_requests: AtomicU64,
    schedule_requests: AtomicU64,
    update_requests: AtomicU64,
    /// calls into [`Engine::handle_batch`] (pipelined client batches)
    batches: AtomicU64,
    /// request lines fanned across the pool by those calls; `batch_lines /
    /// batches` is the mean client-side pipelining depth
    batch_lines: AtomicU64,
    /// shape verdicts assigned, indexed by [`ShapeClass::idx`]: one bump
    /// per recognizer run that produced a snapshot — at intern time and on
    /// every structural `update` re-check (cost-only edits keep the
    /// verdict and do not count)
    shape_verdicts: [AtomicU64; NUM_SHAPE_CLASSES],
    /// requests refused by the admission governor (`shed` errors)
    shed_requests: AtomicU64,
    /// requests refused because their `deadline_ms` elapsed
    deadline_expired: AtomicU64,
    /// panics caught at the request boundary (each counted once, in the
    /// thread that unwound — co-batched requests failed by the same panic
    /// report `internal_panic` errors without re-counting it)
    panics_caught: AtomicU64,
    /// expired cells purged from gather queues before a drain
    queue_rejects: AtomicU64,
}

impl Counters {
    fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    fn read(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }
}

struct State {
    /// interned instances, LRU-bounded: stale handles expire instead of
    /// letting a stream of distinct instances grow memory without bound
    instances: LruCache<u64, Arc<Interned>>,
    /// interned platform execution contexts keyed by structural platform
    /// hash — the panel cache. One entry per distinct platform; its LRU
    /// hit/miss stats are the `panel_ctx_hits`/`panel_ctx_misses` counters
    /// loadgen records. Instances hold `Arc`s, so eviction here never
    /// invalidates a live instance — it only means a future submit of that
    /// platform recomputes the panels once.
    ctxs: LruCache<u64, Arc<PlatformCtx>>,
    /// one cache shard per interned platform hash, created with the ctx
    /// and retired when the ctx is evicted (instances keep their shard
    /// alive through an `Arc`, so by-handle traffic on an evicted
    /// platform's instances still serves cached results)
    shards: HashMap<u64, Arc<CacheShard>>,
}

/// The persistent, memoizing scheduling engine.
///
/// Long-lived scratch arenas live in per-platform-context pools
/// ([`PlatformCtx::with_workspace`]): a cache miss borrows one for the
/// CEFT DP / list-scheduler run instead of allocating fresh DP tables,
/// heaps and pin maps per request. Each context's idle pool is capped at
/// the worker-thread count — TCP bursts beyond it (up to
/// `MAX_CONNECTIONS` handler threads) get transient workspaces that are
/// dropped on check-in rather than pinning their high-water-mark capacity
/// for the process lifetime — and because pools are platform-scoped, a
/// large-`P` platform's arenas are never retained for small-`P` requests:
/// retained scratch is bounded by
/// `threads × high-water instance size` **per live platform**, and a
/// context evicted from the panel cache releases its arenas with it.
pub struct Engine {
    state: Mutex<State>,
    counters: Counters,
    /// stage-latency telemetry: per-thread sinks + trace logs
    recorder: Recorder,
    threads: usize,
    /// per-shard LRU bound for the result caches
    cache_capacity: usize,
    /// gather-window bound of the cross-request batcher
    batch_window: usize,
    /// the admission governor (overload shedding); see [`Governor`]
    admission: Governor,
    /// deterministic fault-injection plan; `None` ⇒ every hook is one
    /// `is_some` branch and nothing else
    fault: Option<Arc<FaultPlan>>,
}

impl Engine {
    /// New engine with the given configuration.
    pub fn new(config: EngineConfig) -> Self {
        let cap = config.cache_capacity.max(1);
        let threads = config.threads.max(1);
        let batch_window = config.batch_window.max(1);
        Self {
            state: Mutex::new(State {
                instances: LruCache::new(config.intern_capacity.max(1)),
                ctxs: LruCache::new(config.intern_capacity.max(1)),
                shards: HashMap::new(),
            }),
            counters: Counters::default(),
            recorder: Recorder::new(config.telemetry.unwrap_or_else(obs::enabled)),
            threads,
            cache_capacity: cap,
            batch_window,
            admission: Governor::new(threads, batch_window, config.admission_budget),
            fault: config
                .fault
                .map(Arc::new)
                .or_else(|| FaultPlan::from_env().map(Arc::new)),
        }
    }

    /// The engine's fault-injection plan, if one is armed — loadgen's
    /// chaos mode disarms it through this handle before its post-fault
    /// replay.
    pub fn fault(&self) -> Option<Arc<FaultPlan>> {
        self.fault.clone()
    }

    /// Sleep out any injected request delay (fault plan `delay=` rule).
    /// Placed *before* the deadline checks so a delayed request
    /// deterministically observes its budget already spent.
    fn inject_delay(&self) {
        if let Some(f) = &self.fault {
            if let Some(d) = f.injected_delay() {
                std::thread::sleep(d);
            }
        }
    }

    /// Whether the fault plan wants the next TCP response dropped.
    fn fault_drop_connection(&self) -> bool {
        self.fault
            .as_ref()
            .map_or(false, |f| f.should_drop_connection())
    }

    /// Build the structured error reply for a [`Reject`], bumping the
    /// matching resilience counter. The single funnel for rejection
    /// accounting — `deadline_expired` et al. are bumped here and only
    /// here, so a request rejected at any checkpoint counts exactly once.
    fn reject_response(&self, rej: Reject) -> Json {
        Counters::bump(&self.counters.errors);
        let retry = Json::Num(self.admission.retry_after_ms() as f64);
        match rej {
            Reject::Deadline => {
                Counters::bump(&self.counters.deadline_expired);
                protocol::error_response_with(
                    "deadline_exceeded",
                    vec![("retry_after_ms", retry)],
                )
            }
            Reject::Shed => {
                Counters::bump(&self.counters.shed_requests);
                protocol::error_response_with("shed", vec![("retry_after_ms", retry)])
            }
            Reject::Failed(msg) => protocol::error_response_with(
                "internal_panic",
                vec![
                    ("detail", Json::Str(msg.to_string())),
                    ("retry_after_ms", retry),
                ],
            ),
        }
    }

    /// The engine's telemetry recorder (stage histograms + trace logs);
    /// loadgen and the integration tests read snapshots from it directly
    /// instead of re-parsing the `trace` response.
    pub fn recorder(&self) -> &Recorder {
        &self.recorder
    }

    /// New engine with default configuration.
    pub fn with_defaults() -> Self {
        Self::new(EngineConfig::default())
    }

    /// Worker threads used by the batched entry points.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Intern an instance (idempotent: same content ⇒ same handle),
    /// charging the `intern` stage (hashing + table work) and — when this
    /// submit is the first sighting of its platform — the `ctx_build`
    /// stage (the O(P²) panel construction) separately, so a ctx-build
    /// spike never masquerades as slow hashing.
    fn intern(
        &self,
        instance: Instance,
        platform: Option<Platform>,
        trace: &mut RequestTrace,
    ) -> Result<Arc<Interned>, String> {
        let t0 = trace.clock();
        let ctx_before = trace.stage_ns(Stage::CtxBuild);
        let out = self.intern_inner(instance, platform, trace);
        if let Some(t0) = t0 {
            let total = t0.elapsed().as_nanos() as u64;
            let ctx_ns = trace.stage_ns(Stage::CtxBuild) - ctx_before;
            trace.add(Stage::Intern, total.saturating_sub(ctx_ns));
        }
        out
    }

    fn intern_inner(
        &self,
        instance: Instance,
        platform: Option<Platform>,
        trace: &mut RequestTrace,
    ) -> Result<Arc<Interned>, String> {
        let platform = match platform {
            Some(p) => {
                if p.num_classes() != instance.p() {
                    return Err(format!(
                        "platform has {} classes but instance expects {}",
                        p.num_classes(),
                        instance.p()
                    ));
                }
                p
            }
            None => Platform::uniform(instance.p(), 1.0, 0.0),
        };
        // `Instance::p` is the cost-matrix stride, so stride consistency is
        // structural; only the task count vs the graph still needs a check
        if instance.comp.n() != instance.graph.num_tasks() {
            return Err(format!(
                "comp has {} rows, expected {}",
                instance.comp.n(),
                instance.graph.num_tasks()
            ));
        }
        let graph_hash = hashing::hash_graph(&instance.graph);
        let platform_hash = hashing::hash_platform(&platform);
        let comp_hash = hashing::hash_comp(instance.comp.as_slice());
        let id = hashing::combine(&[graph_hash, platform_hash, comp_hash]);
        // shape recognition runs once per intern, outside the state lock —
        // O(V+E), amortized across every request the handle later serves
        let shape_verdict = shape::recognize(&instance.graph);
        let mut st = lock_clean(&self.state);
        if let Some(existing) = st.instances.get(&id) {
            // Handles are 64-bit non-cryptographic hashes shared by every
            // client, so never trust a handle hit blindly: confirm the
            // content actually matches before reusing cached results. An
            // edited instance's current content has drifted from its
            // submission, so a same-hash resubmit can no longer be served
            // by the live handle — that is a distinct, actionable error,
            // not a collision.
            let snap = existing.current();
            if snap.generation > 0
                && existing.graph_hash == graph_hash
                && existing.platform_hash == platform_hash
                && existing.comp_hash == comp_hash
            {
                return Err(format!(
                    "instance {} has been edited in place (generation {}) and no longer matches this submission — evict the handle to resubmit",
                    protocol::handle_to_hex(id),
                    snap.generation
                ));
            }
            if existing.graph_hash == graph_hash
                && existing.platform_hash == platform_hash
                && existing.comp_hash == comp_hash
                && snap.graph.num_tasks() == instance.graph.num_tasks()
                && snap.graph.edges() == instance.graph.edges()
                && *snap.comp == instance.comp
                && existing.ctx.platform().content_eq(&platform)
            {
                return Ok(existing.clone());
            }
            return Err(format!(
                "instance hash collision on id {} — submit rejected to avoid serving another instance's results",
                protocol::handle_to_hex(id)
            ));
        }
        // Intern the platform execution context: panels (and the
        // platform-sized workspace pool) are built exactly once per
        // distinct platform hash and shared by every instance on it. The
        // ctx cache's own LRU hit/miss stats are the panel counters the
        // stats endpoint (and loadgen) report. The O(P²) context build
        // runs with the state mutex RELEASED — the lock is only ever held
        // for hash-map lookups (the module's concurrency contract); a
        // racing submit of the same platform is resolved by re-checking
        // after relocking, exactly like the single-flight result caches.
        let platform_collision = || {
            format!(
                "platform hash collision on {} — submit rejected to avoid pricing against another platform's links",
                protocol::handle_to_hex(platform_hash)
            )
        };
        let ctx = match st.ctxs.get(&platform_hash).cloned() {
            Some(ctx) => {
                if !ctx.platform().content_eq(&platform) {
                    return Err(platform_collision());
                }
                ctx
            }
            None => {
                drop(st);
                let built = {
                    let _build = trace.span(Stage::CtxBuild);
                    Arc::new(PlatformCtx::bounded_prehashed(
                        Arc::new(platform),
                        self.threads,
                        platform_hash,
                    ))
                };
                st = lock_clean(&self.state);
                // `peek`: a leader losing this race must not inflate the
                // hit counter (misses already counted the first lookup);
                // the raced build is recorded as a dedup hit instead, so
                // `misses - dedup_hits` is always the exact number of
                // panel builds that got interned — the invariant loadgen
                // and EXPERIMENTS.md check
                match st.ctxs.peek(&platform_hash).cloned() {
                    Some(raced) => {
                        if !raced.platform().content_eq(built.platform()) {
                            return Err(platform_collision());
                        }
                        st.ctxs.record_dedup_hit();
                        raced
                    }
                    None => {
                        // a ctx evicted by the intern bound retires its
                        // cache shard with it (instances still alive keep
                        // the shard reachable through their own Arc)
                        if let Some((evicted_hash, _)) =
                            st.ctxs.put(platform_hash, built.clone())
                        {
                            st.shards.remove(&evicted_hash);
                        }
                        built
                    }
                }
            }
        };
        // the platform's cache shard is created with (and keyed like) the
        // ctx; idempotent for the raced-build path
        let shard = st
            .shards
            .entry(platform_hash)
            .or_insert_with(|| Arc::new(CacheShard::new(self.cache_capacity)))
            .clone();
        Counters::bump(&self.counters.shape_verdicts[shape_verdict.class.idx()]);
        let interned = Arc::new(Interned {
            id,
            ctx,
            shard,
            graph_hash,
            platform_hash,
            comp_hash,
            generation: AtomicU64::new(0),
            versioned: Mutex::new(VersionedState {
                snap: Arc::new(Snapshot {
                    generation: 0,
                    graph: Arc::new(instance.graph),
                    comp: Arc::new(instance.comp),
                    shape: shape_verdict,
                }),
                basis: None,
            }),
        });
        // A racing identical submit that slipped in while the lock was
        // released for the ctx build may already have inserted `id`; this
        // put overwrites it with identical content (handles are
        // content-addressed), so either Arc serves the same answers.
        st.instances.put(id, interned.clone());
        Ok(interned)
    }

    /// Resolve a protocol target to an interned instance. A by-handle
    /// lookup charges `cache_probe` (it is an intern-table probe); an
    /// inline body goes through [`Engine::intern`] and charges
    /// `intern` / `ctx_build`.
    fn resolve(
        &self,
        target: Target,
        trace: &mut RequestTrace,
    ) -> Result<Arc<Interned>, String> {
        match target {
            Target::Handle(id) => {
                let _probe = trace.span(Stage::CacheProbe);
                lock_clean(&self.state)
                    .instances
                    .get(&id)
                    .cloned()
                    .ok_or_else(|| {
                        format!("unknown instance id {}", protocol::handle_to_hex(id))
                    })
            }
            Target::Inline { instance, platform } => self.intern(instance, platform, trace),
        }
    }

    /// The single-flight memoization protocol, shared by both result
    /// caches. Admission runs atomically under the instance's **shard**
    /// lock: a cache hit returns immediately; an uncached key with an
    /// in-flight leader parks this request on the leader's cell (a dedup
    /// hit); otherwise this request leads and runs `compute` **outside**
    /// the lock. A leader that unwinds resolves its cell with `None` and
    /// removes the in-flight entry before re-raising, so followers loop
    /// back into admission instead of parking forever. Returns
    /// `(result, was_cached)`; followers report `cached = true` (the
    /// answer came from another request's computation). `compute` receives
    /// the leader's trace and charges its own stages (both result caches
    /// delegate their DP to [`Engine::table_for`], which attributes
    /// kernel/queue/drain time itself; the residual scheduling or
    /// path-derivation work is charged to `kernel` by the closure).
    fn single_flight<T>(
        &self,
        shard: &CacheShard,
        key: CacheKey,
        adm: Admission,
        slots: for<'a> fn(&'a mut ShardState) -> Slots<'a, T>,
        compute: impl Fn(&mut RequestTrace) -> Result<T, Reject>,
        trace: &mut RequestTrace,
    ) -> Result<(Arc<T>, bool), Reject> {
        loop {
            // one admission pass under the lock: cache hit, follower, leader
            let flight = {
                let _probe = trace.span(Stage::CacheProbe);
                let mut st = lock_clean(&shard.state);
                let (cache, inflight) = slots(&mut st);
                if let Some(hit) = cache.get(&key) {
                    Flight::Hit(hit.clone())
                } else if adm.expired() {
                    // a hit is served regardless of deadline (it is
                    // cheaper than the rejection), but expired *misses*
                    // are refused before they spend a core
                    return Err(Reject::Deadline);
                } else if let Some(f) = inflight.get(&key) {
                    Flight::Follower(f.clone())
                } else {
                    let f = Arc::new(Inflight::new());
                    inflight.insert(key, f.clone());
                    Flight::Leader(f)
                }
            };
            match flight {
                Flight::Hit(v) => return Ok((v, true)),
                Flight::Follower(f) => {
                    // park time behind the identical-key leader is dedup
                    // wait — cache_probe, not queue_wait (which is reserved
                    // for the cross-request batcher)
                    let waited = {
                        let _park = trace.span(Stage::CacheProbe);
                        f.wait()
                    };
                    match waited {
                        FlightOutcome::Ready(v) => {
                            let mut st = lock_clean(&shard.state);
                            slots(&mut st).0.record_dedup_hit();
                            return Ok((v, true));
                        }
                        // the leader stepped aside without producing a
                        // result and its in-flight entry is gone —
                        // re-enter admission (this request may become the
                        // new leader; its own deadline gets re-checked)
                        FlightOutcome::Retry => {}
                        FlightOutcome::Failed(msg) => return Err(Reject::Failed(msg)),
                    }
                }
                Flight::Leader(f) => {
                    let computed =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| compute(trace)));
                    match computed {
                        Ok(Ok(v)) => {
                            let v = Arc::new(v);
                            {
                                let mut st = lock_clean(&shard.state);
                                let (cache, inflight) = slots(&mut st);
                                cache.put(key, v.clone());
                                inflight.remove(&key);
                            }
                            f.complete(FlightOutcome::Ready(v.clone()));
                            return Ok((v, false));
                        }
                        // the compute refused (its table admission shed or
                        // timed out): followers retry with their own terms
                        // — this leader's rejection is not theirs
                        Ok(Err(rej)) => {
                            {
                                let mut st = lock_clean(&shard.state);
                                slots(&mut st).1.remove(&key);
                            }
                            f.complete(FlightOutcome::Retry);
                            return Err(rej);
                        }
                        Err(payload) => {
                            {
                                let mut st = lock_clean(&shard.state);
                                slots(&mut st).1.remove(&key);
                            }
                            f.complete(FlightOutcome::Failed(Arc::from(
                                panic_msg(payload.as_ref()).as_str(),
                            )));
                            std::panic::resume_unwind(payload);
                        }
                    }
                }
            }
        }
    }

    /// The critical-path memoization key of one interned instance at one
    /// snapshot's generation. Keys are always built from the same snapshot
    /// the compute will read, so an entry can never describe a different
    /// generation than its key names.
    fn cp_key(inst: &Interned, snap: &Snapshot) -> CacheKey {
        CacheKey {
            graph: inst.graph_hash,
            platform: inst.platform_hash,
            comp: inst.comp_hash,
            algorithm: CP_MARKER,
            generation: snap.generation,
        }
    }

    /// The CEFT-table memoization key of one interned instance at one
    /// snapshot's generation, in the requested orientation.
    fn table_key(inst: &Interned, snap: &Snapshot, rev: bool) -> CacheKey {
        CacheKey {
            graph: inst.graph_hash,
            platform: inst.platform_hash,
            comp: inst.comp_hash,
            algorithm: if rev {
                TABLE_REV_MARKER
            } else {
                TABLE_FWD_MARKER
            },
            generation: snap.generation,
        }
    }

    /// Memoized CEFT critical path. The cp cache keeps its single-flight
    /// protocol (identical-key dedup, `cached` reporting), but a miss no
    /// longer runs the DP itself: the leader borrows the memoized
    /// **table** from [`Engine::table_for`] — joining the shard's gathered
    /// sweeps and sharing the entry with CEFT-based schedulers — and
    /// derives the path by the same sink-selection/backtracking code
    /// serial dispatch runs, so the result is bit-identical.
    fn critical_path_for(
        &self,
        inst: &Arc<Interned>,
        snap: &Arc<Snapshot>,
        adm: Admission,
        trace: &mut RequestTrace,
    ) -> Result<(Arc<CriticalPath>, bool), Reject> {
        let key = Self::cp_key(inst, snap);
        let shard = inst.shard.clone();
        self.single_flight(
            &shard,
            key,
            adm,
            cp_slots,
            |tr| {
                let (memo, _) = self.table_for(inst, snap, false, TableOrigin::Cp, adm, tr)?;
                let t0 = tr.clock();
                let cp = critical_path_from_table(&snap.graph, &memo.table);
                if let Some(t0) = t0 {
                    tr.add(Stage::Kernel, t0.elapsed().as_nanos() as u64);
                }
                Ok(cp)
            },
            trace,
        )
    }

    /// Memoized CEFT table with single-flight dedup and cross-request
    /// batching. Admission (hit / key follower / key leader) is the
    /// single-flight protocol over the shard's table slots; a key leader
    /// then enters the shard's [`BatchCollector`]: it computes immediately
    /// while a gather slot is free (draining any already-queued
    /// same-platform requests into one drain), or — once the shard has
    /// `threads` gathers in flight — parks on its own cell until a running
    /// gather finishes, whose completion either served it (it was drained
    /// into that gather's window) or promoted it to lead the next gather.
    /// A hit (or dedup wake) whose stored origin differs from `origin`
    /// records a `cp_schedule_shares` event: the table computed for one
    /// workload just served the other.
    fn table_for(
        &self,
        inst: &Arc<Interned>,
        snap: &Arc<Snapshot>,
        rev: bool,
        origin: TableOrigin,
        adm: Admission,
        trace: &mut RequestTrace,
    ) -> Result<(Arc<MemoTable>, bool), Reject> {
        let key = Self::table_key(inst, snap, rev);
        let shard = inst.shard.clone();
        if adm.governed {
            // refresh the governor off the per-request path (snapshot
            // reads happen outside any shard lock)
            self.admission.on_probe(&self.recorder);
        }
        loop {
            let flight = {
                let _probe = trace.span(Stage::CacheProbe);
                let mut st = lock_clean(&shard.state);
                if let Some(hit) = st.table_cache.get(&key) {
                    let hit = hit.clone();
                    if hit.origin != origin {
                        st.table_cache.record_share();
                    }
                    Flight::Hit(hit)
                } else if adm.expired() {
                    // hits are served regardless of deadline; an expired
                    // miss is refused before it parks or computes
                    return Err(Reject::Deadline);
                } else if let Some(f) = st.table_inflight.get(&key) {
                    Flight::Follower(f.clone())
                } else if adm.governed && st.table_inflight.len() >= self.admission.budget() {
                    // admission control: a *new* miss past the shard's
                    // in-flight budget is shed (followers add no kernel
                    // work and are always admitted)
                    return Err(Reject::Shed);
                } else {
                    let f = Arc::new(Inflight::new());
                    st.table_inflight.insert(key, f.clone());
                    Flight::Leader(f)
                }
            };
            match flight {
                Flight::Hit(v) => return Ok((v, true)),
                Flight::Follower(f) => {
                    // identical-key dedup wait is cache_probe (see the
                    // single_flight follower arm)
                    let waited = {
                        let _park = trace.span(Stage::CacheProbe);
                        f.wait()
                    };
                    match waited {
                        FlightOutcome::Ready(v) => {
                            let mut st = lock_clean(&shard.state);
                            st.table_cache.record_dedup_hit();
                            if v.origin != origin {
                                st.table_cache.record_share();
                            }
                            return Ok((v, true));
                        }
                        // leader stepped aside; retry admission (deadline
                        // and budget re-checked there)
                        FlightOutcome::Retry => {}
                        FlightOutcome::Failed(msg) => return Err(Reject::Failed(msg)),
                    }
                }
                Flight::Leader(cell) => {
                    // capture the delta basis *now*, against the same
                    // snapshot the key's generation names — a later edit
                    // replaces the instance's basis, but this plan stays
                    // consistent with this key
                    let me = PendingTable {
                        inst: inst.clone(),
                        snap: snap.clone(),
                        delta: inst.delta_for(snap, rev),
                        key,
                        rev,
                        origin,
                        cell: cell.clone(),
                        queued_at: Instant::now(),
                        timing: Arc::new(BatchTiming::default()),
                        deadline: adm.deadline,
                    };
                    let queued_at = me.queued_at;
                    let timing = me.timing.clone();
                    let queued = {
                        let mut st = lock_clean(&shard.state);
                        // queue only past saturation: below `threads`
                        // in-flight gathers a distinct miss still gets its
                        // own core, as before this batcher existed
                        if self.batch_window > 1 && st.collector.active >= self.threads {
                            st.collector.pending.push_back(me);
                            true
                        } else {
                            st.collector.active += 1;
                            false
                        }
                    };
                    if !queued {
                        return self.run_gather(&shard, me, trace);
                    }
                    match cell.wait() {
                        // computed inside the gather that drained us: the
                        // drain leader stamped our park and sweep durations
                        // into the shared timing cell before completing it
                        FlightOutcome::Ready(v) => {
                            if trace.is_enabled() {
                                trace.add(
                                    Stage::QueueWait,
                                    timing.queue_ns.load(Ordering::Relaxed),
                                );
                                trace.add(
                                    Stage::BatchDrain,
                                    timing.drain_ns.load(Ordering::Relaxed),
                                );
                            }
                            return Ok((v, false));
                        }
                        // promoted to lead the next gather (our in-flight
                        // entry was removed with the retry signal), purged
                        // as expired before a drain, or the gather leader
                        // rejected — re-enter admission (which refuses an
                        // expired purge victim with `Deadline`). The
                        // queue_wait stage is reserved for requests actually
                        // served by a sweep, so this park is cache_probe.
                        FlightOutcome::Retry => {
                            if trace.is_enabled() {
                                trace.add(
                                    Stage::CacheProbe,
                                    queued_at.elapsed().as_nanos() as u64,
                                );
                            }
                            continue;
                        }
                        FlightOutcome::Failed(msg) => return Err(Reject::Failed(msg)),
                    }
                }
            }
        }
    }

    /// Run one gather as its leader: drain up to `batch_window - 1` queued
    /// same-shard requests, compute all CEFT tables — one lock-step
    /// [`find_ceft_tables_gathered`] sweep per orientation present in the
    /// window (width 1 degenerates to the plain fused kernel in a pooled
    /// workspace) — deposit every result in the table cache, fan each to
    /// its single-flight cell, and hand the collector to the next queued
    /// leader. Expired queue cells are purged at drain time (their owners
    /// re-admit into a `Deadline` rejection) and a lone expired leader
    /// aborts before the kernel. On unwind every drained cell resolves
    /// with [`FlightOutcome::Failed`] (a structured error for its owner —
    /// never a hang, never a retry into the same fault) and one promoted
    /// successor gets the retry signal before the panic re-raises — the
    /// single-flight leader contract, extended to the whole window.
    fn run_gather(
        &self,
        shard: &Arc<CacheShard>,
        first: PendingTable,
        trace: &mut RequestTrace,
    ) -> Result<(Arc<MemoTable>, bool), Reject> {
        let mut jobs = vec![first];
        let purged = {
            let mut st = lock_clean(&shard.state);
            // drain up to a window of queued requests, purging cells whose
            // deadline already passed — sweeping dead work would only
            // delay the live window behind it. Purged owners wake with
            // the retry signal and re-enter admission, which refuses them
            // with `Deadline`.
            let mut purged = Vec::new();
            while jobs.len() < self.batch_window {
                match st.collector.pending.pop_front() {
                    Some(p) if p.expired() => {
                        st.table_inflight.remove(&p.key);
                        purged.push(p);
                    }
                    Some(p) => jobs.push(p),
                    None => break,
                }
            }
            purged
        };
        for p in purged {
            Counters::bump(&self.counters.queue_rejects);
            p.cell.complete(FlightOutcome::Retry);
        }
        // A lone leader whose own deadline passed while it reached its
        // gather slot aborts before the kernel: hand the slot to the queue
        // head and reject. (With a drained window the sweep runs anyway —
        // the work is shared, only this leader's *reply* is past due.)
        if jobs.len() == 1 && jobs[0].expired() {
            let only = jobs.pop().expect("one job");
            let promoted = {
                let mut st = lock_clean(&shard.state);
                st.table_inflight.remove(&only.key);
                Self::finish_gather(&mut st)
            };
            only.cell.complete(FlightOutcome::Retry);
            if let Some(next) = promoted {
                next.cell.complete(FlightOutcome::Retry);
            }
            return Err(Reject::Deadline);
        }
        let leader_expired = jobs[0].expired();
        // Sweep timing has two consumers: this leader's own trace, and the
        // drained requests' timing cells (their threads are parked inside
        // `Inflight::wait`, so the leader measures on their behalf — a
        // drained requester may be tracing even when this leader is not).
        let t_sweep = if trace.is_enabled() || jobs.len() > 1 {
            let now = Instant::now();
            for job in &jobs[1..] {
                let park = now.duration_since(job.queued_at).as_nanos() as u64;
                job.timing.queue_ns.store(park.max(1), Ordering::Relaxed);
            }
            Some(now)
        } else {
            None
        };
        let computed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // fault site: an injected kernel panic unwinds through the
            // same recovery path a real kernel defect would
            if let Some(f) = &self.fault {
                if f.should_panic_kernel() {
                    panic!("{INJECTED_PANIC} (width {})", jobs.len());
                }
            }
            if jobs.len() == 1 {
                let only = &jobs[0];
                let rev = only.rev;
                vec![only.inst.ctx.with_workspace(|ws| {
                    let iref = only.snap.bind(&only.inst.ctx);
                    match &only.delta {
                        // serial delta: clean-prefix copy plus in-suffix
                        // change propagation — the tightest recompute (the
                        // basis table dictates the kernel, so a delta sweep
                        // stays on the general path even for SP shapes)
                        Some(d) => ceft_table_delta_with(ws, iref, &d.plan(), rev),
                        None => {
                            let n = only.snap.graph.num_tasks();
                            // interned shape verdict routes the kernel:
                            // SP-decomposed graphs take the tree-DP fast
                            // path, bit-identical to the general sweep
                            let t = match (&only.snap.shape.sp, rev) {
                                (Some(sp), false) => ceft_table_sp_with(ws, iref, sp),
                                (Some(sp), true) => ceft_table_sp_rev_with(ws, iref, sp),
                                (None, false) => ceft_table_with(ws, iref),
                                (None, true) => ceft_table_rev_with(ws, iref),
                            };
                            (t, n)
                        }
                    }
                })]
            } else {
                // one lock-step sweep per orientation in the window; fan
                // results back in job order regardless of direction mix.
                // Jobs with a captured basis join the rounds only from
                // their first dirty sweep position (prefix-only delta).
                let ctx = jobs[0].inst.ctx.clone();
                let mut out: Vec<Option<(CeftTable, usize)>> =
                    (0..jobs.len()).map(|_| None).collect();
                for rev in [false, true] {
                    // Gathered windows may mix shapes: SP-decomposed jobs
                    // without a delta basis peel off before the lock-step
                    // rounds and run the tree-DP kernel individually (its
                    // instance-specific sweep order cannot join a
                    // lock-step round); delta-planned jobs stay general —
                    // the basis table dictates the kernel.
                    let (sp_idxs, idxs): (Vec<usize>, Vec<usize>) = (0..jobs.len())
                        .filter(|&i| jobs[i].rev == rev)
                        .partition(|&i| {
                            jobs[i].delta.is_none() && jobs[i].snap.shape.sp.is_some()
                        });
                    for &i in &sp_idxs {
                        let job = &jobs[i];
                        let sp = job.snap.shape.sp.as_ref().expect("partitioned on sp");
                        let t = job.inst.ctx.with_workspace(|ws| {
                            let iref = job.snap.bind(&job.inst.ctx);
                            if rev {
                                ceft_table_sp_rev_with(ws, iref, sp)
                            } else {
                                ceft_table_sp_with(ws, iref, sp)
                            }
                        });
                        out[i] = Some((t, job.snap.graph.num_tasks()));
                    }
                    if idxs.is_empty() {
                        continue;
                    }
                    let insts: Vec<InstanceRef> = idxs
                        .iter()
                        .map(|&i| jobs[i].snap.bind(&jobs[i].inst.ctx))
                        .collect();
                    let plans: Vec<Option<DeltaPlan>> = idxs
                        .iter()
                        .map(|&i| jobs[i].delta.as_ref().map(|d| d.plan()))
                        .collect();
                    let tables = find_ceft_tables_gathered_delta(&ctx, &insts, rev, &plans);
                    for (&i, t) in idxs.iter().zip(tables) {
                        out[i] = Some(t);
                    }
                }
                out.into_iter()
                    .map(|t| t.expect("every drained job got a table"))
                    .collect()
            }
        }));
        let sweep_ns = t_sweep.map(|t| t.elapsed().as_nanos() as u64);
        match computed {
            Ok(tables) => {
                debug_assert_eq!(tables.len(), jobs.len());
                let results: Vec<Arc<MemoTable>> = tables
                    .into_iter()
                    .zip(&jobs)
                    .map(|((table, recomputed), job)| {
                        Arc::new(MemoTable {
                            table,
                            origin: job.origin,
                            recomputed_rows: recomputed,
                            full_rows: job.snap.graph.num_tasks(),
                        })
                    })
                    .collect();
                if let Some(sweep_ns) = sweep_ns {
                    if jobs.len() == 1 {
                        // a width-1 "gather" is the plain fused kernel — an
                        // ungathered miss, charged to `kernel`
                        trace.add(Stage::Kernel, sweep_ns);
                    } else {
                        // the leader was itself served by the gathered
                        // sweep; drained requests read the same duration
                        // from their timing cells once their cells resolve
                        // (stores precede `complete`, which publishes them)
                        trace.add(Stage::BatchDrain, sweep_ns);
                        for job in &jobs[1..] {
                            job.timing
                                .drain_ns
                                .store(sweep_ns.max(1), Ordering::Relaxed);
                        }
                    }
                }
                let promoted = {
                    let mut st = lock_clean(&shard.state);
                    for (job, res) in jobs.iter().zip(&results) {
                        st.table_cache.put(job.key, res.clone());
                        st.table_inflight.remove(&job.key);
                        // only delta-*planned* computes count toward the
                        // rows-saved ratio — a from-scratch sweep is not a
                        // delta that saved nothing, it had no basis
                        if job.delta.is_some() {
                            st.table_cache
                                .record_delta(res.recomputed_rows as u64, res.full_rows as u64);
                        }
                        // kernel-routing attribution: mirrors the compute
                        // branch above (SP tree DP iff the snapshot carries
                        // a decomposition and no delta basis was captured)
                        st.table_cache
                            .record_shape_route(job.delta.is_none() && job.snap.shape.sp.is_some());
                    }
                    st.table_cache.record_batch(jobs.len() as u64);
                    Self::finish_gather(&mut st)
                };
                for (job, res) in jobs.iter().zip(&results) {
                    job.cell.complete(FlightOutcome::Ready(res.clone()));
                }
                if let Some(next) = promoted {
                    next.cell.complete(FlightOutcome::Retry);
                }
                if leader_expired {
                    // the drained window was computed and cached (shared
                    // work), but this leader's own reply is past its
                    // deadline
                    Err(Reject::Deadline)
                } else {
                    Ok((results[0].clone(), false))
                }
            }
            Err(payload) => {
                let msg: Arc<str> = Arc::from(panic_msg(payload.as_ref()).as_str());
                let promoted = {
                    let mut st = lock_clean(&shard.state);
                    for job in &jobs {
                        st.table_inflight.remove(&job.key);
                    }
                    Self::finish_gather(&mut st)
                };
                // every drained request gets a structured failure — never
                // a silent retry that would re-run into the same fault,
                // and never a hang
                for job in &jobs {
                    job.cell.complete(FlightOutcome::Failed(msg.clone()));
                }
                if let Some(next) = promoted {
                    next.cell.complete(FlightOutcome::Retry);
                }
                std::panic::resume_unwind(payload);
            }
        }
    }

    /// End one of the shard's running gathers: release its slot and pop
    /// the queue head for promotion. The promoted request's in-flight
    /// entry is removed here (under the lock) and its cell completed with
    /// the retry signal by the caller (outside the lock); it then
    /// re-enters admission, becomes a key leader again, finds a free
    /// gather slot and leads the next gather — so a backlog always drains
    /// and no parked request is stranded (every completing gather either
    /// drained from the queue front or promotes it).
    fn finish_gather(st: &mut ShardState) -> Option<PendingTable> {
        st.collector.active = st.collector.active.saturating_sub(1);
        let next = st.collector.pending.pop_front();
        if let Some(ref n) = next {
            st.table_inflight.remove(&n.key);
        }
        next
    }

    /// Memoized schedule with single-flight dedup. A CEFT-family
    /// algorithm's miss borrows the memoized table (in the orientation
    /// [`Algorithm::table_use`] declares) from [`Engine::table_for`] —
    /// joining the shard's gathered sweeps and sharing the entry with
    /// critical-path traffic — and runs the table-borrowing scheduler
    /// hook, which is bit-identical to `run_with` by the
    /// [`crate::sched`] `run_with_tables` contract. Mean-value algorithms
    /// compute exactly as before.
    fn schedule_for(
        &self,
        inst: &Arc<Interned>,
        snap: &Arc<Snapshot>,
        algorithm: Algorithm,
        adm: Admission,
        trace: &mut RequestTrace,
    ) -> Result<(Arc<Schedule>, bool), Reject> {
        let key = CacheKey {
            graph: inst.graph_hash,
            platform: inst.platform_hash,
            comp: inst.comp_hash,
            algorithm: algorithm.id(),
            generation: snap.generation,
        };
        self.single_flight(
            &inst.shard,
            key,
            adm,
            sched_slots,
            |tr| match algorithm.table_use() {
                Some(dir) => {
                    let rev = dir == TableDir::Reverse;
                    let (memo, _) =
                        self.table_for(inst, snap, rev, TableOrigin::Schedule, adm, tr)?;
                    let t0 = tr.clock();
                    let s = inst.ctx.with_workspace(|ws| {
                        algorithm.run_with_tables(ws, snap.bind(&inst.ctx), Some(&memo.table))
                    });
                    if let Some(t0) = t0 {
                        tr.add(Stage::Kernel, t0.elapsed().as_nanos() as u64);
                    }
                    Ok(s)
                }
                None => {
                    let t0 = tr.clock();
                    let s = inst
                        .ctx
                        .with_workspace(|ws| algorithm.run_with(ws, snap.bind(&inst.ctx)));
                    if let Some(t0) = t0 {
                        tr.add(Stage::Kernel, t0.elapsed().as_nanos() as u64);
                    }
                    Ok(s)
                }
            },
            trace,
        )
    }

    /// Apply one `update` batch to an interned instance: edit the graph
    /// and costs under the version mutex, bump the generation, purge every
    /// stale memo entry atomically with the snapshot swap, and retain the
    /// purged tables as the next [`DeltaBasis`]. The response carries the
    /// new critical-path length and per-task slack — from an eager
    /// (delta-planned) recompute, or, when the slack bound proves the
    /// length unchanged, from the basis with the recompute skipped
    /// (`skipped: true`, zero rows recomputed; the reported slack is then
    /// the basis slack the bound was checked against).
    fn apply_update(
        &self,
        inst: &Arc<Interned>,
        edits: &[GraphEdit],
        adm: Admission,
        trace: &mut RequestTrace,
    ) -> Result<Json, RequestError> {
        // Deadline checkpoint: *before* the edit applies, never between
        // the edit and the reply — once the generation bumps, the reply
        // must describe the committed state, so the recompute below runs
        // ungoverned and deadline-free.
        if adm.expired() {
            return Err(Reject::Deadline.into());
        }
        // ---- phase 1: edit + swap + purge, under the version mutex ----
        let mut vs = lock_clean(&inst.versioned);
        let old = vs.snap.clone();
        let res = {
            let _edit = trace.span(Stage::EditApply);
            apply_edits(&old.graph, &old.comp, edits).map_err(RequestError::Client)?
        };
        let new_gen = old.generation + 1;
        let new_n = res.graph.num_tasks();
        let new_edges = res.graph.num_edges();
        // the outgoing generation's memo tables become the delta basis
        // (peek: basis harvesting must not perturb LRU order or hit
        // counters)
        let (old_fwd, old_rev, old_cp) = {
            let st = lock_clean(&inst.shard.state);
            (
                st.table_cache
                    .peek(&Self::table_key(inst, &old, false))
                    .cloned(),
                st.table_cache
                    .peek(&Self::table_key(inst, &old, true))
                    .cloned(),
                st.cp_cache.peek(&Self::cp_key(inst, &old)).cloned(),
            )
        };
        // Skip rule (EXPERIMENTS.md §Incremental re-scheduling): for a
        // cost-only, increase-only batch whose *summed* increase is
        // bounded by the slack of every edited task, every path's length
        // stays ≤ CPL — pick any edited task on a path: the path's total
        // rise ≤ Σ increases ≤ that task's slack ≤ that path's slack —
        // and increase-only monotonicity gives ≥, so the critical-path
        // length is provably unchanged and the eager recompute can be
        // skipped. The table bits still changed (the edited rows did), so
        // the purge and dirty accumulation below happen regardless.
        let mut skip: Option<(f64, Vec<f64>)> = None;
        if res.cost_only && res.increase_only {
            if let Some(fwd) = &old_fwd {
                let mut slack = Vec::new();
                let t0 = trace.clock();
                let cpl = inst.ctx.with_workspace(|ws| {
                    slack_from_table_with(ws, old.bind(&inst.ctx), &fwd.table, &mut slack)
                });
                if let Some(t0) = t0 {
                    trace.add(Stage::Kernel, t0.elapsed().as_nanos() as u64);
                }
                let total: f64 = res.max_increase.iter().sum();
                let bounded = res
                    .max_increase
                    .iter()
                    .zip(&slack)
                    .all(|(&inc, &s)| inc <= 0.0 || total <= s);
                if bounded {
                    skip = Some((cpl, slack));
                }
            }
        }
        // the basis the next table miss will delta-recompute from
        let basis = if !res.ids_stable {
            // task removal shifted ids; no plan can express that
            None
        } else if old_fwd.is_some() || old_rev.is_some() {
            Some(DeltaBasis {
                graph: old.graph.clone(),
                basis_n: old.graph.num_tasks(),
                dirty: Arc::new(res.dirty.clone()),
                fwd: old_fwd,
                rev: old_rev,
            })
        } else if let Some(prev) = vs.basis.take() {
            // no table of the outgoing generation was ever computed:
            // carry the older basis forward, accumulating this edit's
            // dirty flags on top (tasks added since the basis stay dirty)
            let merged: Vec<bool> = (0..new_n)
                .map(|i| res.dirty[i] || prev.dirty.get(i).copied().unwrap_or(true))
                .collect();
            Some(DeltaBasis {
                dirty: Arc::new(merged),
                ..prev
            })
        } else {
            None
        };
        // Shape-verdict maintenance: a cost-only batch reuses the graph
        // `Arc`, so the verdict (and its `SpTree`) carries over unchanged;
        // any structural edit re-runs the O(V+E) recognizer on the
        // successor graph. An SP-breaking edit thus demotes the handle to
        // the general kernel transparently — never a panic, never a stale
        // decomposition serving wrong answers.
        let shape_verdict = if res.cost_only {
            old.shape.clone()
        } else {
            let v = shape::recognize(&res.graph);
            Counters::bump(&self.counters.shape_verdicts[v.class.idx()]);
            v
        };
        let new_snap = Arc::new(Snapshot {
            generation: new_gen,
            graph: res.graph,
            comp: res.costs,
            shape: shape_verdict,
        });
        // Purge every memo entry of prior generations and swap the
        // snapshot inside the same version-mutex critical section: a
        // reader keying off the new snapshot can never find a stale
        // entry, and one that captured the old snapshot only ever sees
        // entries of exactly that generation (its request linearizes
        // before this update). Stale `Arc<MemoTable>`s drop here, with
        // the graph they described, except the ones the basis retains.
        {
            let (g, p, c) = (inst.graph_hash, inst.platform_hash, inst.comp_hash);
            let stale = |k: &CacheKey| {
                k.graph == g && k.platform == p && k.comp == c && k.generation < new_gen
            };
            let mut st = lock_clean(&inst.shard.state);
            st.cp_cache.remove_matching(&stale);
            st.sched_cache.remove_matching(&stale);
            st.table_cache.remove_matching(&stale);
            // a skipped update proved the critical path itself unchanged
            // (no zero-slack task was edited, so the realized path and
            // its length carry over verbatim) — reseed it under the new
            // generation's key
            if skip.is_some() {
                if let Some(cp) = old_cp {
                    st.cp_cache.put(Self::cp_key(inst, &new_snap), cp);
                }
            }
        }
        vs.snap = new_snap.clone();
        vs.basis = basis;
        inst.generation.store(new_gen, Ordering::Release);
        drop(vs);
        // ---- phase 2: respond, no locks held ----
        let (length, slack, recomputed, skipped) = match skip {
            Some((cpl, slack)) => (cpl, slack, 0usize, true),
            None => {
                // ungoverned, deadline-free: the edit is committed, the
                // reply must carry the new generation's numbers (the only
                // reject that can surface here is a co-flight panic)
                let (memo, _) =
                    self.table_for(inst, &new_snap, false, TableOrigin::Cp, Admission::free(), trace)?;
                let (cp, _) = self.critical_path_for(inst, &new_snap, Admission::free(), trace)?;
                let mut slack = Vec::new();
                let t0 = trace.clock();
                inst.ctx.with_workspace(|ws| {
                    slack_from_table_with(ws, new_snap.bind(&inst.ctx), &memo.table, &mut slack)
                });
                if let Some(t0) = t0 {
                    trace.add(Stage::Kernel, t0.elapsed().as_nanos() as u64);
                }
                (cp.length, slack, memo.recomputed_rows, false)
            }
        };
        let _respond = trace.span(Stage::Respond);
        Ok(protocol::ok_response(vec![
            ("id", Json::Str(protocol::handle_to_hex(inst.id))),
            ("generation", Json::Num(new_gen as f64)),
            ("n", Json::Num(new_n as f64)),
            ("edges", Json::Num(new_edges as f64)),
            ("length", Json::Num(length)),
            (
                "slack",
                Json::Arr(slack.into_iter().map(Json::Num).collect()),
            ),
            ("delta_rows_recomputed", Json::Num(recomputed as f64)),
            ("full_rows", Json::Num(new_n as f64)),
            ("skipped", Json::Bool(skipped)),
        ]))
    }

    /// Execute one decoded request, producing the response body.
    pub fn handle(&self, req: Request) -> Json {
        let mut trace = self.recorder.begin(protocol::op_code(&req));
        let resp = self.dispatch_caught(req, &mut trace);
        trace.finish();
        resp
    }

    /// Panic isolation boundary: one request's panic (a kernel defect, an
    /// injected fault) becomes *its* structured `internal_panic` error —
    /// the engine, the connection thread and every other request keep
    /// going. Shared state stays sound across the unwind because every
    /// critical section either completes its invariant before unlocking or
    /// holds only whole-value replacements (see [`lock_clean`]), and the
    /// single-flight/gather unwind paths resolve every dependent cell
    /// before the payload re-raises.
    fn dispatch_caught(&self, req: Request, trace: &mut RequestTrace) -> Json {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.dispatch(req, trace)))
        {
            Ok(resp) => resp,
            Err(payload) => {
                Counters::bump(&self.counters.panics_caught);
                Counters::bump(&self.counters.errors);
                protocol::error_response_with(
                    "internal_panic",
                    vec![
                        ("detail", Json::Str(panic_msg(payload.as_ref()))),
                        (
                            "retry_after_ms",
                            Json::Num(self.admission.retry_after_ms() as f64),
                        ),
                    ],
                )
            }
        }
    }

    /// Execute one decoded request, charging lifecycle stages to `trace`.
    fn dispatch(&self, req: Request, trace: &mut RequestTrace) -> Json {
        Counters::bump(&self.counters.requests);
        let result: Result<Json, RequestError> = match req {
            Request::Ping => Ok(protocol::ok_response(vec![
                ("pong", Json::Bool(true)),
                ("version", Json::Num(protocol::PROTOCOL_VERSION as f64)),
            ])),
            Request::Submit { instance, platform } => {
                Counters::bump(&self.counters.submits);
                (|| -> Result<Json, RequestError> {
                    let inst = self.intern(instance, platform, trace)?;
                    let snap = inst.current();
                    let _respond = trace.span(Stage::Respond);
                    Ok(protocol::ok_response(vec![
                        ("id", Json::Str(protocol::handle_to_hex(inst.id))),
                        ("n", Json::Num(snap.graph.num_tasks() as f64)),
                        ("p", Json::Num(inst.ctx.p() as f64)),
                        ("edges", Json::Num(snap.graph.num_edges() as f64)),
                    ]))
                })()
            }
            Request::CriticalPath {
                target,
                slack,
                deadline_ms,
            } => {
                Counters::bump(&self.counters.cp_requests);
                // admission terms are fixed before the injected delay so
                // a delayed request deterministically sees its budget
                // already spent at the first checkpoint
                let adm = Admission::governed(deadline_ms);
                self.inject_delay();
                (|| -> Result<Json, RequestError> {
                    let inst = self.resolve(target, trace)?;
                    let snap = inst.current();
                    let (cp, cached) = self.critical_path_for(&inst, &snap, adm, trace)?;
                    // per-task slack is derived on demand from the
                    // memoized forward table (a hit after the cp compute)
                    // rather than cached: it is O(v·p²) arithmetic, not a
                    // DP, and most cp traffic never asks for it
                    let slack_json = if slack {
                        let (memo, _) =
                            self.table_for(&inst, &snap, false, TableOrigin::Cp, adm, trace)?;
                        let mut out = Vec::new();
                        let t0 = trace.clock();
                        inst.ctx.with_workspace(|ws| {
                            slack_from_table_with(
                                ws,
                                snap.bind(&inst.ctx),
                                &memo.table,
                                &mut out,
                            )
                        });
                        if let Some(t0) = t0 {
                            trace.add(Stage::Kernel, t0.elapsed().as_nanos() as u64);
                        }
                        Some(Json::Arr(out.into_iter().map(Json::Num).collect()))
                    } else {
                        None
                    };
                    let _respond = trace.span(Stage::Respond);
                    let mut fields = vec![
                        ("id", Json::Str(protocol::handle_to_hex(inst.id))),
                        ("length", Json::Num(cp.length)),
                        (
                            "path",
                            Json::Arr(
                                cp.path
                                    .iter()
                                    .map(|s| {
                                        Json::Arr(vec![
                                            Json::Num(s.task as f64),
                                            Json::Num(s.class as f64),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                        ("cached", Json::Bool(cached)),
                    ];
                    if let Some(s) = slack_json {
                        fields.push(("slack", s));
                    }
                    Ok(protocol::ok_response(fields))
                })()
            }
            Request::Update {
                id,
                edits,
                deadline_ms,
            } => {
                Counters::bump(&self.counters.update_requests);
                let adm = Admission::governed(deadline_ms);
                self.inject_delay();
                self.resolve(Target::Handle(id), trace)
                    .map_err(RequestError::Client)
                    .and_then(|inst| self.apply_update(&inst, &edits, adm, trace))
            }
            Request::Schedule {
                algorithm,
                target,
                deadline_ms,
            } => {
                Counters::bump(&self.counters.schedule_requests);
                let adm = Admission::governed(deadline_ms);
                self.inject_delay();
                (|| -> Result<Json, RequestError> {
                    let inst = self.resolve(target, trace)?;
                    let snap = inst.current();
                    let (s, cached) = self.schedule_for(&inst, &snap, algorithm, adm, trace)?;
                    let _respond = trace.span(Stage::Respond);
                    Ok(protocol::ok_response(vec![
                        ("id", Json::Str(protocol::handle_to_hex(inst.id))),
                        ("algorithm", Json::Str(algorithm.name().to_string())),
                        ("makespan", Json::Num(s.makespan())),
                        ("cached", Json::Bool(cached)),
                        ("schedule", io::schedule_to_json(s.as_ref())),
                    ]))
                })()
            }
            Request::Stats => {
                let _respond = trace.span(Stage::Respond);
                Ok(self.stats_json())
            }
            Request::Trace { limit } => {
                let _respond = trace.span(Stage::Respond);
                Ok(self.trace_json(limit))
            }
            Request::Metrics => {
                let _respond = trace.span(Stage::Respond);
                Ok(protocol::ok_response(vec![(
                    "text",
                    Json::Str(self.prometheus_text()),
                )]))
            }
            Request::Evict { id } => {
                let mut st = lock_clean(&self.state);
                match st.instances.remove(&id) {
                    Some(inst) => {
                        let (g, p, c) = (inst.graph_hash, inst.platform_hash, inst.comp_hash);
                        let matches =
                            |k: &CacheKey| k.graph == g && k.platform == p && k.comp == c;
                        // results live in the instance's platform shard
                        // (state-lock-then-shard-lock is the sanctioned
                        // order)
                        let mut shard = lock_clean(&inst.shard.state);
                        let dropped_cp = shard.cp_cache.remove_matching(&matches);
                        let dropped_sched = shard.sched_cache.remove_matching(&matches);
                        // the marker-keyed table entries share the
                        // (graph, platform, comp) prefix, so the same
                        // predicate purges them
                        let dropped_tables = shard.table_cache.remove_matching(&matches);
                        Ok(protocol::ok_response(vec![
                            ("id", Json::Str(protocol::handle_to_hex(id))),
                            ("dropped_cp", Json::Num(dropped_cp as f64)),
                            ("dropped_schedules", Json::Num(dropped_sched as f64)),
                            ("dropped_tables", Json::Num(dropped_tables as f64)),
                        ]))
                    }
                    None => Err(RequestError::Client(format!(
                        "unknown instance id {}",
                        protocol::handle_to_hex(id)
                    ))),
                }
            }
            Request::Clear => {
                let mut st = lock_clean(&self.state);
                let mut dropped = st.instances.len() + st.ctxs.len();
                for shard in st.shards.values() {
                    let s = lock_clean(&shard.state);
                    dropped += s.cp_cache.len() + s.sched_cache.len() + s.table_cache.len();
                }
                st.instances.clear();
                st.ctxs.clear();
                // dropping the shard map retires every shard's results;
                // in-flight computations finish against their own Arcs
                st.shards.clear();
                Ok(protocol::ok_response(vec![(
                    "dropped",
                    Json::Num(dropped as f64),
                )]))
            }
            Request::Shutdown => {
                // graceful drain: give in-flight gathers a bounded window
                // to land before the serving loop stops accepting. The
                // drain is passive — requests arriving while it polls are
                // still served (the poll just waits longer).
                let (drained, in_flight) = self.drain_in_flight(Duration::from_millis(1000));
                Ok(protocol::ok_response(vec![
                    ("shutting_down", Json::Bool(true)),
                    ("drained", Json::Bool(drained)),
                    ("in_flight", Json::Num(in_flight as f64)),
                ]))
            }
        };
        match result {
            Ok(resp) => resp,
            Err(RequestError::Client(msg)) => {
                Counters::bump(&self.counters.errors);
                protocol::error_response(&msg)
            }
            Err(RequestError::Reject(rej)) => self.reject_response(rej),
        }
    }

    /// Poll until every shard's gather collector is idle (no active
    /// gathers, no parked cells) or the budget elapses. Returns
    /// `(fully_drained, in_flight_at_return)`.
    fn drain_in_flight(&self, budget: Duration) -> (bool, usize) {
        let t0 = Instant::now();
        loop {
            let in_flight = {
                let st = lock_clean(&self.state);
                let mut n = 0usize;
                for shard in st.shards.values() {
                    let s = lock_clean(&shard.state);
                    n += s.collector.active + s.collector.pending.len();
                }
                n
            };
            if in_flight == 0 {
                return (true, 0);
            }
            if t0.elapsed() >= budget {
                return (false, in_flight);
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Parse + execute one request line. The second component is true when
    /// the request asked the serving loop to shut down.
    pub fn handle_line(&self, line: &str) -> (Json, bool) {
        let mut trace = self.recorder.begin(protocol::OP_INVALID);
        let parsed = {
            let _parse = trace.span(Stage::Parse);
            protocol::parse_request(line)
        };
        match parsed {
            Ok(req) => {
                trace.set_op(protocol::op_code(&req));
                let stop = matches!(req, Request::Shutdown);
                let resp = self.dispatch_caught(req, &mut trace);
                trace.finish();
                (resp, stop)
            }
            Err(msg) => {
                Counters::bump(&self.counters.requests);
                Counters::bump(&self.counters.errors);
                trace.finish();
                (protocol::error_response(&msg), false)
            }
        }
    }

    /// Execute a batch of request lines across the worker pool, preserving
    /// input order. This is the throughput path: independent requests run
    /// concurrently and share the memo caches. Each call bumps the
    /// `batches` / `batch_lines` counters, so `batch_lines / batches` in
    /// the stats response is the mean client-side pipelining depth the
    /// gather windows see.
    pub fn handle_batch(&self, lines: &[String]) -> Vec<(Json, bool)> {
        Counters::bump(&self.counters.batches);
        self.counters
            .batch_lines
            .fetch_add(lines.len() as u64, Ordering::Relaxed);
        pool::parallel_map(lines, self.threads, |_, line| self.handle_line(line))
    }

    /// Engine counters and cache occupancy as a stats response. The
    /// `panel_cache` section is the platform-context intern table (one
    /// entry per distinct platform; its hits/misses are the
    /// `panel_ctx_hits`/`panel_ctx_misses` counters loadgen records), and
    /// `workspaces` aggregates the per-context pools with a deterministic
    /// per-context breakdown (sorted by platform hash). The `cp_cache` /
    /// `sched_cache` / `table_cache` sections aggregate over the
    /// per-platform shards
    /// (lengths and counters sum; `batch_width` is a high-water max;
    /// `capacity` is the per-shard bound and `shards` the live shard
    /// count), so their totals read exactly as the pre-sharding globals
    /// did. Shard aggregation goes through [`CacheShard::snapshot`] — one
    /// coherent copy per shard under a single lock acquisition; see its
    /// docs for the exact cross-shard consistency contract. The `stages`
    /// section carries the per-stage latency percentiles from the
    /// telemetry recorder (all zero when telemetry is off).
    pub fn stats_json(&self) -> Json {
        // recorder snapshot before the state lock: the two locks nest fine
        // in this order too, but never holding them together is simpler
        let stages = Self::stages_json(&self.recorder.snapshot());
        let telemetry =
            Json::Str(if self.recorder.enabled() { "on" } else { "off" }.to_string());
        let st = lock_clean(&self.state);
        let cache_obj = |len: usize, cap: usize, shards: usize, s: CacheStats| {
            Json::obj(vec![
                ("len", Json::Num(len as f64)),
                ("capacity", Json::Num(cap as f64)),
                ("shards", Json::Num(shards as f64)),
                ("hits", Json::Num(s.hits as f64)),
                ("misses", Json::Num(s.misses as f64)),
                ("insertions", Json::Num(s.insertions as f64)),
                ("evictions", Json::Num(s.evictions as f64)),
                ("dedup_hits", Json::Num(s.dedup_hits as f64)),
                ("batched_requests", Json::Num(s.batched_requests as f64)),
                ("batch_width", Json::Num(s.batch_width as f64)),
                (
                    "cp_schedule_shares",
                    Json::Num(s.cp_schedule_shares as f64),
                ),
                (
                    "delta_rows_recomputed",
                    Json::Num(s.delta_rows_recomputed as f64),
                ),
                ("delta_full_rows", Json::Num(s.delta_full_rows as f64)),
                (
                    "shape_fast_path_hits",
                    Json::Num(s.shape_fast_path_hits as f64),
                ),
                (
                    "shape_general_fallbacks",
                    Json::Num(s.shape_general_fallbacks as f64),
                ),
            ])
        };
        // aggregate the per-platform shards (state lock before shard lock —
        // the sanctioned order; one shard at a time)
        let mut cp_len = 0;
        let mut sched_len = 0;
        let mut table_len = 0;
        let mut cp_stats = CacheStats::default();
        let mut sched_stats = CacheStats::default();
        let mut table_stats = CacheStats::default();
        let shard_count = st.shards.len();
        for shard in st.shards.values() {
            let snap = shard.snapshot();
            cp_len += snap.cp_len;
            sched_len += snap.sched_len;
            table_len += snap.table_len;
            cp_stats.merge(&snap.cp);
            sched_stats.merge(&snap.sched);
            table_stats.merge(&snap.table);
        }
        let mut per_ctx: Vec<(u64, &Arc<PlatformCtx>)> =
            st.ctxs.iter().map(|(h, ctx)| (*h, ctx)).collect();
        per_ctx.sort_by_key(|&(h, _)| h);
        let created: usize = per_ctx.iter().map(|(_, c)| c.pool_created()).sum();
        let idle: usize = per_ctx.iter().map(|(_, c)| c.pool_idle()).sum();
        let per_ctx_json: Vec<Json> = per_ctx
            .iter()
            .map(|&(h, ctx)| {
                Json::obj(vec![
                    ("platform", Json::Str(protocol::handle_to_hex(h))),
                    ("p", Json::Num(ctx.p() as f64)),
                    ("created", Json::Num(ctx.pool_created() as f64)),
                    ("idle", Json::Num(ctx.pool_idle() as f64)),
                ])
            })
            .collect();
        protocol::ok_response(vec![
            (
                "requests",
                Json::Num(Counters::read(&self.counters.requests) as f64),
            ),
            (
                "errors",
                Json::Num(Counters::read(&self.counters.errors) as f64),
            ),
            (
                "submits",
                Json::Num(Counters::read(&self.counters.submits) as f64),
            ),
            (
                "cp_requests",
                Json::Num(Counters::read(&self.counters.cp_requests) as f64),
            ),
            (
                "schedule_requests",
                Json::Num(Counters::read(&self.counters.schedule_requests) as f64),
            ),
            (
                "update_requests",
                Json::Num(Counters::read(&self.counters.update_requests) as f64),
            ),
            (
                "batches",
                Json::Num(Counters::read(&self.counters.batches) as f64),
            ),
            (
                "batch_lines",
                Json::Num(Counters::read(&self.counters.batch_lines) as f64),
            ),
            ("instances", Json::Num(st.instances.len() as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("batch_window", Json::Num(self.batch_window as f64)),
            ("telemetry", telemetry),
            ("stages", stages),
            (
                "workspaces",
                Json::obj(vec![
                    ("created", Json::Num(created as f64)),
                    ("idle", Json::Num(idle as f64)),
                    ("per_ctx", Json::Arr(per_ctx_json)),
                ]),
            ),
            (
                "panel_cache",
                cache_obj(st.ctxs.len(), st.ctxs.capacity(), 1, st.ctxs.stats()),
            ),
            (
                "cp_cache",
                cache_obj(cp_len, self.cache_capacity, shard_count, cp_stats),
            ),
            (
                "sched_cache",
                cache_obj(sched_len, self.cache_capacity, shard_count, sched_stats),
            ),
            (
                "table_cache",
                cache_obj(table_len, self.cache_capacity, shard_count, table_stats),
            ),
            (
                "shapes",
                Json::obj(vec![
                    (
                        "verdicts",
                        Json::obj(
                            ShapeClass::ALL
                                .iter()
                                .map(|&c| {
                                    (
                                        c.name(),
                                        Json::Num(Counters::read(
                                            &self.counters.shape_verdicts[c.idx()],
                                        )
                                            as f64),
                                    )
                                })
                                .collect(),
                        ),
                    ),
                    (
                        "fast_path_hits",
                        Json::Num(table_stats.shape_fast_path_hits as f64),
                    ),
                    (
                        "general_fallbacks",
                        Json::Num(table_stats.shape_general_fallbacks as f64),
                    ),
                ]),
            ),
            (
                "resilience",
                Json::obj(vec![
                    (
                        "shed_requests",
                        Json::Num(Counters::read(&self.counters.shed_requests) as f64),
                    ),
                    (
                        "deadline_expired",
                        Json::Num(Counters::read(&self.counters.deadline_expired) as f64),
                    ),
                    (
                        "panics_caught",
                        Json::Num(Counters::read(&self.counters.panics_caught) as f64),
                    ),
                    (
                        "queue_rejects",
                        Json::Num(Counters::read(&self.counters.queue_rejects) as f64),
                    ),
                    (
                        "admission_budget",
                        Json::Num(self.admission.budget() as f64),
                    ),
                    (
                        "fault_plan_armed",
                        Json::Bool(self.fault.as_ref().map_or(false, |f| f.armed())),
                    ),
                ]),
            ),
        ])
    }

    /// One `{stage: {count, p50_us, p95_us, p99_us, max_us, mean_us}}`
    /// entry per taxonomy stage, in [`Stage::ALL`] order.
    fn stages_json(snap: &obs::TelemetrySnapshot) -> Json {
        Json::obj(
            Stage::ALL
                .iter()
                .map(|s| (s.name(), snap.stages[s.idx()].to_json()))
                .collect(),
        )
    }

    /// The `trace` response: per-stage latency histograms, kernel-path
    /// throughput attribution, and the slowest / most recent completed
    /// request traces (each with its per-stage breakdown). `limit` bounds
    /// the two trace lists; it is clamped to the recorder's retention.
    pub fn trace_json(&self, limit: usize) -> Json {
        let limit = limit.clamp(1, crate::obs::recorder::SNAPSHOT_TRACES);
        let snap = self.recorder.snapshot();
        let kernel = obs::kernel_snapshot();
        let kernel_json: Vec<(&str, Json)> = obs::KernelPath::ALL
            .iter()
            .map(|&p| {
                let k = &kernel[p as usize];
                (
                    p.name(),
                    Json::obj(vec![
                        ("calls", Json::Num(k.calls as f64)),
                        ("cells", Json::Num(k.cells as f64)),
                        ("time_s", Json::Num(k.nanos as f64 / 1e9)),
                        ("cells_per_s", Json::Num(k.cells_per_s())),
                    ]),
                )
            })
            .collect();
        let rec_json = |r: &obs::TraceRecord| {
            Json::obj(vec![
                ("op", Json::Str(protocol::op_name(r.op).to_string())),
                ("total_us", Json::Num(r.total_ns as f64 / 1e3)),
                (
                    "stages_us",
                    Json::obj(
                        Stage::ALL
                            .iter()
                            .copied()
                            .filter(|s| r.stages[s.idx()] > 0)
                            .map(|s| (s.name(), Json::Num(r.stages[s.idx()] as f64 / 1e3)))
                            .collect(),
                    ),
                ),
            ])
        };
        protocol::ok_response(vec![
            (
                "telemetry",
                Json::Str(if self.recorder.enabled() { "on" } else { "off" }.to_string()),
            ),
            ("stages", Self::stages_json(&snap)),
            ("kernel_paths", Json::obj(kernel_json)),
            (
                "slowest",
                Json::Arr(snap.slowest.iter().take(limit).map(rec_json).collect()),
            ),
            (
                "recent",
                Json::Arr(snap.recent.iter().take(limit).map(rec_json).collect()),
            ),
        ])
    }

    /// Prometheus-style text exposition: request/cache counters, stage
    /// latency quantiles, kernel-path throughput. Served in a JSON
    /// envelope by the `metrics` op and raw over HTTP by
    /// `repro serve --metrics-addr`. Quantiles come from the same
    /// log-linear histograms as the `trace` op, so exposition cost is
    /// `O(buckets)` — never a scan of recorded values.
    pub fn prometheus_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, v) in [
            ("ceft_requests_total", Counters::read(&self.counters.requests)),
            ("ceft_errors_total", Counters::read(&self.counters.errors)),
            ("ceft_submits_total", Counters::read(&self.counters.submits)),
            (
                "ceft_cp_requests_total",
                Counters::read(&self.counters.cp_requests),
            ),
            (
                "ceft_schedule_requests_total",
                Counters::read(&self.counters.schedule_requests),
            ),
            (
                "ceft_update_requests_total",
                Counters::read(&self.counters.update_requests),
            ),
            ("ceft_batches_total", Counters::read(&self.counters.batches)),
            (
                "ceft_batch_lines_total",
                Counters::read(&self.counters.batch_lines),
            ),
        ] {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        }
        // cache counters: one coherent snapshot per shard (see
        // `CacheShard::snapshot` for the cross-shard contract)
        let (cp_stats, sched_stats, table_stats, panel_stats) = {
            let st = lock_clean(&self.state);
            let mut cp = CacheStats::default();
            let mut sched = CacheStats::default();
            let mut table = CacheStats::default();
            for shard in st.shards.values() {
                let snap = shard.snapshot();
                cp.merge(&snap.cp);
                sched.merge(&snap.sched);
                table.merge(&snap.table);
            }
            (cp, sched, table, st.ctxs.stats())
        };
        for family in [
            "ceft_cache_hits_total",
            "ceft_cache_misses_total",
            "ceft_cache_dedup_hits_total",
        ] {
            let _ = writeln!(out, "# TYPE {family} counter");
        }
        for (cache, s) in [
            ("cp", &cp_stats),
            ("sched", &sched_stats),
            ("table", &table_stats),
            ("panel", &panel_stats),
        ] {
            let _ = writeln!(out, "ceft_cache_hits_total{{cache=\"{cache}\"}} {}", s.hits);
            let _ = writeln!(
                out,
                "ceft_cache_misses_total{{cache=\"{cache}\"}} {}",
                s.misses
            );
            let _ = writeln!(
                out,
                "ceft_cache_dedup_hits_total{{cache=\"{cache}\"}} {}",
                s.dedup_hits
            );
        }
        // the gather queue batches *table* computations, so batch counters
        // live on the table cache
        let _ = writeln!(out, "# TYPE ceft_batched_requests_total counter");
        let _ = writeln!(
            out,
            "ceft_batched_requests_total {}",
            table_stats.batched_requests
        );
        let _ = writeln!(out, "# TYPE ceft_table_cp_schedule_shares_total counter");
        let _ = writeln!(
            out,
            "ceft_table_cp_schedule_shares_total {}",
            table_stats.cp_schedule_shares
        );
        // delta-recompute economy: rows actually swept by delta-planned
        // computes vs the rows a from-scratch sweep would have cost
        let _ = writeln!(out, "# TYPE ceft_table_delta_rows_recomputed_total counter");
        let _ = writeln!(
            out,
            "ceft_table_delta_rows_recomputed_total {}",
            table_stats.delta_rows_recomputed
        );
        let _ = writeln!(out, "# TYPE ceft_table_delta_full_rows_total counter");
        let _ = writeln!(
            out,
            "ceft_table_delta_full_rows_total {}",
            table_stats.delta_full_rows
        );
        // structured-shape routing: interned verdict counts and how table
        // computations split between the SP tree DP and the general sweep
        let _ = writeln!(out, "# TYPE ceft_shape_verdicts_total counter");
        for c in ShapeClass::ALL {
            let _ = writeln!(
                out,
                "ceft_shape_verdicts_total{{class=\"{}\"}} {}",
                c.name(),
                Counters::read(&self.counters.shape_verdicts[c.idx()])
            );
        }
        let _ = writeln!(out, "# TYPE ceft_shape_fast_path_hits_total counter");
        let _ = writeln!(
            out,
            "ceft_shape_fast_path_hits_total {}",
            table_stats.shape_fast_path_hits
        );
        let _ = writeln!(out, "# TYPE ceft_shape_general_fallbacks_total counter");
        let _ = writeln!(
            out,
            "ceft_shape_general_fallbacks_total {}",
            table_stats.shape_general_fallbacks
        );
        // overload / fault-recovery accounting (the `resilience` stats
        // section, exported)
        for (name, v) in [
            (
                "ceft_resilience_shed_requests_total",
                Counters::read(&self.counters.shed_requests),
            ),
            (
                "ceft_resilience_deadline_expired_total",
                Counters::read(&self.counters.deadline_expired),
            ),
            (
                "ceft_resilience_panics_caught_total",
                Counters::read(&self.counters.panics_caught),
            ),
            (
                "ceft_resilience_queue_rejects_total",
                Counters::read(&self.counters.queue_rejects),
            ),
        ] {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        }
        let _ = writeln!(out, "# TYPE ceft_resilience_admission_budget gauge");
        let _ = writeln!(
            out,
            "ceft_resilience_admission_budget {}",
            self.admission.budget()
        );
        // per-stage latency summaries
        let snap = self.recorder.snapshot();
        let _ = writeln!(out, "# TYPE ceft_stage_latency_seconds summary");
        for s in Stage::ALL {
            let h = &snap.stages[s.idx()];
            for (q, v) in [("0.5", h.p50()), ("0.95", h.p95()), ("0.99", h.p99())] {
                let _ = writeln!(
                    out,
                    "ceft_stage_latency_seconds{{stage=\"{}\",quantile=\"{q}\"}} {}",
                    s.name(),
                    v as f64 / 1e9
                );
            }
            let _ = writeln!(
                out,
                "ceft_stage_latency_seconds_count{{stage=\"{}\"}} {}",
                s.name(),
                h.count
            );
            let _ = writeln!(
                out,
                "ceft_stage_latency_seconds_sum{{stage=\"{}\"}} {}",
                s.name(),
                h.sum as f64 / 1e9
            );
        }
        // kernel-path throughput
        let kernel = obs::kernel_snapshot();
        for family in [
            "ceft_kernel_calls_total",
            "ceft_kernel_cells_total",
            "ceft_kernel_seconds_total",
        ] {
            let _ = writeln!(out, "# TYPE {family} counter");
        }
        for p in obs::KernelPath::ALL {
            let k = &kernel[p as usize];
            let _ = writeln!(
                out,
                "ceft_kernel_calls_total{{path=\"{}\"}} {}",
                p.name(),
                k.calls
            );
            let _ = writeln!(
                out,
                "ceft_kernel_cells_total{{path=\"{}\"}} {}",
                p.name(),
                k.cells
            );
            let _ = writeln!(
                out,
                "ceft_kernel_seconds_total{{path=\"{}\"}} {}",
                p.name(),
                k.nanos as f64 / 1e9
            );
        }
        out
    }
}

/// Serve the protocol on stdin/stdout until EOF or a `shutdown` request.
///
/// A reader thread feeds lines through a channel; the serving loop drains
/// everything already queued (up to `4 × threads` lines) into one batch and
/// fans it across the worker pool, so a client that pipelines requests gets
/// multi-core throughput while an interactive client still sees one
/// response per line.
pub fn serve_stdio(engine: &Engine) -> std::io::Result<()> {
    // Bounded: when the producer outruns the engine, send() blocks the
    // reader thread, which propagates backpressure to the stdin pipe
    // instead of buffering the backlog in memory.
    let (tx, rx) = std::sync::mpsc::sync_channel::<String>(1024);
    std::thread::spawn(move || {
        let stdin = std::io::stdin();
        for line in stdin.lock().lines() {
            match line {
                Ok(l) => {
                    if tx.send(l).is_err() {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
    });
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let batch_cap = engine.threads().saturating_mul(4).max(1);
    'serve: loop {
        let first = match rx.recv() {
            Ok(l) => l,
            Err(_) => break, // EOF
        };
        let mut lines = vec![first];
        while lines.len() < batch_cap {
            match rx.try_recv() {
                Ok(l) => lines.push(l),
                Err(_) => break,
            }
        }
        lines.retain(|l| !l.trim().is_empty());
        if lines.is_empty() {
            continue;
        }
        // Write *every* response in the batch — the protocol promises one
        // response per request line, in order, even when a shutdown request
        // was pipelined in the middle of the batch.
        let mut stop = false;
        for (resp, shutdown) in engine.handle_batch(&lines) {
            writeln!(out, "{}", resp.to_string())?;
            stop |= shutdown;
        }
        out.flush()?;
        if stop {
            break 'serve;
        }
    }
    Ok(())
}

/// A TCP front end over a shared engine: one handler thread per connection,
/// newline-delimited protocol frames, graceful shutdown via the `shutdown`
/// op from any client.
pub struct Server {
    engine: Arc<Engine>,
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Bind to `addr` (e.g. `"127.0.0.1:7077"`, port 0 for ephemeral).
    pub fn bind(engine: Arc<Engine>, addr: &str) -> std::io::Result<Self> {
        Ok(Self {
            engine,
            listener: TcpListener::bind(addr)?,
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (useful with an ephemeral port).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept loop. Returns after a client sends `shutdown`.
    pub fn run(self) -> std::io::Result<()> {
        let addr = self.listener.local_addr()?;
        let live = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        loop {
            // Transient accept failures (ECONNABORTED from a client that
            // reset while queued, EMFILE under fd pressure) must not kill a
            // server meant to run forever — log, breathe, continue.
            let stream = match self.listener.accept() {
                Ok((stream, _)) => stream,
                Err(e) => {
                    if self.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    eprintln!("accept failed (continuing): {e}");
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    continue;
                }
            };
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            if live.load(Ordering::SeqCst) >= MAX_CONNECTIONS {
                let mut s = stream;
                let _ = writeln!(
                    s,
                    "{}",
                    protocol::error_response("server at connection capacity").to_string()
                );
                continue;
            }
            live.fetch_add(1, Ordering::SeqCst);
            let engine = self.engine.clone();
            let shutdown = self.shutdown.clone();
            let live = live.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(&engine, stream, &shutdown, addr);
                live.fetch_sub(1, Ordering::SeqCst);
            });
        }
        Ok(())
    }
}

fn handle_connection(
    engine: &Engine,
    stream: TcpStream,
    shutdown: &AtomicBool,
    server_addr: SocketAddr,
) -> std::io::Result<()> {
    let reader_half = stream.try_clone()?;
    // Cap the bytes one request line may occupy *before* parsing, so a
    // newline-free stream cannot grow the buffer without bound.
    let mut reader = BufReader::new(reader_half).take(MAX_REQUEST_BYTES);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break; // client closed (or the cap was consumed exactly at EOF)
        }
        if line.len() as u64 >= MAX_REQUEST_BYTES && !line.ends_with('\n') {
            // oversized line: report and drop the connection (we cannot
            // resynchronise mid-line)
            let resp = protocol::error_response(&format!(
                "request line exceeds {MAX_REQUEST_BYTES} bytes"
            ));
            writeln!(writer, "{}", resp.to_string())?;
            writer.flush()?;
            break;
        }
        reader.set_limit(MAX_REQUEST_BYTES);
        if line.trim().is_empty() {
            continue;
        }
        let (resp, is_shutdown) = engine.handle_line(&line);
        // fault site: a planned connection drop closes without responding
        // — the client-side retry path's test substrate
        if engine.fault_drop_connection() {
            return Ok(());
        }
        writeln!(writer, "{}", resp.to_string())?;
        writer.flush()?;
        if is_shutdown {
            shutdown.store(true, Ordering::SeqCst);
            // Wake the acceptor so the accept loop observes the flag. The
            // listener may be bound to a wildcard address, which is not
            // connectable on every platform — wake via loopback instead.
            let mut wake = server_addr;
            if wake.ip().is_unspecified() {
                wake.set_ip(match wake.ip() {
                    std::net::IpAddr::V4(_) => {
                        std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                    }
                    std::net::IpAddr::V6(_) => {
                        std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                    }
                });
            }
            let _ = TcpStream::connect(wake);
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::ceft::find_critical_path;
    use crate::cp::workspace::Workspace;
    use crate::graph::generator::{generate, RggParams};
    use crate::platform::CostModel;

    fn small_instance(seed: u64) -> (Platform, Instance) {
        let plat = Platform::uniform(3, 1.0, 0.0);
        let inst = generate(
            &RggParams {
                n: 40,
                out_degree: 3,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.25,
            },
            &CostModel::Classic { beta: 0.5 },
            &plat,
            seed,
        );
        (plat, inst)
    }

    fn schedule_line(inst: &Instance, algo: &str) -> String {
        format!(
            r#"{{"op":"schedule","algorithm":"{algo}","instance":{}}}"#,
            io::instance_to_json(inst).to_string()
        )
    }

    #[test]
    fn submit_is_idempotent_and_content_addressed() {
        let engine = Engine::with_defaults();
        let (_plat, inst) = small_instance(1);
        let line = format!(
            r#"{{"op":"submit","instance":{}}}"#,
            io::instance_to_json(&inst).to_string()
        );
        let (a, _) = engine.handle_line(&line);
        let (b, _) = engine.handle_line(&line);
        assert_eq!(a.get("id"), b.get("id"));
        assert_eq!(a.get("ok"), Some(&Json::Bool(true)));
        // a different instance gets a different handle
        let (_plat2, inst2) = small_instance(2);
        let line2 = format!(
            r#"{{"op":"submit","instance":{}}}"#,
            io::instance_to_json(&inst2).to_string()
        );
        let (c, _) = engine.handle_line(&line2);
        assert_ne!(a.get("id"), c.get("id"));
        // only one interned copy of the duplicate
        let stats = engine.stats_json();
        assert_eq!(stats.get("instances").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn repeat_request_hits_cache_with_identical_bits() {
        let engine = Engine::with_defaults();
        let (_plat, inst) = small_instance(3);
        let line = schedule_line(&inst, "CEFT-CPOP");
        let (a, _) = engine.handle_line(&line);
        let (b, _) = engine.handle_line(&line);
        assert_eq!(a.get("cached"), Some(&Json::Bool(false)));
        assert_eq!(b.get("cached"), Some(&Json::Bool(true)));
        assert_eq!(a.get("makespan"), b.get("makespan"));
        assert_eq!(a.get("schedule"), b.get("schedule"));
        let stats = engine.stats_json();
        let sched = stats.get("sched_cache").unwrap();
        assert_eq!(sched.get("hits").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn online_matches_batch_bit_for_bit() {
        let engine = Engine::with_defaults();
        let (plat, inst) = small_instance(4);
        for algorithm in Algorithm::ALL {
            let line = schedule_line(&inst, algorithm.name());
            let (resp, _) = engine.handle_line(&line);
            let batch = algorithm.schedule(inst.bind(&plat));
            assert_eq!(
                resp.get("makespan").and_then(Json::as_f64),
                Some(batch.makespan()),
                "{} diverged from batch",
                algorithm.name()
            );
        }
        let cp_line = format!(
            r#"{{"op":"cp","instance":{}}}"#,
            io::instance_to_json(&inst).to_string()
        );
        let (resp, _) = engine.handle_line(&cp_line);
        let batch_cp = find_critical_path(inst.bind(&plat));
        assert_eq!(
            resp.get("length").and_then(Json::as_f64),
            Some(batch_cp.length)
        );
        assert_eq!(
            resp.get("path").and_then(Json::as_arr).unwrap().len(),
            batch_cp.path.len()
        );
    }

    #[test]
    fn evict_forgets_instance_and_results() {
        let engine = Engine::with_defaults();
        let (_plat, inst) = small_instance(5);
        let line = schedule_line(&inst, "HEFT");
        let (first, _) = engine.handle_line(&line);
        let id = first.get("id").and_then(Json::as_str).unwrap().to_string();
        // by-handle request is served from cache
        let (by_handle, _) = engine
            .handle_line(&format!(r#"{{"op":"schedule","algorithm":"HEFT","id":"{id}"}}"#));
        assert_eq!(by_handle.get("cached"), Some(&Json::Bool(true)));
        // evict, then the handle is unknown
        let (evicted, _) = engine.handle_line(&format!(r#"{{"op":"evict","id":"{id}"}}"#));
        assert_eq!(evicted.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(
            evicted.get("dropped_schedules").and_then(Json::as_f64),
            Some(1.0)
        );
        let (gone, _) =
            engine.handle_line(&format!(r#"{{"op":"schedule","algorithm":"HEFT","id":"{id}"}}"#));
        assert_eq!(gone.get("ok"), Some(&Json::Bool(false)));
        // resubmitting recomputes (cache was purged)
        let (again, _) = engine.handle_line(&line);
        assert_eq!(again.get("cached"), Some(&Json::Bool(false)));
        assert_eq!(again.get("makespan"), first.get("makespan"));
    }

    #[test]
    fn lru_bound_evicts_under_churn() {
        let engine = Engine::new(EngineConfig {
            cache_capacity: 2,
            threads: 1,
            ..EngineConfig::default()
        });
        for seed in 0..5 {
            let (_plat, inst) = small_instance(100 + seed);
            engine.handle_line(&schedule_line(&inst, "HEFT"));
        }
        let stats = engine.stats_json();
        let sched = stats.get("sched_cache").unwrap();
        assert_eq!(sched.get("len").and_then(Json::as_f64), Some(2.0));
        assert_eq!(sched.get("evictions").and_then(Json::as_f64), Some(3.0));
    }

    #[test]
    fn batch_results_preserve_order_and_shutdown_flag() {
        let engine = Engine::with_defaults();
        let (_plat, inst) = small_instance(6);
        let lines = vec![
            r#"{"op":"ping"}"#.to_string(),
            schedule_line(&inst, "CEFT-CPOP"),
            "garbage".to_string(),
            r#"{"op":"shutdown"}"#.to_string(),
        ];
        let out = engine.handle_batch(&lines);
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].0.get("pong"), Some(&Json::Bool(true)));
        assert!(out[1].0.get("makespan").is_some());
        assert_eq!(out[2].0.get("ok"), Some(&Json::Bool(false)));
        assert!(out[3].1, "shutdown flag must be set on the last response");
        assert!(!out[0].1 && !out[1].1 && !out[2].1);
    }

    #[test]
    fn racing_identical_requests_are_single_flight() {
        // Eight threads fire the same uncached schedule request at once.
        // The admission pass is atomic under the state lock, so exactly one
        // thread can lead the computation: the cache records exactly one
        // insertion, and the other seven are either plain cache hits
        // (arrived after the leader finished) or dedup hits (parked on the
        // in-flight cell) — in every interleaving hits + dedup_hits == 7.
        let engine = Arc::new(Engine::with_defaults());
        let (_plat, inst) = small_instance(42);
        let line = Arc::new(schedule_line(&inst, "CEFT-CPOP"));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let engine = engine.clone();
            let line = line.clone();
            handles.push(std::thread::spawn(move || {
                let (resp, _) = engine.handle_line(&line);
                resp.get("makespan").and_then(Json::as_f64).unwrap()
            }));
        }
        let makespans: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(
            makespans.windows(2).all(|w| w[0] == w[1]),
            "all clients must see identical bits"
        );
        let stats = engine.stats_json();
        let sched = stats.get("sched_cache").unwrap();
        let get = |k: &str| sched.get(k).and_then(Json::as_f64).unwrap();
        assert_eq!(get("insertions"), 1.0, "only the leader may compute");
        assert_eq!(
            get("hits") + get("dedup_hits"),
            7.0,
            "every non-leader is a cache hit or a dedup hit (hits {}, dedup {})",
            get("hits"),
            get("dedup_hits")
        );
    }

    #[test]
    fn mixed_cp_and_schedule_requests_compute_one_table() {
        // The headline guarantee of the table memo layer: a mixed
        // cp+schedule workload over one instance performs exactly one
        // CEFT table computation. batch_window 1 keeps every step serial
        // and deterministic.
        let engine = Engine::new(EngineConfig {
            threads: 1,
            batch_window: 1,
            ..EngineConfig::default()
        });
        let (plat, inst) = small_instance(2100);
        let serial_cp = find_critical_path(inst.bind(&plat));
        let serial_cpop = Algorithm::CeftCpop.schedule(inst.bind(&plat)).makespan();
        let serial_down = Algorithm::CeftHeftDown.schedule(inst.bind(&plat)).makespan();
        let (a, _) = engine.handle_line(&schedule_line(&inst, "CEFT-CPOP"));
        assert_eq!(a.get("makespan").and_then(Json::as_f64), Some(serial_cpop));
        let cp_line = format!(
            r#"{{"op":"cp","instance":{}}}"#,
            io::instance_to_json(&inst).to_string()
        );
        let (b, _) = engine.handle_line(&cp_line);
        assert_eq!(
            b.get("length").and_then(Json::as_f64),
            Some(serial_cp.length)
        );
        let (c, _) = engine.handle_line(&schedule_line(&inst, "CEFT-HEFT-DOWN"));
        assert_eq!(c.get("makespan").and_then(Json::as_f64), Some(serial_down));
        let stats = engine.stats_json();
        let table = stats.get("table_cache").unwrap();
        let get = |k: &str| table.get(k).and_then(Json::as_f64).unwrap();
        assert_eq!(get("insertions"), 1.0, "exactly one table computation");
        assert_eq!(get("misses"), 1.0, "only the first request misses");
        assert_eq!(get("hits"), 2.0, "cp + second scheduler reuse the entry");
        assert_eq!(
            get("cp_schedule_shares"),
            1.0,
            "cp consumed the schedule-origin table; CEFT-HEFT-DOWN is same-kind"
        );
    }

    #[test]
    fn racing_mixed_requests_share_one_table() {
        // Eight threads race cp and forward-table schedule requests for
        // one uncached instance. Whatever the interleaving, the forward
        // table must be computed exactly once and every response must
        // equal serial dispatch.
        let engine = Arc::new(Engine::with_defaults());
        let (plat, inst) = small_instance(2200);
        let serial_cp = find_critical_path(inst.bind(&plat));
        let serial_cpop = Algorithm::CeftCpop.schedule(inst.bind(&plat)).makespan();
        let serial_down = Algorithm::CeftHeftDown.schedule(inst.bind(&plat)).makespan();
        let cp_line = Arc::new(format!(
            r#"{{"op":"cp","instance":{}}}"#,
            io::instance_to_json(&inst).to_string()
        ));
        let cpop_line = Arc::new(schedule_line(&inst, "CEFT-CPOP"));
        let down_line = Arc::new(schedule_line(&inst, "CEFT-HEFT-DOWN"));
        let barrier = Arc::new(std::sync::Barrier::new(8));
        let mut handles = Vec::new();
        for i in 0..8usize {
            let engine = engine.clone();
            let barrier = barrier.clone();
            let (line, is_cp) = match i % 3 {
                0 => (cp_line.clone(), true),
                1 => (cpop_line.clone(), false),
                _ => (down_line.clone(), false),
            };
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                let (resp, _) = engine.handle_line(&line);
                if is_cp {
                    resp.get("length").and_then(Json::as_f64).unwrap()
                } else {
                    resp.get("makespan").and_then(Json::as_f64).unwrap()
                }
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let want = match i % 3 {
                0 => serial_cp.length,
                1 => serial_cpop,
                _ => serial_down,
            };
            assert_eq!(h.join().unwrap(), want, "request {i}");
        }
        let stats = engine.stats_json();
        let table = stats.get("table_cache").unwrap();
        assert_eq!(
            table.get("insertions").and_then(Json::as_f64),
            Some(1.0),
            "one forward table serves cp and both schedulers in every interleaving"
        );
        assert!(
            table
                .get("cp_schedule_shares")
                .and_then(Json::as_f64)
                .unwrap()
                >= 1.0,
            "at least one cross-workload reuse must be recorded"
        );
    }

    #[test]
    fn platform_ctx_interned_once_per_distinct_platform() {
        let engine = Engine::with_defaults();
        // three distinct instances with no explicit platform all share the
        // default uniform platform -> one ctx, panels built exactly once
        for seed in 0..3 {
            let (_plat, inst) = small_instance(200 + seed);
            let (resp, _) = engine.handle_line(&schedule_line(&inst, "CEFT-CPOP"));
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        }
        let stats = engine.stats_json();
        let panel = stats.get("panel_cache").unwrap();
        let get = |k: &str| panel.get(k).and_then(Json::as_f64).unwrap();
        assert_eq!(get("len"), 1.0, "one ctx for the shared platform");
        assert_eq!(get("misses"), 1.0, "panels computed once, not per submit");
        assert_eq!(get("hits"), 2.0, "later submits reuse the interned ctx");
        // an explicitly different platform interns a second ctx
        let (_plat, inst) = small_instance(300);
        let plat2 = Platform::uniform(3, 2.0, 0.0);
        let line = format!(
            r#"{{"op":"schedule","algorithm":"HEFT","instance":{},"platform":{}}}"#,
            io::instance_to_json(&inst).to_string(),
            io::platform_to_json(&plat2).to_string()
        );
        let (resp, _) = engine.handle_line(&line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let stats = engine.stats_json();
        let panel = stats.get("panel_cache").unwrap();
        assert_eq!(panel.get("len").and_then(Json::as_f64), Some(2.0));
        assert_eq!(panel.get("misses").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn workspace_pools_are_platform_scoped() {
        // instances on two different-P platforms draw arenas from two
        // separate pools, reported per context in the stats breakdown
        let engine = Engine::new(EngineConfig {
            threads: 1,
            ..EngineConfig::default()
        });
        let (_plat3, inst3) = small_instance(10);
        engine.handle_line(&schedule_line(&inst3, "CEFT-CPOP"));
        let plat4 = Platform::uniform(4, 1.0, 0.0);
        let inst4 = generate(
            &RggParams {
                n: 30,
                out_degree: 3,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.25,
            },
            &CostModel::Classic { beta: 0.5 },
            &plat4,
            11,
        );
        let line = format!(
            r#"{{"op":"schedule","algorithm":"CEFT-CPOP","instance":{},"platform":{}}}"#,
            io::instance_to_json(&inst4).to_string(),
            io::platform_to_json(&plat4).to_string()
        );
        let (resp, _) = engine.handle_line(&line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let stats = engine.stats_json();
        let ws = stats.get("workspaces").unwrap();
        let per_ctx = ws.get("per_ctx").and_then(Json::as_arr).unwrap();
        assert_eq!(per_ctx.len(), 2, "one pool per platform context");
        let mut ps: Vec<f64> = per_ctx
            .iter()
            .map(|e| e.get("p").and_then(Json::as_f64).unwrap())
            .collect();
        ps.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(ps, vec![3.0, 4.0]);
        let created_sum: f64 = per_ctx
            .iter()
            .map(|e| e.get("created").and_then(Json::as_f64).unwrap())
            .sum();
        assert_eq!(
            ws.get("created").and_then(Json::as_f64),
            Some(created_sum),
            "aggregate equals the per-ctx sum"
        );
        for e in per_ctx {
            assert!(
                e.get("created").and_then(Json::as_f64).unwrap() >= 1.0,
                "each platform computed at least once on its own pool"
            );
        }
    }

    #[test]
    fn engine_gathered_batch_matches_serial_dispatch() {
        // Deterministic batching test: stage a window of parked table
        // leaders — mixed origins (cp / schedule) and orientations
        // (forward / reverse) — in the shard's collector exactly as
        // concurrent requests would, run one gather, and check every
        // fanned-back table, and every result later derived from it,
        // against serial dispatch.
        let revs = [false, false, false, true, true];
        let origins = [
            TableOrigin::Cp,
            TableOrigin::Schedule,
            TableOrigin::Cp,
            TableOrigin::Schedule,
            TableOrigin::Cp,
        ];
        let engine = Engine::with_defaults();
        let mut interned = Vec::new();
        let mut serial_tables = Vec::new();
        let mut serial_cp = Vec::new();
        let mut serial_up = Vec::new();
        let mut ws = Workspace::new();
        for seed in 0..5u64 {
            let i = seed as usize;
            let (plat, inst) = small_instance(700 + seed);
            serial_tables.push(if revs[i] {
                ceft_table_rev_with(&mut ws, inst.bind(&plat))
            } else {
                ceft_table_with(&mut ws, inst.bind(&plat))
            });
            serial_cp.push(find_critical_path(inst.bind(&plat)));
            serial_up.push(Algorithm::CeftHeftUp.schedule(inst.bind(&plat)).makespan());
            interned.push(
                engine
                    .resolve(
                        Target::Inline {
                            instance: inst,
                            platform: None,
                        },
                        &mut RequestTrace::disabled(),
                    )
                    .expect("inline resolve"),
            );
        }
        // all five share the default platform, hence one shard
        let shard = interned[0].shard.clone();
        for inst in &interned[1..] {
            assert!(Arc::ptr_eq(&inst.shard, &shard), "one shard per platform");
        }
        // park jobs 1.. as queued key leaders behind a saturated shard
        // (one gather slot, held by job 0 below)
        let mut cells = Vec::new();
        let mut timings = Vec::new();
        {
            let mut st = lock_clean(&shard.state);
            st.collector.active = 1;
            for (i, inst) in interned.iter().enumerate().skip(1) {
                let snap = inst.current();
                let key = Engine::table_key(inst, &snap, revs[i]);
                let cell = Arc::new(Inflight::new());
                let timing = Arc::new(BatchTiming::default());
                st.table_inflight.insert(key, cell.clone());
                st.collector.pending.push_back(PendingTable {
                    inst: inst.clone(),
                    snap,
                    delta: None,
                    key,
                    rev: revs[i],
                    origin: origins[i],
                    cell: cell.clone(),
                    queued_at: Instant::now(),
                    timing: timing.clone(),
                    deadline: None,
                });
                cells.push(cell);
                timings.push(timing);
            }
        }
        // job 0 is the gather leader; give it a live trace so the leader's
        // own stage attribution is checked too
        let leader_recorder = Recorder::new(true);
        let mut leader_trace = leader_recorder.begin(2); // "cp"
        let first_snap = interned[0].current();
        let first_key = Engine::table_key(&interned[0], &first_snap, revs[0]);
        let first_cell = Arc::new(Inflight::new());
        lock_clean(&shard.state)
            .table_inflight
            .insert(first_key, first_cell.clone());
        let (first, cached) = engine
            .run_gather(
                &shard,
                PendingTable {
                    inst: interned[0].clone(),
                    snap: first_snap,
                    delta: None,
                    key: first_key,
                    rev: revs[0],
                    origin: origins[0],
                    cell: first_cell,
                    queued_at: Instant::now(),
                    timing: Arc::new(BatchTiming::default()),
                    deadline: None,
                },
                &mut leader_trace,
            )
            .expect("un-deadlined gather is never rejected");
        assert!(!cached, "a gathered computation is not a cache hit");
        assert_eq!(first.table.table, serial_tables[0].table);
        assert_eq!(first.table.backptr, serial_tables[0].backptr);
        assert_eq!(first.origin, TableOrigin::Cp);
        // the leader was served by a width-5 drain: batch_drain, not kernel
        assert!(leader_trace.stage_ns(Stage::BatchDrain) > 0);
        assert_eq!(leader_trace.stage_ns(Stage::Kernel), 0);
        assert_eq!(leader_trace.stage_ns(Stage::QueueWait), 0);
        for (i, cell) in cells.iter().enumerate() {
            let got = match cell.wait() {
                FlightOutcome::Ready(v) => v,
                _ => panic!("gathered cell resolves with a result"),
            };
            assert_eq!(
                got.table.table,
                serial_tables[i + 1].table,
                "queued table {i} == serial"
            );
            assert_eq!(got.table.backptr, serial_tables[i + 1].backptr);
            assert_eq!(got.origin, origins[i + 1], "origin rides the memo entry");
        }
        // every drained request got park + sweep durations stamped (1 ns
        // floor: "occurred" even below clock resolution)
        for timing in &timings {
            assert!(timing.queue_ns.load(Ordering::Relaxed) >= 1);
            assert!(timing.drain_ns.load(Ordering::Relaxed) >= 1);
        }
        // counters: one drain of width 5, five insertions, no leftovers
        {
            let st = lock_clean(&shard.state);
            assert!(st.table_inflight.is_empty());
            assert!(st.collector.pending.is_empty());
            assert_eq!(st.collector.active, 0, "the staged gather slot was released");
            let s = st.table_cache.stats();
            assert_eq!(s.batched_requests, 5);
            assert_eq!(s.batch_width, 5);
            assert_eq!(s.insertions, 5);
            assert_eq!(s.cp_schedule_shares, 0, "no consumer has hit yet");
        }
        // cp requests on the forward instances derive from the memoized
        // tables, bit-identically to serial dispatch; instance 1's table
        // was computed for schedule traffic, so serving its cp request
        // records a cross-workload share
        for i in [0usize, 1, 2] {
            let resp = engine.handle(Request::CriticalPath {
                target: Target::Handle(interned[i].id),
                slack: false,
                deadline_ms: None,
            });
            assert_eq!(
                resp.get("length").and_then(Json::as_f64),
                Some(serial_cp[i].length),
                "cp {i} == serial"
            );
            assert_eq!(
                resp.get("path").and_then(Json::as_arr).unwrap().len(),
                serial_cp[i].path.len()
            );
        }
        // CEFT-HEFT-UP consumes the reverse tables; instance 4's was
        // staged with cp origin, so its schedule request shares too
        for i in [3usize, 4] {
            let resp = engine.handle(Request::Schedule {
                algorithm: Algorithm::CeftHeftUp,
                target: Target::Handle(interned[i].id),
                deadline_ms: None,
            });
            assert_eq!(
                resp.get("makespan").and_then(Json::as_f64),
                Some(serial_up[i]),
                "schedule {i} == serial"
            );
        }
        {
            let st = lock_clean(&shard.state);
            let s = st.table_cache.stats();
            assert_eq!(s.insertions, 5, "no table was recomputed");
            assert_eq!(s.hits, 5, "every consumer hit the memoized table");
            assert_eq!(
                s.cp_schedule_shares, 2,
                "cp over a schedule-origin table + schedule over a cp-origin table"
            );
        }
    }

    #[test]
    fn concurrent_distinct_cp_requests_match_serial_and_count_sanely() {
        // Six threads fire six *distinct* uncached cp requests on one
        // platform simultaneously. Whatever gather widths the race
        // produces, every response must equal serial dispatch and the
        // batching counters must stay coherent.
        let engine = Arc::new(Engine::with_defaults());
        let mut lines = Vec::new();
        let mut expected = Vec::new();
        for seed in 0..6u64 {
            let (plat, inst) = small_instance(900 + seed);
            expected.push(find_critical_path(inst.bind(&plat)).length);
            lines.push(format!(
                r#"{{"op":"cp","instance":{}}}"#,
                io::instance_to_json(&inst).to_string()
            ));
        }
        let barrier = Arc::new(std::sync::Barrier::new(lines.len()));
        let handles: Vec<_> = lines
            .into_iter()
            .map(|line| {
                let engine = engine.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    let (resp, _) = engine.handle_line(&line);
                    resp.get("length").and_then(Json::as_f64).unwrap()
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), expected[i], "request {i}");
        }
        let stats = engine.stats_json();
        let cp = stats.get("cp_cache").unwrap();
        let get = |k: &str| cp.get(k).and_then(Json::as_f64).unwrap();
        assert_eq!(get("insertions"), 6.0, "each distinct key computed once");
        // the gather queue batches the underlying *table* computations
        let table = stats.get("table_cache").unwrap();
        let tget = |k: &str| table.get(k).and_then(Json::as_f64).unwrap();
        assert_eq!(tget("insertions"), 6.0, "one table per distinct key");
        assert!(tget("batched_requests") <= 6.0);
        assert!(tget("batch_width") <= 6.0);
        assert!(
            tget("batched_requests") == 0.0 || tget("batched_requests") >= tget("batch_width"),
            "batched_requests {} vs batch_width {}",
            tget("batched_requests"),
            tget("batch_width")
        );
    }

    #[test]
    fn errors_do_not_poison_the_engine() {
        let engine = Engine::with_defaults();
        let (errs, _): (Json, bool) = engine.handle_line(
            r#"{"op":"cp","instance":{"n":2,"p":1,"edges":[[0,1,1.0],[1,0,1.0]],"comp":[1,2]}}"#,
        );
        assert_eq!(errs.get("ok"), Some(&Json::Bool(false)));
        // engine still serves good requests afterwards
        let (_plat, inst) = small_instance(7);
        let (ok, _) = engine.handle_line(&schedule_line(&inst, "CPOP"));
        assert_eq!(ok.get("ok"), Some(&Json::Bool(true)));
        let stats = engine.stats_json();
        assert!(stats.get("errors").and_then(Json::as_f64).unwrap() >= 1.0);
    }

    /// Count of a stage's histogram entries in a recorder snapshot.
    fn stage_count(engine: &Engine, stage: Stage) -> u64 {
        engine.recorder().snapshot().stages[stage.idx()].count
    }

    #[test]
    fn queue_wait_and_batch_drain_appear_only_for_batched_requests() {
        // Deterministic saturation: a 1-thread engine with a wide batch
        // window, its single gather slot held by the test. Every cp
        // request then parks in the collector; releasing the slot promotes
        // one request to lead a width-N gather over all of them. The
        // taxonomy invariant under test: exactly the N-1 *drained*
        // requests record queue_wait, all N record batch_drain, and the
        // promoted leader's park is cache_probe — matching
        // `batched_requests > 0 ⟺ queue_wait/batch_drain nonzero`.
        const N: usize = 4;
        let engine = Arc::new(Engine::new(EngineConfig {
            threads: 1,
            batch_window: 8,
            telemetry: Some(true),
            ..EngineConfig::default()
        }));
        let mut ids = Vec::new();
        let mut expected = Vec::new();
        let mut shard = None;
        for seed in 0..N as u64 {
            let (plat, inst) = small_instance(1100 + seed);
            expected.push(find_critical_path(inst.bind(&plat)).length);
            let interned = engine
                .resolve(
                    Target::Inline {
                        instance: inst,
                        platform: None,
                    },
                    &mut RequestTrace::disabled(),
                )
                .expect("inline resolve");
            ids.push(interned.id);
            shard.get_or_insert_with(|| interned.shard.clone());
        }
        let shard = shard.unwrap();
        // hold the engine's only gather slot
        lock_clean(&shard.state).collector.active = 1;
        let handles: Vec<_> = ids
            .iter()
            .map(|&id| {
                let engine = engine.clone();
                std::thread::spawn(move || {
                    let resp = engine.handle(Request::CriticalPath {
                        target: Target::Handle(id),
                        slack: false,
                        deadline_ms: None,
                    });
                    resp.get("length").and_then(Json::as_f64).unwrap()
                })
            })
            .collect();
        // wait until all N key leaders parked in the collector
        for _ in 0..2000 {
            if lock_clean(&shard.state).collector.pending.len() == N {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(
            lock_clean(&shard.state).collector.pending.len(),
            N,
            "all requests must queue behind the held gather slot"
        );
        // release the slot as a finishing gather would: promote the head
        let promoted = {
            let mut st = lock_clean(&shard.state);
            Engine::finish_gather(&mut st)
        }
        .expect("a queued leader to promote");
        promoted.cell.complete(FlightOutcome::Retry);
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), expected[i], "request {i}");
        }
        // one width-N gather served everything (batching counts live on
        // the table cache since the gather queue batches table sweeps)
        let stats = engine.stats_json();
        let table = stats.get("table_cache").unwrap();
        assert_eq!(
            table.get("batched_requests").and_then(Json::as_f64),
            Some(N as f64)
        );
        assert_eq!(
            table.get("batch_width").and_then(Json::as_f64),
            Some(N as f64)
        );
        // stage attribution: drained requests (N-1) recorded queue_wait,
        // all N recorded batch_drain, every request recorded kernel (the
        // cp derivation from the memoized table — the DP itself was
        // batch-drained, not width-1 computed), and every request probed
        // the caches
        assert_eq!(stage_count(&engine, Stage::QueueWait), (N - 1) as u64);
        assert_eq!(stage_count(&engine, Stage::BatchDrain), N as u64);
        assert_eq!(stage_count(&engine, Stage::Kernel), N as u64);
        assert_eq!(stage_count(&engine, Stage::Respond), N as u64);
        assert!(stage_count(&engine, Stage::CacheProbe) >= N as u64);
    }

    #[test]
    fn serial_requests_record_kernel_but_never_queue_stages() {
        // batch_window 1 disables gathering entirely: misses run the plain
        // fused kernel, so kernel/cache_probe/respond populate while the
        // batching stages stay silent — the other half of the invariant.
        let engine = Engine::new(EngineConfig {
            threads: 1,
            batch_window: 1,
            telemetry: Some(true),
            ..EngineConfig::default()
        });
        let (_plat, inst) = small_instance(1200);
        let cp_line = format!(
            r#"{{"op":"cp","instance":{}}}"#,
            io::instance_to_json(&inst).to_string()
        );
        engine.handle_line(&cp_line);
        engine.handle_line(&cp_line); // cache hit
        engine.handle_line(&schedule_line(&inst, "CEFT-CPOP"));
        let snap = engine.recorder().snapshot();
        let count = |s: Stage| snap.stages[s.idx()].count;
        assert_eq!(count(Stage::Parse), 3, "every line parsed under a span");
        assert_eq!(count(Stage::Intern), 3, "inline targets intern");
        assert_eq!(count(Stage::CtxBuild), 1, "panels built exactly once");
        assert_eq!(count(Stage::Kernel), 2, "cp miss + schedule miss");
        assert_eq!(count(Stage::QueueWait), 0, "no gathering at window 1");
        assert_eq!(count(Stage::BatchDrain), 0, "no gathering at window 1");
        assert_eq!(count(Stage::Respond), 3);
        assert!(count(Stage::CacheProbe) >= 3);
        // traces carry the op label end-to-end
        let ops: Vec<&str> = snap
            .recent
            .iter()
            .map(|r| protocol::op_name(r.op))
            .collect();
        assert!(ops.contains(&"cp") && ops.contains(&"schedule"));
    }

    #[test]
    fn disabled_telemetry_records_nothing() {
        let engine = Engine::new(EngineConfig {
            telemetry: Some(false),
            ..EngineConfig::default()
        });
        let (_plat, inst) = small_instance(1300);
        engine.handle_line(&schedule_line(&inst, "HEFT"));
        let snap = engine.recorder().snapshot();
        for s in Stage::ALL {
            assert_eq!(snap.stages[s.idx()].count, 0, "{} recorded", s.name());
        }
        assert!(snap.recent.is_empty());
        // the trace endpoint reports the toggle instead of stale data
        let resp = engine.trace_json(8);
        assert_eq!(resp.get("telemetry").and_then(Json::as_str), Some("off"));
        let stats = engine.stats_json();
        assert_eq!(stats.get("telemetry").and_then(Json::as_str), Some("off"));
    }

    #[test]
    fn trace_and_metrics_ops_expose_stage_latencies() {
        let engine = Engine::new(EngineConfig {
            telemetry: Some(true),
            ..EngineConfig::default()
        });
        let (_plat, inst) = small_instance(1400);
        engine.handle_line(&schedule_line(&inst, "CEFT-CPOP"));
        let (resp, _) = engine.handle_line(r#"{"op":"trace","limit":4}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("telemetry").and_then(Json::as_str), Some("on"));
        let stages = resp.get("stages").expect("stages section");
        for s in Stage::ALL {
            assert!(stages.get(s.name()).is_some(), "missing stage {}", s.name());
        }
        let kernel_count = stages
            .get("kernel")
            .and_then(|k| k.get("count"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!(kernel_count >= 1.0, "schedule miss must record kernel time");
        let slowest = resp.get("slowest").and_then(Json::as_arr).unwrap();
        assert!(!slowest.is_empty() && slowest.len() <= 4);
        assert!(slowest[0].get("total_us").and_then(Json::as_f64).unwrap() > 0.0);
        // kernel-path attribution is present for all four dispatch paths
        let paths = resp.get("kernel_paths").expect("kernel_paths section");
        for p in obs::KernelPath::ALL {
            assert!(paths.get(p.name()).is_some(), "missing path {}", p.name());
        }
        // metrics op returns the text exposition with the stage family
        let (m, _) = engine.handle_line(r#"{"op":"metrics"}"#);
        let text = m.get("text").and_then(Json::as_str).unwrap();
        assert!(text.contains("ceft_stage_latency_seconds"));
        assert!(text.contains("ceft_requests_total"));
        assert!(text.contains("quantile=\"0.99\""));
        // stats carries the same percentile fields
        let stats = engine.stats_json();
        let st_stages = stats.get("stages").expect("stats stages section");
        assert!(st_stages
            .get("respond")
            .and_then(|s| s.get("p50_us"))
            .is_some());
    }

    // ---- incremental update (versioned interning + delta-CEFT) ----

    /// Hand-built instance: exact edges and per-class costs, so edit
    /// outcomes are predictable down to the bit (the engine's default
    /// platform for a bare submit is `uniform(p, 1.0, 0.0)`).
    fn hand_instance(n: usize, edges: &[(usize, usize, f64)], p: usize, comp: &[f64]) -> Instance {
        Instance {
            graph: TaskGraph::from_edges(n, edges),
            comp: CostMatrix::new(p, comp.to_vec()),
        }
    }

    fn submit_line(inst: &Instance) -> String {
        format!(
            r#"{{"op":"submit","instance":{}}}"#,
            io::instance_to_json(inst).to_string()
        )
    }

    fn submit_id(engine: &Engine, inst: &Instance) -> String {
        let (resp, _) = engine.handle_line(&submit_line(inst));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        resp.get("id").and_then(Json::as_str).unwrap().to_string()
    }

    #[test]
    fn update_round_trip_recomputes_dirty_suffix_and_reports_slack() {
        let engine = Engine::with_defaults();
        let n = 12;
        let p = 2;
        let edges: Vec<(usize, usize, f64)> = (0..n - 1).map(|i| (i, i + 1, 1.0)).collect();
        let comp: Vec<f64> = (0..n * p).map(|i| 1.0 + (i % 5) as f64).collect();
        let inst = hand_instance(n, &edges, p, &comp);
        let id = submit_id(&engine, &inst);
        // seed the generation-0 forward table so the update has a basis
        let (cp0, _) = engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
        assert_eq!(cp0.get("ok"), Some(&Json::Bool(true)));
        // edit: bump one interior task's costs and splice in a shortcut
        let (up, _) = engine.handle_line(&format!(
            r#"{{"op":"update","id":"{id}","edits":[
                {{"edit":"task_cost","task":8,"costs":[9.0,11.0]}},
                {{"edit":"add_edge","src":3,"dst":7,"data":2.0}}]}}"#
        ));
        assert_eq!(up.get("ok"), Some(&Json::Bool(true)), "{up:?}");
        assert_eq!(up.get("generation").and_then(Json::as_f64), Some(1.0));
        assert_eq!(up.get("n").and_then(Json::as_f64), Some(n as f64));
        assert_eq!(up.get("skipped"), Some(&Json::Bool(false)));
        // bit-identical to a from-scratch solve of the edited instance
        let mut comp2 = comp.clone();
        comp2[8 * p] = 9.0;
        comp2[8 * p + 1] = 11.0;
        let mut edges2 = edges.clone();
        edges2.push((3, 7, 2.0));
        let edited = hand_instance(n, &edges2, p, &comp2);
        let plat = Platform::uniform(p, 1.0, 0.0);
        let scratch = find_critical_path(edited.bind(&plat));
        assert_eq!(
            up.get("length").and_then(Json::as_f64),
            Some(scratch.length)
        );
        // suffix economy: clean prefix before the first dirty task (3) is
        // copied, so strictly fewer than n rows were recomputed
        let rec = up
            .get("delta_rows_recomputed")
            .and_then(Json::as_f64)
            .unwrap();
        assert_eq!(up.get("full_rows").and_then(Json::as_f64), Some(n as f64));
        assert!(rec > 0.0 && rec < n as f64, "recomputed {rec} of {n}");
        // slack: one entry per task, zero exactly on the realized path
        let slack = up.get("slack").and_then(Json::as_arr).unwrap();
        assert_eq!(slack.len(), n);
        for s in slack {
            assert!(s.as_f64().unwrap() >= 0.0);
        }
        // a follow-up cp by handle serves the new generation, and its
        // slack view matches the update's bit for bit
        let (cp1, _) = engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}","slack":true}}"#));
        assert_eq!(
            cp1.get("length").and_then(Json::as_f64),
            Some(scratch.length)
        );
        assert_eq!(cp1.get("slack"), up.get("slack"));
        for step in cp1.get("path").and_then(Json::as_arr).unwrap() {
            let t = step.get(0).and_then(Json::as_f64).unwrap() as usize;
            assert_eq!(slack[t].as_f64(), Some(0.0), "task {t} on cp has slack");
        }
        // the delta counters made it to stats
        let stats = engine.stats_json();
        let table = stats.get("table_cache").unwrap();
        assert!(
            table
                .get("delta_rows_recomputed")
                .and_then(Json::as_f64)
                .unwrap()
                > 0.0
        );
        assert!(table.get("delta_full_rows").and_then(Json::as_f64).unwrap() >= n as f64);
        assert_eq!(
            stats.get("update_requests").and_then(Json::as_f64),
            Some(1.0)
        );
    }

    #[test]
    fn update_tail_edit_recomputes_at_most_ten_percent_of_rows() {
        let engine = Engine::with_defaults();
        let n = 50;
        let p = 2;
        let edges: Vec<(usize, usize, f64)> = (0..n - 1).map(|i| (i, i + 1, 0.5)).collect();
        let comp: Vec<f64> = (0..n * p).map(|i| 2.0 + (i % 3) as f64).collect();
        let inst = hand_instance(n, &edges, p, &comp);
        let id = submit_id(&engine, &inst);
        engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
        // dirty the last decile of the topo order (task 45 of 50): the
        // acceptance bound is ≤ 10% of rows recomputed
        let (up, _) = engine.handle_line(&format!(
            r#"{{"op":"update","id":"{id}","edits":[
                {{"edit":"task_cost","task":45,"costs":[7.0,8.0]}}]}}"#
        ));
        assert_eq!(up.get("ok"), Some(&Json::Bool(true)), "{up:?}");
        assert_eq!(up.get("skipped"), Some(&Json::Bool(false)));
        let rec = up
            .get("delta_rows_recomputed")
            .and_then(Json::as_f64)
            .unwrap();
        let full = up.get("full_rows").and_then(Json::as_f64).unwrap();
        assert!(
            rec <= 0.10 * full,
            "tail edit recomputed {rec} of {full} rows (> 10%)"
        );
        // still bit-identical to scratch
        let mut comp2 = comp.clone();
        comp2[45 * p] = 7.0;
        comp2[45 * p + 1] = 8.0;
        let edited = hand_instance(n, &edges, p, &comp2);
        let plat = Platform::uniform(p, 1.0, 0.0);
        assert_eq!(
            up.get("length").and_then(Json::as_f64),
            Some(find_critical_path(edited.bind(&plat)).length)
        );
    }

    #[test]
    fn update_skip_rule_bounds_increase_by_slack() {
        let engine = Engine::with_defaults();
        // diamond 0 → {1 long, 2 short} → 3, zero-data edges, p = 1:
        // CPL = 1 + 10 + 1 = 12 through task 1; task 2 has slack 9
        let edges = [(0, 1, 0.0), (0, 2, 0.0), (1, 3, 0.0), (2, 3, 0.0)];
        let inst = hand_instance(4, &edges, 1, &[1.0, 10.0, 1.0, 1.0]);
        let id = submit_id(&engine, &inst);
        let (cp0, _) = engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}","slack":true}}"#));
        assert_eq!(cp0.get("length").and_then(Json::as_f64), Some(12.0));
        let slack0 = cp0.get("slack").and_then(Json::as_arr).unwrap();
        assert_eq!(slack0[2].as_f64(), Some(9.0));
        assert_eq!(slack0[1].as_f64(), Some(0.0));
        // +3 on the slack-9 task: provably inert, the recompute is skipped
        let (up1, _) = engine.handle_line(&format!(
            r#"{{"op":"update","id":"{id}","edits":[
                {{"edit":"task_cost","task":2,"costs":[4.0]}}]}}"#
        ));
        assert_eq!(up1.get("ok"), Some(&Json::Bool(true)), "{up1:?}");
        assert_eq!(up1.get("skipped"), Some(&Json::Bool(true)));
        assert_eq!(up1.get("length").and_then(Json::as_f64), Some(12.0));
        assert_eq!(
            up1.get("delta_rows_recomputed").and_then(Json::as_f64),
            Some(0.0)
        );
        // the skipped generation still answers correctly; asking for
        // slack forces the new generation's table (a delta recompute),
        // giving the next update a basis for its own skip check
        let (cp1, _) = engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}","slack":true}}"#));
        assert_eq!(cp1.get("length").and_then(Json::as_f64), Some(12.0));
        // +20 exceeds the short branch's remaining slack (6): eager
        // recompute, and the critical path moves to the short branch
        let (up2, _) = engine.handle_line(&format!(
            r#"{{"op":"update","id":"{id}","edits":[
                {{"edit":"task_cost","task":2,"costs":[24.0]}}]}}"#
        ));
        assert_eq!(up2.get("ok"), Some(&Json::Bool(true)), "{up2:?}");
        assert_eq!(up2.get("skipped"), Some(&Json::Bool(false)));
        assert_eq!(up2.get("generation").and_then(Json::as_f64), Some(2.0));
        assert_eq!(up2.get("length").and_then(Json::as_f64), Some(26.0));
    }

    #[test]
    fn update_skip_rule_sums_increases_across_edited_tasks() {
        let engine = Engine::with_defaults();
        // two parallel chains 0 → 1 → 2 → 5 (long) and 0 → 3 → 4 → 5
        // (short): CPL = 12, tasks 3 and 4 each have slack 8
        let edges = [
            (0, 1, 0.0),
            (1, 2, 0.0),
            (2, 5, 0.0),
            (0, 3, 0.0),
            (3, 4, 0.0),
            (4, 5, 0.0),
        ];
        let inst = hand_instance(6, &edges, 1, &[1.0, 5.0, 5.0, 1.0, 1.0, 1.0]);
        let id = submit_id(&engine, &inst);
        engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
        // +3 on each short-chain task: per-task AND summed (6) within the
        // shared slack 8 — skip
        let (up1, _) = engine.handle_line(&format!(
            r#"{{"op":"update","id":"{id}","edits":[
                {{"edit":"task_cost","task":3,"costs":[4.0]}},
                {{"edit":"task_cost","task":4,"costs":[4.0]}}]}}"#
        ));
        assert_eq!(up1.get("skipped"), Some(&Json::Bool(true)), "{up1:?}");
        assert_eq!(up1.get("length").and_then(Json::as_f64), Some(12.0));
        // force the generation-1 table so the next skip check has a basis
        engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}","slack":true}}"#));
        // +5 on each: per-task each within the remaining slack 2? no —
        // but even when each increase alone would fit a per-task bound,
        // the two tasks share one path, so only the SUMMED rule is sound.
        // 5 + 5 = 10 > 2, no skip; short chain becomes 1+9+9+1 = 20
        let (up2, _) = engine.handle_line(&format!(
            r#"{{"op":"update","id":"{id}","edits":[
                {{"edit":"task_cost","task":3,"costs":[9.0]}},
                {{"edit":"task_cost","task":4,"costs":[9.0]}}]}}"#
        ));
        assert_eq!(up2.get("skipped"), Some(&Json::Bool(false)), "{up2:?}");
        assert_eq!(up2.get("length").and_then(Json::as_f64), Some(20.0));
        // scratch check on the final content
        let plat = Platform::uniform(1, 1.0, 0.0);
        let edited = hand_instance(6, &edges, 1, &[1.0, 5.0, 5.0, 9.0, 9.0, 1.0]);
        assert_eq!(find_critical_path(edited.bind(&plat)).length, 20.0);
    }

    #[test]
    fn sp_shaped_requests_route_to_tree_dp_and_match_general() {
        let engine = Engine::with_defaults();
        // diamond 0 → {1, 2} → 3: fork-join, recognizer-accepted
        let edges = [(0, 1, 2.0), (0, 2, 3.0), (1, 3, 1.0), (2, 3, 4.0)];
        let comp = [3.0, 5.0, 2.0, 7.0, 6.0, 1.0, 4.0, 4.0];
        let inst = hand_instance(4, &edges, 2, &comp);
        let plat = Platform::uniform(2, 1.0, 0.0);
        let expected = find_critical_path(inst.bind(&plat));
        let id = submit_id(&engine, &inst);
        let (cp, _) = engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
        assert_eq!(cp.get("ok"), Some(&Json::Bool(true)), "{cp:?}");
        assert_eq!(
            cp.get("length").and_then(Json::as_f64),
            Some(expected.length)
        );
        // schedulers consume the same (sp-computed) table unchanged
        let mk = Algorithm::CeftCpop.schedule(inst.bind(&plat)).makespan();
        let (sched, _) = engine.handle_line(&format!(
            r#"{{"op":"schedule","algorithm":"CEFT-CPOP","id":"{id}"}}"#
        ));
        assert_eq!(sched.get("makespan").and_then(Json::as_f64), Some(mk));
        let stats = engine.handle(Request::Stats);
        let shapes = stats.get("shapes").expect("stats carry a shapes section");
        assert_eq!(
            shapes
                .get("verdicts")
                .and_then(|v| v.get("fork_join"))
                .and_then(Json::as_f64),
            Some(1.0)
        );
        assert!(
            shapes.get("fast_path_hits").and_then(Json::as_f64) >= Some(1.0),
            "{stats:?}"
        );
        assert_eq!(
            shapes.get("general_fallbacks").and_then(Json::as_f64),
            Some(0.0)
        );
    }

    #[test]
    fn sp_breaking_update_demotes_to_general_path_with_correct_results() {
        let engine = Engine::with_defaults();
        // diamond (SP) at generation 0
        let edges = [(0, 1, 1.0), (0, 2, 1.0), (1, 3, 1.0), (2, 3, 1.0)];
        let comp = [2.0, 3.0, 4.0, 2.0, 5.0, 3.0, 1.0, 6.0];
        let inst = hand_instance(4, &edges, 2, &comp);
        let id = submit_id(&engine, &inst);
        let (cp0, _) = engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
        assert_eq!(cp0.get("ok"), Some(&Json::Bool(true)), "{cp0:?}");
        // the cross-branch edge 1 → 2 turns the diamond into the N-graph —
        // not series-parallel; the verdict must demote, the answer must
        // match a from-scratch general computation on the edited content
        let (up, _) = engine.handle_line(&format!(
            r#"{{"op":"update","id":"{id}","edits":[{{"edit":"add_edge","src":1,"dst":2,"data":2.0}}]}}"#
        ));
        assert_eq!(up.get("ok"), Some(&Json::Bool(true)), "{up:?}");
        let edited_edges = [
            (0, 1, 1.0),
            (0, 2, 1.0),
            (1, 3, 1.0),
            (2, 3, 1.0),
            (1, 2, 2.0),
        ];
        let edited = hand_instance(4, &edited_edges, 2, &comp);
        let plat = Platform::uniform(2, 1.0, 0.0);
        assert_eq!(
            up.get("length").and_then(Json::as_f64),
            Some(find_critical_path(edited.bind(&plat)).length)
        );
        // post-edit traffic keeps serving correct answers off the handle
        let (cp1, _) = engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
        assert_eq!(
            cp1.get("length").and_then(Json::as_f64),
            Some(find_critical_path(edited.bind(&plat)).length)
        );
        let stats = engine.handle(Request::Stats);
        let shapes = stats.get("shapes").expect("stats carry a shapes section");
        // one fork-join verdict at intern, one general verdict at re-check
        assert_eq!(
            shapes
                .get("verdicts")
                .and_then(|v| v.get("fork_join"))
                .and_then(Json::as_f64),
            Some(1.0)
        );
        assert_eq!(
            shapes
                .get("verdicts")
                .and_then(|v| v.get("general"))
                .and_then(Json::as_f64),
            Some(1.0)
        );
        // generation 0 rode the fast path; the post-edit recompute fell
        // back (delta-planned or general from scratch — either way, not sp)
        assert!(
            shapes.get("fast_path_hits").and_then(Json::as_f64) >= Some(1.0),
            "{stats:?}"
        );
        assert!(
            shapes.get("general_fallbacks").and_then(Json::as_f64) >= Some(1.0),
            "{stats:?}"
        );
    }

    #[test]
    fn racing_edits_and_lookups_serve_exactly_one_generation() {
        let engine = Engine::with_defaults();
        let n = 6;
        let edges: Vec<(usize, usize, f64)> = (0..n - 1).map(|i| (i, i + 1, 0.0)).collect();
        let comp = vec![1.0; n];
        let inst = hand_instance(n, &edges, 1, &comp);
        let id = submit_id(&engine, &inst);
        engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
        // generation g sets the sink's cost to 1 + 10g: every generation
        // has a distinct integral CPL, so any torn read (key from one
        // snapshot, bits from another) would surface as an alien length
        let updates = 4;
        let valid: Vec<f64> = (0..=updates).map(|g| 6.0 + 10.0 * g as f64).collect();
        std::thread::scope(|scope| {
            let stop = AtomicBool::new(false);
            let stop = &stop;
            let engine = &engine;
            let id = &id;
            let valid = &valid;
            let mut readers = Vec::new();
            for _ in 0..4 {
                readers.push(scope.spawn(move || {
                    let mut seen = 0usize;
                    while !stop.load(Ordering::Relaxed) {
                        let (resp, _) =
                            engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
                        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
                        let len = resp.get("length").and_then(Json::as_f64).unwrap();
                        assert!(
                            valid.contains(&len),
                            "cp length {len} matches no generation (valid: {valid:?})"
                        );
                        seen += 1;
                    }
                    seen
                }));
            }
            for g in 1..=updates {
                let cost = 1.0 + 10.0 * g as f64;
                let (up, _) = engine.handle_line(&format!(
                    r#"{{"op":"update","id":"{id}","edits":[
                        {{"edit":"task_cost","task":{last},"costs":[{cost}]}}]}}"#,
                    last = n - 1
                ));
                assert_eq!(up.get("ok"), Some(&Json::Bool(true)), "{up:?}");
                assert_eq!(
                    up.get("length").and_then(Json::as_f64),
                    Some(valid[g]),
                    "generation {g}"
                );
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            stop.store(true, Ordering::Relaxed);
            let total: usize = readers.into_iter().map(|r| r.join().unwrap()).sum();
            assert!(total > 0, "readers never ran");
        });
    }

    #[test]
    fn edited_instance_resubmit_evict_and_atomic_failure() {
        let engine = Engine::with_defaults();
        let edges = [(0, 1, 0.0), (0, 2, 0.0), (1, 3, 0.0), (2, 3, 0.0)];
        let comp = [1.0, 10.0, 1.0, 1.0];
        let inst = hand_instance(4, &edges, 1, &comp);
        let id = submit_id(&engine, &inst);
        engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
        // a failing edit batch (cycle) is rejected atomically: the
        // generation does not advance and results are untouched
        let (bad, _) = engine.handle_line(&format!(
            r#"{{"op":"update","id":"{id}","edits":[
                {{"edit":"add_edge","src":3,"dst":0,"data":1.0}}]}}"#
        ));
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
        let (cp, _) = engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
        assert_eq!(cp.get("length").and_then(Json::as_f64), Some(12.0));
        // a successful edit lands generation 1 …
        let (up, _) = engine.handle_line(&format!(
            r#"{{"op":"update","id":"{id}","edits":[
                {{"edit":"task_cost","task":1,"costs":[20.0]}}]}}"#
        ));
        assert_eq!(up.get("generation").and_then(Json::as_f64), Some(1.0));
        assert_eq!(up.get("length").and_then(Json::as_f64), Some(22.0));
        // … after which resubmitting the ORIGINAL content is refused with
        // an actionable error (the handle's content has drifted), not a
        // silent aliasing of stale results
        let (resub, _) = engine.handle_line(&submit_line(&inst));
        assert_eq!(resub.get("ok"), Some(&Json::Bool(false)));
        assert!(resub
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("edited in place"));
        // evicting drops the versioned state with the handle, and the
        // original content can then be interned afresh at generation 0
        let (ev, _) = engine.handle_line(&format!(r#"{{"op":"evict","id":"{id}"}}"#));
        assert_eq!(ev.get("ok"), Some(&Json::Bool(true)));
        let id2 = submit_id(&engine, &inst);
        assert_eq!(id2, id, "content addressing is deterministic");
        let (cp2, _) = engine.handle_line(&format!(r#"{{"op":"cp","id":"{id2}"}}"#));
        assert_eq!(cp2.get("length").and_then(Json::as_f64), Some(12.0));
    }

    // ---- resilience: deadlines, admission control, panic isolation ----

    #[test]
    fn governor_budget_steps_with_hysteresis_dead_band() {
        // pure step function: halve above the high water, grow below the
        // low water, hold inside the dead band, clamp at both rails
        assert_eq!(next_budget(32, SHED_HIGH_WATER_NS + 1, 2, 32), 16);
        assert_eq!(next_budget(3, SHED_HIGH_WATER_NS + 1, 2, 32), 2);
        assert_eq!(next_budget(2, SHED_HIGH_WATER_NS + 1, 2, 32), 2, "floor");
        assert_eq!(next_budget(16, SHED_LOW_WATER_NS - 1, 2, 32), 20);
        assert_eq!(
            next_budget(1, SHED_LOW_WATER_NS - 1, 1, 32),
            2,
            "growth is at least one even from a tiny budget"
        );
        assert_eq!(next_budget(32, SHED_LOW_WATER_NS - 1, 2, 32), 32, "cap");
        // the dead band holds in both directions — a budget change needs a
        // regime change, not noise straddling one threshold
        assert_eq!(next_budget(16, SHED_LOW_WATER_NS, 2, 32), 16);
        assert_eq!(next_budget(16, SHED_HIGH_WATER_NS, 2, 32), 16);
        // bounds derive from the engine shape; pinning disables stepping
        let g = Governor::new(2, 8, None);
        assert_eq!(g.budget(), 2 * 8 * 4);
        let pinned = Governor::new(2, 8, Some(3));
        assert_eq!(pinned.budget(), 3);
        assert!(pinned.pinned);
        // the retry hint clamps to [1, 1000] ms
        assert_eq!(pinned.retry_after_ms(), 1);
        pinned.last_p99_ns.store(5_000_000_000, Ordering::Relaxed);
        assert_eq!(pinned.retry_after_ms(), 1000);
    }

    #[test]
    fn deadline_rejects_expired_miss_but_serves_cache_hit() {
        let engine = Engine::with_defaults();
        let (_plat, inst) = small_instance(5000);
        let inst_json = io::instance_to_json(&inst).to_string();
        // an uncached miss with an already-spent budget is refused at the
        // cache probe, before it costs a core
        let (miss, _) = engine.handle_line(&format!(
            r#"{{"op":"cp","instance":{inst_json},"deadline_ms":0}}"#
        ));
        assert_eq!(miss.get("ok"), Some(&Json::Bool(false)), "{miss:?}");
        assert_eq!(
            miss.get("error").and_then(Json::as_str),
            Some("deadline_exceeded")
        );
        assert!(miss.get("retry_after_ms").and_then(Json::as_f64).unwrap() >= 1.0);
        // compute it without a deadline; the same expired budget is then
        // served from cache — the hit is cheaper than the rejection
        let (full, _) = engine.handle_line(&format!(r#"{{"op":"cp","instance":{inst_json}}}"#));
        assert_eq!(full.get("ok"), Some(&Json::Bool(true)), "{full:?}");
        let (hit, _) = engine.handle_line(&format!(
            r#"{{"op":"cp","instance":{inst_json},"deadline_ms":0}}"#
        ));
        assert_eq!(hit.get("ok"), Some(&Json::Bool(true)), "{hit:?}");
        assert_eq!(hit.get("length"), full.get("length"));
        let stats = engine.stats_json();
        let res = stats.get("resilience").expect("resilience stats section");
        assert_eq!(
            res.get("deadline_expired").and_then(Json::as_f64),
            Some(1.0)
        );
        assert_eq!(res.get("shed_requests").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn update_deadline_checked_before_the_edit_commits() {
        // the deadline checkpoint sits *before* the edit applies: a
        // refused update must not advance the generation (the reply after
        // a committed edit must describe the committed state, so no
        // checkpoint may run between edit and reply)
        let engine = Engine::with_defaults();
        let inst = hand_instance(2, &[(0, 1, 0.0)], 1, &[1.0, 2.0]);
        let id = submit_id(&engine, &inst);
        engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
        let (up, _) = engine.handle_line(&format!(
            r#"{{"op":"update","id":"{id}","deadline_ms":0,"edits":[
                {{"edit":"task_cost","task":1,"costs":[9.0]}}]}}"#
        ));
        assert_eq!(up.get("ok"), Some(&Json::Bool(false)), "{up:?}");
        assert_eq!(
            up.get("error").and_then(Json::as_str),
            Some("deadline_exceeded")
        );
        // still generation-0 content: the edit never landed
        let (cp, _) = engine.handle_line(&format!(r#"{{"op":"cp","id":"{id}"}}"#));
        assert_eq!(cp.get("length").and_then(Json::as_f64), Some(3.0));
    }

    #[test]
    fn injected_delay_deterministically_expires_deadline() {
        // fault plan: a single 30 ms stage delay on the first request.
        // Admission terms are fixed before the injected delay, so a 5 ms
        // budget is deterministically spent at the first checkpoint.
        let engine = Engine::new(EngineConfig {
            fault: Some(FaultPlan::parse("seed=0,delay=1:30x1").unwrap()),
            ..EngineConfig::default()
        });
        let (_plat, inst) = small_instance(5100);
        let inst_json = io::instance_to_json(&inst).to_string();
        let (resp, _) = engine.handle_line(&format!(
            r#"{{"op":"cp","instance":{inst_json},"deadline_ms":5}}"#
        ));
        assert_eq!(
            resp.get("error").and_then(Json::as_str),
            Some("deadline_exceeded"),
            "{resp:?}"
        );
        // the delay rule's cap is spent: an undeadlined retry computes
        let (retry, _) = engine.handle_line(&format!(r#"{{"op":"cp","instance":{inst_json}}}"#));
        assert_eq!(retry.get("ok"), Some(&Json::Bool(true)), "{retry:?}");
        let (panics, delays, drops) = engine.fault().expect("plan armed").fired();
        assert_eq!((panics, delays, drops), (0, 1, 0));
    }

    #[test]
    fn pinned_admission_budget_sheds_new_misses_not_hits() {
        let engine = Engine::new(EngineConfig {
            threads: 1,
            batch_window: 1,
            admission_budget: Some(1),
            ..EngineConfig::default()
        });
        // under budget: the first miss computes normally
        let (_plat, inst_a) = small_instance(5200);
        let line_a = format!(
            r#"{{"op":"cp","instance":{}}}"#,
            io::instance_to_json(&inst_a).to_string()
        );
        let (a, _) = engine.handle_line(&line_a);
        assert_eq!(a.get("ok"), Some(&Json::Bool(true)), "{a:?}");
        // occupy the whole budget with a staged in-flight table entry
        let (_plat, inst_b) = small_instance(5300);
        let interned_b = engine
            .resolve(
                Target::Inline {
                    instance: inst_b,
                    platform: None,
                },
                &mut RequestTrace::disabled(),
            )
            .expect("inline resolve");
        let snap_b = interned_b.current();
        let key_b = Engine::table_key(&interned_b, &snap_b, false);
        lock_clean(&interned_b.shard.state)
            .table_inflight
            .insert(key_b, Arc::new(Inflight::new()));
        // a NEW miss is refused with the structured shed error …
        let (_plat, inst_c) = small_instance(5400);
        let line_c = format!(
            r#"{{"op":"cp","instance":{}}}"#,
            io::instance_to_json(&inst_c).to_string()
        );
        let (c, _) = engine.handle_line(&line_c);
        assert_eq!(c.get("ok"), Some(&Json::Bool(false)), "{c:?}");
        assert_eq!(c.get("error").and_then(Json::as_str), Some("shed"));
        assert!(c.get("retry_after_ms").and_then(Json::as_f64).unwrap() >= 1.0);
        // … while cache hits keep serving under the same pressure
        let (hit, _) = engine.handle_line(&line_a);
        assert_eq!(hit.get("ok"), Some(&Json::Bool(true)), "{hit:?}");
        let stats = engine.stats_json();
        let res = stats.get("resilience").expect("resilience stats section");
        assert_eq!(res.get("shed_requests").and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            res.get("admission_budget").and_then(Json::as_f64),
            Some(1.0)
        );
        // releasing the pressure re-admits the shed key
        lock_clean(&interned_b.shard.state)
            .table_inflight
            .remove(&key_b);
        let (c2, _) = engine.handle_line(&line_c);
        assert_eq!(c2.get("ok"), Some(&Json::Bool(true)), "{c2:?}");
    }

    #[test]
    fn mid_gather_panic_resolves_all_cobatched_requests_with_errors() {
        // A kernel panic inside a width-N gathered sweep must resolve
        // every co-batched request with a structured `internal_panic`
        // error — no hung follower, no dead thread — be counted exactly
        // once, and leave the engine serving.
        const N: usize = 3;
        let engine = Arc::new(Engine::new(EngineConfig {
            threads: 1,
            batch_window: 8,
            fault: Some(FaultPlan::parse("seed=0,kernel_panic=1x1").unwrap()),
            ..EngineConfig::default()
        }));
        let mut ids = Vec::new();
        let mut expected = Vec::new();
        let mut shard = None;
        for seed in 0..N as u64 {
            let (plat, inst) = small_instance(5500 + seed);
            expected.push(find_critical_path(inst.bind(&plat)).length);
            let interned = engine
                .resolve(
                    Target::Inline {
                        instance: inst,
                        platform: None,
                    },
                    &mut RequestTrace::disabled(),
                )
                .expect("inline resolve");
            ids.push(interned.id);
            shard.get_or_insert_with(|| interned.shard.clone());
        }
        let shard = shard.unwrap();
        // hold the single gather slot so all N requests park
        lock_clean(&shard.state).collector.active = 1;
        let handles: Vec<_> = ids
            .iter()
            .map(|&id| {
                let engine = engine.clone();
                std::thread::spawn(move || {
                    engine.handle(Request::CriticalPath {
                        target: Target::Handle(id),
                        slack: false,
                        deadline_ms: None,
                    })
                })
            })
            .collect();
        for _ in 0..2000 {
            if lock_clean(&shard.state).collector.pending.len() == N {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(
            lock_clean(&shard.state).collector.pending.len(),
            N,
            "all requests must queue behind the held gather slot"
        );
        // release the slot: the promoted head leads a width-N gather that
        // hits the injected kernel panic
        let promoted = {
            let mut st = lock_clean(&shard.state);
            Engine::finish_gather(&mut st)
        }
        .expect("a queued leader to promote");
        promoted.cell.complete(FlightOutcome::Retry);
        for h in handles {
            let resp = h.join().expect("request thread must not die");
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
            assert_eq!(
                resp.get("error").and_then(Json::as_str),
                Some("internal_panic")
            );
            assert!(
                resp.get("detail")
                    .and_then(Json::as_str)
                    .unwrap()
                    .contains("injected fault"),
                "{resp:?}"
            );
            assert!(resp.get("retry_after_ms").and_then(Json::as_f64).unwrap() >= 1.0);
        }
        // the panic is counted once, in the thread that unwound — the
        // co-batched failures report errors without re-counting it
        let stats = engine.stats_json();
        let res = stats.get("resilience").expect("resilience stats section");
        assert_eq!(res.get("panics_caught").and_then(Json::as_f64), Some(1.0));
        // the fault cap is spent: the same requests now compute correctly
        for (i, &id) in ids.iter().enumerate() {
            let resp = engine.handle(Request::CriticalPath {
                target: Target::Handle(id),
                slack: false,
                deadline_ms: None,
            });
            assert_eq!(
                resp.get("length").and_then(Json::as_f64),
                Some(expected[i]),
                "request {i} must recover after the fault"
            );
        }
    }

    #[test]
    fn expired_queue_cells_are_purged_before_the_drain() {
        let engine = Engine::with_defaults();
        let mut interned = Vec::new();
        for seed in 0..3u64 {
            let (_plat, inst) = small_instance(5600 + seed);
            interned.push(
                engine
                    .resolve(
                        Target::Inline {
                            instance: inst,
                            platform: None,
                        },
                        &mut RequestTrace::disabled(),
                    )
                    .expect("inline resolve"),
            );
        }
        let shard = interned[0].shard.clone();
        // stage two parked cells: one already expired, one live
        let deadlines = [Some(Instant::now()), None];
        let mut cells = Vec::new();
        {
            let mut st = lock_clean(&shard.state);
            st.collector.active = 1;
            for (i, inst) in interned.iter().enumerate().skip(1) {
                let snap = inst.current();
                let key = Engine::table_key(inst, &snap, false);
                let cell = Arc::new(Inflight::new());
                st.table_inflight.insert(key, cell.clone());
                st.collector.pending.push_back(PendingTable {
                    inst: inst.clone(),
                    snap,
                    delta: None,
                    key,
                    rev: false,
                    origin: TableOrigin::Cp,
                    cell: cell.clone(),
                    queued_at: Instant::now(),
                    timing: Arc::new(BatchTiming::default()),
                    deadline: deadlines[i - 1],
                });
                cells.push(cell);
            }
        }
        let snap0 = interned[0].current();
        let key0 = Engine::table_key(&interned[0], &snap0, false);
        let cell0 = Arc::new(Inflight::new());
        lock_clean(&shard.state)
            .table_inflight
            .insert(key0, cell0.clone());
        let (_table, cached) = engine
            .run_gather(
                &shard,
                PendingTable {
                    inst: interned[0].clone(),
                    snap: snap0,
                    delta: None,
                    key: key0,
                    rev: false,
                    origin: TableOrigin::Cp,
                    cell: cell0,
                    queued_at: Instant::now(),
                    timing: Arc::new(BatchTiming::default()),
                    deadline: None,
                },
                &mut RequestTrace::disabled(),
            )
            .expect("a live leader is served");
        assert!(!cached);
        // the expired cell woke with the retry signal (its owner re-admits
        // into a `Deadline` rejection); the live cell was swept
        match cells[0].wait() {
            FlightOutcome::Retry => {}
            _ => panic!("purged cell must wake with the retry signal"),
        }
        match cells[1].wait() {
            FlightOutcome::Ready(t) => assert_eq!(t.origin, TableOrigin::Cp),
            _ => panic!("live queued cell must be served by the drain"),
        }
        let stats = engine.stats_json();
        let res = stats.get("resilience").expect("resilience stats section");
        assert_eq!(res.get("queue_rejects").and_then(Json::as_f64), Some(1.0));
        // the purge removed the expired key's in-flight entry and the
        // drain removed the others: nothing leaks
        let st = lock_clean(&shard.state);
        assert!(st.table_inflight.is_empty());
        assert!(st.collector.pending.is_empty());
    }

    #[test]
    fn poisoned_locks_recover_and_the_engine_keeps_serving() {
        let engine = Arc::new(Engine::with_defaults());
        let (_plat, inst) = small_instance(5700);
        let line = schedule_line(&inst, "HEFT");
        let (first, _) = engine.handle_line(&line);
        assert_eq!(first.get("ok"), Some(&Json::Bool(true)), "{first:?}");
        // poison both the engine state lock and the shard lock: a thread
        // panics while holding each
        let shard = {
            let st = lock_clean(&engine.state);
            st.shards.values().next().expect("one shard").clone()
        };
        let sh = shard.clone();
        std::thread::spawn(move || {
            let _g = sh.state.lock().unwrap();
            panic!("poison the shard lock");
        })
        .join()
        .unwrap_err();
        let eng = engine.clone();
        std::thread::spawn(move || {
            let _g = eng.state.lock().unwrap();
            panic!("poison the engine state lock");
        })
        .join()
        .unwrap_err();
        assert!(shard.state.lock().is_err(), "the shard mutex is poisoned");
        // every lock site recovers: cached and uncached traffic both serve
        let (hit, _) = engine.handle_line(&line);
        assert_eq!(hit.get("ok"), Some(&Json::Bool(true)), "{hit:?}");
        assert_eq!(hit.get("cached"), Some(&Json::Bool(true)));
        let (_plat, inst2) = small_instance(5800);
        let (miss, _) = engine.handle_line(&schedule_line(&inst2, "HEFT"));
        assert_eq!(miss.get("ok"), Some(&Json::Bool(true)), "{miss:?}");
    }

    #[test]
    fn shutdown_drains_while_racing_requests_and_keeps_state_sound() {
        let engine = Arc::new(Engine::with_defaults());
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let mut handles = Vec::new();
        for seed in 0..3u64 {
            let engine = engine.clone();
            let barrier = barrier.clone();
            let (_plat, inst) = small_instance(5900 + seed);
            let line = schedule_line(&inst, "CEFT-CPOP");
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                let (resp, _) = engine.handle_line(&line);
                resp
            }));
        }
        barrier.wait();
        let (down, is_shutdown) = engine.handle_line(r#"{"op":"shutdown"}"#);
        assert!(is_shutdown, "shutdown flag rides the response");
        assert_eq!(down.get("ok"), Some(&Json::Bool(true)), "{down:?}");
        assert_eq!(down.get("shutting_down"), Some(&Json::Bool(true)));
        assert!(down.get("drained").is_some(), "{down:?}");
        assert!(down.get("in_flight").and_then(Json::as_f64).unwrap() >= 0.0);
        // the drain is passive — it waits, it does not refuse — so racing
        // requests complete with real results
        for h in handles {
            let resp = h.join().unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
            assert!(resp.get("makespan").is_some());
        }
        // and the engine remains consistent afterwards
        let (_plat, inst) = small_instance(5950);
        let (after, _) = engine.handle_line(&schedule_line(&inst, "HEFT"));
        assert_eq!(after.get("ok"), Some(&Json::Bool(true)), "{after:?}");
    }
}
