//! Structural hashing, re-exported from [`crate::util::hashing`].
//!
//! The implementation lives in the `util` substrate layer because the
//! content addresses it produces are consumed below the service too:
//! [`crate::model::PlatformCtx`] stores the interned platform hash and the
//! sweep harness keys its context cache on it. This module preserves the
//! service-side path (`service::hashing::hash_graph` & co.) that the
//! engine and the protocol tests address.

pub use crate::util::hashing::{combine, hash_comp, hash_graph, hash_platform, Fnv64};
