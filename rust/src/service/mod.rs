//! The online scheduling service: a persistent, memoizing CEFT engine
//! behind a newline-delimited JSON protocol.
//!
//! The batch harness ([`crate::exp`]) answers "run this grid of instances
//! once"; this layer answers "keep answering scheduling questions forever".
//! A long-lived [`engine::Engine`] accepts streams of requests — submit an
//! instance, find its CEFT critical path, schedule it with any registry
//! algorithm, inspect or evict the caches — over stdin/stdout or TCP
//! (`repro serve`), or embedded in-process (see
//! `examples/online_service.rs`).
//!
//! Layers:
//!
//! * [`hashing`] — structural FNV-1a hashes of graphs, platforms and cost
//!   matrices; the content addresses everything downstream.
//! * [`cache`] — a bounded LRU keyed by
//!   `(graph-hash, platform-hash, comp-hash, algorithm)` with hit/miss
//!   accounting.
//! * [`protocol`] — request/response codec over [`crate::util::json`].
//! * [`engine`] — interning + memoization + dispatch through the unified
//!   [`crate::sched::Algorithm`] registry, batched across
//!   [`crate::util::pool`] workers; stdio and TCP serving loops. Platforms
//!   intern as shared [`crate::model::PlatformCtx`] execution contexts, so
//!   the CEFT kernel's `P × P` communication panels are computed once per
//!   distinct platform (the stats endpoint's `panel_cache` section) and
//!   scratch arenas pool per platform shape. The memo caches are sharded
//!   per platform context (no global lock on the hit path), and
//!   same-platform critical-path misses gather into one multi-instance
//!   min-plus sweep (the `batched_requests` / `batch_width` counters).
//!   Every request is traced through the [`crate::obs`] stage taxonomy
//!   (`parse` → … → `respond`); the `trace` op returns per-stage latency
//!   histograms plus the slowest/most-recent request breakdowns, the
//!   `metrics` op (and `repro serve --metrics-addr`) serves a
//!   Prometheus-style text exposition, and `stats` carries per-stage
//!   percentiles. `CEFT_TELEMETRY=off` (or
//!   `EngineConfig::telemetry = Some(false)`) turns every hook into a
//!   branch-predictable no-op.
//!
//! Determinism contract: every algorithm in the registry breaks ties
//! deterministically, and the JSON codec round-trips `f64` bit-exactly, so
//! a repeated request returns a byte-identical response body (modulo the
//! `cached` flag) whether it was recomputed or served from cache. The
//! service tests assert this, and the memoization correctness depends on
//! it.
//!
//! Resilience: failure is a first-class input. Requests carry optional
//! `deadline_ms` budgets the engine checks at cache probe, queue admission
//! and pre-kernel (expired work is refused with a structured
//! `deadline_exceeded` + `retry_after_ms`, and expired queue cells are
//! purged before each gathered drain); a per-shard admission governor fed
//! by the [`crate::obs`] recorder's `queue_wait` p99 sheds over-budget
//! *misses* with hysteresis (cache hits are always served); every request
//! runs under `catch_unwind` with poison-recovering locks, so a panicking
//! kernel resolves its co-batched followers with errors and the engine
//! keeps serving; and [`fault`] is a seeded, zero-cost-when-off
//! fault-injection plan (`CEFT_FAULT` / `repro serve --fault-plan`) that
//! makes every one of those recovery paths deterministically testable.
//! Counters surface in the `resilience` stats section and the
//! `ceft_resilience_*` Prometheus series.

pub mod cache;
pub mod engine;
pub mod fault;
pub mod hashing;
pub mod protocol;

pub use cache::{CacheKey, CacheStats, LruCache};
pub use engine::{serve_stdio, Engine, EngineConfig, Server};
pub use fault::FaultPlan;
pub use protocol::{parse_request, request_to_json, Request, Target, PROTOCOL_VERSION};
