//! Log-linear latency histograms (HDR-style) with lock-free recording.
//!
//! The recorder needs a fixed-footprint structure that many threads can
//! write concurrently without coordination and that still yields tight
//! percentiles across nine orders of magnitude (tens of nanoseconds for a
//! cache probe up to minutes for a pathological gathered sweep). The
//! classic answer is a log-linear bucket grid: each power-of-two octave is
//! split into [`SUBBUCKETS`] linear sub-buckets, so the relative
//! quantization error is bounded by `1/SUBBUCKETS` (6.25%) everywhere
//! while the whole grid is only [`BUCKETS`] counters (~5 KiB).
//!
//! Bucket scheme (values are nanoseconds, `S = SUBBUCKETS = 16`):
//!
//! * `v < S` — one bucket per value (exact).
//! * `S <= v < 2^MAX_OCTAVE` — with `k = floor(log2 v)`, the bucket is
//!   `S + (k - 4)*S + ((v >> (k - 4)) - S)`: octave `k` holds 16 linear
//!   sub-buckets of width `2^(k-4)`.
//! * `v >= 2^MAX_OCTAVE` (≈ 73 minutes) — a single overflow bucket.
//!
//! Recording is a relaxed `fetch_add` on one counter plus relaxed
//! `sum`/`min`/`max` updates — no locks, no allocation, wait-free on
//! x86/ARM. Reading takes an inconsistent-but-complete snapshot (counters
//! may lag each other by in-flight records; each individual counter is
//! exact), which is the standard and documented trade for a wait-free
//! write path.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Linear sub-buckets per power-of-two octave (16 → ≤6.25% quantization).
pub const SUBBUCKETS: usize = 16;

const SUB_BITS: u32 = 4; // log2(SUBBUCKETS)

/// Highest precisely-bucketed octave: values at or above `2^MAX_OCTAVE`
/// nanoseconds (~73 min) land in the single overflow bucket.
const MAX_OCTAVE: u32 = 42;

/// Total bucket count: 16 exact small-value buckets, 38 octaves × 16
/// sub-buckets, plus the overflow bucket.
pub const BUCKETS: usize = SUBBUCKETS + (MAX_OCTAVE - SUB_BITS) as usize * SUBBUCKETS + 1;

/// Map a nanosecond value to its bucket index.
#[inline]
pub fn bucket_index(ns: u64) -> usize {
    if ns < SUBBUCKETS as u64 {
        return ns as usize;
    }
    let k = 63 - ns.leading_zeros();
    if k >= MAX_OCTAVE {
        return BUCKETS - 1;
    }
    let sub = (ns >> (k - SUB_BITS)) as usize & (SUBBUCKETS - 1);
    SUBBUCKETS + (k - SUB_BITS) as usize * SUBBUCKETS + sub
}

/// Lower bound (inclusive) of a bucket — the value percentiles report.
///
/// Exact inverse of [`bucket_index`] on bucket floors:
/// `bucket_index(bucket_floor(i)) == i` for every valid `i`.
#[inline]
pub fn bucket_floor(idx: usize) -> u64 {
    if idx < SUBBUCKETS {
        return idx as u64;
    }
    if idx >= BUCKETS - 1 {
        return 1u64 << MAX_OCTAVE;
    }
    let rel = idx - SUBBUCKETS;
    let k = (rel / SUBBUCKETS) as u32 + SUB_BITS;
    let sub = (rel % SUBBUCKETS) as u64;
    (1u64 << k) + (sub << (k - SUB_BITS))
}

/// Concurrent log-linear histogram. All methods take `&self`; recording is
/// wait-free (relaxed atomics only).
pub struct Hist {
    counts: Vec<AtomicU64>,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Hist {
    /// Empty histogram.
    pub fn new() -> Self {
        Self {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one observation (nanoseconds).
    #[inline]
    pub fn record(&self, ns: u64) {
        self.counts[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(ns, Ordering::Relaxed);
        self.min.fetch_min(ns, Ordering::Relaxed);
        self.max.fetch_max(ns, Ordering::Relaxed);
    }

    /// Copy the current contents into an owned snapshot.
    ///
    /// Concurrent recorders may land between individual counter reads, so
    /// a snapshot taken mid-traffic can be "torn" across buckets by the
    /// handful of in-flight records; every counter value itself is exact
    /// and monotone, and a quiescent snapshot is exact.
    pub fn snapshot(&self) -> HistSnapshot {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let count = counts.iter().sum();
        HistSnapshot {
            counts,
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

/// Owned, mergeable copy of a [`Hist`] with percentile extraction.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    counts: Vec<u64>,
    /// Total number of observations.
    pub count: u64,
    /// Sum of all observed values (ns).
    pub sum: u64,
    /// Smallest observation (`u64::MAX` when empty).
    pub min: u64,
    /// Largest observation (0 when empty).
    pub max: u64,
}

impl HistSnapshot {
    /// Snapshot with no observations.
    pub fn empty() -> Self {
        Self {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Merge another snapshot into this one. Merging is commutative and
    /// associative (bucket-wise addition), which the unit tests assert.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// q-th percentile (`0 < q <= 100`) as a bucket lower bound.
    ///
    /// The reported value is exact for observations below [`SUBBUCKETS`] ns
    /// and for exact powers of two; otherwise it underestimates the true
    /// order statistic by at most `1/SUBBUCKETS` (6.25%) relative.
    /// `q = 100` returns the exact tracked maximum. Empty histograms
    /// report 0.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        if q >= 100.0 {
            return self.max;
        }
        let rank = ((q / 100.0 * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_floor(i);
            }
        }
        self.max
    }

    /// Median (bucket floor).
    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    /// 95th percentile (bucket floor).
    pub fn p95(&self) -> u64 {
        self.percentile(95.0)
    }

    /// 99th percentile (bucket floor).
    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    /// Arithmetic mean in nanoseconds (0.0 when empty) — exact, computed
    /// from the tracked sum rather than bucket floors.
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Render the summary used by the `trace`/`stats` endpoints:
    /// `{count, p50_us, p95_us, p99_us, max_us, mean_us}` (microseconds).
    pub fn to_json(&self) -> Json {
        let us = |ns: u64| Json::Num(ns as f64 / 1e3);
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("p50_us", us(self.p50())),
            ("p95_us", us(self.p95())),
            ("p99_us", us(self.p99())),
            ("max_us", us(if self.count == 0 { 0 } else { self.max })),
            ("mean_us", Json::Num(self.mean_ns() / 1e3)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_bucket_exactly() {
        for v in 0..SUBBUCKETS as u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_floor(v as usize), v);
        }
    }

    #[test]
    fn bucket_boundaries_roundtrip() {
        // every bucket floor maps back to its own bucket, and the last
        // value before the next floor still maps to the same bucket
        for idx in 0..BUCKETS - 1 {
            let floor = bucket_floor(idx);
            assert_eq!(bucket_index(floor), idx, "floor of bucket {idx}");
            let next = bucket_floor(idx + 1);
            assert_eq!(bucket_index(next - 1), idx, "ceiling of bucket {idx}");
        }
    }

    #[test]
    fn octave_boundaries() {
        assert_eq!(bucket_index(15), 15);
        assert_eq!(bucket_index(16), 16);
        assert_eq!(bucket_index(31), 31);
        assert_eq!(bucket_index(32), 32);
        assert_eq!(bucket_index(63), 47);
        assert_eq!(bucket_index(64), 48);
    }

    #[test]
    fn overflow_bucket() {
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_index(1u64 << MAX_OCTAVE), BUCKETS - 1);
        assert_eq!(bucket_index((1u64 << MAX_OCTAVE) - 1), BUCKETS - 2);
    }

    #[test]
    fn relative_error_bounded() {
        let mut v: u64 = 1;
        while v < 1u64 << 41 {
            for off in [0u64, 1, v / 3, v / 2, v - 1] {
                let x = v + off;
                let floor = bucket_floor(bucket_index(x));
                assert!(floor <= x, "floor {floor} above value {x}");
                let err = (x - floor) as f64 / x as f64;
                assert!(err <= 1.0 / SUBBUCKETS as f64 + 1e-12, "error {err} at {x}");
            }
            v <<= 1;
        }
    }

    #[test]
    fn exact_percentiles_for_small_values() {
        let h = Hist::new();
        for v in 1..=10u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 10);
        assert_eq!(s.percentile(50.0), 5);
        assert_eq!(s.percentile(10.0), 1);
        assert_eq!(s.percentile(95.0), 10);
        assert_eq!(s.percentile(100.0), 10);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 10);
        assert!((s.mean_ns() - 5.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_of_powers_of_two_is_exact() {
        let h = Hist::new();
        for k in 0..20u32 {
            h.record(1u64 << k);
        }
        let s = h.snapshot();
        // rank ceil(0.5*20) = 10 → the 10th smallest = 2^9
        assert_eq!(s.percentile(50.0), 1 << 9);
        assert_eq!(s.percentile(100.0), 1 << 19);
    }

    #[test]
    fn empty_snapshot_reports_zero() {
        let s = Hist::new().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.percentile(50.0), 0);
        assert_eq!(s.percentile(100.0), 0);
        assert_eq!(s.mean_ns(), 0.0);
    }

    #[test]
    fn merge_matches_combined_recording() {
        let a = Hist::new();
        let b = Hist::new();
        let whole = Hist::new();
        for i in 0..1000u64 {
            let v = i * 37 % 100_000;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            whole.record(v);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged, whole.snapshot());
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mk = |seed: u64, n: u64| {
            let h = Hist::new();
            for i in 0..n {
                h.record(seed.wrapping_mul(i).wrapping_add(i * i) % 1_000_000);
            }
            h.snapshot()
        };
        let (a, b, c) = (mk(17, 100), mk(5231, 57), mk(999, 211));
        // (a ⊕ b) ⊕ c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a ⊕ (b ⊕ c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right);
        // b ⊕ a == a ⊕ b
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
    }

    #[test]
    fn snapshot_json_has_summary_fields() {
        let h = Hist::new();
        h.record(1500);
        let j = h.snapshot().to_json();
        assert_eq!(j.get("count").and_then(Json::as_f64), Some(1.0));
        assert!(j.get("p50_us").is_some());
        assert!(j.get("max_us").is_some());
    }
}
