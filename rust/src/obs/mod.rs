//! Zero-dependency telemetry: request-lifecycle tracing, stage-latency
//! histograms, and kernel throughput attribution.
//!
//! The service engine (`crate::service::engine`) needs to answer "where
//! does a request's time go?" before any batching or scheduling knob can
//! be tuned — the `batched_requests` / `batch_width` counters say *that*
//! cross-request gathering happens, not whether the queueing it introduces
//! is paid back by the sweep. This module is the measurement layer:
//!
//! * [`hist`] — log-linear HDR-style histograms (wait-free recording,
//!   ≤6.25% relative quantization, exact p50/p95/p99/max extraction).
//! * [`recorder`] — per-thread lock-free sinks behind a [`Recorder`];
//!   each request carries a stack-local [`RequestTrace`] that spans are
//!   charged to and that publishes on completion.
//! * [`Stage`] — the fixed eight-stage request-lifecycle taxonomy.
//! * kernel-path counters ([`kernel_timer`] / [`kernel_snapshot`]) —
//!   process-wide cells/s attribution per min-plus dispatch path.
//!
//! # Stage taxonomy
//!
//! | stage         | meaning                                                       |
//! |---------------|---------------------------------------------------------------|
//! | `parse`       | request line → [`crate::service::Request`]                    |
//! | `intern`      | structural hashing + instance/graph interning (submit path)   |
//! | `ctx_build`   | building a new [`crate::model::PlatformCtx`] (comm panels)    |
//! | `cache_probe` | shard lock + LRU probe + single-flight admission, including a |
//! |               | follower's park time behind an in-flight leader               |
//! | `queue_wait`  | time parked in the [`BatchCollector`] pending queue before a  |
//! |               | gathered sweep drained the request                            |
//! | `batch_drain` | the gathered multi-instance sweep the request was served by   |
//! | `kernel`      | a single-instance DP / scheduler compute (ungathered miss)    |
//! | `respond`     | response JSON construction                                    |
//! | `edit_apply`  | applying an `update` request's edit sequence: graph/cost      |
//! |               | rebuild, dirty-set derivation, generation bump + cache purge  |
//!
//! [`BatchCollector`]: crate::service::engine
//!
//! Invariant (asserted by the engine tests and the loadgen validator):
//! `queue_wait` and `batch_drain` are recorded **only** for requests served
//! through a width ≥ 2 gathered sweep, i.e. they are nonzero iff the
//! `batched_requests` counter is. A promoted gather leader that parked but
//! then computed its own sweep charges the park to `cache_probe`.
//!
//! # Runtime toggle
//!
//! `CEFT_TELEMETRY=off|0|false` disables the process-default switch read
//! by [`enabled`]; engines built with `telemetry: None` inherit it, and
//! the kernel-path counters consult it per dispatch. Disabled hooks cost
//! one relaxed load + predictable branch — no clock reads, no atomic RMW
//! (the loadgen A/B in `BENCH_service.json` tracks the measured overhead).

pub mod hist;
pub mod recorder;

pub use hist::{Hist, HistSnapshot};
pub use recorder::{Recorder, RequestTrace, StageSpan, TelemetrySnapshot, TraceRecord};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Number of lifecycle stages in the fixed taxonomy.
pub const NUM_STAGES: usize = 9;

/// Request-lifecycle stage (see the module docs for the taxonomy table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Stage {
    /// Request line → parsed `Request`.
    Parse = 0,
    /// Structural hashing + interning on the submit path.
    Intern = 1,
    /// Building a new platform execution context (comm panels).
    CtxBuild = 2,
    /// Shard lock + LRU probe + single-flight admission/park.
    CacheProbe = 3,
    /// Parked in the batch collector before a gathered sweep drained us.
    QueueWait = 4,
    /// The gathered multi-instance sweep this request was served by.
    BatchDrain = 5,
    /// Single-instance DP / scheduler compute on an ungathered miss.
    Kernel = 6,
    /// Response JSON construction.
    Respond = 7,
    /// Applying an `update` request's edit sequence (graph/cost rebuild,
    /// dirty-set derivation, generation bump + stale-cache purge).
    EditApply = 8,
}

impl Stage {
    /// All stages in taxonomy order (histogram index order).
    pub const ALL: [Stage; NUM_STAGES] = [
        Stage::Parse,
        Stage::Intern,
        Stage::CtxBuild,
        Stage::CacheProbe,
        Stage::QueueWait,
        Stage::BatchDrain,
        Stage::Kernel,
        Stage::Respond,
        Stage::EditApply,
    ];

    /// Histogram index of this stage.
    #[inline]
    pub fn idx(self) -> usize {
        self as usize
    }

    /// Wire/display name (snake_case, stable — part of the protocol).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::Intern => "intern",
            Stage::CtxBuild => "ctx_build",
            Stage::CacheProbe => "cache_probe",
            Stage::QueueWait => "queue_wait",
            Stage::BatchDrain => "batch_drain",
            Stage::Kernel => "kernel",
            Stage::Respond => "respond",
            Stage::EditApply => "edit_apply",
        }
    }
}

fn env_default() -> bool {
    match std::env::var("CEFT_TELEMETRY") {
        Ok(v) => !matches!(v.as_str(), "off" | "0" | "false"),
        Err(_) => true,
    }
}

fn flag() -> &'static AtomicBool {
    static FLAG: OnceLock<AtomicBool> = OnceLock::new();
    FLAG.get_or_init(|| AtomicBool::new(env_default()))
}

/// Process-default telemetry switch: `true` unless `CEFT_TELEMETRY` is
/// `off`/`0`/`false` (or [`set_enabled`] overrode it). Engines consult it
/// when their config leaves telemetry unset; kernel-path counters consult
/// it on every dispatch.
pub fn enabled() -> bool {
    flag().load(Ordering::Relaxed)
}

/// Override the process-default switch (used by the loadgen A/B overhead
/// measurement and the `telemetry_overhead` bench rows).
pub fn set_enabled(on: bool) {
    flag().store(on, Ordering::Relaxed)
}

/// Number of min-plus dispatch paths attributed separately.
pub const NUM_KERNEL_PATHS: usize = 5;

/// Which min-plus implementation served a DP sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum KernelPath {
    /// Fused per-instance kernel with scalar lanes (`CEFT_FORCE_SCALAR`).
    Scalar = 0,
    /// Fused per-instance kernel with 4-wide SIMD lanes.
    Simd = 1,
    /// Blocked matrix-batched kernel (`ceft_table_batched`).
    Batched = 2,
    /// Cross-request gathered multi-instance sweep.
    Gathered = 3,
    /// Series-parallel tree DP over a recognized shape (`cp::ceft::sp`).
    SpTree = 4,
}

impl KernelPath {
    /// All paths in counter-index order.
    pub const ALL: [KernelPath; NUM_KERNEL_PATHS] = [
        KernelPath::Scalar,
        KernelPath::Simd,
        KernelPath::Batched,
        KernelPath::Gathered,
        KernelPath::SpTree,
    ];

    /// Wire/display name.
    pub fn name(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Simd => "simd",
            KernelPath::Batched => "batched",
            KernelPath::Gathered => "gathered",
            KernelPath::SpTree => "sp_tree",
        }
    }
}

struct PathCell {
    calls: AtomicU64,
    cells: AtomicU64,
    nanos: AtomicU64,
}

impl PathCell {
    const fn new() -> Self {
        Self {
            calls: AtomicU64::new(0),
            cells: AtomicU64::new(0),
            nanos: AtomicU64::new(0),
        }
    }
}

static KERNEL_PATHS: [PathCell; NUM_KERNEL_PATHS] = [
    PathCell::new(),
    PathCell::new(),
    PathCell::new(),
    PathCell::new(),
    PathCell::new(),
];

/// RAII guard from [`kernel_timer`]; records on drop. Bind it to a named
/// `_timer` variable — `let _ = ...` drops immediately.
#[must_use = "the kernel span is measured from creation to drop"]
pub struct KernelTimer {
    armed: Option<(KernelPath, u64, Instant)>,
}

impl Drop for KernelTimer {
    fn drop(&mut self) {
        if let Some((path, cells, t0)) = self.armed.take() {
            let cell = &KERNEL_PATHS[path as usize];
            cell.calls.fetch_add(1, Ordering::Relaxed);
            cell.cells.fetch_add(cells, Ordering::Relaxed);
            cell.nanos
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }
}

/// Time one DP sweep on `path` covering `cells` min-plus cells
/// (edges × P²). No-op (no clock read) when telemetry is [`enabled`]-off.
#[inline]
pub fn kernel_timer(path: KernelPath, cells: u64) -> KernelTimer {
    KernelTimer {
        armed: if enabled() {
            Some((path, cells, Instant::now()))
        } else {
            None
        },
    }
}

/// Accumulated totals for one dispatch path.
#[derive(Clone, Copy, Debug, Default)]
pub struct KernelPathSnapshot {
    /// DP sweeps served by this path.
    pub calls: u64,
    /// Min-plus cells processed (edges × P², summed over instances).
    pub cells: u64,
    /// Total nanoseconds inside the kernel on this path.
    pub nanos: u64,
}

impl KernelPathSnapshot {
    /// Throughput in min-plus cells per second (0.0 when unused).
    pub fn cells_per_s(&self) -> f64 {
        if self.nanos == 0 {
            0.0
        } else {
            self.cells as f64 / (self.nanos as f64 / 1e9)
        }
    }
}

/// Read the process-wide kernel-path counters, indexed like
/// [`KernelPath::ALL`].
pub fn kernel_snapshot() -> [KernelPathSnapshot; NUM_KERNEL_PATHS] {
    std::array::from_fn(|i| KernelPathSnapshot {
        calls: KERNEL_PATHS[i].calls.load(Ordering::Relaxed),
        cells: KERNEL_PATHS[i].cells.load(Ordering::Relaxed),
        nanos: KERNEL_PATHS[i].nanos.load(Ordering::Relaxed),
    })
}

/// Zero the kernel-path counters (bench isolation; counters are
/// process-global, so concurrent engines share them).
pub fn kernel_reset() {
    for cell in &KERNEL_PATHS {
        cell.calls.store(0, Ordering::Relaxed);
        cell.cells.store(0, Ordering::Relaxed);
        cell.nanos.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_are_stable_and_indexed() {
        let names: Vec<&str> = Stage::ALL.iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            vec![
                "parse",
                "intern",
                "ctx_build",
                "cache_probe",
                "queue_wait",
                "batch_drain",
                "kernel",
                "respond",
                "edit_apply"
            ]
        );
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(s.idx(), i);
        }
    }

    // the process-default flag is shared by every test in this binary, so
    // tests that toggle it serialize here and restore it before releasing
    static FLAG_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn kernel_timer_attributes_cells() {
        let _g = FLAG_LOCK.lock().unwrap();
        let prev = enabled();
        set_enabled(true);
        let before = kernel_snapshot()[KernelPath::Batched as usize];
        {
            let timer = kernel_timer(KernelPath::Batched, 12_345);
            assert!(timer.armed.is_some());
        }
        let after = kernel_snapshot()[KernelPath::Batched as usize];
        set_enabled(prev);
        // other tests may record concurrently, hence >= on the deltas
        assert!(after.calls >= before.calls + 1);
        assert!(after.cells >= before.cells + 12_345);
        assert!(after.cells_per_s() >= 0.0);
    }

    #[test]
    fn disabled_timer_is_disarmed_at_creation() {
        let _g = FLAG_LOCK.lock().unwrap();
        let prev = enabled();
        set_enabled(false);
        let timer = kernel_timer(KernelPath::Scalar, 999);
        set_enabled(prev);
        // armed-ness is latched at creation; drop will record nothing
        assert!(timer.armed.is_none());
    }
}
