//! Request-lifecycle recorder: per-thread sinks, seqlock trace rings, and
//! the [`RequestTrace`] handle the engine threads stage spans through.
//!
//! Design constraints (see `EXPERIMENTS.md` §Telemetry):
//!
//! * **No locks on the hot path.** A request's stage durations accumulate
//!   in a plain stack-local [`RequestTrace`]; only [`RequestTrace::finish`]
//!   touches shared state, and that state is a [`ThreadSink`] owned
//!   exclusively by the current thread — histogram buckets are relaxed
//!   atomics, ring/slow slots are seqlock-versioned so concurrent snapshot
//!   readers detect torn reads instead of blocking the writer.
//! * **No `SystemTime`.** All timing is monotonic [`Instant`]; records
//!   carry a global sequence number for "most recent" ordering instead of
//!   wall-clock timestamps.
//! * **Bounded memory under thread churn.** The engine's request pool
//!   spawns fresh scoped threads per batch, so sinks are *leased*: a
//!   thread-local cache holds a lease per recorder, and when the thread
//!   exits the lease returns the sink to the recorder's free list for the
//!   next thread. Live sinks are therefore bounded by the peak number of
//!   concurrent threads, not by thread-creation count.
//! * **Disabled means no-op.** A disabled recorder hands out traces with
//!   no sink; every method on them is a branch on one `Option` — no
//!   `Instant::now()`, no atomics.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError, Weak};
use std::time::Instant;

use super::hist::{Hist, HistSnapshot};
use super::{Stage, NUM_STAGES};

/// Completed traces retained per sink before wraparound.
pub const RING_CAP: usize = 64;

/// Slowest-request slots retained per sink (survive ring wraparound).
pub const SLOW_SLOTS: usize = 8;

/// Upper bound on traces returned by [`TelemetrySnapshot::slowest`] /
/// `recent` regardless of sink count.
pub const SNAPSHOT_TRACES: usize = 32;

// seq, op, total_ns + one duration per stage
const TRACE_WORDS: usize = 3 + NUM_STAGES;

static NEXT_RECORDER_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_SEQ: AtomicU64 = AtomicU64::new(1);

/// One completed request: which op it was, end-to-end duration, and the
/// per-stage breakdown (stages that did not occur are 0).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceRecord {
    /// Global completion sequence number (monotone across all recorders).
    pub seq: u64,
    /// Protocol op code (see `service::protocol::op_name`).
    pub op: u8,
    /// End-to-end handling time in nanoseconds.
    pub total_ns: u64,
    /// Nanoseconds attributed to each [`Stage`], indexed by `Stage::idx`.
    pub stages: [u64; NUM_STAGES],
}

/// Seqlock-versioned slot: the owning thread is the only writer; snapshot
/// readers retry-free detect torn reads via the version word. All payload
/// words are atomics, so concurrent access is race-free by construction.
struct Slot {
    version: AtomicU64,
    words: [AtomicU64; TRACE_WORDS],
}

impl Slot {
    fn new() -> Self {
        Self {
            version: AtomicU64::new(0),
            words: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Owner-only write: version goes odd, payload lands, version goes even.
    fn write(&self, rec: &TraceRecord) {
        let v = self.version.load(Ordering::Relaxed);
        self.version.store(v + 1, Ordering::Release);
        self.words[0].store(rec.seq, Ordering::Relaxed);
        self.words[1].store(rec.op as u64, Ordering::Relaxed);
        self.words[2].store(rec.total_ns, Ordering::Relaxed);
        for (w, &d) in self.words[3..].iter().zip(rec.stages.iter()) {
            w.store(d, Ordering::Relaxed);
        }
        self.version.store(v + 2, Ordering::Release);
    }

    /// Best-effort read: `None` for never-written, mid-write, or torn slots.
    fn read(&self) -> Option<TraceRecord> {
        let v1 = self.version.load(Ordering::Acquire);
        if v1 == 0 || v1 % 2 == 1 {
            return None;
        }
        let seq = self.words[0].load(Ordering::Relaxed);
        let op = self.words[1].load(Ordering::Relaxed) as u8;
        let total_ns = self.words[2].load(Ordering::Relaxed);
        let mut stages = [0u64; NUM_STAGES];
        for (d, w) in stages.iter_mut().zip(self.words[3..].iter()) {
            *d = w.load(Ordering::Relaxed);
        }
        if self.version.load(Ordering::Acquire) != v1 {
            return None;
        }
        Some(TraceRecord {
            seq,
            op,
            total_ns,
            stages,
        })
    }
}

/// Per-thread recording sink: one histogram per stage, a ring of recent
/// traces, and a small slowest-N log that survives ring wraparound.
/// Exactly one thread holds a lease on a sink at a time (writes are
/// owner-only); snapshots read concurrently through the atomics.
pub struct ThreadSink {
    stages: [Hist; NUM_STAGES],
    ring: Vec<Slot>,
    cursor: AtomicU64,
    slow: Vec<Slot>,
    slow_len: AtomicU64,
    slow_min: AtomicU64,
}

impl ThreadSink {
    fn new() -> Self {
        Self {
            stages: std::array::from_fn(|_| Hist::new()),
            ring: (0..RING_CAP).map(|_| Slot::new()).collect(),
            cursor: AtomicU64::new(0),
            slow: (0..SLOW_SLOTS).map(|_| Slot::new()).collect(),
            slow_len: AtomicU64::new(0),
            slow_min: AtomicU64::new(0),
        }
    }

    /// Owner-only: fold a finished trace into the histograms and logs.
    /// `occurred` is a bitmask of stages that actually ran — a stage that
    /// ran in 0 ns still counts (the zero bucket), which is what lets the
    /// `trace` endpoint distinguish "never happened" from "instant".
    fn record(&self, rec: &TraceRecord, occurred: u16) {
        for (i, h) in self.stages.iter().enumerate() {
            if occurred & (1 << i) != 0 {
                h.record(rec.stages[i]);
            }
        }
        let idx = self.cursor.fetch_add(1, Ordering::Relaxed) as usize % RING_CAP;
        self.ring[idx].write(rec);
        self.offer_slow(rec);
    }

    fn offer_slow(&self, rec: &TraceRecord) {
        let len = self.slow_len.load(Ordering::Relaxed) as usize;
        if len < SLOW_SLOTS {
            self.slow[len].write(rec);
            self.slow_len.store((len + 1) as u64, Ordering::Relaxed);
            if len + 1 == SLOW_SLOTS {
                let m = self.slow_totals().into_iter().min().unwrap_or(0);
                self.slow_min.store(m, Ordering::Relaxed);
            }
            return;
        }
        // fast reject: the common case once the log is warm
        if rec.total_ns <= self.slow_min.load(Ordering::Relaxed) {
            return;
        }
        let mut totals = self.slow_totals();
        let mut min_i = 0;
        for (i, &t) in totals.iter().enumerate().skip(1) {
            if t < totals[min_i] {
                min_i = i;
            }
        }
        if rec.total_ns <= totals[min_i] {
            return;
        }
        self.slow[min_i].write(rec);
        totals[min_i] = rec.total_ns;
        let m = totals.into_iter().min().unwrap_or(0);
        self.slow_min.store(m, Ordering::Relaxed);
    }

    fn slow_totals(&self) -> Vec<u64> {
        self.slow
            .iter()
            .map(|s| s.words[2].load(Ordering::Relaxed))
            .collect()
    }

    fn collect(&self, out: &mut Vec<TraceRecord>) {
        for slot in self.ring.iter().chain(self.slow.iter()) {
            if let Some(rec) = slot.read() {
                out.push(rec);
            }
        }
    }
}

struct RegistryState {
    all: Vec<Arc<ThreadSink>>,
    free: Vec<Arc<ThreadSink>>,
}

type Registry = Mutex<RegistryState>;

/// Lock the registry, recovering from poison: the state is a pair of
/// `Vec<Arc<_>>` pushes, so a thread that panicked mid-lock left nothing
/// half-updated worth discarding — and telemetry must never take the
/// engine down with it.
fn lock_registry(reg: &Registry) -> MutexGuard<'_, RegistryState> {
    reg.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Thread-local lease on a sink. Dropping it (thread exit or cache
/// eviction) returns the sink to the recorder's free list so the next
/// fresh thread reuses it instead of growing the registry.
struct SinkLease {
    sink: Arc<ThreadSink>,
    registry: Weak<Registry>,
}

impl Drop for SinkLease {
    fn drop(&mut self) {
        if let Some(reg) = self.registry.upgrade() {
            lock_registry(&reg).free.push(self.sink.clone());
        }
    }
}

thread_local! {
    static SINK_CACHE: RefCell<Vec<(u64, SinkLease)>> = const { RefCell::new(Vec::new()) };
}

const SINK_CACHE_CAP: usize = 16;

/// Factory and registry for request traces. One per [`Engine`]
/// (`crate::service::engine::Engine`); cheap to construct. A disabled
/// recorder hands out no-op traces and registers no sinks.
pub struct Recorder {
    id: u64,
    enabled: bool,
    registry: Arc<Registry>,
}

impl Recorder {
    /// New recorder; `enabled = false` makes every trace a no-op.
    pub fn new(enabled: bool) -> Self {
        Self {
            id: NEXT_RECORDER_ID.fetch_add(1, Ordering::Relaxed),
            enabled,
            registry: Arc::new(Mutex::new(RegistryState {
                all: Vec::new(),
                free: Vec::new(),
            })),
        }
    }

    /// New recorder honouring the process-wide `CEFT_TELEMETRY` toggle.
    pub fn from_env() -> Self {
        Self::new(super::enabled())
    }

    /// Whether traces from this recorder record anything.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The calling thread's sink for this recorder: thread-local cache
    /// hit in the common case; on miss, lease one from the free list or
    /// register a fresh sink.
    fn sink(&self) -> Arc<ThreadSink> {
        SINK_CACHE.with(|c| {
            let mut cache = c.borrow_mut();
            if let Some((_, lease)) = cache.iter().find(|(id, _)| *id == self.id) {
                return lease.sink.clone();
            }
            let sink = {
                let mut st = lock_registry(&self.registry);
                st.free.pop().unwrap_or_else(|| {
                    let s = Arc::new(ThreadSink::new());
                    st.all.push(s.clone());
                    s
                })
            };
            if cache.len() >= SINK_CACHE_CAP {
                // evict the oldest lease (returns its sink to that
                // recorder's free list via Drop)
                cache.remove(0);
            }
            cache.push((
                self.id,
                SinkLease {
                    sink: sink.clone(),
                    registry: Arc::downgrade(&self.registry),
                },
            ));
            sink
        })
    }

    /// Start tracing one request. `op` is the protocol op code; update it
    /// with [`RequestTrace::set_op`] once parsing identifies the request.
    pub fn begin(&self, op: u8) -> RequestTrace {
        if !self.enabled {
            return RequestTrace::disabled();
        }
        RequestTrace {
            sink: Some(self.sink()),
            t0: Some(Instant::now()),
            op,
            durs: [0; NUM_STAGES],
            occurred: 0,
        }
    }

    /// Merge every sink into one snapshot: per-stage histograms plus the
    /// slowest / most recent completed traces (deduplicated across the
    /// ring and slow logs by sequence number).
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let sinks: Vec<Arc<ThreadSink>> = lock_registry(&self.registry).all.clone();
        let mut stages: Vec<HistSnapshot> = (0..NUM_STAGES).map(|_| HistSnapshot::empty()).collect();
        let mut records: Vec<TraceRecord> = Vec::new();
        for sink in &sinks {
            for (acc, h) in stages.iter_mut().zip(sink.stages.iter()) {
                acc.merge(&h.snapshot());
            }
            sink.collect(&mut records);
        }
        records.sort_by_key(|r| r.seq);
        records.dedup_by_key(|r| r.seq);
        let mut slowest = records.clone();
        slowest.sort_by(|a, b| b.total_ns.cmp(&a.total_ns).then(a.seq.cmp(&b.seq)));
        slowest.truncate(SNAPSHOT_TRACES);
        let mut recent = records;
        recent.sort_by(|a, b| b.seq.cmp(&a.seq));
        recent.truncate(SNAPSHOT_TRACES);
        TelemetrySnapshot {
            stages,
            slowest,
            recent,
        }
    }
}

/// Merged view over all of a recorder's sinks at one point in time.
pub struct TelemetrySnapshot {
    /// One histogram per [`Stage`], indexed by `Stage::idx`.
    pub stages: Vec<HistSnapshot>,
    /// Completed traces, slowest first (bounded by [`SNAPSHOT_TRACES`]).
    pub slowest: Vec<TraceRecord>,
    /// Completed traces, most recent first (bounded by [`SNAPSHOT_TRACES`]).
    pub recent: Vec<TraceRecord>,
}

/// Per-request stage accumulator. Stack-local and lock-free: stages add
/// into a plain array; [`finish`](Self::finish) publishes to the thread's
/// sink. When the recorder is disabled every method is a no-op and no
/// clock is read.
pub struct RequestTrace {
    sink: Option<Arc<ThreadSink>>,
    t0: Option<Instant>,
    op: u8,
    durs: [u64; NUM_STAGES],
    occurred: u16,
}

impl RequestTrace {
    /// A trace that records nothing (what disabled recorders hand out).
    pub fn disabled() -> Self {
        Self {
            sink: None,
            t0: None,
            op: 0,
            durs: [0; NUM_STAGES],
            occurred: 0,
        }
    }

    /// Whether this trace records anything.
    pub fn is_enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Re-label the op once parsing identifies the request.
    pub fn set_op(&mut self, op: u8) {
        self.op = op;
    }

    /// `Some(Instant::now())` when enabled — the gate callers use for
    /// manual timing so disabled traces never read the clock.
    pub fn clock(&self) -> Option<Instant> {
        if self.sink.is_some() {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Attribute `ns` nanoseconds to `stage` (marks the stage as having
    /// occurred even when `ns == 0`).
    pub fn add(&mut self, stage: Stage, ns: u64) {
        if self.sink.is_none() {
            return;
        }
        self.durs[stage.idx()] += ns;
        self.occurred |= 1 << stage.idx();
    }

    /// RAII span: time from now until drop is attributed to `stage`.
    pub fn span(&mut self, stage: Stage) -> StageSpan<'_> {
        let start = self.clock();
        StageSpan {
            trace: self,
            stage,
            start,
        }
    }

    /// Nanoseconds attributed to `stage` so far (test/assertion hook).
    pub fn stage_ns(&self, stage: Stage) -> u64 {
        self.durs[stage.idx()]
    }

    /// Publish the completed trace to the thread's sink.
    pub fn finish(self) {
        let (Some(sink), Some(t0)) = (self.sink.as_ref(), self.t0) else {
            return;
        };
        let rec = TraceRecord {
            seq: NEXT_SEQ.fetch_add(1, Ordering::Relaxed),
            op: self.op,
            total_ns: t0.elapsed().as_nanos() as u64,
            stages: self.durs,
        };
        sink.record(&rec, self.occurred);
    }
}

/// RAII guard from [`RequestTrace::span`].
pub struct StageSpan<'a> {
    trace: &'a mut RequestTrace,
    stage: Stage,
    start: Option<Instant>,
}

impl Drop for StageSpan<'_> {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            let ns = start.elapsed().as_nanos() as u64;
            self.trace.add(self.stage, ns);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_is_inert() {
        let rec = Recorder::new(false);
        let mut t = rec.begin(0);
        assert!(!t.is_enabled());
        assert!(t.clock().is_none());
        t.add(Stage::Kernel, 123);
        {
            let _s = t.span(Stage::Parse);
        }
        t.finish();
        let snap = rec.snapshot();
        assert_eq!(snap.stages[Stage::Kernel.idx()].count, 0);
        assert!(snap.slowest.is_empty());
    }

    #[test]
    fn spans_and_adds_accumulate() {
        let rec = Recorder::new(true);
        let mut t = rec.begin(2);
        t.add(Stage::QueueWait, 1000);
        t.add(Stage::QueueWait, 500);
        {
            let _s = t.span(Stage::Kernel);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(t.stage_ns(Stage::QueueWait), 1500);
        assert!(t.stage_ns(Stage::Kernel) >= 1_000_000);
        t.finish();
        let snap = rec.snapshot();
        assert_eq!(snap.stages[Stage::QueueWait.idx()].count, 1);
        assert_eq!(snap.stages[Stage::Kernel.idx()].count, 1);
        assert_eq!(snap.stages[Stage::Parse.idx()].count, 0);
        assert_eq!(snap.slowest.len(), 1);
        assert_eq!(snap.slowest[0].op, 2);
        assert_eq!(snap.slowest[0].stages[Stage::QueueWait.idx()], 1500);
    }

    #[test]
    fn zero_duration_stage_still_counts() {
        let rec = Recorder::new(true);
        let mut t = rec.begin(0);
        t.add(Stage::BatchDrain, 0);
        t.finish();
        let snap = rec.snapshot();
        assert_eq!(snap.stages[Stage::BatchDrain.idx()].count, 1);
        assert_eq!(snap.stages[Stage::QueueWait.idx()].count, 0);
    }

    #[test]
    fn ring_wraparound_conserves_histogram_totals() {
        let rec = Recorder::new(true);
        let n = (RING_CAP * 3) as u64;
        for i in 0..n {
            let mut t = rec.begin(1);
            t.add(Stage::Parse, i);
            t.finish();
        }
        let snap = rec.snapshot();
        // histograms never drop records even though the ring wrapped
        assert_eq!(snap.stages[Stage::Parse.idx()].count, n);
        let expected: u64 = (0..n).sum();
        assert_eq!(snap.stages[Stage::Parse.idx()].sum, expected);
        // the trace logs are bounded
        assert!(snap.slowest.len() <= SNAPSHOT_TRACES);
        assert!(snap.recent.len() <= SNAPSHOT_TRACES);
    }

    #[test]
    fn slow_log_keeps_the_largest_totals() {
        let rec = Recorder::new(true);
        // traces with strictly increasing synthetic stage time; total_ns
        // is wall-clock so drive ordering through a recorded stage instead
        for i in 0..(RING_CAP as u64 + 40) {
            let mut t = rec.begin(3);
            t.add(Stage::Kernel, i * 1000);
            t.finish();
        }
        let snap = rec.snapshot();
        // the slowest list is sorted non-increasing by total time
        for w in snap.slowest.windows(2) {
            assert!(w[0].total_ns >= w[1].total_ns);
        }
        // recent is sorted by recency
        for w in snap.recent.windows(2) {
            assert!(w[0].seq > w[1].seq);
        }
    }

    #[test]
    fn sinks_are_reused_across_thread_generations() {
        let rec = Arc::new(Recorder::new(true));
        for _ in 0..8 {
            let r = rec.clone();
            std::thread::spawn(move || {
                let mut t = r.begin(1);
                t.add(Stage::Parse, 1);
                t.finish();
            })
            .join()
            .unwrap();
        }
        // sequential threads lease the same sink from the free list
        let n = rec.registry.lock().unwrap().all.len();
        assert_eq!(n, 1, "expected one pooled sink, got {n}");
        let snap = rec.snapshot();
        assert_eq!(snap.stages[Stage::Parse.idx()].count, 8);
    }

    #[test]
    fn distinct_recorders_do_not_share_sinks() {
        let a = Recorder::new(true);
        let b = Recorder::new(true);
        let mut t = a.begin(1);
        t.add(Stage::Parse, 7);
        t.finish();
        let mut t = b.begin(1);
        t.add(Stage::Parse, 9);
        t.finish();
        assert_eq!(a.snapshot().stages[Stage::Parse.idx()].sum, 7);
        assert_eq!(b.snapshot().stages[Stage::Parse.idx()].sum, 9);
    }
}
