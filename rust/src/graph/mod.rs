//! Task graphs: weighted DAGs of computational tasks.
//!
//! Vertices are tasks; a directed edge `(u, v, data)` means task `v` consumes
//! `data` units of output from task `u` and cannot start before `u` finishes
//! (plus communication time when they run on different processors).
//!
//! [`TaskGraph`] stores both successor and predecessor adjacency in CSR form
//! and a cached topological order, since every algorithm in [`crate::cp`] and
//! [`crate::sched`] is a sweep in (reverse) topological order.

pub mod edit;
pub mod generator;
pub mod io;
pub mod realworld;
pub mod shape;

pub use generator::{generate, generate_fork_join, generate_pipeline};

/// A directed edge with a data volume (communication payload).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    /// producing task
    pub src: usize,
    /// consuming task
    pub dst: usize,
    /// units of data transferred from `src` to `dst`
    pub data: f64,
}

/// An immutable task DAG with CSR adjacency and a cached topological order.
#[derive(Clone, Debug)]
pub struct TaskGraph {
    n: usize,
    edges: Vec<Edge>,
    succ_off: Vec<usize>,
    succ: Vec<(usize, f64)>,
    pred_off: Vec<usize>,
    pred: Vec<(usize, f64)>,
    topo: Vec<usize>,
}

impl TaskGraph {
    /// Build from an edge list over `n` tasks. Panics if the edge list
    /// contains out-of-range vertices or a cycle (this is a programming
    /// error in a generator, not a runtime condition).
    pub fn from_edges(n: usize, edges: &[(usize, usize, f64)]) -> Self {
        Self::try_from_edges(n, edges).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible variant of [`TaskGraph::from_edges`] for untrusted input
    /// (e.g. instances arriving over the service protocol): returns an error
    /// instead of panicking on out-of-range vertices, self loops, negative
    /// data weights, or cycles.
    pub fn try_from_edges(n: usize, edges: &[(usize, usize, f64)]) -> Result<Self, String> {
        let mut checked: Vec<Edge> = Vec::with_capacity(edges.len());
        for &(src, dst, data) in edges {
            if src >= n || dst >= n {
                return Err(format!("edge ({src},{dst}) out of range n={n}"));
            }
            if src == dst {
                return Err(format!("self loop at {src}"));
            }
            if !(data >= 0.0) {
                return Err(format!("negative data on edge ({src},{dst})"));
            }
            if !data.is_finite() {
                return Err(format!("non-finite data on edge ({src},{dst})"));
            }
            checked.push(Edge { src, dst, data });
        }
        Self::from_edge_structs(n, checked)
    }

    fn from_edge_structs(n: usize, edges: Vec<Edge>) -> Result<Self, String> {
        // CSR for successors
        let mut succ_off = vec![0usize; n + 1];
        for e in &edges {
            succ_off[e.src + 1] += 1;
        }
        for i in 0..n {
            succ_off[i + 1] += succ_off[i];
        }
        let mut succ = vec![(0usize, 0f64); edges.len()];
        let mut cursor = succ_off.clone();
        for e in &edges {
            succ[cursor[e.src]] = (e.dst, e.data);
            cursor[e.src] += 1;
        }
        // CSR for predecessors
        let mut pred_off = vec![0usize; n + 1];
        for e in &edges {
            pred_off[e.dst + 1] += 1;
        }
        for i in 0..n {
            pred_off[i + 1] += pred_off[i];
        }
        let mut pred = vec![(0usize, 0f64); edges.len()];
        let mut cursor = pred_off.clone();
        for e in &edges {
            pred[cursor[e.dst]] = (e.src, e.data);
            cursor[e.dst] += 1;
        }
        // Kahn topological sort (also detects cycles)
        let mut indeg: Vec<usize> = (0..n).map(|v| pred_off[v + 1] - pred_off[v]).collect();
        let mut queue: std::collections::VecDeque<usize> =
            (0..n).filter(|&v| indeg[v] == 0).collect();
        let mut topo = Vec::with_capacity(n);
        while let Some(v) = queue.pop_front() {
            topo.push(v);
            for &(s, _) in &succ[succ_off[v]..succ_off[v + 1]] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push_back(s);
                }
            }
        }
        if topo.len() != n {
            return Err("graph contains a cycle".to_string());
        }
        Ok(Self {
            n,
            edges,
            succ_off,
            succ,
            pred_off,
            pred,
            topo,
        })
    }

    /// Number of tasks.
    pub fn num_tasks(&self) -> usize {
        self.n
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// All edges.
    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// Successors of `t` as `(task, data)` pairs.
    pub fn succs(&self, t: usize) -> &[(usize, f64)] {
        &self.succ[self.succ_off[t]..self.succ_off[t + 1]]
    }

    /// Predecessors (parents) of `t` as `(task, data)` pairs.
    pub fn preds(&self, t: usize) -> &[(usize, f64)] {
        &self.pred[self.pred_off[t]..self.pred_off[t + 1]]
    }

    /// Out-degree of `t`.
    pub fn out_degree(&self, t: usize) -> usize {
        self.succ_off[t + 1] - self.succ_off[t]
    }

    /// In-degree of `t`.
    pub fn in_degree(&self, t: usize) -> usize {
        self.pred_off[t + 1] - self.pred_off[t]
    }

    /// A topological order of all tasks (cached).
    pub fn topo_order(&self) -> &[usize] {
        &self.topo
    }

    /// Tasks with no predecessors (entry/source tasks).
    pub fn sources(&self) -> Vec<usize> {
        (0..self.n).filter(|&t| self.in_degree(t) == 0).collect()
    }

    /// Tasks with no successors (exit/sink tasks).
    pub fn sinks(&self) -> Vec<usize> {
        (0..self.n).filter(|&t| self.out_degree(t) == 0).collect()
    }

    /// The transposed DAG (all edges reversed). Used by the CEFT upward
    /// ranking function (§8.2 of the paper).
    pub fn transpose(&self) -> TaskGraph {
        let edges: Vec<Edge> = self
            .edges
            .iter()
            .map(|e| Edge {
                src: e.dst,
                dst: e.src,
                data: e.data,
            })
            .collect();
        Self::from_edge_structs(self.n, edges)
            .expect("transposing an acyclic graph cannot fail")
    }

    /// Level (longest hop-distance from any source) of each task.
    /// Level 0 = sources. Useful for wavefront/batched processing.
    pub fn levels(&self) -> Vec<usize> {
        let mut level = vec![0usize; self.n];
        for &t in &self.topo {
            for &(k, _) in self.preds(t) {
                level[t] = level[t].max(level[k] + 1);
            }
        }
        level
    }

    /// Width parameter β of the graph: the maximum number of tasks on any
    /// level (the moving-frontier bound from the paper's space-complexity
    /// argument, §5).
    pub fn width(&self) -> usize {
        let levels = self.levels();
        let max_level = levels.iter().copied().max().unwrap_or(0);
        let mut counts = vec![0usize; max_level + 1];
        for &l in &levels {
            counts[l] += 1;
        }
        counts.into_iter().max().unwrap_or(0)
    }

    /// Longest path length counting node weights `w` and edge weights from
    /// `edge_w(src, dst, data)`. The classical (homogeneous) critical-path
    /// primitive that CEFT generalizes.
    pub fn longest_path<EW: Fn(usize, usize, f64) -> f64>(
        &self,
        node_w: &[f64],
        edge_w: EW,
    ) -> f64 {
        assert_eq!(node_w.len(), self.n);
        let mut dist = vec![0f64; self.n];
        let mut best: f64 = 0.0;
        for &t in &self.topo {
            let mut d: f64 = 0.0;
            for &(k, data) in self.preds(t) {
                d = d.max(dist[k] + edge_w(k, t, data));
            }
            dist[t] = d + node_w[t];
            best = best.max(dist[t]);
        }
        best
    }

    /// Check structural sanity of a generated graph: connected-ish (every
    /// non-source has a parent, every non-sink has a child is trivially true)
    /// — here we verify single-entry/single-exit when `strict` is set, and
    /// that all data weights are non-negative and finite.
    pub fn validate(&self, strict_single_entry_exit: bool) -> Result<(), String> {
        for e in &self.edges {
            if !e.data.is_finite() || e.data < 0.0 {
                return Err(format!("bad data weight on edge {}->{}", e.src, e.dst));
            }
        }
        if strict_single_entry_exit {
            let s = self.sources();
            let t = self.sinks();
            if s.len() != 1 {
                return Err(format!("expected single entry, got {}", s.len()));
            }
            if t.len() != 1 {
                return Err(format!("expected single exit, got {}", t.len()));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> TaskGraph {
        TaskGraph::from_edges(
            4,
            &[(0, 1, 5.0), (0, 2, 6.0), (1, 3, 7.0), (2, 3, 8.0)],
        )
    }

    #[test]
    fn adjacency_is_consistent() {
        let g = diamond();
        assert_eq!(g.num_tasks(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.succs(0).len(), 2);
        assert_eq!(g.preds(3).len(), 2);
        assert_eq!(g.preds(0).len(), 0);
        assert_eq!(g.succs(3).len(), 0);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.in_degree(3), 2);
        // data payloads preserved
        assert!(g.preds(3).iter().any(|&(k, d)| k == 1 && d == 7.0));
        assert!(g.preds(3).iter().any(|&(k, d)| k == 2 && d == 8.0));
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = diamond();
        let pos: Vec<usize> = {
            let mut p = vec![0; 4];
            for (i, &t) in g.topo_order().iter().enumerate() {
                p[t] = i;
            }
            p
        };
        for e in g.edges() {
            assert!(pos[e.src] < pos[e.dst]);
        }
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_detected() {
        TaskGraph::from_edges(2, &[(0, 1, 1.0), (1, 0, 1.0)]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_edge_panics() {
        TaskGraph::from_edges(2, &[(0, 5, 1.0)]);
    }

    #[test]
    fn sources_and_sinks() {
        let g = diamond();
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![3]);
    }

    #[test]
    fn transpose_swaps_roles() {
        let g = diamond().transpose();
        assert_eq!(g.sources(), vec![3]);
        assert_eq!(g.sinks(), vec![0]);
        assert_eq!(g.preds(0).len(), 2);
    }

    #[test]
    fn levels_and_width() {
        let g = diamond();
        assert_eq!(g.levels(), vec![0, 1, 1, 2]);
        assert_eq!(g.width(), 2);
    }

    #[test]
    fn longest_path_homogeneous() {
        let g = diamond();
        // node weights 1, edge weight = data
        let lp = g.longest_path(&[1.0, 1.0, 1.0, 1.0], |_, _, d| d);
        // 0 ->(6) 2 ->(8) 3 : 1 + 6 + 1 + 8 + 1 = 17
        assert_eq!(lp, 17.0);
    }

    #[test]
    fn longest_path_ignores_edges_when_zeroed() {
        let g = diamond();
        let lp = g.longest_path(&[1.0, 2.0, 3.0, 4.0], |_, _, _| 0.0);
        assert_eq!(lp, 1.0 + 3.0 + 4.0);
    }

    #[test]
    fn validate_flags_multi_exit() {
        let g = TaskGraph::from_edges(3, &[(0, 1, 1.0), (0, 2, 1.0)]);
        assert!(g.validate(false).is_ok());
        assert!(g.validate(true).is_err());
    }

    #[test]
    fn try_from_edges_reports_errors_without_panicking() {
        assert!(TaskGraph::try_from_edges(2, &[(0, 1, 1.0)]).is_ok());
        let cyc = TaskGraph::try_from_edges(2, &[(0, 1, 1.0), (1, 0, 1.0)]);
        assert!(cyc.unwrap_err().contains("cycle"));
        let oob = TaskGraph::try_from_edges(2, &[(0, 5, 1.0)]);
        assert!(oob.unwrap_err().contains("out of range"));
        let neg = TaskGraph::try_from_edges(2, &[(0, 1, -1.0)]);
        assert!(neg.unwrap_err().contains("negative data"));
        let selfloop = TaskGraph::try_from_edges(2, &[(1, 1, 1.0)]);
        assert!(selfloop.unwrap_err().contains("self loop"));
    }

    #[test]
    fn empty_graph_ok() {
        let g = TaskGraph::from_edges(1, &[]);
        assert_eq!(g.topo_order(), &[0]);
        assert_eq!(g.width(), 1);
    }
}
