//! Real-world application task graphs (§7.2 of the paper).
//!
//! Four families, all generated from their published structure:
//!
//! * [`gaussian_elimination`] — GE(m): `(m² + m − 2)/2` tasks (Wu & Gajski;
//!   Cosnard et al.).
//! * [`fft`] — FFT(m) for a power-of-two input vector: `2m − 1` recursive
//!   call tasks + `m·log₂m` butterfly tasks (Topcuoglu et al.).
//! * [`molecular_dynamics`] — the fixed 41-task irregular graph modified by
//!   Kim & Browne.
//! * [`epigenomics`] — the Pegasus epigenomics workflow EW(g): a split into
//!   `g` parallel 4-stage lanes, then merge / filter / map tail.
//!
//! Each builder returns only the *structure* (edges with unit data); use
//! [`weighted_instance`] to attach paper-style weights (base task weights
//! `w_i`, CCR-scaled edge volumes, and a [`CostModel`] execution matrix).

use super::generator::Instance;
use super::TaskGraph;
use crate::platform::{CostModel, Platform};
use crate::util::rng::Xoshiro256;

/// Structure of a real-world DAG: `n` tasks and unit-data edges.
#[derive(Clone, Debug)]
pub struct Skeleton {
    /// number of tasks
    pub n: usize,
    /// edges (src, dst)
    pub edges: Vec<(usize, usize)>,
    /// human-readable family name
    pub name: &'static str,
}

/// Gaussian elimination on an `m × m` matrix.
///
/// Step `k` (1-based, `k = 1..m-1`) has one pivot task followed by `m − k`
/// update tasks. Pivot k feeds all its update tasks; update task `(k, j)`
/// feeds pivot `k+1` when `j = k+1` and update `(k+1, j)` otherwise.
/// Total tasks: `Σ_{k=1}^{m-1} (1 + m − k) = (m² + m − 2)/2`.
pub fn gaussian_elimination(m: usize) -> Skeleton {
    assert!(m >= 2, "GE needs m >= 2");
    // id layout: step k starts at offset(k); pivot first, then updates j=k+1..=m
    let offset = |k: usize| -> usize {
        // sum over s=1..k-1 of (1 + m - s)
        (k - 1) * (m + 1) - (k * (k - 1)) / 2
    };
    let pivot = |k: usize| offset(k);
    let update = |k: usize, j: usize| offset(k) + 1 + (j - k - 1);
    let n = offset(m); // == (m^2 + m - 2) / 2
    debug_assert_eq!(n, (m * m + m - 2) / 2);
    let mut edges = Vec::new();
    for k in 1..m {
        for j in k + 1..=m {
            edges.push((pivot(k), update(k, j)));
        }
        if k + 1 < m {
            // update (k, k+1) -> pivot k+1 ; update (k, j) -> update (k+1, j)
            edges.push((update(k, k + 1), pivot(k + 1)));
            for j in k + 2..=m {
                edges.push((update(k, k + 1), update(k + 1, j)));
                edges.push((update(k, j), update(k + 1, j)));
            }
        }
    }
    Skeleton {
        n,
        edges,
        name: "GE",
    }
}

/// Fast Fourier Transform over an input vector of size `m` (power of two).
///
/// Recursive-call part: a binary tree with `2m − 1` nodes rooted at task 0,
/// leaves at the bottom. Butterfly part: `log₂m` levels of `m` tasks; level
/// `ℓ` task `i` feeds level `ℓ+1` tasks `i` and `i XOR 2^ℓ`. Tree leaves
/// feed butterfly level 0 one-to-one. The `m` final butterfly tasks are the
/// exit frontier (the paper notes every root-to-exit path is critical).
pub fn fft(m: usize) -> Skeleton {
    assert!(m >= 2 && m.is_power_of_two(), "FFT needs power-of-two m >= 2");
    let log_m = m.trailing_zeros() as usize;
    let tree = 2 * m - 1;
    let n = tree + m * log_m;
    let mut edges = Vec::new();
    // binary tree (heap layout): node i -> children 2i+1, 2i+2 for i < m-1
    for i in 0..m - 1 {
        edges.push((i, 2 * i + 1));
        edges.push((i, 2 * i + 2));
    }
    // leaves are ids m-1 .. 2m-2; butterfly level l starts at tree + l*m
    let bfly = |l: usize, i: usize| tree + l * m + i;
    if log_m > 0 {
        for i in 0..m {
            edges.push((m - 1 + i, bfly(0, i)));
        }
        for l in 0..log_m - 1 {
            for i in 0..m {
                edges.push((bfly(l, i), bfly(l + 1, i)));
                edges.push((bfly(l, i), bfly(l + 1, i ^ (1 << l))));
            }
        }
    }
    Skeleton {
        n,
        edges,
        name: "FFT",
    }
}

/// The modified molecular-dynamics task graph of Kim & Browne — a fixed
/// 41-task irregular DAG (redrawn from the paper's Figure 4). Multiple
/// entry tasks, one exit; irregular fan-in/fan-out, the classic stress test
/// for list schedulers.
pub fn molecular_dynamics() -> Skeleton {
    // Adjacency transcribed from the published figure: 41 tasks in 11
    // irregular levels.
    let edges: Vec<(usize, usize)> = vec![
        // level 0: entries 0,1,2,3
        (0, 4),
        (0, 5),
        (1, 5),
        (1, 6),
        (2, 6),
        (2, 7),
        (3, 7),
        (3, 8),
        // level 1 -> 2
        (4, 9),
        (4, 10),
        (5, 10),
        (5, 11),
        (6, 11),
        (6, 12),
        (7, 12),
        (7, 13),
        (8, 13),
        (8, 14),
        // level 2 -> 3 (fan-in pocket)
        (9, 15),
        (10, 15),
        (10, 16),
        (11, 16),
        (11, 17),
        (12, 17),
        (12, 18),
        (13, 18),
        (14, 18),
        (14, 19),
        // level 3 -> 4
        (15, 20),
        (15, 21),
        (16, 21),
        (16, 22),
        (17, 22),
        (18, 23),
        (19, 23),
        (19, 24),
        // level 4 -> 5
        (20, 25),
        (21, 25),
        (21, 26),
        (22, 26),
        (22, 27),
        (23, 27),
        (23, 28),
        (24, 28),
        // level 5 -> 6 (irregular skips)
        (25, 29),
        (26, 29),
        (26, 30),
        (27, 30),
        (28, 31),
        (20, 31), // long skip edge
        // level 6 -> 7
        (29, 32),
        (29, 33),
        (30, 33),
        (30, 34),
        (31, 34),
        (31, 35),
        // level 7 -> 8
        (32, 36),
        (33, 36),
        (33, 37),
        (34, 37),
        (35, 38),
        // level 8 -> 9
        (36, 39),
        (37, 39),
        (38, 39),
        (25, 38), // another skip
        // level 9 -> exit
        (39, 40),
        (35, 40), // skip into exit
    ];
    Skeleton {
        n: 41,
        edges,
        name: "MD",
    }
}

/// Epigenomics workflow EW(g): fastq split feeding `g` independent 4-stage
/// lanes (filterContams → sol2sanger → fastq2bfq → map), merged and followed
/// by the 3-stage tail (mapMerge → maqIndex → pileup). Wider than it is
/// tall, with a compact parallel structure (§7.2.4).
pub fn epigenomics(g: usize) -> Skeleton {
    assert!(g >= 1);
    let n = 1 + 4 * g + 3;
    let mut edges = Vec::new();
    let lane = |i: usize, stage: usize| 1 + i * 4 + stage;
    let merge = 1 + 4 * g;
    for i in 0..g {
        edges.push((0, lane(i, 0)));
        for s in 0..3 {
            edges.push((lane(i, s), lane(i, s + 1)));
        }
        edges.push((lane(i, 3), merge));
    }
    edges.push((merge, merge + 1));
    edges.push((merge + 1, merge + 2));
    Skeleton {
        n,
        edges,
        name: "EW",
    }
}

/// Attach weights to a skeleton, paper-style: task base weights
/// `w_i ~ U(0, 2·w_DAG)`, edge volumes `U(w_i·c·(1∓β/2))`, and an execution
/// matrix from `model`. This is how §7.2 builds the "classic" and "medium"
/// variants of the real-world benchmarks.
pub fn weighted_instance(
    skel: &Skeleton,
    ccr: f64,
    beta_pct: f64,
    model: &CostModel,
    platform: &Platform,
    seed: u64,
) -> Instance {
    let mut rng = Xoshiro256::new(seed);
    let beta = beta_pct / 100.0;
    let w_dag = rng.uniform(50.0, 150.0);
    let w: Vec<f64> = (0..skel.n)
        .map(|_| rng.uniform(0.0, 2.0 * w_dag).max(1e-3))
        .collect();
    let (comp, scalar) = model.generate(&w, platform, &mut rng);
    let edges: Vec<(usize, usize, f64)> = skel
        .edges
        .iter()
        .map(|&(s, d)| {
            let lo = scalar[s] * ccr * (1.0 - beta / 2.0);
            let hi = scalar[s] * ccr * (1.0 + beta / 2.0);
            let data = if hi > lo { rng.uniform(lo, hi) } else { lo };
            (s, d, data.max(0.0))
        })
        .collect();
    Instance {
        graph: TaskGraph::from_edges(skel.n, &edges),
        comp: crate::model::CostMatrix::new(platform.num_classes(), comp),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ge_task_count_matches_formula() {
        for m in 2..=12 {
            let s = gaussian_elimination(m);
            assert_eq!(s.n, (m * m + m - 2) / 2, "m={m}");
            let g = TaskGraph::from_edges(s.n, &unit(&s.edges));
            assert_eq!(g.sources().len(), 1);
            assert_eq!(g.sinks().len(), 1);
        }
    }

    #[test]
    fn ge5_has_14_tasks_like_paper_figure() {
        let s = gaussian_elimination(5);
        assert_eq!(s.n, 14);
    }

    #[test]
    fn fft_task_count_matches_formula() {
        for &m in &[2usize, 4, 8, 16, 32] {
            let log_m = m.trailing_zeros() as usize;
            let s = fft(m);
            assert_eq!(s.n, 2 * m - 1 + m * log_m, "m={m}");
            let g = TaskGraph::from_edges(s.n, &unit(&s.edges));
            assert_eq!(g.sources().len(), 1, "single root");
            // exit frontier: the m final butterfly tasks
            assert_eq!(g.sinks().len(), m, "m={m}");
        }
    }

    #[test]
    fn fft_all_paths_equal_length() {
        // the paper notes every root-to-exit path in FFT has the same hops
        let s = fft(8);
        let g = TaskGraph::from_edges(s.n, &unit(&s.edges));
        let levels = g.levels();
        let sink_levels: std::collections::HashSet<usize> =
            g.sinks().iter().map(|&t| levels[t]).collect();
        assert_eq!(sink_levels.len(), 1);
    }

    #[test]
    fn md_is_valid_dag_with_41_tasks() {
        let s = molecular_dynamics();
        assert_eq!(s.n, 41);
        let g = TaskGraph::from_edges(s.n, &unit(&s.edges));
        assert!(g.sources().len() > 1, "MD has multiple entries");
        assert_eq!(g.sinks(), vec![40]);
        // every task is reachable / co-reachable (no isolated tasks)
        for t in 0..41 {
            assert!(
                g.in_degree(t) + g.out_degree(t) > 0,
                "task {t} isolated"
            );
        }
    }

    #[test]
    fn ew_structure() {
        let s = epigenomics(6);
        assert_eq!(s.n, 1 + 24 + 3);
        let g = TaskGraph::from_edges(s.n, &unit(&s.edges));
        assert_eq!(g.sources().len(), 1);
        assert_eq!(g.sinks().len(), 1);
        // wider than tall: width g, height 8
        assert_eq!(g.width(), 6);
    }

    #[test]
    fn weighted_instance_attaches_costs() {
        let s = gaussian_elimination(6);
        let plat = Platform::uniform(4, 1.0, 0.0);
        let inst = weighted_instance(&s, 1.0, 50.0, &CostModel::Classic { beta: 0.5 }, &plat, 3);
        assert_eq!(inst.comp.len(), s.n * 4);
        assert_eq!(inst.graph.num_edges(), s.edges.len());
        assert!(inst.comp.iter().all(|&c| c > 0.0));
    }

    fn unit(edges: &[(usize, usize)]) -> Vec<(usize, usize, f64)> {
        edges.iter().map(|&(s, d)| (s, d, 1.0)).collect()
    }
}
