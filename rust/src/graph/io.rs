//! Graph / instance / platform / schedule serialization: JSON interchange
//! and Graphviz DOT export.
//!
//! Everything round-trips bit-exactly: the JSON writer emits shortest
//! round-tripping decimal for `f64`, so `x_from_json(x_to_json(v)) == v`
//! down to the float bits. The service layer (`crate::service`) relies on
//! this for its memoization keys and its repeat-request determinism
//! guarantee.

use super::generator::Instance;
use super::TaskGraph;
use crate::platform::Platform;
use crate::sched::{Assignment, Schedule};
use crate::util::json::Json;
use std::fmt::Write as _;

/// Upper bound on task counts accepted from untrusted JSON (guards the
/// service against allocation bombs; far above anything the paper sweeps).
pub const MAX_TASKS: usize = 10_000_000;
/// Upper bound on processor-class counts accepted from untrusted JSON.
pub const MAX_CLASSES: usize = 4096;

/// Serialize an instance (structure + data volumes + cost matrix) to JSON.
pub fn instance_to_json(inst: &Instance) -> Json {
    let edges = inst
        .graph
        .edges()
        .iter()
        .map(|e| {
            Json::Arr(vec![
                Json::Num(e.src as f64),
                Json::Num(e.dst as f64),
                Json::Num(e.data),
            ])
        })
        .collect();
    Json::obj(vec![
        ("n", Json::Num(inst.graph.num_tasks() as f64)),
        ("p", Json::Num(inst.p() as f64)),
        ("edges", Json::Arr(edges)),
        (
            "comp",
            Json::Arr(inst.comp.iter().map(|&c| Json::Num(c)).collect()),
        ),
    ])
}

/// Parse an instance back from [`instance_to_json`] output.
pub fn instance_from_json(j: &Json) -> Result<Instance, String> {
    let n = j
        .get("n")
        .and_then(Json::as_usize)
        .ok_or("missing n")?;
    let p = j
        .get("p")
        .and_then(Json::as_usize)
        .ok_or("missing p")?;
    if n == 0 || n > MAX_TASKS {
        return Err(format!("n = {n} out of range [1, {MAX_TASKS}]"));
    }
    if p == 0 || p > MAX_CLASSES {
        return Err(format!("p = {p} out of range [1, {MAX_CLASSES}]"));
    }
    let edges: Vec<(usize, usize, f64)> = j
        .get("edges")
        .and_then(Json::as_arr)
        .ok_or("missing edges")?
        .iter()
        .map(|e| {
            let a = e.as_arr().ok_or("edge not an array")?;
            if a.len() != 3 {
                return Err(format!("edge has {} fields, expected 3", a.len()));
            }
            Ok((
                a[0].as_usize().ok_or("bad src")?,
                a[1].as_usize().ok_or("bad dst")?,
                a[2].as_f64().ok_or("bad data")?,
            ))
        })
        .collect::<Result<_, String>>()?;
    let comp: Vec<f64> = j
        .get("comp")
        .and_then(Json::as_arr)
        .ok_or("missing comp")?
        .iter()
        .map(|c| c.as_f64().ok_or_else(|| "bad comp".to_string()))
        .collect::<Result<_, String>>()?;
    if comp.len() != n * p {
        return Err(format!("comp has {} entries, expected {}", comp.len(), n * p));
    }
    if let Some(i) = comp.iter().position(|c| !c.is_finite() || *c < 0.0) {
        return Err(format!(
            "comp[{i}] = {} must be finite and >= 0 (non-finite costs would poison every downstream result)",
            comp[i]
        ));
    }
    // the thin raw-slice shim at the JSON boundary: the wire carries a flat
    // row-major array; everything past this point works on the SoA matrix
    Ok(Instance {
        graph: TaskGraph::try_from_edges(n, &edges)?,
        comp: crate::model::CostMatrix::try_new(p, comp)?,
    })
}

/// Serialize a platform (class count, startup latencies, bandwidth matrix,
/// optional two-weight class capacities) to JSON.
pub fn platform_to_json(plat: &Platform) -> Json {
    let p = plat.num_classes();
    let startup: Vec<Json> = (0..p).map(|j| Json::Num(plat.startup(j))).collect();
    let mut bandwidth = Vec::with_capacity(p * p);
    for a in 0..p {
        for b in 0..p {
            bandwidth.push(Json::Num(plat.bandwidth(a, b)));
        }
    }
    let mut fields = vec![
        ("p", Json::Num(p as f64)),
        ("startup", Json::Arr(startup)),
        ("bandwidth", Json::Arr(bandwidth)),
    ];
    let weights = plat.class_weight_table();
    if !weights.is_empty() {
        fields.push((
            "weights",
            Json::Arr(
                weights
                    .iter()
                    .map(|&(w0, w1)| Json::Arr(vec![Json::Num(w0), Json::Num(w1)]))
                    .collect(),
            ),
        ));
    }
    Json::obj(fields)
}

/// Parse a platform back from [`platform_to_json`] output.
pub fn platform_from_json(j: &Json) -> Result<Platform, String> {
    let p = j.get("p").and_then(Json::as_usize).ok_or("missing p")?;
    if p == 0 || p > MAX_CLASSES {
        return Err(format!("p = {p} out of range [1, {MAX_CLASSES}]"));
    }
    let startup: Vec<f64> = j
        .get("startup")
        .and_then(Json::as_arr)
        .ok_or("missing startup")?
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| "bad startup entry".to_string()))
        .collect::<Result<_, String>>()?;
    let bandwidth: Vec<f64> = j
        .get("bandwidth")
        .and_then(Json::as_arr)
        .ok_or("missing bandwidth")?
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| "bad bandwidth entry".to_string()))
        .collect::<Result<_, String>>()?;
    let weights: Vec<(f64, f64)> = match j.get("weights") {
        None => Vec::new(),
        Some(w) => w
            .as_arr()
            .ok_or("weights must be an array")?
            .iter()
            .map(|pair| {
                let a = pair.as_arr().ok_or("weight entry not an array")?;
                if a.len() != 2 {
                    return Err(format!("weight entry has {} fields, expected 2", a.len()));
                }
                Ok((
                    a[0].as_f64().ok_or("bad weight w0")?,
                    a[1].as_f64().ok_or("bad weight w1")?,
                ))
            })
            .collect::<Result<_, String>>()?,
    };
    Platform::from_parts(p, startup, bandwidth, weights)
}

/// Serialize a schedule (per-task `[proc, start, finish]` triples) to JSON.
pub fn schedule_to_json(s: &Schedule) -> Json {
    Json::obj(vec![
        ("p", Json::Num(s.p as f64)),
        (
            "assignments",
            Json::Arr(
                s.assignments
                    .iter()
                    .map(|a| {
                        Json::Arr(vec![
                            Json::Num(a.proc as f64),
                            Json::Num(a.start),
                            Json::Num(a.finish),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Parse a schedule back from [`schedule_to_json`] output.
pub fn schedule_from_json(j: &Json) -> Result<Schedule, String> {
    let p = j.get("p").and_then(Json::as_usize).ok_or("missing p")?;
    if p == 0 || p > MAX_CLASSES {
        return Err(format!("p = {p} out of range [1, {MAX_CLASSES}]"));
    }
    let assignments: Vec<Assignment> = j
        .get("assignments")
        .and_then(Json::as_arr)
        .ok_or("missing assignments")?
        .iter()
        .map(|a| {
            let t = a.as_arr().ok_or("assignment not an array")?;
            if t.len() != 3 {
                return Err(format!("assignment has {} fields, expected 3", t.len()));
            }
            let proc = t[0].as_usize().ok_or("bad proc")?;
            if proc >= p {
                return Err(format!("proc {proc} out of range p={p}"));
            }
            Ok(Assignment {
                proc,
                start: t[1].as_f64().ok_or("bad start")?,
                finish: t[2].as_f64().ok_or("bad finish")?,
            })
        })
        .collect::<Result<_, String>>()?;
    Ok(Schedule { assignments, p })
}

/// Render a task graph as Graphviz DOT (node label = id, edge label = data).
pub fn to_dot(g: &TaskGraph, highlight: &[usize]) -> String {
    let hi: std::collections::HashSet<usize> = highlight.iter().copied().collect();
    let mut s = String::from("digraph tasks {\n  rankdir=TB;\n");
    for t in 0..g.num_tasks() {
        if hi.contains(&t) {
            let _ = writeln!(
                s,
                "  t{t} [label=\"{t}\", style=filled, fillcolor=gold];"
            );
        } else {
            let _ = writeln!(s, "  t{t} [label=\"{t}\"];");
        }
    }
    for e in g.edges() {
        let _ = writeln!(s, "  t{} -> t{} [label=\"{:.1}\"];", e.src, e.dst, e.data);
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{generate, RggParams};
    use crate::platform::{CostModel, Platform};

    #[test]
    fn json_roundtrip() {
        let plat = Platform::uniform(3, 1.0, 0.0);
        let inst = generate(
            &RggParams {
                n: 32,
                out_degree: 2,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.2,
            },
            &CostModel::Classic { beta: 0.5 },
            &plat,
            99,
        );
        let j = instance_to_json(&inst);
        let text = j.to_string();
        let back = instance_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.graph.num_tasks(), inst.graph.num_tasks());
        assert_eq!(back.graph.num_edges(), inst.graph.num_edges());
        assert_eq!(back.comp, inst.comp);
        assert_eq!(back.p(), inst.p());
    }

    #[test]
    fn dot_contains_nodes_and_highlight() {
        let g = TaskGraph::from_edges(3, &[(0, 1, 2.0), (1, 2, 3.0)]);
        let dot = to_dot(&g, &[1]);
        assert!(dot.contains("t0 -> t1"));
        assert!(dot.contains("fillcolor=gold"));
        assert!(dot.starts_with("digraph"));
    }

    #[test]
    fn from_json_rejects_bad_comp_len() {
        let j = Json::parse(r#"{"n":2,"p":2,"edges":[[0,1,1.0]],"comp":[1,2,3]}"#).unwrap();
        assert!(instance_from_json(&j).is_err());
    }

    #[test]
    fn from_json_rejects_malformed_structure_without_panicking() {
        // cycle
        let j = Json::parse(
            r#"{"n":2,"p":1,"edges":[[0,1,1.0],[1,0,1.0]],"comp":[1,2]}"#,
        )
        .unwrap();
        assert!(instance_from_json(&j).unwrap_err().contains("cycle"));
        // out-of-range vertex
        let j = Json::parse(r#"{"n":2,"p":1,"edges":[[0,9,1.0]],"comp":[1,2]}"#).unwrap();
        assert!(instance_from_json(&j).unwrap_err().contains("out of range"));
        // zero tasks
        let j = Json::parse(r#"{"n":0,"p":1,"edges":[],"comp":[]}"#).unwrap();
        assert!(instance_from_json(&j).is_err());
    }

    #[test]
    fn platform_json_roundtrip_uniform_and_two_weight() {
        let mut rng = crate::util::rng::Xoshiro256::new(12);
        for plat in [
            Platform::uniform(4, 2.0, 0.25),
            Platform::random_links(3, &mut rng, 0.5, 1.5, 0.0, 0.3),
            Platform::two_weight(5, 0.5, &mut rng, 1.0, 0.0),
        ] {
            let text = platform_to_json(&plat).to_string();
            let back = platform_from_json(&Json::parse(&text).unwrap()).unwrap();
            let p = plat.num_classes();
            assert_eq!(back.num_classes(), p);
            for a in 0..p {
                assert_eq!(back.startup(a), plat.startup(a));
                for b in 0..p {
                    assert_eq!(back.bandwidth(a, b), plat.bandwidth(a, b));
                }
            }
            assert_eq!(back.class_weight_table(), plat.class_weight_table());
            // derived comm scalarisation identical -> same schedules downstream
            assert_eq!(back.mean_comm_cost(3.7), plat.mean_comm_cost(3.7));
        }
    }

    #[test]
    fn platform_from_json_rejects_bad_shapes() {
        for bad in [
            r#"{"startup":[0],"bandwidth":[1]}"#,                      // missing p
            r#"{"p":2,"startup":[0],"bandwidth":[1,1,1,1]}"#,          // short startup
            r#"{"p":2,"startup":[0,0],"bandwidth":[1,1,1]}"#,          // short bandwidth
            r#"{"p":2,"startup":[0,0],"bandwidth":[1,0,1,1]}"#,        // zero bandwidth
            r#"{"p":2,"startup":[0,0],"bandwidth":[1,1,1,1],"weights":[[1,2]]}"#, // short weights
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(platform_from_json(&j).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn schedule_json_roundtrip_is_bit_exact() {
        let g = TaskGraph::from_edges(3, &[(0, 1, 2.0), (0, 2, 3.0)]);
        let plat = Platform::uniform(2, 1.0, 0.1);
        let comp = crate::model::CostMatrix::new(2, vec![1.5, 2.5, 3.25, 0.75, 2.0, 4.0]);
        let inst = crate::model::InstanceRef::new(&g, &plat, &comp);
        let s = crate::sched::Algorithm::CeftCpop.schedule(inst);
        let text = schedule_to_json(&s).to_string();
        let back = schedule_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.p, s.p);
        assert_eq!(back.assignments, s.assignments);
        // still a legal schedule after the round trip
        back.validate(inst).unwrap();
    }

    #[test]
    fn schedule_from_json_rejects_bad_entries() {
        let j = Json::parse(r#"{"p":1,"assignments":[[5,0.0,1.0]]}"#).unwrap();
        assert!(schedule_from_json(&j).unwrap_err().contains("out of range"));
        let j = Json::parse(r#"{"p":1,"assignments":[[0,0.0]]}"#).unwrap();
        assert!(schedule_from_json(&j).is_err());
        let j = Json::parse(r#"{"p":0,"assignments":[]}"#).unwrap();
        assert!(schedule_from_json(&j).is_err());
    }
}
