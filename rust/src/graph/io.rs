//! Graph / instance serialization: JSON interchange and Graphviz DOT export.

use super::generator::Instance;
use super::TaskGraph;
use crate::util::json::Json;
use std::fmt::Write as _;

/// Serialize an instance (structure + data volumes + cost matrix) to JSON.
pub fn instance_to_json(inst: &Instance) -> Json {
    let edges = inst
        .graph
        .edges()
        .iter()
        .map(|e| {
            Json::Arr(vec![
                Json::Num(e.src as f64),
                Json::Num(e.dst as f64),
                Json::Num(e.data),
            ])
        })
        .collect();
    Json::obj(vec![
        ("n", Json::Num(inst.graph.num_tasks() as f64)),
        ("p", Json::Num(inst.p as f64)),
        ("edges", Json::Arr(edges)),
        (
            "comp",
            Json::Arr(inst.comp.iter().map(|&c| Json::Num(c)).collect()),
        ),
    ])
}

/// Parse an instance back from [`instance_to_json`] output.
pub fn instance_from_json(j: &Json) -> Result<Instance, String> {
    let n = j
        .get("n")
        .and_then(Json::as_usize)
        .ok_or("missing n")?;
    let p = j
        .get("p")
        .and_then(Json::as_usize)
        .ok_or("missing p")?;
    let edges: Vec<(usize, usize, f64)> = j
        .get("edges")
        .and_then(Json::as_arr)
        .ok_or("missing edges")?
        .iter()
        .map(|e| {
            let a = e.as_arr().ok_or("edge not an array")?;
            Ok((
                a[0].as_usize().ok_or("bad src")?,
                a[1].as_usize().ok_or("bad dst")?,
                a[2].as_f64().ok_or("bad data")?,
            ))
        })
        .collect::<Result<_, String>>()?;
    let comp: Vec<f64> = j
        .get("comp")
        .and_then(Json::as_arr)
        .ok_or("missing comp")?
        .iter()
        .map(|c| c.as_f64().ok_or_else(|| "bad comp".to_string()))
        .collect::<Result<_, String>>()?;
    if comp.len() != n * p {
        return Err(format!("comp has {} entries, expected {}", comp.len(), n * p));
    }
    Ok(Instance {
        graph: TaskGraph::from_edges(n, &edges),
        comp,
        p,
    })
}

/// Render a task graph as Graphviz DOT (node label = id, edge label = data).
pub fn to_dot(g: &TaskGraph, highlight: &[usize]) -> String {
    let hi: std::collections::HashSet<usize> = highlight.iter().copied().collect();
    let mut s = String::from("digraph tasks {\n  rankdir=TB;\n");
    for t in 0..g.num_tasks() {
        if hi.contains(&t) {
            let _ = writeln!(
                s,
                "  t{t} [label=\"{t}\", style=filled, fillcolor=gold];"
            );
        } else {
            let _ = writeln!(s, "  t{t} [label=\"{t}\"];");
        }
    }
    for e in g.edges() {
        let _ = writeln!(s, "  t{} -> t{} [label=\"{:.1}\"];", e.src, e.dst, e.data);
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{generate, RggParams};
    use crate::platform::{CostModel, Platform};

    #[test]
    fn json_roundtrip() {
        let plat = Platform::uniform(3, 1.0, 0.0);
        let inst = generate(
            &RggParams {
                n: 32,
                out_degree: 2,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.2,
            },
            &CostModel::Classic { beta: 0.5 },
            &plat,
            99,
        );
        let j = instance_to_json(&inst);
        let text = j.to_string();
        let back = instance_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.graph.num_tasks(), inst.graph.num_tasks());
        assert_eq!(back.graph.num_edges(), inst.graph.num_edges());
        assert_eq!(back.comp, inst.comp);
        assert_eq!(back.p, inst.p);
    }

    #[test]
    fn dot_contains_nodes_and_highlight() {
        let g = TaskGraph::from_edges(3, &[(0, 1, 2.0), (1, 2, 3.0)]);
        let dot = to_dot(&g, &[1]);
        assert!(dot.contains("t0 -> t1"));
        assert!(dot.contains("fillcolor=gold"));
        assert!(dot.starts_with("digraph"));
    }

    #[test]
    fn from_json_rejects_bad_comp_len() {
        let j = Json::parse(r#"{"n":2,"p":2,"edges":[[0,1,1.0]],"comp":[1,2,3]}"#).unwrap();
        assert!(instance_from_json(&j).is_err());
    }
}
