//! Random instance generators: layered RGGs plus structured families.
//!
//! [`generate`] is the Topcuoglu-style layered random graph generator
//! (§7.1 of the paper), controlled by the six paper parameters:
//!
//! * `n` — number of tasks,
//! * `out_degree` — average out-degree,
//! * `ccr` — communication-to-computation ratio,
//! * `alpha` — shape (height ≈ √n/α; level width ~ U with mean α√n),
//! * `beta` — heterogeneity factor (percent, 0..100),
//! * `gamma` — skewness (fraction of "hot" levels holding heavy tasks).
//!
//! Every generator in this module guarantees a single entry and a single
//! exit task, every non-entry task has at least one parent, and every
//! non-exit task has at least one child — the structural properties CPOP's
//! critical-path extraction needs.
//!
//! Two structured families feed the series-parallel fast path
//! ([`crate::graph::shape`], [`crate::cp::ceft::sp`]):
//!
//! * [`generate_fork_join`] — a chain of fork-join blocks (each block fans
//!   a junction out to `width` parallel tasks and joins them again);
//!   classifies as [`crate::graph::shape::ShapeClass::ForkJoin`].
//! * [`generate_pipeline`] — `replicas` independent `stages`-long chains
//!   between a shared entry and exit (a parallel composition of series
//!   chains); classifies as
//!   [`crate::graph::shape::ShapeClass::SeriesParallel`].
//!
//! Determinism contract: all three families are pure functions of their
//! parameters and `seed` — the same seed yields a bit-identical instance
//! (structure, payloads, and cost matrix), across runs and platforms.

use super::TaskGraph;
use crate::model::{CostMatrix, InstanceRef, PlatformCtx};
use crate::platform::{CostModel, Platform};
use crate::util::rng::Xoshiro256;

/// Parameters of one random graph (one experiment cell).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RggParams {
    /// number of tasks
    pub n: usize,
    /// average out-degree
    pub out_degree: usize,
    /// communication-to-computation ratio
    pub ccr: f64,
    /// shape parameter α
    pub alpha: f64,
    /// heterogeneity factor β as a percentage (paper values {10,25,50,75,95})
    pub beta_pct: f64,
    /// skewness γ ∈ [0, 1]
    pub gamma: f64,
}

impl RggParams {
    /// β as a fraction in [0, 1].
    pub fn beta(&self) -> f64 {
        self.beta_pct / 100.0
    }
}

/// A generated problem instance: structure + payloads + execution costs.
/// Produced by any of the generator families in this module — layered RGG
/// ([`generate`]), fork-join ([`generate_fork_join`]), or pipeline
/// ([`generate_pipeline`]) — all of which are deterministic per seed.
/// The processor-class count lives in the cost matrix ([`Instance::p`]
/// reads it) — there is deliberately no separate field that could
/// disagree with the matrix stride.
#[derive(Clone, Debug)]
pub struct Instance {
    /// the task DAG (edge `data` fields are the communication volumes)
    pub graph: TaskGraph,
    /// dense `v × P` execution-cost matrix (task-major SoA)
    pub comp: CostMatrix,
}

impl Instance {
    /// Number of processor classes (the cost matrix's row stride).
    pub fn p(&self) -> usize {
        self.comp.p()
    }

    /// Borrow this instance together with a platform as the
    /// [`InstanceRef`] view every algorithm entry point consumes. Panics
    /// when the platform's class count disagrees with the cost matrix.
    pub fn bind<'a>(&'a self, platform: &'a Platform) -> InstanceRef<'a> {
        InstanceRef::new(&self.graph, platform, &self.comp)
    }

    /// Borrow this instance through a [`PlatformCtx`]: the returned view
    /// carries the context, so the CEFT kernels read its resident
    /// communication panels instead of refilling workspace copies. Panics
    /// when the context's class count disagrees with the cost matrix.
    pub fn bind_ctx<'a>(&'a self, ctx: &'a PlatformCtx) -> InstanceRef<'a> {
        ctx.bind(&self.graph, &self.comp)
    }
}

/// Generate the *structure* of a layered DAG: returns `(edges, level_of)`.
///
/// Levels: `h ≈ √n/α` levels; widths drawn `U(1, 2α√n)` (mean α√n) until all
/// `n` tasks are placed; first and last levels forced to width 1.
fn structure(params: &RggParams, rng: &mut Xoshiro256) -> (Vec<(usize, usize)>, Vec<usize>) {
    let n = params.n;
    assert!(n >= 2, "need at least entry and exit");
    let sqrt_n = (n as f64).sqrt();
    let mean_width = (params.alpha * sqrt_n).max(1.0);
    let height = ((sqrt_n / params.alpha).round() as usize).clamp(2, n);

    // Assign widths: level 0 and last are 1; middle levels sampled.
    let mut widths = vec![1usize; height];
    let mut placed = 2usize; // entry + exit
    let middle = height.saturating_sub(2);
    if middle > 0 {
        for w in widths.iter_mut().take(height - 1).skip(1) {
            if placed >= n {
                *w = 0;
                continue;
            }
            let draw = rng.uniform(1.0, (2.0 * mean_width).max(2.0)).round() as usize;
            let take = draw.clamp(1, n - placed);
            *w = take;
            placed += take;
        }
        // distribute any remainder over middle levels round-robin
        let mut l = 1;
        while placed < n {
            widths[1 + (l % middle)] += 1;
            placed += 1;
            l += 1;
        }
        // drop empty middle levels
        widths.retain(|&w| w > 0);
    } else {
        // height 2: everything beyond entry/exit goes to a middle level
        if n > 2 {
            widths = vec![1, n - 2, 1];
        }
    }

    // task ids assigned level-major: level 0 = {0}, etc.
    let height = widths.len();
    let mut level_start = vec![0usize; height + 1];
    for l in 0..height {
        level_start[l + 1] = level_start[l] + widths[l];
    }
    debug_assert_eq!(level_start[height], n);
    let mut level_of = vec![0usize; n];
    for l in 0..height {
        for t in level_start[l]..level_start[l + 1] {
            level_of[t] = l;
        }
    }

    // Edges. For each task, out-degree ~ U(1, 2*o); targets drawn from the
    // next few levels (geometric preference for the immediate next level,
    // as in the reference generator).
    let mut edges: Vec<(usize, usize)> = Vec::new();
    let mut has_parent = vec![false; n];
    let mut has_child = vec![false; n];
    let mut seen = std::collections::HashSet::new();
    for src in 0..n {
        let l = level_of[src];
        if l + 1 >= height {
            continue;
        }
        let deg = rng.range_inclusive(1, 2 * params.out_degree.max(1));
        for _ in 0..deg {
            // pick target level: next level with prob 0.7, else uniform later
            let tl = if l + 2 >= height || rng.chance(0.7) {
                l + 1
            } else {
                rng.range_inclusive(l + 2, height - 1)
            };
            let dst = rng.range_inclusive(level_start[tl], level_start[tl + 1] - 1);
            if seen.insert((src, dst)) {
                edges.push((src, dst));
                has_parent[dst] = true;
                has_child[src] = true;
            }
        }
    }
    // Guarantee connectivity: parent from an earlier level for every
    // non-entry task, child for every non-exit task.
    for t in 1..n {
        if !has_parent[t] {
            let l = level_of[t];
            let pl = rng.range_inclusive(0, l - 1);
            let src = rng.range_inclusive(level_start[pl], level_start[pl + 1] - 1);
            if seen.insert((src, t)) {
                edges.push((src, t));
            }
            has_parent[t] = true;
            has_child[src] = true;
        }
    }
    for t in 0..n - 1 {
        if !has_child[t] {
            let l = level_of[t];
            let tl = rng.range_inclusive(l + 1, height - 1);
            let dst = rng.range_inclusive(level_start[tl], level_start[tl + 1] - 1);
            if seen.insert((t, dst)) {
                edges.push((t, dst));
            }
            has_child[t] = true;
        }
    }
    (edges, level_of)
}

/// Draw per-task base weights `w_i` with skewness γ: a γ-fraction of levels
/// is "hot" and draws from a 4× heavier uniform range (pockets of
/// computation, §7.1).
fn base_weights(
    n: usize,
    level_of: &[usize],
    gamma: f64,
    rng: &mut Xoshiro256,
) -> Vec<f64> {
    let w_dag = rng.uniform(50.0, 150.0);
    let height = level_of.iter().copied().max().unwrap_or(0) + 1;
    let hot: Vec<bool> = (0..height).map(|_| rng.chance(gamma)).collect();
    (0..n)
        .map(|t| {
            let scale = if hot[level_of[t]] { 4.0 } else { 1.0 };
            rng.uniform(0.0, 2.0 * w_dag * scale).max(1e-3)
        })
        .collect()
}

/// Generate a full layered-RGG instance under the given cost model and
/// platform. For the structured families see [`generate_fork_join`] and
/// [`generate_pipeline`]; all three share the determinism contract (same
/// parameters + same seed ⇒ bit-identical instance).
///
/// Edge data volumes follow the paper: the weight of an edge leaving `t_i`
/// is `U(w_i·c·(1-β/2), w_i·c·(1+β/2))` where `w_i` is the scalar task
/// weight (mean execution time under the two-weight model).
pub fn generate(
    params: &RggParams,
    model: &CostModel,
    platform: &Platform,
    seed: u64,
) -> Instance {
    let mut rng = Xoshiro256::new(seed);
    let (skeleton, level_of) = structure(params, &mut rng);
    let w = base_weights(params.n, &level_of, params.gamma, &mut rng);
    let (comp, scalar) = model.generate(&w, platform, &mut rng);
    let beta = params.beta();
    let edges: Vec<(usize, usize, f64)> = skeleton
        .into_iter()
        .map(|(src, dst)| {
            let lo = scalar[src] * params.ccr * (1.0 - beta / 2.0);
            let hi = scalar[src] * params.ccr * (1.0 + beta / 2.0);
            let data = if hi > lo { rng.uniform(lo, hi) } else { lo };
            (src, dst, data.max(0.0))
        })
        .collect();
    Instance {
        graph: TaskGraph::from_edges(params.n, &edges),
        comp: CostMatrix::new(platform.num_classes(), comp),
    }
}

/// Finish a structured skeleton into a full [`Instance`]: draw per-task
/// base weights (no level skew — structured families are homogeneous),
/// expand them into the `v × P` cost matrix under `model`, and attach edge
/// data volumes with the same `U(w_i·c·(1-β/2), w_i·c·(1+β/2))` rule as
/// [`generate`].
fn finish_structured(
    n: usize,
    skeleton: &[(usize, usize)],
    ccr: f64,
    beta_pct: f64,
    model: &CostModel,
    platform: &Platform,
    rng: &mut Xoshiro256,
) -> Instance {
    let w_dag = rng.uniform(50.0, 150.0);
    let w: Vec<f64> = (0..n)
        .map(|_| rng.uniform(0.0, 2.0 * w_dag).max(1e-3))
        .collect();
    let (comp, scalar) = model.generate(&w, platform, rng);
    let beta = beta_pct / 100.0;
    let edges: Vec<(usize, usize, f64)> = skeleton
        .iter()
        .map(|&(src, dst)| {
            let lo = scalar[src] * ccr * (1.0 - beta / 2.0);
            let hi = scalar[src] * ccr * (1.0 + beta / 2.0);
            let data = if hi > lo { rng.uniform(lo, hi) } else { lo };
            (src, dst, data.max(0.0))
        })
        .collect();
    Instance {
        graph: TaskGraph::from_edges(n, &edges),
        comp: CostMatrix::new(platform.num_classes(), comp),
    }
}

/// Generate a fork-join instance: a chain of `depth` blocks, each fanning
/// a junction out to `width` parallel single-task branches and joining
/// them at the next junction. Total tasks: `(depth + 1) + depth · width`.
///
/// With `width ≥ 2` the result classifies as
/// [`crate::graph::shape::ShapeClass::ForkJoin`]; `width == 1`
/// degenerates to a chain. Deterministic per seed, like [`generate`].
///
/// Panics if `width == 0` or `depth == 0`.
pub fn generate_fork_join(
    width: usize,
    depth: usize,
    ccr: f64,
    beta_pct: f64,
    model: &CostModel,
    platform: &Platform,
    seed: u64,
) -> Instance {
    assert!(width >= 1, "fork-join needs at least one branch");
    assert!(depth >= 1, "fork-join needs at least one block");
    let mut rng = Xoshiro256::new(seed);
    let n = (depth + 1) + depth * width;
    let mut skeleton: Vec<(usize, usize)> = Vec::with_capacity(2 * depth * width);
    let mut junction = 0usize;
    let mut next_id = 1usize;
    for _ in 0..depth {
        let branch_start = next_id;
        next_id += width;
        let next_junction = next_id;
        next_id += 1;
        for b in 0..width {
            skeleton.push((junction, branch_start + b));
            skeleton.push((branch_start + b, next_junction));
        }
        junction = next_junction;
    }
    debug_assert_eq!(next_id, n);
    finish_structured(n, &skeleton, ccr, beta_pct, model, platform, &mut rng)
}

/// Generate a pipeline instance: `replicas` independent chains of `stages`
/// tasks each, between a shared entry and exit — a parallel composition of
/// series chains. Total tasks: `stages · replicas + 2`.
///
/// With `replicas ≥ 2` and `stages ≥ 2` the result classifies as
/// [`crate::graph::shape::ShapeClass::SeriesParallel`]; `stages == 1`
/// degenerates to fork-join and `replicas == 1` to a chain. Deterministic
/// per seed, like [`generate`].
///
/// Panics if `stages == 0` or `replicas == 0`.
pub fn generate_pipeline(
    stages: usize,
    replicas: usize,
    ccr: f64,
    beta_pct: f64,
    model: &CostModel,
    platform: &Platform,
    seed: u64,
) -> Instance {
    assert!(stages >= 1, "pipeline needs at least one stage");
    assert!(replicas >= 1, "pipeline needs at least one replica");
    let mut rng = Xoshiro256::new(seed);
    let n = stages * replicas + 2;
    let exit = n - 1;
    let mut skeleton: Vec<(usize, usize)> = Vec::with_capacity(replicas * (stages + 1));
    for r in 0..replicas {
        let first = 1 + r * stages;
        skeleton.push((0, first));
        for s in 1..stages {
            skeleton.push((first + s - 1, first + s));
        }
        skeleton.push((first + stages - 1, exit));
    }
    finish_structured(n, &skeleton, ccr, beta_pct, model, platform, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(n: usize, alpha: f64) -> RggParams {
        RggParams {
            n,
            out_degree: 3,
            ccr: 1.0,
            alpha,
            beta_pct: 50.0,
            gamma: 0.25,
        }
    }

    #[test]
    fn generates_requested_size_single_entry_exit() {
        for &n in &[2usize, 8, 32, 128, 500] {
            for &alpha in &[0.1, 0.5, 1.0] {
                let plat = Platform::uniform(4, 1.0, 0.0);
                let inst = generate(
                    &params(n, alpha),
                    &CostModel::Classic { beta: 0.5 },
                    &plat,
                    42,
                );
                assert_eq!(inst.graph.num_tasks(), n);
                assert_eq!(inst.graph.sources().len(), 1, "n={n} alpha={alpha}");
                assert_eq!(inst.graph.sinks().len(), 1, "n={n} alpha={alpha}");
                assert_eq!(inst.comp.len(), n * 4);
                inst.graph.validate(true).unwrap();
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let plat = Platform::uniform(4, 1.0, 0.0);
        let a = generate(&params(64, 0.5), &CostModel::Classic { beta: 0.5 }, &plat, 7);
        let b = generate(&params(64, 0.5), &CostModel::Classic { beta: 0.5 }, &plat, 7);
        assert_eq!(a.comp, b.comp);
        assert_eq!(a.graph.num_edges(), b.graph.num_edges());
        let c = generate(&params(64, 0.5), &CostModel::Classic { beta: 0.5 }, &plat, 8);
        assert_ne!(a.comp, c.comp);
    }

    #[test]
    fn alpha_controls_shape() {
        let plat = Platform::uniform(2, 1.0, 0.0);
        let skinny = generate(&params(256, 0.1), &CostModel::Classic { beta: 0.5 }, &plat, 1);
        let fat = generate(&params(256, 1.0), &CostModel::Classic { beta: 0.5 }, &plat, 1);
        // tall skinny graphs have many levels; short fat graphs few
        let h_skinny = *skinny.graph.levels().iter().max().unwrap();
        let h_fat = *fat.graph.levels().iter().max().unwrap();
        assert!(
            h_skinny > h_fat,
            "alpha=0.1 height {h_skinny} should exceed alpha=1.0 height {h_fat}"
        );
        assert!(fat.graph.width() > skinny.graph.width());
    }

    #[test]
    fn ccr_scales_edge_data() {
        let plat = Platform::uniform(2, 1.0, 0.0);
        let mut lo_params = params(128, 0.5);
        lo_params.ccr = 0.01;
        let mut hi_params = lo_params;
        hi_params.ccr = 10.0;
        let lo = generate(&lo_params, &CostModel::Classic { beta: 0.5 }, &plat, 3);
        let hi = generate(&hi_params, &CostModel::Classic { beta: 0.5 }, &plat, 3);
        let mean = |inst: &Instance| {
            inst.graph.edges().iter().map(|e| e.data).sum::<f64>()
                / inst.graph.num_edges() as f64
        };
        assert!(mean(&hi) > 100.0 * mean(&lo));
    }

    #[test]
    fn out_degree_tracks_parameter() {
        let plat = Platform::uniform(2, 1.0, 0.0);
        let mut p2 = params(512, 0.5);
        p2.out_degree = 2;
        let mut p8 = p2;
        p8.out_degree = 8;
        let g2 = generate(&p2, &CostModel::Classic { beta: 0.5 }, &plat, 5);
        let g8 = generate(&p8, &CostModel::Classic { beta: 0.5 }, &plat, 5);
        assert!(g8.graph.num_edges() > g2.graph.num_edges());
    }

    #[test]
    fn two_weight_instance_builds() {
        let mut rng = Xoshiro256::new(9);
        let plat = Platform::two_weight(8, 0.5, &mut rng, 1.0, 0.0);
        let inst = generate(&params(128, 0.5), &CostModel::two_weight_high(0.5), &plat, 11);
        assert_eq!(inst.comp.len(), 128 * 8);
        assert!(inst.comp.iter().all(|&c| c > 0.0 && c.is_finite()));
    }

    #[test]
    fn fork_join_shape_size_and_determinism() {
        let plat = Platform::uniform(3, 1.0, 0.0);
        let model = CostModel::Classic { beta: 0.5 };
        let inst = generate_fork_join(4, 3, 1.0, 50.0, &model, &plat, 21);
        assert_eq!(inst.graph.num_tasks(), (3 + 1) + 3 * 4);
        assert_eq!(inst.graph.num_edges(), 2 * 3 * 4);
        assert_eq!(inst.graph.sources().len(), 1);
        assert_eq!(inst.graph.sinks().len(), 1);
        inst.graph.validate(true).unwrap();
        let verdict = crate::graph::shape::recognize(&inst.graph);
        assert_eq!(verdict.class, crate::graph::shape::ShapeClass::ForkJoin);
        let again = generate_fork_join(4, 3, 1.0, 50.0, &model, &plat, 21);
        assert_eq!(inst.comp, again.comp);
        assert_eq!(inst.graph.edges(), again.graph.edges());
        let other = generate_fork_join(4, 3, 1.0, 50.0, &model, &plat, 22);
        assert_ne!(inst.comp, other.comp);
    }

    #[test]
    fn pipeline_shape_size_and_determinism() {
        let plat = Platform::uniform(3, 1.0, 0.0);
        let model = CostModel::Classic { beta: 0.5 };
        let inst = generate_pipeline(5, 3, 1.0, 50.0, &model, &plat, 31);
        assert_eq!(inst.graph.num_tasks(), 5 * 3 + 2);
        assert_eq!(inst.graph.num_edges(), 3 * (5 + 1));
        assert_eq!(inst.graph.sources().len(), 1);
        assert_eq!(inst.graph.sinks().len(), 1);
        inst.graph.validate(true).unwrap();
        let verdict = crate::graph::shape::recognize(&inst.graph);
        assert_eq!(
            verdict.class,
            crate::graph::shape::ShapeClass::SeriesParallel
        );
        let again = generate_pipeline(5, 3, 1.0, 50.0, &model, &plat, 31);
        assert_eq!(inst.comp, again.comp);
        assert_eq!(inst.graph.edges(), again.graph.edges());
    }

    #[test]
    fn structured_degenerate_cases_are_chains() {
        let plat = Platform::uniform(2, 1.0, 0.0);
        let model = CostModel::Classic { beta: 0.5 };
        let fj = generate_fork_join(1, 4, 1.0, 50.0, &model, &plat, 41);
        assert_eq!(
            crate::graph::shape::recognize(&fj.graph).class,
            crate::graph::shape::ShapeClass::Chain
        );
        let pipe = generate_pipeline(6, 1, 1.0, 50.0, &model, &plat, 43);
        assert_eq!(
            crate::graph::shape::recognize(&pipe.graph).class,
            crate::graph::shape::ShapeClass::Chain
        );
    }

    #[test]
    fn all_costs_positive_finite() {
        let plat = Platform::uniform(4, 1.0, 0.0);
        let inst = generate(&params(200, 0.75), &CostModel::Classic { beta: 0.95 }, &plat, 13);
        assert!(inst.comp.iter().all(|&c| c > 0.0 && c.is_finite()));
        assert!(inst.graph.edges().iter().all(|e| e.data >= 0.0));
    }
}
