//! Topcuoglu-style random graph generator (§7.1 of the paper).
//!
//! Generates layered DAGs controlled by the six paper parameters:
//!
//! * `n` — number of tasks,
//! * `out_degree` — average out-degree,
//! * `ccr` — communication-to-computation ratio,
//! * `alpha` — shape (height ≈ √n/α; level width ~ U with mean α√n),
//! * `beta` — heterogeneity factor (percent, 0..100),
//! * `gamma` — skewness (fraction of "hot" levels holding heavy tasks).
//!
//! The generator guarantees a single entry and a single exit task (levels 0
//! and h−1 have width 1), every non-entry task has at least one parent in an
//! earlier level, and every non-exit task has at least one child — the
//! structural properties CPOP's critical-path extraction needs.

use super::TaskGraph;
use crate::model::{CostMatrix, InstanceRef, PlatformCtx};
use crate::platform::{CostModel, Platform};
use crate::util::rng::Xoshiro256;

/// Parameters of one random graph (one experiment cell).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RggParams {
    /// number of tasks
    pub n: usize,
    /// average out-degree
    pub out_degree: usize,
    /// communication-to-computation ratio
    pub ccr: f64,
    /// shape parameter α
    pub alpha: f64,
    /// heterogeneity factor β as a percentage (paper values {10,25,50,75,95})
    pub beta_pct: f64,
    /// skewness γ ∈ [0, 1]
    pub gamma: f64,
}

impl RggParams {
    /// β as a fraction in [0, 1].
    pub fn beta(&self) -> f64 {
        self.beta_pct / 100.0
    }
}

/// A generated problem instance: structure + payloads + execution costs.
/// The processor-class count lives in the cost matrix ([`Instance::p`]
/// reads it) — there is deliberately no separate field that could
/// disagree with the matrix stride.
#[derive(Clone, Debug)]
pub struct Instance {
    /// the task DAG (edge `data` fields are the communication volumes)
    pub graph: TaskGraph,
    /// dense `v × P` execution-cost matrix (task-major SoA)
    pub comp: CostMatrix,
}

impl Instance {
    /// Number of processor classes (the cost matrix's row stride).
    pub fn p(&self) -> usize {
        self.comp.p()
    }

    /// Borrow this instance together with a platform as the
    /// [`InstanceRef`] view every algorithm entry point consumes. Panics
    /// when the platform's class count disagrees with the cost matrix.
    pub fn bind<'a>(&'a self, platform: &'a Platform) -> InstanceRef<'a> {
        InstanceRef::new(&self.graph, platform, &self.comp)
    }

    /// Borrow this instance through a [`PlatformCtx`]: the returned view
    /// carries the context, so the CEFT kernels read its resident
    /// communication panels instead of refilling workspace copies. Panics
    /// when the context's class count disagrees with the cost matrix.
    pub fn bind_ctx<'a>(&'a self, ctx: &'a PlatformCtx) -> InstanceRef<'a> {
        ctx.bind(&self.graph, &self.comp)
    }
}

/// Generate the *structure* of a layered DAG: returns `(edges, level_of)`.
///
/// Levels: `h ≈ √n/α` levels; widths drawn `U(1, 2α√n)` (mean α√n) until all
/// `n` tasks are placed; first and last levels forced to width 1.
fn structure(params: &RggParams, rng: &mut Xoshiro256) -> (Vec<(usize, usize)>, Vec<usize>) {
    let n = params.n;
    assert!(n >= 2, "need at least entry and exit");
    let sqrt_n = (n as f64).sqrt();
    let mean_width = (params.alpha * sqrt_n).max(1.0);
    let height = ((sqrt_n / params.alpha).round() as usize).clamp(2, n);

    // Assign widths: level 0 and last are 1; middle levels sampled.
    let mut widths = vec![1usize; height];
    let mut placed = 2usize; // entry + exit
    let middle = height.saturating_sub(2);
    if middle > 0 {
        for w in widths.iter_mut().take(height - 1).skip(1) {
            if placed >= n {
                *w = 0;
                continue;
            }
            let draw = rng.uniform(1.0, (2.0 * mean_width).max(2.0)).round() as usize;
            let take = draw.clamp(1, n - placed);
            *w = take;
            placed += take;
        }
        // distribute any remainder over middle levels round-robin
        let mut l = 1;
        while placed < n {
            widths[1 + (l % middle)] += 1;
            placed += 1;
            l += 1;
        }
        // drop empty middle levels
        widths.retain(|&w| w > 0);
    } else {
        // height 2: everything beyond entry/exit goes to a middle level
        if n > 2 {
            widths = vec![1, n - 2, 1];
        }
    }

    // task ids assigned level-major: level 0 = {0}, etc.
    let height = widths.len();
    let mut level_start = vec![0usize; height + 1];
    for l in 0..height {
        level_start[l + 1] = level_start[l] + widths[l];
    }
    debug_assert_eq!(level_start[height], n);
    let mut level_of = vec![0usize; n];
    for l in 0..height {
        for t in level_start[l]..level_start[l + 1] {
            level_of[t] = l;
        }
    }

    // Edges. For each task, out-degree ~ U(1, 2*o); targets drawn from the
    // next few levels (geometric preference for the immediate next level,
    // as in the reference generator).
    let mut edges: Vec<(usize, usize)> = Vec::new();
    let mut has_parent = vec![false; n];
    let mut has_child = vec![false; n];
    let mut seen = std::collections::HashSet::new();
    for src in 0..n {
        let l = level_of[src];
        if l + 1 >= height {
            continue;
        }
        let deg = rng.range_inclusive(1, 2 * params.out_degree.max(1));
        for _ in 0..deg {
            // pick target level: next level with prob 0.7, else uniform later
            let tl = if l + 2 >= height || rng.chance(0.7) {
                l + 1
            } else {
                rng.range_inclusive(l + 2, height - 1)
            };
            let dst = rng.range_inclusive(level_start[tl], level_start[tl + 1] - 1);
            if seen.insert((src, dst)) {
                edges.push((src, dst));
                has_parent[dst] = true;
                has_child[src] = true;
            }
        }
    }
    // Guarantee connectivity: parent from an earlier level for every
    // non-entry task, child for every non-exit task.
    for t in 1..n {
        if !has_parent[t] {
            let l = level_of[t];
            let pl = rng.range_inclusive(0, l - 1);
            let src = rng.range_inclusive(level_start[pl], level_start[pl + 1] - 1);
            if seen.insert((src, t)) {
                edges.push((src, t));
            }
            has_parent[t] = true;
            has_child[src] = true;
        }
    }
    for t in 0..n - 1 {
        if !has_child[t] {
            let l = level_of[t];
            let tl = rng.range_inclusive(l + 1, height - 1);
            let dst = rng.range_inclusive(level_start[tl], level_start[tl + 1] - 1);
            if seen.insert((t, dst)) {
                edges.push((t, dst));
            }
            has_child[t] = true;
        }
    }
    (edges, level_of)
}

/// Draw per-task base weights `w_i` with skewness γ: a γ-fraction of levels
/// is "hot" and draws from a 4× heavier uniform range (pockets of
/// computation, §7.1).
fn base_weights(
    n: usize,
    level_of: &[usize],
    gamma: f64,
    rng: &mut Xoshiro256,
) -> Vec<f64> {
    let w_dag = rng.uniform(50.0, 150.0);
    let height = level_of.iter().copied().max().unwrap_or(0) + 1;
    let hot: Vec<bool> = (0..height).map(|_| rng.chance(gamma)).collect();
    (0..n)
        .map(|t| {
            let scale = if hot[level_of[t]] { 4.0 } else { 1.0 };
            rng.uniform(0.0, 2.0 * w_dag * scale).max(1e-3)
        })
        .collect()
}

/// Generate a full instance under the given cost model and platform.
///
/// Edge data volumes follow the paper: the weight of an edge leaving `t_i`
/// is `U(w_i·c·(1-β/2), w_i·c·(1+β/2))` where `w_i` is the scalar task
/// weight (mean execution time under the two-weight model).
pub fn generate(
    params: &RggParams,
    model: &CostModel,
    platform: &Platform,
    seed: u64,
) -> Instance {
    let mut rng = Xoshiro256::new(seed);
    let (skeleton, level_of) = structure(params, &mut rng);
    let w = base_weights(params.n, &level_of, params.gamma, &mut rng);
    let (comp, scalar) = model.generate(&w, platform, &mut rng);
    let beta = params.beta();
    let edges: Vec<(usize, usize, f64)> = skeleton
        .into_iter()
        .map(|(src, dst)| {
            let lo = scalar[src] * params.ccr * (1.0 - beta / 2.0);
            let hi = scalar[src] * params.ccr * (1.0 + beta / 2.0);
            let data = if hi > lo { rng.uniform(lo, hi) } else { lo };
            (src, dst, data.max(0.0))
        })
        .collect();
    Instance {
        graph: TaskGraph::from_edges(params.n, &edges),
        comp: CostMatrix::new(platform.num_classes(), comp),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(n: usize, alpha: f64) -> RggParams {
        RggParams {
            n,
            out_degree: 3,
            ccr: 1.0,
            alpha,
            beta_pct: 50.0,
            gamma: 0.25,
        }
    }

    #[test]
    fn generates_requested_size_single_entry_exit() {
        for &n in &[2usize, 8, 32, 128, 500] {
            for &alpha in &[0.1, 0.5, 1.0] {
                let plat = Platform::uniform(4, 1.0, 0.0);
                let inst = generate(
                    &params(n, alpha),
                    &CostModel::Classic { beta: 0.5 },
                    &plat,
                    42,
                );
                assert_eq!(inst.graph.num_tasks(), n);
                assert_eq!(inst.graph.sources().len(), 1, "n={n} alpha={alpha}");
                assert_eq!(inst.graph.sinks().len(), 1, "n={n} alpha={alpha}");
                assert_eq!(inst.comp.len(), n * 4);
                inst.graph.validate(true).unwrap();
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let plat = Platform::uniform(4, 1.0, 0.0);
        let a = generate(&params(64, 0.5), &CostModel::Classic { beta: 0.5 }, &plat, 7);
        let b = generate(&params(64, 0.5), &CostModel::Classic { beta: 0.5 }, &plat, 7);
        assert_eq!(a.comp, b.comp);
        assert_eq!(a.graph.num_edges(), b.graph.num_edges());
        let c = generate(&params(64, 0.5), &CostModel::Classic { beta: 0.5 }, &plat, 8);
        assert_ne!(a.comp, c.comp);
    }

    #[test]
    fn alpha_controls_shape() {
        let plat = Platform::uniform(2, 1.0, 0.0);
        let skinny = generate(&params(256, 0.1), &CostModel::Classic { beta: 0.5 }, &plat, 1);
        let fat = generate(&params(256, 1.0), &CostModel::Classic { beta: 0.5 }, &plat, 1);
        // tall skinny graphs have many levels; short fat graphs few
        let h_skinny = *skinny.graph.levels().iter().max().unwrap();
        let h_fat = *fat.graph.levels().iter().max().unwrap();
        assert!(
            h_skinny > h_fat,
            "alpha=0.1 height {h_skinny} should exceed alpha=1.0 height {h_fat}"
        );
        assert!(fat.graph.width() > skinny.graph.width());
    }

    #[test]
    fn ccr_scales_edge_data() {
        let plat = Platform::uniform(2, 1.0, 0.0);
        let mut lo_params = params(128, 0.5);
        lo_params.ccr = 0.01;
        let mut hi_params = lo_params;
        hi_params.ccr = 10.0;
        let lo = generate(&lo_params, &CostModel::Classic { beta: 0.5 }, &plat, 3);
        let hi = generate(&hi_params, &CostModel::Classic { beta: 0.5 }, &plat, 3);
        let mean = |inst: &Instance| {
            inst.graph.edges().iter().map(|e| e.data).sum::<f64>()
                / inst.graph.num_edges() as f64
        };
        assert!(mean(&hi) > 100.0 * mean(&lo));
    }

    #[test]
    fn out_degree_tracks_parameter() {
        let plat = Platform::uniform(2, 1.0, 0.0);
        let mut p2 = params(512, 0.5);
        p2.out_degree = 2;
        let mut p8 = p2;
        p8.out_degree = 8;
        let g2 = generate(&p2, &CostModel::Classic { beta: 0.5 }, &plat, 5);
        let g8 = generate(&p8, &CostModel::Classic { beta: 0.5 }, &plat, 5);
        assert!(g8.graph.num_edges() > g2.graph.num_edges());
    }

    #[test]
    fn two_weight_instance_builds() {
        let mut rng = Xoshiro256::new(9);
        let plat = Platform::two_weight(8, 0.5, &mut rng, 1.0, 0.0);
        let inst = generate(&params(128, 0.5), &CostModel::two_weight_high(0.5), &plat, 11);
        assert_eq!(inst.comp.len(), 128 * 8);
        assert!(inst.comp.iter().all(|&c| c > 0.0 && c.is_finite()));
    }

    #[test]
    fn all_costs_positive_finite() {
        let plat = Platform::uniform(4, 1.0, 0.0);
        let inst = generate(&params(200, 0.75), &CostModel::Classic { beta: 0.95 }, &plat, 13);
        assert!(inst.comp.iter().all(|&c| c > 0.0 && c.is_finite()));
        assert!(inst.graph.edges().iter().all(|e| e.data >= 0.0));
    }
}
