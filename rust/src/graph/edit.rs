//! In-place graph edits for live, evolving workflows.
//!
//! An [`GraphEdit`] sequence mutates a `(TaskGraph, CostMatrix)` pair into
//! a successor version without re-submitting the whole instance: the
//! service's `update` request (op 10) parses edits, applies them here, and
//! bumps the interned instance's generation. [`apply_edits`] additionally
//! reports everything the delta-CEFT layer needs to recompute only the
//! damage ([`crate::cp::ceft::DeltaPlan`]):
//!
//! * a **dirty set** in the resulting id space — every task whose cost
//!   row, predecessor list, or successor list differs from the input.
//!   Edge edits mark *both* endpoints, so one dirty set serves the
//!   forward and the reverse sweep;
//! * **id stability** — task removal renumbers ids above the removed
//!   task, which invalidates any memoized basis table (the delta plan's
//!   id-prefix contract); callers must fall back to a from-scratch sweep;
//! * **cost-only** classification with per-task increase bounds — when
//!   every edit is a [`GraphEdit::TaskCost`], the graph `Arc` is reused
//!   unchanged (same CSR, same cached topo order) and the per-task
//!   maximum row increase feeds the slack-based skip rule: increase-only
//!   edits bounded by each task's slack provably leave the critical-path
//!   length unchanged, so the engine can skip recompute entirely.
//!
//! Edits apply **sequentially**: each edit addresses the id space produced
//! by the edits before it. Untouched edges keep their relative order in
//! the edge list (and thus their CSR and tie-breaking order); added edges
//! append at the end.
//!
//! The shape verdict ([`crate::graph::shape`]) rides on the same
//! classification: a `cost_only` result reuses the graph `Arc`, so the
//! interned shape verdict (and its `SpTree`) survives unchanged, while any
//! structural edit — including [`GraphEdit::EdgeCost`], which rebuilds the
//! edge list — makes the engine re-run the O(V+E) recognizer on the
//! successor graph. An edit that breaks series-parallel shape therefore
//! demotes the handle to the general kernel transparently; it never
//! panics and never serves a stale decomposition.

use std::sync::Arc;

use super::{Edge, TaskGraph};
use crate::model::CostMatrix;

/// One mutation of a task graph or its computation-cost matrix.
#[derive(Clone, Debug, PartialEq)]
pub enum GraphEdit {
    /// Replace task `task`'s computation-cost row (length `P`).
    TaskCost { task: usize, costs: Vec<f64> },
    /// Set the data payload of every existing `src → dst` edge.
    EdgeCost { src: usize, dst: usize, data: f64 },
    /// Append a new `src → dst` edge with payload `data`.
    AddEdge { src: usize, dst: usize, data: f64 },
    /// Remove every `src → dst` edge.
    RemoveEdge { src: usize, dst: usize },
    /// Append a new task (id `n`) with the given cost row; it starts
    /// disconnected — follow with [`GraphEdit::AddEdge`] to wire it in.
    AddTask { costs: Vec<f64> },
    /// Remove task `task` and every incident edge; ids above `task`
    /// shift down by one (sets [`EditResult::ids_stable`] to `false`).
    RemoveTask { task: usize },
}

impl GraphEdit {
    /// Stable lower-case tag used by the wire protocol.
    pub fn kind(&self) -> &'static str {
        match self {
            GraphEdit::TaskCost { .. } => "task_cost",
            GraphEdit::EdgeCost { .. } => "edge_cost",
            GraphEdit::AddEdge { .. } => "add_edge",
            GraphEdit::RemoveEdge { .. } => "remove_edge",
            GraphEdit::AddTask { .. } => "add_task",
            GraphEdit::RemoveTask { .. } => "remove_task",
        }
    }
}

/// The outcome of [`apply_edits`]: the successor instance plus the
/// invalidation facts the versioned memo layer consumes.
#[derive(Clone, Debug)]
pub struct EditResult {
    /// the edited graph — the *same* `Arc` as the input when no edit was
    /// structural (cost-only), so pointer identity doubles as a "topo
    /// order unchanged" guarantee
    pub graph: Arc<TaskGraph>,
    /// the edited cost matrix
    pub costs: Arc<CostMatrix>,
    /// per-task dirty flags in the resulting id space (`len == n`); all
    /// `true` when `ids_stable` is `false`
    pub dirty: Vec<bool>,
    /// `false` iff a [`GraphEdit::RemoveTask`] renumbered ids — memoized
    /// basis tables indexed by task id are then unusable as delta bases
    pub ids_stable: bool,
    /// every edit was a [`GraphEdit::TaskCost`]: graph `Arc` reused,
    /// `max_increase` is populated
    pub cost_only: bool,
    /// cost-only runs: `true` iff no cost entry decreased (the
    /// monotonicity half of the slack skip rule)
    pub increase_only: bool,
    /// cost-only runs: per-task `max_j (new − old)` against the input
    /// matrix, `0.0` for untouched tasks; empty otherwise
    pub max_increase: Vec<f64>,
}

/// Apply `edits` in order to `(graph, costs)`, returning the successor
/// instance and its invalidation facts. Fails — leaving no partial state,
/// since inputs are immutable — on out-of-range ids, shape-mismatched
/// cost rows, non-finite or negative payloads/costs, editing an absent
/// edge, adding a cycle-forming or duplicate-endpoint-invalid edge, or
/// removing the last task.
pub fn apply_edits(
    graph: &Arc<TaskGraph>,
    costs: &Arc<CostMatrix>,
    edits: &[GraphEdit],
) -> Result<EditResult, String> {
    let p = costs.p();
    let mut n = graph.num_tasks();
    if costs.n() != n {
        return Err(format!(
            "cost matrix covers {} tasks but graph has {n}",
            costs.n()
        ));
    }
    let mut edges: Vec<Edge> = graph.edges().to_vec();
    let mut cost_data: Vec<f64> = costs.as_slice().to_vec();
    let mut dirty = vec![false; n];
    let mut ids_stable = true;
    let mut structural = false;

    for edit in edits {
        match edit {
            GraphEdit::TaskCost { task, costs: row } => {
                let t = *task;
                if t >= n {
                    return Err(format!("task_cost: task {t} out of range n={n}"));
                }
                check_cost_row(row, p, "task_cost")?;
                cost_data[t * p..(t + 1) * p].copy_from_slice(row);
                dirty[t] = true;
            }
            GraphEdit::EdgeCost { src, dst, data } => {
                check_endpoints(*src, *dst, n, "edge_cost")?;
                check_payload(*data, "edge_cost")?;
                let mut hit = false;
                for e in edges.iter_mut() {
                    if e.src == *src && e.dst == *dst {
                        e.data = *data;
                        hit = true;
                    }
                }
                if !hit {
                    return Err(format!("edge_cost: no edge {src}->{dst}"));
                }
                dirty[*src] = true;
                dirty[*dst] = true;
                structural = true;
            }
            GraphEdit::AddEdge { src, dst, data } => {
                check_endpoints(*src, *dst, n, "add_edge")?;
                check_payload(*data, "add_edge")?;
                edges.push(Edge {
                    src: *src,
                    dst: *dst,
                    data: *data,
                });
                dirty[*src] = true;
                dirty[*dst] = true;
                structural = true;
            }
            GraphEdit::RemoveEdge { src, dst } => {
                check_endpoints(*src, *dst, n, "remove_edge")?;
                let before = edges.len();
                edges.retain(|e| !(e.src == *src && e.dst == *dst));
                if edges.len() == before {
                    return Err(format!("remove_edge: no edge {src}->{dst}"));
                }
                dirty[*src] = true;
                dirty[*dst] = true;
                structural = true;
            }
            GraphEdit::AddTask { costs: row } => {
                check_cost_row(row, p, "add_task")?;
                cost_data.extend_from_slice(row);
                dirty.push(true);
                n += 1;
                structural = true;
            }
            GraphEdit::RemoveTask { task } => {
                let t = *task;
                if t >= n {
                    return Err(format!("remove_task: task {t} out of range n={n}"));
                }
                if n == 1 {
                    return Err("remove_task: cannot remove the last task".to_string());
                }
                edges.retain(|e| e.src != t && e.dst != t);
                for e in edges.iter_mut() {
                    if e.src > t {
                        e.src -= 1;
                    }
                    if e.dst > t {
                        e.dst -= 1;
                    }
                }
                cost_data.drain(t * p..(t + 1) * p);
                dirty.remove(t);
                n -= 1;
                ids_stable = false;
                structural = true;
            }
        }
    }

    if !ids_stable {
        // renumbered ids void any basis — the whole table is "dirty"
        dirty.iter_mut().for_each(|d| *d = true);
    }
    let cost_only = !structural;
    let new_graph = if cost_only {
        Arc::clone(graph)
    } else {
        let tuples: Vec<(usize, usize, f64)> =
            edges.iter().map(|e| (e.src, e.dst, e.data)).collect();
        Arc::new(TaskGraph::try_from_edges(n, &tuples).map_err(|e| format!("edit result: {e}"))?)
    };
    let new_costs = Arc::new(CostMatrix::try_new(p, cost_data).map_err(|e| format!("edit result: {e}"))?);

    let (increase_only, max_increase) = if cost_only {
        let mut inc = vec![0.0f64; n];
        let mut monotone = true;
        for t in 0..n {
            if !dirty[t] {
                continue;
            }
            let old = costs.row(t);
            let new = new_costs.row(t);
            for j in 0..p {
                let d = new[j] - old[j];
                if d < 0.0 {
                    monotone = false;
                }
                if d > inc[t] {
                    inc[t] = d;
                }
            }
        }
        (monotone, inc)
    } else {
        (false, Vec::new())
    };

    Ok(EditResult {
        graph: new_graph,
        costs: new_costs,
        dirty,
        ids_stable,
        cost_only,
        increase_only,
        max_increase,
    })
}

fn check_cost_row(row: &[f64], p: usize, what: &str) -> Result<(), String> {
    if row.len() != p {
        return Err(format!(
            "{what}: cost row has {} entries, platform has P={p}",
            row.len()
        ));
    }
    for &c in row {
        if !c.is_finite() || c < 0.0 {
            return Err(format!("{what}: cost entries must be finite and >= 0"));
        }
    }
    Ok(())
}

fn check_endpoints(src: usize, dst: usize, n: usize, what: &str) -> Result<(), String> {
    if src >= n || dst >= n {
        return Err(format!("{what}: edge ({src},{dst}) out of range n={n}"));
    }
    if src == dst {
        return Err(format!("{what}: self loop at {src}"));
    }
    Ok(())
}

fn check_payload(data: f64, what: &str) -> Result<(), String> {
    if !data.is_finite() || data < 0.0 {
        return Err(format!("{what}: edge data must be finite and >= 0"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> (Arc<TaskGraph>, Arc<CostMatrix>) {
        let g = TaskGraph::from_edges(4, &[(0, 1, 5.0), (0, 2, 6.0), (1, 3, 7.0), (2, 3, 8.0)]);
        let c = CostMatrix::new(2, vec![1.0; 8]);
        (Arc::new(g), Arc::new(c))
    }

    #[test]
    fn cost_only_edit_reuses_graph_arc_and_bounds_increase() {
        let (g, c) = diamond();
        let r = apply_edits(
            &g,
            &c,
            &[GraphEdit::TaskCost {
                task: 2,
                costs: vec![1.5, 3.0],
            }],
        )
        .unwrap();
        assert!(Arc::ptr_eq(&r.graph, &g));
        assert!(r.cost_only && r.ids_stable && r.increase_only);
        assert_eq!(r.dirty, vec![false, false, true, false]);
        assert_eq!(r.max_increase, vec![0.0, 0.0, 2.0, 0.0]);
        assert_eq!(r.costs.row(2), &[1.5, 3.0]);
        // inputs untouched
        assert_eq!(c.row(2), &[1.0, 1.0]);
    }

    #[test]
    fn cost_decrease_clears_increase_only() {
        let (g, c) = diamond();
        let r = apply_edits(
            &g,
            &c,
            &[GraphEdit::TaskCost {
                task: 1,
                costs: vec![0.5, 2.0],
            }],
        )
        .unwrap();
        assert!(r.cost_only && !r.increase_only);
        assert_eq!(r.max_increase[1], 1.0);
    }

    #[test]
    fn edge_edits_mark_both_endpoints_and_rebuild() {
        let (g, c) = diamond();
        let r = apply_edits(&g, &c, &[GraphEdit::EdgeCost { src: 1, dst: 3, data: 9.0 }]).unwrap();
        assert!(!r.cost_only && r.ids_stable);
        assert!(!Arc::ptr_eq(&r.graph, &g));
        assert_eq!(r.dirty, vec![false, true, false, true]);
        assert!(r.graph.preds(3).iter().any(|&(k, d)| k == 1 && d == 9.0));
        // untouched edges keep their order, so the cached topo matches
        assert_eq!(r.graph.topo_order(), g.topo_order());
    }

    #[test]
    fn add_and_remove_edge_round_trip_preserves_structure() {
        let (g, c) = diamond();
        let added = apply_edits(&g, &c, &[GraphEdit::AddEdge { src: 1, dst: 2, data: 4.0 }]).unwrap();
        assert_eq!(added.graph.num_edges(), 5);
        assert_eq!(added.dirty, vec![false, true, true, false]);
        let removed = apply_edits(
            &added.graph,
            &added.costs,
            &[GraphEdit::RemoveEdge { src: 1, dst: 2 }],
        )
        .unwrap();
        assert_eq!(removed.graph.num_edges(), 4);
        assert_eq!(removed.graph.edges(), g.edges());
        assert_eq!(removed.graph.topo_order(), g.topo_order());
    }

    #[test]
    fn add_task_appends_id_and_cost_row() {
        let (g, c) = diamond();
        let r = apply_edits(
            &g,
            &c,
            &[
                GraphEdit::AddTask { costs: vec![2.0, 3.0] },
                GraphEdit::AddEdge { src: 3, dst: 4, data: 1.0 },
            ],
        )
        .unwrap();
        assert!(r.ids_stable);
        assert_eq!(r.graph.num_tasks(), 5);
        assert_eq!(r.costs.n(), 5);
        assert_eq!(r.costs.row(4), &[2.0, 3.0]);
        assert_eq!(r.dirty, vec![false, false, false, true, true]);
    }

    #[test]
    fn remove_task_shifts_ids_and_voids_stability() {
        let (g, c) = diamond();
        let r = apply_edits(&g, &c, &[GraphEdit::RemoveTask { task: 1 }]).unwrap();
        assert!(!r.ids_stable);
        assert_eq!(r.graph.num_tasks(), 3);
        // old task 2 is now id 1, old 3 is 2; only 0->1 and 1->2 survive
        assert_eq!(r.graph.num_edges(), 2);
        assert!(r.graph.succs(0).iter().any(|&(s, _)| s == 1));
        assert!(r.graph.succs(1).iter().any(|&(s, _)| s == 2));
        assert!(r.dirty.iter().all(|&d| d));
    }

    #[test]
    fn cycle_forming_edit_is_rejected_atomically() {
        let (g, c) = diamond();
        let err = apply_edits(&g, &c, &[GraphEdit::AddEdge { src: 3, dst: 0, data: 1.0 }]);
        assert!(err.unwrap_err().contains("cycle"));
    }

    #[test]
    fn invalid_edits_report_errors() {
        let (g, c) = diamond();
        for (edit, frag) in [
            (GraphEdit::TaskCost { task: 9, costs: vec![1.0, 1.0] }, "out of range"),
            (GraphEdit::TaskCost { task: 0, costs: vec![1.0] }, "entries"),
            (GraphEdit::TaskCost { task: 0, costs: vec![-1.0, 1.0] }, "finite"),
            (GraphEdit::EdgeCost { src: 0, dst: 3, data: 1.0 }, "no edge"),
            (GraphEdit::RemoveEdge { src: 0, dst: 3 }, "no edge"),
            (GraphEdit::AddEdge { src: 0, dst: 0, data: 1.0 }, "self loop"),
            (GraphEdit::AddEdge { src: 0, dst: 1, data: f64::NAN }, "finite"),
            (GraphEdit::RemoveTask { task: 7 }, "out of range"),
        ] {
            let err = apply_edits(&g, &c, std::slice::from_ref(&edit)).unwrap_err();
            assert!(err.contains(frag), "{edit:?}: {err}");
        }
    }

    #[test]
    fn sequential_edits_address_the_evolving_id_space() {
        let (g, c) = diamond();
        // remove task 0, then edit the task formerly known as 1 (now 0)
        let r = apply_edits(
            &g,
            &c,
            &[
                GraphEdit::RemoveTask { task: 0 },
                GraphEdit::TaskCost { task: 0, costs: vec![5.0, 5.0] },
            ],
        )
        .unwrap();
        assert_eq!(r.graph.num_tasks(), 3);
        assert_eq!(r.costs.row(0), &[5.0, 5.0]);
        assert!(!r.ids_stable && !r.cost_only);
    }
}
