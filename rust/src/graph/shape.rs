//! Structural-shape recognition: classify a [`TaskGraph`] as chain /
//! fork-join / general series-parallel / general DAG, and produce the
//! binary series-parallel decomposition tree when one exists.
//!
//! ## Why
//!
//! The CEFT recurrence is a general topological sweep, but the workloads
//! heterogeneous schedulers are judged on are mostly *structured*:
//! fork-join task graphs and pipeline workflows. For two-terminal
//! series-parallel (TTSP) shapes the `v × P` table collapses to a tree DP
//! over the SP decomposition (`crate::cp::ceft::sp`): series composition
//! is one `P×P` min-plus panel product per hop, parallel composition an
//! element-wise max at the join. The service engine runs [`recognize`]
//! **once per intern** and stores the verdict on the instance snapshot, so
//! every later request routes to the structured kernel for free.
//!
//! ## Recognition algorithm
//!
//! A Valdes-style worklist reduction over a simple multigraph view of the
//! DAG. Duplicate `(u, w)` edges merge into a [`SpNode::Parallel`] node on
//! sight, so the working graph stays simple and every `(u, w)` lookup is
//! one hash probe. Any internal vertex `v` with in-degree 1 and out-degree
//! 1 is *series-reduced*: its edges `(u, v)` and `(v, w)` splice into
//! `(u, w)` under a [`SpNode::Series`] node (immediately parallel-merged
//! if `(u, w)` already exists). The graph is TTSP **iff** this terminates
//! at the single edge `source → sink` — the reduction system is confluent,
//! so reduction order cannot change the verdict. Each reduction is O(1)
//! amortized and removes at least one edge, and a vertex re-enters the
//! worklist only when an incident reduction changed its degree, so the
//! whole recognizer is O(V + E).
//!
//! ## The derived task order
//!
//! [`SpTree::order`] is a topological order of the accepted graph read off
//! the tree: `[source] ++ internal(root) ++ [sink]`, where
//! `internal(Series{l, r, mid}) = internal(l) ++ [mid] ++ internal(r)` and
//! `internal(Parallel{l, r}) = internal(l) ++ internal(r)`. By induction
//! over the tree, a node with terminals `(x, y)` lists its internal
//! vertices so that `x ++ internal ++ y` topologically orders its
//! sub-DAG: a leaf has no internals; a series node sandwiches its midpoint
//! between its two halves; a parallel node's halves share only terminals
//! and carry no cross edges, so concatenation is safe. This is the order
//! the SP kernel sweeps — any topological order yields bit-identical CEFT
//! rows (each row is a function of its parents' rows alone), so the tree
//! order buys locality without touching results.
//!
//! ## Never a wrong answer
//!
//! [`recognize`] is total: graphs with no edges, several sources or sinks,
//! or a stuck reduction (the embedded-"N" witness) simply classify as
//! [`ShapeClass::General`] and keep the general kernel. Edits that break
//! SP shape therefore *demote* a handle transparently — see
//! `graph::edit` and the engine's snapshot maintenance.

use crate::graph::TaskGraph;
use std::collections::HashMap;
use std::sync::Arc;

/// The structural class [`recognize`] assigns to a graph. `Chain` and
/// `ForkJoin` are refinements of `SeriesParallel` used for stats and bench
/// labels; every accepted class carries an [`SpTree`], and all three route
/// to the same structured kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShapeClass {
    /// A single path: every vertex has in- and out-degree ≤ 1.
    Chain = 0,
    /// Junction-separated parallel blocks: every non-junction vertex sits
    /// alone between two junctions, and the junctions form a chain.
    ForkJoin = 1,
    /// Two-terminal series-parallel, but neither of the refinements above.
    SeriesParallel = 2,
    /// Everything else — the general kernel's territory.
    General = 3,
}

/// Number of [`ShapeClass`] variants (sizes the verdict counters).
pub const NUM_SHAPE_CLASSES: usize = 4;

impl ShapeClass {
    /// All classes, in discriminant order (stable stats/report ordering).
    pub const ALL: [ShapeClass; NUM_SHAPE_CLASSES] = [
        ShapeClass::Chain,
        ShapeClass::ForkJoin,
        ShapeClass::SeriesParallel,
        ShapeClass::General,
    ];

    /// Stable label for stats JSON, Prometheus metrics and bench rows.
    pub fn name(self) -> &'static str {
        match self {
            ShapeClass::Chain => "chain",
            ShapeClass::ForkJoin => "fork_join",
            ShapeClass::SeriesParallel => "series_parallel",
            ShapeClass::General => "general",
        }
    }

    /// Counter-array index.
    #[inline]
    pub fn idx(self) -> usize {
        self as usize
    }
}

/// One node of the binary SP decomposition. Indices refer to
/// [`SpTree::nodes`]; children always precede their parent (the vector is
/// in construction order), so an index-ordered sweep is a post-order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpNode {
    /// An original graph edge, by index into `graph.edges()`.
    Leaf { edge: usize },
    /// Series composition at midpoint vertex `mid`: `left` spans
    /// `(u, mid)`, `right` spans `(mid, w)`.
    Series { left: usize, right: usize, mid: usize },
    /// Parallel composition of two subgraphs sharing both terminals.
    Parallel { left: usize, right: usize },
}

/// The SP decomposition of an accepted graph, plus the task order the
/// structured CEFT kernel sweeps (see the module docs for its derivation
/// and topological-order proof).
#[derive(Clone, Debug)]
pub struct SpTree {
    /// All decomposition nodes, children before parents.
    pub nodes: Vec<SpNode>,
    /// Index of the root node (spans `source → sink`).
    pub root: usize,
    /// The graph's unique source.
    pub source: usize,
    /// The graph's unique sink.
    pub sink: usize,
    /// Tree-derived topological task order over all `n` tasks.
    pub order: Vec<usize>,
}

impl SpTree {
    /// The original edge indices under `node`'s subtree, in tree order.
    /// Over the root this is a permutation of `0..m` for a sound
    /// decomposition — the re-expansion check the soundness property
    /// enforces.
    pub fn leaf_edges(&self) -> Vec<usize> {
        let mut edges = Vec::new();
        let mut stack = vec![self.root];
        while let Some(i) = stack.pop() {
            match self.nodes[i] {
                SpNode::Leaf { edge } => edges.push(edge),
                SpNode::Series { left, right, .. } | SpNode::Parallel { left, right } => {
                    // right first so left's leaves pop (and emit) first
                    stack.push(right);
                    stack.push(left);
                }
            }
        }
        edges
    }
}

/// What [`recognize`] returns: the class, and the decomposition whenever
/// the class is an accepted SP shape. The `Arc` makes the verdict cheap to
/// hang off versioned engine snapshots.
#[derive(Clone, Debug)]
pub struct ShapeVerdict {
    /// Structural class of the graph.
    pub class: ShapeClass,
    /// The SP decomposition; `Some` iff `class != General`.
    pub sp: Option<Arc<SpTree>>,
}

impl ShapeVerdict {
    /// The reject verdict: general DAG, no decomposition.
    pub fn general() -> Self {
        ShapeVerdict {
            class: ShapeClass::General,
            sp: None,
        }
    }

    /// Whether the structured kernel applies.
    #[inline]
    pub fn is_sp(&self) -> bool {
        self.sp.is_some()
    }
}

/// Work items of the iterative order derivation (explicit stack: a chain
/// of `n` tasks builds a left-deep series spine of depth `n`, which would
/// overflow the call stack under recursion).
enum OrderWork {
    Node(usize),
    Emit(usize),
}

/// Classify `graph` and build its SP decomposition if one exists. Total
/// and panic-free on every valid DAG; O(V + E). See the module docs for
/// the algorithm.
pub fn recognize(graph: &TaskGraph) -> ShapeVerdict {
    let n = graph.num_tasks();
    let m = graph.num_edges();
    if n < 2 || m == 0 {
        // a TTSP graph needs two distinct terminals joined by edges
        return ShapeVerdict::general();
    }
    let sources = graph.sources();
    let sinks = graph.sinks();
    if sources.len() != 1 || sinks.len() != 1 {
        return ShapeVerdict::general();
    }
    let (source, sink) = (sources[0], sinks[0]);

    // The working multigraph, kept simple by merging parallel edges on
    // sight: per vertex, neighbour -> decomposition node of the one
    // surviving edge. A reduced graph has at most m live pairs.
    let mut nodes: Vec<SpNode> = Vec::with_capacity(2 * m);
    let mut out: Vec<HashMap<usize, usize>> = vec![HashMap::new(); n];
    let mut inn: Vec<HashMap<usize, usize>> = vec![HashMap::new(); n];
    let mut live_edges = 0usize;
    for (idx, e) in graph.edges().iter().enumerate() {
        let leaf = nodes.len();
        nodes.push(SpNode::Leaf { edge: idx });
        match out[e.src].get(&e.dst).copied() {
            Some(existing) => {
                let merged = nodes.len();
                nodes.push(SpNode::Parallel {
                    left: existing,
                    right: leaf,
                });
                out[e.src].insert(e.dst, merged);
                inn[e.dst].insert(e.src, merged);
            }
            None => {
                out[e.src].insert(e.dst, leaf);
                inn[e.dst].insert(e.src, leaf);
                live_edges += 1;
            }
        }
    }

    // Series-reduce until no candidate remains. A vertex only becomes
    // reducible when an incident reduction changes its degree, so the
    // worklist re-push below is the only re-examination needed.
    let mut work: Vec<usize> = (0..n).collect();
    while let Some(v) = work.pop() {
        if v == source || v == sink || inn[v].len() != 1 || out[v].len() != 1 {
            continue;
        }
        // singleton maps: iter().next() is the one edge, deterministically
        let (&u, &left) = inn[v].iter().next().expect("in-degree 1");
        let (&w, &right) = out[v].iter().next().expect("out-degree 1");
        inn[v].clear();
        out[v].clear();
        out[u].remove(&v);
        inn[w].remove(&v);
        let series = nodes.len();
        nodes.push(SpNode::Series { left, right, mid: v });
        live_edges -= 1; // two edges out, one (possibly merged) in
        match out[u].get(&w).copied() {
            Some(existing) => {
                let merged = nodes.len();
                nodes.push(SpNode::Parallel {
                    left: existing,
                    right: series,
                });
                out[u].insert(w, merged);
                inn[w].insert(u, merged);
                live_edges -= 1;
            }
            None => {
                out[u].insert(w, series);
                inn[w].insert(u, series);
            }
        }
        // only u and w changed degree
        work.push(u);
        work.push(w);
    }

    // Accept iff exactly the edge source -> sink survived. (Then every
    // internal vertex was series-reduced exactly once: an untouched
    // internal vertex would still hold live edges — an isolated vertex is
    // impossible, it would have been a second source.)
    let root = match out[source].get(&sink).copied() {
        Some(root) if live_edges == 1 => root,
        _ => return ShapeVerdict::general(),
    };

    // Tree-derived topological order (module docs): iterative in-order
    // walk emitting series midpoints between their halves.
    let mut order = Vec::with_capacity(n);
    order.push(source);
    let mut stack = vec![OrderWork::Node(root)];
    while let Some(item) = stack.pop() {
        match item {
            OrderWork::Emit(v) => order.push(v),
            OrderWork::Node(i) => match nodes[i] {
                SpNode::Leaf { .. } => {}
                SpNode::Series { left, right, mid } => {
                    stack.push(OrderWork::Node(right));
                    stack.push(OrderWork::Emit(mid));
                    stack.push(OrderWork::Node(left));
                }
                SpNode::Parallel { left, right } => {
                    stack.push(OrderWork::Node(right));
                    stack.push(OrderWork::Node(left));
                }
            },
        }
    }
    order.push(sink);
    debug_assert_eq!(order.len(), n, "SP order must cover every task");

    let class = if is_chain(graph) {
        ShapeClass::Chain
    } else if is_fork_join(graph, source, sink) {
        ShapeClass::ForkJoin
    } else {
        ShapeClass::SeriesParallel
    };
    ShapeVerdict {
        class,
        sp: Some(Arc::new(SpTree {
            nodes,
            root,
            source,
            sink,
            order,
        })),
    }
}

/// A single path: every vertex has in- and out-degree at most one. Only
/// called on accepted (single-source, single-sink, connected) graphs.
fn is_chain(graph: &TaskGraph) -> bool {
    (0..graph.num_tasks()).all(|v| graph.in_degree(v) <= 1 && graph.out_degree(v) <= 1)
}

/// Junction-separated parallel blocks (the `generate_fork_join` family):
/// vertices whose degrees differ from (1, 1) are *junctions*; every other
/// vertex must sit alone between two junctions, and following each
/// junction's unique next junction must chain from `source` to `sink`
/// through all of them. Label-only refinement — both outcomes route to the
/// SP kernel.
fn is_fork_join(graph: &TaskGraph, source: usize, sink: usize) -> bool {
    let n = graph.num_tasks();
    let junction = |v: usize| graph.in_degree(v) != 1 || graph.out_degree(v) != 1;
    for v in 0..n {
        if junction(v) {
            continue;
        }
        let p = graph.preds(v)[0].0;
        let s = graph.succs(v)[0].0;
        if !junction(p) || !junction(s) {
            return false;
        }
    }
    // each non-sink junction must reach exactly one next junction, through
    // direct edges or single-vertex branches
    let mut next: Vec<Option<usize>> = vec![None; n];
    let mut junction_count = 0usize;
    for u in 0..n {
        if !junction(u) {
            continue;
        }
        junction_count += 1;
        if u == sink {
            continue;
        }
        for &(w, _) in graph.succs(u) {
            let hop = if junction(w) { w } else { graph.succs(w)[0].0 };
            match next[u] {
                None => next[u] = Some(hop),
                Some(prev) if prev == hop => {}
                Some(_) => return false,
            }
        }
    }
    // the next-junction relation must walk source -> sink covering all
    let mut seen = 1usize;
    let mut at = source;
    while at != sink {
        match next[at] {
            Some(j) if seen <= junction_count => {
                at = j;
                seen += 1;
            }
            _ => return false,
        }
    }
    seen == junction_count
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(n: usize, edges: &[(usize, usize, f64)]) -> TaskGraph {
        TaskGraph::from_edges(n, edges)
    }

    /// `order` is a topological order of `g` covering every task once.
    fn assert_valid_topo(g: &TaskGraph, order: &[usize]) {
        assert_eq!(order.len(), g.num_tasks());
        let mut pos = vec![usize::MAX; g.num_tasks()];
        for (i, &t) in order.iter().enumerate() {
            assert_eq!(pos[t], usize::MAX, "task {t} repeated");
            pos[t] = i;
        }
        for e in g.edges() {
            assert!(pos[e.src] < pos[e.dst], "edge {}->{} inverted", e.src, e.dst);
        }
    }

    /// The decomposition re-expands to the exact edge set.
    fn assert_leaves_are_edge_permutation(sp: &SpTree, m: usize) {
        let mut leaves = sp.leaf_edges();
        leaves.sort_unstable();
        assert_eq!(leaves, (0..m).collect::<Vec<_>>());
    }

    #[test]
    fn chain_is_recognized_with_identity_order() {
        let n = 7;
        let edges: Vec<_> = (0..n - 1).map(|i| (i, i + 1, 1.0)).collect();
        let g = graph(n, &edges);
        let v = recognize(&g);
        assert_eq!(v.class, ShapeClass::Chain);
        let sp = v.sp.expect("chain decomposes");
        assert_eq!(sp.order, (0..n).collect::<Vec<_>>());
        assert_leaves_are_edge_permutation(&sp, g.num_edges());
    }

    #[test]
    fn diamond_is_fork_join() {
        let g = graph(4, &[(0, 1, 1.0), (0, 2, 1.0), (1, 3, 1.0), (2, 3, 1.0)]);
        let v = recognize(&g);
        assert_eq!(v.class, ShapeClass::ForkJoin);
        let sp = v.sp.expect("diamond decomposes");
        assert_valid_topo(&g, &sp.order);
        assert_leaves_are_edge_permutation(&sp, g.num_edges());
    }

    #[test]
    fn parallel_chains_are_sp_but_not_fork_join() {
        // entry -> two 2-task chains -> exit: branches longer than one
        // vertex, so the fork-join refinement must decline
        let g = graph(
            6,
            &[
                (0, 1, 1.0),
                (1, 2, 1.0),
                (2, 5, 1.0),
                (0, 3, 1.0),
                (3, 4, 1.0),
                (4, 5, 1.0),
            ],
        );
        let v = recognize(&g);
        assert_eq!(v.class, ShapeClass::SeriesParallel);
        let sp = v.sp.expect("parallel chains decompose");
        assert_valid_topo(&g, &sp.order);
        assert_leaves_are_edge_permutation(&sp, g.num_edges());
    }

    #[test]
    fn embedded_n_graph_is_general() {
        // s -> {a, b}, a -> b, {a, b} -> t: the reduction has no
        // series-reducible vertex (a is 1-in/2-out, b 2-in/1-out), the
        // classic non-TTSP witness
        let g = graph(
            4,
            &[(0, 1, 1.0), (0, 2, 1.0), (1, 2, 1.0), (1, 3, 1.0), (2, 3, 1.0)],
        );
        let v = recognize(&g);
        assert_eq!(v.class, ShapeClass::General);
        assert!(v.sp.is_none());
    }

    #[test]
    fn multiple_sources_or_sinks_are_general() {
        let two_sources = graph(3, &[(0, 2, 1.0), (1, 2, 1.0)]);
        assert_eq!(recognize(&two_sources).class, ShapeClass::General);
        let two_sinks = graph(3, &[(0, 1, 1.0), (0, 2, 1.0)]);
        assert_eq!(recognize(&two_sinks).class, ShapeClass::General);
        assert_eq!(recognize(&graph(1, &[])).class, ShapeClass::General);
    }

    #[test]
    fn deep_chain_does_not_overflow() {
        // left-deep series spine: exercises the iterative order walk
        let n = 20_000;
        let edges: Vec<_> = (0..n - 1).map(|i| (i, i + 1, 0.5)).collect();
        let v = recognize(&graph(n, &edges));
        assert_eq!(v.class, ShapeClass::Chain);
        assert_eq!(v.sp.unwrap().order.len(), n);
    }

    #[test]
    fn nested_series_parallel_round_trips() {
        // series of a diamond and a parallel pair with a mid vertex:
        // 0 -> {1, 2} -> 3 -> {4 (direct edge alongside), via 4? } keep it
        // concrete: diamond 0..3 then edges 3->4, 3->5, 4->6, 5->6
        let g = graph(
            7,
            &[
                (0, 1, 1.0),
                (0, 2, 2.0),
                (1, 3, 1.0),
                (2, 3, 2.0),
                (3, 4, 1.0),
                (3, 5, 2.0),
                (4, 6, 1.0),
                (5, 6, 2.0),
            ],
        );
        let v = recognize(&g);
        assert_eq!(v.class, ShapeClass::ForkJoin);
        let sp = v.sp.expect("decomposes");
        assert_valid_topo(&g, &sp.order);
        assert_leaves_are_edge_permutation(&sp, g.num_edges());
    }
}
