//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The compile path (`make artifacts`) lowers the Layer-1 Pallas relaxation
//! kernel, wrapped in the Layer-2 JAX function, to HLO *text* (see
//! `python/compile/aot.py`; text rather than serialized proto because the
//! crate's xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit instruction ids).
//! The [`pjrt`]-feature implementation loads those artifacts through the
//! `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `compile` → `execute`) and exposes them as a batched CEFT edge-relaxation
//! evaluator. Python never runs at this point: the artifacts are
//! self-contained.
//!
//! The `xla` crate closure is only present in some build images, so the
//! whole PJRT path is gated behind the `pjrt` cargo feature. Without it this
//! module compiles a stub whose constructor returns an error; every caller
//! (`repro runtime-check`, the `accelerated_ceft` example, the
//! `runtime_roundtrip` tests, the `runtime_pjrt` bench) already treats a
//! failed construction as "skip", so default builds stay green while the
//! public API is identical in both configurations.

use crate::cp::ceft::{CeftTable, CriticalPath};
use crate::model::InstanceRef;
use std::path::PathBuf;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtRuntime;

/// Batch size the artifacts are compiled for (must match `aot.py`).
pub const BATCH: usize = 256;
/// Processor-class counts with a compiled artifact (must match `aot.py`).
pub const CLASS_SIZES: [usize; 6] = [2, 4, 8, 16, 32, 64];

/// Runtime-layer error (message-only; `anyhow` is unavailable offline).
#[derive(Clone, Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

impl From<String> for RuntimeError {
    fn from(s: String) -> Self {
        RuntimeError(s)
    }
}

/// Result alias used throughout the runtime layer.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Artifact file name for a class count.
pub fn artifact_name(p: usize) -> String {
    format!("ceft_relax_b{BATCH}_p{p}.hlo.txt")
}

/// Directory holding the artifacts (env `CEFT_ARTIFACTS` override, else
/// `artifacts/` relative to the working directory).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("CEFT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Stub runtime compiled when the `pjrt` feature is off. Not constructible:
/// both constructors return an error, so the methods below are only here to
/// keep the API surface identical for downstream code.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    _unconstructible: (),
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    /// Always fails: PJRT support is not compiled in.
    pub fn new() -> Result<Self> {
        Self::with_dir(artifacts_dir())
    }

    /// Always fails: PJRT support is not compiled in.
    pub fn with_dir<P: AsRef<std::path::Path>>(dir: P) -> Result<Self> {
        let _ = dir;
        Err(RuntimeError(
            "PJRT support not compiled in (rebuild with `--features pjrt` and the vendored `xla` crate)"
                .to_string(),
        ))
    }

    /// Platform name (never reached: the stub cannot be constructed).
    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    /// Whether the artifact for `p` classes exists (stub: always false).
    pub fn has_artifact(&self, p: usize) -> bool {
        let _ = p;
        false
    }

    /// Batched relaxation (stub: always an error).
    pub fn relax_batch(
        &self,
        _p: usize,
        _f: &[f32],
        _data: &[f32],
        _l: &[f32],
        _invbw: &[f32],
        _comp: &[f32],
    ) -> Result<Vec<f32>> {
        Err(RuntimeError("PJRT support not compiled in".to_string()))
    }
}

/// CEFT evaluated through the PJRT artifact: fills the DP table by batching
/// all parent edges of each topological level into `BATCH`-sized artifact
/// calls, then reconstructs the path (and backpointers along it) in rust.
///
/// This is the "accelerated backend" of the coordinator; it must agree with
/// [`crate::cp::ceft::find_critical_path`] to float32 tolerance (asserted by
/// the integration tests and the `accelerated_ceft` example).
pub struct AcceleratedCeft {
    rt: PjrtRuntime,
}

impl AcceleratedCeft {
    /// Wrap a runtime.
    pub fn new(rt: PjrtRuntime) -> Self {
        Self { rt }
    }

    /// Whether `p` classes are supported by the compiled artifacts.
    pub fn supports(&self, p: usize) -> bool {
        CLASS_SIZES.contains(&p) && self.rt.has_artifact(p)
    }

    /// Compute the CEFT table on the accelerator.
    ///
    /// Instances bound through a [`crate::model::PlatformCtx`] reuse the
    /// context's resident f32 marshals (`startup_f32` / `invbw_f32`,
    /// derived from the same panels the CPU kernel reads) instead of
    /// re-deriving them per call — the two backends consume one batching
    /// layer. Unbound instances marshal locally, bit-identically.
    pub fn ceft_table(&self, inst: InstanceRef) -> Result<CeftTable> {
        let graph = inst.graph;
        let platform = inst.platform;
        let costs = inst.costs;
        let p = platform.num_classes();
        if !CLASS_SIZES.contains(&p) {
            return Err(RuntimeError(format!("no artifact for p={p}")));
        }
        let v = graph.num_tasks();
        let mut local_l = Vec::new();
        let mut local_invbw = Vec::new();
        let (l, invbw): (&[f32], &[f32]) = match inst.ctx() {
            Some(ctx) => (ctx.startup_f32(), ctx.invbw_f32()),
            None => {
                crate::model::fill_f32_marshals(platform, &mut local_l, &mut local_invbw);
                (&local_l, &local_invbw)
            }
        };
        let mut table = vec![0f64; v * p];
        // process tasks level by level; batch the edge relaxations
        let levels = graph.levels();
        let max_level = levels.iter().copied().max().unwrap_or(0);
        let mut tasks_at: Vec<Vec<usize>> = vec![Vec::new(); max_level + 1];
        for t in 0..v {
            tasks_at[levels[t]].push(t);
        }
        // edge batch buffers
        let mut fbuf = vec![0f32; BATCH * p];
        let mut dbuf = vec![0f32; BATCH];
        let mut cbuf = vec![0f32; BATCH * p];
        for level_tasks in &tasks_at {
            // collect (task, parent, data) tuples for this level
            let mut items: Vec<(usize, usize, f64)> = Vec::new();
            for &t in level_tasks {
                if graph.preds(t).is_empty() {
                    for j in 0..p {
                        table[t * p + j] = costs.get(t, j);
                    }
                } else {
                    for &(k, data) in graph.preds(t) {
                        items.push((t, k, data));
                    }
                }
            }
            // relax in BATCH-sized chunks; aggregate max over parents per task
            for chunk in items.chunks(BATCH) {
                for (i, &(t, k, data)) in chunk.iter().enumerate() {
                    for j in 0..p {
                        fbuf[i * p + j] = table[k * p + j] as f32;
                        cbuf[i * p + j] = costs.get(t, j) as f32;
                    }
                    dbuf[i] = data as f32;
                }
                // pad the tail with zeros (results ignored)
                for i in chunk.len()..BATCH {
                    for j in 0..p {
                        fbuf[i * p + j] = 0.0;
                        cbuf[i * p + j] = 0.0;
                    }
                    dbuf[i] = 0.0;
                }
                let out = self.rt.relax_batch(p, &fbuf, &dbuf, l, invbw, &cbuf)?;
                for (i, &(t, _, _)) in chunk.iter().enumerate() {
                    for j in 0..p {
                        let cand = out[i * p + j] as f64;
                        let cell = &mut table[t * p + j];
                        if cand > *cell {
                            *cell = cand;
                        }
                    }
                }
            }
        }
        // Backpointers are not produced by the kernel; reconstruct them in
        // rust (cheap second pass, same recurrence, f64).
        let bt = crate::cp::ceft::ceft_table(inst);
        Ok(CeftTable {
            p,
            table,
            backptr: bt.backptr,
        })
    }

    /// Full critical path via the accelerator table (path structure from the
    /// f64 backpointer pass, length from the accelerated table).
    pub fn find_critical_path(&self, inst: InstanceRef) -> Result<CriticalPath> {
        let t = self.ceft_table(inst)?;
        Ok(crate::cp::ceft::critical_path_from_table(inst.graph, &t))
    }
}

/// Reference (pure-rust, f32) implementation of the artifact's relaxation,
/// used by unit tests to validate `PjrtRuntime::relax_batch` numerics
/// without requiring the artifacts to exist.
pub fn relax_batch_reference(
    p: usize,
    f: &[f32],
    data: &[f32],
    l: &[f32],
    invbw: &[f32],
    comp: &[f32],
) -> Vec<f32> {
    let b = data.len();
    let mut out = vec![0f32; b * p];
    for i in 0..b {
        for j in 0..p {
            let mut best = f32::INFINITY;
            for k in 0..p {
                let comm = if k == j {
                    0.0
                } else {
                    l[k] + data[i] * invbw[k * p + j]
                };
                let cand = f[i * p + k] + comm;
                if cand < best {
                    best = cand;
                }
            }
            out[i * p + j] = best + comp[i * p + j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;

    #[test]
    fn artifact_names_are_stable() {
        assert_eq!(artifact_name(8), "ceft_relax_b256_p8.hlo.txt");
    }

    #[test]
    fn reference_relaxation_matches_scalar_ceft_recurrence() {
        // single edge, p=2: compare against hand computation
        let p = 2;
        let f = vec![10.0f32, 20.0]; // parent CEFT per class (batch row 0)
        let data = vec![4.0f32];
        let l = vec![1.0f32, 2.0];
        let invbw = vec![0.0f32, 0.5, 0.25, 0.0];
        let comp = vec![3.0f32, 7.0];
        let mut fb = vec![0f32; p];
        fb.copy_from_slice(&f);
        let out = relax_batch_reference(p, &fb, &data, &l, &invbw, &comp);
        // j=0: min(f0 + 0, f1 + l1 + 4*invbw[1,0]) = min(10, 20+2+1) = 10; +3 = 13
        assert_eq!(out[0], 13.0);
        // j=1: min(f0 + l0 + 4*0.5, f1 + 0) = min(10+1+2, 20) = 13; +7 = 20
        assert_eq!(out[1], 20.0);
    }

    #[test]
    fn reference_relaxation_agrees_with_platform_comm_cost() {
        // randomised cross-check against Platform::comm_cost + scalar min
        let mut rng = crate::util::rng::Xoshiro256::new(77);
        let p = 4;
        let plat = Platform::random_links(p, &mut rng, 0.5, 2.0, 0.0, 1.0);
        let l: Vec<f32> = (0..p).map(|j| plat.startup(j) as f32).collect();
        let mut invbw = vec![0f32; p * p];
        for a in 0..p {
            for b in 0..p {
                invbw[a * p + b] = if a == b {
                    0.0
                } else {
                    (1.0 / plat.bandwidth(a, b)) as f32
                };
            }
        }
        let b = 8;
        let f: Vec<f32> = (0..b * p).map(|_| rng.uniform(0.0, 50.0) as f32).collect();
        let data: Vec<f32> = (0..b).map(|_| rng.uniform(0.0, 20.0) as f32).collect();
        let comp: Vec<f32> = (0..b * p).map(|_| rng.uniform(1.0, 9.0) as f32).collect();
        let out = relax_batch_reference(p, &f, &data, &l, &invbw, &comp);
        for i in 0..b {
            for j in 0..p {
                let mut best = f64::INFINITY;
                for k in 0..p {
                    let cand =
                        f[i * p + k] as f64 + plat.comm_cost(k, j, data[i] as f64);
                    best = best.min(cand);
                }
                let expect = best + comp[i * p + j] as f64;
                assert!(
                    (out[i * p + j] as f64 - expect).abs() < 1e-3,
                    "({i},{j}): {} vs {expect}",
                    out[i * p + j]
                );
            }
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = PjrtRuntime::new().err().expect("stub must not construct");
        assert!(err.to_string().contains("not compiled in"));
    }

    #[test]
    fn ctx_marshals_match_local_marshalling() {
        // the PlatformCtx f32 marshals must be bit-identical to the local
        // per-call marshalling the unbound path performs, so binding an
        // instance through a ctx cannot change accelerator numerics
        let mut rng = crate::util::rng::Xoshiro256::new(55);
        let p = 4;
        let plat = Platform::random_links(p, &mut rng, 0.5, 2.0, 0.0, 1.0);
        let ctx = crate::model::PlatformCtx::new(plat.clone());
        for j in 0..p {
            assert_eq!(ctx.startup_f32()[j], plat.startup(j) as f32);
        }
        for a in 0..p {
            for b in 0..p {
                let local = if a == b {
                    0.0
                } else {
                    (1.0 / plat.bandwidth(a, b)) as f32
                };
                assert_eq!(ctx.invbw_f32()[a * p + b].to_bits(), local.to_bits());
            }
        }
    }

    #[test]
    fn cpu_batch_kernel_agrees_with_relax_batch_reference() {
        // The CPU batched min-plus kernel (ceft_dp_kernel_batch_into) and
        // the artifact's relaxation reference implement the same batching
        // layer: B rows against one shared panel pair. With comp = 0 the
        // f32 reference must match the f64 kernel to f32 tolerance.
        let mut rng = crate::util::rng::Xoshiro256::new(56);
        let p = 4;
        let plat = Platform::random_links(p, &mut rng, 0.5, 2.0, 0.0, 1.0);
        let ctx = crate::model::PlatformCtx::new(plat.clone());
        let b = 8;
        let rows: Vec<f64> = (0..b * p).map(|_| rng.uniform(0.0, 50.0)).collect();
        let data: Vec<f64> = (0..b).map(|_| rng.uniform(0.0, 20.0)).collect();
        let mut vals = Vec::new();
        let mut args = Vec::new();
        crate::cp::ceft::ceft_dp_kernel_batch_into(&ctx, &rows, &data, &mut vals, &mut args);
        let rows32: Vec<f32> = rows.iter().map(|&x| x as f32).collect();
        let data32: Vec<f32> = data.iter().map(|&x| x as f32).collect();
        let comp32 = vec![0f32; b * p];
        let out = relax_batch_reference(
            p,
            &rows32,
            &data32,
            ctx.startup_f32(),
            ctx.invbw_f32(),
            &comp32,
        );
        for i in 0..b {
            for j in 0..p {
                let diff = (out[i * p + j] as f64 - vals[i * p + j]).abs();
                assert!(
                    diff < 1e-3 * vals[i * p + j].abs().max(1.0),
                    "({i},{j}): f32 {} vs f64 {}",
                    out[i * p + j],
                    vals[i * p + j]
                );
            }
        }
    }
}
