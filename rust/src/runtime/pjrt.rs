//! The real PJRT client, compiled only with `--features pjrt` (requires the
//! vendored `xla` crate closure — see the note in `Cargo.toml`).

use super::{artifact_name, artifacts_dir, Result, RuntimeError, BATCH};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A PJRT CPU client with a cache of compiled executables, one per class
/// count.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    exes: Mutex<HashMap<usize, xla::PjRtLoadedExecutable>>,
    dir: PathBuf,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client rooted at the default artifacts directory.
    pub fn new() -> Result<Self> {
        Self::with_dir(artifacts_dir())
    }

    /// Create a CPU PJRT client rooted at `dir`.
    pub fn with_dir<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| RuntimeError(format!("pjrt cpu client: {e:?}")))?;
        Ok(Self {
            client,
            exes: Mutex::new(HashMap::new()),
            dir: dir.as_ref().to_path_buf(),
        })
    }

    /// Platform name reported by PJRT (e.g. "cpu").
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Whether the artifact for `p` classes exists on disk.
    pub fn has_artifact(&self, p: usize) -> bool {
        self.dir.join(artifact_name(p)).exists()
    }

    /// Load (or fetch from cache) the executable for `p` classes.
    fn executable(&self, p: usize) -> Result<()> {
        let mut exes = self.exes.lock().unwrap();
        if exes.contains_key(&p) {
            return Ok(());
        }
        let path = self.dir.join(artifact_name(p));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| RuntimeError(format!("load {path:?}: {e:?}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| RuntimeError(format!("compile {path:?}: {e:?}")))?;
        exes.insert(p, exe);
        Ok(())
    }

    /// One batched CEFT edge relaxation on the accelerator:
    ///
    /// `out[b, j] = min_l ( F[b, l] + (l==j ? 0 : L[l] + data[b] * invbw[l, j]) ) + comp[b, j]`
    ///
    /// Shapes: `f` is `BATCH×p` (parent CEFT rows), `data` is `BATCH`
    /// (edge payloads), `l` is `p` (startup latencies), `invbw` is `p×p`
    /// (reciprocal bandwidths, diagonal ignored), `comp` is `BATCH×p`
    /// (child execution costs). Returns `BATCH×p`.
    pub fn relax_batch(
        &self,
        p: usize,
        f: &[f32],
        data: &[f32],
        l: &[f32],
        invbw: &[f32],
        comp: &[f32],
    ) -> Result<Vec<f32>> {
        assert_eq!(f.len(), BATCH * p);
        assert_eq!(data.len(), BATCH);
        assert_eq!(l.len(), p);
        assert_eq!(invbw.len(), p * p);
        assert_eq!(comp.len(), BATCH * p);
        self.executable(p)?;
        let exes = self.exes.lock().unwrap();
        let exe = exes.get(&p).unwrap();
        let lit = |v: &[f32], shape: &[i64]| -> Result<xla::Literal> {
            xla::Literal::vec1(v)
                .reshape(shape)
                .map_err(|e| RuntimeError(format!("reshape {shape:?}: {e:?}")))
        };
        let b = BATCH as i64;
        let pi = p as i64;
        let args = [
            lit(f, &[b, pi])?,
            lit(data, &[b])?,
            lit(l, &[pi])?,
            lit(invbw, &[pi, pi])?,
            lit(comp, &[b, pi])?,
        ];
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| RuntimeError(format!("execute: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| RuntimeError(format!("fetch: {e:?}")))?;
        // aot.py lowers with return_tuple=True -> 1-tuple
        let out = result
            .to_tuple1()
            .map_err(|e| RuntimeError(format!("untuple: {e:?}")))?;
        out.to_vec::<f32>()
            .map_err(|e| RuntimeError(format!("to_vec: {e:?}")))
    }
}
