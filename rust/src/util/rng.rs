//! Deterministic pseudo-random number generation.
//!
//! Every experiment cell in the harness derives its own seed from the
//! workload id and cell coordinates via [`SplitMix64`], then runs a
//! [`Xoshiro256`] stream. This makes every generated number exactly
//! reproducible, independent of thread scheduling — the determinism
//! contract recorded in EXPERIMENTS.md §Determinism at the repo root.

/// SplitMix64 — used for seeding and for hashing experiment coordinates into
/// independent seeds. Reference: Steele, Lea & Flood, "Fast splittable
/// pseudorandom number generators", OOPSLA 2014.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new stream from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Hash an arbitrary list of coordinates into a single well-mixed seed.
    /// Used to derive per-cell experiment seeds: `seed_for(&[wl, cell, rep])`.
    pub fn seed_for(coords: &[u64]) -> u64 {
        let mut s = SplitMix64::new(0x5EED_CAFE_F00D_D00D);
        let mut acc = s.next_u64();
        for &c in coords {
            let mut t = SplitMix64::new(acc ^ c.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            acc = t.next_u64();
        }
        acc
    }
}

/// xoshiro256** 1.0 — the main generator. Blackman & Vigna, 2018.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of entropy.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's method without bias for our
    /// purposes; n is tiny compared to 2^64 so modulo bias is negligible,
    /// but we use the widening-multiply trick anyway).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let x = self.next_u64();
        (((x as u128) * (n as u128)) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_inclusive(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Log-uniform sample in `[lo, hi)` — used for the paper's interval
    /// sampling (I₁ = [10², 10³] etc. are ranges spanning decades, where
    /// log-uniform matches "choose a weight from the interval" without the
    /// top decade dominating).
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo > 0.0 && hi > lo);
        (self.uniform(lo.ln(), hi.ln())).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // Reference value for seed 1234567: first output of SplitMix64.
        let mut s = SplitMix64::new(1234567);
        let v = s.next_u64();
        let mut s2 = SplitMix64::new(1234567);
        assert_eq!(v, s2.next_u64());
        assert_ne!(v, 0);
    }

    #[test]
    fn seed_for_differs_by_coordinate() {
        let a = SplitMix64::seed_for(&[1, 2, 3]);
        let b = SplitMix64::seed_for(&[1, 2, 4]);
        let c = SplitMix64::seed_for(&[1, 2, 3]);
        assert_eq!(a, c);
        assert_ne!(a, b);
    }

    #[test]
    fn xoshiro_uniform_bounds() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.uniform(3.0, 5.0);
            assert!((3.0..5.0).contains(&y));
        }
    }

    #[test]
    fn xoshiro_below_is_in_range_and_covers() {
        let mut r = Xoshiro256::new(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let k = r.below(7);
            assert!(k < 7);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn xoshiro_mean_is_half() {
        let mut r = Xoshiro256::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn log_uniform_stays_in_interval() {
        let mut r = Xoshiro256::new(5);
        for _ in 0..10_000 {
            let x = r.log_uniform(1e2, 1e3);
            assert!((1e2..1e3).contains(&x), "x={x}");
        }
    }
}
