//! Minimal JSON value model, serializer and parser.
//!
//! Used for graph/platform interchange files and machine-readable experiment
//! summaries. Supports the full JSON grammar except `\u` surrogate pairs are
//! passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// numbers (always f64; integers round-trip up to 2^53)
    Num(f64),
    /// strings
    Str(String),
    /// arrays
    Arr(Vec<Json>),
    /// objects — BTreeMap for deterministic serialization order
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Get an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Number accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// usize accessor (lossy via f64).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // -0.0 must take the float path ("-0") — the i64 path would
                // print "0" and break the bit-exact round trip the service
                // layer's hashing relies on.
                if x.fract() == 0.0 && x.abs() < 9e15 && (*x != 0.0 || x.is_sign_positive()) {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse JSON text.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Nesting bound for the recursive-descent parser. Parsing runs on
/// service-handler threads against untrusted input, so recursion must be
/// bounded well below any thread's stack; legitimate payloads in this crate
/// nest single digits deep.
const MAX_DEPTH: usize = 256;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.pos;
            while let Some(c) = self.peek() {
                if c == b'"' || c == b'\\' {
                    break;
                }
                self.pos += 1;
            }
            s.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|e| e.to_string())?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or("truncated \\u escape")?,
                            )
                            .map_err(|e| e.to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.pos += 1;
                }
                _ => return Err("unterminated string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn enter(&mut self) -> Result<(), String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.pos));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.enter()?;
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] found {:?}", other)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.enter()?;
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} found {:?}", other)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let j = Json::obj(vec![
            ("n", Json::Num(128.0)),
            ("name", Json::Str("rgg-high".into())),
            (
                "edges",
                Json::Arr(vec![Json::Arr(vec![Json::Num(0.0), Json::Num(1.0)])]),
            ),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let text = j.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn parse_whitespace_and_escapes() {
        let j = Json::parse(" { \"a\\n\\\"b\" : [ 1 , -2.5e1, null ] } ").unwrap();
        let arr = j.get("a\n\"b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].as_f64(), Some(-25.0));
        assert_eq!(arr[2], Json::Null);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn negative_zero_roundtrips_bit_exact() {
        assert_eq!(Json::Num(-0.0).to_string(), "-0");
        let back = Json::parse("-0").unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits());
        // positive zero still uses the integer path
        assert_eq!(Json::Num(0.0).to_string(), "0");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{]").is_err());
        assert!(Json::parse("123 45").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn depth_limit_rejects_instead_of_overflowing() {
        // far beyond any legitimate payload, far below any thread stack
        let deep = "[".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.contains("nesting"), "{err}");
        // boundary: MAX_DEPTH levels parse fine
        let ok = format!("{}{}", "[".repeat(256), "]".repeat(256));
        assert!(Json::parse(&ok).is_ok());
        let too_deep = format!("{}{}", "[".repeat(257), "]".repeat(257));
        assert!(Json::parse(&too_deep).is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse("\"\\u0041\"").unwrap();
        assert_eq!(j.as_str(), Some("A"));
    }
}
