//! From-scratch substrates.
//!
//! This image is fully offline and only the `xla` crate closure is present in
//! the local registry, so the usual ecosystem crates (rayon, clap, serde,
//! criterion, proptest) are unavailable. Everything the system needs from
//! them is implemented here, purpose-built and tested:
//!
//! * [`rng`] — deterministic SplitMix64 / xoshiro256** PRNG streams.
//! * [`stats`] — mean / stddev / percentile / online accumulators.
//! * [`pool`] — a scoped thread pool with work queue (rayon substitute for
//!   the experiment sweeps).
//! * [`csv`] — CSV writer/reader for results files.
//! * [`json`] — a minimal JSON value model + serializer/parser for graph and
//!   result interchange.
//! * [`bench`] — a micro-benchmark harness (criterion substitute) used by
//!   `cargo bench` targets.
//! * [`prop`] — a property-test harness (proptest substitute): random input
//!   generation + shrinking-free counterexample reporting with fixed seeds.
//! * [`cli`] — a small declarative argument parser (clap substitute).
//! * [`hashing`] — FNV-1a structural hashing of graphs, platforms and cost
//!   matrices; the content addresses used by the service's intern tables
//!   and by [`crate::model::PlatformCtx`] (it lives here, below the model
//!   layer, so `model` never depends upward on `service`).
//! * [`aligned`] — 32-byte-aligned `f64` buffers for the SIMD min-plus
//!   lanes: the resident communication panels and the workspace DP tables
//!   allocate through it so lane loads never straddle a cache line.

pub mod aligned;
pub mod bench;
pub mod cli;
pub mod csv;
pub mod hashing;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
