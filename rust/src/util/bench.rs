//! Micro-benchmark harness — the criterion substitute.
//!
//! `cargo bench` targets in `rust/benches/` are plain `harness = false`
//! binaries built on this module. Each benchmark is warmed up, then run for
//! a fixed wall-clock budget, and reported as mean ± stddev with min/max,
//! in criterion-like one-line format. Results are also appended to a CSV so
//! the perf pass can diff before/after.

use crate::util::stats::Accumulator;
use std::time::{Duration, Instant};

/// One benchmark group, printing results as it goes.
pub struct Bench {
    name: String,
    /// minimum number of timed iterations
    min_iters: u32,
    /// wall-clock budget per benchmark
    budget: Duration,
    results: Vec<BenchResult>,
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// group/case identifier
    pub id: String,
    /// mean wall-clock per iteration, seconds
    pub mean_s: f64,
    /// sample stddev, seconds
    pub stddev_s: f64,
    /// fastest iteration
    pub min_s: f64,
    /// slowest iteration
    pub max_s: f64,
    /// timed iterations
    pub iters: u64,
    /// optional throughput denominator (elements per iteration)
    pub elements: Option<u64>,
}

impl BenchResult {
    /// elements/second if a throughput denominator was set.
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.mean_s)
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.2} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

impl Bench {
    /// New group. Budget defaults to 1s per case (override with
    /// `CEFT_BENCH_BUDGET_MS`); fast mode for CI via `CEFT_BENCH_FAST=1`.
    pub fn new(name: &str) -> Self {
        let ms = std::env::var("CEFT_BENCH_BUDGET_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(if std::env::var("CEFT_BENCH_FAST").is_ok() {
                150
            } else {
                1000
            });
        println!("\n== bench group: {name} ==");
        Self {
            name: name.to_string(),
            min_iters: 5,
            budget: Duration::from_millis(ms),
            results: Vec::new(),
        }
    }

    /// Time `f` (called once per iteration); returns (and stores) the result.
    pub fn case<F: FnMut()>(&mut self, id: &str, f: F) -> BenchResult {
        self.case_with_elements(id, None, f)
    }

    /// Time `f` with a throughput denominator (e.g. relaxation cells/iter).
    pub fn case_with_elements<F: FnMut()>(
        &mut self,
        id: &str,
        elements: Option<u64>,
        mut f: F,
    ) -> BenchResult {
        // warmup: one call (plus more if very fast)
        let t0 = Instant::now();
        f();
        let first = t0.elapsed();
        if first < self.budget / 20 {
            let n_warm = 3;
            for _ in 0..n_warm {
                f();
            }
        }
        // timed
        let mut acc = Accumulator::new();
        let start = Instant::now();
        let mut iters: u64 = 0;
        while iters < self.min_iters as u64 || start.elapsed() < self.budget {
            let t = Instant::now();
            f();
            acc.push(t.elapsed().as_secs_f64());
            iters += 1;
            if iters > 10_000_000 {
                break;
            }
        }
        let r = BenchResult {
            id: format!("{}/{}", self.name, id),
            mean_s: acc.mean(),
            stddev_s: acc.stddev(),
            min_s: acc.min(),
            max_s: acc.max(),
            iters,
            elements,
        };
        let thr = r
            .throughput()
            .map(|t| format!("  thrpt: {:.3} Melem/s", t / 1e6))
            .unwrap_or_default();
        println!(
            "{:<52} time: [{} ± {}]  ({} iters, min {}, max {}){}",
            r.id,
            fmt_time(r.mean_s),
            fmt_time(r.stddev_s),
            r.iters,
            fmt_time(r.min_s),
            fmt_time(r.max_s),
            thr
        );
        self.results.push(r.clone());
        r
    }

    /// All results recorded so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Append results to `target/ceft-bench.csv` for before/after diffing.
    pub fn save_csv(&self) {
        use std::io::Write as _;
        let path = std::path::Path::new("target/ceft-bench.csv");
        let add_header = !path.exists();
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
        {
            if add_header {
                let _ = writeln!(f, "id,mean_s,stddev_s,min_s,max_s,iters,elements");
            }
            for r in &self.results {
                let _ = writeln!(
                    f,
                    "{},{},{},{},{},{},{}",
                    r.id,
                    r.mean_s,
                    r.stddev_s,
                    r.min_s,
                    r.max_s,
                    r.iters,
                    r.elements.map(|e| e.to_string()).unwrap_or_default()
                );
            }
        }
    }
}

/// Prevent the optimizer from discarding a computed value
/// (std::hint::black_box re-export point for benches).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_result() {
        std::env::set_var("CEFT_BENCH_BUDGET_MS", "10");
        let mut b = Bench::new("unit");
        let mut acc = 0u64;
        let r = b.case("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters >= 5);
        assert!(r.mean_s >= 0.0);
        assert_eq!(b.results().len(), 1);
        std::env::remove_var("CEFT_BENCH_BUDGET_MS");
    }

    #[test]
    fn throughput_computed() {
        let r = BenchResult {
            id: "x".into(),
            mean_s: 0.5,
            stddev_s: 0.0,
            min_s: 0.5,
            max_s: 0.5,
            iters: 10,
            elements: Some(100),
        };
        assert_eq!(r.throughput(), Some(200.0));
    }

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(2e-9).contains("ns"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2.0).contains(" s"));
    }
}
