//! Small statistics toolkit used by the experiment harness and the
//! micro-benchmark harness.

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Merge another accumulator into this one (parallel reduction).
    pub fn merge(&mut self, other: &Accumulator) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Minimum observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Mean of a slice (NaN if empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation of a slice.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on sorted copy.
/// When taking several percentiles of one large sample, sort once and use
/// [`percentile_sorted`] instead — this clones and sorts per call.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// p-th percentile of an **already ascending-sorted** slice (linear
/// interpolation, same convention as [`percentile`]).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let f = rank - lo as f64;
        sorted[lo] * (1.0 - f) + sorted[hi] * f
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Geometric mean (for ratio metrics).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut a = Accumulator::new();
        for &x in &xs {
            a.push(x);
        }
        assert!((a.mean() - mean(&xs)).abs() < 1e-12);
        assert!((a.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 10.0);
        assert_eq!(a.count(), 5);
    }

    #[test]
    fn accumulator_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Accumulator::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut left = Accumulator::new();
        let mut right = Accumulator::new();
        for &x in &xs[..37] {
            left.push(x);
        }
        for &x in &xs[37..] {
            right.push(x);
        }
        left.merge(&right);
        assert!((left.mean() - whole.mean()).abs() < 1e-9);
        assert!((left.stddev() - whole.stddev()).abs() < 1e-9);
        assert_eq!(left.count(), whole.count());
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_sorted_matches_percentile() {
        let xs = [9.0, 1.0, 4.0, 7.0, 2.0];
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(percentile(&xs, p), percentile_sorted(&sorted, p));
        }
    }

    #[test]
    fn geomean_of_ratios() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_single() {
        assert!(mean(&[]).is_nan());
        assert_eq!(stddev(&[1.0]), 0.0);
        let mut a = Accumulator::new();
        assert!(a.mean().is_nan());
        a.push(3.0);
        assert_eq!(a.mean(), 3.0);
        assert_eq!(a.stddev(), 0.0);
    }
}
