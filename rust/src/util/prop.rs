//! Property-test harness — the proptest substitute.
//!
//! A property test generates `cases` random inputs from a deterministic seed
//! and checks an invariant for each. On failure, it reports the seed and
//! case index so the exact counterexample is reproducible with
//! `CEFT_PROP_SEED`/`CEFT_PROP_CASE`. We don't shrink; instead generators
//! are parameterised so failures are usually already small.

use crate::util::rng::Xoshiro256;

/// Default number of cases (override with `CEFT_PROP_CASES`).
pub fn default_cases() -> u32 {
    std::env::var("CEFT_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run a property: `gen` draws an input from the RNG, `check` returns
/// `Err(msg)` on violation. Panics with a reproduction line on failure.
pub fn check_property<T, G, C>(name: &str, cases: u32, base_seed: u64, mut gen: G, mut check: C)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Xoshiro256) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    let (seed, only_case) = overrides(base_seed);
    for case in 0..cases {
        if let Some(oc) = only_case {
            if case != oc {
                continue;
            }
        }
        let mut rng = Xoshiro256::new(seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property '{name}' failed at case {case}: {msg}\n\
                 reproduce with CEFT_PROP_SEED={seed} CEFT_PROP_CASE={case}\n\
                 input: {input:#?}"
            );
        }
    }
}

fn overrides(base_seed: u64) -> (u64, Option<u32>) {
    let seed = std::env::var("CEFT_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(base_seed);
    let case = std::env::var("CEFT_PROP_CASE")
        .ok()
        .and_then(|v| v.parse().ok());
    (seed, case)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_property(
            "reverse-reverse-id",
            32,
            42,
            |rng| {
                let n = rng.below(20);
                (0..n).map(|_| rng.next_u64() % 100).collect::<Vec<_>>()
            },
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                if &w == v {
                    Ok(())
                } else {
                    Err("reverse twice changed the vec".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        check_property(
            "always-fails",
            4,
            7,
            |rng| rng.next_u64(),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn deterministic_inputs_per_seed() {
        let mut first: Vec<u64> = Vec::new();
        check_property(
            "collect",
            8,
            99,
            |rng| rng.next_u64(),
            |x| {
                first.push(*x);
                Ok(())
            },
        );
        let mut second: Vec<u64> = Vec::new();
        check_property(
            "collect",
            8,
            99,
            |rng| rng.next_u64(),
            |x| {
                second.push(*x);
                Ok(())
            },
        );
        assert_eq!(first, second);
    }
}
