//! A scoped thread pool — the rayon substitute for experiment sweeps.
//!
//! The harness needs exactly one parallel primitive: "map this function over
//! a list of independent jobs on N threads and collect results in input
//! order". [`parallel_map`] provides it with a shared atomic cursor (so work
//! is dynamically balanced across threads even when job costs are skewed,
//! which they are: graph sizes span 128..16384 tasks).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: `CEFT_THREADS` env override, else the
/// available parallelism, else 4.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("CEFT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Dynamically-balanced parallel map preserving input order.
///
/// `f` must be `Sync` (it is shared by all workers); items are taken from a
/// shared cursor so long jobs don't serialise behind short ones.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots: Vec<Mutex<&mut Option<R>>> = out.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                **slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    drop(slots);
    out.into_iter().map(|r| r.expect("worker wrote slot")).collect()
}

/// Parallel for-each with a progress callback invoked (from worker threads)
/// after every completed item. Used by the coordinator to print progress.
pub fn parallel_for_each<T, F, P>(items: &[T], threads: usize, f: F, progress: P)
where
    T: Sync,
    F: Fn(usize, &T) + Sync,
    P: Fn(usize, usize) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    let cursor = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i, &items[i]);
                let d = done.fetch_add(1, Ordering::Relaxed) + 1;
                progress(d, n);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_thread_path() {
        let items = vec![1, 2, 3];
        let out = parallel_map(&items, 1, |i, &x| x + i);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn map_empty() {
        let items: Vec<u32> = vec![];
        let out: Vec<u32> = parallel_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn map_with_skewed_costs() {
        // long job first: dynamic balancing should still finish correctly
        let items: Vec<u64> = (0..64).collect();
        let out = parallel_map(&items, 4, |_, &x| {
            if x == 0 {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            x
        });
        assert_eq!(out, items);
    }

    #[test]
    fn for_each_counts_progress() {
        use std::sync::atomic::AtomicUsize;
        let items: Vec<u32> = (0..100).collect();
        let seen = AtomicUsize::new(0);
        let max_done = AtomicUsize::new(0);
        parallel_for_each(
            &items,
            4,
            |_, _| {
                seen.fetch_add(1, Ordering::Relaxed);
            },
            |done, total| {
                assert!(done <= total);
                max_done.fetch_max(done, Ordering::Relaxed);
            },
        );
        assert_eq!(seen.load(Ordering::Relaxed), 100);
        assert_eq!(max_done.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn threads_env_default_is_positive() {
        assert!(default_threads() >= 1);
    }
}
