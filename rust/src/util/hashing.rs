//! Structural hashing of problem objects for interning and memoization.
//!
//! The service keys its caches on *content*, not identity: two clients
//! submitting the same instance (or one client resubmitting) must land on
//! the same cache line — and [`crate::model::PlatformCtx`] carries the
//! same platform hash as its interned identity, which is why this module
//! lives in the `util` substrate (below `model`) and is re-exported as
//! `service::hashing`. Graphs, platforms and cost matrices are hashed over
//! a canonical byte encoding (FNV-1a, 64-bit) that covers every field the
//! algorithms read:
//!
//! * graph — task count + every edge `(src, dst, data-bits)` in stored
//!   order ([`crate::graph::TaskGraph`] preserves construction order, and
//!   [`crate::graph::io::instance_from_json`] rebuilds it in the serialized
//!   order, so a JSON round trip is hash-stable);
//! * platform — class count, startup latencies, the bandwidth matrix, and
//!   the two-weight capacities when present;
//! * comp — the dense `v × P` execution-cost matrix, bit-exact.
//!
//! f64 values are hashed by their IEEE bit pattern, matching the bit-exact
//! round-trip guarantee of [`crate::util::json`]'s shortest-decimal writer.

use crate::graph::TaskGraph;
use crate::platform::Platform;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64-bit hasher.
#[derive(Clone, Debug)]
pub struct Fnv64 {
    state: u64,
}

impl Fnv64 {
    /// Fresh hasher.
    pub fn new() -> Self {
        Self { state: FNV_OFFSET }
    }

    /// Absorb raw bytes.
    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
    }

    /// Absorb a `u64` (little-endian bytes).
    pub fn write_u64(&mut self, x: u64) {
        self.write_bytes(&x.to_le_bytes());
    }

    /// Absorb a `usize` (widened to `u64` for cross-platform stability).
    pub fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }

    /// Absorb an `f64` by IEEE-754 bit pattern.
    pub fn write_f64(&mut self, x: f64) {
        self.write_u64(x.to_bits());
    }

    /// Final digest.
    pub fn finish(&self) -> u64 {
        self.state
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

// Domain-separation tags so a graph and a platform that happen to encode to
// the same byte stream still hash differently.
const TAG_GRAPH: u64 = 0x4752_4150_4800_0001; // "GRAPH"
const TAG_PLATFORM: u64 = 0x504c_4154_4600_0002; // "PLATF"
const TAG_COMP: u64 = 0x434f_4d50_0000_0003; // "COMP"

/// Structural hash of a task graph (task count + ordered edge list).
pub fn hash_graph(g: &TaskGraph) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(TAG_GRAPH);
    h.write_usize(g.num_tasks());
    h.write_usize(g.num_edges());
    for e in g.edges() {
        h.write_usize(e.src);
        h.write_usize(e.dst);
        h.write_f64(e.data);
    }
    h.finish()
}

/// Structural hash of a platform (classes, startups, bandwidths, weights).
pub fn hash_platform(plat: &Platform) -> u64 {
    let p = plat.num_classes();
    let mut h = Fnv64::new();
    h.write_u64(TAG_PLATFORM);
    h.write_usize(p);
    for j in 0..p {
        h.write_f64(plat.startup(j));
    }
    for a in 0..p {
        for b in 0..p {
            h.write_f64(plat.bandwidth(a, b));
        }
    }
    let weights = plat.class_weight_table();
    h.write_usize(weights.len());
    for &(w0, w1) in weights {
        h.write_f64(w0);
        h.write_f64(w1);
    }
    h.finish()
}

/// Hash of a dense execution-cost matrix.
pub fn hash_comp(comp: &[f64]) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(TAG_COMP);
    h.write_usize(comp.len());
    for &c in comp {
        h.write_f64(c);
    }
    h.finish()
}

/// Combine component hashes into one (order-sensitive).
pub fn combine(parts: &[u64]) -> u64 {
    let mut h = Fnv64::new();
    for &p in parts {
        h.write_u64(p);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::io;
    use crate::util::json::Json;
    use crate::util::rng::Xoshiro256;

    fn sample_graph() -> TaskGraph {
        TaskGraph::from_edges(4, &[(0, 1, 1.5), (0, 2, 2.5), (1, 3, 3.5), (2, 3, 4.5)])
    }

    #[test]
    fn equal_structures_hash_equal() {
        assert_eq!(hash_graph(&sample_graph()), hash_graph(&sample_graph()));
        let a = Platform::uniform(3, 1.0, 0.5);
        let b = Platform::uniform(3, 1.0, 0.5);
        assert_eq!(hash_platform(&a), hash_platform(&b));
        assert_eq!(hash_comp(&[1.0, 2.0]), hash_comp(&[1.0, 2.0]));
    }

    #[test]
    fn perturbation_changes_hash() {
        let base = hash_graph(&sample_graph());
        let other =
            TaskGraph::from_edges(4, &[(0, 1, 1.5), (0, 2, 2.5), (1, 3, 3.5), (2, 3, 4.6)]);
        assert_ne!(base, hash_graph(&other));
        assert_ne!(
            hash_platform(&Platform::uniform(3, 1.0, 0.5)),
            hash_platform(&Platform::uniform(3, 1.0, 0.6))
        );
        assert_ne!(hash_comp(&[1.0, 2.0]), hash_comp(&[2.0, 1.0]));
        assert_ne!(combine(&[1, 2]), combine(&[2, 1]));
    }

    #[test]
    fn tags_separate_domains() {
        // an empty comp matrix must not collide with an empty-ish graph
        let empty_graph = TaskGraph::from_edges(1, &[]);
        assert_ne!(hash_graph(&empty_graph), hash_comp(&[]));
    }

    #[test]
    fn json_roundtrip_is_hash_stable() {
        let mut rng = Xoshiro256::new(31);
        let plat = Platform::two_weight(4, 0.5, &mut rng, 1.0, 0.0);
        let inst = crate::graph::generator::generate(
            &crate::graph::generator::RggParams {
                n: 48,
                out_degree: 3,
                ccr: 1.0,
                alpha: 0.5,
                beta_pct: 50.0,
                gamma: 0.25,
            },
            &crate::platform::CostModel::two_weight_low(0.5),
            &plat,
            7,
        );
        let text = io::instance_to_json(&inst).to_string();
        let back = io::instance_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(hash_graph(&inst.graph), hash_graph(&back.graph));
        assert_eq!(hash_comp(&inst.comp), hash_comp(&back.comp));

        let ptext = io::platform_to_json(&plat).to_string();
        let pback = io::platform_from_json(&Json::parse(&ptext).unwrap()).unwrap();
        assert_eq!(hash_platform(&plat), hash_platform(&pback));
    }
}
