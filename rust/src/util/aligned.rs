//! 32-byte-aligned `f64` buffers for the SIMD min-plus lanes.
//!
//! The hand-vectorised CEFT kernel ([`crate::cp::ceft::simd`]) streams
//! 4-wide `f64` lanes over the resident communication panels and the DP
//! table. `Vec<f64>` only guarantees 8-byte alignment, so a lane load can
//! straddle a cache-line boundary and split into two transfers. An
//! [`AlignedVec`] is a growable `f64` buffer whose data pointer is always
//! aligned to [`ALIGN`] (32 bytes — one AVX lane, half a cache line), so
//! lane loads that start at the buffer base never straddle a line.
//!
//! The implementation is entirely safe code: the buffer over-allocates a
//! plain `Vec<f64>` by up to [`ALIGN`]`/8 - 1` lead-in elements and exposes
//! the aligned window `buf[off..off + len]` through `Deref<Target = [f64]>`.
//! When the backing `Vec` reallocates (and may land at a different
//! alignment), the window is re-based and live elements are shifted with
//! `copy_within` — `O(len)` on growth only, exactly like `Vec`'s own
//! realloc copy. Alignment is re-asserted after every resize in debug
//! builds ([`AlignedVec::assert_aligned`]).
//!
//! Semantics mirror the `Vec` subset the workspace buffers use:
//! `clear` / `resize` / `extend_from_slice` keep capacity, lengths grow
//! monotonically to the high-water mark, and equality compares the live
//! window (so tests can diff an `AlignedVec` table against a `Vec` table).

use std::ops::{Deref, DerefMut};

/// Alignment of the live window, in bytes: one 4-lane `f64` SIMD register.
pub const ALIGN: usize = 32;

/// Maximum lead-in elements needed to realign an 8-byte-aligned base:
/// `ALIGN / size_of::<f64>() - 1`.
const LEAD: usize = ALIGN / std::mem::size_of::<f64>() - 1;

/// A growable `f64` buffer whose live window is always 32-byte aligned.
/// See the module docs for the layout and the safety-free realignment
/// strategy.
#[derive(Default)]
pub struct AlignedVec {
    /// backing storage; the live window is `buf[off..off + len]`
    buf: Vec<f64>,
    /// lead-in elements skipped so the window base is [`ALIGN`]-aligned
    off: usize,
    /// live elements
    len: usize,
}

impl AlignedVec {
    /// New empty buffer (no allocation until first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// Buffer of `len` copies of `value`, aligned.
    pub fn with_len(len: usize, value: f64) -> Self {
        let mut v = Self::new();
        v.resize(len, value);
        v
    }

    /// Lead-in offset (elements) that aligns `buf[off..]` to [`ALIGN`].
    fn aligned_off(buf: &[f64]) -> usize {
        let addr = buf.as_ptr() as usize;
        // Vec<f64> is always 8-byte aligned, so the remainder is a whole
        // number of elements in 0..=LEAD
        (ALIGN - addr % ALIGN) % ALIGN / std::mem::size_of::<f64>()
    }

    /// Grow the backing store to hold `total` live elements, re-basing the
    /// window (and moving the live prefix) if reallocation changed the
    /// base alignment.
    fn reserve_total(&mut self, total: usize) {
        if self.buf.len() < total + LEAD {
            self.buf.resize(total + LEAD, 0.0);
            let off = Self::aligned_off(&self.buf);
            if off != self.off {
                if self.len > 0 {
                    self.buf.copy_within(self.off..self.off + self.len, off);
                }
                self.off = off;
            }
        }
    }

    /// Drop every element, keeping capacity (like `Vec::clear`).
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Resize the live window to `new_len`, filling new elements with
    /// `value` (like `Vec::resize`).
    pub fn resize(&mut self, new_len: usize, value: f64) {
        self.reserve_total(new_len);
        if new_len > self.len {
            self.buf[self.off + self.len..self.off + new_len].fill(value);
        }
        self.len = new_len;
        self.assert_aligned();
    }

    /// Append a slice (like `Vec::extend_from_slice`).
    pub fn extend_from_slice(&mut self, xs: &[f64]) {
        let old = self.len;
        self.reserve_total(old + xs.len());
        self.buf[self.off + old..self.off + old + xs.len()].copy_from_slice(xs);
        self.len = old + xs.len();
        self.assert_aligned();
    }

    /// Live elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the window is empty.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Elements the buffer can hold without reallocating — the capacity
    /// gauge `Workspace::capacity_hint` and the reuse tests read.
    pub fn capacity(&self) -> usize {
        self.buf.capacity().saturating_sub(LEAD)
    }

    /// The live window as a slice (also available through `Deref`).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.buf[self.off..self.off + self.len]
    }

    /// The live window as a mutable slice.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.buf[self.off..self.off + self.len]
    }

    /// Debug-build check of the alignment invariant: a non-empty window
    /// always starts on an [`ALIGN`]-byte boundary.
    #[inline]
    pub fn assert_aligned(&self) {
        debug_assert!(
            self.len == 0 || self.as_slice().as_ptr() as usize % ALIGN == 0,
            "AlignedVec window lost its {ALIGN}-byte alignment"
        );
    }
}

impl Clone for AlignedVec {
    /// Clone by re-aligning against the new allocation's base — a derived
    /// clone would reuse the old offset on a differently-aligned buffer.
    fn clone(&self) -> Self {
        let mut v = Self::new();
        v.extend_from_slice(self.as_slice());
        v
    }
}

impl Deref for AlignedVec {
    type Target = [f64];

    #[inline]
    fn deref(&self) -> &[f64] {
        self.as_slice()
    }
}

impl DerefMut for AlignedVec {
    #[inline]
    fn deref_mut(&mut self) -> &mut [f64] {
        self.as_mut_slice()
    }
}

impl PartialEq for AlignedVec {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Vec<f64>> for AlignedVec {
    fn eq(&self, other: &Vec<f64>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<AlignedVec> for Vec<f64> {
    fn eq(&self, other: &AlignedVec) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::fmt::Debug for AlignedVec {
    /// Print the live window only (the lead-in is uninitialised noise).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.as_slice()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_is_aligned_across_growth() {
        let mut v = AlignedVec::new();
        for n in [1usize, 3, 4, 5, 31, 32, 1000, 4096] {
            v.resize(n, 1.5);
            assert_eq!(v.len(), n);
            assert_eq!(v.as_slice().as_ptr() as usize % ALIGN, 0, "len {n}");
            assert!(v.iter().all(|&x| x == 1.5 || x == 0.0));
        }
    }

    #[test]
    fn resize_preserves_prefix_and_fills_suffix() {
        let mut v = AlignedVec::new();
        v.resize(4, 2.0);
        v[0] = 9.0;
        // grow far enough to force reallocation (and possibly re-basing)
        v.resize(10_000, 7.0);
        assert_eq!(v[0], 9.0);
        assert_eq!(&v[1..4], &[2.0, 2.0, 2.0]);
        assert!(v[4..].iter().all(|&x| x == 7.0));
        assert_eq!(v.as_slice().as_ptr() as usize % ALIGN, 0);
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut v = AlignedVec::new();
        v.resize(1024, 0.0);
        let cap = v.capacity();
        assert!(cap >= 1024);
        v.clear();
        assert!(v.is_empty());
        assert_eq!(v.capacity(), cap);
        // refilling after clear is still aligned
        v.resize(8, 3.0);
        assert_eq!(v.as_slice().as_ptr() as usize % ALIGN, 0);
    }

    #[test]
    fn extend_from_slice_appends_aligned() {
        let mut v = AlignedVec::new();
        let mut expect = Vec::new();
        for chunk in 0..50 {
            let xs: Vec<f64> = (0..7).map(|i| (chunk * 7 + i) as f64).collect();
            v.extend_from_slice(&xs);
            expect.extend_from_slice(&xs);
            assert_eq!(v.as_slice().as_ptr() as usize % ALIGN, 0, "chunk {chunk}");
        }
        assert_eq!(v, expect);
    }

    #[test]
    fn clone_realigns_on_the_new_allocation() {
        let mut a = AlignedVec::new();
        a.extend_from_slice(&[5.0, 6.0, 7.0, 8.0, 9.0]);
        let b = a.clone();
        assert_eq!(a, b);
        assert_eq!(b.as_slice().as_ptr() as usize % ALIGN, 0);
    }

    #[test]
    fn equality_against_vec_and_self() {
        let mut a = AlignedVec::new();
        a.extend_from_slice(&[1.0, 2.0, 3.0]);
        let mut b = AlignedVec::new();
        b.resize(3, 0.0);
        b.copy_from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(a, b);
        assert_eq!(a, vec![1.0, 2.0, 3.0]);
        assert_eq!(vec![1.0, 2.0, 3.0], a);
        b[2] = 4.0;
        assert!(a != b);
    }
}
