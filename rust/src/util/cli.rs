//! Declarative command-line argument parsing — the clap substitute.
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! auto-generated `--help`. Just enough for the `repro` binary and examples.

use std::collections::BTreeMap;

/// Specification of one option.
#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// An argument parser for one (sub)command.
#[derive(Clone, Debug)]
pub struct Args {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
    positional: Vec<(String, String)>,
    values: BTreeMap<String, String>,
    pos_values: Vec<String>,
}

impl Args {
    /// New parser for `program` with a one-line description.
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            positional: Vec::new(),
            values: BTreeMap::new(),
            pos_values: Vec::new(),
        }
    }

    /// Declare `--name <value>` with optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(String::from),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Declare a positional argument (all required, in order).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positional.push((name.to_string(), help.to_string()));
        self
    }

    /// Render help text.
    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (p, _) in &self.positional {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positional.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positional {
                s.push_str(&format!("  <{p:<12}> {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let metavar = if o.is_flag {
                    format!("--{}", o.name)
                } else {
                    format!("--{} <v>", o.name)
                };
                let def = o
                    .default
                    .as_ref()
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                s.push_str(&format!("  {metavar:<24} {}{def}\n", o.help));
            }
        }
        s
    }

    /// Parse a token list (no program name). Returns Err(help) on `--help`
    /// or error text on bad input.
    pub fn parse(mut self, tokens: &[String]) -> Result<Parsed, String> {
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if t == "--help" || t == "-h" {
                return Err(self.help_text());
            }
            if let Some(rest) = t.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.help_text()))?
                    .clone();
                let val = if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    "true".to_string()
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    tokens
                        .get(i)
                        .cloned()
                        .ok_or_else(|| format!("option --{key} requires a value"))?
                };
                self.values.insert(key, val);
            } else {
                if self.pos_values.len() >= self.positional.len() {
                    return Err(format!(
                        "unexpected positional argument {t:?}\n\n{}",
                        self.help_text()
                    ));
                }
                self.pos_values.push(t.clone());
            }
            i += 1;
        }
        if self.pos_values.len() < self.positional.len() {
            return Err(format!(
                "missing required argument <{}>\n\n{}",
                self.positional[self.pos_values.len()].0,
                self.help_text()
            ));
        }
        // fill defaults
        for o in &self.opts {
            if !o.is_flag && !self.values.contains_key(&o.name) {
                if let Some(d) = &o.default {
                    self.values.insert(o.name.clone(), d.clone());
                }
            }
        }
        Ok(Parsed {
            values: self.values,
            pos_values: self.pos_values,
            pos_names: self.positional.into_iter().map(|(n, _)| n).collect(),
        })
    }
}

/// Parsed argument values.
#[derive(Clone, Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pos_values: Vec<String>,
    pos_names: Vec<String>,
}

impl Parsed {
    /// String value of an option or positional by name.
    pub fn get(&self, name: &str) -> Option<&str> {
        if let Some(v) = self.values.get(name) {
            return Some(v);
        }
        self.pos_names
            .iter()
            .position(|n| n == name)
            .and_then(|i| self.pos_values.get(i))
            .map(|s| s.as_str())
    }

    /// Required string value (panics with a clear message when absent).
    pub fn req(&self, name: &str) -> &str {
        self.get(name)
            .unwrap_or_else(|| panic!("missing required option --{name}"))
    }

    /// Typed accessor.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Option<T> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    /// Boolean flag presence.
    pub fn is_set(&self, name: &str) -> bool {
        self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let p = Args::new("demo", "test")
            .positional("cmd", "the command")
            .opt("n", Some("128"), "tasks")
            .opt("out", None, "output file")
            .flag("verbose", "chatty")
            .parse(&toks(&["run", "--n", "256", "--verbose", "--out=x.csv"]))
            .unwrap();
        assert_eq!(p.get("cmd"), Some("run"));
        assert_eq!(p.get_parse::<u32>("n"), Some(256));
        assert_eq!(p.get("out"), Some("x.csv"));
        assert!(p.is_set("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let p = Args::new("demo", "t")
            .opt("n", Some("128"), "tasks")
            .parse(&[])
            .unwrap();
        assert_eq!(p.get_parse::<u32>("n"), Some(128));
    }

    #[test]
    fn unknown_option_is_error() {
        let e = Args::new("demo", "t").parse(&toks(&["--wat"])).unwrap_err();
        assert!(e.contains("unknown option"));
    }

    #[test]
    fn missing_positional_is_error() {
        let e = Args::new("demo", "t")
            .positional("cmd", "c")
            .parse(&[])
            .unwrap_err();
        assert!(e.contains("missing required argument"));
    }

    #[test]
    fn help_lists_everything() {
        let e = Args::new("demo", "about-me")
            .positional("cmd", "the command")
            .opt("n", Some("1"), "count")
            .flag("fast", "go fast")
            .parse(&toks(&["--help"]))
            .unwrap_err();
        for needle in ["about-me", "<cmd", "--n", "--fast", "default: 1"] {
            assert!(e.contains(needle), "help missing {needle}: {e}");
        }
    }

    #[test]
    fn flag_with_value_is_error() {
        let e = Args::new("demo", "t")
            .flag("fast", "f")
            .parse(&toks(&["--fast=yes"]))
            .unwrap_err();
        assert!(e.contains("takes no value"));
    }
}
