//! Minimal CSV writer/reader for experiment results.
//!
//! The harness writes plain RFC-4180-ish CSV (quoting only when needed) and
//! reads back its own output; this is not a general-purpose CSV library.

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// A CSV table: header + rows of stringly-typed cells.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Table {
    /// Column names.
    pub header: Vec<String>,
    /// Row-major cells; each row has `header.len()` cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column names.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the arity mismatches the header.
    pub fn push_row<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Serialize to CSV text.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        write_record(&mut s, &self.header);
        for row in &self.rows {
            write_record(&mut s, row);
        }
        s
    }

    /// Write to a file, creating parent directories.
    pub fn write_file<P: AsRef<Path>>(&self, path: P) -> io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }

    /// Parse CSV text (must have a header line).
    pub fn from_csv(text: &str) -> Result<Table, String> {
        let mut records = parse_csv(text)?;
        if records.is_empty() {
            return Err("empty csv".into());
        }
        let header = records.remove(0);
        for (i, r) in records.iter().enumerate() {
            if r.len() != header.len() {
                return Err(format!(
                    "row {} has {} cells, header has {}",
                    i,
                    r.len(),
                    header.len()
                ));
            }
        }
        Ok(Table {
            header,
            rows: records,
        })
    }

    /// Render as an aligned ASCII table (for terminal output).
    pub fn to_ascii(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            for w in &widths {
                let _ = write!(out, "+{}", "-".repeat(w + 2));
            }
            out.push_str("+\n");
        };
        sep(&mut out);
        for (i, h) in self.header.iter().enumerate() {
            let _ = write!(out, "| {:>w$} ", h, w = widths[i]);
        }
        out.push_str("|\n");
        sep(&mut out);
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                let _ = write!(out, "| {:>w$} ", c, w = widths[i]);
            }
            out.push_str("|\n");
        }
        sep(&mut out);
        out
    }
}

fn needs_quoting(s: &str) -> bool {
    s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r')
}

fn write_record(out: &mut String, cells: &[String]) {
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if needs_quoting(c) {
            out.push('"');
            out.push_str(&c.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(c);
        }
    }
    out.push('\n');
}

fn parse_csv(text: &str) -> Result<Vec<Vec<String>>, String> {
    let mut records = Vec::new();
    let mut record = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;
    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                _ => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => {
                    record.push(std::mem::take(&mut field));
                }
                '\r' => {}
                '\n' => {
                    record.push(std::mem::take(&mut field));
                    records.push(std::mem::take(&mut record));
                }
                _ => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err("unterminated quote".into());
    }
    if any && (!field.is_empty() || !record.is_empty()) {
        record.push(field);
        records.push(record);
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let mut t = Table::new(vec!["a", "b"]);
        t.push_row(vec!["1", "x"]);
        t.push_row(vec!["2", "y"]);
        let parsed = Table::from_csv(&t.to_csv()).unwrap();
        assert_eq!(parsed, t);
    }

    #[test]
    fn roundtrip_quoted() {
        let mut t = Table::new(vec!["name", "note"]);
        t.push_row(vec!["a,b", "say \"hi\"\nnewline"]);
        let parsed = Table::from_csv(&t.to_csv()).unwrap();
        assert_eq!(parsed, t);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.push_row(vec!["only-one"]);
    }

    #[test]
    fn col_lookup() {
        let t = Table::new(vec!["x", "y", "z"]);
        assert_eq!(t.col("y"), Some(1));
        assert_eq!(t.col("nope"), None);
    }

    #[test]
    fn ascii_render_contains_cells() {
        let mut t = Table::new(vec!["metric", "value"]);
        t.push_row(vec!["slr", "1.25"]);
        let a = t.to_ascii();
        assert!(a.contains("slr"));
        assert!(a.contains("1.25"));
        assert!(a.contains('+'));
    }

    #[test]
    fn parse_rejects_ragged() {
        let err = Table::from_csv("a,b\n1\n").unwrap_err();
        assert!(err.contains("cells"));
    }

    #[test]
    fn parse_handles_missing_trailing_newline() {
        let t = Table::from_csv("a,b\n1,2").unwrap();
        assert_eq!(t.rows, vec![vec!["1".to_string(), "2".to_string()]]);
    }
}
