//! `repro` — the CEFT command-line driver.
//!
//! Subcommands:
//!
//! * `repro experiment <id>` — regenerate a paper table/figure
//!   (`table3`, `fig7`..`fig20`, or `all`) at a chosen `--scale`.
//! * `repro schedule` — generate one instance and print every algorithm's
//!   schedule metrics (quick inspection of a single cell).
//! * `repro cp` — print the CEFT critical path (with assignment) of one
//!   instance next to CPOP's estimate.
//! * `repro gengraph` — emit a generated instance as JSON or DOT.
//! * `repro runtime-check` — load the PJRT artifacts and cross-validate the
//!   accelerated CEFT backend against the pure-rust one.

use ceft::coordinator::{Coordinator, EXPERIMENT_IDS};
use ceft::cp::ceft::find_critical_path;
use ceft::cp::ranks::cpop_critical_path;
use ceft::exp::cells::{grid, Scale, Workload};
use ceft::exp::run::{build_instance, run_cell, ALGOS};
use ceft::graph::io;
use ceft::util::cli::Args;
use ceft::sched::Scheduler as _;
use ceft::util::pool;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{}", usage());
        std::process::exit(2);
    }
    let cmd = argv[0].as_str();
    let rest = &argv[1..];
    let code = match cmd {
        "experiment" => cmd_experiment(rest),
        "schedule" => cmd_schedule(rest),
        "cp" => cmd_cp(rest),
        "gengraph" => cmd_gengraph(rest),
        "runtime-check" => cmd_runtime_check(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            0
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{}", usage());
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    format!(
        "repro — CEFT critical paths & schedules on heterogeneous systems\n\n\
         USAGE:\n  repro <command> [options]\n\n\
         COMMANDS:\n\
         \x20 experiment <id>   regenerate a paper table/figure ({})\n\
         \x20 schedule          run every scheduler on one generated instance\n\
         \x20 cp                print CEFT vs CPOP critical paths for one instance\n\
         \x20 gengraph          emit a generated instance (JSON or DOT)\n\
         \x20 runtime-check     validate the PJRT artifact backend\n\n\
         Run `repro <command> --help` for options.",
        EXPERIMENT_IDS.join("|")
    )
}

fn parse_or_exit(args: Args, tokens: &[String]) -> ceft::util::cli::Parsed {
    match args.parse(tokens) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

fn workload_of(name: &str) -> Workload {
    match name {
        "rgg-classic" | "classic" => Workload::RggClassic,
        "rgg-low" | "low" => Workload::RggLow,
        "rgg-medium" | "medium" => Workload::RggMedium,
        "rgg-high" | "high" => Workload::RggHigh,
        other => {
            eprintln!("unknown workload {other:?} (rgg-classic|rgg-low|rgg-medium|rgg-high)");
            std::process::exit(2);
        }
    }
}

fn cmd_experiment(tokens: &[String]) -> i32 {
    let args = Args::new("repro experiment", "regenerate a paper table/figure")
        .positional("id", "table3 | fig7..fig20 | all")
        .opt("scale", Some("paper-small"), "full | paper-small | smoke")
        .opt("threads", None, "worker threads (default: all cores)")
        .opt("out", Some("results"), "output directory for CSVs")
        .flag("quiet", "suppress progress output");
    let p = parse_or_exit(args, tokens);
    let id = p.req("id").to_string();
    if !EXPERIMENT_IDS.contains(&id.as_str()) {
        eprintln!("unknown experiment id {id:?}; valid: {}", EXPERIMENT_IDS.join(", "));
        return 2;
    }
    let scale = match Scale::parse(p.req("scale")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let threads = p
        .get_parse::<usize>("threads")
        .unwrap_or_else(pool::default_threads);
    let mut coord = Coordinator::new(
        threads,
        scale,
        p.req("out").into(),
        !p.is_set("quiet"),
    );
    let produced = match coord.produce_and_write(&id) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("write failed: {e}");
            return 1;
        }
    };
    for t in &produced {
        println!("\n# {}", t.name);
        print!("{}", t.table.to_ascii());
    }
    0
}

/// Shared instance options for `schedule`, `cp`, `gengraph`.
fn instance_args(program: &str, about: &str) -> Args {
    Args::new(program, about)
        .opt("workload", Some("rgg-high"), "rgg-classic|rgg-low|rgg-medium|rgg-high")
        .opt("n", Some("128"), "number of tasks")
        .opt("out-degree", Some("4"), "average out-degree")
        .opt("ccr", Some("1.0"), "communication-to-computation ratio")
        .opt("alpha", Some("0.5"), "shape parameter")
        .opt("beta", Some("50"), "heterogeneity percent")
        .opt("gamma", Some("0.25"), "skewness")
        .opt("p", Some("8"), "number of processors")
        .opt("seed", Some("0"), "cell index / seed")
        .flag("gantt", "render a Gantt chart of the CEFT-CPOP schedule")
}

fn cell_from(p: &ceft::util::cli::Parsed) -> ceft::exp::cells::Cell {
    ceft::exp::cells::Cell {
        workload: workload_of(p.req("workload")),
        n: p.get_parse("n").unwrap(),
        out_degree: p.get_parse("out-degree").unwrap(),
        ccr: p.get_parse("ccr").unwrap(),
        alpha: p.get_parse("alpha").unwrap(),
        beta_pct: p.get_parse("beta").unwrap(),
        gamma: p.get_parse("gamma").unwrap(),
        p: p.get_parse("p").unwrap(),
        index: p.get_parse("seed").unwrap(),
    }
}

fn cmd_schedule(tokens: &[String]) -> i32 {
    let args = instance_args("repro schedule", "run every scheduler on one instance");
    let parsed = parse_or_exit(args, tokens);
    let cell = cell_from(&parsed);
    let row = run_cell(&cell);
    println!(
        "instance: {} n={} p={} ccr={} alpha={} beta={} gamma={}",
        row.workload, row.n, row.p, row.ccr, row.alpha, row.beta_pct, row.gamma
    );
    println!(
        "CPL: ceft={:.2} cpop_est={:.2} cpop_realized={:.2} minexec={:.2} cp_min={:.2}",
        row.cpl_ceft, row.cpl_cpop, row.cpl_cpop_realized, row.cpl_minexec, row.cp_min
    );
    let mut t = ceft::util::csv::Table::new(vec![
        "algorithm", "makespan", "speedup", "slr", "slack",
    ]);
    for (i, a) in ALGOS.iter().enumerate() {
        let r = &row.algos[i];
        t.push_row(vec![
            a.to_string(),
            format!("{:.2}", r.makespan),
            format!("{:.3}", r.speedup),
            format!("{:.3}", r.slr),
            format!("{:.2}", r.slack),
        ]);
    }
    print!("{}", t.to_ascii());
    if parsed.is_set("gantt") {
        let (platform, inst) = build_instance(&cell);
        let s = ceft::sched::ceft_cpop::CeftCpop.schedule(&inst.graph, &platform, &inst.comp);
        println!("\nCEFT-CPOP Gantt:");
        print!("{}", ceft::sched::gantt::render(&s, 100));
    }
    0
}

fn cmd_cp(tokens: &[String]) -> i32 {
    let args = instance_args("repro cp", "print CEFT vs CPOP critical paths");
    let parsed = parse_or_exit(args, tokens);
    let cell = cell_from(&parsed);
    let (platform, inst) = build_instance(&cell);
    let ceft_cp = find_critical_path(&inst.graph, &platform, &inst.comp);
    let (cpop_cp, cpop_len) = cpop_critical_path(&inst.graph, &platform, &inst.comp);
    println!("CEFT critical path (length {:.2}):", ceft_cp.length);
    for s in &ceft_cp.path {
        println!("  task {:>5} -> class {}", s.task, s.class);
    }
    println!("\nCPOP critical path (mean-value estimate {cpop_len:.2}):");
    println!(
        "  tasks: {:?} (all pinned to one processor by CPOP)",
        cpop_cp
    );
    0
}

fn cmd_gengraph(tokens: &[String]) -> i32 {
    let args = instance_args("repro gengraph", "emit a generated instance")
        .opt("format", Some("json"), "json | dot");
    let parsed = parse_or_exit(args, tokens);
    let cell = cell_from(&parsed);
    let (platform, inst) = build_instance(&cell);
    match parsed.req("format") {
        "json" => println!("{}", io::instance_to_json(&inst).to_string()),
        "dot" => {
            let cp = find_critical_path(&inst.graph, &platform, &inst.comp);
            print!("{}", io::to_dot(&inst.graph, &cp.tasks()));
        }
        other => {
            eprintln!("unknown format {other:?}");
            return 2;
        }
    }
    0
}

fn cmd_runtime_check(tokens: &[String]) -> i32 {
    let args = Args::new(
        "repro runtime-check",
        "load PJRT artifacts and cross-validate vs pure-rust CEFT",
    )
    .opt("p", Some("8"), "processor count (artifact to test)")
    .opt("n", Some("128"), "tasks in the validation instance");
    let parsed = parse_or_exit(args, tokens);
    let p: usize = parsed.get_parse("p").unwrap();
    let n: usize = parsed.get_parse("n").unwrap();
    let rt = match ceft::runtime::PjrtRuntime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT client failed: {e}");
            return 1;
        }
    };
    println!("PJRT platform: {}", rt.platform_name());
    if !rt.has_artifact(p) {
        eprintln!(
            "artifact {} missing — run `make artifacts` first",
            ceft::runtime::artifact_name(p)
        );
        return 1;
    }
    let acc = ceft::runtime::AcceleratedCeft::new(rt);
    let cells = grid(Workload::RggClassic, Scale::Smoke);
    let mut cell = cells[0];
    cell.n = n;
    cell.p = p;
    let (platform, inst) = build_instance(&cell);
    let cpu = find_critical_path(&inst.graph, &platform, &inst.comp);
    match acc.find_critical_path(&inst.graph, &platform, &inst.comp) {
        Ok(accel) => {
            let rel = (cpu.length - accel.length).abs() / cpu.length.max(1e-12);
            println!(
                "pure-rust CPL = {:.4}, accelerated CPL = {:.4}, rel diff = {:.2e}",
                cpu.length, accel.length, rel
            );
            if rel < 1e-4 && cpu.tasks() == accel.tasks() {
                println!("runtime-check OK (paths identical, lengths within f32 tolerance)");
                0
            } else {
                eprintln!("runtime-check FAILED");
                1
            }
        }
        Err(e) => {
            eprintln!("accelerated CEFT failed: {e}");
            1
        }
    }
}
