//! `repro` — the CEFT command-line driver.
//!
//! Subcommands:
//!
//! * `repro experiment <id>` — regenerate a paper table/figure
//!   (`table3`, `fig7`..`fig20`, or `all`) at a chosen `--scale`.
//! * `repro schedule` — generate one instance and print every algorithm's
//!   schedule metrics (quick inspection of a single cell).
//! * `repro cp` — print the CEFT critical path (with assignment) of one
//!   instance next to CPOP's estimate.
//! * `repro gengraph` — emit a generated instance as JSON or DOT.
//! * `repro runtime-check` — load the PJRT artifacts and cross-validate the
//!   accelerated CEFT backend against the pure-rust one.
//! * `repro serve` — run the online scheduling engine (stdin/stdout or TCP);
//!   `--metrics-addr` adds a Prometheus-style HTTP metrics endpoint,
//!   `--fault-plan` arms seeded fault injection (kernel panics, stage
//!   delays, connection drops) and `--admission-budget` pins the overload
//!   governor's per-shard miss budget.
//! * `repro request` — send one protocol request to a running server
//!   (`--op trace` pretty-prints the per-stage latency tables, `--op
//!   metrics` dumps the text exposition); `--deadline-ms` attaches a
//!   request budget and `--retries` retries transport errors and
//!   shed/deadline/panic refusals with jittered exponential backoff.
//! * `repro loadgen` — replay generated instances against an in-process
//!   engine at a target rate; reports requests/sec, p50/p95/p99 per-request
//!   latency, cache hit rate, panel-context counters
//!   (`--platform-mix K` round-robins K distinct platforms across the mix
//!   to exercise the per-platform panel cache) and cross-request
//!   batch-efficiency (`--cp-share` sets how much of the mix is
//!   critical-path traffic; both cp and schedule misses gather into the
//!   shared table sweeps, so a comma list like `0.0,0.25,1.0` sweeps the
//!   workload mix and reports one point per value), validates the
//!   telemetry stage taxonomy, runs a telemetry on/off A/B throughput
//!   pass, and writes `BENCH_service.json` (including the per-stage
//!   latency percentiles and `telemetry_overhead_pct`) so the perf
//!   trajectory is tracked across PRs. `--clients` sets dispatch
//!   concurrency; the default (2× worker threads) oversubscribes the
//!   pool so the engine's saturation gate actually opens. `--shape
//!   layered|fork-join|pipeline|mix` picks the instance family —
//!   structured families route through the series-parallel tree-DP fast
//!   path, and the report records `shape_fast_path_hits` /
//!   `shape_general_fallbacks` plus per-shape p99 latency. `--chaos`
//!   appends an overload/fault pass — seeded fault injection plus
//!   per-request deadlines at 4× dispatch oversubscription against a
//!   fault-free baseline twin — gated on availability ≥ 99%, bit-identical
//!   surviving (and post-fault) results, and a served-p99 ceiling, with
//!   `availability_pct` / `shed_requests` / `deadline_expired` /
//!   `panics_caught` recorded in every report entry.

use ceft::coordinator::{Coordinator, EXPERIMENT_IDS};
use ceft::cp::ceft::find_critical_path;
use ceft::cp::ranks::cpop_critical_path;
use ceft::exp::cells::{grid, Scale, Workload};
use ceft::exp::run::{build_instance, run_cell, ALGOS};
use ceft::graph::io;
use ceft::sched::{Algorithm, Scheduler as _};
use ceft::service::{serve_stdio, Engine, EngineConfig, FaultPlan, Request, Server, Target};
use ceft::util::cli::Args;
use ceft::util::json::Json;
use ceft::util::pool;
use std::io::{BufRead as _, BufReader, Read as _, Write as _};
use std::sync::Arc;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{}", usage());
        std::process::exit(2);
    }
    let cmd = argv[0].as_str();
    let rest = &argv[1..];
    let code = match cmd {
        "experiment" => cmd_experiment(rest),
        "schedule" => cmd_schedule(rest),
        "cp" => cmd_cp(rest),
        "gengraph" => cmd_gengraph(rest),
        "runtime-check" => cmd_runtime_check(rest),
        "serve" => cmd_serve(rest),
        "request" => cmd_request(rest),
        "loadgen" => cmd_loadgen(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            0
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{}", usage());
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    format!(
        "repro — CEFT critical paths & schedules on heterogeneous systems\n\n\
         USAGE:\n  repro <command> [options]\n\n\
         COMMANDS:\n\
         \x20 experiment <id>   regenerate a paper table/figure ({})\n\
         \x20 schedule          run every scheduler on one generated instance\n\
         \x20 cp                print CEFT vs CPOP critical paths for one instance\n\
         \x20 gengraph          emit a generated instance (JSON or DOT)\n\
         \x20 runtime-check     validate the PJRT artifact backend\n\
         \x20 serve             run the online scheduling engine (stdio or TCP)\n\
         \x20 request           send one request to a running `repro serve`\n\
         \x20 loadgen           measure engine requests/sec at a target rate\n\n\
         Run `repro <command> --help` for options.",
        EXPERIMENT_IDS.join("|")
    )
}

fn parse_or_exit(args: Args, tokens: &[String]) -> ceft::util::cli::Parsed {
    match args.parse(tokens) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

/// Parse `--name`'s numeric value, exiting with a message on malformed
/// input (rather than silently falling back to a default). When the option
/// was not given at all, `missing` supplies the value.
fn num_or_exit<T: std::str::FromStr>(
    parsed: &ceft::util::cli::Parsed,
    name: &str,
    missing: Option<T>,
) -> T {
    match parsed.get(name) {
        Some(v) => match v.parse::<T>() {
            Ok(x) => x,
            Err(_) => {
                eprintln!("invalid value for --{name}: {v:?}");
                std::process::exit(2);
            }
        },
        None => match missing {
            Some(d) => d,
            None => {
                eprintln!("missing required option --{name}");
                std::process::exit(2);
            }
        },
    }
}

fn workload_of(name: &str) -> Workload {
    match name {
        "rgg-classic" | "classic" => Workload::RggClassic,
        "rgg-low" | "low" => Workload::RggLow,
        "rgg-medium" | "medium" => Workload::RggMedium,
        "rgg-high" | "high" => Workload::RggHigh,
        other => {
            eprintln!("unknown workload {other:?} (rgg-classic|rgg-low|rgg-medium|rgg-high)");
            std::process::exit(2);
        }
    }
}

fn cmd_experiment(tokens: &[String]) -> i32 {
    let args = Args::new("repro experiment", "regenerate a paper table/figure")
        .positional("id", "table3 | fig7..fig20 | all")
        .opt("scale", Some("paper-small"), "full | paper-small | smoke")
        .opt("threads", None, "worker threads (default: all cores)")
        .opt("out", Some("results"), "output directory for CSVs")
        .flag("quiet", "suppress progress output");
    let p = parse_or_exit(args, tokens);
    let id = p.req("id").to_string();
    if !EXPERIMENT_IDS.contains(&id.as_str()) {
        eprintln!("unknown experiment id {id:?}; valid: {}", EXPERIMENT_IDS.join(", "));
        return 2;
    }
    let scale = match Scale::parse(p.req("scale")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let threads = p
        .get_parse::<usize>("threads")
        .unwrap_or_else(pool::default_threads);
    let mut coord = Coordinator::new(
        threads,
        scale,
        p.req("out").into(),
        !p.is_set("quiet"),
    );
    let produced = match coord.produce_and_write(&id) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("write failed: {e}");
            return 1;
        }
    };
    for t in &produced {
        println!("\n# {}", t.name);
        print!("{}", t.table.to_ascii());
    }
    0
}

/// Shared instance options for `schedule`, `cp`, `gengraph`.
fn instance_args(program: &str, about: &str) -> Args {
    Args::new(program, about)
        .opt("workload", Some("rgg-high"), "rgg-classic|rgg-low|rgg-medium|rgg-high")
        .opt("n", Some("128"), "number of tasks")
        .opt("out-degree", Some("4"), "average out-degree")
        .opt("ccr", Some("1.0"), "communication-to-computation ratio")
        .opt("alpha", Some("0.5"), "shape parameter")
        .opt("beta", Some("50"), "heterogeneity percent")
        .opt("gamma", Some("0.25"), "skewness")
        .opt("p", Some("8"), "number of processors")
        .opt("seed", Some("0"), "cell index / seed")
        .flag("gantt", "render a Gantt chart of the CEFT-CPOP schedule")
}

fn cell_from(p: &ceft::util::cli::Parsed) -> ceft::exp::cells::Cell {
    ceft::exp::cells::Cell {
        workload: workload_of(p.req("workload")),
        n: num_or_exit(p, "n", None),
        out_degree: num_or_exit(p, "out-degree", None),
        ccr: num_or_exit(p, "ccr", None),
        alpha: num_or_exit(p, "alpha", None),
        beta_pct: num_or_exit(p, "beta", None),
        gamma: num_or_exit(p, "gamma", None),
        p: num_or_exit(p, "p", None),
        index: num_or_exit(p, "seed", None),
    }
}

fn cmd_schedule(tokens: &[String]) -> i32 {
    let args = instance_args("repro schedule", "run every scheduler on one instance");
    let parsed = parse_or_exit(args, tokens);
    let cell = cell_from(&parsed);
    let row = run_cell(&cell);
    println!(
        "instance: {} n={} p={} ccr={} alpha={} beta={} gamma={}",
        row.workload, row.n, row.p, row.ccr, row.alpha, row.beta_pct, row.gamma
    );
    println!(
        "CPL: ceft={:.2} cpop_est={:.2} cpop_realized={:.2} minexec={:.2} cp_min={:.2}",
        row.cpl_ceft, row.cpl_cpop, row.cpl_cpop_realized, row.cpl_minexec, row.cp_min
    );
    let mut t = ceft::util::csv::Table::new(vec![
        "algorithm", "makespan", "speedup", "slr", "slack",
    ]);
    for (i, a) in ALGOS.iter().enumerate() {
        let r = &row.algos[i];
        t.push_row(vec![
            a.to_string(),
            format!("{:.2}", r.makespan),
            format!("{:.3}", r.speedup),
            format!("{:.3}", r.slr),
            format!("{:.2}", r.slack),
        ]);
    }
    print!("{}", t.to_ascii());
    if parsed.is_set("gantt") {
        let (platform, inst) = build_instance(&cell);
        let s = ceft::sched::ceft_cpop::CeftCpop.schedule(inst.bind(&platform));
        println!("\nCEFT-CPOP Gantt:");
        print!("{}", ceft::sched::gantt::render(&s, 100));
    }
    0
}

fn cmd_cp(tokens: &[String]) -> i32 {
    let args = instance_args("repro cp", "print CEFT vs CPOP critical paths");
    let parsed = parse_or_exit(args, tokens);
    let cell = cell_from(&parsed);
    let (platform, inst) = build_instance(&cell);
    // one ctx for both queries: panels computed once, arenas pooled
    let ctx = ceft::model::PlatformCtx::new(platform);
    let ceft_cp = find_critical_path(inst.bind_ctx(&ctx));
    let (cpop_cp, cpop_len) = cpop_critical_path(inst.bind_ctx(&ctx));
    println!("CEFT critical path (length {:.2}):", ceft_cp.length);
    for s in &ceft_cp.path {
        println!("  task {:>5} -> class {}", s.task, s.class);
    }
    println!("\nCPOP critical path (mean-value estimate {cpop_len:.2}):");
    println!(
        "  tasks: {:?} (all pinned to one processor by CPOP)",
        cpop_cp
    );
    0
}

fn cmd_gengraph(tokens: &[String]) -> i32 {
    let args = instance_args("repro gengraph", "emit a generated instance")
        .opt("format", Some("json"), "json | dot");
    let parsed = parse_or_exit(args, tokens);
    let cell = cell_from(&parsed);
    let (platform, inst) = build_instance(&cell);
    match parsed.req("format") {
        "json" => println!("{}", io::instance_to_json(&inst).to_string()),
        "dot" => {
            let cp = find_critical_path(inst.bind(&platform));
            print!("{}", io::to_dot(&inst.graph, &cp.tasks()));
        }
        other => {
            eprintln!("unknown format {other:?}");
            return 2;
        }
    }
    0
}

fn cmd_serve(tokens: &[String]) -> i32 {
    let args = Args::new("repro serve", "run the online scheduling engine")
        .opt(
            "addr",
            None,
            "TCP listen address (e.g. 127.0.0.1:7077); omit to serve stdin/stdout",
        )
        .opt(
            "cache-capacity",
            Some("1024"),
            "LRU entries per result cache (also bounds interned instances)",
        )
        .opt("threads", None, "worker threads (default: all cores)")
        .opt(
            "batch-window",
            Some("8"),
            "max critical-path requests per gathered cross-request sweep (1 disables)",
        )
        .opt(
            "metrics-addr",
            None,
            "HTTP listen address for Prometheus-style metrics (e.g. 127.0.0.1:9077)",
        )
        .opt(
            "admission-budget",
            None,
            "pin the per-shard in-flight miss budget (default: p99-governed)",
        )
        .opt(
            "fault-plan",
            None,
            "seeded fault-injection plan, e.g. seed=1,kernel_panic=3x2,delay=7:40x3,conn_drop=5x1 \
             (also honours CEFT_FAULT)",
        );
    let p = parse_or_exit(args, tokens);
    let cache_capacity: usize = num_or_exit(&p, "cache-capacity", None);
    // `None` lets the engine fall back to the CEFT_FAULT environment switch
    let fault = match p.get("fault-plan") {
        Some(spec) => match FaultPlan::parse(spec) {
            Ok(plan) => Some(plan),
            Err(e) => {
                eprintln!("bad --fault-plan: {e}");
                return 2;
            }
        },
        None => None,
    };
    let config = EngineConfig {
        cache_capacity,
        intern_capacity: cache_capacity,
        threads: num_or_exit(&p, "threads", Some(pool::default_threads())),
        batch_window: num_or_exit(&p, "batch-window", None),
        telemetry: None,
        admission_budget: p
            .get("admission-budget")
            .map(|_| num_or_exit(&p, "admission-budget", None)),
        fault,
    };
    let engine = Arc::new(Engine::new(config));
    if let Some(maddr) = p.get("metrics-addr") {
        match serve_metrics(engine.clone(), maddr) {
            Ok(a) => eprintln!("repro serve: metrics on http://{a}/metrics"),
            Err(e) => {
                eprintln!("metrics bind {maddr}: {e}");
                return 1;
            }
        }
    }
    match p.get("addr") {
        Some(addr) => {
            let server = match Server::bind(engine, addr) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("bind {addr}: {e}");
                    return 1;
                }
            };
            match server.local_addr() {
                Ok(a) => eprintln!("repro serve: listening on {a}"),
                Err(_) => eprintln!("repro serve: listening on {addr}"),
            }
            match server.run() {
                Ok(()) => 0,
                Err(e) => {
                    eprintln!("serve failed: {e}");
                    1
                }
            }
        }
        None => match serve_stdio(&engine) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("serve failed: {e}");
                1
            }
        },
    }
}

/// Minimal HTTP/1.0 metrics endpoint on its own listener thread: every
/// request, whatever the path, gets the engine's current Prometheus-style
/// exposition. One short-lived connection per scrape — the protocol both
/// Prometheus' scraper and `curl` speak — so there is no keep-alive state
/// to manage, and a stuck client can at worst hold one accept slot.
fn serve_metrics(engine: Arc<Engine>, addr: &str) -> std::io::Result<std::net::SocketAddr> {
    let listener = std::net::TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { continue };
            // best-effort drain of the request head; the response does not
            // depend on it
            let mut head = [0u8; 1024];
            let _ = stream.read(&mut head);
            let body = engine.prometheus_text();
            let resp = format!(
                "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
                 Content-Length: {}\r\n\r\n{body}",
                body.len()
            );
            let _ = stream.write_all(resp.as_bytes());
        }
    });
    Ok(local)
}

/// Send one line to a TCP server and read one response line.
fn send_request(addr: &str, line: &str) -> Result<String, String> {
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| format!("connect {addr}: {e}"))?;
    writeln!(stream, "{line}").map_err(|e| format!("send: {e}"))?;
    stream.flush().map_err(|e| format!("send: {e}"))?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader
        .read_line(&mut resp)
        .map_err(|e| format!("receive: {e}"))?;
    if resp.is_empty() {
        return Err("server closed the connection without responding".to_string());
    }
    Ok(resp.trim_end().to_string())
}

/// Is this response a structured refusal the client should retry? Shed and
/// deadline refusals clear once the queue drains; `internal_panic` means a
/// co-batched fault took this request down with it — the work itself is
/// fine on a fresh attempt.
fn retryable_refusal(resp: &str) -> Option<u64> {
    let j = Json::parse(resp).ok()?;
    if j.get("ok") != Some(&Json::Bool(false)) {
        return None;
    }
    match j.get("error").and_then(Json::as_str) {
        Some("shed") | Some("deadline_exceeded") | Some("internal_panic") => Some(
            j.get("retry_after_ms")
                .and_then(Json::as_f64)
                .map(|ms| ms as u64)
                .unwrap_or(0),
        ),
        _ => None,
    }
}

/// Deterministically jittered exponential backoff: 20ms · 2^attempt plus a
/// spread derived from the attempt index, floored by the server's
/// `retry_after_ms` hint when one came back.
fn backoff_for(attempt: u32, hint_ms: u64) -> std::time::Duration {
    let base = 20u64.saturating_mul(1 << attempt.min(6));
    let jitter = (attempt as u64).wrapping_mul(7919) % (base / 2 + 1);
    std::time::Duration::from_millis((base + jitter).max(hint_ms))
}

/// [`send_request`] plus a bounded retry loop over transport errors
/// (connection drops) and retryable structured refusals.
fn send_request_retrying(addr: &str, line: &str, retries: u32) -> Result<String, String> {
    let mut attempt = 0u32;
    loop {
        let (outcome, hint_ms) = match send_request(addr, line) {
            Ok(resp) => match retryable_refusal(&resp) {
                Some(hint) => (Ok(resp), Some(hint)),
                None => return Ok(resp),
            },
            Err(e) => (Err(e), Some(0)),
        };
        if attempt >= retries {
            return outcome;
        }
        std::thread::sleep(backoff_for(attempt, hint_ms.unwrap_or(0)));
        attempt += 1;
    }
}

fn cmd_request(tokens: &[String]) -> i32 {
    let args = instance_args("repro request", "send one request to a running `repro serve`")
        .opt("addr", Some("127.0.0.1:7077"), "server address")
        .opt(
            "op",
            Some("schedule"),
            "ping | submit | cp | schedule | update | stats | trace | metrics | evict | clear | shutdown",
        )
        .opt("algorithm", Some("CEFT-CPOP"), "scheduler for --op schedule")
        .opt(
            "limit",
            Some("8"),
            "slowest/most-recent traces to return for --op trace",
        )
        .opt(
            "id",
            None,
            "instance handle from a previous submit (skips instance generation)",
        )
        .opt(
            "slack",
            Some("false"),
            "for --op cp: also return the per-task slack array",
        )
        .opt(
            "edits",
            None,
            "for --op update: JSON array of edit objects, e.g. \
             '[{\"edit\":\"task_cost\",\"task\":3,\"costs\":[2.0,1.5]}]'",
        )
        .opt(
            "deadline-ms",
            None,
            "for cp/schedule/update: relative deadline in milliseconds",
        )
        .opt(
            "retries",
            Some("0"),
            "retry transport errors and shed/deadline_exceeded/internal_panic refusals \
             with jittered exponential backoff",
        );
    let parsed = parse_or_exit(args, tokens);
    let op = parsed.req("op").to_string();
    let deadline_ms: Option<u64> = parsed
        .get("deadline-ms")
        .map(|_| num_or_exit(&parsed, "deadline-ms", None));
    let retries: u32 = num_or_exit(&parsed, "retries", None);
    let parse_id = |s: &str| match ceft::service::protocol::parse_handle(s) {
        Ok(id) => id,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let target = || -> Target {
        match parsed.get("id") {
            Some(id) => Target::Handle(parse_id(id)),
            None => {
                let (platform, inst) = build_instance(&cell_from(&parsed));
                Target::Inline {
                    instance: inst,
                    platform: Some(platform),
                }
            }
        }
    };
    let req = match op.as_str() {
        "ping" => Request::Ping,
        "stats" => Request::Stats,
        "trace" => Request::Trace {
            limit: num_or_exit(&parsed, "limit", None),
        },
        "metrics" => Request::Metrics,
        "clear" => Request::Clear,
        "shutdown" => Request::Shutdown,
        "evict" => match parsed.get("id") {
            Some(id) => Request::Evict { id: parse_id(id) },
            None => {
                eprintln!("--op evict requires --id");
                return 2;
            }
        },
        "submit" => {
            if parsed.get("id").is_some() {
                eprintln!("--op submit does not take --id (it creates handles)");
                return 2;
            }
            let (platform, inst) = build_instance(&cell_from(&parsed));
            Request::Submit {
                instance: inst,
                platform: Some(platform),
            }
        }
        "cp" => Request::CriticalPath {
            target: target(),
            slack: parsed.req("slack") == "true",
            deadline_ms,
        },
        "update" => {
            let id = match parsed.get("id") {
                Some(id) => parse_id(id),
                None => {
                    eprintln!("--op update requires --id (updates are handle-only)");
                    return 2;
                }
            };
            let edits_json = match parsed.get("edits") {
                Some(e) => e,
                None => {
                    eprintln!("--op update requires --edits");
                    return 2;
                }
            };
            let edits = match Json::parse(edits_json)
                .map_err(|e| e.to_string())
                .and_then(|j| {
                    j.as_arr()
                        .ok_or_else(|| "--edits must be a JSON array".to_string())
                        .and_then(|arr| {
                            arr.iter()
                                .map(ceft::service::protocol::edit_from_json)
                                .collect::<Result<Vec<_>, _>>()
                        })
                }) {
                Ok(e) if !e.is_empty() => e,
                Ok(_) => {
                    eprintln!("--edits must contain at least one edit");
                    return 2;
                }
                Err(e) => {
                    eprintln!("bad --edits: {e}");
                    return 2;
                }
            };
            Request::Update {
                id,
                edits,
                deadline_ms,
            }
        }
        "schedule" => {
            let algorithm = match Algorithm::parse(parsed.req("algorithm")) {
                Ok(a) => a,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            Request::Schedule {
                algorithm,
                target: target(),
                deadline_ms,
            }
        }
        other => {
            eprintln!("unknown op {other:?}");
            return 2;
        }
    };
    let line = ceft::service::request_to_json(&req).to_string();
    match send_request_retrying(parsed.req("addr"), &line, retries) {
        Ok(resp) => match Json::parse(&resp) {
            Ok(j) if j.get("ok") == Some(&Json::Bool(true)) => {
                // human-oriented renderings for the observability ops;
                // every other response is already a one-line summary
                match op.as_str() {
                    "trace" => print_trace(&j),
                    "metrics" => match j.get("text").and_then(Json::as_str) {
                        Some(text) => print!("{text}"),
                        None => println!("{resp}"),
                    },
                    _ => println!("{resp}"),
                }
                0
            }
            _ => {
                println!("{resp}");
                1
            }
        },
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

/// Render a `trace` response as stage-latency and kernel-path tables plus
/// the slowest request breakdowns (the raw JSON is a `stats`-sized blob;
/// the table is what a human scanning for a regression wants).
fn print_trace(resp: &Json) {
    let field = |j: &Json, k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "telemetry: {}",
        resp.get("telemetry").and_then(Json::as_str).unwrap_or("?")
    );
    let mut stage_table = ceft::util::csv::Table::new(vec![
        "stage", "count", "p50_us", "p95_us", "p99_us", "max_us", "mean_us",
    ]);
    if let Some(stages) = resp.get("stages") {
        for stage in ceft::obs::Stage::ALL {
            let Some(h) = stages.get(stage.name()) else {
                continue;
            };
            stage_table.push_row(vec![
                stage.name().to_string(),
                format!("{}", field(h, "count")),
                format!("{:.1}", field(h, "p50_us")),
                format!("{:.1}", field(h, "p95_us")),
                format!("{:.1}", field(h, "p99_us")),
                format!("{:.1}", field(h, "max_us")),
                format!("{:.1}", field(h, "mean_us")),
            ]);
        }
    }
    print!("{}", stage_table.to_ascii());
    if let Some(paths) = resp.get("kernel_paths") {
        let mut path_table =
            ceft::util::csv::Table::new(vec!["kernel_path", "calls", "cells", "cells_per_s"]);
        for p in ceft::obs::KernelPath::ALL {
            let Some(k) = paths.get(p.name()) else {
                continue;
            };
            path_table.push_row(vec![
                p.name().to_string(),
                format!("{}", field(k, "calls")),
                format!("{}", field(k, "cells")),
                format!("{:.3e}", field(k, "cells_per_s")),
            ]);
        }
        print!("{}", path_table.to_ascii());
    }
    if let Some(slowest) = resp.get("slowest").and_then(Json::as_arr) {
        println!("slowest requests:");
        for r in slowest {
            println!(
                "  {op:>9} {total:>10.1} µs  {stages}",
                op = r.get("op").and_then(Json::as_str).unwrap_or("?"),
                total = field(r, "total_us"),
                stages = r
                    .get("stages_us")
                    .map(|s| s.to_string())
                    .unwrap_or_default()
            );
        }
    }
}

/// Shared configuration for one `repro loadgen` invocation — everything
/// except the `--cp-share` value, which varies per sweep point.
struct LoadgenCfg {
    count: usize,
    platform_mix: usize,
    rate: f64,
    duration_s: f64,
    algo: Algorithm,
    cache_capacity: usize,
    threads_cfg: usize,
    batch_window: usize,
    /// concurrent dispatchers driving `Engine::handle_line`. Batching only
    /// opens when in-flight misses reach the worker-thread count, so this
    /// must exceed `threads_cfg` for the gather path to be reachable.
    clients: usize,
    /// fraction of the instance mix that also receives in-place `update`
    /// traffic (tail-decile cost edits, see [`EditSpec`])
    edit_share: f64,
    /// instance family of the mix: "layered", "fork-join", "pipeline" or
    /// "mix" — structured families exercise the SP tree-DP fast path, and
    /// a pure fork-join run gates on `shape_fast_path_hits > 0`
    shape: String,
}

/// One edited instance in the loadgen mix: `update` requests flip task
/// `task` of instance `index` between cost rows `a` and `b`. The task is
/// chosen from the **tail decile of the topological order**, so any
/// delta-served recompute may touch at most `bound` rows — the acceptance
/// invariant `repro loadgen --edit-share` counter-verifies per response.
struct EditSpec {
    index: usize,
    task: usize,
    a: Vec<f64>,
    b: Vec<f64>,
    bound: usize,
}

/// What one replay point hands back to [`cmd_loadgen`] for the sweep-level
/// gates and the report file.
struct LoadgenPoint {
    entry: Json,
    batched_requests: f64,
    batch_efficiency: f64,
    failures: u64,
}

fn cmd_loadgen(tokens: &[String]) -> i32 {
    let args = instance_args(
        "repro loadgen",
        "replay generated instances against an in-process engine",
    )
    .opt("count", Some("16"), "distinct instances in the replay mix")
    .opt(
        "shape",
        Some("layered"),
        "instance family: layered (RGG), fork-join, pipeline, or mix \
         (round-robin of all three); structured families route through \
         the series-parallel tree-DP fast path",
    )
    .opt(
        "platform-mix",
        Some("1"),
        "distinct platforms round-robined across the instance mix",
    )
    .opt("rate", Some("1000"), "target requests/sec")
    .opt("duration", Some("3"), "seconds to run (per sweep point)")
    .opt("algorithm", Some("CEFT-CPOP"), "scheduler to request")
    .opt(
        "cp-share",
        Some("0.25"),
        "fraction of the mix replayed as critical-path requests; a comma \
         list (e.g. 0.0,0.25,1.0) sweeps the mix, one report point each",
    )
    .opt(
        "edit-share",
        Some("0.0"),
        "fraction of instances that also receive in-place update traffic \
         (cost edits on a tail-decile task, exercising delta-CEFT)",
    )
    .opt("cache-capacity", Some("4096"), "LRU entries per result cache")
    .opt("threads", None, "worker threads (default: all cores)")
    .opt(
        "batch-window",
        Some("8"),
        "max table requests per gathered cross-request sweep (1 disables)",
    )
    .opt(
        "clients",
        Some("0"),
        "concurrent request dispatchers (0 = 2x worker threads)",
    )
    .opt(
        "json-out",
        Some("BENCH_service.json"),
        "machine-readable report path (\"none\" to disable)",
    )
    .flag(
        "chaos",
        "after the replay, run an overload/fault pass: seeded fault injection \
         + per-request deadlines at 4x dispatch oversubscription, gated on \
         availability and bit-identical surviving results",
    )
    .opt(
        "fault-plan",
        Some("seed=1,kernel_panic=1x2,delay=3:30x2"),
        "fault-injection plan for the --chaos pass",
    )
    .opt(
        "deadline-ms",
        Some("100"),
        "per-request deadline carried by the --chaos pass",
    )
    .opt(
        "retries",
        Some("4"),
        "per-request retry budget for internal_panic refusals under --chaos",
    );
    let parsed = parse_or_exit(args, tokens);
    let count: usize = num_or_exit::<usize>(&parsed, "count", None).max(1);
    let shape_cfg = parsed.req("shape").to_string();
    if !["layered", "fork-join", "pipeline", "mix"].contains(&shape_cfg.as_str()) {
        eprintln!("--shape must be one of layered, fork-join, pipeline, mix");
        return 2;
    }
    let platform_mix: usize = num_or_exit::<usize>(&parsed, "platform-mix", None).max(1);
    let rate: f64 = num_or_exit(&parsed, "rate", None);
    let duration_s: f64 = num_or_exit(&parsed, "duration", None);
    let algo = match Algorithm::parse(parsed.req("algorithm")) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if !(rate > 0.0) || !(duration_s > 0.0) {
        eprintln!("--rate and --duration must be positive");
        return 2;
    }
    let cp_shares: Vec<f64> = match parsed
        .req("cp-share")
        .split(',')
        .map(|s| s.trim().parse::<f64>())
        .collect::<Result<Vec<_>, _>>()
    {
        Ok(v) if !v.is_empty() && v.iter().all(|s| (0.0..=1.0).contains(s)) => v,
        _ => {
            eprintln!("--cp-share must be a comma list of fractions in [0, 1]");
            return 2;
        }
    };
    let edit_share: f64 = num_or_exit(&parsed, "edit-share", None);
    if !(0.0..=1.0).contains(&edit_share) {
        eprintln!("--edit-share must be a fraction in [0, 1]");
        return 2;
    }
    let cache_capacity: usize = num_or_exit(&parsed, "cache-capacity", None);
    let threads_cfg: usize = num_or_exit(&parsed, "threads", Some(pool::default_threads()));
    let batch_window: usize = num_or_exit(&parsed, "batch-window", None);
    let clients_cfg: usize = num_or_exit(&parsed, "clients", None);
    let cfg = LoadgenCfg {
        count,
        platform_mix,
        rate,
        duration_s,
        algo,
        cache_capacity,
        threads_cfg,
        batch_window,
        clients: if clients_cfg == 0 {
            2 * threads_cfg.max(1)
        } else {
            clients_cfg
        },
        edit_share,
        shape: shape_cfg,
    };

    // Build the submit stream once: `count` distinct instances (same grid
    // coordinates, different seeds). With --platform-mix K, instance i runs
    // on platform i mod K (distinct uniform-link platforms, deterministic
    // in K), so each engine's platform-context cache sees exactly K
    // distinct platforms: its panel_ctx_misses must be min(K, count) and
    // every other submit a panel_ctx_hit. Handles are structural hashes, so
    // every sweep point (and the telemetry A/B engines) replays these
    // submits verbatim and gets the same ids back.
    let base = cell_from(&parsed);
    let edit_count = ((count as f64) * cfg.edit_share).ceil() as usize;
    let mut submit_lines = Vec::with_capacity(count);
    let mut inst_shapes: Vec<&'static str> = Vec::with_capacity(count);
    let mut edit_specs: Vec<EditSpec> = Vec::with_capacity(edit_count);
    for i in 0..count {
        let mut cell = base;
        cell.index = base.index + i as u64;
        // Per-instance family: `--shape mix` round-robins all three. The
        // structured families size themselves to the cell's --n (fork-join
        // blocks of width 4, pipelines of 4 replicas) and share the
        // layered generator's cost/edge-data idiom and seed determinism.
        let family = match cfg.shape.as_str() {
            "mix" => ["layered", "fork_join", "pipeline"][i % 3],
            "fork-join" => "fork_join",
            other => other, // "layered" | "pipeline"
        };
        let (platform, inst) = match family {
            "fork_join" => {
                let plat = ceft::platform::Platform::uniform(cell.p, 1.0, 0.0);
                let depth = (cell.n.saturating_sub(1) / 5).max(1);
                let inst = ceft::graph::generate_fork_join(
                    4,
                    depth,
                    cell.ccr,
                    cell.beta_pct,
                    &ceft::platform::CostModel::Classic { beta: 0.5 },
                    &plat,
                    cell.index,
                );
                (plat, inst)
            }
            "pipeline" => {
                let plat = ceft::platform::Platform::uniform(cell.p, 1.0, 0.0);
                let stages = (cell.n.saturating_sub(2) / 4).max(1);
                let inst = ceft::graph::generate_pipeline(
                    stages,
                    4,
                    cell.ccr,
                    cell.beta_pct,
                    &ceft::platform::CostModel::Classic { beta: 0.5 },
                    &plat,
                    cell.index,
                );
                (plat, inst)
            }
            _ => build_instance(&cell),
        };
        inst_shapes.push(family);
        let platform = if platform_mix > 1 {
            // distinct bandwidth per mix slot -> distinct platform hash
            ceft::platform::Platform::uniform(inst.p(), 1.0 + (i % platform_mix) as f64, 0.0)
        } else {
            platform
        };
        if i >= count - edit_count {
            // Edit target: the task sitting `bound` positions before the
            // END of the topological order, so a delta recompute's dirty
            // suffix spans at most `bound` = max(1, n/10) rows — the
            // last-decile acceptance bound. Two cost variants with
            // opposite per-class scaling: flipping between them always
            // changes bits, and for p ≥ 2 the change is never
            // increase-only, so the slack skip rule stays out of the way
            // and every flip exercises the delta kernel.
            let n = inst.graph.num_tasks();
            let bound = (n / 10).max(1);
            let task = inst.graph.topo_order()[n - bound];
            let row = inst.comp.row(task);
            let scale = |k: usize, up: bool| -> f64 {
                if (k % 2 == 0) == up {
                    1.5
                } else {
                    0.5
                }
            };
            edit_specs.push(EditSpec {
                index: i,
                task,
                a: row.iter().enumerate().map(|(k, &c)| c * scale(k, true)).collect(),
                b: row.iter().enumerate().map(|(k, &c)| c * scale(k, false)).collect(),
                bound,
            });
        }
        let line = ceft::service::request_to_json(&Request::Submit {
            instance: inst,
            platform: Some(platform),
        })
        .to_string();
        submit_lines.push(line);
    }
    // One extra, never-replayed instance for the chaos pass's deadline
    // probe: a guaranteed cache miss, so an already-expired budget is
    // refused at the cache probe instead of being served as a hit.
    let probe_submit = {
        let mut cell = base;
        cell.index = base.index + count as u64;
        let (platform, inst) = build_instance(&cell);
        ceft::service::request_to_json(&Request::Submit {
            instance: inst,
            platform: Some(platform),
        })
        .to_string()
    };

    let sweep = cp_shares.len() > 1;
    let mut points: Vec<(f64, LoadgenPoint)> = Vec::with_capacity(cp_shares.len());
    for &share in &cp_shares {
        if sweep {
            println!("--- cp-share {share} ---");
        }
        match loadgen_point(&cfg, &submit_lines, &inst_shapes, &edit_specs, share) {
            Ok(pt) => points.push((share, pt)),
            Err(code) => return code,
        }
    }

    // Sweep gates. Both request kinds now feed the same table-level
    // batcher, so a schedule-heavy point that never gathers means the
    // schedule path fell off the batched sweep — exactly the regression
    // this sweep exists to catch. Only enforced when the configuration can
    // batch at all (window open, dispatchers oversubscribe the workers).
    let batching_possible = cfg.batch_window > 1 && cfg.clients > cfg.threads_cfg.max(1);
    if sweep && batching_possible {
        for (share, pt) in &points {
            if *share <= 0.5 && pt.batched_requests == 0.0 {
                eprintln!(
                    "loadgen: cp-share {share} gathered zero requests — \
                     schedule traffic is not reaching the batcher"
                );
                return 1;
            }
        }
    }
    // Batch-efficiency floor: a schedule-only mix (cp-share 0.0) must hold
    // at least half the efficiency of the cp-only baseline (1.0) — both
    // are the same DP sweeps under the hood. Only judged when the sweep
    // includes both endpoints.
    let eff_at = |s: f64| {
        points
            .iter()
            .find(|(x, _)| *x == s)
            .map(|(_, p)| p.batch_efficiency)
    };
    let floor_ok = match (eff_at(0.0), eff_at(1.0)) {
        (Some(e0), Some(e1)) => e0 >= 0.5 * e1,
        _ => true,
    };
    if sweep {
        for (share, pt) in &points {
            println!(
                "cp-share {share}: efficiency {:.4}, {} gathered, {} table hits",
                pt.batch_efficiency,
                pt.batched_requests,
                pt.entry
                    .get("table_cache_hits")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0)
            );
        }
        if !floor_ok && batching_possible {
            eprintln!(
                "loadgen: batch efficiency at cp-share 0.0 fell below half \
                 the cp-only baseline — schedule batching regressed"
            );
            // the report is still written below so the failure is inspectable
        }
    }

    // Overload/fault pass: its own engines (a fault-free baseline and a
    // faulted twin), so the chaos traffic cannot pollute the perf points
    // above. Runs at the first sweep point's mix.
    let mut chaos_failed = false;
    let mut chaos_entry: Option<Json> = None;
    if parsed.is_set("chaos") {
        let fault_spec = parsed.req("fault-plan");
        let chaos_deadline: u64 = num_or_exit(&parsed, "deadline-ms", None);
        let chaos_retries: u32 = num_or_exit(&parsed, "retries", None);
        match chaos_point(
            &cfg,
            &submit_lines,
            &probe_submit,
            fault_spec,
            chaos_deadline,
            chaos_retries,
            cp_shares[0],
        ) {
            Ok((entry, failed)) => {
                chaos_failed = failed;
                chaos_entry = Some(entry);
            }
            Err(code) => return code,
        }
    }

    let json_out = parsed.req("json-out");
    if json_out != "none" {
        let mut report = if sweep {
            Json::obj(vec![
                ("bench", Json::Str("repro loadgen".to_string())),
                ("sweep", Json::Str("cp_share".to_string())),
                ("algorithm", Json::Str(cfg.algo.name().to_string())),
                (
                    "points",
                    Json::Arr(points.iter().map(|(_, p)| p.entry.clone()).collect()),
                ),
                ("sweep_batch_floor_ok", Json::Bool(floor_ok)),
            ])
        } else {
            points[0].1.entry.clone()
        };
        if let Some(chaos) = &chaos_entry {
            if let Json::Obj(m) = &mut report {
                m.insert("chaos".to_string(), chaos.clone());
            }
        }
        match std::fs::write(json_out, format!("{}\n", report.to_string())) {
            Ok(()) => println!("wrote {json_out}"),
            Err(e) => {
                eprintln!("could not write {json_out}: {e}");
                return 1;
            }
        }
    }
    if points.iter().any(|(_, p)| p.failures > 0)
        || (sweep && batching_possible && !floor_ok)
        || chaos_failed
    {
        1
    } else {
        0
    }
}

/// Run one replay point of `repro loadgen` against a fresh engine (fresh
/// caches, so per-point batching counters are not polluted by the previous
/// mix) and return its report entry plus the values the sweep gates need.
fn loadgen_point(
    cfg: &LoadgenCfg,
    submit_lines: &[String],
    inst_shapes: &[&'static str],
    edit_specs: &[EditSpec],
    cp_share: f64,
) -> Result<LoadgenPoint, i32> {
    let engine = Engine::new(EngineConfig {
        cache_capacity: cfg.cache_capacity,
        intern_capacity: cfg.cache_capacity.max(cfg.count),
        threads: cfg.threads_cfg,
        batch_window: cfg.batch_window,
        // inherit CEFT_TELEMETRY: the same binary serves as both the
        // telemetry smoke (env on) and the zero-overhead check (env off)
        telemetry: None,
        admission_budget: None,
        fault: None,
    });
    let mut ids = Vec::with_capacity(cfg.count);
    for line in submit_lines {
        let (resp, _) = engine.handle_line(line);
        match resp.get("id").and_then(Json::as_str) {
            Some(id) => match ceft::service::protocol::parse_handle(id) {
                Ok(h) => ids.push(h),
                Err(e) => {
                    eprintln!("submit returned a bad handle: {e}");
                    return Err(1);
                }
            },
            None => {
                eprintln!("submit failed: {}", resp.to_string());
                return Err(1);
            }
        }
    }
    // Replay mix: the first ceil(cp_share * count) instances are requested
    // as critical paths, the rest as schedules — both route their CEFT
    // table misses through the engine's cross-request batcher. Deterministic
    // striping, so a given flag set always produces the same request stream.
    let cp_count = ((cfg.count as f64) * cp_share).ceil() as usize;
    let mut lines: Vec<String> = ids
        .iter()
        .enumerate()
        .map(|(i, &id)| {
            let req = if i < cp_count {
                Request::CriticalPath {
                    target: Target::Handle(id),
                    slack: false,
                    deadline_ms: None,
                }
            } else {
                Request::Schedule {
                    algorithm: cfg.algo,
                    target: Target::Handle(id),
                    deadline_ms: None,
                }
            };
            ceft::service::request_to_json(&req).to_string()
        })
        .collect();
    // per-line shape labels (parallel to `lines`), so per-request
    // latencies can bucket into per-shape percentiles
    let mut line_shapes: Vec<&'static str> = inst_shapes.to_vec();
    // In-place edit traffic: each edited instance contributes both cost
    // variants, so every cycle of the ring flips the row's bits and the
    // table miss behind the follow-up cp/schedule is served by a delta
    // recompute over the tail-decile dirty suffix (the first flip per
    // instance has no memoized basis yet and recomputes in full).
    for spec in edit_specs {
        for costs in [&spec.a, &spec.b] {
            let req = Request::Update {
                id: ids[spec.index],
                edits: vec![ceft::graph::edit::GraphEdit::TaskCost {
                    task: spec.task,
                    costs: costs.clone(),
                }],
                deadline_ms: None,
            };
            lines.push(ceft::service::request_to_json(&req).to_string());
            line_shapes.push(inst_shapes[spec.index]);
        }
    }
    debug_assert_eq!(line_shapes.len(), lines.len());

    // Fire in 50ms ticks at the target rate; measure what the engine
    // actually sustains.
    let tick = std::time::Duration::from_millis(50);
    let per_tick = ((cfg.rate * tick.as_secs_f64()).ceil() as usize).max(1);
    // Pre-expanded ring: any window of `per_tick` consecutive requests is a
    // contiguous slice, so the hot loop passes borrowed slices instead of
    // cloning multi-KB strings every tick.
    let ring: Vec<String> = lines
        .iter()
        .cycle()
        .take(lines.len() + per_tick)
        .cloned()
        .collect();
    let deadline = std::time::Duration::from_secs_f64(cfg.duration_s);
    // True per-request latencies: each request is timed individually inside
    // the dispatcher that serves it (dispatch width = cfg.clients, which
    // deliberately oversubscribes the engine's workers so concurrent misses
    // can pile up past the saturation gate), so the percentiles below are
    // per-request, not per-tick averages.
    let mut latencies: Vec<f64> = Vec::new();
    // per-shape latency buckets (keys are the family labels in
    // `line_shapes`); one percentile row per shape present in the mix
    let mut shape_lat: std::collections::HashMap<&'static str, Vec<f64>> =
        std::collections::HashMap::new();
    let threads = engine.threads();
    let mut sent: u64 = 0;
    let mut failures: u64 = 0;
    // update-response accounting: every update reply carries its own
    // delta economy counters, so the tail-decile bound is verified on
    // every single delta-served edit, not just in aggregate
    let bound_max = edit_specs.iter().map(|s| s.bound).max().unwrap_or(0);
    let mut upd_seen: u64 = 0;
    let mut upd_skipped: u64 = 0;
    let mut upd_delta_served: u64 = 0;
    let mut upd_delta_rows: f64 = 0.0;
    let mut upd_full_rows: f64 = 0.0;
    let mut upd_bound_violations: u64 = 0;
    let start = std::time::Instant::now();
    while start.elapsed() < deadline {
        let tick_start = std::time::Instant::now();
        let offset = sent as usize % lines.len();
        let batch = &ring[offset..offset + per_tick];
        let results = pool::parallel_map(batch, cfg.clients, |_, line| {
            let t0 = std::time::Instant::now();
            let (resp, _) = engine.handle_line(line);
            (resp, t0.elapsed().as_secs_f64())
        });
        sent += batch.len() as u64;
        for (j, (resp, secs)) in results.iter().enumerate() {
            let shape = line_shapes[(offset + j) % lines.len()];
            shape_lat.entry(shape).or_default().push(*secs);
            latencies.push(*secs);
            if resp.get("ok") != Some(&Json::Bool(true)) {
                failures += 1;
            } else if let Some(skipped) = resp.get("skipped").and_then(Json::as_bool) {
                // only update replies carry "skipped"
                upd_seen += 1;
                if skipped {
                    upd_skipped += 1;
                } else {
                    let rec = resp
                        .get("delta_rows_recomputed")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0);
                    let full = resp.get("full_rows").and_then(Json::as_f64).unwrap_or(0.0);
                    upd_delta_rows += rec;
                    upd_full_rows += full;
                    if rec < full {
                        // a delta-served recompute: the dirty suffix of a
                        // tail-decile cost edit is at most `bound` rows
                        upd_delta_served += 1;
                        if rec > bound_max as f64 {
                            upd_bound_violations += 1;
                        }
                    }
                }
            }
        }
        if let Some(rest) = tick.checked_sub(tick_start.elapsed()) {
            std::thread::sleep(rest);
        }
    }
    let elapsed = start.elapsed().as_secs_f64();
    if sent == 0 {
        // refuse BEFORE touching the report file: a zero-send run must
        // neither report success nor clobber the previous real measurement
        // with a placeholder-shaped requests:0 record
        eprintln!("loadgen: no requests were sent — refusing to report");
        return Err(1);
    }
    let achieved = sent as f64 / elapsed;
    println!(
        "loadgen: {} requests in {:.2}s -> {:.0} req/s (target {:.0}), {} failures",
        sent, elapsed, achieved, cfg.rate, failures
    );
    // one sort, three percentile reads (latencies are dead after reporting)
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p95, p99, mean_lat, max_lat) = if latencies.is_empty() {
        (0.0, 0.0, 0.0, 0.0, 0.0)
    } else {
        (
            ceft::util::stats::percentile_sorted(&latencies, 50.0),
            ceft::util::stats::percentile_sorted(&latencies, 95.0),
            ceft::util::stats::percentile_sorted(&latencies, 99.0),
            ceft::util::stats::mean(&latencies),
            *latencies.last().unwrap(),
        )
    };
    println!(
        "per-request latency (µs): p50 {:.1}, p95 {:.1}, p99 {:.1}, mean {:.1}, max {:.1}",
        p50 * 1e6,
        p95 * 1e6,
        p99 * 1e6,
        mean_lat * 1e6,
        max_lat * 1e6
    );
    let stats = engine.stats_json();
    let hit_rate = |cache: &str| -> f64 {
        let c = stats.get(cache);
        let hits = c
            .and_then(|c| c.get("hits"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        let misses = c
            .and_then(|c| c.get("misses"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        if hits + misses > 0.0 {
            hits / (hits + misses)
        } else {
            0.0
        }
    };
    let sched_hit_rate = hit_rate("sched_cache");
    println!(
        "cache hit rate: schedule {:.1}%, cp {:.1}%, table {:.1}%",
        sched_hit_rate * 100.0,
        hit_rate("cp_cache") * 100.0,
        hit_rate("table_cache") * 100.0
    );
    // Panel-context counters: panels must be computed once per distinct
    // platform (misses == the number of distinct platforms submitted),
    // never per request.
    let panel_counter = |k: &str| -> f64 {
        stats
            .get("panel_cache")
            .and_then(|c| c.get(k))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    let (panel_hits, panel_misses) = (panel_counter("hits"), panel_counter("misses"));
    // `misses - dedup_hits` = panel builds that got interned (raced
    // duplicate builds count as dedup hits) — exactly the distinct
    // platforms the engine has priced.
    let panel_builds = panel_misses - panel_counter("dedup_hits");
    println!(
        "panel ctx cache: {panel_hits} hits, {panel_misses} misses, \
         {panel_builds} interned panel builds"
    );
    // Cross-request batching: distinct-key CEFT-table misses — whether
    // raised by a critical-path request or a table-consuming scheduler —
    // the engine gathered into shared min-plus sweeps. `batch_efficiency`
    // is the fraction of all replayed requests served inside such a gather
    // — 0.0 on a fully cached mix, rising with concurrent same-platform
    // misses of either kind (see EXPERIMENTS.md §Gathered schedule tables).
    let table_counter = |k: &str| -> f64 {
        stats
            .get("table_cache")
            .and_then(|c| c.get(k))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    let batched_requests = table_counter("batched_requests");
    let batch_width = table_counter("batch_width");
    let (table_hits, table_misses) = (table_counter("hits"), table_counter("misses"));
    let cp_schedule_shares = table_counter("cp_schedule_shares");
    let batch_efficiency = batched_requests / sent as f64;
    println!(
        "cross-request batching: {batched_requests} gathered requests \
         (max width {batch_width}), efficiency {batch_efficiency:.4}, \
         {cp_schedule_shares} cp<->schedule table shares"
    );
    // Delta-recompute economy (engine-wide: update-triggered eager solves
    // AND the delta-planned table misses behind later cp/schedule
    // traffic). `delta_speedup` is the row-count leverage of the
    // incremental path: rows a from-scratch solve would have swept per
    // rows actually recomputed.
    let delta_rows = table_counter("delta_rows_recomputed");
    let delta_full = table_counter("delta_full_rows");
    let delta_speedup = if delta_rows > 0.0 {
        delta_full / delta_rows
    } else {
        0.0
    };
    if cfg.edit_share > 0.0 {
        println!(
            "delta recompute: {upd_seen} updates ({upd_skipped} slack-skipped, \
             {upd_delta_served} delta-served), {delta_rows} of {delta_full} \
             rows recomputed, speedup {delta_speedup:.1}x"
        );
        if upd_seen == 0 {
            eprintln!("loadgen: --edit-share {} sent no updates", cfg.edit_share);
            return Err(1);
        }
        if upd_bound_violations > 0 {
            eprintln!(
                "loadgen: {upd_bound_violations} delta-served updates recomputed \
                 more than the {bound_max}-row tail-decile bound"
            );
            return Err(1);
        }
        if upd_delta_served == 0 {
            eprintln!(
                "loadgen: no update was served by a delta recompute — the \
                 versioned basis never reached the kernel"
            );
            return Err(1);
        }
    }
    // Structured-shape routing: how many table computations the interned
    // verdict sent to the SP tree DP vs the general sweep, plus per-shape
    // latency percentiles. A pure fork-join mix that never engages the
    // fast path is a routing regression, not a slow run — fail it.
    let shapes_counter = |k: &str| -> f64 {
        stats
            .get("shapes")
            .and_then(|c| c.get(k))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    let resil = |k: &str| -> f64 {
        stats
            .get("resilience")
            .and_then(|c| c.get(k))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    let shape_fast_path_hits = shapes_counter("fast_path_hits");
    let shape_general_fallbacks = shapes_counter("general_fallbacks");
    println!(
        "shape routing ({}): {shape_fast_path_hits} fast-path tables, \
         {shape_general_fallbacks} general fallbacks",
        cfg.shape
    );
    if cfg.shape == "fork-join" && shape_fast_path_hits == 0.0 {
        eprintln!(
            "loadgen: pure fork-join workload reported zero shape_fast_path_hits \
             — the SP fast path never engaged"
        );
        return Err(1);
    }
    let per_shape_p99 = {
        let mut rows: Vec<(&'static str, Json)> = shape_lat
            .iter_mut()
            .map(|(&shape, lat)| {
                lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
                (
                    shape,
                    Json::Num(ceft::util::stats::percentile_sorted(lat, 99.0) * 1e6),
                )
            })
            .collect();
        rows.sort_by_key(|&(shape, _)| shape);
        for (shape, p99) in &rows {
            if let Json::Num(v) = p99 {
                println!("  p99 {shape}: {:.1} µs", v);
            }
        }
        Json::obj(rows)
    };
    // With an explicit --platform-mix the distinct-platform count is under
    // our control, so enforce the residency invariant: panels built once
    // per platform, never per request. (Without it, the workload's own
    // platform stream decides — e.g. two-weight families draw a fresh
    // platform per seed — so only the counters are reported.)
    if cfg.platform_mix > 1 && panel_builds as usize != cfg.platform_mix.min(cfg.count) {
        eprintln!(
            "loadgen: {} interned panel builds != distinct platforms {} — panels were rebuilt",
            panel_builds,
            cfg.platform_mix.min(cfg.count)
        );
        return Err(1);
    }
    // Telemetry self-check (only when recording): a replay that parsed,
    // interned, resolved, computed and responded must have samples in
    // every always-on stage, and the batching stages must agree with the
    // batching counters — `queue_wait`/`batch_drain` appear iff requests
    // were actually served through a width ≥ 2 gather.
    let telemetry_on = stats.get("telemetry").and_then(Json::as_str) == Some("on");
    let stage_count = |name: &str| -> f64 {
        stats
            .get("stages")
            .and_then(|s| s.get(name))
            .and_then(|h| h.get("count"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    if telemetry_on {
        for required in ["parse", "intern", "ctx_build", "cache_probe", "respond"] {
            if stage_count(required) == 0.0 {
                eprintln!("loadgen: stage {required:?} recorded no samples — a telemetry hook is dead");
                return Err(1);
            }
        }
        if stage_count("kernel") + stage_count("batch_drain") == 0.0 {
            eprintln!("loadgen: no kernel or batch_drain samples — compute was never attributed");
            return Err(1);
        }
        let queued = stage_count("queue_wait") > 0.0 || stage_count("batch_drain") > 0.0;
        if queued != (batched_requests > 0.0) {
            eprintln!(
                "loadgen: queue_wait/batch_drain samples disagree with \
                 batched_requests = {batched_requests}"
            );
            return Err(1);
        }
    }
    // Telemetry overhead A/B: replay the same mix, hot-cache, against two
    // fresh engines — every hook forced on vs forced off — and compare
    // fixed-work throughput. A serial handle_line loop: no thread-pool
    // scheduling noise, so the delta isolates the hooks themselves (see
    // EXPERIMENTS.md §Telemetry for the protocol and the ≤2% budget).
    let ab_pass = |telemetry: bool| -> Result<f64, String> {
        let eng = Engine::new(EngineConfig {
            cache_capacity: cfg.cache_capacity,
            intern_capacity: cfg.cache_capacity.max(cfg.count),
            threads: cfg.threads_cfg,
            batch_window: cfg.batch_window,
            telemetry: Some(telemetry),
            admission_budget: None,
            fault: None,
        });
        for line in submit_lines {
            let (resp, _) = eng.handle_line(line);
            if resp.get("ok") != Some(&Json::Bool(true)) {
                return Err(format!("A/B submit failed: {}", resp.to_string()));
            }
        }
        // one warm pass computes every miss; the timed rounds then measure
        // the steady state the overhead budget is defined over
        for line in &lines {
            let _ = eng.handle_line(line);
        }
        let rounds = (4000 / lines.len()).max(3);
        let t0 = std::time::Instant::now();
        for _ in 0..rounds {
            for line in &lines {
                let _ = eng.handle_line(line);
            }
        }
        Ok((rounds * lines.len()) as f64 / t0.elapsed().as_secs_f64())
    };
    let (ab_rps_on, ab_rps_off, overhead_pct) = match (ab_pass(true), ab_pass(false)) {
        (Ok(on), Ok(off)) => (on, off, (off / on - 1.0) * 100.0),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("loadgen: {e}");
            return Err(1);
        }
    };
    println!(
        "telemetry A/B (hot cache, serial): on {ab_rps_on:.0} req/s, \
         off {ab_rps_off:.0} req/s, overhead {overhead_pct:+.2}%"
    );
    println!("{}", stats.to_string());
    // Machine-readable perf record, tracked across PRs (see EXPERIMENTS.md
    // §Workspace for the before/after methodology). In sweep mode this
    // entry becomes one element of the report's `points` array.
    let entry = Json::obj(vec![
        ("bench", Json::Str("repro loadgen".to_string())),
        ("algorithm", Json::Str(cfg.algo.name().to_string())),
        ("instances", Json::Num(cfg.count as f64)),
        ("platform_mix", Json::Num(cfg.platform_mix as f64)),
        ("cp_share", Json::Num(cp_share)),
        ("panel_ctx_hits", Json::Num(panel_hits)),
        ("panel_ctx_misses", Json::Num(panel_misses)),
        ("batched_requests", Json::Num(batched_requests)),
        ("batch_width", Json::Num(batch_width)),
        ("batch_efficiency", Json::Num(batch_efficiency)),
        ("table_cache_hits", Json::Num(table_hits)),
        ("table_cache_misses", Json::Num(table_misses)),
        ("cp_schedule_shares", Json::Num(cp_schedule_shares)),
        ("edit_share", Json::Num(cfg.edit_share)),
        ("updates", Json::Num(upd_seen as f64)),
        ("updates_skipped", Json::Num(upd_skipped as f64)),
        ("updates_delta_served", Json::Num(upd_delta_served as f64)),
        ("update_delta_rows", Json::Num(upd_delta_rows)),
        ("update_full_rows", Json::Num(upd_full_rows)),
        ("delta_rows_recomputed", Json::Num(delta_rows)),
        ("delta_full_rows", Json::Num(delta_full)),
        ("delta_speedup", Json::Num(delta_speedup)),
        ("shape", Json::Str(cfg.shape.clone())),
        ("shape_fast_path_hits", Json::Num(shape_fast_path_hits)),
        (
            "shape_general_fallbacks",
            Json::Num(shape_general_fallbacks),
        ),
        ("per_shape_p99_us", per_shape_p99),
        ("threads", Json::Num(threads as f64)),
        ("clients", Json::Num(cfg.clients as f64)),
        ("target_rps", Json::Num(cfg.rate)),
        ("duration_s", Json::Num(elapsed)),
        ("requests", Json::Num(sent as f64)),
        ("failures", Json::Num(failures as f64)),
        ("achieved_rps", Json::Num(achieved)),
        (
            "latency_us",
            Json::obj(vec![
                ("p50", Json::Num(p50 * 1e6)),
                ("p95", Json::Num(p95 * 1e6)),
                ("p99", Json::Num(p99 * 1e6)),
                ("mean", Json::Num(mean_lat * 1e6)),
                ("max", Json::Num(max_lat * 1e6)),
            ]),
        ),
        ("schedule_cache_hit_rate", Json::Num(sched_hit_rate)),
        (
            "telemetry",
            Json::Str(if telemetry_on { "on" } else { "off" }.to_string()),
        ),
        // per-stage latency percentiles from the engine's recorder
        // (µs; empty histograms when the env switch is off)
        (
            "stages",
            stats.get("stages").cloned().unwrap_or_else(|| Json::obj(vec![])),
        ),
        ("ab_rps_on", Json::Num(ab_rps_on)),
        ("ab_rps_off", Json::Num(ab_rps_off)),
        ("telemetry_overhead_pct", Json::Num(overhead_pct)),
        // Resilience counters, always present so overload gates can grep
        // any report: all zero on a fault-free, undeadlined replay, and a
        // plain replay counts every ok response as available.
        (
            "availability_pct",
            Json::Num((sent - failures) as f64 / sent as f64 * 100.0),
        ),
        ("shed_requests", Json::Num(resil("shed_requests"))),
        ("deadline_expired", Json::Num(resil("deadline_expired"))),
        ("panics_caught", Json::Num(resil("panics_caught"))),
        ("queue_rejects", Json::Num(resil("queue_rejects"))),
        ("retries", Json::Num(0.0)),
    ]);
    Ok(LoadgenPoint {
        entry,
        batched_requests,
        batch_efficiency,
        failures,
    })
}

/// The `--chaos` overload/fault pass. Three phases on two engines:
///
/// 1. a fault-free baseline computes the reference bits for every request
///    in the mix and its p99 at the same 4× oversubscribed dispatch width;
/// 2. a faulted twin replays the mix with per-request deadlines — injected
///    kernel panics are retried with jittered backoff, shed/deadline
///    refusals count as available-with-error, every surviving answer must
///    be bit-identical to the baseline, and an expired-budget probe against
///    a never-cached instance pins the deadline path deterministically;
/// 3. the plan is disarmed, the caches and interned instances dropped, and
///    the whole mix recomputed from scratch on the SAME engine — a faulted
///    past must leave no numeric residue.
///
/// Returns the chaos report entry plus whether any gate failed (the report
/// is still written either way so the failure is inspectable).
fn chaos_point(
    cfg: &LoadgenCfg,
    submit_lines: &[String],
    probe_submit: &str,
    fault_spec: &str,
    deadline_ms: u64,
    retries: u32,
    cp_share: f64,
) -> Result<(Json, bool), i32> {
    let plan = match FaultPlan::parse(fault_spec) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("bad --fault-plan: {e}");
            return Err(2);
        }
    };
    // 4× the worker pool: enough dispatchers that misses pile up past the
    // saturation gate and the queue actually forms under injected delays
    let clients = cfg.threads_cfg.max(1) * 4;
    let mk_engine = |fault: Option<FaultPlan>| {
        Engine::new(EngineConfig {
            cache_capacity: cfg.cache_capacity,
            intern_capacity: cfg.cache_capacity.max(cfg.count + 1),
            threads: cfg.threads_cfg,
            batch_window: cfg.batch_window,
            telemetry: None,
            admission_budget: None,
            fault,
        })
    };
    let submit_all = |eng: &Engine| -> Result<Vec<u64>, i32> {
        let mut ids = Vec::with_capacity(submit_lines.len());
        for line in submit_lines {
            let (resp, _) = eng.handle_line(line);
            match resp
                .get("id")
                .and_then(Json::as_str)
                .and_then(|id| ceft::service::protocol::parse_handle(id).ok())
            {
                Some(h) => ids.push(h),
                None => {
                    eprintln!("chaos submit failed: {}", resp.to_string());
                    return Err(1);
                }
            }
        }
        Ok(ids)
    };
    let request_lines = |ids: &[u64], deadline: Option<u64>| -> Vec<String> {
        let cp_count = ((ids.len() as f64) * cp_share).ceil() as usize;
        ids.iter()
            .enumerate()
            .map(|(i, &id)| {
                let req = if i < cp_count {
                    Request::CriticalPath {
                        target: Target::Handle(id),
                        slack: false,
                        deadline_ms: deadline,
                    }
                } else {
                    Request::Schedule {
                        algorithm: cfg.algo,
                        target: Target::Handle(id),
                        deadline_ms: deadline,
                    }
                };
                ceft::service::request_to_json(&req).to_string()
            })
            .collect()
    };
    // the f64 the request exists to produce; bit-compared, not
    // epsilon-compared — the determinism contract is exact
    let value_bits = |resp: &Json| -> Option<u64> {
        resp.get("length")
            .or_else(|| resp.get("makespan"))
            .and_then(Json::as_f64)
            .map(f64::to_bits)
    };

    // Phase 1 — fault-free baseline: reference bits (serial warm pass),
    // then the unshedded p99 at the same dispatch width.
    let baseline = mk_engine(None);
    let ids = submit_all(&baseline)?;
    let plain = request_lines(&ids, None);
    let mut expected: Vec<u64> = Vec::with_capacity(plain.len());
    for line in &plain {
        let (resp, _) = baseline.handle_line(line);
        match value_bits(&resp) {
            Some(bits) => expected.push(bits),
            None => {
                eprintln!("chaos baseline request failed: {}", resp.to_string());
                return Err(1);
            }
        }
    }
    let rounds = (512 / plain.len().max(1)).max(4);
    let mut base_lat: Vec<f64> = Vec::new();
    for _ in 0..rounds {
        let timed = pool::parallel_map(&plain, clients, |_, line| {
            let t0 = std::time::Instant::now();
            let (resp, _) = baseline.handle_line(line);
            (
                resp.get("ok") == Some(&Json::Bool(true)),
                t0.elapsed().as_secs_f64(),
            )
        });
        for (ok, secs) in timed {
            if !ok {
                eprintln!("chaos baseline replay failed");
                return Err(1);
            }
            base_lat.push(secs);
        }
    }

    // Phase 2 — the faulted twin under deadlines. Round 0 absorbs the cold
    // misses (and, with the default plan, the injected panics); its
    // latencies are excluded from the p99 comparison but every round counts
    // toward availability.
    let chaos = mk_engine(Some(plan));
    let chaos_ids = submit_all(&chaos)?;
    if chaos_ids != ids {
        // handles are structural hashes; a mismatch means interning broke
        eprintln!("chaos: replay handles diverged from the baseline's");
        return Err(1);
    }
    let deadlined = request_lines(&chaos_ids, Some(deadline_ms));
    let mut served: u64 = 0;
    let mut refused: u64 = 0; // shed + deadline_exceeded: available-with-error
    let mut unavailable: u64 = 0;
    let mut total_retries: u64 = 0;
    let mut chaos_bit_identical = true;
    let mut served_lat: Vec<f64> = Vec::new();
    for round in 0..rounds {
        let results = pool::parallel_map(&deadlined, clients, |_, line| {
            let mut attempts = 0u32;
            loop {
                let t0 = std::time::Instant::now();
                let (resp, _) = chaos.handle_line(line);
                let secs = t0.elapsed().as_secs_f64();
                if resp.get("ok") == Some(&Json::Bool(true)) {
                    return (Some(resp), secs, attempts, false);
                }
                let err = resp.get("error").and_then(Json::as_str).unwrap_or("");
                // shed / deadline refusals are the overload design working:
                // available-with-error, no retry; a panic-poisoned answer
                // is retried with backoff
                if err == "shed" || err == "deadline_exceeded" {
                    return (None, secs, attempts, false);
                }
                if err == "internal_panic" && attempts < retries {
                    let hint = resp
                        .get("retry_after_ms")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0) as u64;
                    std::thread::sleep(backoff_for(attempts, hint));
                    attempts += 1;
                    continue;
                }
                return (None, secs, attempts, true);
            }
        });
        for (i, (resp, secs, attempts, exhausted)) in results.into_iter().enumerate() {
            total_retries += attempts as u64;
            match resp {
                Some(resp) => {
                    served += 1;
                    if round > 0 {
                        served_lat.push(secs);
                    }
                    if value_bits(&resp) != Some(expected[i]) {
                        chaos_bit_identical = false;
                    }
                }
                None if exhausted => unavailable += 1,
                None => refused += 1,
            }
        }
    }
    // Deadline probe: a fresh, never-computed instance with an
    // already-expired budget — a deterministic deadline_exceeded no matter
    // how the replay's races landed.
    let (resp, _) = chaos.handle_line(probe_submit);
    let probe_id = match resp
        .get("id")
        .and_then(Json::as_str)
        .and_then(|id| ceft::service::protocol::parse_handle(id).ok())
    {
        Some(h) => h,
        None => {
            eprintln!("chaos probe submit failed: {}", resp.to_string());
            return Err(1);
        }
    };
    let probe_line = ceft::service::request_to_json(&Request::CriticalPath {
        target: Target::Handle(probe_id),
        slack: false,
        deadline_ms: Some(0),
    })
    .to_string();
    let (resp, _) = chaos.handle_line(&probe_line);
    if resp.get("error").and_then(Json::as_str) != Some("deadline_exceeded") {
        eprintln!(
            "chaos: expired-budget probe was not refused with deadline_exceeded: {}",
            resp.to_string()
        );
        return Err(1);
    }
    refused += 1;

    let stats = chaos.stats_json();
    let resil = |k: &str| -> f64 {
        stats
            .get("resilience")
            .and_then(|c| c.get(k))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    let (fired_panics, fired_delays, fired_drops) =
        chaos.fault().map(|f| f.fired()).unwrap_or((0, 0, 0));

    // Phase 3 — post-fault determinism on the same engine: disarm, drop
    // everything (results AND interned instances), recompute from scratch.
    if let Some(f) = chaos.fault() {
        f.disarm();
    }
    let (resp, _) = chaos.handle_line(r#"{"op":"clear"}"#);
    if resp.get("ok") != Some(&Json::Bool(true)) {
        eprintln!("chaos: clear failed: {}", resp.to_string());
        return Err(1);
    }
    let replay_ids = submit_all(&chaos)?;
    let replay = request_lines(&replay_ids, None);
    let mut post_fault_bit_identical = true;
    for (i, line) in replay.iter().enumerate() {
        let (resp, _) = chaos.handle_line(line);
        if value_bits(&resp) != Some(expected[i]) {
            post_fault_bit_identical = false;
        }
    }

    let total = served + refused + unavailable;
    let availability_pct = (total - unavailable) as f64 / total.max(1) as f64 * 100.0;
    base_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    served_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let baseline_p99 = if base_lat.is_empty() {
        0.0
    } else {
        ceft::util::stats::percentile_sorted(&base_lat, 99.0)
    };
    let served_p99 = if served_lat.is_empty() {
        0.0
    } else {
        ceft::util::stats::percentile_sorted(&served_lat, 99.0)
    };

    let mut failed = false;
    {
        let mut gate = |ok: bool, msg: String| {
            if !ok {
                eprintln!("chaos gate failed: {msg}");
                failed = true;
            }
        };
        gate(
            fired_panics + fired_delays + fired_drops > 0,
            "the fault plan never fired — the chaos pass was vacuous".to_string(),
        );
        gate(
            availability_pct >= 99.0,
            format!("availability {availability_pct:.2}% < 99%"),
        );
        gate(
            chaos_bit_identical,
            "a surviving answer diverged from the fault-free baseline".to_string(),
        );
        gate(
            post_fault_bit_identical,
            "the post-fault from-scratch replay diverged from the baseline".to_string(),
        );
        gate(
            resil("deadline_expired") > 0.0,
            "no deadline ever expired (probe included)".to_string(),
        );
        if fired_panics > 0 {
            gate(
                resil("panics_caught") > 0.0,
                "injected kernel panics were not caught".to_string(),
            );
            gate(
                total_retries > 0,
                "panicked requests were never retried".to_string(),
            );
        }
        // served tail no worse than the unshedded baseline's, with a small
        // absolute floor so µs-scale hot-cache noise cannot trip the ratio
        gate(
            served_p99 <= baseline_p99 * 1.5 + 200e-6,
            format!(
                "served p99 {:.1}µs blew past the unshedded baseline's {:.1}µs",
                served_p99 * 1e6,
                baseline_p99 * 1e6
            ),
        );
    }

    println!(
        "chaos: {total} requests at {clients} clients — {served} served, \
         {refused} refused (shed/deadline), {unavailable} unavailable, \
         {total_retries} retries; availability {availability_pct:.2}%"
    );
    println!(
        "chaos: injected {fired_panics} panics / {fired_delays} delays / \
         {fired_drops} drops; caught {} panics, {} deadline-expired, {} shed; \
         served p99 {:.1}µs vs baseline {:.1}µs; bit-identical: chaos {}, \
         post-fault {}",
        resil("panics_caught"),
        resil("deadline_expired"),
        resil("shed_requests"),
        served_p99 * 1e6,
        baseline_p99 * 1e6,
        chaos_bit_identical,
        post_fault_bit_identical
    );
    let entry = Json::obj(vec![
        ("fault_plan", Json::Str(fault_spec.to_string())),
        ("deadline_ms", Json::Num(deadline_ms as f64)),
        ("clients", Json::Num(clients as f64)),
        ("requests", Json::Num(total as f64)),
        ("served", Json::Num(served as f64)),
        ("refused", Json::Num(refused as f64)),
        ("unavailable", Json::Num(unavailable as f64)),
        ("retries", Json::Num(total_retries as f64)),
        ("availability_pct", Json::Num(availability_pct)),
        ("shed_requests", Json::Num(resil("shed_requests"))),
        ("deadline_expired", Json::Num(resil("deadline_expired"))),
        ("panics_caught", Json::Num(resil("panics_caught"))),
        ("queue_rejects", Json::Num(resil("queue_rejects"))),
        ("injected_kernel_panics", Json::Num(fired_panics as f64)),
        ("injected_delays", Json::Num(fired_delays as f64)),
        ("injected_conn_drops", Json::Num(fired_drops as f64)),
        ("chaos_bit_identical", Json::Bool(chaos_bit_identical)),
        (
            "post_fault_bit_identical",
            Json::Bool(post_fault_bit_identical),
        ),
        ("served_p99_us", Json::Num(served_p99 * 1e6)),
        ("baseline_p99_us", Json::Num(baseline_p99 * 1e6)),
        ("gates_passed", Json::Bool(!failed)),
    ]);
    Ok((entry, failed))
}

fn cmd_runtime_check(tokens: &[String]) -> i32 {
    let args = Args::new(
        "repro runtime-check",
        "load PJRT artifacts and cross-validate vs pure-rust CEFT",
    )
    .opt("p", Some("8"), "processor count (artifact to test)")
    .opt("n", Some("128"), "tasks in the validation instance");
    let parsed = parse_or_exit(args, tokens);
    let p: usize = parsed.get_parse("p").unwrap();
    let n: usize = parsed.get_parse("n").unwrap();
    let rt = match ceft::runtime::PjrtRuntime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT client failed: {e}");
            return 1;
        }
    };
    println!("PJRT platform: {}", rt.platform_name());
    if !rt.has_artifact(p) {
        eprintln!(
            "artifact {} missing — run `make artifacts` first",
            ceft::runtime::artifact_name(p)
        );
        return 1;
    }
    let acc = ceft::runtime::AcceleratedCeft::new(rt);
    let cells = grid(Workload::RggClassic, Scale::Smoke);
    let mut cell = cells[0];
    cell.n = n;
    cell.p = p;
    let (platform, inst) = build_instance(&cell);
    // both backends share one PlatformCtx: the CPU kernel reads its
    // resident panels, the accelerator its f32 marshals
    let ctx = ceft::model::PlatformCtx::new(platform);
    let cpu = find_critical_path(inst.bind_ctx(&ctx));
    match acc.find_critical_path(inst.bind_ctx(&ctx)) {
        Ok(accel) => {
            let rel = (cpu.length - accel.length).abs() / cpu.length.max(1e-12);
            println!(
                "pure-rust CPL = {:.4}, accelerated CPL = {:.4}, rel diff = {:.2e}",
                cpu.length, accel.length, rel
            );
            if rel < 1e-4 && cpu.tasks() == accel.tasks() {
                println!("runtime-check OK (paths identical, lengths within f32 tolerance)");
                0
            } else {
                eprintln!("runtime-check FAILED");
                1
            }
        }
        Err(e) => {
            eprintln!("accelerated CEFT failed: {e}");
            1
        }
    }
}
